//! Fig. 7 case study: side-by-side book-summary continuations from full
//! verification and SpecPV, with divergence markers — the qualitative
//! view of what partial verification loses and keeps.
//!
//! ```bash
//! cargo run --release --example case_study
//! ```

use specpv::config::{Config, EngineKind};
use specpv::engine::{self, GenRequest};
use specpv::metrics::rouge_l;
use specpv::backend;
use specpv::{corpus, tokenizer};

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let be = backend::from_config(&cfg)?;

    let book = corpus::novel_text(0xB00C, 3000);
    let prompt = corpus::summarize_prompt(&book);
    let req = GenRequest::greedy(tokenizer::encode(&prompt), 200);

    let mut full_cfg = cfg.clone();
    full_cfg.engine = EngineKind::SpecFull;
    let full = engine::generate_with(&full_cfg, be.as_ref(), &req)?;

    let mut pv_cfg = cfg.clone();
    pv_cfg.engine = EngineKind::SpecPv;
    pv_cfg.specpv.retrieval_budget = 256;
    let pv = engine::generate_with(&pv_cfg, be.as_ref(), &req)?;

    // first divergence point
    let ft = full.tokens.clone();
    let pt = pv.tokens.clone();
    let div = ft.iter().zip(&pt).take_while(|(a, b)| a == b).count();

    println!("================ Full verification ================");
    println!("{}", full.text());
    println!("\n================ SpecPV-256 =======================");
    println!("{}", pv.text());
    println!("\n---------------------------------------------------");
    println!(
        "identical prefix: {div}/{} tokens; ROUGE-L similarity {:.1}",
        ft.len().min(pt.len()),
        rouge_l(&pv.text(), &full.text())
    );
    println!(
        "speed: full {:.1} tok/s vs SpecPV {:.1} tok/s ({:.2}x)",
        full.stats.throughput(),
        pv.stats.throughput(),
        pv.stats.throughput() / full.stats.throughput().max(1e-9)
    );
    Ok(())
}
