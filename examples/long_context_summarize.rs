//! Long-document summarization (the paper's GovReport/QMSum scenario):
//! generates a synthetic report, produces a continuation-summary with
//! full verification and with SpecPV under several budgets, and prints
//! the similarity metrics of paper Table 2.
//!
//! ```bash
//! cargo run --release --example long_context_summarize [-- <ctx_bytes>]
//! ```

use specpv::config::{Config, EngineKind};
use specpv::engine::{self, GenRequest};
use specpv::metrics::{bleurt_proxy, rouge_l};
use specpv::backend;
use specpv::{corpus, tokenizer};

fn main() -> anyhow::Result<()> {
    let ctx: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2800);
    let cfg = Config::default();
    let be = backend::from_config(&cfg)?;

    let doc = corpus::report_text(0xD0C, ctx);
    let prompt = corpus::summarize_prompt(&doc);
    let req = GenRequest::greedy(tokenizer::encode(&prompt), 160);

    let mut full_cfg = cfg.clone();
    full_cfg.engine = EngineKind::SpecFull;
    let full = engine::generate_with(&full_cfg, be.as_ref(), &req)?;
    println!("=== full verification ===\n{}\n", full.text());

    println!("| budget | ROUGE-L | BLEURT* | tok/s | refreshes |");
    println!("|---|---|---|---|---|");
    for budget in [512usize, 256, 64] {
        let mut c = cfg.clone();
        c.engine = EngineKind::SpecPv;
        c.specpv.retrieval_budget = budget;
        let r = engine::generate_with(&c, be.as_ref(), &req)?;
        println!(
            "| {budget} | {:.1} | {:.1} | {:.1} | {} |",
            rouge_l(&r.text(), &full.text()),
            bleurt_proxy(&r.text(), &full.text()),
            r.stats.throughput(),
            r.stats.refresh_steps,
        );
        if budget == 256 {
            println!("\n=== SpecPV-256 ===\n{}\n", r.text());
        }
    }
    Ok(())
}
