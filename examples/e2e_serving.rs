//! End-to-end serving driver (the DESIGN.md §5 validation run): starts
//! the TCP server with the trained model, submits a mixed batch of
//! long-context requests through **concurrent** client connections (one
//! of them streaming), and reports per-request latency/TTFT plus
//! aggregate throughput — the serving-paper analogue of "load a small
//! real model and serve batched requests". The server interleaves the
//! generations at decode-round granularity (continuous batching), so the
//! requests genuinely share the device instead of queuing.
//!
//! ```bash
//! cargo run --release --example e2e_serving
//! ```
//! The measured numbers are recorded in EXPERIMENTS.md §E2E.

use std::thread;

use specpv::config::Config;
use specpv::json::Json;
use specpv::backend;
use specpv::server::{serve, Client};
use specpv::{corpus, util::Stopwatch};

fn main() -> anyhow::Result<()> {
    let cfg = Config {
        server_addr: "127.0.0.1:7799".into(),
        max_active: 4,
        ..Config::default()
    };
    let addr = cfg.server_addr.clone();

    let server = thread::spawn(move || {
        // the server thread owns its backend (device handles are !Send)
        let be = backend::from_config(&cfg).expect("backend");
        serve(be.as_ref(), cfg).expect("server");
    });
    // workload: continuation + summarization + needle QA, mixed engines
    let mut jobs: Vec<(String, String, usize)> = Vec::new();
    for seed in 0..2u64 {
        jobs.push((
            format!("continue/{seed}"),
            corpus::continuation_prompt(seed, 1400),
            96,
        ));
    }
    jobs.push((
        "summarize".into(),
        corpus::summarize_prompt(&corpus::report_text(9, 1200)),
        96,
    ));
    let qa = corpus::needle_qa(17, 1200, 6);
    jobs.push(("needle_qa".into(), format!("{}{}", qa.context, qa.question), 12));

    let sw = Stopwatch::new();
    // all jobs in flight at once, each on its own connection; the last
    // one streams and counts its incremental deltas
    let handles: Vec<_> = jobs
        .into_iter()
        .enumerate()
        .map(|(i, (name, prompt, max_new))| {
            let addr = addr.clone();
            thread::spawn(move || -> anyhow::Result<(String, &'static str, Json, usize)> {
                let engine = if i % 2 == 0 { "spec_pv" } else { "spec_full" };
                let mut client = connect_retry(&addr);
                if i == 3 {
                    let (steps, fin) =
                        client.generate_stream(&prompt, max_new, engine)?;
                    let deltas =
                        steps.iter().filter(|j| j.get("delta").is_some()).count();
                    Ok((name, engine, fin, deltas))
                } else {
                    let r = client.generate(&prompt, max_new, engine)?;
                    Ok((name, engine, r, 0))
                }
            })
        })
        .collect();

    let mut total_tokens = 0usize;
    println!("| request | engine | tokens | latency | ttft | tok/s | tau | modes F/P/R | stream deltas |");
    println!("|---|---|---|---|---|---|---|---|---|");
    for h in handles {
        let (name, engine, r, deltas) = h.join().expect("client thread")?;
        anyhow::ensure!(
            r.get("ok").and_then(|x| x.as_bool()) == Some(true),
            "request failed: {r:?}"
        );
        let tokens = r.get("tokens").and_then(|x| x.as_usize()).unwrap_or(0);
        total_tokens += tokens;
        let modes = r.get("modes").cloned().unwrap_or(Json::Null);
        println!(
            "| {name} | {engine} | {tokens} | {:.2}s | {:.2}s | {:.1} | {:.2} | {}/{}/{} | {deltas} |",
            r.get("latency_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
            r.get("ttft_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
            r.get("tok_per_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
            r.get("tau").and_then(|x| x.as_f64()).unwrap_or(0.0),
            modes.get("full").and_then(|x| x.as_i64()).unwrap_or(0),
            modes.get("partial").and_then(|x| x.as_i64()).unwrap_or(0),
            modes.get("refresh").and_then(|x| x.as_i64()).unwrap_or(0),
        );
    }
    let wall = sw.total();
    let mut client = connect_retry(&addr);
    let m = client.metrics()?;
    println!(
        "\naggregate: {total_tokens} tokens in {wall:.1}s = {:.1} tok/s end-to-end",
        total_tokens as f64 / wall
    );
    println!("server: {}", m.get("summary").and_then(|x| x.as_str()).unwrap_or("?"));
    client.shutdown()?;
    drop(client);
    server.join().unwrap();
    Ok(())
}

/// Retry the connect until the server thread has bound the listener.
fn connect_retry(addr: &str) -> Client {
    for _ in 0..50 {
        if let Ok(c) = Client::connect(addr) {
            return c;
        }
        thread::sleep(std::time::Duration::from_millis(100));
    }
    panic!("server did not come up on {addr}");
}
