//! End-to-end serving driver (the DESIGN.md §5 validation run): starts
//! the TCP server with the trained model, submits a mixed batch of
//! long-context requests through the real client protocol, and reports
//! per-request latency plus aggregate throughput — the serving-paper
//! analogue of "load a small real model and serve batched requests".
//!
//! ```bash
//! cargo run --release --example e2e_serving
//! ```
//! The measured numbers are recorded in EXPERIMENTS.md §E2E.

use std::thread;
use std::time::Duration;

use specpv::config::Config;
use specpv::json::Json;
use specpv::runtime::Runtime;
use specpv::server::{serve, Client};
use specpv::{corpus, util::Stopwatch};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.server_addr = "127.0.0.1:7799".into();
    let addr = cfg.server_addr.clone();

    let server = thread::spawn(move || {
        let rt = Runtime::new(&cfg.artifacts_dir).expect("runtime");
        serve(&rt, cfg).expect("server");
    });
    thread::sleep(Duration::from_millis(500));

    let mut client = Client::connect(&addr)?;
    // workload: continuation + summarization + needle QA, mixed engines
    let mut jobs: Vec<(String, String, usize)> = Vec::new();
    for seed in 0..2u64 {
        jobs.push((
            format!("continue/{seed}"),
            corpus::continuation_prompt(seed, 1400),
            96,
        ));
    }
    jobs.push((
        "summarize".into(),
        corpus::summarize_prompt(&corpus::report_text(9, 1200)),
        96,
    ));
    let qa = corpus::needle_qa(17, 1200, 6);
    jobs.push(("needle_qa".into(), format!("{}{}", qa.context, qa.question), 12));

    let sw = Stopwatch::new();
    let mut total_tokens = 0usize;
    println!("| request | engine | tokens | latency | tok/s | tau | modes F/P/R |");
    println!("|---|---|---|---|---|---|---|");
    for (i, (name, prompt, max_new)) in jobs.iter().enumerate() {
        let engine = if i % 2 == 0 { "spec_pv" } else { "spec_full" };
        let r = client.generate(prompt, *max_new, engine)?;
        anyhow::ensure!(
            r.get("ok").and_then(|x| x.as_bool()) == Some(true),
            "request failed: {r:?}"
        );
        let tokens = r.get("tokens").and_then(|x| x.as_usize()).unwrap_or(0);
        total_tokens += tokens;
        let modes = r.get("modes").cloned().unwrap_or(Json::Null);
        println!(
            "| {name} | {engine} | {tokens} | {:.2}s | {:.1} | {:.2} | {}/{}/{} |",
            r.get("latency_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
            r.get("tok_per_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
            r.get("tau").and_then(|x| x.as_f64()).unwrap_or(0.0),
            modes.get("full").and_then(|x| x.as_i64()).unwrap_or(0),
            modes.get("partial").and_then(|x| x.as_i64()).unwrap_or(0),
            modes.get("refresh").and_then(|x| x.as_i64()).unwrap_or(0),
        );
    }
    let wall = sw.total();
    let m = client.call(Json::obj().set("op", "metrics"))?;
    println!(
        "\naggregate: {total_tokens} tokens in {wall:.1}s = {:.1} tok/s end-to-end",
        total_tokens as f64 / wall
    );
    println!("server: {}", m.get("summary").and_then(|x| x.as_str()).unwrap_or("?"));
    client.shutdown()?;
    server.join().unwrap();
    Ok(())
}
