//! Needle-in-a-haystack QA (the paper's Fig. 5 scenario): facts buried
//! in a long context; the model must retrieve the queried one. Shows how
//! partial-KV retrieval quality depends on the budget.
//!
//! ```bash
//! cargo run --release --example needle_qa [-- <ctx_bytes> <n_instances>]
//! ```

use specpv::config::{Config, EngineKind};
use specpv::engine::{self, GenRequest};
use specpv::metrics::exact_match;
use specpv::backend;
use specpv::{corpus, tokenizer};

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let ctx: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(5);
    let cfg = Config::default();
    let be = backend::from_config(&cfg)?;

    println!("| method | hits | accuracy |");
    println!("|---|---|---|");
    for budget in [None, Some(512), Some(256), Some(64)] {
        let mut c = cfg.clone();
        match budget {
            None => c.engine = EngineKind::SpecFull,
            Some(b) => {
                c.engine = EngineKind::SpecPv;
                c.specpv.retrieval_budget = b;
            }
        }
        let mut hits = 0usize;
        for i in 0..n {
            let qa = corpus::needle_qa(100 + i as u64, ctx, 8);
            let prompt = format!("{}{}", qa.context, qa.question);
            let req = GenRequest::greedy(tokenizer::encode(&prompt), 12);
            let r = engine::generate_with(&c, be.as_ref(), &req)?;
            let text = r.text();
            let got = text
                .split_whitespace()
                .next()
                .unwrap_or("")
                .trim_matches(|ch: char| !ch.is_alphanumeric());
            if exact_match(got, &qa.answer) {
                hits += 1;
            } else if i == 0 {
                eprintln!("  miss: wanted {:?}, got {:?}", qa.answer, got);
            }
        }
        let label = match budget {
            None => "full".to_string(),
            Some(b) => format!("SpecPV-{b}"),
        };
        println!("| {label} | {hits}/{n} | {:.0}% |", hits as f64 / n as f64 * 100.0);
    }
    Ok(())
}
