//! Quickstart: generate a continuation with SpecPV and print the
//! efficiency telemetry. Runs on the AOT artifacts when present and on
//! the pure-Rust reference backend otherwise, so it works on a fresh
//! checkout:
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use specpv::config::{Config, EngineKind};
use specpv::engine::{self, GenRequest};
use specpv::backend;
use specpv::{corpus, tokenizer};

fn main() -> anyhow::Result<()> {
    let cfg = Config {
        engine: EngineKind::SpecPv,
        ..Config::default()
    };
    let be = backend::from_config(&cfg)?;

    // A PG-19-style synthetic prompt long enough for partial verification
    // to engage (budget 512 → core ≈ 608 tokens).
    let prompt = corpus::continuation_prompt(/*seed=*/ 1, /*bytes=*/ 1200);
    println!("--- prompt tail ---\n...{}", &prompt[prompt.len() - 160..]);

    let req = GenRequest::greedy(tokenizer::encode(&prompt), 128);
    let result = engine::generate_with(&cfg, be.as_ref(), &req)?;

    println!("--- SpecPV continuation ---\n{}", result.text());
    let s = &result.stats;
    println!(
        "\n{} new tokens | {:.1} tok/s | accept length τ = {:.2}",
        s.new_tokens,
        s.throughput(),
        s.accept_len()
    );
    println!(
        "verification modes: {} full, {} partial, {} refresh",
        s.full_steps, s.partial_steps, s.refresh_steps
    );
    Ok(())
}
