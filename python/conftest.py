"""Pytest bootstrap: make `compile.*` importable when the suite is run
from the repo root (`python -m pytest python/tests -q`, the CI
invocation) as well as from `python/` directly."""

import sys
from pathlib import Path

_PY_ROOT = str(Path(__file__).resolve().parent)
if _PY_ROOT not in sys.path:
    sys.path.insert(0, _PY_ROOT)
