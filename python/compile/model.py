"""L2 — the JAX compute graphs for the SpecPV stack.

Everything is purely functional: params are pytrees (dict name → array),
KV caches are explicit inputs/outputs so the rust coordinator can thread
them through as device-resident PJRT buffers.

Model family ("specpv-s/m/l"): LLaMA-style pre-norm transformer —
RMSNorm, RoPE (+YARN long-context scaling), MHA, SwiGLU — at char level.
All attention runs through the L1 pallas `tree_attention` kernel, so full
verification, partial verification, AR decode, prefill and the EAGLE draft
layer all share one fused kernel (the SpecPV trick is just the KV bucket
that's passed in).

Draft modules (paper §2/§3.1, appendix A):
  * EAGLE-3-style head: fuses features from a low/mid/top target layer
    with the token embedding, one decoder layer, tied LM head, trained
    with the multi-step training-time-test loss (Eq. 5).
  * Medusa heads (TokenSwift baseline): 3 independent heads off the top
    feature predicting t+1..t+3.
  * Independent tiny 2-layer LM (TriForce baseline draft).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.tree_attention import tree_attention
from .kernels.block_score import block_scores, reduce_scores
from .kernels.ref import tree_attention_ref
from . import data as data_mod

VOCAB = data_mod.VOCAB_SIZE


class ModelCfg(NamedTuple):
    name: str
    n_layer: int
    d_model: int
    n_head: int
    d_head: int
    d_ff: int
    vocab: int = VOCAB
    rope_theta: float = 10000.0
    # YARN long-context scaling (paper appendix A): trained at train_ctx,
    # served at yarn_factor × train_ctx.
    train_ctx: int = 512
    yarn_factor: float = 16.0
    # which layers feed the EAGLE-3 fused feature (low/mid/top)
    feat_layers: tuple = ()

    @property
    def feats(self):
        if self.feat_layers:
            return self.feat_layers
        lo = 0
        mid = self.n_layer // 2
        return (lo, mid, self.n_layer - 1)


# The three evaluation sizes (Table 3 substitute: Qwen3 4B/8B/14B → s/m/l).
SIZES = {
    "s": ModelCfg("s", n_layer=4, d_model=128, n_head=4, d_head=32, d_ff=512),
    "m": ModelCfg("m", n_layer=6, d_model=192, n_head=6, d_head=32, d_ff=768),
    "l": ModelCfg("l", n_layer=8, d_model=256, n_head=8, d_head=32, d_ff=1024),
}

# independent tiny draft LM (TriForce baseline)
TINY = ModelCfg("tiny", n_layer=2, d_model=64, n_head=2, d_head=32, d_ff=256)

DRAFT_SUFFIX = "_draft"


# ---------------------------------------------------------------------------
# RoPE with YARN scaling
# ---------------------------------------------------------------------------

def yarn_inv_freq(cfg: ModelCfg, factor: float):
    """YARN-scaled inverse frequencies + attention temperature (mscale).

    NTK-by-parts: low-frequency dims are interpolated by `factor`, high-
    frequency dims are left alone, with a linear ramp between (Peng et al.
    2023). beta_fast/beta_slow defaults 32/1.
    """
    d = cfg.d_head
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    if factor <= 1.0:
        return inv, 1.0
    L = cfg.train_ctx
    beta_fast, beta_slow = 32.0, 1.0

    def corr_dim(rot):
        return (d * math.log(L / (rot * 2 * math.pi))) / (
            2 * math.log(cfg.rope_theta))

    low = max(math.floor(corr_dim(beta_fast)), 0)
    high = min(math.ceil(corr_dim(beta_slow)), d // 2 - 1)
    ramp = jnp.clip(
        (jnp.arange(d // 2, dtype=jnp.float32) - low) / max(high - low, 1),
        0.0, 1.0)
    # ramp=0 → extrapolate (keep inv), ramp=1 → interpolate (inv/factor)
    inv_scaled = inv / factor
    inv_yarn = inv * (1 - ramp) + inv_scaled * ramp
    mscale = 0.1 * math.log(factor) + 1.0
    return inv_yarn, float(mscale)


def rope_apply(x, pos, inv_freq):
    """x: [H, T, D], pos: [T] int32 → rotated x."""
    ang = pos.astype(jnp.float32)[:, None] * inv_freq[None, :]   # [T, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos[None] - x2 * sin[None]
    r2 = x1 * sin[None] + x2 * cos[None]
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------

def _dense(key, fan_in, fan_out):
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * (
        1.0 / math.sqrt(fan_in))


def init_target(cfg: ModelCfg, key) -> dict:
    keys = jax.random.split(key, 4 + cfg.n_layer * 8)
    p = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "ln_f": jnp.ones((cfg.d_model,)),
        "head": _dense(keys[1], cfg.d_model, cfg.vocab),
    }
    hd = cfg.n_head * cfg.d_head
    for i in range(cfg.n_layer):
        k = keys[4 + i * 8:]
        p[f"l{i}.ln1"] = jnp.ones((cfg.d_model,))
        p[f"l{i}.wq"] = _dense(k[0], cfg.d_model, hd)
        p[f"l{i}.wk"] = _dense(k[1], cfg.d_model, hd)
        p[f"l{i}.wv"] = _dense(k[2], cfg.d_model, hd)
        p[f"l{i}.wo"] = _dense(k[3], hd, cfg.d_model)
        p[f"l{i}.ln2"] = jnp.ones((cfg.d_model,))
        p[f"l{i}.wg"] = _dense(k[4], cfg.d_model, cfg.d_ff)
        p[f"l{i}.wu"] = _dense(k[5], cfg.d_model, cfg.d_ff)
        p[f"l{i}.wd"] = _dense(k[6], cfg.d_ff, cfg.d_model)
    return p


def init_draft(cfg: ModelCfg, key) -> dict:
    """EAGLE-3-style draft: fuse 3 target features + token embed → one
    decoder layer → tied target head (the head is NOT duplicated here; the
    executables take the target head as input)."""
    keys = jax.random.split(key, 12)
    hd = cfg.n_head * cfg.d_head
    p = {
        "fuse": _dense(keys[0], 3 * cfg.d_model, cfg.d_model),
        "inp": _dense(keys[1], 2 * cfg.d_model, cfg.d_model),
        "ln1": jnp.ones((cfg.d_model,)),
        "wq": _dense(keys[2], cfg.d_model, hd),
        "wk": _dense(keys[3], cfg.d_model, hd),
        "wv": _dense(keys[4], cfg.d_model, hd),
        "wo": _dense(keys[5], hd, cfg.d_model),
        "ln2": jnp.ones((cfg.d_model,)),
        "wg": _dense(keys[6], cfg.d_model, cfg.d_ff),
        "wu": _dense(keys[7], cfg.d_model, cfg.d_ff),
        "wd": _dense(keys[8], cfg.d_ff, cfg.d_model),
        "ln_f": jnp.ones((cfg.d_model,)),
    }
    return p


def init_medusa(cfg: ModelCfg, key, n_heads: int = 3) -> dict:
    keys = jax.random.split(key, n_heads * 2)
    p = {}
    for i in range(n_heads):
        p[f"m{i}.w1"] = _dense(keys[2 * i], cfg.d_model, cfg.d_model)
        p[f"m{i}.w2"] = _dense(keys[2 * i + 1], cfg.d_model, cfg.vocab)
    return p


# ---------------------------------------------------------------------------
# Core blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, g, eps=1e-5):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _split_heads(x, n_head, d_head):
    T = x.shape[0]
    return x.reshape(T, n_head, d_head).transpose(1, 0, 2)   # [H, T, D]


def _merge_heads(x):
    H, T, D = x.shape
    return x.transpose(1, 0, 2).reshape(T, H * D)


def layer_fwd(p, i, x, pos, kv_l, kv_len, tree_mask, cfg, inv_freq, mscale,
              chunk, prefix=None, attn_impl="pallas", write_pos=None):
    """One transformer layer.

    kv_l: [2, H, B, D] this layer's KV bucket.
    Returns (x_out, kv_l_updated, q_rope) — q_rope is exported for the
    retrieval scorer.
    """
    pfx = f"l{i}." if prefix is None else prefix
    T = x.shape[0]
    h = rmsnorm(x, p[f"{pfx}ln1"])
    xq = _split_heads(h @ p[f"{pfx}wq"], cfg.n_head, cfg.d_head)
    xk = _split_heads(h @ p[f"{pfx}wk"], cfg.n_head, cfg.d_head)
    xv = _split_heads(h @ p[f"{pfx}wv"], cfg.n_head, cfg.d_head)
    xq = rope_apply(xq, pos, inv_freq)
    xk = rope_apply(xk, pos, inv_freq)

    # write new K/V into the bucket at write_pos (functional update).
    # write_pos == kv_len for verification; draft tree levels and the
    # TriForce streaming ring write elsewhere inside/behind the region.
    write_pos = kv_len if write_pos is None else write_pos
    kv_l = jax.lax.dynamic_update_slice(
        kv_l, jnp.stack([xk, xv]), (0, 0, write_pos, 0))

    scale = mscale / math.sqrt(cfg.d_head)
    if attn_impl == "pallas":
        att = tree_attention(
            xq, kv_l[0], kv_l[1], kv_len, tree_mask, sm_scale=scale,
            chunk=chunk)
    else:
        # differentiable jnp path (training); identical semantics, checked
        # against the pallas kernel by python/tests.
        att = tree_attention_ref(xq, kv_l[0], kv_l[1], kv_len, tree_mask,
                                 scale)
    x = x + _merge_heads(att) @ p[f"{pfx}wo"]

    h2 = rmsnorm(x, p[f"{pfx}ln2"])
    x = x + (jax.nn.silu(h2 @ p[f"{pfx}wg"]) * (h2 @ p[f"{pfx}wu"])) @ p[
        f"{pfx}wd"]
    return x, kv_l, xq


def compact_window(kv, kv_len, prev_idx, n_prev, window: int):
    """Acceptance compaction, fused into the next verification step.

    After step k the KV rows of step k's tree live at
    [kv_len, kv_len + T_k) with accepted and rejected rows interleaved.
    Step k+1 receives the accepted row indices (`prev_idx`, within the
    window) and moves row `kv_len + prev_idx[j]` → `kv_len + j` for
    j < n_prev, making the committed region contiguous again before the
    new tokens are appended at `kv_len + n_prev`.

    kv: [L, 2, H, B, D]; prev_idx: [PREV] int32 (PREV ≤ window).
    """
    L, _, H, B, D = kv.shape
    win = jax.lax.dynamic_slice(
        kv, (0, 0, 0, kv_len, 0), (L, 2, H, window, D))
    PREV = prev_idx.shape[0]
    gathered = jnp.take(win, jnp.clip(prev_idx, 0, window - 1), axis=3)
    rows = jnp.arange(PREV, dtype=jnp.int32)
    keep = (rows < n_prev)[None, None, None, :, None]
    head = jnp.where(keep, gathered, jax.lax.dynamic_slice(
        win, (0, 0, 0, 0, 0), (L, 2, H, PREV, D)))
    win = jax.lax.dynamic_update_slice(win, head, (0, 0, 0, 0, 0))
    return jax.lax.dynamic_update_slice(kv, win, (0, 0, 0, kv_len, 0))


def target_fwd(params, cfg: ModelCfg, tokens, pos, kv, kv_len, tree_mask,
               yarn_factor: float, chunk: int = 512, attn_impl="pallas",
               write_pos=None):
    """Target-model forward over a bucketed KV cache.

    Serves prefill (tree_mask = causal chain), AR decode (T=1), full
    verification (bucket = full) and partial verification (bucket = P):
    the executables only differ in the static bucket size B and token
    count T.

    Args:
      tokens:   [T] int32.
      pos:      [T] int32 absolute positions (RoPE).
      kv:       [L, 2, H, B, D] f32.
      kv_len:   () int32 committed length (write offset for new K/V).
      tree_mask:[T, T] f32.

    Returns dict with: logits [T, V], feats [T, 3*d_model] (EAGLE-3 fused
    feature input), queries [L, H, T, D] (retrieval scoring), kv updated.
    """
    inv_freq, mscale = yarn_inv_freq(cfg, yarn_factor)
    x = params["embed"][tokens]
    feats = []
    queries = []
    kv_out = []
    for i in range(cfg.n_layer):
        if i in cfg.feats:
            feats.append(x)
        x, kv_l, xq = layer_fwd(
            params, i, x, pos, kv[i], kv_len, tree_mask, cfg, inv_freq,
            mscale, chunk, attn_impl=attn_impl, write_pos=write_pos)
        kv_out.append(kv_l)
        queries.append(xq)
    # EAGLE-3 takes the *inputs* of the low/mid/top layers plus needs the
    # normalised top output for the LM head.
    xf = rmsnorm(x, params["ln_f"])
    logits = xf @ params["head"]
    fused = jnp.concatenate(feats, axis=-1) if len(feats) == 3 else None
    return {
        "logits": logits,
        "feats": fused,
        "queries": jnp.stack(queries),       # [L, H, T, D]
        "kv": jnp.stack(kv_out),             # [L, 2, H, B, D]
    }


def score_fwd(kv, queries, kv_len, n_queries, *, block_size: int):
    """Retrieval scores for every layer (refresh step).

    kv:      [L, 2, H, B, D]; queries: [L, H, T, D].
    Returns [L, 3, NB]: the three reductions (mean/max/last) stacked, so a
    single compiled executable serves the Table-4 ablation.
    """
    L = kv.shape[0]
    outs = []
    for i in range(L):
        s = block_scores(kv[i, 0], queries[i], kv_len, block_size=block_size)
        outs.append(jnp.stack([
            reduce_scores(s, n_queries, "mean"),
            reduce_scores(s, n_queries, "max"),
            reduce_scores(s, n_queries, "last"),
        ]))
    return jnp.stack(outs)                   # [L, 3, NB]


def gather_fwd(kv, block_idx, *, block_size: int):
    """Assemble the partial-cache core by gathering whole KV blocks.

    kv:        [L, 2, H, B, D] full cache.
    block_idx: [L, NSEL] int32 block ids (sink ++ retrieval ++ local, in
               token order — rust builds this list).
    Returns    [L, 2, H, NSEL*block_size, D].
    """
    L, _, H, B, D = kv.shape
    NB = B // block_size
    kvb = kv.reshape(L, 2, H, NB, block_size, D)

    def per_layer(kv_l, idx_l):
        return jnp.take(kv_l, idx_l, axis=2)     # [2, H, NSEL, bs, D]

    out = jax.vmap(per_layer)(kvb, block_idx)
    L2, _, H2, NSEL, bs, D2 = out.shape
    return out.reshape(L, 2, H, NSEL * block_size, D)


# ---------------------------------------------------------------------------
# EAGLE-3 draft module
# ---------------------------------------------------------------------------

def draft_fwd(dparams, head, embed, cfg: ModelCfg, tokens, feats, pos, kv,
              kv_len, tree_mask, yarn_factor: float, chunk: int = 512,
              attn_impl="pallas", write_pos=None):
    """Draft decoder forward (one EAGLE-3 step over W tree nodes or a
    prefill chunk).

    tokens: [T] int32 — the tokens being *extended from*.
    feats:  [T, 3*d_model] fused target features for those tokens (or the
            draft's own recycled hidden states, pre-tiled to 3h — see
            `recycle`).
    kv:     [2, H, B, D] the draft layer's bucket.
    Returns (logits [T, V], hidden [T, d_model], kv').
    """
    inv_freq, mscale = yarn_inv_freq(cfg, yarn_factor)
    f = feats @ dparams["fuse"]                         # [T, h]
    x = jnp.concatenate([embed[tokens], f], axis=-1) @ dparams["inp"]
    x, kv, _ = layer_fwd(
        dparams, 0, x, pos, kv, kv_len, tree_mask, cfg, inv_freq, mscale,
        chunk, prefix="", attn_impl=attn_impl, write_pos=write_pos)
    hidden = x
    logits = rmsnorm(x, dparams["ln_f"]) @ head
    return logits, hidden, kv


def recycle(hidden):
    """EAGLE-3 feeds its own hidden state back as the 'feature' for tokens
    it drafted itself; we tile it to the 3h fused-feature width."""
    return jnp.concatenate([hidden, hidden, hidden], axis=-1)


def medusa_fwd(mparams, feat, n_heads: int = 3):
    """Medusa heads (TokenSwift baseline): feat [d_model] → [n_heads, V]."""
    outs = []
    for i in range(n_heads):
        h = jax.nn.silu(feat @ mparams[f"m{i}.w1"]) + feat
        outs.append(h @ mparams[f"m{i}.w2"])
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# Training-side helpers (used by train.py; not exported to rust)
# ---------------------------------------------------------------------------

SERVE_YARN = 16.0   # must match aot.YARN_FACTOR — trained == served
MAX_POS = 8192      # serving position range; training offsets cover it


def lm_loss(params, cfg: ModelCfg, batch, offsets=None, chunk: int = 512):
    """Plain next-token loss over [N, S] token batches (teacher forcing).

    Trains with the SERVING YARN factor and random absolute-position
    offsets (one per sequence) so every RoPE angle the serving stack uses
    (positions up to MAX_POS) is in-distribution — the collapsed
    equivalent of the paper's YARN fine-tuning stage (appendix A)."""
    if offsets is None:
        offsets = jnp.zeros((batch.shape[0],), jnp.int32)

    def one(seq, off):
        S = seq.shape[0]
        kv = jnp.zeros((cfg.n_layer, 2, cfg.n_head, S, cfg.d_head))
        out = target_fwd(
            params, cfg, seq, off + jnp.arange(S, dtype=jnp.int32), kv,
            jnp.int32(0), jnp.tril(jnp.ones((S, S), jnp.float32)),
            yarn_factor=SERVE_YARN, chunk=min(chunk, S), attn_impl="jnp")
        logits = out["logits"][:-1]
        tgt = seq[1:]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tgt[:, None], axis=1)[:, 0]
        return jnp.mean(lse - ll), (out["feats"][:-1] if out["feats"] is not
                                    None else None)

    losses, _ = jax.vmap(one)(batch, offsets)
    return jnp.mean(losses)
