"""AOT lowering: every executable of the SpecPV stack → HLO *text*.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

FLAT-STATE ABI.  The CPU PJRT client exposed by the xla crate neither
untuples executable results (multi-output programs come back as ONE tuple
buffer that cannot be re-fed as an input) nor implements CopyRawToHost
(no partial downloads). Every stateful executable therefore has exactly
ONE output: a flat f32 "state" vector with a fixed per-(model, bucket)
layout

    full    state = [ kv(L,2,H,B,D) | logits(256,V) | feats(256,3h) | queries(L,H,64,D) ]
    partial state = [ kv(L,2,H,P,D) | logits(16,V)  | feats(16,3h) ]
    draft   state = [ kv(2,H,B,D)   | logits(4,V)   | hidden(4,h) ]
    tiny    state = [ kv(2,2,H,B,D) | logits_last(V) ]

A variant that produces fewer rows than the region (e.g. T=1 AR decode)
writes its rows at the top and zero-pads the rest. The state buffer is
threaded device-side call-to-call (zero host↔device KV traffic in steady
state); the rust runtime downloads ONLY the outputs of the tiny `read_*`
extractor executables, which slice the small regions out of a state.
Weights are trailing runtime arguments (uploaded once per process);
`manifest.json` records arg order, shapes, layouts and attributes — the
rust side is entirely manifest-driven.

Executable families (see DESIGN.md §4):
  verify_{s}_b{B}_t{T}   target fwd, full bucket (AR decode T=1, tree
                         verify T=16, refresh T=64/192, prefill T=256)
  pverify_{s}_p{P}_t16   partial verification (same graph, small bucket)
  score_{s}_b{B}         retrieval scores (3 reductions) from a full state
  gather_{s}_b{B}_p{P}   full state + block ids → fresh partial state
  draft_prefill_{s}_b{B} EAGLE draft prefill (slices feats from the target
                         state internally — no host round-trip)
  draft_step_{s}_b{B}    EAGLE draft tree-level step (W nodes)
  read_full_{s}_b{B}     state → [logits(64,V) | feats(64,3h)]
  read_last_{s}_b{B}     state, idx → [logits[idx] | feats[idx]]
  read_partial_{s}_p{P}  state → [logits(16,V) | feats(16,3h)]
  read_draft_{s}_b{B}    state → [logits(4,V) | hidden(4,h)]
  medusa_{s}             top feature → 3 Medusa head logits
  verify_tiny_b512_t{T}, read_tiny_b512   TriForce independent draft

Usage: python -m compile.aot --out-dir ../artifacts [--sizes s,m,l]
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

CHUNK = 256              # prefill chunk length == max logits/feats rows
TREE_T = 16              # verification tree size
REFRESH_T = 64           # refresh step capacity (pv tokens + tree)
BIG_REFRESH_T = 192      # fig6 large-buffer ablation (bucket 4096 only)
QROWS = 64               # query rows kept for retrieval scoring
DRAFT_W = 8              # draft slots per call (catch-up chain or level)
DRAFT_REGION = 32        # draft-tree scratch region (max drafted per round)
PREV_MAX = 8             # max accepted rows compacted by a fused verify
PREV_WINDOW = 16         # window the fused compaction gathers from (= TREE_T)
BLOCK = 32               # KV block size (paged cache granularity)
YARN_FACTOR = 16.0

FULL_BUCKETS = [1024, 2048, 4096, 8192]
PARTIAL_BUCKETS = [512, 768, 1280]   # budgets 256/512/1024 + sink/local/buffer
TINY_BUCKET = 512                    # TriForce streaming draft cache

ML_FULL_BUCKETS = [1024, 2048, 4096]
ML_PARTIAL_BUCKETS = [768]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# State layouts (mirrored in rust/src/model.rs; manifest carries the offsets)
# ---------------------------------------------------------------------------

def full_layout(cfg: M.ModelCfg, B: int) -> dict:
    L, H, D, V, h = cfg.n_layer, cfg.n_head, cfg.d_head, cfg.vocab, cfg.d_model
    kv = L * 2 * H * B * D
    logits = CHUNK * V
    feats = CHUNK * 3 * h
    queries = L * H * QROWS * D
    return {"kv": kv, "logits": logits, "feats": feats, "queries": queries,
            "total": kv + logits + feats + queries}


def partial_layout(cfg: M.ModelCfg, P: int) -> dict:
    L, H, D, V, h = cfg.n_layer, cfg.n_head, cfg.d_head, cfg.vocab, cfg.d_model
    kv = L * 2 * H * P * D
    logits = TREE_T * V
    feats = TREE_T * 3 * h
    return {"kv": kv, "logits": logits, "feats": feats, "queries": 0,
            "total": kv + logits + feats}


def draft_layout(cfg: M.ModelCfg, B: int) -> dict:
    # hidden region is CHUNK rows: draft_prefill writes the whole chunk's
    # hidden states (the engine needs the last real prompt row, which may
    # be anywhere in a padded chunk); draft_step writes rows 0..W.
    H, D, V, h = cfg.n_head, cfg.d_head, cfg.vocab, cfg.d_model
    kv = 2 * H * B * D
    logits = DRAFT_W * V
    hidden = CHUNK * h
    return {"kv": kv, "logits": logits, "feats": hidden, "queries": 0,
            "total": kv + logits + hidden}


def tiny_layout(cfg: M.ModelCfg, B: int) -> dict:
    kv = cfg.n_layer * 2 * cfg.n_head * B * cfg.d_head
    return {"kv": kv, "logits": cfg.vocab, "feats": 0, "queries": 0,
            "total": kv + cfg.vocab}


def _pad_rows(x, rows):
    """Pad [T, …] to [rows, …] with zeros (T ≤ rows)."""
    T = x.shape[0]
    if T == rows:
        return x
    pad = [(0, rows - T)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def pack_full(cfg, B, kv, logits, feats, queries):
    T = logits.shape[0]
    q = queries  # [L, H, T, D]
    if T >= QROWS:
        q = q[:, :, :QROWS]
    else:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, QROWS - T), (0, 0)))
    return jnp.concatenate([
        kv.reshape(-1),
        _pad_rows(logits, CHUNK).reshape(-1),
        _pad_rows(feats, CHUNK).reshape(-1),
        q.reshape(-1),
    ])


def pack_partial(cfg, P, kv, logits, feats):
    return jnp.concatenate([
        kv.reshape(-1),
        _pad_rows(logits, TREE_T).reshape(-1),
        _pad_rows(feats, TREE_T).reshape(-1),
    ])


def unpack_kv(state, cfg, B, n_layer=None):
    L = cfg.n_layer if n_layer is None else n_layer
    H, D = cfg.n_head, cfg.d_head
    n = L * 2 * H * B * D
    return state[:n].reshape(L, 2, H, B, D)


def unpack_queries(state, cfg, B):
    lay = full_layout(cfg, B)
    off = lay["kv"] + lay["logits"] + lay["feats"]
    L, H, D = cfg.n_layer, cfg.n_head, cfg.d_head
    return state[off:off + lay["queries"]].reshape(L, H, QROWS, D)


def unpack_feats_row(state, cfg, B, idx):
    lay = full_layout(cfg, B)
    off = lay["kv"] + lay["logits"]
    h3 = 3 * cfg.d_model
    return jax.lax.dynamic_slice(state, (off + idx * h3,), (h3,))


# ---------------------------------------------------------------------------
# Emitter
# ---------------------------------------------------------------------------

class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest: dict = {"executables": {}, "models": {}, "consts": {
            "chunk": CHUNK, "tree_t": TREE_T, "refresh_t": REFRESH_T,
            "big_refresh_t": BIG_REFRESH_T, "qrows": QROWS,
            "draft_w": DRAFT_W, "draft_region": DRAFT_REGION, "block": BLOCK,
            "prev_max": PREV_MAX, "prev_window": PREV_WINDOW,
            "yarn_factor": YARN_FACTOR, "vocab": M.VOCAB,
            "full_buckets": FULL_BUCKETS, "partial_buckets": PARTIAL_BUCKETS,
            "tiny_bucket": TINY_BUCKET,
        }}

    def emit(self, name, fn, arg_specs, arg_names, attrs=None, layout=None):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        with open(os.path.join(self.out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        # jax.jit drops arguments the computation never reads (e.g. the
        # LM head in draft_prefill, whose logits are not emitted); the
        # manifest must record the COMPILED entry signature, so filter by
        # kept_var_idx — the rust runtime passes exactly these.
        kept = lowered._lowering.compile_args.get("kept_var_idx")
        if kept is None:
            kept = set(range(len(arg_specs)))
        args = [
            {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
            for i, (n, s) in enumerate(zip(arg_names, arg_specs))
            if i in kept
        ]
        if len(args) != len(arg_specs):
            dropped = [n for i, n in enumerate(arg_names) if i not in kept]
            print(f"    note: {name} dropped unused args {dropped}")
        self.manifest["executables"][name] = {
            "file": f"{name}.hlo.txt",
            "args": args,
            "attrs": attrs or {},
            "layout": layout,
        }
        print(f"  emitted {name} ({len(text) // 1024} KiB)", flush=True)


def weight_specs(shapes: dict, prefix: str):
    names = sorted(n for n in shapes if n.startswith(prefix))
    return names, [spec(tuple(shapes[n])) for n in names]


def load_weight_shapes(path: str) -> dict:
    shapes = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"SPVW"
        _ver, n = struct.unpack("<II", f.read(8))
        for _ in range(n):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode()
            (nd,) = struct.unpack("<B", f.read(1))
            dims = [struct.unpack("<I", f.read(4))[0] for _ in range(nd)]
            f.seek(4 * int(np.prod(dims)) if dims else 4, 1)
            shapes[name] = dims
    return shapes


def params_from_args(names, args, strip):
    return {n[len(strip):]: a for n, a in zip(names, args)}


# ---------------------------------------------------------------------------
# Families
# ---------------------------------------------------------------------------

def emit_target_family(em, size, cfg, shapes, full_buckets, partial_buckets,
                       t_variants):
    L, H, D = cfg.n_layer, cfg.n_head, cfg.d_head
    V, h = cfg.vocab, cfg.d_model
    wnames, wspecs = weight_specs(shapes, "t.")

    def make_verify(B, T, chunk, partial):
        """Verification step with fused acceptance compaction: the accepted
        rows of the PREVIOUS step's tree (prev_idx, n_prev) are compacted
        into the committed region before the new T tokens are processed and
        appended at kv_len + n_prev."""
        lay = partial_layout(cfg, B) if partial else full_layout(cfg, B)

        def fn(tokens, pos, tree_mask, state, kv_len, prev_idx, n_prev,
               *weights):
            params = params_from_args(wnames, weights, "t.")
            kv = unpack_kv(state, cfg, B)
            kv = M.compact_window(kv, kv_len, prev_idx, n_prev, PREV_WINDOW)
            eff = kv_len + n_prev
            out = M.target_fwd(
                params, cfg, tokens, pos, kv, eff, tree_mask,
                yarn_factor=YARN_FACTOR, chunk=chunk)
            if partial:
                return pack_partial(cfg, B, out["kv"], out["logits"],
                                    out["feats"])
            return pack_full(cfg, B, out["kv"], out["logits"], out["feats"],
                             out["queries"])
        return fn, lay

    for B in full_buckets:
        chunk = 512 if B % 512 == 0 else 256
        lay = full_layout(cfg, B)
        for T in t_variants(B):
            fn, _ = make_verify(B, T, chunk, partial=False)
            em.emit(
                f"verify_{size}_b{B}_t{T}", fn,
                [spec((T,), jnp.int32), spec((T,), jnp.int32), spec((T, T)),
                 spec((lay["total"],)), spec((), jnp.int32),
                 spec((PREV_MAX,), jnp.int32), spec((), jnp.int32), *wspecs],
                ["tokens", "pos", "tree_mask", "state", "kv_len",
                 "prev_idx", "n_prev", *wnames],
                attrs={"family": "verify", "size": size, "bucket": B, "t": T},
                layout=lay)

        # standalone commit (used after Refresh steps, where up to
        # REFRESH_T rows must be compacted before score/gather run)
        for W in ([REFRESH_T, BIG_REFRESH_T] if B == 4096
                  else [REFRESH_T]):
            def commit_fn(state, idx, n, kv_len, W=W):
                kv = unpack_kv(state, cfg, B)
                kv = M.compact_window(kv, kv_len, idx, n, W)
                return jnp.concatenate(
                    [kv.reshape(-1), state[lay["kv"]:]])

            em.emit(f"commit_{size}_b{B}_w{W}", commit_fn,
                    [spec((lay["total"],)), spec((W,), jnp.int32),
                     spec((), jnp.int32), spec((), jnp.int32)],
                    ["state", "idx", "n", "kv_len"],
                    attrs={"family": "commit", "size": size, "bucket": B,
                           "t": W},
                    layout=lay)

        # extractors: a QROWS-row window of logits+feats starting at `start`
        # (start > 0 is used by the large-buffer Refresh ablation where the
        # tree sits past row 64)
        def read_full(state, start):
            lg = jax.lax.dynamic_slice(
                state, (lay["kv"] + start * V,), (QROWS * V,))
            fs = jax.lax.dynamic_slice(
                state, (lay["kv"] + lay["logits"] + start * 3 * h,),
                (QROWS * 3 * h,))
            return jnp.concatenate([lg, fs])

        em.emit(f"read_full_{size}_b{B}", read_full,
                [spec((lay["total"],)), spec((), jnp.int32)],
                ["state", "start"],
                attrs={"family": "read_full", "size": size, "bucket": B,
                       "rows": QROWS})

        def read_last(state, idx):
            lg = jax.lax.dynamic_slice(state, (lay["kv"] + idx * V,), (V,))
            fs = unpack_feats_row(state, cfg, B, idx)
            return jnp.concatenate([lg, fs])

        em.emit(f"read_last_{size}_b{B}", read_last,
                [spec((lay["total"],)), spec((), jnp.int32)],
                ["state", "idx"],
                attrs={"family": "read_last", "size": size, "bucket": B})

        # retrieval scoring (queries sliced from the refresh state)
        NB = B // BLOCK

        def score_fn(state, kv_len, n_queries):
            kv = unpack_kv(state, cfg, B)
            q = unpack_queries(state, cfg, B)
            return M.score_fwd(kv, q, kv_len, n_queries,
                               block_size=BLOCK).reshape(-1)

        em.emit(f"score_{size}_b{B}", score_fn,
                [spec((lay["total"],)), spec((), jnp.int32),
                 spec((), jnp.int32)],
                ["state", "kv_len", "n_queries"],
                attrs={"family": "score", "size": size, "bucket": B,
                       "nb": NB})

        # gather → fresh partial state
        for P in partial_buckets:
            nsel = P // BLOCK
            play = partial_layout(cfg, P)

            def gather_fn(state, idx, P=P, play=play):
                kv = unpack_kv(state, cfg, B)
                pkv = M.gather_fwd(kv, idx, block_size=BLOCK)
                pad = play["total"] - play["kv"]
                return jnp.concatenate(
                    [pkv.reshape(-1), jnp.zeros((pad,), jnp.float32)])

            em.emit(f"gather_{size}_b{B}_p{P}", gather_fn,
                    [spec((lay["total"],)), spec((L, nsel), jnp.int32)],
                    ["state", "block_idx"],
                    attrs={"family": "gather", "size": size, "bucket": B,
                           "p": P, "nsel": nsel},
                    layout=play)

    for P in partial_buckets:
        chunk = 512 if P % 512 == 0 else 256
        play = partial_layout(cfg, P)
        fn, _ = make_verify(P, TREE_T, chunk, partial=True)
        em.emit(
            f"pverify_{size}_p{P}_t{TREE_T}", fn,
            [spec((TREE_T,), jnp.int32), spec((TREE_T,), jnp.int32),
             spec((TREE_T, TREE_T)), spec((play["total"],)),
             spec((), jnp.int32), spec((PREV_MAX,), jnp.int32),
             spec((), jnp.int32), *wspecs],
            ["tokens", "pos", "tree_mask", "state", "kv_len", "prev_idx",
             "n_prev", *wnames],
            attrs={"family": "pverify", "size": size, "bucket": P,
                   "t": TREE_T},
            layout=play)

        def read_partial(state, play=play):
            lg = state[play["kv"]:play["kv"] + TREE_T * V]
            fs = state[play["kv"] + play["logits"]:play["total"]]
            return jnp.concatenate([lg, fs])

        em.emit(f"read_partial_{size}_p{P}", read_partial,
                [spec((play["total"],))], ["state"],
                attrs={"family": "read_partial", "size": size, "bucket": P,
                       "rows": TREE_T})


def emit_draft_family(em, size, cfg, shapes, full_buckets):
    H, D, h, V = cfg.n_head, cfg.d_head, cfg.d_model, cfg.vocab
    dnames, dspecs = weight_specs(shapes, "d.")
    shared = ["t.embed", "t.head"]
    sspecs = [spec(tuple(shapes[n])) for n in shared]

    for B in full_buckets:
        chunk = 512 if B % 512 == 0 else 256
        dlay = draft_layout(cfg, B)
        flay = full_layout(cfg, B)

        # prefill: feats sliced from the TARGET state (device-side)
        def prefill_fn(tokens, tstate, pos, tree_mask, dstate, kv_len,
                       write_pos, *weights, B=B, dlay=dlay, chunk=chunk):
            dp = params_from_args(dnames, weights[:len(dnames)], "d.")
            embed, head = weights[len(dnames)], weights[len(dnames) + 1]
            lay = full_layout(cfg, B)
            off = lay["kv"] + lay["logits"]
            feats = tstate[off:off + CHUNK * 3 * h].reshape(CHUNK, 3 * h)
            kv = dstate[:dlay["kv"]].reshape(2, H, B, D)
            logits, hidden, kv2 = M.draft_fwd(
                dp, head, embed, cfg, tokens, feats, pos, kv, kv_len,
                tree_mask, yarn_factor=YARN_FACTOR, chunk=chunk,
                write_pos=write_pos)
            return jnp.concatenate([
                kv2.reshape(-1),
                jnp.zeros((dlay["logits"],), jnp.float32),
                hidden.reshape(-1),          # full chunk's hidden rows
            ])

        em.emit(
            f"draft_prefill_{size}_b{B}", prefill_fn,
            [spec((CHUNK,), jnp.int32), spec((flay["total"],)),
             spec((CHUNK,), jnp.int32), spec((CHUNK, CHUNK)),
             spec((dlay["total"],)), spec((), jnp.int32),
             spec((), jnp.int32), *dspecs, *sspecs],
            ["tokens", "tstate", "pos", "tree_mask", "dstate", "kv_len",
             "write_pos", *dnames, *shared],
            attrs={"family": "draft_prefill", "size": size, "bucket": B,
                   "t": CHUNK},
            layout=dlay)

        def step_fn(tokens, feats, pos, tree_mask, dstate, kv_len,
                    write_pos, *weights, B=B, dlay=dlay, chunk=chunk):
            dp = params_from_args(dnames, weights[:len(dnames)], "d.")
            embed, head = weights[len(dnames)], weights[len(dnames) + 1]
            kv = dstate[:dlay["kv"]].reshape(2, H, B, D)
            logits, hidden, kv2 = M.draft_fwd(
                dp, head, embed, cfg, tokens, feats, pos, kv, kv_len,
                tree_mask, yarn_factor=YARN_FACTOR, chunk=chunk,
                write_pos=write_pos)
            pad = dlay["feats"] - DRAFT_W * h
            return jnp.concatenate([
                kv2.reshape(-1), logits.reshape(-1), hidden.reshape(-1),
                jnp.zeros((pad,), jnp.float32)])

        em.emit(
            f"draft_step_{size}_b{B}", step_fn,
            [spec((DRAFT_W,), jnp.int32), spec((DRAFT_W, 3 * h)),
             spec((DRAFT_W,), jnp.int32), spec((DRAFT_W, DRAFT_REGION)),
             spec((dlay["total"],)), spec((), jnp.int32),
             spec((), jnp.int32), *dspecs, *sspecs],
            ["tokens", "feats", "pos", "tree_mask", "dstate", "kv_len",
             "write_pos", *dnames, *shared],
            attrs={"family": "draft_step", "size": size, "bucket": B,
                   "t": DRAFT_W, "region": DRAFT_REGION},
            layout=dlay)

        def read_draft(dstate, dlay=dlay):
            lg = dstate[dlay["kv"]:dlay["kv"] + dlay["logits"]]
            off = dlay["kv"] + dlay["logits"]
            hd = dstate[off:off + DRAFT_W * h]
            return jnp.concatenate([lg, hd])

        em.emit(f"read_draft_{size}_b{B}", read_draft,
                [spec((dlay["total"],))], ["dstate"],
                attrs={"family": "read_draft", "size": size, "bucket": B})

        # single hidden row by index (last real prompt token of a padded
        # prefill chunk)
        def read_draft_row(dstate, idx, dlay=dlay):
            off = dlay["kv"] + dlay["logits"]
            return jax.lax.dynamic_slice(dstate, (off + idx * h,), (h,))

        em.emit(f"read_draft_row_{size}_b{B}", read_draft_row,
                [spec((dlay["total"],)), spec((), jnp.int32)],
                ["dstate", "idx"],
                attrs={"family": "read_draft_row", "size": size,
                       "bucket": B})


def emit_medusa(em, size, cfg, shapes):
    mnames, mspecs = weight_specs(shapes, "md.")

    def fn(feat, *weights):
        mp = params_from_args(mnames, weights, "md.")
        return M.medusa_fwd(mp, feat).reshape(-1)

    em.emit(f"medusa_{size}", fn,
            [spec((cfg.d_model,)), *mspecs], ["feat", *mnames],
            attrs={"family": "medusa", "size": size})


def emit_tiny(em, shapes):
    cfg = M.TINY
    B = TINY_BUCKET
    wnames, wspecs = weight_specs(shapes, "t.")
    lay = tiny_layout(cfg, B)

    def make(T):
        def fn(tokens, pos, tree_mask, state, kv_len, write_pos, last_idx,
               *weights):
            params = params_from_args(wnames, weights, "t.")
            kv = unpack_kv(state, cfg, B)
            out = M.target_fwd(
                params, cfg, tokens, pos, kv, kv_len, tree_mask,
                yarn_factor=YARN_FACTOR, chunk=256, write_pos=write_pos)
            last = jax.lax.dynamic_slice(
                out["logits"], (last_idx, 0), (1, cfg.vocab))[0]
            return jnp.concatenate([out["kv"].reshape(-1), last])
        return fn

    for T in (1, CHUNK):
        em.emit(
            f"verify_tiny_b{B}_t{T}", make(T),
            [spec((T,), jnp.int32), spec((T,), jnp.int32), spec((T, T)),
             spec((lay["total"],)), spec((), jnp.int32), spec((), jnp.int32),
             spec((), jnp.int32), *wspecs],
            ["tokens", "pos", "tree_mask", "state", "kv_len", "write_pos",
             "last_idx", *wnames],
            attrs={"family": "verify_tiny", "size": "tiny", "bucket": B,
                   "t": T},
            layout=lay)

    def read_tiny(state):
        return state[lay["kv"]:]

    em.emit(f"read_tiny_b{B}", read_tiny, [spec((lay["total"],))], ["state"],
            attrs={"family": "read_tiny", "size": "tiny", "bucket": B})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="s,m,l")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    em = Emitter(args.out_dir)

    for size in [s for s in args.sizes.split(",") if s]:
        cfg = M.SIZES[size]
        shapes = load_weight_shapes(
            os.path.join(args.out_dir, f"weights_{size}.bin"))
        em.manifest["models"][size] = {
            "n_layer": cfg.n_layer, "d_model": cfg.d_model,
            "n_head": cfg.n_head, "d_head": cfg.d_head, "d_ff": cfg.d_ff,
            "vocab": cfg.vocab, "weights": f"weights_{size}.bin",
            "train_ctx": cfg.train_ctx, "yarn_factor": YARN_FACTOR,
        }
        if size == "s":
            fb, pb = FULL_BUCKETS, PARTIAL_BUCKETS

            def t_variants(B):
                ts = [1, TREE_T, REFRESH_T, CHUNK]
                if B == 4096:
                    ts.append(BIG_REFRESH_T)
                return ts
        else:
            fb, pb = ML_FULL_BUCKETS, ML_PARTIAL_BUCKETS

            def t_variants(B):
                return [1, TREE_T, REFRESH_T, CHUNK]

        print(f"== size {size} ==", flush=True)
        emit_target_family(em, size, cfg, shapes, fb, pb, t_variants)
        emit_draft_family(em, size, cfg, shapes, fb)
        emit_medusa(em, size, cfg, shapes)

    tiny_shapes = load_weight_shapes(
        os.path.join(args.out_dir, "weights_tiny.bin"))
    em.manifest["models"]["tiny"] = {
        "n_layer": M.TINY.n_layer, "d_model": M.TINY.d_model,
        "n_head": M.TINY.n_head, "d_head": M.TINY.d_head,
        "d_ff": M.TINY.d_ff, "vocab": M.TINY.vocab,
        "weights": "weights_tiny.bin", "train_ctx": M.TINY.train_ctx,
        "yarn_factor": YARN_FACTOR,
    }
    emit_tiny(em, tiny_shapes)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(em.manifest, f, indent=1)
    print(f"manifest: {len(em.manifest['executables'])} executables")


if __name__ == "__main__":
    main()
