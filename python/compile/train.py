"""Build-time training for the SpecPV reproduction (runs ONCE; never on
the request path).

Trains, per model size s/m/l:
  1. the target char-LM on the synthetic training mix,
  2. the EAGLE-3-style draft head with the multi-step training-time-test
     loss  L = L0 + a·L1 + a²·L2  (paper Eq. 5, a = 0.8) — this is the
     YARN-fit stage of paper appendix A collapsed into one run (our model
     trains with YARN scaling baked into serving, so there is no separate
     repair phase; the *loss curves* land in artifacts/train_log.json and
     regenerate paper Fig. 8),
  3. Medusa heads (TokenSwift baseline),
plus the independent tiny draft LM (TriForce baseline).

Outputs:
  artifacts/weights_{s,m,l}.bin   (target "t." + draft "d." + medusa "md.")
  artifacts/weights_tiny.bin
  artifacts/train_log.json        (per-phase loss curves + EMA — Fig. 8)

Usage: python -m compile.train --out-dir ../artifacts [--quick] [--sizes s,m,l]
"""

from __future__ import annotations

import argparse
import json
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as M

SEQ = 256
TTT_ALPHA = 0.8
TTT_STEPS = 3  # L0..L2


# ---------------------------------------------------------------------------
# Minimal Adam (optax is unavailable offline)
# ---------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

class Windows:
    """Random fixed-length windows over the synthetic training mix."""

    def __init__(self, seed: int, n_bytes: int = 1 << 21):
        text = data_mod.training_text(seed, n_bytes)
        self.ids = np.frombuffer(
            text.encode("utf-8", errors="replace")[:n_bytes], dtype=np.uint8
        ).astype(np.int32)
        self.rng = np.random.default_rng(seed)

    def batch(self, n: int, seq: int = SEQ):
        starts = self.rng.integers(0, len(self.ids) - seq - 1, n)
        toks = jnp.stack([jnp.array(self.ids[s:s + seq]) for s in starts])
        # random absolute-position offsets: serving positions up to
        # MAX_POS must be in-distribution under the serving YARN factor
        offs = jnp.array(
            self.rng.integers(0, M.MAX_POS - seq, n), jnp.int32)
        return toks, offs


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def _xent(logits, targets):
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[:, None], axis=1)[:, 0]
    return jnp.mean(lse - ll)


def draft_ttt_loss(dparams, tparams, cfg: M.ModelCfg, batch, offsets):
    """EAGLE-3 training-time-test loss over teacher-forced sequences.

    Pass 0 predicts x_{t+1} from (x_t, target feature f_t); pass k>0
    recycles the previous pass's draft hidden state as the feature,
    simulating autoregressive drafting k tokens ahead. Positions carry
    the same random offsets as the target so the draft's YARN RoPE is
    in-distribution at serving positions (paper appendix A).
    """
    def one(seq, off):
        S = seq.shape[0]
        kv = jnp.zeros((cfg.n_layer, 2, cfg.n_head, S, cfg.d_head))
        tout = M.target_fwd(
            tparams, cfg, seq, off + jnp.arange(S, dtype=jnp.int32), kv,
            jnp.int32(0), jnp.tril(jnp.ones((S, S), jnp.float32)),
            yarn_factor=M.SERVE_YARN, chunk=S, attn_impl="jnp")
        feats = jax.lax.stop_gradient(tout["feats"])       # [S, 3h]

        total = 0.0
        cur_feats = feats
        for step in range(TTT_STEPS):
            # tokens shifted by `step`: at TTT step k the draft extends
            # from x_{t+k} (teacher forced) toward x_{t+k+1}
            Sk = S - 1 - step
            toks = jax.lax.dynamic_slice_in_dim(seq, step, Sk)
            tgts = jax.lax.dynamic_slice_in_dim(seq, step + 1, Sk)
            f = cur_feats[:Sk]
            dkv = jnp.zeros((2, cfg.n_head, Sk, cfg.d_head))
            logits, hidden, _ = M.draft_fwd(
                dparams, tparams["head"], tparams["embed"], cfg, toks, f,
                off + jnp.arange(step, step + Sk, dtype=jnp.int32), dkv,
                jnp.int32(0), jnp.tril(jnp.ones((Sk, Sk), jnp.float32)),
                yarn_factor=M.SERVE_YARN, chunk=Sk, attn_impl="jnp")
            total = total + (TTT_ALPHA ** step) * _xent(logits, tgts)
            # recycle: hidden at position t becomes the feature for x_{t+1}
            cur_feats = M.recycle(hidden)
        return total

    return jnp.mean(jax.vmap(one)(batch, offsets))


def medusa_loss(mparams, tparams, cfg: M.ModelCfg, batch, offsets, n_heads=3):
    def one(seq, off):
        S = seq.shape[0]
        kv = jnp.zeros((cfg.n_layer, 2, cfg.n_head, S, cfg.d_head))
        tout = M.target_fwd(
            tparams, cfg, seq, off + jnp.arange(S, dtype=jnp.int32), kv,
            jnp.int32(0), jnp.tril(jnp.ones((S, S), jnp.float32)),
            yarn_factor=M.SERVE_YARN, chunk=S, attn_impl="jnp")
        # top-layer fused slice = input of the final layer
        feats = jax.lax.stop_gradient(tout["feats"][:, 2 * cfg.d_model:])
        total = 0.0
        for h in range(n_heads):
            k = h + 1
            logits = jax.vmap(lambda f: M.medusa_fwd(mparams, f, n_heads)[h])(
                feats[: S - k - 1])
            total = total + _xent(logits, seq[k + 1: S])
        return total / n_heads

    return jnp.mean(jax.vmap(one)(batch, offsets))


# ---------------------------------------------------------------------------
# Serialization: own binary format, mirrored by rust/src/weights.
# ---------------------------------------------------------------------------

def save_weights(path: str, tensors: dict):
    with open(path, "wb") as f:
        f.write(b"SPVW")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name in sorted(tensors):
            arr = np.asarray(tensors[name], dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


# ---------------------------------------------------------------------------
# Training loops
# ---------------------------------------------------------------------------

def run_phase(name, params, loss_fn, windows, steps, batch_size, lr, log):
    state = adam_init(params)
    step_fn = jax.jit(jax.value_and_grad(loss_fn))
    losses = []
    t0 = time.time()
    for i in range(steps):
        batch, offs = windows.batch(batch_size)
        loss, grads = step_fn(params, batch, offs)
        params, state = adam_update(params, grads, state, lr)
        losses.append(float(loss))
        if i % 20 == 0 or i == steps - 1:
            print(f"[{name}] step {i:4d}/{steps} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    ema, es = [], None
    for x in losses:
        es = x if es is None else 0.95 * es + 0.05 * x
        ema.append(es)
    log[name] = {"loss": losses, "ema": ema}
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="s,m,l")
    ap.add_argument("--quick", action="store_true",
                    help="tiny step counts (CI / pytest smoke)")
    ap.add_argument("--steps-target", type=int, default=0)
    ap.add_argument("--steps-draft", type=int, default=0)
    args = ap.parse_args()

    log: dict = {}
    sizes = [s for s in args.sizes.split(",") if s]

    # step budgets per size (1 CPU core → keep ~20 min total)
    budget = {
        "s": (300, 220, 60),    # target, draft, medusa
        "m": (140, 100, 40),
        "l": (100, 80, 30),
    }

    for size in sizes:
        cfg = M.SIZES[size]
        st, sd, sm = budget[size]
        if args.quick:
            st, sd, sm = 3, 3, 2
        if args.steps_target:
            st = args.steps_target
        if args.steps_draft:
            sd = args.steps_draft
        bsz = {"s": 6, "m": 4, "l": 3}[size]
        win = Windows(seed=0xC0FFEE + ord(size))

        key = jax.random.PRNGKey(ord(size))
        tparams = M.init_target(cfg, key)
        tparams = run_phase(
            f"target_{size}", tparams,
            lambda p, b, o: M.lm_loss(p, cfg, b, o, chunk=SEQ),
            win, st, bsz, 3e-3, log)

        dparams = M.init_draft(cfg, jax.random.fold_in(key, 1))
        dparams = run_phase(
            f"draft_{size}", dparams,
            lambda p, b, o: draft_ttt_loss(p, tparams, cfg, b, o),
            win, sd, max(bsz - 2, 2), 3e-3, log)

        mparams = M.init_medusa(cfg, jax.random.fold_in(key, 2))
        mparams = run_phase(
            f"medusa_{size}", mparams,
            lambda p, b, o: medusa_loss(p, tparams, cfg, b, o),
            win, sm, max(bsz - 2, 2), 3e-3, log)

        tensors = {}
        tensors.update({f"t.{k}": v for k, v in tparams.items()})
        tensors.update({f"d.{k}": v for k, v in dparams.items()})
        tensors.update({f"md.{k}": v for k, v in mparams.items()})
        save_weights(f"{args.out_dir}/weights_{size}.bin", tensors)
        print(f"saved weights_{size}.bin ({len(tensors)} tensors)")

    # independent tiny draft LM (TriForce baseline)
    cfg = M.TINY
    win = Windows(seed=0xC0FFEE)
    steps = 3 if args.quick else 160
    tiny = M.init_target(cfg, jax.random.PRNGKey(99))
    tiny = run_phase(
        "tiny", tiny, lambda p, b, o: M.lm_loss(p, cfg, b, o, chunk=SEQ),
        win, steps, 6, 3e-3, log)
    save_weights(f"{args.out_dir}/weights_tiny.bin",
                 {f"t.{k}": v for k, v in tiny.items()})

    with open(f"{args.out_dir}/train_log.json", "w") as f:
        json.dump(log, f)
    print("wrote train_log.json")


if __name__ == "__main__":
    main()
