"""Continue training the size-s target + draft from saved weights
(sharpens greedy rollouts; the initial budgeted run plateaus before the
model commits to word-level continuations). Build-time only.

Usage: python -m compile.finetune --out-dir ../artifacts --steps 300
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .train import (Windows, adam_init, adam_update, draft_ttt_loss,
                    run_phase, save_weights, SEQ)


def load_all(path):
    import struct
    t = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"SPVW"
        _, n = struct.unpack("<II", f.read(8))
        for _ in range(n):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode()
            (nd,) = struct.unpack("<B", f.read(1))
            dims = [struct.unpack("<I", f.read(4))[0] for _ in range(nd)]
            cnt = int(np.prod(dims)) if dims else 1
            t[name] = jnp.array(
                np.frombuffer(f.read(4 * cnt), np.float32).reshape(dims))
    return t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--steps-draft", type=int, default=150)
    args = ap.parse_args()

    path = f"{args.out_dir}/weights_s.bin"
    tensors = load_all(path)
    cfg = M.SIZES["s"]
    tparams = {k[2:]: v for k, v in tensors.items() if k.startswith("t.")}
    dparams = {k[2:]: v for k, v in tensors.items() if k.startswith("d.")}

    log: dict = {}
    win = Windows(seed=0xC0FFEE + ord("s") + 1)
    tparams = run_phase(
        "target_s_ft", tparams,
        lambda p, b, o: M.lm_loss(p, cfg, b, o, chunk=SEQ),
        win, args.steps, 8, 1e-3, log)
    dparams = run_phase(
        "draft_s_ft", dparams,
        lambda p, b, o: draft_ttt_loss(p, tparams, cfg, b, o),
        win, args.steps_draft, 4, 1e-3, log)

    tensors.update({f"t.{k}": v for k, v in tparams.items()})
    tensors.update({f"d.{k}": v for k, v in dparams.items()})
    save_weights(path, tensors)

    old = json.load(open(f"{args.out_dir}/train_log.json"))
    old.update(log)
    json.dump(old, open(f"{args.out_dir}/train_log.json", "w"))
    print("finetune saved")


if __name__ == "__main__":
    main()
