"""Pallas block-summary + retrieval-scoring kernel (paper §3.2, Eqs. 1–3).

At each Refresh step SpecPV re-selects the retrieval blocks of the partial
KV cache. The score of block i under the step's query set {q_j} is

    S_i      = (K_i^max, K_i^min)                 (elementwise over block)
    s_{i,j}  = max(q_j · K_i^maxᵀ, q_j · K_i^minᵀ)
    s_i      = f(s_{i,1} … s_{i,M})               f ∈ {mean, max, last}

This kernel fuses the summary reduction and the scoring matmuls; it emits
the per-(query, block) score matrix summed over heads, and the host-side
reduction `f` (3 flops/block) is applied by the caller so one compiled
kernel serves all three ablation modes of paper Table 4.

Grid = (heads,): each cell stages one head's full key row into VMEM,
reduces it to (NB × D) max/min summaries, and issues two (T×D)·(D×NB) MXU
matmuls. VMEM worst case (H=8, B=8192, D=32): 1 MiB keys + 2·32 KiB
summaries + 64·256·4 = 64 KiB scores ≈ 1.1 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _score_kernel(kv_len_ref, q_ref, k_ref, o_ref, *, block_size: int):
    h = pl.program_id(0)
    kv_len = kv_len_ref[0, 0]
    k = k_ref[0]                                  # [B, D]
    q = q_ref[0]                                  # [T, D]
    B, D = k.shape
    NB = B // block_size

    kb = k.reshape(NB, block_size, D)
    rows = jax.lax.broadcasted_iota(jnp.int32, (NB, block_size), 0) * block_size \
        + jax.lax.broadcasted_iota(jnp.int32, (NB, block_size), 1)
    valid = (rows < kv_len)[:, :, None]           # [NB, bs, 1]
    kmax = jnp.max(jnp.where(valid, kb, -jnp.inf), axis=1)   # [NB, D]
    kmin = jnp.min(jnp.where(valid, kb, jnp.inf), axis=1)
    any_valid = rows[:, 0] < kv_len               # block has ≥1 valid row
    kmax = jnp.where(any_valid[:, None], kmax, 0.0)
    kmin = jnp.where(any_valid[:, None], kmin, 0.0)

    s = jnp.maximum(
        jnp.dot(q, kmax.T, preferred_element_type=jnp.float32),
        jnp.dot(q, kmin.T, preferred_element_type=jnp.float32),
    )                                             # [T, NB]

    @pl.when(h == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += s


@functools.partial(jax.jit, static_argnames=("block_size",))
def block_scores(k, q, kv_len, *, block_size: int = 32):
    """Per-(query, block) retrieval scores summed over heads.

    Args:
      k:      [H, B, D] f32 post-RoPE key cache.
      q:      [H, T, D] f32 verification-step queries.
      kv_len: () int32 committed length.
    Returns:
      [T, NB] f32; blocks entirely past kv_len are NEG_INF.
    """
    H, B, D = k.shape
    T = q.shape[1]
    assert B % block_size == 0
    NB = B // block_size
    kv_len_arr = jnp.reshape(kv_len.astype(jnp.int32), (1, 1))

    s = pl.pallas_call(
        functools.partial(_score_kernel, block_size=block_size),
        grid=(H,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda h: (0, 0)),
            pl.BlockSpec((1, T, D), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, B, D), lambda h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((T, NB), lambda h: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, NB), jnp.float32),
        interpret=True,
    )(kv_len_arr, q, k)

    blk_start = jnp.arange(NB, dtype=jnp.int32) * block_size
    any_valid = blk_start < kv_len
    return jnp.where(any_valid[None, :], s, NEG_INF)


def reduce_scores(s, n_queries, reduction: str):
    """Host-side reduction f over the query axis of [T, NB] scores.

    Only the first `n_queries` rows are real (the rest are padded tree
    slots); `last` means the most recently verified token's query.
    """
    T = s.shape[0]
    rows = jnp.arange(T)
    real = (rows < n_queries)[:, None]
    if reduction == "mean":
        return jnp.sum(jnp.where(real, s, 0.0), axis=0) / jnp.maximum(
            n_queries.astype(jnp.float32), 1.0)
    if reduction == "max":
        return jnp.max(jnp.where(real, s, NEG_INF), axis=0)
    if reduction == "last":
        idx = jnp.clip(n_queries - 1, 0, T - 1)
        return s[idx]
    raise ValueError(reduction)
