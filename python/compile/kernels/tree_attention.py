"""Pallas fused tree/verification attention — the L1 hot-spot kernel.

One kernel serves every attention site in the stack (prefill chunks, AR
decode, full verification, partial verification, draft decoding): the only
thing that changes is the KV bucket size and the tree mask, which is exactly
the SpecPV trick — partial verification is *this same kernel* run over a
budget-sized cache instead of the full one.

TPU design (paper targets CUDA; see DESIGN.md §Hardware-Adaptation):
  * grid = (heads, kv_chunks): each grid cell stages one (chunk × d_head)
    K/V tile from HBM into VMEM via BlockSpec — the explicit analogue of the
    paper's threadblock HBM→SMEM staging.
  * online-softmax carry (m, l, acc) lives in VMEM scratch across the kv
    grid dimension (flash-attention-on-TPU structure).
  * scores are computed as (T × chunk) MXU matmuls; T and chunk are padded
    to MXU-friendly multiples by the caller.
  * visibility = committed-history test (col < kv_len, via iota compare)
    OR tree-mask lookup for the new-token region written at
    [kv_len, kv_len + TK).

Runs with interpret=True everywhere in this repo (CPU PJRT cannot execute
Mosaic custom-calls); the structure above is what would compile for real
TPU. VMEM budget per cell (worst case H=8, T=64, chunk=512, D=32):
  K,V tiles 2·512·32·4 = 128 KiB, scores 64·512·4 = 128 KiB,
  q 8 KiB, carry ~17 KiB  →  ≈ 280 KiB  (≪ 16 MiB VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(kv_len_ref, tm_ref, q_ref, k_ref, v_ref, o_ref,
                 *, sm_scale: float, chunk: int, n_chunks: int):
    """Body for one head. The kv-chunk loop is unrolled at trace time
    (n_chunks is static); carry stays in registers/VMEM values."""
    q = q_ref[0]                       # [T, D]
    tm = tm_ref[...]                   # [T, TK] {0,1}
    kv_len = kv_len_ref[0, 0]          # scalar i32
    T = q.shape[0]
    TK = tm.shape[1]

    m = jnp.full((T,), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((T,), dtype=jnp.float32)
    acc = jnp.zeros((T, q.shape[1]), dtype=jnp.float32)

    for c in range(n_chunks):
        kc = k_ref[0, c * chunk:(c + 1) * chunk, :]   # [C, D] ← VMEM tile
        vc = v_ref[0, c * chunk:(c + 1) * chunk, :]
        s = jnp.dot(q, kc.T, preferred_element_type=jnp.float32) * sm_scale

        cols = c * chunk + jax.lax.broadcasted_iota(jnp.int32, (T, chunk), 1)
        hist = cols < kv_len                           # committed history
        rel = cols - kv_len                            # new-region offset
        in_new = (rel >= 0) & (rel < TK)
        relc = jnp.clip(rel, 0, TK - 1)
        new_vis = jnp.take_along_axis(tm, relc, axis=1) > 0.5
        visible = hist | (new_vis & in_new)
        s = jnp.where(visible, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, vc, preferred_element_type=jnp.float32)
        m = m_new

    o_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=("sm_scale", "chunk"))
def tree_attention(q, k, v, kv_len, tree_mask, *, sm_scale: float,
                   chunk: int = 512):
    """Fused verification attention over a bucketed KV cache.

    Args:
      q:         [H, T, D] f32 queries.
      k, v:      [H, B, D] f32 KV bucket; rows < kv_len are history, rows
                 [kv_len, kv_len+TK) are this step's new tokens.
      kv_len:    () int32.
      tree_mask: [T, TK] f32 {0,1} tree visibility (self edge included).
      sm_scale:  float softmax scale.
      chunk:     KV tile length staged per inner step.

    Returns: [H, T, D] f32.
    """
    H, T, D = q.shape
    B = k.shape[1]
    chunk = min(chunk, B)
    assert B % chunk == 0, (B, chunk)
    n_chunks = B // chunk
    kv_len_arr = jnp.reshape(kv_len.astype(jnp.int32), (1, 1))

    kernel = functools.partial(
        _attn_kernel, sm_scale=sm_scale, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda h: (0, 0)),           # kv_len
            pl.BlockSpec(tree_mask.shape, lambda h: (0, 0)),  # tree mask
            pl.BlockSpec((1, T, D), lambda h: (h, 0, 0)),     # q row
            pl.BlockSpec((1, B, D), lambda h: (h, 0, 0)),     # k row
            pl.BlockSpec((1, B, D), lambda h: (h, 0, 0)),     # v row
        ],
        out_specs=pl.BlockSpec((1, T, D), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, T, D), jnp.float32),
        interpret=True,
    )(kv_len_arr, tree_mask.astype(jnp.float32), q, k, v)
