"""Pure-jnp oracles for the pallas kernels.

These are the correctness ground truth: pytest (and hypothesis sweeps)
compare every kernel against these functions across shapes, lengths and
masks. They are written for clarity, not speed.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def tree_attention_ref(q, k, v, kv_len, tree_mask, sm_scale):
    """Reference fused verification attention.

    Args:
      q:         [H, T, D]  query states (tree/candidate tokens).
      k, v:      [H, B, D]  bucketed KV cache. Rows `< kv_len` are committed
                 history; rows `[kv_len, kv_len + TK)` are the "new region"
                 holding this step's tokens; rows beyond are garbage.
      kv_len:    scalar int32, number of committed tokens.
      tree_mask: [T, TK] {0,1} — visibility of query i over new-region slot j
                 (must include the self edge for real queries).
      sm_scale:  softmax scale (1/sqrt(D), possibly YARN-tempered).

    Returns:
      [H, T, D] attention output.
    """
    H, T, D = q.shape
    B = k.shape[1]
    TK = tree_mask.shape[1]
    cols = jnp.arange(B)[None, :]                      # [1, B]
    hist = jnp.broadcast_to(cols < kv_len, (T, B))     # visible history
    rel = jnp.broadcast_to(cols - kv_len, (T, B))      # new-region offset
    in_new = (rel >= 0) & (rel < TK)
    rel_c = jnp.clip(rel, 0, TK - 1)
    tm = tree_mask.astype(bool)                        # [T, TK]
    new_vis = jnp.take_along_axis(tm, rel_c, axis=1) & in_new
    visible = hist | new_vis                           # [T, B]

    scores = jnp.einsum("htd,hbd->htb", q, k) * sm_scale
    scores = jnp.where(visible[None, :, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores - m)
    probs = probs / jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("htb,hbd->htd", probs, v)


def block_score_ref(k, q, kv_len, block_size, reduction):
    """Reference Quest-style block scoring (paper Eqs. 1–3).

    Args:
      k:        [H, B, D] key cache (post-RoPE), rows >= kv_len invalid.
      q:        [H, T, D] query states from the verification step.
      kv_len:   scalar int32 — blocks entirely beyond kv_len score NEG_INF.
      block_size: tokens per KV block.
      reduction: 'mean' | 'max' | 'last' over the T query scores.

    Returns:
      [NB] float32 scores, NB = B // block_size, summed over heads.
    """
    H, B, D = k.shape
    NB = B // block_size
    kb = k.reshape(H, NB, block_size, D)
    idx = jnp.arange(B).reshape(NB, block_size)
    valid = (idx < kv_len)[None, :, :, None]           # [1, NB, bs, 1]
    kmax = jnp.max(jnp.where(valid, kb, -jnp.inf), axis=2)   # [H, NB, D]
    kmin = jnp.min(jnp.where(valid, kb, jnp.inf), axis=2)
    any_valid = jnp.any(idx < kv_len, axis=1)          # [NB]
    kmax = jnp.where(any_valid[None, :, None], kmax, 0.0)
    kmin = jnp.where(any_valid[None, :, None], kmin, 0.0)

    s = jnp.maximum(
        jnp.einsum("htd,hnd->htn", q, kmax),
        jnp.einsum("htd,hnd->htn", q, kmin),
    )                                                  # [H, T, NB]
    s = jnp.sum(s, axis=0)                             # heads -> [T, NB]
    if reduction == "mean":
        r = jnp.mean(s, axis=0)
    elif reduction == "max":
        r = jnp.max(s, axis=0)
    elif reduction == "last":
        r = s[-1]
    else:
        raise ValueError(reduction)
    return jnp.where(any_valid, r, NEG_INF)
