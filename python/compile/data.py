"""Synthetic corpora for SpecPV reproduction (PG-19 / GovReport / QMSum /
needle-QA substitutes).

Everything here is DETERMINISTIC given a seed and mirrored 1:1 by the rust
`corpus` module (same xorshift64* RNG, same word lists, same structure) so
that python-side training data and rust-side serving workloads come from the
same distribution, and golden-file parity tests can hold across languages.

Tokenization is byte-level: token id = byte value, plus BOS=256, EOS=257,
PAD=258; vocab padded to 320.
"""

from __future__ import annotations

VOCAB_SIZE = 320
BOS, EOS, PAD = 256, 257, 258

MASK64 = (1 << 64) - 1


class XorShift64Star:
    """xorshift64* PRNG; mirrored exactly in rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = (seed | 1) & MASK64

    def next_u64(self) -> int:
        x = self.state
        x ^= (x >> 12)
        x &= MASK64
        x ^= (x << 25) & MASK64
        x ^= (x >> 27)
        self.state = x & MASK64
        return (x * 0x2545F4914F6CDD1D) & MASK64

    def below(self, n: int) -> int:
        """Uniform in [0, n) via multiply-shift (no modulo bias games —
        rust side uses the identical 128-bit multiply)."""
        return ((self.next_u64() >> 11) * n) >> 53

    def f64(self) -> float:
        return (self.next_u64() >> 11) / float(1 << 53)


# ---------------------------------------------------------------------------
# Word inventory — compact but produces locally-coherent "novel" prose.
# Kept in one flat place so the rust port is a literal transcription.
# ---------------------------------------------------------------------------

NAMES = [
    "Armand", "Beatrice", "Clement", "Dorothea", "Edmund", "Felicity",
    "Gideon", "Harriet", "Isadora", "Jasper", "Katherine", "Leopold",
    "Margaret", "Nathaniel", "Octavia", "Percival",
]

PLACES = [
    "the harbour", "the old mill", "the vicarage", "the moor", "the library",
    "the garden", "the station", "the courthouse", "the lighthouse",
    "the market square", "the abbey", "the orchard",
]

NOUNS = [
    "letter", "storm", "candle", "ledger", "portrait", "carriage", "sermon",
    "fortune", "rumour", "voyage", "inheritance", "debt", "promise",
    "manuscript", "telegram", "garden", "winter", "journey", "secret",
    "bargain", "fever", "wedding", "funeral", "harvest", "quarrel",
]

VERBS = [
    "remembered", "concealed", "discovered", "promised", "refused",
    "demanded", "whispered", "confessed", "regretted", "imagined",
    "suspected", "announced", "abandoned", "forgave", "inherited",
    "questioned", "observed", "resolved", "feared", "admired",
]

ADJS = [
    "pale", "weathered", "solemn", "curious", "forgotten", "distant",
    "quiet", "restless", "grave", "peculiar", "faded", "earnest",
    "bitter", "gentle", "obstinate", "melancholy",
]

CONNECTIVES = [
    "and yet", "however", "meanwhile", "at length", "in truth",
    "nevertheless", "presently", "by morning", "after some reflection",
    "against all advice",
]

TOPICS = [
    "the drainage works", "the school inspection", "the parish budget",
    "the railway extension", "the water supply", "the grain tariff",
    "the hospital wing", "the coastal survey", "the census returns",
    "the bridge repairs", "the timber contract", "the postal service",
]

SPEAKERS = [
    "the chairman", "the secretary", "the inspector", "the treasurer",
    "the delegate", "the engineer", "the clerk", "the surveyor",
]


def _sentence(rng: XorShift64Star) -> str:
    """One pseudo-Victorian sentence. Markov-ish: structure templates with
    sampled slots; enough statistical regularity for a 1M-param char LM to
    learn and for attention locality to be meaningful."""
    t = rng.below(5)
    n1 = NAMES[rng.below(len(NAMES))]
    n2 = NAMES[rng.below(len(NAMES))]
    v = VERBS[rng.below(len(VERBS))]
    noun = NOUNS[rng.below(len(NOUNS))]
    adj = ADJS[rng.below(len(ADJS))]
    place = PLACES[rng.below(len(PLACES))]
    if t == 0:
        return f"{n1} {v} the {adj} {noun} near {place}."
    if t == 1:
        return f"At {place[4:] if place.startswith('the ') else place}, {n1} {v} that {n2} had kept the {noun}."
    if t == 2:
        c = CONNECTIVES[rng.below(len(CONNECTIVES))]
        return f"{c.capitalize()}, the {noun} remained {adj}, and {n1} {v} it."
    if t == 3:
        return f'"I have {v} the {noun}," said {n1}, looking toward {place}.'
    return f"The {adj} {noun} of {n1} was known in every corner of {place}."


def novel_text(seed: int, n_bytes: int) -> str:
    """PG-19 substitute: chapters of generated prose, ~n_bytes long."""
    rng = XorShift64Star(seed)
    out: list[str] = []
    total = 0
    chapter = 1
    while total < n_bytes:
        head = f"CHAPTER {chapter}.\n\n"
        out.append(head)
        total += len(head)
        sentences = 30 + rng.below(30)
        para: list[str] = []
        for i in range(sentences):
            para.append(_sentence(rng))
            if (i + 1) % (4 + rng.below(4)) == 0:
                para.append("\n\n")
            else:
                para.append(" ")
            if total > n_bytes:
                break
            total += len(para[-2]) + len(para[-1])
        out.extend(para)
        out.append("\n\n")
        chapter += 1
    return "".join(out)[:n_bytes]


def report_text(seed: int, n_bytes: int) -> str:
    """GovReport substitute: sectioned bureaucratic report."""
    rng = XorShift64Star(seed)
    out: list[str] = []
    total = 0
    sec = 1
    while total < n_bytes:
        topic = TOPICS[rng.below(len(TOPICS))]
        head = f"SECTION {sec}. REPORT ON {topic.upper()}.\n"
        out.append(head)
        total += len(head)
        for _ in range(6 + rng.below(8)):
            amount = 100 + rng.below(9900)
            year = 1860 + rng.below(60)
            s = (
                f"The committee on {topic} recorded an expenditure of "
                f"{amount} pounds in the year {year}, and "
                f"{VERBS[rng.below(len(VERBS))]} further works. "
            )
            out.append(s)
            total += len(s)
            if total > n_bytes:
                break
        out.append("\n")
        total += 1
        sec += 1
    return "".join(out)[:n_bytes]


def meeting_text(seed: int, n_bytes: int) -> str:
    """QMSum substitute: meeting transcript with speakers."""
    rng = XorShift64Star(seed)
    out: list[str] = []
    total = 0
    while total < n_bytes:
        sp = SPEAKERS[rng.below(len(SPEAKERS))]
        topic = TOPICS[rng.below(len(TOPICS))]
        t = rng.below(3)
        if t == 0:
            s = f"{sp.upper()}: We must return to the question of {topic}. "
        elif t == 1:
            s = f"{sp.upper()}: The figures for {topic} were {ADJS[rng.below(len(ADJS))]} at best. "
        else:
            s = f"{sp.upper()}: I move that {topic} be deferred until the next session. "
        out.append(s + "\n")
        total += len(s) + 1
    return "".join(out)[:n_bytes]


# ---------------------------------------------------------------------------
# Needle-QA (HotpotQA / LongBench substitute): key→value facts buried in
# filler prose; question asks for the value of one key. Exact-match scoring.
# Format is chosen to be learnable by a char-level model with induction
# heads: the answer is a literal copy of a span seen once in context.
# ---------------------------------------------------------------------------

def _code_word(rng: XorShift64Star) -> str:
    # 6-letter pronounceable code: CVCVCV
    cons = "bdfgklmnprstvz"
    vow = "aeiou"
    w = []
    for i in range(6):
        src = cons if i % 2 == 0 else vow
        w.append(src[rng.below(len(src))])
    return "".join(w)


def needle_qa(seed: int, n_bytes: int, n_facts: int) -> tuple[str, str, str]:
    """Returns (context, question, answer). Facts 'The code of <name-i> is
    <code>.' are spread uniformly through filler prose; the question asks for
    one of them."""
    rng = XorShift64Star(seed)
    facts = []
    for i in range(n_facts):
        key = f"{NAMES[rng.below(len(NAMES))]}-{rng.below(90) + 10}"
        val = _code_word(rng)
        facts.append((key, val))
    # filler segments between facts
    seg = max(1, n_bytes // (n_facts + 1))
    out: list[str] = []
    frng = XorShift64Star(seed ^ 0x9E3779B97F4A7C15)
    for i in range(n_facts):
        total = 0
        while total < seg:
            s = _sentence(frng) + " "
            out.append(s)
            total += len(s)
        k, v = facts[i]
        out.append(f"\nThe code of agent {k} is {v}.\n")
    qi = rng.below(n_facts)
    qk, qv = facts[qi]
    context = "".join(out)[: n_bytes + 40 * n_facts]
    question = f"\nQuestion: what is the code of agent {qk}?\nAnswer: the code of agent {qk} is"
    return context, question, qv


# ---------------------------------------------------------------------------
# Training-mix stream: novel prose + copy-format facts, so the LM learns both
# local structure and the induction/copy behaviour needle-QA needs.
# ---------------------------------------------------------------------------

def training_text(seed: int, n_bytes: int) -> str:
    rng = XorShift64Star(seed)
    out: list[str] = []
    total = 0
    while total < n_bytes:
        r = rng.below(10)
        if r < 5:
            s = _sentence(rng) + " "
        elif r < 7:
            # copy-task material: same key repeated with its value
            key = f"{NAMES[rng.below(len(NAMES))]}-{rng.below(90) + 10}"
            val = _code_word(rng)
            gap = _sentence(rng)
            s = (
                f"The code of agent {key} is {val}. {gap} "
                f"Question: what is the code of agent {key}?"
                f"\nAnswer: the code of agent {key} is {val}.\n"
            )
        elif r < 9:
            sp = SPEAKERS[rng.below(len(SPEAKERS))]
            s = f"{sp.upper()}: We must return to the question of {TOPICS[rng.below(len(TOPICS))]}. \n"
        else:
            amount = 100 + rng.below(9900)
            s = f"The committee recorded an expenditure of {amount} pounds. "
        out.append(s)
        total += len(s)
    return "".join(out)[:n_bytes]


def encode(text: str) -> list[int]:
    """Byte-level encoding (no specials)."""
    return list(text.encode("utf-8", errors="replace"))


def decode(ids) -> str:
    bs = bytes(int(i) for i in ids if 0 <= int(i) < 256)
    return bs.decode("utf-8", errors="replace")
