"""L2 model invariants: chunked-prefill consistency, verify-vs-dense
equivalence, compaction semantics, YARN properties, draft shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


CFG = M.SIZES["s"]
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def params():
    return M.init_target(CFG, KEY)


def causal(t):
    return jnp.tril(jnp.ones((t, t), jnp.float32))


def zero_kv(bucket, cfg=CFG):
    return jnp.zeros((cfg.n_layer, 2, cfg.n_head, bucket, cfg.d_head))


def toks(n, seed=0):
    return jnp.array(
        np.random.default_rng(seed).integers(0, 255, n), jnp.int32)


class TestTargetForward:
    def test_chunked_prefill_matches_dense(self, params):
        t = toks(96)
        dense = M.target_fwd(
            params, CFG, t, jnp.arange(96, dtype=jnp.int32), zero_kv(128),
            jnp.int32(0), causal(96), yarn_factor=16.0, chunk=128)
        kv = zero_kv(128)
        outs = []
        for c in range(3):
            o = M.target_fwd(
                params, CFG, t[c * 32:(c + 1) * 32],
                jnp.arange(c * 32, (c + 1) * 32, dtype=jnp.int32), kv,
                jnp.int32(c * 32), causal(32), yarn_factor=16.0, chunk=128)
            kv = o["kv"]
            outs.append(o["logits"])
        np.testing.assert_allclose(
            jnp.concatenate(outs), dense["logits"], rtol=1e-3, atol=1e-4)

    def test_verify_equals_decode_chain(self, params):
        """Verifying a 4-token chain == 4 AR decode steps (losslessness of
        chain verification)."""
        prompt = toks(64, 1)
        pre = M.target_fwd(
            params, CFG, prompt, jnp.arange(64, dtype=jnp.int32),
            zero_kv(128), jnp.int32(0), causal(64), yarn_factor=16.0,
            chunk=128)
        chain = toks(4, 2)
        # chain verification in one call
        ver = M.target_fwd(
            params, CFG, chain, jnp.arange(64, 68, dtype=jnp.int32),
            pre["kv"], jnp.int32(64), causal(4), yarn_factor=16.0, chunk=128)
        # step-by-step
        kv = pre["kv"]
        logits = []
        for i in range(4):
            o = M.target_fwd(
                params, CFG, chain[i:i + 1],
                jnp.arange(64 + i, 65 + i, dtype=jnp.int32), kv,
                jnp.int32(64 + i), causal(1), yarn_factor=16.0, chunk=128)
            kv = o["kv"]
            logits.append(o["logits"][0])
        np.testing.assert_allclose(
            ver["logits"], jnp.stack(logits), rtol=1e-3, atol=1e-4)

    def test_tree_siblings_independent(self, params):
        """Changing a sibling's token must not change the other branch's
        logits (the tree mask isolates branches)."""
        prompt = toks(32, 3)
        pre = M.target_fwd(
            params, CFG, prompt, jnp.arange(32, dtype=jnp.int32),
            zero_kv(64), jnp.int32(0), causal(32), yarn_factor=16.0,
            chunk=64)
        # tree: root(0); children 1, 2
        tm = jnp.array(
            [[1, 0, 0], [1, 1, 0], [1, 0, 1]], jnp.float32)
        pos = jnp.array([32, 33, 33], jnp.int32)
        t1 = jnp.array([10, 20, 30], jnp.int32)
        t2 = jnp.array([10, 20, 99], jnp.int32)  # change sibling 2
        o1 = M.target_fwd(params, CFG, t1, pos, pre["kv"], jnp.int32(32),
                          tm, yarn_factor=16.0, chunk=64)
        o2 = M.target_fwd(params, CFG, t2, pos, pre["kv"], jnp.int32(32),
                          tm, yarn_factor=16.0, chunk=64)
        np.testing.assert_allclose(
            o1["logits"][1], o2["logits"][1], rtol=1e-4, atol=1e-5)


class TestCompaction:
    def test_compact_window_moves_rows(self):
        L, H, B, D = 1, 1, 64, 4
        kv = jnp.arange(L * 2 * H * B * D, dtype=jnp.float32).reshape(
            L, 2, H, B, D)
        out = M.compact_window(
            kv, jnp.int32(10), jnp.array([1, 3, 0, 0, 0, 0, 0, 0], jnp.int32),
            jnp.int32(2), 16)
        # row 10 ← old row 11, row 11 ← old row 13
        np.testing.assert_allclose(out[0, 0, 0, 10], kv[0, 0, 0, 11])
        np.testing.assert_allclose(out[0, 0, 0, 11], kv[0, 0, 0, 13])
        # untouched regions identical
        np.testing.assert_allclose(out[0, 0, 0, :10], kv[0, 0, 0, :10])
        np.testing.assert_allclose(out[0, 0, 0, 26:], kv[0, 0, 0, 26:])

    def test_compact_noop_when_empty(self):
        kv = jax.random.normal(KEY, (2, 2, 2, 32, 4))
        out = M.compact_window(
            kv, jnp.int32(5), jnp.zeros((8,), jnp.int32), jnp.int32(0), 16)
        np.testing.assert_allclose(out, kv)


class TestYarn:
    def test_mscale_grows_with_factor(self):
        _, m1 = M.yarn_inv_freq(CFG, 1.0)
        _, m16 = M.yarn_inv_freq(CFG, 16.0)
        assert m1 == 1.0
        assert m16 > 1.0

    def test_high_freq_dims_preserved(self):
        base, _ = M.yarn_inv_freq(CFG, 1.0)
        yarn, _ = M.yarn_inv_freq(CFG, 16.0)
        # dim 0 is the highest frequency: extrapolated (unchanged)
        np.testing.assert_allclose(yarn[0], base[0], rtol=1e-6)
        # the lowest-frequency dim is interpolated (divided by ~factor)
        assert yarn[-1] < base[-1] / 4

    def test_rope_preserves_norm(self):
        x = jax.random.normal(KEY, (2, 8, 32))
        inv, _ = M.yarn_inv_freq(CFG, 16.0)
        r = M.rope_apply(x, jnp.arange(100, 108, dtype=jnp.int32), inv)
        np.testing.assert_allclose(
            jnp.linalg.norm(r, axis=-1), jnp.linalg.norm(x, axis=-1),
            rtol=1e-5)

    def test_rope_relative_property(self):
        """q·k after RoPE depends on relative distance only (per 2-dim
        pair), so shifting both positions equally preserves scores."""
        q = jax.random.normal(KEY, (1, 1, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 32))
        inv, _ = M.yarn_inv_freq(CFG, 16.0)
        def score(pq, pk):
            qq = M.rope_apply(q, jnp.array([pq], jnp.int32), inv)
            kk = M.rope_apply(k, jnp.array([pk], jnp.int32), inv)
            return float(jnp.sum(qq * kk))
        assert abs(score(100, 90) - score(1100, 1090)) < 1e-3


class TestDraft:
    def test_shapes_and_determinism(self, params):
        dp = M.init_draft(CFG, KEY)
        t = toks(8, 5)
        feats = jax.random.normal(KEY, (8, 3 * CFG.d_model))
        kv = jnp.zeros((2, CFG.n_head, 64, CFG.d_head))
        lg, hid, kv2 = M.draft_fwd(
            dp, params["head"], params["embed"], CFG, t, feats,
            jnp.arange(8, dtype=jnp.int32), kv, jnp.int32(0), causal(8),
            yarn_factor=16.0, chunk=64)
        assert lg.shape == (8, CFG.vocab)
        assert hid.shape == (8, CFG.d_model)
        assert kv2.shape == kv.shape
        lg2, _, _ = M.draft_fwd(
            dp, params["head"], params["embed"], CFG, t, feats,
            jnp.arange(8, dtype=jnp.int32), kv, jnp.int32(0), causal(8),
            yarn_factor=16.0, chunk=64)
        np.testing.assert_allclose(lg, lg2)

    def test_medusa_heads_shape(self):
        mp = M.init_medusa(CFG, KEY)
        out = M.medusa_fwd(mp, jnp.ones((CFG.d_model,)))
        assert out.shape == (3, CFG.vocab)


class TestScoreGather:
    def test_gather_reassembles_blocks(self, params):
        kv = jax.random.normal(KEY, (CFG.n_layer, 2, CFG.n_head, 128,
                                     CFG.d_head))
        idx = jnp.array([[0, 2, 3]] * CFG.n_layer, jnp.int32)
        g = M.gather_fwd(kv, idx, block_size=32)
        np.testing.assert_allclose(g[:, :, :, :32], kv[:, :, :, 0:32])
        np.testing.assert_allclose(g[:, :, :, 32:64], kv[:, :, :, 64:96])

    def test_score_shapes(self, params):
        kv = jax.random.normal(KEY, (CFG.n_layer, 2, CFG.n_head, 256,
                                     CFG.d_head))
        q = jax.random.normal(KEY, (CFG.n_layer, CFG.n_head, 16, CFG.d_head))
        s = M.score_fwd(kv, q, jnp.int32(200), jnp.int32(16), block_size=32)
        assert s.shape == (CFG.n_layer, 3, 8)
