"""L1 kernel correctness: pallas vs pure-jnp oracle, swept with hypothesis
over shapes, lengths, masks and chunk sizes. This is the core correctness
signal for the verification hot path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property sweeps need the hypothesis package"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels.tree_attention import tree_attention
from compile.kernels.block_score import block_scores, reduce_scores
from compile.kernels.ref import tree_attention_ref, block_score_ref, NEG_INF


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def tril_mask(t, tk):
    m = jnp.zeros((t, tk))
    return m.at[:, :].set(jnp.tril(jnp.ones((t, tk))))


class TestTreeAttention:
    @pytest.mark.parametrize("H,T,B,D", [(4, 16, 256, 32), (2, 1, 128, 32),
                                         (8, 64, 512, 32), (4, 16, 768, 32)])
    def test_matches_ref_chain_mask(self, H, T, B, D):
        q = rand(1, (H, T, D))
        k = rand(2, (H, B, D))
        v = rand(3, (H, B, D))
        kv_len = jnp.int32(B // 2)
        tm = tril_mask(T, T)
        out = tree_attention(q, k, v, kv_len, tm, sm_scale=0.2, chunk=128)
        ref = tree_attention_ref(q, k, v, kv_len, tm, 0.2)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_tree_mask_blocks_siblings(self):
        H, T, B, D = 2, 4, 128, 32
        q, k, v = rand(4, (H, T, D)), rand(5, (H, B, D)), rand(6, (H, B, D))
        # tree: 0 root; 1,2 children of 0; 3 child of 1
        tm = jnp.array([
            [1, 0, 0, 0],
            [1, 1, 0, 0],
            [1, 0, 1, 0],
            [1, 1, 0, 1],
        ], jnp.float32)
        kv_len = jnp.int32(60)
        out = tree_attention(q, k, v, kv_len, tm, sm_scale=0.18)
        ref = tree_attention_ref(q, k, v, kv_len, tm, 0.18)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_kv_len_zero_sees_only_tree(self):
        H, T, B, D = 2, 3, 64, 32
        q, k, v = rand(7, (H, T, D)), rand(8, (H, B, D)), rand(9, (H, B, D))
        tm = tril_mask(T, T)
        out = tree_attention(q, k, v, jnp.int32(0), tm, sm_scale=0.2)
        ref = tree_attention_ref(q, k, v, jnp.int32(0), tm, 0.2)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
        # row 0 attends only to itself → output == v[:,0]
        np.testing.assert_allclose(out[:, 0], v[:, 0], rtol=1e-4, atol=1e-5)

    def test_garbage_rows_beyond_region_ignored(self):
        """Rows past kv_len+TK must not affect the output (the flat-state
        design leaves stale garbage there)."""
        H, T, B, D = 2, 4, 128, 32
        q = rand(10, (H, T, D))
        k = rand(11, (H, B, D))
        v = rand(12, (H, B, D))
        kv_len = jnp.int32(40)
        tm = tril_mask(T, T)
        out1 = tree_attention(q, k, v, kv_len, tm, sm_scale=0.2)
        k2 = k.at[:, 60:].set(1e6)   # poison beyond the region
        v2 = v.at[:, 60:].set(-1e6)
        out2 = tree_attention(q, k2, v2, kv_len, tm, sm_scale=0.2)
        np.testing.assert_allclose(out1, out2, rtol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        H=st.sampled_from([1, 2, 4]),
        T=st.sampled_from([1, 4, 16]),
        B=st.sampled_from([64, 128, 512]),
        frac=st.floats(0.1, 0.9),
        chunk=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, H, T, B, frac, chunk, seed):
        D = 32
        rng = np.random.default_rng(seed)
        q = jnp.array(rng.standard_normal((H, T, D)), jnp.float32)
        k = jnp.array(rng.standard_normal((H, B, D)), jnp.float32)
        v = jnp.array(rng.standard_normal((H, B, D)), jnp.float32)
        kv_len = jnp.int32(max(1, int((B - T) * frac)))
        # random tree mask with guaranteed self-edges
        tm = jnp.array(rng.integers(0, 2, (T, T)), jnp.float32)
        tm = jnp.maximum(tm, jnp.eye(T))
        out = tree_attention(q, k, v, kv_len, tm, sm_scale=0.17, chunk=chunk)
        ref = tree_attention_ref(q, k, v, kv_len, tm, 0.17)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-4)


class TestBlockScore:
    @pytest.mark.parametrize("H,T,B", [(4, 16, 1024), (2, 64, 512), (1, 1, 256)])
    @pytest.mark.parametrize("red", ["mean", "max", "last"])
    def test_matches_ref(self, H, T, B, red):
        D, bs = 32, 32
        rng = np.random.default_rng(0)
        k = jnp.array(rng.standard_normal((H, B, D)), jnp.float32)
        q = jnp.array(rng.standard_normal((H, T, D)), jnp.float32)
        kv_len = jnp.int32(B * 3 // 4 + 7)
        s = block_scores(k, q, kv_len, block_size=bs)
        got = reduce_scores(s, jnp.int32(T), red)
        ref = block_score_ref(k, q, kv_len, bs, red)
        valid = np.array(ref) > NEG_INF / 2
        np.testing.assert_allclose(
            np.array(got)[valid], np.array(ref)[valid], rtol=1e-4, atol=1e-4)
        # invalid blocks are sentinel on both sides
        assert np.all(np.array(got)[~valid] < NEG_INF / 2)

    def test_partial_block_boundary(self):
        """A block straddling kv_len only summarises its valid rows."""
        H, B, D, bs = 2, 256, 32, 32
        rng = np.random.default_rng(1)
        k = jnp.array(rng.standard_normal((H, B, D)), jnp.float32)
        q = jnp.array(rng.standard_normal((H, 4, D)), jnp.float32)
        kv_len = jnp.int32(100)  # block 3 holds rows 96..99 only
        # poison the invalid rows of block 3: must not change scores
        k2 = k.at[:, 100:128].set(1e5)
        s1 = reduce_scores(block_scores(k, q, kv_len, block_size=bs),
                           jnp.int32(4), "mean")
        s2 = reduce_scores(block_scores(k2, q, kv_len, block_size=bs),
                           jnp.int32(4), "mean")
        np.testing.assert_allclose(s1, s2, rtol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        H=st.sampled_from([1, 2, 4]),
        nq=st.integers(1, 16),
        nb=st.sampled_from([4, 8, 16]),
        fill=st.floats(0.05, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, H, nq, nb, fill, seed):
        D, bs = 32, 32
        B = nb * bs
        rng = np.random.default_rng(seed)
        k = jnp.array(rng.standard_normal((H, B, D)), jnp.float32)
        q = jnp.array(rng.standard_normal((H, nq, D)), jnp.float32)
        kv_len = jnp.int32(max(1, int(B * fill)))
        s = block_scores(k, q, kv_len, block_size=bs)
        for red in ("mean", "max", "last"):
            got = np.array(reduce_scores(s, jnp.int32(nq), red))
            ref = np.array(block_score_ref(k, q, kv_len, bs, red))
            valid = ref > NEG_INF / 2
            np.testing.assert_allclose(got[valid], ref[valid],
                                       rtol=2e-4, atol=2e-4)
