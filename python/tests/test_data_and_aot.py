"""Corpus determinism + weights/manifest round-trips + golden parity
values (pinned on the rust side too — see rust/src/corpus.rs tests)."""

import json
import os
import struct

import numpy as np
import pytest

from compile import data as D
from compile.train import save_weights
from compile.aot import (load_weight_shapes, full_layout, partial_layout,
                         draft_layout, tiny_layout)
from compile import model as M


class TestRng:
    def test_stream_golden(self):
        r = D.XorShift64Star(12345)
        assert [r.next_u64() for _ in range(4)] == [
            10977518812293740004,
            13893246733018840292,
            1412386850724336324,
            13578198927181985541,
        ]

    def test_below_unbiasedish(self):
        r = D.XorShift64Star(7)
        counts = [0] * 10
        for _ in range(10000):
            counts[r.below(10)] += 1
        assert all(700 < c < 1300 for c in counts)


class TestCorpora:
    def test_deterministic_and_sized(self):
        for fn in (D.novel_text, D.report_text, D.meeting_text,
                   D.training_text):
            a = fn(3, 2000)
            assert a == fn(3, 2000)
            assert len(a) == 2000
            assert a.isascii()

    def test_needle_qa(self):
        qa_ctx, q, a = D.needle_qa(11, 4000, 8)
        assert a in qa_ctx
        assert "what is the code of agent" in q
        assert len(a) == 6

    def test_rust_parity_goldens(self):
        """First 64 chars of each corpus, pinned; the same values are
        asserted in rust/tests/parity.rs."""
        assert D.novel_text(1, 200)[:12] == "CHAPTER 1.\n\n"
        # values generated once and frozen — cross-language contract
        golden = D.novel_text(42, 96)
        assert golden == GOLDEN_NOVEL_42, golden
        golden_r = D.report_text(42, 64)
        assert golden_r == GOLDEN_REPORT_42, golden_r

    def test_encode_decode(self):
        s = "hello SpecPV"
        assert D.decode(D.encode(s)) == s


# frozen cross-language goldens (generated from this implementation; the
# rust corpus must reproduce them byte-for-byte)
GOLDEN_NOVEL_42 = None  # pinned in conftest via regeneration check
GOLDEN_REPORT_42 = None


def setup_module():
    global GOLDEN_NOVEL_42, GOLDEN_REPORT_42
    path = os.path.join(os.path.dirname(__file__), "golden_corpus.json")
    if os.path.exists(path):
        g = json.load(open(path))
    else:
        g = {
            "novel_42": D.novel_text(42, 96),
            "report_42": D.report_text(42, 64),
        }
        json.dump(g, open(path, "w"))
    GOLDEN_NOVEL_42 = g["novel_42"]
    GOLDEN_REPORT_42 = g["report_42"]


class TestWeightsFormat:
    def test_roundtrip(self, tmp_path):
        path = str(tmp_path / "w.bin")
        save_weights(path, {"a": np.ones((2, 3), np.float32),
                            "z.b": np.zeros((4,), np.float32)})
        shapes = load_weight_shapes(path)
        assert shapes == {"a": [2, 3], "z.b": [4]}

    def test_magic(self, tmp_path):
        path = str(tmp_path / "w.bin")
        with open(path, "wb") as f:
            f.write(b"XXXX" + struct.pack("<II", 1, 0))
        with pytest.raises(AssertionError):
            load_weight_shapes(path)


class TestLayouts:
    def test_layout_totals_consistent(self):
        cfg = M.SIZES["s"]
        for B in (1024, 8192):
            lay = full_layout(cfg, B)
            assert lay["total"] == (lay["kv"] + lay["logits"] +
                                    lay["feats"] + lay["queries"])
        for P in (512, 1280):
            lay = partial_layout(cfg, P)
            assert lay["total"] == lay["kv"] + lay["logits"] + lay["feats"]
        d = draft_layout(cfg, 1024)
        assert d["total"] == d["kv"] + d["logits"] + d["feats"]
        t = tiny_layout(M.TINY, 512)
        assert t["total"] == t["kv"] + t["logits"]

    def test_manifest_exists_after_aot(self):
        """Integration guard: when artifacts/ is built, the manifest must
        reference existing files with consistent layouts."""
        art = os.path.join(os.path.dirname(__file__), "../../artifacts")
        man_path = os.path.join(art, "manifest.json")
        if not os.path.exists(man_path):
            pytest.skip("artifacts not built")
        man = json.load(open(man_path))
        assert len(man["executables"]) > 50
        for name, e in list(man["executables"].items())[:20]:
            assert os.path.exists(os.path.join(art, e["file"])), name
            if e.get("layout"):
                lay = e["layout"]
                assert lay["total"] >= lay["kv"]
