//! TCP JSON-lines serving protocol (std::net — tokio is not in the
//! offline vendor set, and the PJRT client is single-device anyway, so a
//! blocking accept loop with a request queue is the right shape).
//!
//! Protocol: one JSON object per line.
//!   → {"op":"generate","prompt":"...","max_new":128,"engine":"spec_pv",
//!      "temperature":0.0}
//!   ← {"ok":true,"text":"...","tokens":57,"tok_per_s":31.2,"tau":2.9,
//!      "modes":{"full":1,"partial":12,"refresh":3}}
//!   → {"op":"metrics"}           ← {"ok":true,"summary":"..."}
//!   → {"op":"ping"}              ← {"ok":true}
//!   → {"op":"shutdown"}          ← {"ok":true}  (server exits)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::{anyhow, Context, Result};

use crate::config::Config;
use crate::coordinator::Coordinator;
use crate::engine::GenRequest;
use crate::json::Json;
use crate::runtime::Runtime;
use crate::tokenizer;

/// Serve forever (or until a `shutdown` op). One connection at a time:
/// the device is serial, so parallel accepts would only queue anyway.
pub fn serve(rt: &Runtime, cfg: Config) -> Result<()> {
    let listener = TcpListener::bind(&cfg.server_addr)
        .with_context(|| format!("binding {}", cfg.server_addr))?;
    println!("specpv server listening on {}", cfg.server_addr);
    let mut coord = Coordinator::new(rt, cfg);
    for stream in listener.incoming() {
        let stream = stream?;
        match handle_conn(stream, &mut coord) {
            Ok(true) => break, // shutdown requested
            Ok(false) => {}
            Err(e) => eprintln!("connection error: {e:#}"),
        }
    }
    println!("server metrics: {}", coord.registry.summary());
    Ok(())
}

fn handle_conn(stream: TcpStream, coord: &mut Coordinator) -> Result<bool> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(false); // client closed
        }
        let req = match Json::parse(line.trim()) {
            Ok(j) => j,
            Err(e) => {
                write_json(
                    &mut writer,
                    Json::obj().set("ok", false).set("error", format!("{e:#}")),
                )?;
                continue;
            }
        };
        let op = req.get("op").and_then(|x| x.as_str()).unwrap_or("generate");
        match op {
            "ping" => write_json(&mut writer, Json::obj().set("ok", true))?,
            "metrics" => write_json(
                &mut writer,
                Json::obj()
                    .set("ok", true)
                    .set("summary", coord.registry.summary()),
            )?,
            "shutdown" => {
                write_json(&mut writer, Json::obj().set("ok", true))?;
                return Ok(true);
            }
            "generate" => {
                let resp = match handle_generate(&req, coord) {
                    Ok(j) => j,
                    Err(e) => Json::obj()
                        .set("ok", false)
                        .set("error", format!("{e:#}")),
                };
                write_json(&mut writer, resp)?;
            }
            other => write_json(
                &mut writer,
                Json::obj()
                    .set("ok", false)
                    .set("error", format!("unknown op '{other}' from {peer}")),
            )?,
        }
    }
}

fn handle_generate(req: &Json, coord: &mut Coordinator) -> Result<Json> {
    let prompt = req
        .get("prompt")
        .and_then(|x| x.as_str())
        .ok_or_else(|| anyhow!("missing 'prompt'"))?;
    let max_new = req
        .get("max_new")
        .and_then(|x| x.as_usize())
        .unwrap_or(coord.cfg.max_new_tokens);
    let temperature = req
        .get("temperature")
        .and_then(|x| x.as_f64())
        .unwrap_or(coord.cfg.temperature as f64) as f32;
    let engine = match req.get("engine").and_then(|x| x.as_str()) {
        Some(e) => Some(e.parse()?),
        None => None,
    };
    let seed = req.get("seed").and_then(|x| x.as_i64()).unwrap_or(0) as u64;

    let gen = GenRequest {
        prompt: tokenizer::encode(prompt),
        max_new,
        temperature,
        seed,
    };
    let id = coord.submit(gen, engine)?;
    coord.step();
    let tr = coord.get(id).ok_or_else(|| anyhow!("request vanished"))?;
    match (&tr.state, &tr.result) {
        (crate::coordinator::RequestState::Done, Some(r)) => Ok(Json::obj()
            .set("ok", true)
            .set("text", r.text())
            .set("tokens", r.tokens.len())
            .set("tok_per_s", r.stats.throughput())
            .set("tau", r.stats.accept_len())
            .set(
                "modes",
                Json::obj()
                    .set("full", r.stats.full_steps)
                    .set("partial", r.stats.partial_steps)
                    .set("refresh", r.stats.refresh_steps),
            )
            .set("latency_s", tr.service_secs)),
        (crate::coordinator::RequestState::Failed(e), _) => {
            Ok(Json::obj().set("ok", false).set("error", e.as_str()))
        }
        _ => Ok(Json::obj().set("ok", false).set("error", "not finished")),
    }
}

fn write_json(w: &mut TcpStream, j: Json) -> Result<()> {
    let mut s = j.to_string();
    s.push('\n');
    w.write_all(s.as_bytes())?;
    w.flush()?;
    Ok(())
}

/// Blocking client for examples/tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn call(&mut self, req: Json) -> Result<Json> {
        let mut s = req.to_string();
        s.push('\n');
        self.stream.write_all(s.as_bytes())?;
        self.stream.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim())
    }

    pub fn generate(&mut self, prompt: &str, max_new: usize, engine: &str) -> Result<Json> {
        self.call(
            Json::obj()
                .set("op", "generate")
                .set("prompt", prompt)
                .set("max_new", max_new)
                .set("engine", engine),
        )
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.call(Json::obj().set("op", "shutdown"))?;
        Ok(())
    }
}
