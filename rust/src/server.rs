//! TCP JSON-lines serving protocol (std::net — tokio is not in the
//! offline vendor set). `serve`/`serve_on` are thin compatibility
//! wrappers over [`crate::serve`]: a nonblocking event-loop front end
//! owns every client socket and routes parsed ops to worker shards —
//! each one `Coordinator` + `Backend` on its own thread — by a
//! prefix-affinity rendezvous hash (`--shards N`; the default 1 keeps
//! single-worker behavior with byte-identical output). Connections are
//! served **concurrently**: many clients interleave at decode-round
//! granularity instead of waiting for whole generations.
//!
//! Protocol: one JSON object per line (see DESIGN.md §"Serving protocol").
//!   → {"op":"generate","prompt":"...","max_new":128,"engine":"spec_pv",
//!      "temperature":0.0,"seed":0,"timeout_ms":30000}
//!     (`timeout_ms` is the per-request deadline; the older `deadline_s`
//!      spelling still parses and loses to `timeout_ms` when both are
//!      present. A request that overruns it gets one final line with
//!      "deadline_exceeded":true and its KV pages are freed.)
//!   ← {"ok":true,"id":0,"done":true,"text":"...","tokens":57,
//!      "tok_per_s":31.2,"tau":2.9,"ttft_s":0.21,"steps":19,
//!      "modes":{"full":1,"partial":12,"refresh":3}}
//!   → {"op":"generate","stream":true,...}
//!   ← {"ok":true,"id":1,"stream":true,"queued":true}      (ack with id)
//!   ← {"ok":true,"id":1,"stream":true,"step":1,"delta":"…","done":false}*
//!   ← {"ok":true,"id":1,"done":true,"text":"…",...}       (final)
//!   → {"op":"cancel","id":1}     ← {"ok":true,"cancelled":true}
//!   → {"op":"admin","cmd":"metrics","v":1}
//!                                ← {"ok":true,"v":1,"cmd":"metrics",
//!                                   "summary":"...","queue_depth":0,...}
//!   → {"op":"admin","cmd":"cache"}  (prefix cache + swap stats; `v`
//!                                    defaults to 1, other versions error)
//!   → {"op":"admin","cmd":"kv"}  ← {"ok":true,"v":1,"cmd":"kv",
//!                                   "pages_resident":..,"pages_shared":..,
//!                                   "frag_pct":..,...}  (page-pool gauges)
//!   → {"op":"admin","cmd":"shards"}
//!                                ← {"ok":true,"v":1,"cmd":"shards",
//!                                   "shards":2,"routed_away":0,
//!                                   "per_shard":[{"shard":0,"load":..,
//!                                    "placed":..,"tokens_out":..,...},..]}
//!   → {"op":"metrics"} / {"op":"cache"}
//!                                ← same bodies as the admin subcommands
//!                                   plus "deprecated":true — flat op
//!                                   names are aliases kept for old
//!                                   clients
//!   → {"op":"ping"}              ← {"ok":true}
//!   → {"op":"shutdown"}          ← {"ok":true}  (server drains: stops
//!                                   admitting, in-flight streaming
//!                                   clients get {"ok":true,"id":N,
//!                                   "draining":true,"done":false}, every
//!                                   in-flight request still gets its
//!                                   final line, then the server exits)
//!
//! With `shards > 1`, `metrics`/`kv`/`cache` bodies are merged across
//! shards: counters sum, ratios and percentiles average, "ok" ANDs.
//!
//! `generate` also accepts `"priority":N` — under KV-byte pressure the
//! coordinator swaps out the lowest-priority active session first.
//!
//! Overload control: with `--shard-queue N`, a generate bound for a
//! shard already carrying N in-flight sessions is shed immediately with
//! ← {"ok":false,"error":"overloaded","retry_after_ms":M} — no id is
//! assigned and no final line follows; clients should back off at least
//! `retry_after_ms` (plus jitter) and resend. The [`Client`]'s
//! `*_retry` helpers implement that loop.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::{Context, Result};

use crate::backend::Backend;
use crate::config::Config;
use crate::coordinator::Coordinator;
use crate::json::Json;
use crate::util::rng::Rng;

/// Attempt cap for the [`Client`] retry helpers: the last attempt's
/// response is returned whatever it says.
const RETRY_ATTEMPTS: usize = 16;

/// Backoff cap between retry attempts.
const RETRY_MAX_MS: u64 = 500;

/// Whether a response line is the structured overload rejection.
fn overloaded(j: &Json) -> bool {
    j.get("error").and_then(|x| x.as_str()) == Some("overloaded")
}

/// Sleep out the server's `retry_after_ms` hint plus up to 100% jitter
/// (decorrelates a thundering herd of shed clients), capped.
fn backoff(rng: &mut Rng, j: &Json) {
    let base = j
        .get("retry_after_ms")
        .and_then(|x| x.as_f64())
        .map(|ms| ms.max(1.0) as u64)
        .unwrap_or(50);
    let wait = (base + rng.below(base.max(1) as usize) as u64).min(RETRY_MAX_MS);
    std::thread::sleep(std::time::Duration::from_millis(wait));
}

/// Serve until drained (a `shutdown` op or Ctrl-C) on the configured
/// address. Delegates to [`crate::serve::serve`].
pub fn serve(be: &dyn Backend, cfg: Config) -> Result<()> {
    crate::serve::serve(be, cfg)
}

/// Serve on an already-bound listener with an existing coordinator.
/// Tests inject a scripted coordinator here; `serve` binds the real one.
/// Delegates to [`crate::serve::serve_on`].
pub fn serve_on(listener: TcpListener, coord: Coordinator<'_>) -> Result<()> {
    crate::serve::serve_on(listener, coord)
}

/// Blocking client for examples/tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    fn send_line(&mut self, req: &Json) -> Result<()> {
        let mut s = req.to_string();
        s.push('\n');
        self.stream.write_all(s.as_bytes())?;
        self.stream.flush()?;
        Ok(())
    }

    fn read_json(&mut self) -> Result<Json> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            anyhow::bail!("server closed the connection");
        }
        Json::parse(line.trim())
    }

    /// One request → one response line.
    pub fn call(&mut self, req: Json) -> Result<Json> {
        self.send_line(&req)?;
        self.read_json()
    }

    /// Fire a request without waiting for the reply (used to interleave a
    /// `cancel` op with an in-flight streaming generation).
    pub fn send(&mut self, req: Json) -> Result<()> {
        self.send_line(&req)
    }

    /// Read the next response line.
    pub fn recv(&mut self) -> Result<Json> {
        self.read_json()
    }

    pub fn generate(
        &mut self,
        prompt: &str,
        max_new: usize,
        engine: &str,
    ) -> Result<Json> {
        self.call(
            Json::obj()
                .set("op", "generate")
                .set("prompt", prompt)
                .set("max_new", max_new)
                .set("engine", engine),
        )
    }

    /// Streaming generation: returns (per-step delta lines, final line).
    /// The first line the server sends is the `queued` ack carrying the
    /// request id; it is included in the step-line vector.
    pub fn generate_stream(
        &mut self,
        prompt: &str,
        max_new: usize,
        engine: &str,
    ) -> Result<(Vec<Json>, Json)> {
        self.send_line(
            &Json::obj()
                .set("op", "generate")
                .set("prompt", prompt)
                .set("max_new", max_new)
                .set("engine", engine)
                .set("stream", true),
        )?;
        let mut steps = Vec::new();
        loop {
            let j = self.read_json()?;
            if j.get("done").and_then(|x| x.as_bool()) == Some(true)
                || j.get("ok").and_then(|x| x.as_bool()) == Some(false)
            {
                return Ok((steps, j));
            }
            steps.push(j);
        }
    }

    /// [`Client::generate`] with retry on the structured overload
    /// rejection: honors the server's `retry_after_ms` with seeded
    /// jitter, gives up (returning the rejection) after
    /// [`RETRY_ATTEMPTS`]. Resubmission is safe — a shed request was
    /// never admitted (no id, no partial output).
    pub fn generate_retry(
        &mut self,
        prompt: &str,
        max_new: usize,
        engine: &str,
        seed: u64,
    ) -> Result<Json> {
        let mut rng = Rng::new(seed ^ 0x7265_7472_79);
        let mut last = self.generate(prompt, max_new, engine)?;
        for _ in 1..RETRY_ATTEMPTS {
            if !overloaded(&last) {
                break;
            }
            backoff(&mut rng, &last);
            last = self.generate(prompt, max_new, engine)?;
        }
        Ok(last)
    }

    /// [`Client::generate_stream`] with the same overload retry loop;
    /// collected step lines reset on every attempt (a shed request
    /// streamed nothing).
    pub fn generate_stream_retry(
        &mut self,
        prompt: &str,
        max_new: usize,
        engine: &str,
        seed: u64,
    ) -> Result<(Vec<Json>, Json)> {
        let mut rng = Rng::new(seed ^ 0x7265_7472_79);
        let mut last = self.generate_stream(prompt, max_new, engine)?;
        for _ in 1..RETRY_ATTEMPTS {
            if !overloaded(&last.1) {
                break;
            }
            backoff(&mut rng, &last.1);
            last = self.generate_stream(prompt, max_new, engine)?;
        }
        Ok(last)
    }

    pub fn cancel(&mut self, id: u64) -> Result<Json> {
        self.call(Json::obj().set("op", "cancel").set("id", id as i64))
    }

    /// Reattach to a journaled request after a server restart (or a
    /// dropped connection). The server replies with a retry header line
    /// (`{"ok":true,"id":..,"retry":true,"delivered":W,"done":..}`)
    /// carrying the delivered-token watermark, then streams exactly the
    /// lines the original connection never received. Returns
    /// `(header, step lines, final line)`; when the session already
    /// finished, the buffered final line follows immediately.
    pub fn resume_stream(&mut self, id: u64) -> Result<(Json, Vec<Json>, Json)> {
        self.send_line(
            &Json::obj().set("op", "generate_retry").set("id", id as i64),
        )?;
        let header = self.read_json()?;
        if header.get("ok").and_then(|x| x.as_bool()) == Some(false) {
            let final_line = header.clone();
            return Ok((header, Vec::new(), final_line));
        }
        let mut steps = Vec::new();
        loop {
            let j = self.read_json()?;
            if j.get("done").and_then(|x| x.as_bool()) == Some(true)
                || j.get("ok").and_then(|x| x.as_bool()) == Some(false)
            {
                return Ok((header, steps, j));
            }
            steps.push(j);
        }
    }

    /// Versioned admin subcommand (`metrics`, `kv`, `cache`, `shards`).
    pub fn admin(&mut self, cmd: &str) -> Result<Json> {
        self.call(Json::obj().set("op", "admin").set("cmd", cmd).set("v", 1i64))
    }

    /// Deprecated alias for `admin("metrics")`.
    pub fn metrics(&mut self) -> Result<Json> {
        self.call(Json::obj().set("op", "metrics"))
    }

    /// Deprecated alias for `admin("cache")` — KV state manager stats
    /// (prefix cache, resident bytes, swaps).
    pub fn cache(&mut self) -> Result<Json> {
        self.call(Json::obj().set("op", "cache"))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.call(Json::obj().set("op", "shutdown"))?;
        Ok(())
    }
}
