//! TCP JSON-lines serving protocol (std::net — tokio is not in the
//! offline vendor set). Connections are served **concurrently**: each
//! accepted socket gets a reader thread (parses ops into [`WorkItem`]s)
//! and a writer thread (drains response lines), all feeding one shared
//! `std::sync::mpsc` work queue. The device loop — the only thread that
//! touches the backend, whose handles are not `Send` — drains the
//! queue and drives the coordinator's continuous-batching `tick()`, so
//! many clients interleave at decode-round granularity instead of
//! waiting for whole generations.
//!
//! Protocol: one JSON object per line (see DESIGN.md §"Serving protocol").
//!   → {"op":"generate","prompt":"...","max_new":128,"engine":"spec_pv",
//!      "temperature":0.0,"seed":0,"deadline_s":30.0}
//!   ← {"ok":true,"id":0,"done":true,"text":"...","tokens":57,
//!      "tok_per_s":31.2,"tau":2.9,"ttft_s":0.21,"steps":19,
//!      "modes":{"full":1,"partial":12,"refresh":3}}
//!   → {"op":"generate","stream":true,...}
//!   ← {"ok":true,"id":1,"stream":true,"queued":true}      (ack with id)
//!   ← {"ok":true,"id":1,"stream":true,"step":1,"delta":"…","done":false}*
//!   ← {"ok":true,"id":1,"done":true,"text":"…",...}       (final)
//!   → {"op":"cancel","id":1}     ← {"ok":true,"cancelled":true}
//!   → {"op":"admin","cmd":"metrics","v":1}
//!                                ← {"ok":true,"v":1,"cmd":"metrics",
//!                                   "summary":"...","queue_depth":0,...}
//!   → {"op":"admin","cmd":"cache"}  (prefix cache + swap stats; `v`
//!                                    defaults to 1, other versions error)
//!   → {"op":"admin","cmd":"kv"}  ← {"ok":true,"v":1,"cmd":"kv",
//!                                   "pages_resident":..,"pages_shared":..,
//!                                   "frag_pct":..,...}  (page-pool gauges)
//!   → {"op":"metrics"} / {"op":"cache"}
//!                                ← same bodies as the admin subcommands
//!                                   plus "deprecated":true — flat op
//!                                   names are aliases kept for old
//!                                   clients
//!   → {"op":"ping"}              ← {"ok":true}
//!   → {"op":"shutdown"}          ← {"ok":true}  (server exits)
//!
//! `generate` also accepts `"priority":N` — under KV-byte pressure the
//! coordinator swaps out the lowest-priority active session first.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::backend::Backend;
use crate::config::{Config, EngineKind};
use crate::coordinator::{Coordinator, Event, RequestId, RequestState};
use crate::engine::GenRequest;
use crate::json::Json;
use crate::tokenizer;

/// One parsed client operation, routed to the device loop together with
/// the originating connection's reply channel.
enum WorkItem {
    Generate {
        gen: GenRequest,
        engine: Option<EngineKind>,
        stream: bool,
        deadline_secs: Option<f64>,
        priority: i32,
        reply: Sender<String>,
    },
    Cancel { id: RequestId, reply: Sender<String> },
    Admin { cmd: AdminCmd, legacy: bool, reply: Sender<String> },
    Ping { reply: Sender<String> },
    Shutdown { reply: Sender<String> },
}

/// Read-only admin subcommands (`{"op":"admin","cmd":...,"v":1}`). The
/// old flat `metrics`/`cache` op names parse to the same commands with
/// `legacy: true` and answer with a `"deprecated":true` marker.
#[derive(Clone, Copy)]
enum AdminCmd {
    Metrics,
    Kv,
    Cache,
}

impl AdminCmd {
    fn name(self) -> &'static str {
        match self {
            AdminCmd::Metrics => "metrics",
            AdminCmd::Kv => "kv",
            AdminCmd::Cache => "cache",
        }
    }
}

/// Request-level defaults a reader thread needs to parse `generate` ops
/// without touching the coordinator.
#[derive(Clone)]
struct Defaults {
    max_new: usize,
    temperature: f32,
}

/// Serve forever (or until a `shutdown` op) on the configured address.
pub fn serve(be: &dyn Backend, cfg: Config) -> Result<()> {
    let listener = TcpListener::bind(&cfg.server_addr)
        .with_context(|| format!("binding {}", cfg.server_addr))?;
    println!(
        "specpv server listening on {} ({} backend)",
        cfg.server_addr,
        be.name()
    );
    let coord = Coordinator::new(be, cfg);
    serve_on(listener, coord)
}

/// Serve on an already-bound listener with an existing coordinator.
/// Tests inject a scripted coordinator here; `serve` binds the real one.
pub fn serve_on(listener: TcpListener, mut coord: Coordinator<'_>) -> Result<()> {
    let addr = listener.local_addr()?;
    let defaults = Defaults {
        max_new: coord.cfg.max_new_tokens,
        temperature: coord.cfg.temperature,
    };
    let (work_tx, work_rx) = channel::<WorkItem>();
    let shutdown = Arc::new(AtomicBool::new(false));

    thread::scope(|s| {
        let accept_shutdown = shutdown.clone();
        let accept_tx = work_tx.clone();
        let accept_defaults = defaults;
        s.spawn(move || {
            for stream in listener.incoming() {
                if accept_shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                // short read timeout so reader threads can observe
                // shutdown instead of blocking on idle clients forever
                let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
                let Ok(write_half) = stream.try_clone() else { continue };
                let (conn_tx, conn_rx) = channel::<String>();
                let wsd = accept_shutdown.clone();
                s.spawn(move || writer_loop(write_half, conn_rx, wsd));
                let tx = accept_tx.clone();
                let sd = accept_shutdown.clone();
                let d = accept_defaults.clone();
                s.spawn(move || reader_loop(stream, tx, conn_tx, sd, d));
            }
        });

        let served = device_loop(&mut coord, &work_rx);
        // unblock the acceptor (and, via their timeouts, readers/writers)
        shutdown.store(true, Ordering::SeqCst);
        // drop work items still buffered in the channel: they hold clones
        // of per-connection reply senders that would otherwise keep
        // writer threads alive past shutdown
        while work_rx.try_recv().is_ok() {}
        let _ = TcpStream::connect(addr);
        served
    })?;
    coord.sync_backend_counters();
    println!("server metrics: {}", coord.registry.summary());
    Ok(())
}

/// Per-connection writer: drains response lines onto the socket. Polls
/// the shutdown flag so a sender clone buffered somewhere (e.g. a work
/// item that was never consumed) cannot keep the thread alive past
/// server exit.
fn writer_loop(mut stream: TcpStream, rx: Receiver<String>, shutdown: Arc<AtomicBool>) {
    loop {
        match rx.recv_timeout(Duration::from_millis(200)) {
            Ok(line) => {
                if stream
                    .write_all(line.as_bytes())
                    .and_then(|_| stream.flush())
                    .is_err()
                {
                    return;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// Per-connection reader: parses JSON lines into work items.
fn reader_loop(
    stream: TcpStream,
    work: Sender<WorkItem>,
    out: Sender<String>,
    shutdown: Arc<AtomicBool>,
    defaults: Defaults,
) {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return, // client closed
            Ok(_) => {
                let trimmed = line.trim();
                if !trimmed.is_empty() {
                    match parse_item(trimmed, &defaults, out.clone()) {
                        Ok(item) => {
                            if work.send(item).is_err() {
                                let _ = out.send(line_of(
                                    Json::obj()
                                        .set("ok", false)
                                        .set("error", "server shutting down"),
                                ));
                                return;
                            }
                        }
                        Err(e) => {
                            let _ = out.send(line_of(
                                Json::obj()
                                    .set("ok", false)
                                    .set("error", format!("{e:#}")),
                            ));
                        }
                    }
                }
                line.clear();
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

fn parse_item(raw: &str, defaults: &Defaults, reply: Sender<String>) -> Result<WorkItem> {
    let req = Json::parse(raw)?;
    let op = req.get("op").and_then(|x| x.as_str()).unwrap_or("generate");
    match op {
        "ping" => Ok(WorkItem::Ping { reply }),
        "admin" => {
            let v = req.get("v").and_then(|x| x.as_i64()).unwrap_or(1);
            if v != 1 {
                return Err(anyhow!("unsupported admin version {v} (supported: 1)"));
            }
            let cmd = match req.get("cmd").and_then(|x| x.as_str()) {
                Some("metrics") => AdminCmd::Metrics,
                Some("kv") => AdminCmd::Kv,
                Some("cache") => AdminCmd::Cache,
                Some(other) => {
                    return Err(anyhow!(
                        "unknown admin cmd '{other}' (metrics|kv|cache)"
                    ))
                }
                None => return Err(anyhow!("admin needs 'cmd'")),
            };
            Ok(WorkItem::Admin { cmd, legacy: false, reply })
        }
        // deprecated flat aliases for the admin subcommands
        "metrics" => Ok(WorkItem::Admin { cmd: AdminCmd::Metrics, legacy: true, reply }),
        "cache" => Ok(WorkItem::Admin { cmd: AdminCmd::Cache, legacy: true, reply }),
        "shutdown" => Ok(WorkItem::Shutdown { reply }),
        "cancel" => {
            let id = req
                .get("id")
                .and_then(|x| x.as_i64())
                .ok_or_else(|| anyhow!("cancel needs 'id'"))? as RequestId;
            Ok(WorkItem::Cancel { id, reply })
        }
        "generate" => {
            let prompt = req
                .get("prompt")
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow!("missing 'prompt'"))?;
            let max_new = req
                .get("max_new")
                .and_then(|x| x.as_usize())
                .unwrap_or(defaults.max_new);
            let temperature = req
                .get("temperature")
                .and_then(|x| x.as_f64())
                .unwrap_or(defaults.temperature as f64) as f32;
            let engine = match req.get("engine").and_then(|x| x.as_str()) {
                Some(e) => Some(e.parse()?),
                None => None,
            };
            let seed = req.get("seed").and_then(|x| x.as_i64()).unwrap_or(0) as u64;
            let stream =
                req.get("stream").and_then(|x| x.as_bool()).unwrap_or(false);
            let deadline_secs = req.get("deadline_s").and_then(|x| x.as_f64());
            let priority =
                req.get("priority").and_then(|x| x.as_i64()).unwrap_or(0) as i32;
            Ok(WorkItem::Generate {
                gen: GenRequest {
                    prompt: tokenizer::encode(prompt),
                    max_new,
                    temperature,
                    seed,
                },
                engine,
                stream,
                deadline_secs,
                priority,
                reply,
            })
        }
        other => Err(anyhow!("unknown op '{other}'")),
    }
}

/// Per-request reply routing held by the device loop.
struct PendingReply {
    reply: Sender<String>,
    stream: bool,
}

/// The single device-owning loop: drain work items, tick the scheduler,
/// route events back to the right connection. Returns on `shutdown`.
fn device_loop(coord: &mut Coordinator<'_>, work_rx: &Receiver<WorkItem>) -> Result<()> {
    let mut pending: HashMap<RequestId, PendingReply> = HashMap::new();
    loop {
        // block when there is nothing to schedule, drain otherwise
        if coord.idle() {
            match work_rx.recv() {
                Ok(item) => {
                    if handle_item(item, coord, &mut pending) {
                        return Ok(());
                    }
                }
                Err(_) => return Ok(()),
            }
        }
        loop {
            match work_rx.try_recv() {
                Ok(item) => {
                    if handle_item(item, coord, &mut pending) {
                        return Ok(());
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return Ok(()),
            }
        }
        for ev in coord.tick() {
            route_event(ev, coord, &mut pending);
        }
    }
}

/// Apply one work item; returns true on shutdown.
fn handle_item(
    item: WorkItem,
    coord: &mut Coordinator<'_>,
    pending: &mut HashMap<RequestId, PendingReply>,
) -> bool {
    match item {
        WorkItem::Ping { reply } => {
            send(&reply, Json::obj().set("ok", true));
        }
        WorkItem::Admin { cmd, legacy, reply } => {
            let body = match cmd {
                AdminCmd::Metrics => metrics_body(coord),
                AdminCmd::Kv => kv_body(coord),
                AdminCmd::Cache => cache_body(coord),
            };
            let body = if legacy {
                body.set("deprecated", true)
            } else {
                body.set("v", 1i64).set("cmd", cmd.name())
            };
            send(&reply, body);
        }
        WorkItem::Shutdown { reply } => {
            send(&reply, Json::obj().set("ok", true));
            return true;
        }
        WorkItem::Cancel { id, reply } => {
            let cancelled = coord.cancel(id);
            if cancelled {
                if let Some(p) = pending.remove(&id) {
                    send_final(&p, coord, id);
                }
            }
            send(&reply, Json::obj().set("ok", true).set("cancelled", cancelled));
        }
        WorkItem::Generate { gen, engine, stream, deadline_secs, priority, reply } => {
            match coord.submit_opts(
                gen,
                crate::coordinator::SubmitOpts { engine, deadline_secs, priority },
            ) {
                Ok(id) => {
                    if stream {
                        // ack with the id so the client can cancel
                        send(
                            &reply,
                            Json::obj()
                                .set("ok", true)
                                .set("id", id as i64)
                                .set("stream", true)
                                .set("queued", true),
                        );
                    }
                    pending.insert(id, PendingReply { reply, stream });
                }
                Err(e) => {
                    send(
                        &reply,
                        Json::obj().set("ok", false).set("error", format!("{e:#}")),
                    );
                }
            }
        }
    }
    false
}

/// The `admin metrics` body: scheduler registry + backend counters.
fn metrics_body(coord: &mut Coordinator<'_>) -> Json {
    coord.sync_backend_counters();
    let reg = &coord.registry;
    Json::obj()
        .set("ok", true)
        .set("summary", reg.summary())
        .set(
            "backend",
            if reg.backend.is_empty() { "scripted" } else { reg.backend.as_str() },
        )
        .set("executions", reg.executions as i64)
        .set("exec_secs", reg.exec_secs)
        .set("compilations", reg.compilations as i64)
        .set("queue_depth", coord.queue_len())
        .set("active", coord.active_len())
        .set("completed", reg.completed as i64)
        .set("failed", reg.failed as i64)
        .set("cancelled", reg.cancelled as i64)
        .set("kv_resident_bytes", reg.kv_resident_bytes)
        .set("kv_budget_bytes", reg.kv_budget_bytes)
        .set("kv_pages_resident", reg.kv_pages_resident)
        .set("kv_pages_shared", reg.kv_pages_shared)
        .set("kv_frag_pct", reg.kv_frag_pct)
        .set("swap_outs", reg.swap_outs as i64)
        .set("swap_ins", reg.swap_ins as i64)
        .set("swap_faults", reg.swap_faults as i64)
        .set("prefix_hits", reg.prefix_hits as i64)
        .set("prefix_misses", reg.prefix_misses as i64)
        .set("threads", reg.threads)
        .set("fused_groups", reg.batch_groups as i64)
        .set("batch_ops_fused", reg.batch_ops_fused as i64)
        .set("batch_ops_single", reg.batch_ops_single as i64)
        .set("fallback_steps", reg.fallback_steps as i64)
        .set("batch_mean_width", reg.batch_mean_width())
        .set("batch_max_width", reg.batch_width_max)
        .set("batch_tick_groups", reg.batch_tick_groups)
        .set("batched_frac", reg.batched_frac())
        .set("ttft_p50_s", reg.ttft.p50())
        .set("ttft_p99_s", reg.ttft.p99())
}

/// The `admin cache` body: prefix cache + swap-tier aggregates.
fn cache_body(coord: &mut Coordinator<'_>) -> Json {
    let s = coord.kv_stats();
    Json::obj()
        .set("ok", true)
        .set("prefix_entries", s.prefix.entries)
        .set("prefix_bytes", s.prefix.bytes)
        .set("prefix_budget_bytes", s.prefix.budget_bytes)
        .set("prefix_hits", s.prefix.hits as i64)
        .set("prefix_misses", s.prefix.misses as i64)
        .set("prefix_insertions", s.prefix.insertions as i64)
        .set("prefix_evictions", s.prefix.evictions as i64)
        .set("kv_resident_bytes", s.resident_bytes)
        .set("kv_budget_bytes", s.budget_bytes)
        .set("live_states", s.live_states)
        .set("swapped", s.swapped)
        .set("swap_bytes", s.swap_bytes)
        .set("swap_outs", s.swap_outs as i64)
        .set("swap_ins", s.swap_ins as i64)
}

/// The `admin kv` body: page-level pool gauges (residency, sharing,
/// dedup/CoW counters, quantization and spill tiers).
fn kv_body(coord: &mut Coordinator<'_>) -> Json {
    let s = coord.kv_stats();
    let p = &s.pages;
    Json::obj()
        .set("ok", true)
        .set("page_bytes", p.page_bytes)
        .set("pages_resident", p.pages_resident)
        .set("pages_shared", p.pages_shared)
        .set("pages_zero", p.pages_zero)
        .set("pages_spilled", p.pages_spilled)
        .set("ram_bytes", p.ram_bytes)
        .set("disk_bytes", p.disk_bytes)
        .set("frag_pct", p.frag_pct)
        .set("page_allocs", p.page_allocs as i64)
        .set("dedup_hits", p.dedup_hits as i64)
        .set("cow_copies", p.cow_copies as i64)
        .set("quant_pages", p.quant_pages as i64)
        .set("spills", p.spills as i64)
        .set("spill_loads", p.spill_loads as i64)
        .set("swap_faults", p.swap_faults as i64)
        .set("parked_sessions", s.swapped)
        .set("parked_bytes", s.swap_bytes)
}

fn route_event(
    ev: Event,
    coord: &Coordinator<'_>,
    pending: &mut HashMap<RequestId, PendingReply>,
) {
    match ev {
        // swap transitions — including a recovered SwapFault, which only
        // re-queues the request — are scheduler-internal (output is
        // unaffected); operators observe them through the admin ops
        Event::Started { .. }
        | Event::SwappedOut { .. }
        | Event::Resumed { .. }
        | Event::SwapFault { .. } => {}
        Event::Step { id, new_tokens, step, .. } => {
            if let Some(p) = pending.get(&id) {
                if p.stream && !new_tokens.is_empty() {
                    send(
                        &p.reply,
                        Json::obj()
                            .set("ok", true)
                            .set("id", id as i64)
                            .set("stream", true)
                            .set("step", step)
                            .set("delta", tokenizer::decode(&new_tokens))
                            .set("done", false),
                    );
                }
            }
        }
        Event::Finished { id } | Event::Cancelled { id } | Event::Failed { id, .. } => {
            if let Some(p) = pending.remove(&id) {
                send_final(&p, coord, id);
            }
        }
    }
}

/// The terminal response line for a request (results keyed by id — the
/// device loop never assumes "the last submitted request finished").
fn send_final(p: &PendingReply, coord: &Coordinator<'_>, id: RequestId) {
    let Some(tr) = coord.get(id) else {
        send(
            &p.reply,
            Json::obj().set("ok", false).set("error", "request vanished"),
        );
        return;
    };
    let resp = match (&tr.state, &tr.result) {
        (RequestState::Done, Some(r)) => Json::obj()
            .set("ok", true)
            .set("id", id as i64)
            .set("done", true)
            .set("text", r.text())
            .set("tokens", r.tokens.len())
            .set("tok_per_s", r.stats.throughput())
            .set("tau", r.stats.accept_len())
            .set(
                "modes",
                Json::obj()
                    .set("full", r.stats.full_steps)
                    .set("partial", r.stats.partial_steps)
                    .set("refresh", r.stats.refresh_steps),
            )
            .set("latency_s", tr.service_secs)
            .set("ttft_s", tr.ttft_secs)
            .set("steps", tr.steps),
        (RequestState::Cancelled, r) => Json::obj()
            .set("ok", true)
            .set("id", id as i64)
            .set("done", true)
            .set("cancelled", true)
            .set(
                "text",
                r.as_ref().map(|r| r.text()).unwrap_or_default(),
            ),
        (RequestState::Failed(e), _) => Json::obj()
            .set("ok", false)
            .set("id", id as i64)
            .set("done", true)
            .set("error", e.as_str()),
        _ => Json::obj()
            .set("ok", false)
            .set("id", id as i64)
            .set("error", "not finished"),
    };
    send(&p.reply, resp);
}

fn line_of(j: Json) -> String {
    let mut s = j.to_string();
    s.push('\n');
    s
}

fn send(tx: &Sender<String>, j: Json) {
    let _ = tx.send(line_of(j));
}

/// Blocking client for examples/tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    fn send_line(&mut self, req: &Json) -> Result<()> {
        let mut s = req.to_string();
        s.push('\n');
        self.stream.write_all(s.as_bytes())?;
        self.stream.flush()?;
        Ok(())
    }

    fn read_json(&mut self) -> Result<Json> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            anyhow::bail!("server closed the connection");
        }
        Json::parse(line.trim())
    }

    /// One request → one response line.
    pub fn call(&mut self, req: Json) -> Result<Json> {
        self.send_line(&req)?;
        self.read_json()
    }

    /// Fire a request without waiting for the reply (used to interleave a
    /// `cancel` op with an in-flight streaming generation).
    pub fn send(&mut self, req: Json) -> Result<()> {
        self.send_line(&req)
    }

    /// Read the next response line.
    pub fn recv(&mut self) -> Result<Json> {
        self.read_json()
    }

    pub fn generate(
        &mut self,
        prompt: &str,
        max_new: usize,
        engine: &str,
    ) -> Result<Json> {
        self.call(
            Json::obj()
                .set("op", "generate")
                .set("prompt", prompt)
                .set("max_new", max_new)
                .set("engine", engine),
        )
    }

    /// Streaming generation: returns (per-step delta lines, final line).
    /// The first line the server sends is the `queued` ack carrying the
    /// request id; it is included in the step-line vector.
    pub fn generate_stream(
        &mut self,
        prompt: &str,
        max_new: usize,
        engine: &str,
    ) -> Result<(Vec<Json>, Json)> {
        self.send_line(
            &Json::obj()
                .set("op", "generate")
                .set("prompt", prompt)
                .set("max_new", max_new)
                .set("engine", engine)
                .set("stream", true),
        )?;
        let mut steps = Vec::new();
        loop {
            let j = self.read_json()?;
            if j.get("done").and_then(|x| x.as_bool()) == Some(true)
                || j.get("ok").and_then(|x| x.as_bool()) == Some(false)
            {
                return Ok((steps, j));
            }
            steps.push(j);
        }
    }

    pub fn cancel(&mut self, id: u64) -> Result<Json> {
        self.call(Json::obj().set("op", "cancel").set("id", id as i64))
    }

    /// Versioned admin subcommand (`metrics`, `kv`, `cache`).
    pub fn admin(&mut self, cmd: &str) -> Result<Json> {
        self.call(Json::obj().set("op", "admin").set("cmd", cmd).set("v", 1i64))
    }

    /// Deprecated alias for `admin("metrics")`.
    pub fn metrics(&mut self) -> Result<Json> {
        self.call(Json::obj().set("op", "metrics"))
    }

    /// Deprecated alias for `admin("cache")` — KV state manager stats
    /// (prefix cache, resident bytes, swaps).
    pub fn cache(&mut self) -> Result<Json> {
        self.call(Json::obj().set("op", "cache"))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.call(Json::obj().set("op", "shutdown"))?;
        Ok(())
    }
}
