//! Byte-level tokenizer (+ BOS/EOS/PAD specials), mirroring
//! `python/compile/data.py`: token id == byte value for 0..=255.

pub const BOS: u32 = 256;
pub const EOS: u32 = 257;
pub const PAD: u32 = 258;
pub const VOCAB: usize = 320;

/// Encode UTF-8 text to byte-level token ids (no specials added).
pub fn encode(text: &str) -> Vec<u32> {
    text.bytes().map(|b| b as u32).collect()
}

/// Decode token ids back to text; specials and out-of-range ids are
/// dropped, invalid UTF-8 is replaced.
pub fn decode(ids: &[u32]) -> String {
    let bytes: Vec<u8> =
        ids.iter().filter(|&&t| t < 256).map(|&t| t as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Is this token a sequence terminator?
pub fn is_eos(t: u32) -> bool {
    t == EOS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;

    #[test]
    fn ascii_roundtrip() {
        let s = "Hello, SpecPV! 123";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn utf8_roundtrip() {
        let s = "café → λ";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn specials_dropped_on_decode() {
        let mut ids = encode("ab");
        ids.insert(0, BOS);
        ids.push(EOS);
        ids.push(PAD);
        assert_eq!(decode(&ids), "ab");
    }

    #[test]
    fn ids_in_vocab() {
        for t in encode("any text ü") {
            assert!((t as usize) < VOCAB);
        }
    }

    #[test]
    fn roundtrip_property() {
        Prop::new("tokenizer ascii roundtrip", 200).run(|g| {
            let s: String = (0..g.usize_in(0, 64))
                .map(|_| (g.usize_in(0x20, 0x7e) as u8) as char)
                .collect();
            assert_eq!(decode(&encode(&s)), s);
        });
    }
}
