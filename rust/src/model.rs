//! Model-facing helpers: executable naming (consumed only by
//! `backend::pjrt`, which maps typed kernel ops to manifest entries) and
//! the decoding of extractor outputs shared by the engines.

use anyhow::{bail, Result};

use crate::manifest::{Consts, ModelInfo};

/// Executable names for one model size (manifest naming scheme).
pub fn verify_name(size: &str, bucket: usize, t: usize) -> String {
    format!("verify_{size}_b{bucket}_t{t}")
}

pub fn pverify_name(size: &str, p: usize, t: usize) -> String {
    format!("pverify_{size}_p{p}_t{t}")
}

pub fn commit_name(size: &str, bucket: usize, w: usize) -> String {
    format!("commit_{size}_b{bucket}_w{w}")
}

pub fn score_name(size: &str, bucket: usize) -> String {
    format!("score_{size}_b{bucket}")
}

pub fn gather_name(size: &str, bucket: usize, p: usize) -> String {
    format!("gather_{size}_b{bucket}_p{p}")
}

pub fn read_full_name(size: &str, bucket: usize) -> String {
    format!("read_full_{size}_b{bucket}")
}

pub fn read_last_name(size: &str, bucket: usize) -> String {
    format!("read_last_{size}_b{bucket}")
}

pub fn read_partial_name(size: &str, p: usize) -> String {
    format!("read_partial_{size}_p{p}")
}

pub fn draft_prefill_name(size: &str, bucket: usize) -> String {
    format!("draft_prefill_{size}_b{bucket}")
}

pub fn draft_step_name(size: &str, bucket: usize) -> String {
    format!("draft_step_{size}_b{bucket}")
}

pub fn read_draft_name(size: &str, bucket: usize) -> String {
    format!("read_draft_{size}_b{bucket}")
}

pub fn medusa_name(size: &str) -> String {
    format!("medusa_{size}")
}

/// Decoded output of a `read_full_*` / `read_partial_*` extractor: `rows`
/// rows of logits `[rows, vocab]` and fused features `[rows, 3h]`.
#[derive(Debug)]
pub struct ReadOut {
    pub rows: usize,
    pub vocab: usize,
    pub feat_dim: usize,
    data: Vec<f32>,
}

impl ReadOut {
    pub fn new(data: Vec<f32>, rows: usize, vocab: usize, feat_dim: usize) -> Result<ReadOut> {
        if data.len() != rows * (vocab + feat_dim) {
            bail!(
                "read output length {} != rows {rows} × (V {vocab} + F {feat_dim})",
                data.len()
            );
        }
        Ok(ReadOut { rows, vocab, feat_dim, data })
    }

    pub fn logits(&self, row: usize) -> &[f32] {
        assert!(row < self.rows);
        &self.data[row * self.vocab..(row + 1) * self.vocab]
    }

    pub fn feats(&self, row: usize) -> &[f32] {
        let off = self.rows * self.vocab;
        &self.data[off + row * self.feat_dim..off + (row + 1) * self.feat_dim]
    }
}

/// Decoded `read_draft_*` output: `[w, vocab]` logits + `[w, h]` hiddens.
#[derive(Debug)]
pub struct DraftOut {
    pub w: usize,
    pub vocab: usize,
    pub hidden: usize,
    data: Vec<f32>,
}

impl DraftOut {
    pub fn new(data: Vec<f32>, w: usize, vocab: usize, hidden: usize) -> Result<DraftOut> {
        if data.len() != w * (vocab + hidden) {
            bail!("draft read length {} mismatch", data.len());
        }
        Ok(DraftOut { w, vocab, hidden, data })
    }

    pub fn logits(&self, i: usize) -> &[f32] {
        &self.data[i * self.vocab..(i + 1) * self.vocab]
    }

    pub fn hidden(&self, i: usize) -> &[f32] {
        let off = self.w * self.vocab;
        &self.data[off + i * self.hidden..off + (i + 1) * self.hidden]
    }
}

/// Bytes of one token's K+V rows across all layers (offload cost model).
pub fn kv_bytes_per_token(info: &ModelInfo) -> usize {
    info.n_layer * 2 * info.n_head * info.d_head * 4
}

/// Required full bucket for a request: prompt + generation + tree/refresh
/// headroom.
pub fn bucket_need(prompt: usize, max_new: usize, consts: &Consts) -> usize {
    prompt + max_new + consts.chunk + consts.refresh_t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names() {
        assert_eq!(verify_name("s", 1024, 16), "verify_s_b1024_t16");
        assert_eq!(pverify_name("s", 768, 16), "pverify_s_p768_t16");
        assert_eq!(commit_name("s", 4096, 192), "commit_s_b4096_w192");
    }

    #[test]
    fn readout_slicing() {
        // 2 rows, vocab 3, feat 2
        let data = vec![
            1.0, 2.0, 3.0, 4.0, 5.0, 6.0, // logits rows
            0.1, 0.2, 0.3, 0.4, // feats rows
        ];
        let r = ReadOut::new(data, 2, 3, 2).unwrap();
        assert_eq!(r.logits(1), &[4.0, 5.0, 6.0]);
        assert_eq!(r.feats(0), &[0.1, 0.2]);
        assert!(ReadOut::new(vec![0.0; 7], 2, 3, 2).is_err());
    }

    #[test]
    fn draftout_slicing() {
        let data = vec![
            1.0, 2.0, // logits w=2, vocab=1
            9.0, 8.0, 7.0, 6.0, // hidden w=2, h=2
        ];
        let d = DraftOut::new(data, 2, 1, 2).unwrap();
        assert_eq!(d.logits(1), &[2.0]);
        assert_eq!(d.hidden(0), &[9.0, 8.0]);
    }
}
