//! Synthetic corpora — the exact rust mirror of `python/compile/data.py`.
//!
//! PG-19 / GovReport / QMSum / needle-QA substitutes (see DESIGN.md §3).
//! Generators are deterministic given a seed and produce byte-identical
//! text to the python implementations (same xorshift64* stream, same word
//! lists, same assembly order); `python/tests/test_parity.py` and the
//! golden tests below pin this.

use crate::util::rng::Rng;

pub const NAMES: [&str; 16] = [
    "Armand", "Beatrice", "Clement", "Dorothea", "Edmund", "Felicity",
    "Gideon", "Harriet", "Isadora", "Jasper", "Katherine", "Leopold",
    "Margaret", "Nathaniel", "Octavia", "Percival",
];

pub const PLACES: [&str; 12] = [
    "the harbour", "the old mill", "the vicarage", "the moor", "the library",
    "the garden", "the station", "the courthouse", "the lighthouse",
    "the market square", "the abbey", "the orchard",
];

pub const NOUNS: [&str; 25] = [
    "letter", "storm", "candle", "ledger", "portrait", "carriage", "sermon",
    "fortune", "rumour", "voyage", "inheritance", "debt", "promise",
    "manuscript", "telegram", "garden", "winter", "journey", "secret",
    "bargain", "fever", "wedding", "funeral", "harvest", "quarrel",
];

pub const VERBS: [&str; 20] = [
    "remembered", "concealed", "discovered", "promised", "refused",
    "demanded", "whispered", "confessed", "regretted", "imagined",
    "suspected", "announced", "abandoned", "forgave", "inherited",
    "questioned", "observed", "resolved", "feared", "admired",
];

pub const ADJS: [&str; 16] = [
    "pale", "weathered", "solemn", "curious", "forgotten", "distant",
    "quiet", "restless", "grave", "peculiar", "faded", "earnest",
    "bitter", "gentle", "obstinate", "melancholy",
];

pub const CONNECTIVES: [&str; 10] = [
    "and yet", "however", "meanwhile", "at length", "in truth",
    "nevertheless", "presently", "by morning", "after some reflection",
    "against all advice",
];

pub const TOPICS: [&str; 12] = [
    "the drainage works", "the school inspection", "the parish budget",
    "the railway extension", "the water supply", "the grain tariff",
    "the hospital wing", "the coastal survey", "the census returns",
    "the bridge repairs", "the timber contract", "the postal service",
];

pub const SPEAKERS: [&str; 8] = [
    "the chairman", "the secretary", "the inspector", "the treasurer",
    "the delegate", "the engineer", "the clerk", "the surveyor",
];

fn capitalize(s: &str) -> String {
    let mut cs = s.chars();
    match cs.next() {
        Some(c) => c.to_uppercase().collect::<String>() + cs.as_str(),
        None => String::new(),
    }
}

/// One pseudo-Victorian sentence — mirrors `data._sentence`.
pub fn sentence(rng: &mut Rng) -> String {
    let t = rng.below(5);
    let n1 = NAMES[rng.below(NAMES.len())];
    let n2 = NAMES[rng.below(NAMES.len())];
    let v = VERBS[rng.below(VERBS.len())];
    let noun = NOUNS[rng.below(NOUNS.len())];
    let adj = ADJS[rng.below(ADJS.len())];
    let place = PLACES[rng.below(PLACES.len())];
    match t {
        0 => format!("{n1} {v} the {adj} {noun} near {place}."),
        1 => {
            let p = place.strip_prefix("the ").unwrap_or(place);
            format!("At {p}, {n1} {v} that {n2} had kept the {noun}.")
        }
        2 => {
            let c = CONNECTIVES[rng.below(CONNECTIVES.len())];
            format!("{}, the {noun} remained {adj}, and {n1} {v} it.",
                    capitalize(c))
        }
        3 => format!(
            "\"I have {v} the {noun},\" said {n1}, looking toward {place}."
        ),
        _ => format!(
            "The {adj} {noun} of {n1} was known in every corner of {place}."
        ),
    }
}

/// PG-19 substitute: chapters of generated prose, ~`n_bytes` long.
pub fn novel_text(seed: u64, n_bytes: usize) -> String {
    let mut rng = Rng::new(seed);
    let mut out: Vec<String> = Vec::new();
    let mut total = 0usize;
    let mut chapter = 1;
    while total < n_bytes {
        let head = format!("CHAPTER {chapter}.\n\n");
        total += head.len();
        out.push(head);
        let sentences = 30 + rng.below(30);
        let mut para: Vec<String> = Vec::new();
        for i in 0..sentences {
            para.push(sentence(&mut rng));
            if (i + 1) % (4 + rng.below(4)) == 0 {
                para.push("\n\n".to_string());
            } else {
                para.push(" ".to_string());
            }
            if total > n_bytes {
                break;
            }
            total += para[para.len() - 2].len() + para[para.len() - 1].len();
        }
        out.extend(para);
        out.push("\n\n".to_string());
        chapter += 1;
    }
    let joined: String = out.concat();
    joined.chars().take(n_bytes).collect()
}

/// GovReport substitute: sectioned bureaucratic report.
pub fn report_text(seed: u64, n_bytes: usize) -> String {
    let mut rng = Rng::new(seed);
    let mut out: Vec<String> = Vec::new();
    let mut total = 0usize;
    let mut sec = 1;
    while total < n_bytes {
        let topic = TOPICS[rng.below(TOPICS.len())];
        let head = format!("SECTION {sec}. REPORT ON {}.\n",
                           topic.to_uppercase());
        total += head.len();
        out.push(head);
        let n = 6 + rng.below(8);
        for _ in 0..n {
            let amount = 100 + rng.below(9900);
            let year = 1860 + rng.below(60);
            let s = format!(
                "The committee on {topic} recorded an expenditure of \
                 {amount} pounds in the year {year}, and {} further works. ",
                VERBS[rng.below(VERBS.len())]
            );
            total += s.len();
            out.push(s);
            if total > n_bytes {
                break;
            }
        }
        out.push("\n".to_string());
        total += 1;
        sec += 1;
    }
    out.concat().chars().take(n_bytes).collect()
}

/// QMSum substitute: meeting transcript with speakers.
pub fn meeting_text(seed: u64, n_bytes: usize) -> String {
    let mut rng = Rng::new(seed);
    let mut out: Vec<String> = Vec::new();
    let mut total = 0usize;
    while total < n_bytes {
        let sp = SPEAKERS[rng.below(SPEAKERS.len())];
        let topic = TOPICS[rng.below(TOPICS.len())];
        let t = rng.below(3);
        let s = match t {
            0 => format!(
                "{}: We must return to the question of {topic}. ",
                sp.to_uppercase()
            ),
            1 => format!(
                "{}: The figures for {topic} were {} at best. ",
                sp.to_uppercase(),
                ADJS[rng.below(ADJS.len())]
            ),
            _ => format!(
                "{}: I move that {topic} be deferred until the next session. ",
                sp.to_uppercase()
            ),
        };
        total += s.len() + 1;
        out.push(s);
        out.push("\n".to_string());
    }
    out.concat().chars().take(n_bytes).collect()
}

/// 6-letter pronounceable code word (CVCVCV) — mirrors `data._code_word`.
pub fn code_word(rng: &mut Rng) -> String {
    const CONS: &[u8] = b"bdfgklmnprstvz";
    const VOW: &[u8] = b"aeiou";
    (0..6)
        .map(|i| {
            let src = if i % 2 == 0 { CONS } else { VOW };
            src[rng.below(src.len())] as char
        })
        .collect()
}

/// A needle-QA instance (HotpotQA / LongBench substitute).
#[derive(Debug, Clone)]
pub struct NeedleQa {
    pub context: String,
    pub question: String,
    pub answer: String,
}

/// Key→value facts buried in filler prose; the question asks for one of
/// them. Mirrors `data.needle_qa`.
pub fn needle_qa(seed: u64, n_bytes: usize, n_facts: usize) -> NeedleQa {
    let mut rng = Rng::new(seed);
    let mut facts: Vec<(String, String)> = Vec::new();
    for _ in 0..n_facts {
        let key = format!(
            "{}-{}",
            NAMES[rng.below(NAMES.len())],
            rng.below(90) + 10
        );
        let val = code_word(&mut rng);
        facts.push((key, val));
    }
    let seg = std::cmp::max(1, n_bytes / (n_facts + 1));
    let mut out: Vec<String> = Vec::new();
    let mut frng = Rng::new(seed ^ 0x9E3779B97F4A7C15);
    for (k, v) in facts.iter() {
        let mut total = 0usize;
        while total < seg {
            let s = sentence(&mut frng) + " ";
            total += s.len();
            out.push(s);
        }
        out.push(format!("\nThe code of agent {k} is {v}.\n"));
    }
    let qi = rng.below(n_facts);
    let (qk, qv) = facts[qi].clone();
    let context: String = out
        .concat()
        .chars()
        .take(n_bytes + 40 * n_facts)
        .collect();
    let question = format!(
        "\nQuestion: what is the code of agent {qk}?\nAnswer: the code of \
         agent {qk} is"
    );
    NeedleQa { context, question, answer: qv }
}

/// Prompt builders for the evaluation tasks.
pub fn continuation_prompt(seed: u64, ctx_bytes: usize) -> String {
    novel_text(seed, ctx_bytes)
}

pub fn summarize_prompt(doc: &str) -> String {
    format!("{doc}\n\nSummary:\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(novel_text(1, 2000), novel_text(1, 2000));
        assert_ne!(novel_text(1, 2000), novel_text(2, 2000));
    }

    #[test]
    fn exact_length() {
        for n in [100, 1000, 5000] {
            assert_eq!(novel_text(3, n).len(), n);
            assert_eq!(report_text(3, n).len(), n);
            assert_eq!(meeting_text(3, n).len(), n);
        }
    }

    #[test]
    fn novel_structure() {
        let t = novel_text(7, 4000);
        assert!(t.starts_with("CHAPTER 1.\n\n"));
        assert!(t.contains('.'));
    }

    #[test]
    fn needle_has_answer_in_context() {
        let qa = needle_qa(11, 4000, 8);
        assert!(qa.context.contains(&qa.answer));
        assert!(qa.question.contains("what is the code of agent"));
        // the queried key appears in both context and question
        let key = qa
            .question
            .split("agent ")
            .nth(1)
            .unwrap()
            .split('?')
            .next()
            .unwrap();
        assert!(qa.context.contains(&format!("agent {key} is")));
    }

    #[test]
    fn code_word_shape() {
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let w = code_word(&mut rng);
            assert_eq!(w.len(), 6);
            assert!(w.chars().all(|c| c.is_ascii_lowercase()));
        }
    }

    #[test]
    fn ascii_only() {
        // python parity depends on len()==bytes; all corpora must be ASCII
        for t in [
            novel_text(1, 3000),
            report_text(2, 3000),
            meeting_text(3, 3000),
            needle_qa(4, 3000, 6).context,
        ] {
            assert!(t.is_ascii());
        }
    }
}
