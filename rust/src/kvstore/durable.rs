//! Crash-consistent checkpoint store (DESIGN.md §17): persists the
//! front end's retained [`SessionCheckpoint`]s so in-flight sessions
//! survive process death, not just shard death.
//!
//! Each checkpoint is one file `ckpt-<gid:016x>.spc` under
//! `<journal_dir>/ckpt/`, written via the atomic temp-file + fsync +
//! rename path shared with the swap tier — a crash mid-save leaves
//! either the previous image or the new one, never a torn file. The
//! image itself ([`SessionCheckpoint::encode_durable`]) reuses the KV
//! spill-page codec for its payloads, so corruption is detected on
//! load (checksum/magic/length) and surfaces as "no checkpoint" —
//! recovery then regenerates from the journaled prompt instead.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::engine::SessionCheckpoint;
use crate::kvstore::swap::{atomic_write, purge_temps};

pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    /// Open (creating if needed) the store under `dir`, purging any
    /// orphaned temp files a previous incarnation's crash left behind.
    pub fn open(dir: &Path) -> Result<CheckpointStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint store dir {dir:?}"))?;
        purge_temps(dir);
        Ok(CheckpointStore { dir: dir.to_path_buf() })
    }

    fn path_of(&self, gid: u64) -> PathBuf {
        self.dir.join(format!("ckpt-{gid:016x}.spc"))
    }

    /// Atomically persist the checkpoint for request `gid`, replacing
    /// any previous image.
    pub fn save(&self, gid: u64, ck: &SessionCheckpoint) -> Result<()> {
        atomic_write(&self.path_of(gid), &ck.encode_durable())
            .with_context(|| format!("persisting checkpoint for request {gid}"))
    }

    /// Load the durable checkpoint for `gid`, if one exists and decodes
    /// cleanly. Corrupt or torn images return `None` — callers fall
    /// back to regenerating from the journal.
    pub fn load(&self, gid: u64) -> Option<SessionCheckpoint> {
        let blob = std::fs::read(self.path_of(gid)).ok()?;
        SessionCheckpoint::decode_durable(&blob).ok()
    }

    /// Drop the image for a finished or cancelled request.
    pub fn remove(&self, gid: u64) {
        let _ = std::fs::remove_file(self.path_of(gid));
    }

    /// All gids with a durable image on disk, with decode validation:
    /// corrupt files are skipped (and deleted — they can never load).
    pub fn scan(&self) -> BTreeMap<u64, SessionCheckpoint> {
        let mut out = BTreeMap::new();
        let Ok(rd) = std::fs::read_dir(&self.dir) else { return out };
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            let Some(hex) = name.strip_prefix("ckpt-").and_then(|s| s.strip_suffix(".spc"))
            else {
                continue;
            };
            let Ok(gid) = u64::from_str_radix(hex, 16) else { continue };
            match std::fs::read(e.path()).ok().and_then(|b| {
                SessionCheckpoint::decode_durable(&b).ok()
            }) {
                Some(ck) => {
                    out.insert(gid, ck);
                }
                None => {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
        out
    }

    /// Delete every image (journal marked clean on graceful shutdown).
    pub fn clear(&self) {
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                let name = e.file_name().to_string_lossy().into_owned();
                if name.starts_with("ckpt-") && name.ends_with(".spc") {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
    }
}

impl std::fmt::Debug for CheckpointStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CheckpointStore({:?})", self.dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;

    fn ck(tag: u32) -> SessionCheckpoint {
        SessionCheckpoint {
            engine: EngineKind::SpecPv,
            emitted: vec![tag, tag + 1, tag + 2],
            steps: 5,
            size: "tiny".into(),
            bucket: 1,
            data: vec![0.5, -1.25, 3.0],
            extra: vec![2.0; 8],
            committed: 7,
            pending: vec![1, 2],
            rng: u64::MAX - 3,
            policy: None,
        }
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("specpv-ckpt-{tag}-{}", std::process::id()))
    }

    #[test]
    fn save_load_roundtrip_and_scan() {
        let dir = tmp("rt");
        let _ = std::fs::remove_dir_all(&dir);
        let st = CheckpointStore::open(&dir).unwrap();
        st.save(7, &ck(100)).unwrap();
        st.save(9, &ck(200)).unwrap();
        let got = st.load(7).unwrap();
        assert_eq!(got.emitted, vec![100, 101, 102]);
        assert_eq!(got.rng, u64::MAX - 3);
        assert_eq!(got.data, vec![0.5, -1.25, 3.0]);
        let all = st.scan();
        assert_eq!(all.keys().copied().collect::<Vec<_>>(), vec![7, 9]);
        st.remove(7);
        assert!(st.load(7).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_image_skipped_not_fatal() {
        let dir = tmp("corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        let st = CheckpointStore::open(&dir).unwrap();
        st.save(3, &ck(1)).unwrap();
        // truncate the image mid-payload: must decode as "no checkpoint"
        let path = dir.join("ckpt-0000000000000003.spc");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(st.load(3).is_none());
        assert!(st.scan().is_empty());
        // scan removed the unloadable file
        assert!(!path.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_purges_orphaned_temps() {
        let dir = tmp("temps");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("ckpt-0000000000000001.spc.tmp"), b"torn").unwrap();
        let _st = CheckpointStore::open(&dir).unwrap();
        assert!(!dir.join("ckpt-0000000000000001.spc.tmp").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
