//! Content-addressed prompt-prefix snapshot cache (LRU + byte budget).
//!
//! Keys are `(geometry hash, fnv1a over the prefix tokens, prefix len)`;
//! the geometry hash folds in everything that makes a snapshot
//! re-usable: backend name, model size, full bucket, prefill chunk width
//! and whether a paired EAGLE draft state rides along. Prefixes are only
//! cached at whole-chunk boundaries strictly inside the prompt, so a hit
//! always leaves at least one tail token to prefill (the final-row read
//! then comes from a freshly computed chunk). Hash collisions cannot
//! corrupt output: the stored prefix tokens are compared verbatim before
//! a hit is declared.
//!
//! The store is a cheaply clonable shared handle (`Rc<RefCell<..>>`) —
//! the coordinator, its session factory and every live session on the
//! single device thread share one instance.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::backend::StateSnapshot;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// fnv1a-64, continued from `h` over `bytes`.
pub fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Hash a set of geometry-defining byte strings into one prefix-cache
/// geometry key.
pub fn geom_hash(parts: &[&[u8]]) -> u64 {
    let mut h = FNV_OFFSET;
    for p in parts {
        h = fnv1a(h, p);
        h = fnv1a(h, &[0xff]); // separator so ("ab","c") != ("a","bc")
    }
    h
}

/// Rolling fnv1a over a token stream, sampled at every whole multiple of
/// `chunk` that still leaves a tail: returns `(prefix_len, hash)` pairs
/// ascending, each with `prefix_len < tokens.len()`.
pub fn chunk_boundary_hashes(tokens: &[u32], chunk: usize) -> Vec<(usize, u64)> {
    let mut out = Vec::new();
    if chunk == 0 || tokens.len() < 2 {
        return out;
    }
    let max_len = ((tokens.len() - 1) / chunk) * chunk;
    let mut h = FNV_OFFSET;
    for (i, &t) in tokens.iter().enumerate().take(max_len) {
        h = fnv1a(h, &t.to_le_bytes());
        let len = i + 1;
        if len % chunk == 0 {
            out.push((len, h));
        }
    }
    out
}

/// Observable counters + occupancy of a [`KvStore`].
#[derive(Debug, Default, Clone)]
pub struct PrefixStats {
    pub entries: usize,
    pub bytes: usize,
    pub budget_bytes: usize,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

struct Entry {
    /// the exact prefix tokens (collision guard; also what `bytes` counts
    /// beyond the snapshots)
    tokens: Vec<u32>,
    snaps: Rc<Vec<StateSnapshot>>,
    bytes: usize,
    /// LRU stamp (monotone per-store clock)
    stamp: u64,
}

struct Inner {
    budget: usize,
    bytes: usize,
    clock: u64,
    map: HashMap<(u64, u64, usize), Entry>,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
}

/// Shared handle to the prefix cache. Cloning shares the store.
#[derive(Clone)]
pub struct KvStore {
    inner: Rc<RefCell<Inner>>,
}

impl KvStore {
    /// A store evicting LRU entries beyond `budget_bytes` (0 disables
    /// insertion entirely — every lookup misses).
    pub fn new(budget_bytes: usize) -> KvStore {
        KvStore {
            inner: Rc::new(RefCell::new(Inner {
                budget: budget_bytes,
                bytes: 0,
                clock: 0,
                map: HashMap::new(),
                hits: 0,
                misses: 0,
                insertions: 0,
                evictions: 0,
            })),
        }
    }

    /// Whether this store can ever hold an entry.
    pub fn enabled(&self) -> bool {
        self.inner.borrow().budget > 0
    }

    /// Whether an entry of roughly `bytes` could ever be inserted —
    /// callers gate the (expensive, possibly device→host) export on this
    /// so oversized snapshots are never materialized just to be dropped.
    pub fn accepts(&self, bytes: usize) -> bool {
        let budget = self.inner.borrow().budget;
        budget > 0 && bytes <= budget
    }

    /// Longest cached prefix of `tokens` at a chunk boundary under
    /// geometry `geom`. Returns `(prefix_len, snapshots)`; the snapshots
    /// are shared (`Rc`), not copied. Counts one hit or one miss.
    pub fn lookup_longest(
        &self,
        geom: u64,
        tokens: &[u32],
        chunk: usize,
    ) -> Option<(usize, Rc<Vec<StateSnapshot>>)> {
        let bounds = chunk_boundary_hashes(tokens, chunk);
        let mut inner = self.inner.borrow_mut();
        inner.clock += 1;
        let stamp = inner.clock;
        for &(len, h) in bounds.iter().rev() {
            let mut found = None;
            if let Some(e) = inner.map.get_mut(&(geom, h, len)) {
                if e.tokens[..] == tokens[..len] {
                    e.stamp = stamp;
                    found = Some(Rc::clone(&e.snaps));
                }
            }
            if let Some(snaps) = found {
                inner.hits += 1;
                return Some((len, snaps));
            }
        }
        inner.misses += 1;
        None
    }

    /// Insert a post-prefill snapshot set for `prefix` under `geom`,
    /// evicting LRU entries until the byte budget holds. Oversized
    /// entries and duplicates are dropped silently.
    pub fn insert(&self, geom: u64, prefix: &[u32], snaps: Vec<StateSnapshot>) {
        let bytes =
            snaps.iter().map(|s| s.bytes()).sum::<usize>() + prefix.len() * 4;
        let mut inner = self.inner.borrow_mut();
        if inner.budget == 0 || bytes > inner.budget {
            return;
        }
        let mut h = FNV_OFFSET;
        for &t in prefix {
            h = fnv1a(h, &t.to_le_bytes());
        }
        let key = (geom, h, prefix.len());
        if inner.map.contains_key(&key) {
            return;
        }
        inner.clock += 1;
        let stamp = inner.clock;
        inner.map.insert(
            key,
            Entry { tokens: prefix.to_vec(), snaps: Rc::new(snaps), bytes, stamp },
        );
        inner.bytes += bytes;
        inner.insertions += 1;
        while inner.bytes > inner.budget {
            // the just-inserted entry carries the newest stamp, so the
            // LRU scan can never evict it (bytes ≤ budget was checked)
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| *k);
            let Some(k) = victim else { break };
            if let Some(e) = inner.map.remove(&k) {
                inner.bytes -= e.bytes;
                inner.evictions += 1;
            }
        }
    }

    pub fn stats(&self) -> PrefixStats {
        let inner = self.inner.borrow();
        PrefixStats {
            entries: inner.map.len(),
            bytes: inner.bytes,
            budget_bytes: inner.budget,
            hits: inner.hits,
            misses: inner.misses,
            insertions: inner.insertions,
            evictions: inner.evictions,
        }
    }

}

impl std::fmt::Debug for KvStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "KvStore({} entries, {}/{} bytes, {} hits / {} misses)",
            s.entries, s.bytes, s.budget_bytes, s.hits, s.misses
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{StateKind, StateSnapshot};

    fn snap(n: usize) -> StateSnapshot {
        StateSnapshot {
            kind: StateKind::Full,
            size: "s".into(),
            bucket: 128,
            data: vec![0.5; n],
            extra: Vec::new(),
        }
    }

    #[test]
    fn boundary_hashes_leave_a_tail() {
        let toks: Vec<u32> = (0..10).collect();
        let b = chunk_boundary_hashes(&toks, 4);
        assert_eq!(b.iter().map(|&(l, _)| l).collect::<Vec<_>>(), vec![4, 8]);
        // an exact-multiple prompt still reserves the final chunk
        let toks: Vec<u32> = (0..8).collect();
        let b = chunk_boundary_hashes(&toks, 4);
        assert_eq!(b.iter().map(|&(l, _)| l).collect::<Vec<_>>(), vec![4]);
        assert!(chunk_boundary_hashes(&toks[..1], 4).is_empty());
        // prefix hashes are rolling: boundary k's hash equals a fresh
        // hash over the first k tokens
        let toks: Vec<u32> = (10..30).collect();
        let b = chunk_boundary_hashes(&toks, 8);
        let fresh = chunk_boundary_hashes(&toks[..9], 8);
        assert_eq!(b[0], fresh[0]);
    }

    #[test]
    fn lookup_prefers_longest_and_checks_tokens() {
        let st = KvStore::new(1 << 20);
        let toks: Vec<u32> = (0..100).collect();
        st.insert(7, &toks[..32], vec![snap(10)]);
        st.insert(7, &toks[..64], vec![snap(10)]);
        let (len, _) = st.lookup_longest(7, &toks, 32).unwrap();
        assert_eq!(len, 64);
        // different geometry misses
        assert!(st.lookup_longest(8, &toks, 32).is_none());
        // a diverging prompt with the same length misses
        let mut other = toks.clone();
        other[10] = 999;
        assert!(st.lookup_longest(7, &other[..40], 32).is_none());
        let s = st.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 2);
        assert_eq!(s.insertions, 2);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        // each entry ≈ 4000 (snap) + 128 (tokens) bytes
        let st = KvStore::new(9000);
        let toks: Vec<u32> = (0..200).collect();
        st.insert(1, &toks[..32], vec![snap(1000)]);
        st.insert(2, &toks[..32], vec![snap(1000)]);
        assert_eq!(st.stats().entries, 2);
        // touch entry 1 so entry 2 becomes LRU
        assert!(st.lookup_longest(1, &toks[..40], 32).is_some());
        st.insert(3, &toks[..32], vec![snap(1000)]);
        let s = st.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= 9000);
        assert!(st.lookup_longest(1, &toks[..40], 32).is_some(), "MRU kept");
        assert!(st.lookup_longest(2, &toks[..40], 32).is_none(), "LRU evicted");
        // oversized entries never land (and `accepts` predicts that
        // without materializing the snapshot)
        assert!(st.accepts(4000));
        assert!(!st.accepts(10_000));
        st.insert(4, &toks[..32], vec![snap(1 << 20)]);
        assert!(st.lookup_longest(4, &toks[..40], 32).is_none());
        // a zero-budget store is inert
        let off = KvStore::new(0);
        assert!(!off.enabled());
        assert!(!off.accepts(1));
        off.insert(1, &toks[..32], vec![snap(10)]);
        assert!(off.lookup_longest(1, &toks, 32).is_none());
    }

    #[test]
    fn geom_hash_separates_parts() {
        assert_ne!(geom_hash(&[b"ab", b"c"]), geom_hash(&[b"a", b"bc"]));
        assert_eq!(geom_hash(&[b"x", b"y"]), geom_hash(&[b"x", b"y"]));
    }
}
