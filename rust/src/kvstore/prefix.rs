//! Content-addressed prompt-prefix cache (LRU + byte budget) over the
//! paged block pool.
//!
//! Keys are `(geometry hash, fnv1a over the prefix tokens, prefix len)`;
//! the geometry hash folds in everything that makes an entry re-usable:
//! backend name, model size, full bucket, prefill chunk width and
//! whether a paired EAGLE draft state rides along. Prefixes are only
//! cached at whole-chunk boundaries strictly inside the prompt, so a hit
//! always leaves at least one tail token to prefill (the final-row read
//! then comes from a freshly computed chunk). Hash collisions cannot
//! corrupt output: the stored prefix tokens are compared verbatim before
//! a hit is declared.
//!
//! Entries are [`PagedState`] block tables into the store's [`KvPool`],
//! not flat snapshots: a lookup hit *maps* the cached pages into the new
//! session's table (refcount increment per page, zero new pages
//! allocated) instead of memcpy'ing a slab; the pool's copy-on-write
//! contract keeps the cached entry immutable under any later divergence.
//! Budget accounting stays in flat-slab-equivalent bytes
//! ([`PagedState::logical_bytes`]) so `prefix_cache_bytes` means the
//! same thing it always did, while the *actual* residency — after
//! zero-page and cross-entry dedup — is visible in the pool's
//! [`PoolStats`](crate::kvstore::PoolStats).
//!
//! The store is a cheaply clonable shared handle (`Rc<RefCell<..>>`) —
//! the coordinator, its session factory and every live session on the
//! single device thread share one instance (and one pool).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::kvstore::pool::{KvPool, PagedState};

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// fnv1a-64, continued from `h` over `bytes`.
pub fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Hash a set of geometry-defining byte strings into one prefix-cache
/// geometry key.
pub fn geom_hash(parts: &[&[u8]]) -> u64 {
    let mut h = FNV_OFFSET;
    for p in parts {
        h = fnv1a(h, p);
        h = fnv1a(h, &[0xff]); // separator so ("ab","c") != ("a","bc")
    }
    h
}

/// Rolling fnv1a over a token stream, sampled at every whole multiple of
/// `chunk` that still leaves a tail: returns `(prefix_len, hash)` pairs
/// ascending, each with `prefix_len < tokens.len()`.
pub fn chunk_boundary_hashes(tokens: &[u32], chunk: usize) -> Vec<(usize, u64)> {
    let mut out = Vec::new();
    if chunk == 0 || tokens.len() < 2 {
        return out;
    }
    let max_len = ((tokens.len() - 1) / chunk) * chunk;
    let mut h = FNV_OFFSET;
    for (i, &t) in tokens.iter().enumerate().take(max_len) {
        h = fnv1a(h, &t.to_le_bytes());
        let len = i + 1;
        if len % chunk == 0 {
            out.push((len, h));
        }
    }
    out
}

/// Observable counters + occupancy of a [`KvStore`].
#[derive(Debug, Default, Clone)]
pub struct PrefixStats {
    pub entries: usize,
    /// flat-slab-equivalent bytes of all entries (budget denomination)
    pub bytes: usize,
    pub budget_bytes: usize,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

struct Entry {
    /// the exact prefix tokens (collision guard; also what `bytes` counts
    /// beyond the states)
    tokens: Vec<u32>,
    /// parked post-prefill states (target first, optional draft second);
    /// the entry owns one page reference per table slot
    states: Vec<PagedState>,
    bytes: usize,
    /// LRU stamp (monotone per-store clock)
    stamp: u64,
}

struct Inner {
    budget: usize,
    bytes: usize,
    clock: u64,
    map: HashMap<(u64, u64, usize), Entry>,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
}

/// Shared handle to the prefix cache. Cloning shares the store.
#[derive(Clone)]
pub struct KvStore {
    pool: KvPool,
    inner: Rc<RefCell<Inner>>,
}

impl KvStore {
    /// A store evicting LRU entries beyond `budget_bytes` (0 disables
    /// insertion entirely — every lookup misses), backed by a private
    /// unbounded pool. Use [`KvStore::with_pool`] to share pages with
    /// the coordinator's pool.
    pub fn new(budget_bytes: usize) -> KvStore {
        KvStore::with_pool(budget_bytes, KvPool::new(0))
    }

    /// A store whose entries live as pages of `pool`.
    pub fn with_pool(budget_bytes: usize, pool: KvPool) -> KvStore {
        KvStore {
            pool,
            inner: Rc::new(RefCell::new(Inner {
                budget: budget_bytes,
                bytes: 0,
                clock: 0,
                map: HashMap::new(),
                hits: 0,
                misses: 0,
                insertions: 0,
                evictions: 0,
            })),
        }
    }

    /// The page pool backing this store's entries.
    pub fn pool(&self) -> KvPool {
        self.pool.clone()
    }

    /// Whether this store can ever hold an entry.
    pub fn enabled(&self) -> bool {
        self.inner.borrow().budget > 0
    }

    /// Whether an entry of roughly `bytes` (flat-slab equivalent) could
    /// ever be inserted — callers gate the (expensive, possibly
    /// device→host) export on this so oversized states are never
    /// materialized just to be dropped.
    pub fn accepts(&self, bytes: usize) -> bool {
        let budget = self.inner.borrow().budget;
        budget > 0 && bytes <= budget
    }

    /// Longest cached prefix of `tokens` at a chunk boundary under
    /// geometry `geom`. Returns `(prefix_len, states)` where the states'
    /// pages are *shared into* the returned tables (one new reference
    /// per page, zero pages allocated) — the caller owns those
    /// references and must drop them with
    /// [`KvPool::free_state`] once restored. Counts one hit or one miss.
    pub fn lookup_longest(
        &self,
        geom: u64,
        tokens: &[u32],
        chunk: usize,
    ) -> Option<(usize, Vec<PagedState>)> {
        let bounds = chunk_boundary_hashes(tokens, chunk);
        let mut inner = self.inner.borrow_mut();
        inner.clock += 1;
        let stamp = inner.clock;
        for &(len, h) in bounds.iter().rev() {
            let mut found = None;
            if let Some(e) = inner.map.get_mut(&(geom, h, len)) {
                if e.tokens[..] == tokens[..len] {
                    e.stamp = stamp;
                    found = Some(
                        e.states
                            .iter()
                            .map(|ps| self.pool.share_state(ps))
                            .collect::<Vec<_>>(),
                    );
                }
            }
            if let Some(states) = found {
                inner.hits += 1;
                return Some((len, states));
            }
        }
        inner.misses += 1;
        None
    }

    /// Insert post-prefill parked states for `prefix` under `geom`,
    /// evicting LRU entries until the byte budget holds. The entry takes
    /// ownership of the states' page references; oversized entries and
    /// duplicates are dropped (their pages freed) silently.
    pub fn insert(&self, geom: u64, prefix: &[u32], states: Vec<PagedState>) {
        let bytes = states.iter().map(|s| s.logical_bytes()).sum::<usize>()
            + prefix.len() * 4;
        let mut inner = self.inner.borrow_mut();
        if inner.budget == 0 || bytes > inner.budget {
            drop(inner);
            self.drop_states(&states);
            return;
        }
        let mut h = FNV_OFFSET;
        for &t in prefix {
            h = fnv1a(h, &t.to_le_bytes());
        }
        let key = (geom, h, prefix.len());
        if inner.map.contains_key(&key) {
            drop(inner);
            self.drop_states(&states);
            return;
        }
        inner.clock += 1;
        let stamp = inner.clock;
        inner.map.insert(
            key,
            Entry { tokens: prefix.to_vec(), states, bytes, stamp },
        );
        inner.bytes += bytes;
        inner.insertions += 1;
        let mut victims = Vec::new();
        while inner.bytes > inner.budget {
            // the just-inserted entry carries the newest stamp, so the
            // LRU scan can never evict it (bytes ≤ budget was checked)
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| *k);
            let Some(k) = victim else { break };
            if let Some(e) = inner.map.remove(&k) {
                inner.bytes -= e.bytes;
                inner.evictions += 1;
                victims.push(e);
            }
        }
        drop(inner);
        for e in victims {
            self.drop_states(&e.states);
        }
    }

    fn drop_states(&self, states: &[PagedState]) {
        for ps in states {
            self.pool.free_state(ps);
        }
    }

    pub fn stats(&self) -> PrefixStats {
        let inner = self.inner.borrow();
        PrefixStats {
            entries: inner.map.len(),
            bytes: inner.bytes,
            budget_bytes: inner.budget,
            hits: inner.hits,
            misses: inner.misses,
            insertions: inner.insertions,
            evictions: inner.evictions,
        }
    }
}

impl std::fmt::Debug for KvStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "KvStore({} entries, {}/{} bytes, {} hits / {} misses)",
            s.entries, s.bytes, s.budget_bytes, s.hits, s.misses
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::StateKind;

    fn park(pool: &KvPool, n: usize) -> PagedState {
        pool.park_image(StateKind::Full, "s", 128, &vec![0.5; n], &[])
    }

    #[test]
    fn boundary_hashes_leave_a_tail() {
        let toks: Vec<u32> = (0..10).collect();
        let b = chunk_boundary_hashes(&toks, 4);
        assert_eq!(b.iter().map(|&(l, _)| l).collect::<Vec<_>>(), vec![4, 8]);
        // an exact-multiple prompt still reserves the final chunk
        let toks: Vec<u32> = (0..8).collect();
        let b = chunk_boundary_hashes(&toks, 4);
        assert_eq!(b.iter().map(|&(l, _)| l).collect::<Vec<_>>(), vec![4]);
        assert!(chunk_boundary_hashes(&toks[..1], 4).is_empty());
        // prefix hashes are rolling: boundary k's hash equals a fresh
        // hash over the first k tokens
        let toks: Vec<u32> = (10..30).collect();
        let b = chunk_boundary_hashes(&toks, 8);
        let fresh = chunk_boundary_hashes(&toks[..9], 8);
        assert_eq!(b[0], fresh[0]);
    }

    #[test]
    fn lookup_prefers_longest_and_checks_tokens() {
        let st = KvStore::new(1 << 20);
        let pool = st.pool();
        let toks: Vec<u32> = (0..100).collect();
        st.insert(7, &toks[..32], vec![park(&pool, 10)]);
        st.insert(7, &toks[..64], vec![park(&pool, 10)]);
        let (len, states) = st.lookup_longest(7, &toks, 32).unwrap();
        assert_eq!(len, 64);
        // the hit mapped the cached pages: shared, not copied
        assert!(pool.stats().pages_shared > 0);
        for ps in &states {
            pool.free_state(ps);
        }
        // different geometry misses
        assert!(st.lookup_longest(8, &toks, 32).is_none());
        // a diverging prompt with the same length misses
        let mut other = toks.clone();
        other[10] = 999;
        assert!(st.lookup_longest(7, &other[..40], 32).is_none());
        let s = st.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 2);
        assert_eq!(s.insertions, 2);
    }

    #[test]
    fn lru_eviction_respects_budget_and_frees_pages() {
        // each entry ≈ 4000 (state) + 128 (tokens) bytes; distinct fill
        // values defeat cross-entry dedup so page counts are observable
        let st = KvStore::new(9000);
        let pool = st.pool();
        let fill = |v: f32| {
            pool.park_image(StateKind::Full, "s", 128, &vec![v; 1000], &[])
        };
        let toks: Vec<u32> = (0..200).collect();
        st.insert(1, &toks[..32], vec![fill(0.1)]);
        st.insert(2, &toks[..32], vec![fill(0.2)]);
        assert_eq!(st.stats().entries, 2);
        let resident_two = pool.stats().pages_resident;
        // touch entry 1 so entry 2 becomes LRU
        let (_, s1) = st.lookup_longest(1, &toks[..40], 32).unwrap();
        for ps in &s1 {
            pool.free_state(ps);
        }
        st.insert(3, &toks[..32], vec![fill(0.3)]);
        let s = st.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= 9000);
        assert_eq!(
            pool.stats().pages_resident,
            resident_two,
            "evicted entry must free its pages"
        );
        assert!(st.lookup_longest(1, &toks[..40], 32).is_some(), "MRU kept");
        assert!(st.lookup_longest(2, &toks[..40], 32).is_none(), "LRU evicted");
        // oversized entries never land (and `accepts` predicts that
        // without materializing the state)
        assert!(st.accepts(4000));
        assert!(!st.accepts(10_000));
        st.insert(5, &toks[..32], vec![park(&pool, 1 << 20)]);
        assert!(st.lookup_longest(5, &toks[..40], 32).is_none());
        // a zero-budget store is inert (and frees rejected pages)
        let off = KvStore::new(0);
        let opool = off.pool();
        assert!(!off.enabled());
        assert!(!off.accepts(1));
        off.insert(1, &toks[..32], vec![park(&opool, 10)]);
        assert!(off.lookup_longest(1, &toks, 32).is_none());
        assert_eq!(opool.stats().pages_resident, 0);
    }

    #[test]
    fn geom_hash_separates_parts() {
        assert_ne!(geom_hash(&[b"ab", b"c"]), geom_hash(&[b"a", b"bc"]));
        assert_eq!(geom_hash(&[b"x", b"y"]), geom_hash(&[b"x", b"y"]));
    }
}
