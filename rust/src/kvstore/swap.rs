//! Disk tier for cold KV pages: per-page spill files + async prefetch.
//!
//! [`KvPool::park_cold`](crate::kvstore::KvPool::park_cold) spills
//! unshared pages of parked sessions here; re-admission prefetches them
//! back on a background thread so the resume path mostly reads RAM.
//! The store moves opaque byte blobs — the page codec (header, checksum,
//! optional int8 payload) lives in [`crate::kvstore::pool`], which
//! validates on decode, so a truncated or corrupt spill file surfaces as
//! a clean error there, never a panic.
//!
//! Spill keys carry a per-slot generation tag so a freed-and-reused page
//! id can never read a stale prefetched blob from its previous life.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

/// State shared with the prefetch thread, under one lock. The prefetch
/// thread reads spill files *outside* the lock, then re-checks `live`
/// *inside* it before parking the blob: a `remove` racing an in-flight
/// prefetch therefore always wins — the stale blob is dropped on the
/// floor instead of parked in `blobs` forever (keys are generation-
/// tagged, so a leaked blob would never be read again, only leaked).
#[derive(Default)]
struct PrefetchShared {
    /// background-prefetched blobs, consumed by `read`
    blobs: HashMap<u64, Vec<u8>>,
    /// keys currently live on disk
    live: HashSet<u64>,
}

pub struct SwapStore {
    dir: PathBuf,
    created: bool,
    /// spill key -> file bytes on disk
    files: HashMap<u64, usize>,
    bytes: usize,
    prefetched: Arc<Mutex<PrefetchShared>>,
    prefetches: u64,
}

impl SwapStore {
    /// A spill-file manager rooted at `dir`. The directory is created
    /// lazily on the first write, so constructing the store is
    /// infallible and a never-spilling pool touches no filesystem.
    pub fn new(dir: &Path) -> SwapStore {
        SwapStore {
            dir: dir.to_path_buf(),
            created: false,
            files: HashMap::new(),
            bytes: 0,
            prefetched: Arc::new(Mutex::new(PrefetchShared::default())),
            prefetches: 0,
        }
    }

    fn file_name(key: u64) -> String {
        format!("page-{key:016x}.kvp")
    }

    /// On-disk path of a spill key (public so fault tests can corrupt it).
    pub fn path_of(&self, key: u64) -> PathBuf {
        self.dir.join(Self::file_name(key))
    }

    /// Spill one encoded page.
    pub fn write(&mut self, key: u64, blob: &[u8]) -> Result<()> {
        if !self.created {
            std::fs::create_dir_all(&self.dir)
                .with_context(|| format!("creating kv swap dir {:?}", self.dir))?;
            self.created = true;
        }
        std::fs::write(self.path_of(key), blob)
            .with_context(|| format!("kv spill write {:?}", self.path_of(key)))?;
        {
            let mut p = self.prefetched.lock().unwrap();
            p.blobs.remove(&key);
            p.live.insert(key);
        }
        if let Some(old) = self.files.insert(key, blob.len()) {
            self.bytes -= old;
        }
        self.bytes += blob.len();
        Ok(())
    }

    /// Read one encoded page back, consuming the prefetched copy when
    /// the background thread already pulled it in.
    pub fn read(&mut self, key: u64) -> Result<Vec<u8>> {
        if let Some(blob) = self.prefetched.lock().unwrap().blobs.remove(&key) {
            return Ok(blob);
        }
        std::fs::read(self.path_of(key))
            .with_context(|| format!("kv spill read {:?}", self.path_of(key)))
    }

    /// Drop a spilled page (page freed while cold). Deregistering the
    /// key from the live set under the lock guarantees that a prefetch
    /// in flight for this key can never park its blob afterwards.
    pub fn remove(&mut self, key: u64) {
        if let Some(n) = self.files.remove(&key) {
            self.bytes -= n;
            let _ = std::fs::remove_file(self.path_of(key));
        }
        let mut p = self.prefetched.lock().unwrap();
        p.blobs.remove(&key);
        p.live.remove(&key);
    }

    /// Start pulling `keys` into RAM on a background thread; `read`
    /// consumes whatever landed and falls back to the file otherwise.
    /// Read errors are ignored here — the synchronous `read` re-reads
    /// and reports them with context.
    pub fn prefetch(&mut self, keys: Vec<u64>) {
        if keys.is_empty() {
            return;
        }
        self.prefetches += keys.len() as u64;
        let dir = self.dir.clone();
        let shared = Arc::clone(&self.prefetched);
        std::thread::spawn(move || {
            for key in keys {
                if let Ok(blob) = std::fs::read(dir.join(SwapStore::file_name(key))) {
                    let mut p = shared.lock().unwrap();
                    // a `remove` may have raced the file read — only park
                    // blobs whose key is still live
                    if p.live.contains(&key) {
                        p.blobs.insert(key, blob);
                    }
                }
            }
        });
    }

    /// Blobs currently parked by the prefetch thread (leak checks).
    pub fn prefetched_len(&self) -> usize {
        self.prefetched.lock().unwrap().blobs.len()
    }

    /// Bytes currently on disk across all spilled pages.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Spilled page count.
    pub fn len(&self) -> usize {
        self.files.len()
    }

    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    /// Total pages handed to the prefetch thread so far.
    pub fn prefetches(&self) -> u64 {
        self.prefetches
    }
}

impl std::fmt::Debug for SwapStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SwapStore({:?}: {} pages, {} bytes)", self.dir, self.files.len(), self.bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("specpv-swap-{tag}-{}", std::process::id()))
    }

    #[test]
    fn write_read_remove_roundtrip() {
        let dir = tmp("rt");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = SwapStore::new(&dir);
        assert!(s.is_empty());
        s.write(7, b"hello").unwrap();
        assert_eq!((s.len(), s.bytes()), (1, 5));
        assert_eq!(s.read(7).unwrap(), b"hello");
        // rewrite replaces without double counting
        s.write(7, b"hi").unwrap();
        assert_eq!(s.bytes(), 2);
        s.remove(7);
        assert!(s.is_empty());
        assert!(s.read(7).is_err(), "removed page must not read back");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefetch_lands_and_read_consumes() {
        let dir = tmp("pf");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = SwapStore::new(&dir);
        s.write(1, b"abc").unwrap();
        s.prefetch(vec![1]);
        // read must succeed whether the prefetch thread won the race or not
        assert_eq!(s.read(1).unwrap(), b"abc");
        assert!(s.prefetches() >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn remove_racing_prefetch_never_parks_a_stale_blob() {
        let dir = tmp("race");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = SwapStore::new(&dir);
        // distinct key per round (real keys are generation-tagged, so a
        // freed key is never reused); many rounds so both interleavings
        // — blob parked before remove, and remove before park — occur
        for key in 0..200u64 {
            s.write(key, b"payload").unwrap();
            s.prefetch(vec![key]);
            s.remove(key);
            // remove deregisters the key under the lock, so from here on
            // the in-flight prefetch can never park this blob
            assert_eq!(s.prefetched_len(), 0, "stale blob parked for key {key}");
        }
        // let stragglers finish, then re-check nothing landed late
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert_eq!(s.prefetched_len(), 0);
        assert!(s.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
