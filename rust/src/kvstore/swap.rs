//! Disk tier for cold KV pages: per-page spill files + async prefetch.
//!
//! [`KvPool::park_cold`](crate::kvstore::KvPool::park_cold) spills
//! unshared pages of parked sessions here; re-admission prefetches them
//! back on a background thread so the resume path mostly reads RAM.
//! The store moves opaque byte blobs — the page codec (header, checksum,
//! optional int8 payload) lives in [`crate::kvstore::pool`], which
//! validates on decode, so a truncated or corrupt spill file surfaces as
//! a clean error there, never a panic.
//!
//! Spill keys carry a per-slot generation tag so a freed-and-reused page
//! id can never read a stale prefetched blob from its previous life.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

/// Marker file under a swap root recording the current boot epoch.
const EPOCH_FILE: &str = "BOOT_EPOCH";

struct BootState {
    epoch: u64,
    next_pool: u64,
}

/// One boot epoch per swap root per process: the first pool constructed
/// against a root bumps the on-disk epoch counter and GCs every stale
/// epoch directory; later pools in the same process reuse the epoch and
/// get their own subdirectory (so sibling shards can never collide on
/// `gen<<32|id` spill keys).
static BOOTS: Mutex<BTreeMap<PathBuf, BootState>> = Mutex::new(BTreeMap::new());

/// Crash-consistent file replacement: write to `<path>.tmp`, fsync the
/// data, rename over `path`, then fsync the parent directory so the
/// rename itself is durable. A crash at any point leaves either the old
/// file or the new one — never a torn mix (orphaned `*.tmp` files are
/// purged on boot).
pub(crate) fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = tmp_path(path);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating temp file {tmp:?}"))?;
        f.write_all(bytes).with_context(|| format!("writing temp file {tmp:?}"))?;
        f.sync_data().with_context(|| format!("syncing temp file {tmp:?}"))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} over {path:?}"))?;
    if let Some(parent) = path.parent() {
        if let Ok(d) = std::fs::File::open(parent) {
            let _ = d.sync_data();
        }
    }
    Ok(())
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Delete orphaned `*.tmp` files directly under `dir` (crash mid
/// atomic write from a previous incarnation).
pub(crate) fn purge_temps(dir: &Path) {
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            if e.file_name().to_string_lossy().ends_with(".tmp") {
                let _ = std::fs::remove_file(e.path());
            }
        }
    }
}

/// Begin a new boot epoch under `root`: bump the `BOOT_EPOCH` marker
/// and garbage-collect every directory belonging to a previous epoch
/// (plus orphaned temp files at the root). Returns the new epoch.
fn begin_epoch(root: &Path) -> Result<u64> {
    std::fs::create_dir_all(root)
        .with_context(|| format!("creating kv swap root {root:?}"))?;
    let marker = root.join(EPOCH_FILE);
    let prev = std::fs::read_to_string(&marker)
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .unwrap_or(0);
    let epoch = prev + 1;
    atomic_write(&marker, format!("{epoch}\n").as_bytes())?;
    let live = format!("epoch-{epoch:08x}");
    if let Ok(rd) = std::fs::read_dir(root) {
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            if name.starts_with("epoch-") && name != live {
                let _ = std::fs::remove_dir_all(e.path());
            } else if name.ends_with(".tmp") {
                let _ = std::fs::remove_file(e.path());
            }
        }
    }
    Ok(epoch)
}

/// Resolve the per-pool spill directory for `root` under the current
/// boot epoch: `root/epoch-<E>/p<N>` where `E` is bumped once per
/// process (per root) and `N` is unique per constructed pool.
fn resolve_boot_dir(root: &Path) -> PathBuf {
    let mut boots = BOOTS.lock().unwrap();
    if !boots.contains_key(root) {
        // Epoch resolution is best-effort: an unwritable root falls back
        // to epoch 0 (no GC) rather than failing pool construction.
        let epoch = begin_epoch(root).unwrap_or(0);
        boots.insert(root.to_path_buf(), BootState { epoch, next_pool: 0 });
    }
    let st = boots.get_mut(root).unwrap();
    let dir = root
        .join(format!("epoch-{:08x}", st.epoch))
        .join(format!("p{}", st.next_pool));
    st.next_pool += 1;
    dir
}

/// Test hook: forget the process-cached epoch for `root`, so the next
/// `boot_scoped` call simulates a fresh process incarnation (bumps the
/// epoch and GCs the old one).
#[doc(hidden)]
pub fn force_new_boot(root: &Path) {
    BOOTS.lock().unwrap().remove(root);
}

/// State shared with the prefetch thread, under one lock. The prefetch
/// thread reads spill files *outside* the lock, then re-checks `live`
/// *inside* it before parking the blob: a `remove` racing an in-flight
/// prefetch therefore always wins — the stale blob is dropped on the
/// floor instead of parked in `blobs` forever (keys are generation-
/// tagged, so a leaked blob would never be read again, only leaked).
#[derive(Default)]
struct PrefetchShared {
    /// background-prefetched blobs, consumed by `read`
    blobs: HashMap<u64, Vec<u8>>,
    /// keys currently live on disk
    live: HashSet<u64>,
}

pub struct SwapStore {
    dir: PathBuf,
    created: bool,
    /// spill key -> file bytes on disk
    files: HashMap<u64, usize>,
    bytes: usize,
    prefetched: Arc<Mutex<PrefetchShared>>,
    prefetches: u64,
}

impl SwapStore {
    /// A spill-file manager rooted at `dir`. The directory is created
    /// lazily on the first write, so constructing the store is
    /// infallible and a never-spilling pool touches no filesystem.
    pub fn new(dir: &Path) -> SwapStore {
        SwapStore {
            dir: dir.to_path_buf(),
            created: false,
            files: HashMap::new(),
            bytes: 0,
            prefetched: Arc::new(Mutex::new(PrefetchShared::default())),
            prefetches: 0,
        }
    }

    /// A spill-file manager scoped to the current boot epoch under
    /// `root`: spills land in `root/epoch-<E>/p<N>`, so files written by
    /// a previous process incarnation (same `gen<<32|id` keys, dead
    /// sessions) can never be resolved by this one, and sibling pools in
    /// one process never collide. Stale epoch directories are
    /// garbage-collected the first time a root is opened after boot.
    pub fn boot_scoped(root: &Path) -> SwapStore {
        SwapStore::new(&resolve_boot_dir(root))
    }

    fn file_name(key: u64) -> String {
        format!("page-{key:016x}.kvp")
    }

    /// On-disk path of a spill key (public so fault tests can corrupt it).
    pub fn path_of(&self, key: u64) -> PathBuf {
        self.dir.join(Self::file_name(key))
    }

    /// Spill one encoded page. The write is atomic (temp file + fsync +
    /// rename), so a crash mid-spill leaves either the previous blob or
    /// the new one on disk — never a truncated file that would surface
    /// later as a `SwapFault` on resume.
    pub fn write(&mut self, key: u64, blob: &[u8]) -> Result<()> {
        if !self.created {
            std::fs::create_dir_all(&self.dir)
                .with_context(|| format!("creating kv swap dir {:?}", self.dir))?;
            purge_temps(&self.dir);
            self.created = true;
        }
        atomic_write(&self.path_of(key), blob)
            .with_context(|| format!("kv spill write {:?}", self.path_of(key)))?;
        {
            let mut p = self.prefetched.lock().unwrap();
            p.blobs.remove(&key);
            p.live.insert(key);
        }
        if let Some(old) = self.files.insert(key, blob.len()) {
            self.bytes -= old;
        }
        self.bytes += blob.len();
        Ok(())
    }

    /// Read one encoded page back, consuming the prefetched copy when
    /// the background thread already pulled it in.
    pub fn read(&mut self, key: u64) -> Result<Vec<u8>> {
        if let Some(blob) = self.prefetched.lock().unwrap().blobs.remove(&key) {
            return Ok(blob);
        }
        std::fs::read(self.path_of(key))
            .with_context(|| format!("kv spill read {:?}", self.path_of(key)))
    }

    /// Drop a spilled page (page freed while cold). Deregistering the
    /// key from the live set under the lock guarantees that a prefetch
    /// in flight for this key can never park its blob afterwards.
    pub fn remove(&mut self, key: u64) {
        if let Some(n) = self.files.remove(&key) {
            self.bytes -= n;
            let _ = std::fs::remove_file(self.path_of(key));
        }
        let mut p = self.prefetched.lock().unwrap();
        p.blobs.remove(&key);
        p.live.remove(&key);
    }

    /// Start pulling `keys` into RAM on a background thread; `read`
    /// consumes whatever landed and falls back to the file otherwise.
    /// Read errors are ignored here — the synchronous `read` re-reads
    /// and reports them with context.
    pub fn prefetch(&mut self, keys: Vec<u64>) {
        if keys.is_empty() {
            return;
        }
        self.prefetches += keys.len() as u64;
        let dir = self.dir.clone();
        let shared = Arc::clone(&self.prefetched);
        std::thread::spawn(move || {
            for key in keys {
                if let Ok(blob) = std::fs::read(dir.join(SwapStore::file_name(key))) {
                    let mut p = shared.lock().unwrap();
                    // a `remove` may have raced the file read — only park
                    // blobs whose key is still live
                    if p.live.contains(&key) {
                        p.blobs.insert(key, blob);
                    }
                }
            }
        });
    }

    /// Blobs currently parked by the prefetch thread (leak checks).
    pub fn prefetched_len(&self) -> usize {
        self.prefetched.lock().unwrap().blobs.len()
    }

    /// Bytes currently on disk across all spilled pages.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Spilled page count.
    pub fn len(&self) -> usize {
        self.files.len()
    }

    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    /// Total pages handed to the prefetch thread so far.
    pub fn prefetches(&self) -> u64 {
        self.prefetches
    }
}

impl std::fmt::Debug for SwapStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SwapStore({:?}: {} pages, {} bytes)", self.dir, self.files.len(), self.bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("specpv-swap-{tag}-{}", std::process::id()))
    }

    #[test]
    fn write_read_remove_roundtrip() {
        let dir = tmp("rt");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = SwapStore::new(&dir);
        assert!(s.is_empty());
        s.write(7, b"hello").unwrap();
        assert_eq!((s.len(), s.bytes()), (1, 5));
        assert_eq!(s.read(7).unwrap(), b"hello");
        // rewrite replaces without double counting
        s.write(7, b"hi").unwrap();
        assert_eq!(s.bytes(), 2);
        s.remove(7);
        assert!(s.is_empty());
        assert!(s.read(7).is_err(), "removed page must not read back");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefetch_lands_and_read_consumes() {
        let dir = tmp("pf");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = SwapStore::new(&dir);
        s.write(1, b"abc").unwrap();
        s.prefetch(vec![1]);
        // read must succeed whether the prefetch thread won the race or not
        assert_eq!(s.read(1).unwrap(), b"abc");
        assert!(s.prefetches() >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_temp() {
        let dir = tmp("atomic");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("page-0000000000000001.kvp");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        let temps: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(temps.is_empty(), "atomic_write left temp files behind");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn orphaned_temps_purged_on_first_write() {
        let dir = tmp("purge");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // a crash mid-spill from a previous incarnation
        std::fs::write(dir.join("page-00000000000000aa.kvp.tmp"), b"torn").unwrap();
        let mut s = SwapStore::new(&dir);
        s.write(1, b"fresh").unwrap();
        assert!(
            !dir.join("page-00000000000000aa.kvp.tmp").exists(),
            "orphaned temp must be purged on boot"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn boot_epochs_isolate_incarnations_and_gc_stale_dirs() {
        let root = tmp("epoch");
        let _ = std::fs::remove_dir_all(&root);
        force_new_boot(&root);

        // incarnation N spills key 42
        let mut s1 = SwapStore::boot_scoped(&root);
        s1.write(42, b"incarnation-one").unwrap();
        let old_dir = s1.dir.clone();
        assert!(old_dir.starts_with(&root));
        assert!(old_dir.join("page-000000000000002a.kvp").exists());

        // sibling pool in the same incarnation: same epoch, distinct dir
        let s1b = SwapStore::boot_scoped(&root);
        assert_ne!(s1b.dir, old_dir, "sibling pools must not share a spill dir");
        assert_eq!(s1b.dir.parent(), old_dir.parent(), "siblings share the epoch");

        // incarnation N+1: same slot id + generation (key 42) must never
        // resolve incarnation N's file, and N's epoch dir is GC'd
        force_new_boot(&root);
        let mut s2 = SwapStore::boot_scoped(&root);
        assert_ne!(s2.dir.parent(), old_dir.parent(), "epoch must advance across boots");
        assert!(
            s2.read(42).is_err(),
            "stale-epoch spill file must not resolve in the new incarnation"
        );
        assert!(!old_dir.exists(), "stale epoch dir must be garbage-collected on boot");
        s2.write(42, b"incarnation-two").unwrap();
        assert_eq!(s2.read(42).unwrap(), b"incarnation-two");

        force_new_boot(&root);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn remove_racing_prefetch_never_parks_a_stale_blob() {
        let dir = tmp("race");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = SwapStore::new(&dir);
        // distinct key per round (real keys are generation-tagged, so a
        // freed key is never reused); many rounds so both interleavings
        // — blob parked before remove, and remove before park — occur
        for key in 0..200u64 {
            s.write(key, b"payload").unwrap();
            s.prefetch(vec![key]);
            s.remove(key);
            // remove deregisters the key under the lock, so from here on
            // the in-flight prefetch can never park this blob
            assert_eq!(s.prefetched_len(), 0, "stale blob parked for key {key}");
        }
        // let stragglers finish, then re-check nothing landed late
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert_eq!(s.prefetched_len(), 0);
        assert!(s.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
