//! Host store for swapped-out session state.
//!
//! When the coordinator preempts a session, its exported
//! [`StateSnapshot`]s land here keyed by request id; re-admission takes
//! them back for restore-on-resume. The store owns only the *state* —
//! the dormant session object itself (host-side accounting, RNG, output
//! cursor) stays with the coordinator.

use std::collections::HashMap;

use crate::backend::StateSnapshot;

#[derive(Default)]
pub struct SwapStore {
    entries: HashMap<u64, Vec<StateSnapshot>>,
    bytes: usize,
}

impl SwapStore {
    fn bytes_of_entry(snaps: &[StateSnapshot]) -> usize {
        snaps.iter().map(|s| s.bytes()).sum()
    }

    /// Park a swapped-out session's snapshots.
    pub fn put(&mut self, id: u64, snaps: Vec<StateSnapshot>) {
        self.bytes += Self::bytes_of_entry(&snaps);
        if let Some(old) = self.entries.insert(id, snaps) {
            self.bytes -= Self::bytes_of_entry(&old);
        }
    }

    /// Take a session's snapshots back for resume.
    pub fn take(&mut self, id: u64) -> Option<Vec<StateSnapshot>> {
        let snaps = self.entries.remove(&id)?;
        self.bytes -= Self::bytes_of_entry(&snaps);
        Some(snaps)
    }

    /// Drop a session's snapshots (cancellation / expiry while swapped).
    pub fn discard(&mut self, id: u64) {
        let _ = self.take(id);
    }

    pub fn bytes_of(&self, id: u64) -> Option<usize> {
        self.entries.get(&id).map(|s| Self::bytes_of_entry(s))
    }

    /// Host bytes held across all parked sessions.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl std::fmt::Debug for SwapStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SwapStore({} sessions, {} bytes)", self.entries.len(), self.bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::StateKind;

    fn snap(n: usize) -> StateSnapshot {
        StateSnapshot {
            kind: StateKind::Full,
            size: "s".into(),
            bucket: 128,
            data: vec![0.0; n],
            extra: vec![0.0; n],
        }
    }

    #[test]
    fn put_take_accounting() {
        let mut s = SwapStore::default();
        assert!(s.is_empty());
        s.put(3, vec![snap(10), snap(5)]);
        assert_eq!(s.bytes(), (10 + 10 + 5 + 5) * 4);
        assert_eq!(s.bytes_of(3), Some(s.bytes()));
        // re-put replaces the old entry without double counting
        s.put(3, vec![snap(2)]);
        assert_eq!(s.bytes(), 16);
        let got = s.take(3).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!((s.bytes(), s.len()), (0, 0));
        assert!(s.take(3).is_none());
        s.put(4, vec![snap(1)]);
        s.discard(4);
        assert!(s.is_empty());
    }
}
