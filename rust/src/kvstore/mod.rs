//! KV state manager: the subsystem that makes long-context KV state a
//! first-class, *movable* resource instead of an opaque device buffer
//! (DESIGN.md §11, §13).
//!
//! Three cooperating pieces, all built on the `Backend` trait's
//! page-granular state ABI (`export_pages`/`import_pages`):
//!
//! * [`KvPool`] ([`pool`]) — the **paged block pool**: parked state
//!   lives as fixed-size (`kv_page_bytes`) refcounted pages with
//!   content-hash dedup, copy-on-write updates, optional int8
//!   quantization for cold pages (`kv_quant`) and a disk spill tier
//!   (`kv_swap_dir`). The pool doubles as the byte-denominated
//!   **admission ledger** the coordinator gates on (`kv_budget_bytes`).
//! * [`KvStore`] ([`prefix`]) — a content-addressed **prompt-prefix
//!   cache**: post-prefill [`PagedState`] block tables keyed by
//!   (geometry, prompt-prefix hash, prefix length) with LRU +
//!   byte-budget eviction. A hit maps the cached pages into the new
//!   session's table (refcount bump, zero pages allocated) and prefills
//!   only the tail — TTFT for repeated long documents collapses from
//!   O(context) to O(tail).
//! * [`SwapStore`] ([`swap`]) — the **disk tier**: spill files with
//!   checksummed page blobs and async prefetch on resume. Under byte
//!   pressure the coordinator preempts the lowest-priority active
//!   session, parks its states into the pool, demotes the unshared
//!   pages ([`KvPool::park_cold`]) and re-queues it; re-admission
//!   promotes the pages and rebuilds the live state
//!   (restore-on-resume).
//!
//! Everything resident as f32 is exact: park → unpark → continue is
//! byte-identical to an unsuspended run (pinned by
//! `rust/tests/kvstore.rs` and the `rust/tests/paged_pool.rs` oracle
//! property test). Int8 applies only to cold/swapped pages under
//! `kv_quant = int8` and is tolerance-bounded by contract.

pub mod durable;
pub mod pool;
pub mod prefix;
pub mod swap;

pub use durable::CheckpointStore;
pub use pool::{KvPool, PageId, PagedState, PoolStats, DEFAULT_PAGE_BYTES};
pub use prefix::{KvStore, PrefixStats};
pub use swap::SwapStore;

use crate::config::Config;

/// The KV context threaded from the coordinator (or a bare
/// `generate_with`) into every engine session: one shared page pool plus
/// an optional prefix cache whose entries live in that same pool.
#[derive(Clone)]
pub struct KvCtx {
    pub pool: KvPool,
    pub prefix: Option<KvStore>,
}

impl KvCtx {
    /// No budget, no prefix cache, default pages — the context used by
    /// one-shot generation and tests that don't exercise the KV tier.
    pub fn disabled() -> KvCtx {
        KvCtx { pool: KvPool::new(0), prefix: None }
    }

    /// A context over an existing pool, no prefix cache.
    pub fn with_pool(pool: KvPool) -> KvCtx {
        KvCtx { pool, prefix: None }
    }

    /// A context sharing a prefix store's pool.
    pub fn with_prefix(store: KvStore) -> KvCtx {
        KvCtx { pool: store.pool(), prefix: Some(store) }
    }

    /// Build the full context a config describes: a pool sized by
    /// `kv_budget_bytes`/`kv_page_bytes` with the configured swap dir
    /// and cold-page quantization, plus a prefix cache when
    /// `prefix_cache_bytes > 0`.
    pub fn from_config(cfg: &Config) -> KvCtx {
        let pool = KvPool::with_opts(
            cfg.kv_budget_bytes,
            cfg.kv_page_bytes,
            cfg.swap_dir().as_deref(),
            cfg.kv_quant,
        );
        let prefix = (cfg.prefix_cache_bytes > 0)
            .then(|| KvStore::with_pool(cfg.prefix_cache_bytes, pool.clone()));
        KvCtx { pool, prefix }
    }
}

/// Aggregated snapshot of the KV subsystem, reported by the server's
/// admin `kv`/`cache` subcommands and `Coordinator::kv_stats`.
#[derive(Debug, Default, Clone)]
pub struct KvStats {
    pub prefix: PrefixStats,
    /// working-set bytes currently reserved by live sessions
    pub resident_bytes: usize,
    /// admission byte budget (0 = unlimited)
    pub budget_bytes: usize,
    /// live sessions with a reservation
    pub live_states: usize,
    /// sessions currently parked (preempted, pages possibly demoted)
    pub swapped: usize,
    /// flat-slab-equivalent bytes of parked sessions
    pub swap_bytes: usize,
    pub swap_outs: u64,
    pub swap_ins: u64,
    /// page-level pool residency (dedup/CoW/quant/spill gauges)
    pub pages: PoolStats,
}
