//! KV state manager: the subsystem that makes long-context KV state a
//! first-class, *movable* resource instead of an opaque device buffer
//! (DESIGN.md §11).
//!
//! Three cooperating pieces, all built on the `Backend` trait's
//! snapshot/restore ABI ([`crate::backend::StateSnapshot`]):
//!
//! * [`KvStore`] ([`prefix`]) — a content-addressed **prompt-prefix
//!   cache**: post-prefill snapshots keyed by (geometry, prompt-prefix
//!   hash, prefix length) with LRU + byte-budget eviction.
//!   `TargetSession::prefill` consults it, so a request whose prompt
//!   extends a cached prefix restores the snapshot and prefills only the
//!   tail — TTFT for repeated long documents collapses from O(context)
//!   to O(tail).
//! * [`KvPool`] ([`pool`]) — **byte-denominated admission accounting**:
//!   the coordinator registers each live session's resident state bytes
//!   (from `Backend::state_bytes`) and gates admission on a configurable
//!   budget (`kv_budget_bytes`) instead of a session head-count alone.
//! * [`SwapStore`] ([`swap`]) — the **host store for swapped-out
//!   sessions**: under byte pressure the coordinator preempts the
//!   lowest-priority active session, exports its states here, and
//!   re-queues it; re-admission imports the snapshots back
//!   (restore-on-resume), turning step-resumable sessions into real
//!   elastic scheduling.
//!
//! Everything is exact: export → import → continue is byte-identical to
//! an unsuspended run (pinned by `rust/tests/kvstore.rs`), so neither
//! prefix hits nor swaps are observable in the output stream.

pub mod pool;
pub mod prefix;
pub mod swap;

pub use pool::KvPool;
pub use prefix::{KvStore, PrefixStats};
pub use swap::SwapStore;

/// Aggregated snapshot of the KV subsystem, reported by the server's
/// `{"op":"cache"}` admin op and `Coordinator::kv_stats`.
#[derive(Debug, Default, Clone)]
pub struct KvStats {
    pub prefix: PrefixStats,
    /// device bytes currently registered to live sessions
    pub resident_bytes: usize,
    /// admission byte budget (0 = unlimited)
    pub budget_bytes: usize,
    /// live sessions with registered state
    pub live_states: usize,
    /// sessions currently swapped out to the host store
    pub swapped: usize,
    /// host bytes held by swapped-out snapshots
    pub swap_bytes: usize,
    pub swap_outs: u64,
    pub swap_ins: u64,
}
