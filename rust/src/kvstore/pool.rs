//! Byte-denominated admission accounting for live KV state.
//!
//! The coordinator registers every live session's resident state bytes
//! (computed from `Backend::state_bytes` over the session's full /
//! partial / draft / tiny buckets) and asks [`KvPool::admits`] before
//! starting or resuming a session. The KV footprint — not a session
//! head-count — is what governs who runs; `max_active` remains only as a
//! scheduling-width cap.

use std::collections::HashMap;

/// Tracks resident bytes per live session against a budget.
#[derive(Debug, Default)]
pub struct KvPool {
    budget: usize,
    resident: usize,
    by_id: HashMap<u64, usize>,
}

impl KvPool {
    /// A pool with `budget_bytes` capacity (0 = unlimited).
    pub fn new(budget_bytes: usize) -> KvPool {
        KvPool { budget: budget_bytes, resident: 0, by_id: HashMap::new() }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Bytes currently registered to live sessions.
    pub fn resident(&self) -> usize {
        self.resident
    }

    /// Live sessions with registered state.
    pub fn live(&self) -> usize {
        self.by_id.len()
    }

    /// Would a new state of `bytes` fit? Unlimited when the budget is 0;
    /// an empty pool always admits, so one oversized session degrades to
    /// run-alone instead of deadlocking the scheduler.
    pub fn admits(&self, bytes: usize) -> bool {
        self.budget == 0 || self.by_id.is_empty() || self.resident + bytes <= self.budget
    }

    /// Register (or re-register) a session's resident bytes.
    pub fn register(&mut self, id: u64, bytes: usize) {
        let prev = self.by_id.insert(id, bytes).unwrap_or(0);
        self.resident = self.resident - prev + bytes;
    }

    /// Release a session's bytes (idempotent); returns what was held.
    pub fn release(&mut self, id: u64) -> usize {
        let b = self.by_id.remove(&id).unwrap_or(0);
        self.resident -= b;
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_roundtrip() {
        let mut p = KvPool::new(100);
        assert!(p.admits(100));
        p.register(1, 60);
        assert_eq!((p.resident(), p.live()), (60, 1));
        assert!(p.admits(40));
        assert!(!p.admits(41));
        p.register(1, 70); // re-register replaces, not adds
        assert_eq!(p.resident(), 70);
        assert_eq!(p.release(1), 70);
        assert_eq!(p.release(1), 0);
        assert_eq!((p.resident(), p.live()), (0, 0));
    }

    #[test]
    fn zero_budget_is_unlimited_and_empty_pool_admits_oversize() {
        let p = KvPool::new(0);
        assert!(p.admits(usize::MAX / 2));
        let mut p = KvPool::new(10);
        assert!(p.admits(1 << 30), "empty pool must admit (no deadlock)");
        p.register(1, 5);
        assert!(!p.admits(1 << 30));
    }
}
