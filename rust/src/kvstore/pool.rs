//! Paged KV block pool: fixed-size refcounted pages with content dedup,
//! copy-on-write updates, optional int8 quantization for cold pages, and
//! a disk spill tier (DESIGN.md §13).
//!
//! Session state parked here (prefix-cache entries, suspended sessions)
//! is stored as a [`PagedState`] — a per-state block table of page ids
//! into the pool — instead of a flat slab. Pages are deduplicated by
//! content hash (verified byte-exact before sharing), so the all-zero
//! padding tail of a bucket-sized state costs one page, and identical
//! prefix KV across parked sessions is stored once. A page is never
//! mutated while shared: [`KvPool::update`] keeps the page when the new
//! content is byte-identical and otherwise allocates (write-to-shared
//! triggers the copy), which is what makes mapping cached prefix pages
//! into a new session's table safe.
//!
//! The pool doubles as the byte-denominated **admission** ledger the
//! coordinator has always used: [`KvPool::reserve`]/[`KvPool::release`]
//! track each live session's working-set bytes against
//! `kv_budget_bytes`, unchanged semantics from the flat-slab pool
//! (unlimited at 0; an empty pool always admits so one oversized session
//! degrades to run-alone instead of deadlocking).
//!
//! Everything resident as f32 is exact; int8 applies only to pages
//! quantized by [`KvPool::park_cold`] (cold/swapped pages) and is
//! tolerance-bounded by contract.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::backend::{copy_image_range, page_count, Backend, StateBuf, StateKind};
use crate::config::KvQuant;
use crate::kvstore::swap::SwapStore;
use crate::util::rng::Rng;

/// Index of a page slot within the pool.
pub type PageId = u32;

/// Default `kv_page_bytes`: 64 KiB ≙ 16 Ki f32 elements per page.
pub const DEFAULT_PAGE_BYTES: usize = 64 << 10;

/// Pages per `export_pages` call when parking a backend state — bounds
/// scratch memory and (on download-whole backends) transfer count.
const PARK_BATCH_PAGES: usize = 32;

/// A parked backend state as a block table of pool pages. The canonical
/// flat image is `data ++ extra` of the matching [`StateSnapshot`]
/// (`crate::backend::StateSnapshot`), split into page-sized runs.
#[derive(Debug, Clone, PartialEq)]
pub struct PagedState {
    pub kind: StateKind,
    pub size: String,
    pub bucket: usize,
    /// f32 elements of the snapshot `data` section
    pub data_len: usize,
    /// f32 elements of the snapshot `extra` section
    pub extra_len: usize,
    /// block table: page ids in image order
    pub pages: Vec<PageId>,
}

impl PagedState {
    /// Total f32 elements of the flat image.
    pub fn image_len(&self) -> usize {
        self.data_len + self.extra_len
    }

    /// Bytes of the flat-slab equivalent (what a non-paged store holds).
    pub fn logical_bytes(&self) -> usize {
        self.image_len() * 4
    }
}

/// Point-in-time pool gauges (page-level residency for `Registry`).
#[derive(Debug, Default, Clone)]
pub struct PoolStats {
    pub page_bytes: usize,
    /// live page slots (RAM + disk)
    pub pages_resident: usize,
    /// live slots with refcount ≥ 2
    pub pages_shared: usize,
    /// live slots stored as all-zero (no payload RAM)
    pub pages_zero: usize,
    /// live slots currently spilled to disk
    pub pages_spilled: usize,
    /// actual RAM payload bytes across live pages
    pub ram_bytes: usize,
    /// bytes on disk across spilled pages
    pub disk_bytes: usize,
    /// internal fragmentation: unused tail capacity of live pages, %
    pub frag_pct: f64,
    /// alloc requests (dedup hits included)
    pub allocs: u64,
    /// pages actually materialized (alloc misses)
    pub page_allocs: u64,
    pub dedup_hits: u64,
    /// updates that diverged from a shared page (true CoW copies)
    pub cow_copies: u64,
    /// pages quantized to int8 by `park_cold`
    pub quant_pages: u64,
    pub spills: u64,
    pub spill_loads: u64,
    /// spill decode failures (corrupt/truncated file on resume)
    pub swap_faults: u64,
}

enum PageData {
    /// slot on the free list
    Free,
    /// all-zero payload, no storage
    Zero,
    F32(Vec<f32>),
    Int8 { q: Vec<i8>, scale: f32 },
    /// payload in the swap tier under `spill key = gen << 32 | id`
    Disk { blob_bytes: usize },
}

struct Slot {
    refs: u32,
    /// generation, bumped on free — part of the spill key so a reused
    /// slot id can never resolve a stale spill file
    gen: u32,
    /// payload f32 elements
    len: usize,
    /// content hash of the payload (dedup index key)
    hash: u64,
    data: PageData,
}

struct PoolInner {
    page_bytes: usize,
    quant: KvQuant,
    swap: Option<SwapStore>,

    slots: Vec<Slot>,
    free: Vec<PageId>,
    /// content hash -> candidate page ids (RAM, dedup-eligible slots)
    index: HashMap<u64, Vec<PageId>>,
    ram_bytes: usize,

    // ---- byte-denominated admission ledger (reservation accounting) ----
    budget: usize,
    reserved: usize,
    by_id: HashMap<u64, usize>,

    // ---- fault injection (DESIGN.md §15; off by default) ----
    /// probability that a spill read fails as if the blob were corrupt
    corrupt_rate: f64,
    fault_rng: Rng,

    // ---- counters ----
    allocs: u64,
    page_allocs: u64,
    dedup_hits: u64,
    cow_copies: u64,
    quant_pages: u64,
    spills: u64,
    spill_loads: u64,
    swap_faults: u64,
}

fn hash_f32(xs: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Byte-exact payload comparison (bit-level: preserves -0.0 and NaN
/// payloads) against dedup-eligible storage only.
fn slot_matches(slot: &Slot, content: &[f32]) -> bool {
    if slot.len != content.len() {
        return false;
    }
    match &slot.data {
        PageData::Zero => content.iter().all(|x| x.to_bits() == 0),
        PageData::F32(v) => {
            v.iter().zip(content).all(|(a, b)| a.to_bits() == b.to_bits())
        }
        _ => false,
    }
}

fn quantize_int8(v: &[f32]) -> (Vec<i8>, f32) {
    let absmax = v.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
    let q = v
        .iter()
        .map(|x| (x / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (q, scale)
}

// ---- spill-file codec: magic, flags, len, scale, checksum, payload ----

const SPILL_MAGIC: u32 = 0x4B56_5047; // "KVPG"
const SPILL_F32: u32 = 0;
const SPILL_INT8: u32 = 1;

fn encode_page(data: &PageData, len: usize) -> Vec<u8> {
    let (flags, scale, payload): (u32, f32, Vec<u8>) = match data {
        PageData::F32(v) => {
            (SPILL_F32, 0.0, v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        PageData::Int8 { q, scale } => {
            (SPILL_INT8, *scale, q.iter().map(|&b| b as u8).collect())
        }
        _ => unreachable!("only RAM payload pages are spilled"),
    };
    let mut out = Vec::with_capacity(24 + payload.len());
    out.extend_from_slice(&SPILL_MAGIC.to_le_bytes());
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&(len as u32).to_le_bytes());
    out.extend_from_slice(&scale.to_le_bytes());
    out.extend_from_slice(&hash_bytes(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Encode a raw f32 slice with the spill-file codec (magic / flags /
/// len / checksum header + payload). The durable checkpoint store uses
/// this so checkpoint payloads share the validated on-disk format with
/// KV spill pages.
pub fn encode_f32_blob(v: &[f32]) -> Vec<u8> {
    encode_page(&PageData::F32(v.to_vec()), v.len())
}

/// Decode + validate a blob produced by [`encode_f32_blob`]. Torn or
/// corrupt blobs surface as clean errors, never panics.
pub fn decode_f32_blob(blob: &[u8]) -> Result<Vec<f32>> {
    if blob.len() < 24 {
        bail!("truncated spill blob ({} bytes)", blob.len());
    }
    let len = u32::from_le_bytes(blob[8..12].try_into().unwrap()) as usize;
    match decode_page(blob, len)? {
        PageData::F32(v) => Ok(v),
        _ => bail!("expected f32 spill payload"),
    }
}

pub(crate) fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Decode + validate a spill blob; the error text is what a swap-tier
/// fault surfaces through the coordinator (clean re-queue, never panic).
fn decode_page(blob: &[u8], want_len: usize) -> Result<PageData> {
    if blob.len() < 24 {
        bail!("truncated spill blob ({} bytes)", blob.len());
    }
    let word = |i: usize| u32::from_le_bytes(blob[i..i + 4].try_into().unwrap());
    if word(0) != SPILL_MAGIC {
        bail!("bad spill magic {:#x}", word(0));
    }
    let flags = word(4);
    let len = word(8) as usize;
    let scale = f32::from_le_bytes(blob[12..16].try_into().unwrap());
    let sum = u64::from_le_bytes(blob[16..24].try_into().unwrap());
    let payload = &blob[24..];
    if len != want_len {
        bail!("spill length mismatch (file {len}, slot {want_len})");
    }
    if hash_bytes(payload) != sum {
        bail!("spill checksum mismatch ({} payload bytes)", payload.len());
    }
    match flags {
        SPILL_F32 => {
            if payload.len() != len * 4 {
                bail!("spill f32 payload truncated ({} of {})", payload.len(), len * 4);
            }
            Ok(PageData::F32(
                payload
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ))
        }
        SPILL_INT8 => {
            if payload.len() != len {
                bail!("spill int8 payload truncated ({} of {len})", payload.len());
            }
            Ok(PageData::Int8 {
                q: payload.iter().map(|&b| b as i8).collect(),
                scale,
            })
        }
        f => bail!("unknown spill flags {f:#x}"),
    }
}

impl PoolInner {
    fn spill_key(&self, id: PageId) -> u64 {
        ((self.slots[id as usize].gen as u64) << 32) | id as u64
    }

    fn deindex(&mut self, id: PageId) {
        let hash = self.slots[id as usize].hash;
        if let Some(v) = self.index.get_mut(&hash) {
            v.retain(|&x| x != id);
            if v.is_empty() {
                self.index.remove(&hash);
            }
        }
    }

    fn ram_bytes_of(data: &PageData) -> usize {
        match data {
            PageData::F32(v) => v.len() * 4,
            PageData::Int8 { q, .. } => q.len(),
            _ => 0,
        }
    }

    fn alloc(&mut self, content: &[f32]) -> PageId {
        self.allocs += 1;
        let hash = hash_f32(content);
        if let Some(cands) = self.index.get(&hash) {
            let cands = cands.clone();
            for id in cands {
                if slot_matches(&self.slots[id as usize], content) {
                    self.slots[id as usize].refs += 1;
                    self.dedup_hits += 1;
                    return id;
                }
            }
        }
        let zero = content.iter().all(|x| x.to_bits() == 0);
        let data = if zero {
            PageData::Zero
        } else {
            self.ram_bytes += content.len() * 4;
            PageData::F32(content.to_vec())
        };
        let id = match self.free.pop() {
            Some(id) => {
                let slot = &mut self.slots[id as usize];
                slot.refs = 1;
                slot.len = content.len();
                slot.hash = hash;
                slot.data = data;
                id
            }
            None => {
                self.slots.push(Slot {
                    refs: 1,
                    gen: 0,
                    len: content.len(),
                    hash,
                    data,
                });
                (self.slots.len() - 1) as PageId
            }
        };
        self.index.entry(hash).or_default().push(id);
        self.page_allocs += 1;
        id
    }

    fn free(&mut self, id: PageId) {
        let slot = &self.slots[id as usize];
        debug_assert!(slot.refs > 0, "double free of kv page {id}");
        if slot.refs > 1 {
            self.slots[id as usize].refs -= 1;
            return;
        }
        self.deindex(id);
        let key = self.spill_key(id);
        let slot = &mut self.slots[id as usize];
        slot.refs = 0;
        slot.gen = slot.gen.wrapping_add(1);
        let data = std::mem::replace(&mut slot.data, PageData::Free);
        slot.len = 0;
        self.ram_bytes -= Self::ram_bytes_of(&data);
        if matches!(data, PageData::Disk { .. }) {
            if let Some(swap) = self.swap.as_mut() {
                swap.remove(key);
            }
        }
        self.free.push(id);
    }

    /// Materialize a page's payload into `out` (dequantizing / loading
    /// from disk as needed). Disk reads do not promote — see `promote`.
    fn read_into(&mut self, id: PageId, out: &mut Vec<f32>) -> Result<()> {
        out.clear();
        let key = self.spill_key(id);
        if matches!(self.slots[id as usize].data, PageData::Disk { .. }) {
            let data = self.load_spilled(id, key)?;
            match &data {
                PageData::F32(v) => out.extend_from_slice(v),
                PageData::Int8 { q, scale } => {
                    out.extend(q.iter().map(|&b| b as f32 * *scale))
                }
                _ => unreachable!(),
            }
            return Ok(());
        }
        let slot = &self.slots[id as usize];
        match &slot.data {
            PageData::Free => bail!("read of freed kv page {id}"),
            PageData::Zero => out.resize(slot.len, 0.0),
            PageData::F32(v) => out.extend_from_slice(v),
            PageData::Int8 { q, scale } => {
                let scale = *scale;
                out.extend(q.iter().map(|&b| b as f32 * scale));
            }
            PageData::Disk { .. } => unreachable!(),
        }
        Ok(())
    }

    fn load_spilled(&mut self, id: PageId, key: u64) -> Result<PageData> {
        // failpoint: fail the read as if the blob were corrupt, driving
        // the same swap-fault recovery a real bad file would
        if self.corrupt_rate > 0.0 && self.fault_rng.f64() < self.corrupt_rate {
            self.swap_faults += 1;
            bail!("kv spill page {id}: injected spill corruption (failpoint)");
        }
        let len = self.slots[id as usize].len;
        let swap = self
            .swap
            .as_mut()
            .with_context(|| format!("kv page {id} spilled but no swap tier configured"))?;
        let loaded = swap
            .read(key)
            .and_then(|blob| decode_page(&blob, len))
            .with_context(|| format!("kv spill page {id}"));
        match loaded {
            Ok(data) => {
                self.spill_loads += 1;
                Ok(data)
            }
            Err(e) => {
                self.swap_faults += 1;
                Err(e)
            }
        }
    }
}

/// Cheap-clone shared handle to the paged pool (single-threaded, like
/// [`crate::kvstore::KvStore`]); the prefix cache, coordinator, and
/// engine sessions all hold clones of one pool.
#[derive(Clone)]
pub struct KvPool {
    inner: Rc<RefCell<PoolInner>>,
}

impl KvPool {
    /// A pool with `budget_bytes` admission capacity (0 = unlimited) and
    /// default page size, no quantization, no disk tier.
    pub fn new(budget_bytes: usize) -> KvPool {
        KvPool::with_opts(budget_bytes, DEFAULT_PAGE_BYTES, None, KvQuant::None)
    }

    /// Full constructor: `page_bytes` is clamped to a positive multiple
    /// of 4; `swap_dir` enables the disk tier (created lazily on first
    /// spill); `quant` selects cold-page storage.
    pub fn with_opts(
        budget_bytes: usize,
        page_bytes: usize,
        swap_dir: Option<&Path>,
        quant: KvQuant,
    ) -> KvPool {
        let page_bytes = (page_bytes.max(4)) & !3;
        KvPool {
            inner: Rc::new(RefCell::new(PoolInner {
                page_bytes,
                quant,
                swap: swap_dir.map(SwapStore::boot_scoped),
                slots: Vec::new(),
                free: Vec::new(),
                index: HashMap::new(),
                ram_bytes: 0,
                budget: budget_bytes,
                reserved: 0,
                by_id: HashMap::new(),
                corrupt_rate: 0.0,
                fault_rng: Rng::new(1),
                allocs: 0,
                page_allocs: 0,
                dedup_hits: 0,
                cow_copies: 0,
                quant_pages: 0,
                spills: 0,
                spill_loads: 0,
                swap_faults: 0,
            })),
        }
    }

    pub fn page_bytes(&self) -> usize {
        self.inner.borrow().page_bytes
    }

    /// f32 elements per page.
    pub fn page_elems(&self) -> usize {
        self.inner.borrow().page_bytes / 4
    }

    // ---- admission ledger (reservation accounting, unchanged ABI) ----

    pub fn budget(&self) -> usize {
        self.inner.borrow().budget
    }

    /// Working-set bytes currently reserved by live sessions.
    pub fn resident(&self) -> usize {
        self.inner.borrow().reserved
    }

    /// Live sessions with a reservation.
    pub fn live(&self) -> usize {
        self.inner.borrow().by_id.len()
    }

    /// Would a new working set of `bytes` fit? Unlimited when the budget
    /// is 0; an empty pool always admits, so one oversized session
    /// degrades to run-alone instead of deadlocking the scheduler.
    pub fn admits(&self, bytes: usize) -> bool {
        let p = self.inner.borrow();
        p.budget == 0 || p.by_id.is_empty() || p.reserved + bytes <= p.budget
    }

    /// Reserve (or re-reserve) a session's working-set bytes.
    pub fn reserve(&self, id: u64, bytes: usize) {
        let mut p = self.inner.borrow_mut();
        let prev = p.by_id.insert(id, bytes).unwrap_or(0);
        p.reserved = p.reserved - prev + bytes;
    }

    /// Release a session's reservation (idempotent); returns what was held.
    pub fn release(&self, id: u64) -> usize {
        let mut p = self.inner.borrow_mut();
        let b = p.by_id.remove(&id).unwrap_or(0);
        p.reserved -= b;
        b
    }

    // ---- page store ----

    /// Allocate a page holding `content` (≤ one page of elements),
    /// deduplicating byte-identical resident pages (all-zero content is
    /// stored as a zero page with no payload RAM).
    pub fn alloc(&self, content: &[f32]) -> PageId {
        let mut p = self.inner.borrow_mut();
        assert!(
            content.len() <= p.page_bytes / 4,
            "page content {} elems exceeds page size {} bytes",
            content.len(),
            p.page_bytes
        );
        p.alloc(content)
    }

    /// Add a reference to an existing page.
    pub fn share(&self, id: PageId) {
        self.inner.borrow_mut().slots[id as usize].refs += 1;
    }

    /// Drop a reference; the last reference frees the slot (and its
    /// spill file, if any).
    pub fn free(&self, id: PageId) {
        self.inner.borrow_mut().free(id);
    }

    /// Copy-on-write update: returns the page to use for `content`.
    /// Byte-identical content keeps the existing page (and its sharing);
    /// changed content never mutates the page in place — it allocates
    /// (dedup-aware) and drops this reference.
    pub fn update(&self, id: PageId, content: &[f32]) -> PageId {
        let shared = {
            let p = self.inner.borrow();
            if slot_matches(&p.slots[id as usize], content) {
                return id;
            }
            p.slots[id as usize].refs > 1
        };
        if shared {
            self.inner.borrow_mut().cow_copies += 1;
        }
        let nid = self.alloc(content);
        self.free(id);
        nid
    }

    /// Materialize a page's payload into `out`.
    pub fn read_into(&self, id: PageId, out: &mut Vec<f32>) -> Result<()> {
        self.inner.borrow_mut().read_into(id, out)
    }

    // ---- paged-state helpers ----

    /// Park a flat image (`data ++ extra`) as pool pages.
    pub fn park_image(
        &self,
        kind: StateKind,
        size: &str,
        bucket: usize,
        data: &[f32],
        extra: &[f32],
    ) -> PagedState {
        let pe = self.page_elems();
        let total = data.len() + extra.len();
        let n = page_count(total, pe);
        let mut scratch = Vec::with_capacity(pe);
        let mut pages = Vec::with_capacity(n);
        for i in 0..n {
            copy_image_range(data, extra, i * pe, ((i + 1) * pe).min(total), &mut scratch);
            pages.push(self.alloc(&scratch));
        }
        PagedState {
            kind,
            size: size.to_string(),
            bucket,
            data_len: data.len(),
            extra_len: extra.len(),
            pages,
        }
    }

    /// Reassemble a parked state's flat image as `(data, extra)`.
    pub fn read_image(&self, ps: &PagedState) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut data = Vec::with_capacity(ps.data_len);
        let mut extra = Vec::with_capacity(ps.extra_len);
        let mut scratch = Vec::new();
        for (i, &id) in ps.pages.iter().enumerate() {
            self.read_into(id, &mut scratch)?;
            let start = i * self.page_elems();
            for (j, &x) in scratch.iter().enumerate() {
                if start + j < ps.data_len {
                    data.push(x);
                } else {
                    extra.push(x);
                }
            }
        }
        if data.len() != ps.data_len || extra.len() != ps.extra_len {
            bail!(
                "paged state image mismatch: got {}+{}, want {}+{}",
                data.len(),
                extra.len(),
                ps.data_len,
                ps.extra_len
            );
        }
        Ok((data, extra))
    }

    /// Park a live backend state, streaming pages (`export_pages` in
    /// bounded batches) instead of exporting one whole slab.
    pub fn park_state(
        &self,
        be: &dyn Backend,
        kind: StateKind,
        size: &str,
        bucket: usize,
        state: &StateBuf,
    ) -> Result<PagedState> {
        let (data_len, extra_len) = be.state_image_len(kind, size, bucket, state)?;
        let pe = self.page_elems();
        let n = page_count(data_len + extra_len, pe);
        let mut pages = Vec::with_capacity(n);
        let mut start = 0;
        while start < n {
            let end = (start + PARK_BATCH_PAGES).min(n);
            for page in be.export_pages(kind, size, bucket, state, start..end, pe)? {
                pages.push(self.alloc(&page));
            }
            start = end;
        }
        Ok(PagedState {
            kind,
            size: size.to_string(),
            bucket,
            data_len,
            extra_len,
            pages,
        })
    }

    /// Rebuild a live backend state from parked pages, streaming one
    /// page at a time through the backend's `import_pages`.
    pub fn unpark_state(&self, be: &dyn Backend, ps: &PagedState) -> Result<StateBuf> {
        be.import_pages(
            ps.kind,
            &ps.size,
            ps.bucket,
            ps.data_len,
            ps.extra_len,
            self.page_elems(),
            &mut |i, buf| self.read_into(ps.pages[i], buf),
        )
    }

    /// Add a reference to every page of a parked state (prefix-cache
    /// hits map the cached pages instead of copying a snapshot).
    pub fn share_state(&self, ps: &PagedState) -> PagedState {
        for &id in &ps.pages {
            self.share(id);
        }
        ps.clone()
    }

    /// Drop one reference from every page of a parked state.
    pub fn free_state(&self, ps: &PagedState) {
        for &id in &ps.pages {
            self.free(id);
        }
    }

    // ---- tiering ----

    /// Demote the unshared pages of parked states: quantize to int8
    /// when `kv_quant = int8`, then spill to the disk tier when one is
    /// configured. Shared pages (prefix cache, other parked sessions)
    /// stay hot and exact. A spill write error leaves the page safely in
    /// RAM and is returned to the caller.
    pub fn park_cold(&self, states: &[PagedState]) -> Result<()> {
        let mut p = self.inner.borrow_mut();
        let p = &mut *p;
        for ps in states {
            for &id in &ps.pages {
                let slot = &p.slots[id as usize];
                if slot.refs != 1 {
                    continue;
                }
                if p.quant == KvQuant::Int8 {
                    if let PageData::F32(v) = &slot.data {
                        let (q, scale) = quantize_int8(v);
                        p.ram_bytes -= slot.len * 4 - q.len();
                        p.deindex(id);
                        p.slots[id as usize].data = PageData::Int8 { q, scale };
                        p.quant_pages += 1;
                    }
                }
                let slot = &p.slots[id as usize];
                if p.swap.is_some()
                    && matches!(slot.data, PageData::F32(_) | PageData::Int8 { .. })
                {
                    let blob = encode_page(&slot.data, slot.len);
                    let key = ((slot.gen as u64) << 32) | id as u64;
                    p.swap.as_mut().unwrap().write(key, &blob)?;
                    p.deindex(id);
                    let old = std::mem::replace(
                        &mut p.slots[id as usize].data,
                        PageData::Disk { blob_bytes: blob.len() },
                    );
                    p.ram_bytes -= PoolInner::ram_bytes_of(&old);
                    p.spills += 1;
                }
            }
        }
        Ok(())
    }

    /// Kick off async prefetch of any spilled pages of these states.
    pub fn prefetch(&self, states: &[PagedState]) {
        let mut p = self.inner.borrow_mut();
        let p = &mut *p;
        let mut keys = Vec::new();
        for ps in states {
            for &id in &ps.pages {
                if matches!(p.slots[id as usize].data, PageData::Disk { .. }) {
                    keys.push(((p.slots[id as usize].gen as u64) << 32) | id as u64);
                }
            }
        }
        if let Some(swap) = p.swap.as_mut() {
            swap.prefetch(keys);
        }
    }

    /// Load every spilled page of these states back into RAM (f32 stays
    /// exact, int8 stays int8). A corrupt or truncated spill file
    /// surfaces as a clean error here — the coordinator's swap-fault
    /// path — never a panic.
    pub fn promote(&self, states: &[PagedState]) -> Result<()> {
        let mut p = self.inner.borrow_mut();
        for ps in states {
            for &id in &ps.pages {
                let key = p.spill_key(id);
                if !matches!(p.slots[id as usize].data, PageData::Disk { .. }) {
                    continue;
                }
                let data = p.load_spilled(id, key)?;
                let key_bytes = PoolInner::ram_bytes_of(&data);
                if let Some(swap) = p.swap.as_mut() {
                    swap.remove(key);
                }
                p.slots[id as usize].data = data;
                p.ram_bytes += key_bytes;
            }
        }
        Ok(())
    }

    /// Arm the spill-corruption failpoint: each spill read fails with
    /// probability `rate` as if the blob were corrupt, exercising the
    /// coordinator's swap-fault recovery path (drop dormant session,
    /// re-queue, deterministic replay). Off by default; `rate = 0`
    /// disarms.
    pub fn set_corrupt_faults(&self, rate: f64, seed: u64) {
        let mut p = self.inner.borrow_mut();
        p.corrupt_rate = rate;
        // decorrelate from the coordinator's backend-error stream, which
        // is seeded from the same spec
        p.fault_rng = Rng::new(seed ^ 0x6b76_7370);
    }

    /// Page-level residency gauges.
    pub fn stats(&self) -> PoolStats {
        let p = self.inner.borrow();
        let mut s = PoolStats {
            page_bytes: p.page_bytes,
            ram_bytes: p.ram_bytes,
            disk_bytes: p.swap.as_ref().map(|s| s.bytes()).unwrap_or(0),
            allocs: p.allocs,
            page_allocs: p.page_allocs,
            dedup_hits: p.dedup_hits,
            cow_copies: p.cow_copies,
            quant_pages: p.quant_pages,
            spills: p.spills,
            spill_loads: p.spill_loads,
            swap_faults: p.swap_faults,
            ..PoolStats::default()
        };
        let mut payload_elems = 0usize;
        for slot in &p.slots {
            if slot.refs == 0 {
                continue;
            }
            s.pages_resident += 1;
            payload_elems += slot.len;
            if slot.refs > 1 {
                s.pages_shared += 1;
            }
            match slot.data {
                PageData::Zero => s.pages_zero += 1,
                PageData::Disk { .. } => s.pages_spilled += 1,
                _ => {}
            }
        }
        let cap = s.pages_resident * (p.page_bytes / 4);
        s.frag_pct = if cap == 0 {
            0.0
        } else {
            100.0 * (1.0 - payload_elems as f64 / cap as f64)
        };
        s
    }
}

impl std::fmt::Debug for KvPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "KvPool({}B pages, {} resident / {} shared, {} RAM B, {} disk B)",
            s.page_bytes, s.pages_resident, s.pages_shared, s.ram_bytes, s.disk_bytes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn reservation_accounting_roundtrip() {
        let p = KvPool::new(100);
        assert!(p.admits(100));
        p.reserve(1, 60);
        assert_eq!((p.resident(), p.live()), (60, 1));
        assert!(p.admits(40));
        assert!(!p.admits(41));
        p.reserve(1, 70); // re-reserve replaces, not adds
        assert_eq!(p.resident(), 70);
        assert_eq!(p.release(1), 70);
        assert_eq!(p.release(1), 0);
        assert_eq!((p.resident(), p.live()), (0, 0));
    }

    #[test]
    fn zero_budget_is_unlimited_and_empty_pool_admits_oversize() {
        let p = KvPool::new(0);
        assert!(p.admits(usize::MAX / 2));
        let p = KvPool::new(10);
        assert!(p.admits(1 << 30), "empty pool must admit (no deadlock)");
        p.reserve(1, 5);
        assert!(!p.admits(1 << 30));
    }

    #[test]
    fn alloc_dedups_and_zero_pages_cost_nothing() {
        let p = KvPool::with_opts(0, 16, None, KvQuant::None);
        let a = p.alloc(&[1.0, 2.0, 3.0, 4.0]);
        let b = p.alloc(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a, b, "identical content must dedup");
        let z1 = p.alloc(&[0.0; 4]);
        let z2 = p.alloc(&[0.0; 4]);
        assert_eq!(z1, z2);
        // -0.0 is a different bit pattern: must NOT dedup into the zero page
        let nz = p.alloc(&[-0.0, 0.0, 0.0, 0.0]);
        assert_ne!(nz, z1, "-0.0 must not be conflated with +0.0");
        let s = p.stats();
        assert_eq!(s.pages_resident, 3);
        assert_eq!(s.pages_shared, 2);
        assert_eq!(s.pages_zero, 1);
        assert_eq!(s.dedup_hits, 2);
        // zero page stores no payload: only the f32 + the -0.0 page cost RAM
        assert_eq!(s.ram_bytes, 2 * 16);
        // drain
        for id in [a, b, z1, z2, nz] {
            p.free(id);
        }
        let s = p.stats();
        assert_eq!((s.pages_resident, s.ram_bytes), (0, 0));
    }

    #[test]
    fn update_is_copy_on_write() {
        let p = KvPool::with_opts(0, 16, None, KvQuant::None);
        let a = p.alloc(&[1.0, 2.0]);
        p.share(a); // two logical owners
        let same = p.update(a, &[1.0, 2.0]);
        assert_eq!(same, a, "byte-identical update keeps the page");
        let b = p.update(a, &[9.0, 2.0]);
        assert_ne!(b, a, "divergent write to a shared page must copy");
        assert_eq!(p.stats().cow_copies, 1);
        // original owner still reads the old content
        let mut buf = Vec::new();
        p.read_into(a, &mut buf).unwrap();
        assert_eq!(buf, vec![1.0, 2.0]);
        p.read_into(b, &mut buf).unwrap();
        assert_eq!(buf, vec![9.0, 2.0]);
        p.free(a);
        p.free(b);
        assert_eq!(p.stats().pages_resident, 0);
    }

    #[test]
    fn park_image_roundtrip_is_bit_exact() {
        let p = KvPool::with_opts(0, 16, None, KvQuant::None);
        let data: Vec<f32> = vec![1.5, -0.0, f32::NAN, 0.0, 2.5, 3.5, 0.0];
        let extra: Vec<f32> = vec![7.0, 8.0, 0.0];
        let ps = p.park_image(StateKind::Full, "s", 128, &data, &extra);
        assert_eq!(ps.image_len(), 10);
        assert_eq!(ps.pages.len(), 3, "10 elems at 4/page");
        let (d2, e2) = p.read_image(&ps).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&d2), bits(&data), "data must round-trip bit-exact");
        assert_eq!(bits(&e2), bits(&extra));
        p.free_state(&ps);
        assert_eq!(p.stats().pages_resident, 0);
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("specpv-pool-{tag}-{}", std::process::id()))
    }

    #[test]
    fn spill_roundtrip_and_corruption_is_a_clean_error() {
        let dir = tmp("spill");
        let _ = std::fs::remove_dir_all(&dir);
        let p = KvPool::with_opts(0, 16, Some(&dir), KvQuant::None);
        let data: Vec<f32> = (0..9).map(|i| i as f32 * 0.5 - 1.0).collect();
        let ps = p.park_image(StateKind::Full, "s", 128, &data, &[]);
        p.park_cold(std::slice::from_ref(&ps)).unwrap();
        let st = p.stats();
        assert!(st.spills >= 2, "non-zero pages must spill: {st:?}");
        assert!(st.disk_bytes > 0);
        // read-through (no promote) is exact for f32 spills
        let (d2, _) = p.read_image(&ps).unwrap();
        assert_eq!(d2, data);
        // promote brings pages back; a truncated file is an error, not a panic
        p.promote(std::slice::from_ref(&ps)).unwrap();
        p.park_cold(std::slice::from_ref(&ps)).unwrap();
        for f in std::fs::read_dir(&dir).unwrap() {
            let path = f.unwrap().path();
            std::fs::write(&path, b"xx").unwrap(); // corrupt every spill file
        }
        let err = p.promote(std::slice::from_ref(&ps)).unwrap_err();
        assert!(format!("{err:#}").contains("spill"), "unexpected error: {err:#}");
        assert!(p.stats().swap_faults >= 1);
        p.free_state(&ps);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drain_racing_prefetch_leaves_nothing_parked() {
        let dir = tmp("drainrace");
        let _ = std::fs::remove_dir_all(&dir);
        let p = KvPool::with_opts(0, 16, Some(&dir), KvQuant::None);
        // spill, kick off an async prefetch, then free immediately while
        // the prefetch may still be in flight — pages and spill files
        // must fully drain, no blob parked by a late prefetch
        for i in 0..30 {
            let data: Vec<f32> = (0..9).map(|j| (i * 16 + j) as f32 + 0.5).collect();
            let ps = p.park_image(StateKind::Full, "s", 128, &data, &[]);
            p.park_cold(std::slice::from_ref(&ps)).unwrap();
            p.prefetch(std::slice::from_ref(&ps));
            p.free_state(&ps);
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
        let s = p.stats();
        assert_eq!(
            (s.pages_resident, s.ram_bytes, s.disk_bytes),
            (0, 0, 0),
            "pool must drain to zero: {s:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_failpoint_fails_spill_reads_cleanly() {
        let dir = tmp("failpoint");
        let _ = std::fs::remove_dir_all(&dir);
        let p = KvPool::with_opts(0, 16, Some(&dir), KvQuant::None);
        let data: Vec<f32> = (0..9).map(|i| i as f32 + 1.0).collect();
        let ps = p.park_image(StateKind::Full, "s", 128, &data, &[]);
        p.park_cold(std::slice::from_ref(&ps)).unwrap();
        p.set_corrupt_faults(1.0, 7);
        let err = p.promote(std::slice::from_ref(&ps)).unwrap_err();
        assert!(
            format!("{err:#}").contains("failpoint"),
            "unexpected error: {err:#}"
        );
        assert!(p.stats().swap_faults >= 1);
        // disarm: the on-disk blobs were never touched, so promote succeeds
        p.set_corrupt_faults(0.0, 7);
        p.promote(std::slice::from_ref(&ps)).unwrap();
        let (d2, _) = p.read_image(&ps).unwrap();
        assert_eq!(d2, data);
        p.free_state(&ps);
        assert_eq!(p.stats().pages_resident, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn int8_cold_pages_shrink_and_stay_within_tolerance() {
        let p = KvPool::with_opts(0, 64, None, KvQuant::Int8);
        let data: Vec<f32> = (0..40).map(|i| (i as f32 * 0.37).sin()).collect();
        let ps = p.park_image(StateKind::Full, "s", 128, &data, &[]);
        let hot = p.stats().ram_bytes;
        p.park_cold(std::slice::from_ref(&ps)).unwrap();
        let cold = p.stats().ram_bytes;
        assert!(cold * 3 < hot, "int8 must shrink RAM ~4x: {hot} -> {cold}");
        assert!(p.stats().quant_pages >= 2);
        let (d2, _) = p.read_image(&ps).unwrap();
        let worst = data
            .iter()
            .zip(&d2)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(worst <= 1.0 / 127.0 + 1e-6, "int8 tolerance blown: {worst}");
        p.free_state(&ps);
    }
}
