//! Minimal property-testing framework (the `proptest` crate is not
//! available in the offline vendor set).
//!
//! Usage (no_run: doctest binaries don't inherit the cargo-config rpath
//! to libxla_extension.so in this offline environment):
//! ```no_run
//! use specpv::util::proptest::Prop;
//! Prop::new("sorted stays sorted", 200).run(|g| {
//!     let n = g.usize_in(0, 50);
//!     let mut v: Vec<u32> = (0..n).map(|_| g.u32()).collect();
//!     v.sort();
//!     for w in v.windows(2) { assert!(w[0] <= w[1]); }
//! });
//! ```
//! On failure the seed of the failing case is printed so it can be
//! replayed with `Prop::replay`.

use super::rng::Rng;

/// Case generator handed to each property iteration.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u64() as u32
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f64() as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }
}

/// A named property with an iteration budget.
pub struct Prop {
    name: &'static str,
    cases: u64,
    base_seed: u64,
}

impl Prop {
    pub fn new(name: &'static str, cases: u64) -> Self {
        // stable per-name base seed so failures are reproducible run-to-run
        let mut h = 0xcbf29ce484222325u64;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Prop { name, cases, base_seed: h }
    }

    /// Run the property for `cases` generated inputs; panic (with the
    /// failing seed) on the first failure.
    pub fn run<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(&self, f: F) {
        for i in 0..self.cases {
            let seed = self.base_seed.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
            let result = std::panic::catch_unwind(|| {
                let mut g = Gen::new(seed);
                f(&mut g);
            });
            if let Err(e) = result {
                eprintln!(
                    "property '{}' failed at case {i} (replay seed {seed:#x})",
                    self.name
                );
                std::panic::resume_unwind(e);
            }
        }
    }

    /// Replay a single failing seed printed by `run`.
    pub fn replay<F: FnOnce(&mut Gen)>(seed: u64, f: F) {
        let mut g = Gen::new(seed);
        f(&mut g);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_ranges() {
        Prop::new("usize_in bounds", 300).run(|g| {
            let lo = g.usize_in(0, 10);
            let hi = lo + g.usize_in(0, 10);
            let x = g.usize_in(lo, hi);
            assert!(x >= lo && x <= hi);
        });
    }

    #[test]
    fn deterministic_base_seed() {
        let a = Prop::new("same name", 1).base_seed;
        let b = Prop::new("same name", 1).base_seed;
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        Prop::new("always fails", 5).run(|_| panic!("boom"));
    }
}
