//! Small substrates: RNG, timing, statistics, threading, property-testing.
//!
//! The offline build environment has no `rand`, `criterion`, `rayon` or
//! `proptest` crates, so the pieces of them this project needs are
//! implemented here (and double as paper-faithful determinism: the corpus
//! generators must match `python/compile/data.py` bit-for-bit, and the
//! thread pool's chunked parallel-for keeps kernel results byte-identical
//! at any thread count).

pub mod failpoint;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;

use std::time::Instant;

/// Wall-clock stopwatch with split support, used by the engines to
/// attribute time to draft / verify / overhead phases (paper Fig. 1).
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn total(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous `lap()` (or construction).
    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }
}

/// Format seconds human-readably for logs and tables.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let mut sw = Stopwatch::new();
        let a = sw.lap();
        let b = sw.lap();
        assert!(a >= 0.0 && b >= 0.0);
        assert!(sw.total() >= a + b - 1e-9);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(0.5e-3).ends_with("us"));
        assert!(fmt_secs(0.5).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }
}
