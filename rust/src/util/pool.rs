//! Hand-rolled scoped thread pool for the reference backend's kernels
//! (rayon is not in the offline vendor set).
//!
//! Design constraints, in priority order:
//!
//! 1. **Determinism**: [`Pool::run`] executes `f(0)…f(chunks-1)` where
//!    every chunk writes a *disjoint* part of the output and no chunk
//!    reads another chunk's output. Because no floating-point reduction
//!    ever crosses a chunk boundary, results are byte-identical at any
//!    thread count (including 1) and under any scheduling order.
//! 2. **No per-call spawn cost**: workers are persistent and block on a
//!    condvar; a `run` call posts one broadcast job per helper and the
//!    calling thread participates in the chunk loop itself, so a pool of
//!    size 1 (or a tiny job) degenerates to a plain serial loop.
//! 3. **No new dependencies**: `std` only.
//!
//! The default pool size comes from the `SPECPV_THREADS` environment
//! variable, falling back to `available_parallelism` capped at 8 (the
//! reference geometry is small; more threads only add sync overhead).
//!
//! Safety model: `run` erases the closure's lifetime to move it across
//! threads, and is sound because it blocks on a completion latch before
//! returning — no worker can observe the closure (or anything it
//! borrows) after `run` returns. A panicking chunk is caught on the
//! worker, recorded, and re-raised on the calling thread once every
//! chunk finished, so the latch always completes.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

/// A persistent pool of `threads - 1` workers; the caller of [`Pool::run`]
/// is always the remaining participant.
pub struct Pool {
    inner: Arc<Inner>,
    threads: usize,
    workers: Vec<thread::JoinHandle<()>>,
}

struct Inner {
    q: Mutex<Queue>,
    cv: Condvar,
}

struct Queue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Type-erased pointer to the on-stack [`RunCtx`] of an active `run`
/// call. Valid for the duration of that call (the latch guarantees it).
struct Job(*const ());

// SAFETY: the pointee is a RunCtx pinned on the stack of a `run` call
// that blocks until every job referencing it has counted down.
unsafe impl Send for Job {}

/// Shared state of one `run` call: the chunk cursor, the closure and the
/// completion latch the caller blocks on.
struct RunCtx<'a> {
    f: &'a (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    n: usize,
    latch: Latch,
    /// first caught panic payload, re-raised on the calling thread so
    /// the original assertion message/location survives the pool hop
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl RunCtx<'_> {
    /// Claim-and-run chunks until the cursor runs out.
    fn drive(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            let f = self.f;
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
        }
    }
}

/// Count-down latch (Mutex + Condvar; `std::sync::Barrier` cannot express
/// "wait for k helpers that may be busy elsewhere").
struct Latch {
    left: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { left: Mutex::new(n), cv: Condvar::new() }
    }

    fn count_down(&self) {
        let mut left = self.left.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = self.left.lock().unwrap();
        while *left > 0 {
            left = self.cv.wait(left).unwrap();
        }
    }
}

fn worker(inner: Arc<Inner>) {
    loop {
        let job = {
            let mut q = inner.q.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = inner.cv.wait(q).unwrap();
            }
        };
        // SAFETY: the posting `run` call blocks on ctx.latch until this
        // count_down, so ctx outlives every access here.
        let ctx = unsafe { &*(job.0 as *const RunCtx) };
        ctx.drive();
        ctx.latch.count_down();
    }
}

impl Pool {
    /// Pool with `threads` total participants (min 1). `threads - 1`
    /// worker threads are spawned; the `run` caller is the last one.
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            q: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|w| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("specpv-pool-{w}"))
                    .spawn(move || worker(inner))
                    .expect("spawn pool worker")
            })
            .collect();
        Pool { inner, threads, workers }
    }

    /// Total participants (workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0)…f(chunks-1)` across the pool and block until all chunks
    /// completed. Chunks must be independent (each writes disjoint data),
    /// which is what keeps results identical at any thread count.
    ///
    /// Panics (on the calling thread) if any chunk panicked.
    pub fn run(&self, chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        if chunks == 0 {
            return;
        }
        if self.threads == 1 || chunks == 1 {
            for i in 0..chunks {
                f(i);
            }
            return;
        }
        let helpers = (self.threads - 1).min(chunks - 1);
        let ctx = RunCtx {
            f,
            next: AtomicUsize::new(0),
            n: chunks,
            latch: Latch::new(helpers),
            panic: Mutex::new(None),
        };
        let job_ptr = &ctx as *const RunCtx as *const ();
        {
            let mut q = self.inner.q.lock().unwrap();
            for _ in 0..helpers {
                q.jobs.push_back(Job(job_ptr));
            }
        }
        self.inner.cv.notify_all();
        ctx.drive();
        ctx.latch.wait();
        if let Some(payload) = ctx.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut q = self.inner.q.lock().unwrap();
            q.shutdown = true;
        }
        self.inner.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Thread count for the process-wide pool: `SPECPV_THREADS` override, else
/// `available_parallelism` capped at 8.
pub fn default_threads() -> usize {
    match std::env::var("SPECPV_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) if n >= 1 => n.min(64),
        _ => thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8),
    }
}

/// Effective pool width for a configured override (the `threads` config
/// key / `--threads` flag, mirroring the `SPECPV_THREADS` env override):
/// an explicit `n >= 1` wins, 0 falls back to [`default_threads`].
pub fn resolve_threads(cfg_threads: usize) -> usize {
    if cfg_threads >= 1 {
        cfg_threads.min(64)
    } else {
        default_threads()
    }
}

/// Process-wide shared pool (kernels are tiny at the reference geometry;
/// one pool amortizes worker spawn across every backend instance).
pub fn global() -> &'static Arc<Pool> {
    static GLOBAL: OnceLock<Arc<Pool>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(Pool::new(default_threads())))
}

/// Split `n` items into `chunks` near-equal contiguous ranges; returns
/// the half-open range of chunk `c`. Deterministic in (n, chunks, c).
pub fn split_range(n: usize, chunks: usize, c: usize) -> (usize, usize) {
    let base = n / chunks;
    let rem = n % chunks;
    let start = c * base + c.min(rem);
    let len = base + usize::from(c < rem);
    (start, (start + len).min(n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn split_range_covers_everything() {
        for n in [0usize, 1, 5, 16, 17, 100] {
            for chunks in [1usize, 2, 3, 7, 16] {
                let mut covered = 0;
                let mut prev_end = 0;
                for c in 0..chunks {
                    let (a, b) = split_range(n, chunks, c);
                    assert_eq!(a, prev_end, "ranges must be contiguous");
                    assert!(b >= a);
                    covered += b - a;
                    prev_end = b;
                }
                assert_eq!(covered, n, "n={n} chunks={chunks}");
            }
        }
    }

    #[test]
    fn run_executes_every_chunk_once() {
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let hits: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
            pool.run(37, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i} at {threads} threads");
            }
        }
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin()).collect();
        let pool = Pool::new(4);
        // per-chunk partial sums into disjoint slots, combined in fixed order
        let chunks = 8;
        let mut partial = vec![0f64; chunks];
        {
            let slots: Vec<Mutex<f64>> = (0..chunks).map(|_| Mutex::new(0.0)).collect();
            pool.run(chunks, &|c| {
                let (a, b) = split_range(xs.len(), chunks, c);
                *slots[c].lock().unwrap() = xs[a..b].iter().sum::<f64>();
            });
            for (p, s) in partial.iter_mut().zip(&slots) {
                *p = *s.lock().unwrap();
            }
        }
        let serial: f64 = (0..chunks)
            .map(|c| {
                let (a, b) = split_range(xs.len(), chunks, c);
                xs[a..b].iter().sum::<f64>()
            })
            .sum();
        assert_eq!(partial.iter().sum::<f64>(), serial);
    }

    #[test]
    fn chunk_panic_propagates_without_deadlock() {
        let pool = Pool::new(3);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, &|i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must surface on the caller");
        // pool still usable afterwards
        let n = AtomicU64::new(0);
        pool.run(8, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }
}
