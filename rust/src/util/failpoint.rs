//! Fault-injection failpoints (DESIGN.md §15).
//!
//! A [`FaultSpec`] is parsed from the `faults` config key (`--faults`
//! flag) and threaded — always compiled in, config-gated, off by
//! default — through the places failures actually happen in production:
//! backend dispatch (`backend_err_rate`), the swap tier's spill decode
//! path (`swap_corrupt_rate`), and the shard device loops (`shard_panic`
//! and `slow_op_ms`). The grammar is a comma-separated key list:
//!
//! ```text
//! shard_panic@step=40,backend_err_rate=0.01,swap_corrupt_rate=0.05,slow_op_ms=200
//! ```
//!
//! * `shard_panic@step=N` — panic the shard loop after it has routed N
//!   step events (one-shot per shard: a restarted shard does not
//!   re-fire, which is what lets recovery tests converge).
//! * `backend_err_rate=P` — each scheduler step fails with probability
//!   `P` ("injected backend error"); the request terminates `ok:false`
//!   and a retrying client resubmits it.
//! * `swap_corrupt_rate=P` — each spill read-back fails with
//!   probability `P`, exercising the recoverable `SwapFault`
//!   re-queue-and-replay path.
//! * `slow_op_ms=T` — one-shot `T` ms stall inside the shard loop while
//!   it is marked busy, tripping the heartbeat wedge detector.
//! * `seed=S` — seed for the probabilistic injections (default 1).
//!
//! Probabilistic rates draw from a dedicated [`crate::util::rng::Rng`]
//! stream so injection never perturbs generation randomness.

use anyhow::{bail, Result};

/// Parsed `faults` spec. `Default` is everything off.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSpec {
    /// panic the shard loop once, after routing this many step events
    pub shard_panic_step: Option<u64>,
    /// probability a scheduler step fails with an injected backend error
    pub backend_err_rate: f64,
    /// probability a spill read-back reports corruption
    pub swap_corrupt_rate: f64,
    /// one-shot busy stall in the shard loop, milliseconds
    pub slow_op_ms: u64,
    /// seed for the probabilistic injections
    pub seed: u64,
}

impl FaultSpec {
    /// Parse the comma-separated failpoint grammar. Empty input is the
    /// all-off spec; unknown keys and malformed values are errors so a
    /// typo in `--faults` cannot silently disable a chaos run.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec { seed: 1, ..FaultSpec::default() };
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            match part.split_once('=') {
                Some(("shard_panic@step", v)) => {
                    spec.shard_panic_step = Some(parse_u64("shard_panic@step", v)?);
                }
                Some(("backend_err_rate", v)) => {
                    spec.backend_err_rate = parse_rate("backend_err_rate", v)?;
                }
                Some(("swap_corrupt_rate", v)) => {
                    spec.swap_corrupt_rate = parse_rate("swap_corrupt_rate", v)?;
                }
                Some(("slow_op_ms", v)) => {
                    spec.slow_op_ms = parse_u64("slow_op_ms", v)?;
                }
                Some(("seed", v)) => spec.seed = parse_u64("seed", v)?,
                _ => bail!("unknown failpoint '{part}'"),
            }
        }
        Ok(spec)
    }

    /// True when no failpoint is armed (the production fast path).
    pub fn is_off(&self) -> bool {
        self.shard_panic_step.is_none()
            && self.backend_err_rate == 0.0
            && self.swap_corrupt_rate == 0.0
            && self.slow_op_ms == 0
    }
}

fn parse_u64(key: &str, v: &str) -> Result<u64> {
    v.parse::<u64>()
        .map_err(|_| anyhow::anyhow!("failpoint {key}: bad integer '{v}'"))
}

fn parse_rate(key: &str, v: &str) -> Result<f64> {
    let p: f64 = v
        .parse()
        .map_err(|_| anyhow::anyhow!("failpoint {key}: bad rate '{v}'"))?;
    if !(0.0..=1.0).contains(&p) {
        bail!("failpoint {key}: rate {p} outside [0, 1]");
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_off() {
        let s = FaultSpec::parse("").unwrap();
        assert!(s.is_off());
        assert!(FaultSpec::default().is_off());
    }

    #[test]
    fn full_grammar() {
        let s = FaultSpec::parse(
            "shard_panic@step=40,backend_err_rate=0.01,swap_corrupt_rate=0.05,slow_op_ms=200",
        )
        .unwrap();
        assert_eq!(s.shard_panic_step, Some(40));
        assert!((s.backend_err_rate - 0.01).abs() < 1e-12);
        assert!((s.swap_corrupt_rate - 0.05).abs() < 1e-12);
        assert_eq!(s.slow_op_ms, 200);
        assert_eq!(s.seed, 1);
        assert!(!s.is_off());
    }

    #[test]
    fn whitespace_and_seed() {
        let s = FaultSpec::parse(" slow_op_ms=5 , seed=9 ").unwrap();
        assert_eq!(s.slow_op_ms, 5);
        assert_eq!(s.seed, 9);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(FaultSpec::parse("nope=1").is_err());
        assert!(FaultSpec::parse("slow_op_ms").is_err());
        assert!(FaultSpec::parse("backend_err_rate=2.0").is_err());
        assert!(FaultSpec::parse("shard_panic@step=abc").is_err());
    }
}
