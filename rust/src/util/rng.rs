//! xorshift64* PRNG — the exact mirror of `python/compile/data.py`'s
//! `XorShift64Star`, so rust-side workloads and python-side training data
//! come from the same deterministic stream (golden-file parity is tested
//! in `corpus::tests`).

/// xorshift64* with the multiply-shift range reduction used on the python
/// side (`((x >> 11) * n) >> 53`), which is bias-free for n < 2^53 and —
/// unlike modulo — identical across languages without bigint tricks.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed | 1 }
    }

    /// The raw generator state, for checkpointing a stream mid-flight.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a generator at an exact saved state (no `| 1` adjustment —
    /// a state captured by [`Rng::state`] is already valid), so a
    /// restored session continues the identical sample stream.
    pub fn from_state(state: u64) -> Self {
        Rng { state }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        (((self.next_u64() >> 11) as u128 * n as u128) >> 53) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller (used by failure-injection tests and
    /// synthetic latency jitter; not needed for python parity).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for n in [1usize, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn f64_in_unit() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "bucket count {c}");
        }
    }

    /// A stream restored from a mid-flight state continues identically —
    /// the contract session checkpoint/failover relies on.
    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(42);
        for _ in 0..10 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    /// Golden values pinned against the python implementation
    /// (`XorShift64Star(12345)`), guaranteeing cross-language parity.
    #[test]
    fn python_parity_golden() {
        let mut r = Rng::new(12345);
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        // python: r = XorShift64Star(12345); [r.next_u64() for _ in range(4)]
        assert_eq!(
            got,
            vec![
                10977518812293740004,
                13893246733018840292,
                1412386850724336324,
                13578198927181985541,
            ]
        );
    }
}
