//! Running statistics and percentile summaries for the bench harness and
//! the serving metrics registry.

/// Online mean/min/max/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Running {
    pub n: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        if self.n == 1 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile summary over a stored sample set (exact, for the modest
/// sample counts the harness produces).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    /// Nearest-rank percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Throughput derivation for duration samples: `units` of work per
    /// mean sample (e.g. tokens per second when the samples are seconds
    /// per generation of `units` tokens). 0.0 on empty/degenerate input.
    pub fn per_sec(&self, units: f64) -> f64 {
        let m = self.mean();
        if m <= 0.0 {
            0.0
        } else {
            units / m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_mean_var() {
        let mut r = Running::default();
        for x in [1.0, 2.0, 3.0, 4.0] {
            r.push(x);
        }
        assert!((r.mean - 2.5).abs() < 1e-12);
        assert!((r.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 4.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::default();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.p50() - 50.0).abs() <= 1.0); // nearest-rank rounding
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!(s.p99() >= 98.0);
    }

    #[test]
    fn empty_safe() {
        let s = Samples::default();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p50(), 0.0);
        assert_eq!(s.p95(), 0.0);
        assert_eq!(s.per_sec(100.0), 0.0);
    }

    #[test]
    fn per_sec_derivation() {
        let mut s = Samples::default();
        s.push(0.5);
        s.push(1.5); // mean 1.0s per batch
        assert!((s.per_sec(32.0) - 32.0).abs() < 1e-12);
    }
}
