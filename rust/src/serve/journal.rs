//! Write-ahead request journal (DESIGN.md §17): the durable record of
//! every accepted `generate` line and every delivered-token watermark,
//! from which a cold restart rebuilds the unfinished session set.
//!
//! On-disk layout: an 8-byte header (magic `SPVJ` + version) followed
//! by appended, length-prefixed, FNV-checksummed records framing JSON
//! payloads — `[len u32][crc u64][payload]`. Three record kinds:
//!
//! * `accept` — the parsed request (prompt tokens, options, the
//!   assigned wire id, priority), written *before* the ack leaves;
//! * `progress` — the delivered-token watermark for a gid, written only
//!   after the line bytes were flushed to the client socket (never on
//!   emit — tokens sitting in the outbox at crash time must replay);
//! * `done` — the final line for a gid was flushed; the session no
//!   longer needs recovery.
//!
//! Replay ([`scan_bytes`]) folds records in order and is idempotent and
//! prefix-closed: any prefix of a journal is a consistent state, and
//! replaying records twice changes nothing (accepts of done/known gids
//! are ignored, watermarks max-merge). A torn or corrupt tail —
//! whatever a crash left after the last valid record — is counted and
//! truncated on the next open, never fatal.

use std::collections::{BTreeMap, BTreeSet};
use std::fs::{File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{EngineKind, JournalFsync};
use crate::engine::GenRequest;
use crate::json::Json;
use crate::kvstore::pool::hash_bytes;
use crate::kvstore::swap::purge_temps;

/// Journal file name under `journal_dir`.
pub const JOURNAL_FILE: &str = "journal.wal";
/// Checkpoint-store subdirectory under `journal_dir`.
pub const CKPT_SUBDIR: &str = "ckpt";

const JOURNAL_MAGIC: u32 = 0x5350_564A; // "SPVJ"
const JOURNAL_VERSION: u32 = 1;
const HEADER_LEN: u64 = 8;
/// Sanity bound on one record's payload (a prompt is at most
/// `max_prompt` tokens; anything larger is corruption, not data).
const MAX_RECORD: u32 = 64 << 20;

/// One unfinished request rebuilt from the journal.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayedRequest {
    pub gid: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
    pub engine: Option<EngineKind>,
    pub auto: bool,
    pub stream: bool,
    pub deadline_secs: Option<f64>,
    pub priority: i32,
    /// delivered-token watermark: absolute tokens whose delta lines
    /// were flushed to the client before the crash
    pub delivered: usize,
}

/// The folded state of a journal scan.
#[derive(Debug, Default)]
pub struct Replay {
    /// unfinished requests by gid (accepted, no `done` record)
    pub requests: BTreeMap<u64, ReplayedRequest>,
    /// gids whose final line was flushed (their accepts are ignored on
    /// a re-replay — this is what makes the fold idempotent)
    pub done: BTreeSet<u64>,
    /// valid records folded
    pub records: u64,
    /// torn/corrupt tail records dropped (0 or 1 per scan)
    pub torn: u64,
    /// smallest gid the restarted front end may assign (the journaled
    /// id space stays monotone across incarnations)
    pub next_gid: u64,
    /// byte offset of the last valid record's end; the file is
    /// truncated here on open
    pub valid_len: u64,
}

impl Replay {
    /// Fold one record payload into the replay state.
    pub fn fold(&mut self, j: &Json) {
        self.records += 1;
        let gid = j.get("gid").and_then(|x| x.as_i64()).unwrap_or(-1);
        if gid < 0 {
            return;
        }
        let gid = gid as u64;
        self.next_gid = self.next_gid.max(gid + 1);
        match j.get("op").and_then(|x| x.as_str()) {
            Some("accept") => {
                if self.done.contains(&gid) || self.requests.contains_key(&gid) {
                    return;
                }
                let prompt: Vec<u32> = j
                    .get("prompt")
                    .and_then(|x| x.as_arr())
                    .map(|a| a.iter().filter_map(|t| t.as_f64()).map(|t| t as u32).collect())
                    .unwrap_or_default();
                let engine = j
                    .get("engine")
                    .and_then(|x| x.as_str())
                    .and_then(|s| s.parse::<EngineKind>().ok());
                self.requests.insert(
                    gid,
                    ReplayedRequest {
                        gid,
                        prompt,
                        max_new: j.get("max_new").and_then(|x| x.as_usize()).unwrap_or(0),
                        temperature: j
                            .get("temperature")
                            .and_then(|x| x.as_f64())
                            .unwrap_or(0.0) as f32,
                        seed: j
                            .get("seed")
                            .and_then(|x| x.as_str())
                            .and_then(|s| s.parse().ok())
                            .unwrap_or(0),
                        engine,
                        auto: j.get("auto").and_then(|x| x.as_bool()).unwrap_or(false),
                        stream: j.get("stream").and_then(|x| x.as_bool()).unwrap_or(false),
                        deadline_secs: j.get("deadline_s").and_then(|x| x.as_f64()),
                        priority: j.get("priority").and_then(|x| x.as_i64()).unwrap_or(0)
                            as i32,
                        delivered: 0,
                    },
                );
            }
            Some("progress") => {
                if let Some(r) = self.requests.get_mut(&gid) {
                    let tokens = j.get("tokens").and_then(|x| x.as_usize()).unwrap_or(0);
                    r.delivered = r.delivered.max(tokens);
                }
            }
            Some("done") => {
                self.requests.remove(&gid);
                self.done.insert(gid);
            }
            _ => {}
        }
    }
}

/// Frame one record payload: `[len u32][fnv crc u64][payload bytes]`.
pub fn frame(payload: &Json) -> Vec<u8> {
    let bytes = payload.to_string().into_bytes();
    let mut out = Vec::with_capacity(12 + bytes.len());
    out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&hash_bytes(&bytes).to_le_bytes());
    out.extend_from_slice(&bytes);
    out
}

/// The `accept` record for a newly admitted generate op.
pub fn accept_record(
    gid: u64,
    gen: &GenRequest,
    engine: Option<EngineKind>,
    auto: bool,
    stream: bool,
    deadline_secs: Option<f64>,
    priority: i32,
) -> Json {
    let prompt: Vec<Json> = gen.prompt.iter().map(|&t| Json::from(t as f64)).collect();
    let mut j = Json::obj()
        .set("op", "accept")
        .set("gid", gid as i64)
        .set("prompt", Json::Arr(prompt))
        .set("max_new", gen.max_new)
        .set("temperature", gen.temperature as f64)
        .set("seed", format!("{}", gen.seed))
        .set("auto", auto)
        .set("stream", stream)
        .set("priority", priority as i64);
    if let Some(e) = engine {
        j = j.set("engine", e.to_string());
    }
    if let Some(d) = deadline_secs {
        j = j.set("deadline_s", d);
    }
    j
}

/// The `progress` record: `tokens` absolute tokens flushed for `gid`.
pub fn progress_record(gid: u64, tokens: usize) -> Json {
    Json::obj().set("op", "progress").set("gid", gid as i64).set("tokens", tokens)
}

/// The `done` record: gid's final line was flushed.
pub fn done_record(gid: u64) -> Json {
    Json::obj().set("op", "done").set("gid", gid as i64)
}

/// Scan raw journal bytes into a [`Replay`]. Stops at the first invalid
/// frame (short, oversized, checksum mismatch, or unparsable payload)
/// and counts the remainder as one torn record — a crash can tear at
/// most the final append.
pub fn scan_bytes(bytes: &[u8]) -> Replay {
    let mut rp = Replay::default();
    if bytes.is_empty() {
        return rp;
    }
    if bytes.len() < HEADER_LEN as usize
        || u32::from_le_bytes(bytes[0..4].try_into().unwrap()) != JOURNAL_MAGIC
        || u32::from_le_bytes(bytes[4..8].try_into().unwrap()) != JOURNAL_VERSION
    {
        rp.torn = 1;
        return rp;
    }
    let mut i = HEADER_LEN as usize;
    rp.valid_len = HEADER_LEN;
    while i < bytes.len() {
        if bytes.len() - i < 12 {
            rp.torn = 1;
            break;
        }
        let len = u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap());
        let crc = u64::from_le_bytes(bytes[i + 4..i + 12].try_into().unwrap());
        if len > MAX_RECORD || bytes.len() - i - 12 < len as usize {
            rp.torn = 1;
            break;
        }
        let payload = &bytes[i + 12..i + 12 + len as usize];
        if hash_bytes(payload) != crc {
            rp.torn = 1;
            break;
        }
        let Ok(j) = std::str::from_utf8(payload).map_err(anyhow::Error::from).and_then(|s| {
            Json::parse(s)
        }) else {
            rp.torn = 1;
            break;
        };
        rp.fold(&j);
        i += 12 + len as usize;
        rp.valid_len = i as u64;
    }
    rp
}

/// An open journal: appends framed records with the configured fsync
/// policy.
pub struct Journal {
    file: File,
    policy: JournalFsync,
    last_sync: Instant,
    /// records appended this incarnation
    pub appended: u64,
}

impl Journal {
    /// Open (creating if needed) the journal under `dir` and replay it:
    /// returns the open append handle positioned after the last valid
    /// record — a torn tail is truncated here — plus the folded
    /// [`Replay`]. Orphaned temp files under `dir` are purged.
    pub fn open(dir: &Path, policy: JournalFsync) -> Result<(Journal, Replay)> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating journal dir {dir:?}"))?;
        purge_temps(dir);
        let path = dir.join(JOURNAL_FILE);
        let bytes = std::fs::read(&path).unwrap_or_default();
        let replay = scan_bytes(&bytes);
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)
            .with_context(|| format!("opening journal {path:?}"))?;
        if replay.valid_len < bytes.len() as u64 {
            file.set_len(replay.valid_len.max(HEADER_LEN))
                .with_context(|| format!("truncating torn journal tail in {path:?}"))?;
        }
        if replay.valid_len < HEADER_LEN {
            file.set_len(0)?;
            file.seek(SeekFrom::Start(0))?;
            let mut header = Vec::with_capacity(HEADER_LEN as usize);
            header.extend_from_slice(&JOURNAL_MAGIC.to_le_bytes());
            header.extend_from_slice(&JOURNAL_VERSION.to_le_bytes());
            file.write_all(&header)?;
            file.sync_data()?;
        } else {
            file.seek(SeekFrom::Start(replay.valid_len))?;
        }
        Ok((Journal { file, policy, last_sync: Instant::now(), appended: 0 }, replay))
    }

    /// Append one record payload, syncing per the fsync policy.
    pub fn append(&mut self, payload: &Json) -> Result<()> {
        self.file.write_all(&frame(payload)).context("journal append")?;
        self.appended += 1;
        match self.policy {
            JournalFsync::Always => self.file.sync_data().context("journal fsync")?,
            JournalFsync::IntervalMs(ms) => {
                if self.last_sync.elapsed().as_millis() as u64 >= ms {
                    self.file.sync_data().context("journal fsync")?;
                    self.last_sync = Instant::now();
                }
            }
            JournalFsync::Never => {}
        }
        Ok(())
    }

    /// Force a sync regardless of policy (graceful shutdown).
    pub fn sync(&mut self) {
        let _ = self.file.sync_data();
    }

    /// Truncate back to the header: every session reached its final
    /// line, so a clean restart replays nothing and reports
    /// `recovered: 0`.
    pub fn mark_clean(&mut self) -> Result<()> {
        self.file.set_len(HEADER_LEN).context("journal mark_clean")?;
        self.file.seek(SeekFrom::Start(HEADER_LEN))?;
        self.file.sync_data().context("journal fsync")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("specpv-journal-{tag}-{}", std::process::id()))
    }

    fn gen(prompt: &[u32]) -> GenRequest {
        GenRequest { prompt: prompt.to_vec(), max_new: 8, temperature: 0.0, seed: 11 }
    }

    #[test]
    fn append_reopen_replays_requests_and_watermarks() {
        let dir = tmp("rt");
        let _ = std::fs::remove_dir_all(&dir);
        {
            let (mut j, rp) = Journal::open(&dir, JournalFsync::Always).unwrap();
            assert_eq!(rp.records, 0);
            j.append(&accept_record(0, &gen(&[1, 2]), None, false, true, None, 0)).unwrap();
            j.append(&accept_record(1, &gen(&[3]), Some(EngineKind::Autoregressive), false, true, Some(2.5), 7))
                .unwrap();
            j.append(&progress_record(0, 3)).unwrap();
            j.append(&progress_record(0, 5)).unwrap();
            j.append(&done_record(1)).unwrap();
        }
        let (_j, rp) = Journal::open(&dir, JournalFsync::Always).unwrap();
        assert_eq!(rp.records, 5);
        assert_eq!(rp.torn, 0);
        assert_eq!(rp.next_gid, 2);
        assert_eq!(rp.requests.len(), 1, "gid 1 is done, gid 0 unfinished");
        let r = &rp.requests[&0];
        assert_eq!((r.prompt.as_slice(), r.delivered, r.seed), (&[1u32, 2][..], 5, 11));
        assert!(rp.done.contains(&1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_truncated_on_open_not_fatal() {
        let dir = tmp("torn");
        let _ = std::fs::remove_dir_all(&dir);
        {
            let (mut j, _) = Journal::open(&dir, JournalFsync::Always).unwrap();
            j.append(&accept_record(0, &gen(&[9]), None, false, true, None, 0)).unwrap();
        }
        let path = dir.join(JOURNAL_FILE);
        let mut bytes = std::fs::read(&path).unwrap();
        // a torn append: half a record at the tail
        bytes.extend_from_slice(&frame(&progress_record(0, 4))[..7]);
        std::fs::write(&path, &bytes).unwrap();
        let (_j, rp) = Journal::open(&dir, JournalFsync::Always).unwrap();
        assert_eq!((rp.records, rp.torn), (1, 1));
        assert_eq!(rp.requests[&0].delivered, 0, "torn progress must not apply");
        // the truncation stuck: a re-open sees a clean file
        let (_j2, rp2) = Journal::open(&dir, JournalFsync::Always).unwrap();
        assert_eq!((rp2.records, rp2.torn), (1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mark_clean_empties_the_journal() {
        let dir = tmp("clean");
        let _ = std::fs::remove_dir_all(&dir);
        {
            let (mut j, _) = Journal::open(&dir, JournalFsync::Never).unwrap();
            j.append(&accept_record(0, &gen(&[1]), None, false, true, None, 0)).unwrap();
            j.mark_clean().unwrap();
        }
        let (_j, rp) = Journal::open(&dir, JournalFsync::Never).unwrap();
        assert_eq!((rp.records, rp.torn, rp.requests.len()), (0, 0, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
