//! Prefix-affinity router: places sessions on worker shards by a
//! rendezvous (highest-random-weight) hash of the prompt-prefix
//! fingerprint, with load-aware spill (DESIGN.md §14).
//!
//! The fingerprint reuses the `kvstore::prefix` rolling chunk-boundary
//! hash at the reference prefill chunk width, so two prompts sharing
//! their first cached chunk share a fingerprint — and therefore a home
//! shard, whose prefix cache already holds their pages. Prompts shorter
//! than one chunk fall back to a hash of all their tokens.

use crate::kvstore::prefix::{chunk_boundary_hashes, geom_hash};

/// Fingerprint chunk width, matching the reference backend's prefill
/// chunk — the granularity the prefix cache stores entries at, so
/// fingerprint-equal prompts are exactly the ones that can share a
/// cached prefix entry.
pub const FP_CHUNK: usize = 64;

/// The prompt-prefix fingerprint: the first chunk-boundary rolling hash
/// when the prompt spans at least one chunk, else a hash of the whole
/// prompt.
pub fn fingerprint(prompt: &[u32]) -> u64 {
    if let Some(&(_, h)) = chunk_boundary_hashes(prompt, FP_CHUNK).first() {
        return h;
    }
    let mut bytes = Vec::with_capacity(prompt.len() * 4);
    for &t in prompt {
        bytes.extend_from_slice(&t.to_le_bytes());
    }
    geom_hash(&[&bytes])
}

/// Routing decision for one session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// shard the session was placed on
    pub shard: usize,
    /// its prefix-affinity home shard
    pub home: usize,
}

/// Session placement + per-shard load accounting. Lives in the front
/// end; shards never see it.
pub struct Router {
    /// spill factor: leave the home shard only when
    /// `home_load + 1 > imbalance * (min_load + 1)`
    imbalance: f64,
    /// in-flight sessions per shard (submitted − terminal)
    load: Vec<usize>,
    /// sessions placed per shard (lifetime counter)
    placed: Vec<u64>,
    /// sessions spilled off their home shard by the imbalance rule (or
    /// re-homed off a down shard)
    routed_away: u64,
    /// shards excluded from placement (dead or mid-failover)
    down: Vec<bool>,
}

impl Router {
    pub fn new(shards: usize, imbalance: f64) -> Router {
        Router {
            imbalance: imbalance.max(1.0),
            load: vec![0; shards.max(1)],
            placed: vec![0; shards.max(1)],
            routed_away: 0,
            down: vec![false; shards.max(1)],
        }
    }

    pub fn shards(&self) -> usize {
        self.load.len()
    }

    /// Exclude (or re-include) a shard from placement. Down shards keep
    /// their load accounting — their in-flight sessions are re-homed by
    /// the front end's failover path, which decrements as it goes.
    pub fn set_down(&mut self, shard: usize, down: bool) {
        if let Some(d) = self.down.get_mut(shard) {
            *d = down;
        }
    }

    pub fn is_down(&self, shard: usize) -> bool {
        self.down.get(shard).copied().unwrap_or(false)
    }

    /// No shard can take a placement right now.
    pub fn all_down(&self) -> bool {
        self.down.iter().all(|&d| d)
    }

    /// The deterministic prefix-affinity home shard for a prompt:
    /// rendezvous hash of the fingerprint against each live shard index,
    /// so a given prefix maps to the same shard at a fixed shard count,
    /// reshuffles minimally when the count changes, and re-homes
    /// deterministically while its home shard is down.
    pub fn home(&self, prompt: &[u32]) -> usize {
        let fp = fingerprint(prompt);
        (0..self.load.len())
            .filter(|&s| !self.down[s])
            .max_by_key(|&s| {
                geom_hash(&[&fp.to_le_bytes(), &(s as u64).to_le_bytes()])
            })
            .unwrap_or(0)
    }

    /// The placement `place` would make, without committing it — the
    /// front end's overload check inspects the target shard's queue
    /// depth before deciding to admit or shed.
    pub fn peek(&self, prompt: &[u32]) -> Placement {
        let home = self.home(prompt);
        let min = (0..self.load.len())
            .filter(|&s| !self.down[s])
            .min_by_key(|&s| self.load[s])
            .unwrap_or(home);
        let spill = (self.load[home] + 1) as f64
            > self.imbalance * ((self.load[min] + 1) as f64);
        let shard = if spill { min } else { home };
        Placement { shard, home }
    }

    /// Commit a placement from `peek`: load + lifetime counters (a
    /// session landing off its home shard counts as routed away).
    pub fn commit(&mut self, p: Placement) {
        if p.shard != p.home {
            self.routed_away += 1;
        }
        self.load[p.shard] += 1;
        self.placed[p.shard] += 1;
    }

    /// Place a session: its home shard, unless the imbalance rule spills
    /// it to the least-loaded shard. Increments the chosen shard's load.
    pub fn place(&mut self, prompt: &[u32]) -> Placement {
        let p = self.peek(prompt);
        self.commit(p);
        p
    }

    /// A placed session reached a terminal state on `shard`.
    pub fn finished(&mut self, shard: usize) {
        if let Some(l) = self.load.get_mut(shard) {
            *l = l.saturating_sub(1);
        }
    }

    /// Current in-flight sessions on `shard`.
    pub fn load(&self, shard: usize) -> usize {
        self.load.get(shard).copied().unwrap_or(0)
    }

    /// Lifetime sessions placed on `shard`.
    pub fn placed(&self, shard: usize) -> u64 {
        self.placed.get(shard).copied().unwrap_or(0)
    }

    /// Lifetime sessions spilled off their home shard.
    pub fn routed_away(&self) -> u64 {
        self.routed_away
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompt(seed: u32, len: usize) -> Vec<u32> {
        (0..len as u32).map(|i| seed.wrapping_mul(31).wrapping_add(i) % 96 + 32).collect()
    }

    #[test]
    fn home_is_deterministic_across_instances() {
        let a = Router::new(4, 2.0);
        let b = Router::new(4, 2.0);
        for s in 0..32 {
            let p = prompt(s, 200);
            assert_eq!(a.home(&p), b.home(&p), "seed {s}");
            assert_eq!(a.home(&p), a.home(&p));
        }
    }

    #[test]
    fn shared_prefix_shares_home() {
        let r = Router::new(4, 2.0);
        let mut a = prompt(7, 200);
        let mut b = a.clone();
        // diverge after the first fingerprint chunk
        a.push(1);
        b.push(2);
        b.extend_from_slice(&[9, 9, 9]);
        assert_eq!(r.home(&a), r.home(&b), "same first chunk → same home");
    }

    #[test]
    fn short_prompts_route_and_spread() {
        let r = Router::new(4, 2.0);
        let mut seen = std::collections::BTreeSet::new();
        for s in 0..64 {
            let p = prompt(s, 3); // below one chunk → fallback fingerprint
            let h = r.home(&p);
            assert!(h < 4);
            seen.insert(h);
        }
        assert!(seen.len() > 1, "64 fingerprints all on one shard: {seen:?}");
    }

    #[test]
    fn spill_reroutes_under_imbalance_and_counts() {
        let mut r = Router::new(2, 1.0); // imbalance 1.0 → strict balance
        let p = prompt(3, 200);
        let home = r.home(&p);
        let first = r.place(&p);
        assert_eq!(first.shard, home, "empty router keeps affinity");
        // home now has load 1, the other shard 0 → the same prefix spills
        let second = r.place(&p);
        assert_eq!(second.home, home);
        assert_ne!(second.shard, home, "imbalance 1.0 must spill");
        assert_eq!(r.routed_away(), 1);
        assert_eq!(r.placed(home), 1);
        // finishing the home session restores affinity
        r.finished(home);
        let third = r.place(&p);
        assert_eq!(third.shard, home);
    }

    #[test]
    fn down_shards_are_excluded_and_rejoin() {
        let mut r = Router::new(3, 100.0);
        let p = prompt(11, 200);
        let home = r.home(&p);
        r.set_down(home, true);
        assert!(r.is_down(home));
        let rehomed = r.home(&p);
        assert_ne!(rehomed, home, "down shard must not be a home");
        // deterministic re-home: same prefix, same fallback shard
        assert_eq!(r.home(&p), rehomed);
        let placed = r.place(&p);
        assert_ne!(placed.shard, home);
        assert!(!r.all_down());
        r.set_down((home + 1) % 3, true);
        r.set_down((home + 2) % 3, true);
        assert!(r.all_down());
        // back up: affinity restored
        r.set_down(home, false);
        r.set_down((home + 1) % 3, false);
        r.set_down((home + 2) % 3, false);
        assert_eq!(r.home(&p), home);
    }

    #[test]
    fn peek_does_not_commit() {
        let mut r = Router::new(2, 2.0);
        let p = prompt(9, 200);
        let a = r.peek(&p);
        let b = r.peek(&p);
        assert_eq!(a, b, "peek must be pure");
        assert_eq!(r.load(a.shard), 0);
        r.commit(a);
        assert_eq!(r.load(a.shard), 1);
    }

    #[test]
    fn high_imbalance_keeps_affinity() {
        let mut r = Router::new(2, 100.0);
        let p = prompt(5, 200);
        let home = r.home(&p);
        for _ in 0..10 {
            assert_eq!(r.place(&p).shard, home);
        }
        assert_eq!(r.routed_away(), 0);
        assert_eq!(r.load(home), 10);
    }
}
