//! Wire-level protocol pieces shared by the front end and the shards:
//! request parsing, admin bodies, the cross-shard admin merge and line
//! framing. The line shapes here are the byte-level compatibility
//! contract with the original single-coordinator server (DESIGN.md §8).

use anyhow::{anyhow, Result};

use crate::config::EngineKind;
use crate::coordinator::Coordinator;
use crate::engine::GenRequest;
use crate::json::Json;
use crate::tokenizer;

/// Read-only admin subcommands (`{"op":"admin","cmd":...,"v":1}`). The
/// old flat `metrics`/`cache` op names parse to the same commands with
/// `legacy: true` and answer with a `"deprecated":true` marker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdminCmd {
    Metrics,
    Kv,
    Cache,
    /// per-shard dump: queue depth, active sessions, KV residency and
    /// routing counters (sharded serving)
    Shards,
}

impl AdminCmd {
    pub fn name(self) -> &'static str {
        match self {
            AdminCmd::Metrics => "metrics",
            AdminCmd::Kv => "kv",
            AdminCmd::Cache => "cache",
            AdminCmd::Shards => "shards",
        }
    }
}

/// Request-level defaults the front end needs to parse `generate` ops
/// without touching a coordinator.
#[derive(Debug, Clone, Copy)]
pub struct Defaults {
    pub max_new: usize,
    pub temperature: f32,
}

/// One parsed client operation.
pub enum Request {
    Generate {
        gen: GenRequest,
        engine: Option<EngineKind>,
        /// `"engine":"auto"`: the policy layer picks the engine per
        /// request (DESIGN.md §16)
        auto: bool,
        stream: bool,
        deadline_secs: Option<f64>,
        priority: i32,
    },
    Cancel { id: u64 },
    /// Reconnect to a journaled in-flight request after a server
    /// restart: replays the undelivered suffix of `id`'s output
    /// (DESIGN.md §17)
    GenerateRetry { id: u64 },
    Admin { cmd: AdminCmd, legacy: bool },
    Ping,
    Shutdown,
}

/// Parse one JSON line into a [`Request`]. Error messages are part of
/// the wire contract (clients see them verbatim in error lines).
pub fn parse_request(raw: &str, defaults: &Defaults) -> Result<Request> {
    let req = Json::parse(raw)?;
    let op = req.get("op").and_then(|x| x.as_str()).unwrap_or("generate");
    match op {
        "ping" => Ok(Request::Ping),
        "admin" => {
            let v = req.get("v").and_then(|x| x.as_i64()).unwrap_or(1);
            if v != 1 {
                return Err(anyhow!("unsupported admin version {v} (supported: 1)"));
            }
            let cmd = match req.get("cmd").and_then(|x| x.as_str()) {
                Some("metrics") => AdminCmd::Metrics,
                Some("kv") => AdminCmd::Kv,
                Some("cache") => AdminCmd::Cache,
                Some("shards") => AdminCmd::Shards,
                Some(other) => {
                    return Err(anyhow!(
                        "unknown admin cmd '{other}' (metrics|kv|cache|shards)"
                    ))
                }
                None => return Err(anyhow!("admin needs 'cmd'")),
            };
            Ok(Request::Admin { cmd, legacy: false })
        }
        // deprecated flat aliases for the admin subcommands
        "metrics" => Ok(Request::Admin { cmd: AdminCmd::Metrics, legacy: true }),
        "cache" => Ok(Request::Admin { cmd: AdminCmd::Cache, legacy: true }),
        "shutdown" => Ok(Request::Shutdown),
        "cancel" => {
            let id = req
                .get("id")
                .and_then(|x| x.as_i64())
                .ok_or_else(|| anyhow!("cancel needs 'id'"))? as u64;
            Ok(Request::Cancel { id })
        }
        "generate_retry" => {
            let id = req
                .get("id")
                .and_then(|x| x.as_i64())
                .ok_or_else(|| anyhow!("generate_retry needs 'id'"))? as u64;
            Ok(Request::GenerateRetry { id })
        }
        "generate" => {
            let prompt = req
                .get("prompt")
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow!("missing 'prompt'"))?;
            let max_new = req
                .get("max_new")
                .and_then(|x| x.as_usize())
                .unwrap_or(defaults.max_new);
            let temperature = req
                .get("temperature")
                .and_then(|x| x.as_f64())
                .unwrap_or(defaults.temperature as f64) as f32;
            let (engine, auto) = match req.get("engine").and_then(|x| x.as_str()) {
                Some("auto") => (None, true),
                Some(e) => (Some(e.parse()?), false),
                None => (None, false),
            };
            let seed = req.get("seed").and_then(|x| x.as_i64()).unwrap_or(0) as u64;
            let stream =
                req.get("stream").and_then(|x| x.as_bool()).unwrap_or(false);
            // timeout_ms (the documented spelling) takes precedence over
            // the older deadline_s; both land in the same scheduler
            // deadline
            let deadline_secs = req
                .get("timeout_ms")
                .and_then(|x| x.as_f64())
                .map(|ms| ms / 1000.0)
                .or_else(|| req.get("deadline_s").and_then(|x| x.as_f64()));
            let priority =
                req.get("priority").and_then(|x| x.as_i64()).unwrap_or(0) as i32;
            Ok(Request::Generate {
                gen: GenRequest {
                    prompt: tokenizer::encode(prompt),
                    max_new,
                    temperature,
                    seed,
                },
                engine,
                auto,
                stream,
                deadline_secs,
                priority,
            })
        }
        other => Err(anyhow!("unknown op '{other}'")),
    }
}

/// The `admin metrics` body: scheduler registry + backend counters.
pub fn metrics_body(coord: &mut Coordinator<'_>) -> Json {
    coord.sync_backend_counters();
    let reg = &coord.registry;
    let mut body = Json::obj()
        .set("ok", true)
        .set("summary", reg.summary())
        .set(
            "backend",
            if reg.backend.is_empty() { "scripted" } else { reg.backend.as_str() },
        )
        .set("executions", reg.executions as i64)
        .set("exec_secs", reg.exec_secs)
        .set("compilations", reg.compilations as i64)
        .set("queue_depth", coord.queue_len())
        .set("active", coord.active_len())
        .set("completed", reg.completed as i64)
        .set("failed", reg.failed as i64)
        .set("cancelled", reg.cancelled as i64)
        .set("kv_resident_bytes", reg.kv_resident_bytes)
        .set("kv_budget_bytes", reg.kv_budget_bytes)
        .set("kv_pages_resident", reg.kv_pages_resident)
        .set("kv_pages_shared", reg.kv_pages_shared)
        .set("kv_frag_pct", reg.kv_frag_pct)
        .set("swap_outs", reg.swap_outs as i64)
        .set("swap_ins", reg.swap_ins as i64)
        .set("swap_faults", reg.swap_faults as i64)
        .set("prefix_hits", reg.prefix_hits as i64)
        .set("prefix_misses", reg.prefix_misses as i64)
        .set("threads", reg.threads)
        .set("fused_groups", reg.batch_groups as i64)
        .set("batch_ops_fused", reg.batch_ops_fused as i64)
        .set("batch_ops_single", reg.batch_ops_single as i64)
        .set("fallback_steps", reg.fallback_steps as i64)
        .set("batch_mean_width", reg.batch_mean_width())
        .set("batch_max_width", reg.batch_width_max)
        .set("batch_tick_groups", reg.batch_tick_groups)
        .set("batched_frac", reg.batched_frac())
        .set("ttft_p50_s", reg.ttft.p50())
        .set("ttft_p99_s", reg.ttft.p99())
        .set("deadline_hits", reg.deadline_hits as i64)
        .set("restarts", reg.restarts as i64)
        .set("checkpoint_resumes", reg.checkpoint_resumes as i64)
        .set("recovered_sessions", reg.recovered_sessions as i64)
        .set("journal_replayed", reg.journal_replayed as i64)
        .set("journal_torn_records", reg.journal_torn_records as i64)
        .set("policy", reg.policy_mode.as_str())
        .set("policy_depth_changes", reg.policy_depth_changes as i64)
        .set("policy_refreshes", reg.policy_refreshes as i64);
    // per-engine speculation counters (DESIGN.md §16): flat keys so the
    // cross-shard merge applies — counters sum, `_tau_mean` /
    // `_partial_frac` average per `averaged_key`
    for (k, c) in &reg.spec {
        body = body
            .set(&format!("spec_{k}_proposed"), c.proposed as i64)
            .set(&format!("spec_{k}_committed"), c.committed as i64)
            .set(&format!("spec_{k}_rounds"), c.rounds as i64)
            .set(&format!("spec_{k}_refreshes"), c.refresh_steps as i64)
            .set(&format!("spec_{k}_tau_mean"), c.tau_mean())
            .set(&format!("spec_{k}_partial_frac"), c.partial_frac());
    }
    for (k, n) in &reg.auto_selected {
        body = body.set(&format!("auto_{k}"), *n as i64);
    }
    body
}

/// The `admin cache` body: prefix cache + swap-tier aggregates.
pub fn cache_body(coord: &mut Coordinator<'_>) -> Json {
    let s = coord.kv_stats();
    Json::obj()
        .set("ok", true)
        .set("prefix_entries", s.prefix.entries)
        .set("prefix_bytes", s.prefix.bytes)
        .set("prefix_budget_bytes", s.prefix.budget_bytes)
        .set("prefix_hits", s.prefix.hits as i64)
        .set("prefix_misses", s.prefix.misses as i64)
        .set("prefix_insertions", s.prefix.insertions as i64)
        .set("prefix_evictions", s.prefix.evictions as i64)
        .set("kv_resident_bytes", s.resident_bytes)
        .set("kv_budget_bytes", s.budget_bytes)
        .set("live_states", s.live_states)
        .set("swapped", s.swapped)
        .set("swap_bytes", s.swap_bytes)
        .set("swap_outs", s.swap_outs as i64)
        .set("swap_ins", s.swap_ins as i64)
}

/// The `admin kv` body: page-level pool gauges (residency, sharing,
/// dedup/CoW counters, quantization and spill tiers).
pub fn kv_body(coord: &mut Coordinator<'_>) -> Json {
    let s = coord.kv_stats();
    let p = &s.pages;
    Json::obj()
        .set("ok", true)
        .set("page_bytes", p.page_bytes)
        .set("pages_resident", p.pages_resident)
        .set("pages_shared", p.pages_shared)
        .set("pages_zero", p.pages_zero)
        .set("pages_spilled", p.pages_spilled)
        .set("ram_bytes", p.ram_bytes)
        .set("disk_bytes", p.disk_bytes)
        .set("frag_pct", p.frag_pct)
        .set("page_allocs", p.page_allocs as i64)
        .set("dedup_hits", p.dedup_hits as i64)
        .set("cow_copies", p.cow_copies as i64)
        .set("quant_pages", p.quant_pages as i64)
        .set("spills", p.spills as i64)
        .set("spill_loads", p.spill_loads as i64)
        .set("swap_faults", p.swap_faults as i64)
        .set("parked_sessions", s.swapped)
        .set("parked_bytes", s.swap_bytes)
}

/// One entry of the `admin shards` dump: per-shard scheduler gauges,
/// lifetime counters and KV residency (the front end adds the routing
/// counters it owns).
pub fn shard_body(shard: usize, coord: &mut Coordinator<'_>) -> Json {
    coord.sync_backend_counters();
    let s = coord.kv_stats();
    let reg = &coord.registry;
    Json::obj()
        .set("shard", shard)
        .set("queue_depth", coord.queue_len())
        .set("active", coord.active_len())
        .set("completed", reg.completed as i64)
        .set("failed", reg.failed as i64)
        .set("cancelled", reg.cancelled as i64)
        .set("tokens_out", reg.tokens_out as i64)
        .set("kv_resident_bytes", s.resident_bytes)
        .set("kv_pages_resident", s.pages.pages_resident)
        .set("prefix_entries", s.prefix.entries)
        .set("prefix_hits", s.prefix.hits as i64)
        .set("prefix_misses", s.prefix.misses as i64)
}

/// True for keys whose cross-shard aggregate is an average (ratios,
/// percentiles, per-shard constants) rather than a sum.
fn averaged_key(k: &str) -> bool {
    k == "page_bytes"
        || ["pct", "frac", "p50", "p95", "p99", "mean"].iter().any(|m| k.contains(m))
}

fn merge_key(k: &str, vals: &[&Json]) -> Json {
    match vals.first() {
        Some(Json::Bool(_)) => {
            Json::Bool(vals.iter().all(|v| v.as_bool().unwrap_or(false)))
        }
        Some(Json::Num(_)) => {
            let nums: Vec<f64> = vals.iter().filter_map(|v| v.as_f64()).collect();
            let sum: f64 = nums.iter().sum();
            if averaged_key(k) && !nums.is_empty() {
                Json::Num(sum / nums.len() as f64)
            } else {
                Json::Num(sum)
            }
        }
        Some(Json::Str(_)) => {
            if k == "summary" {
                Json::Str(
                    vals.iter()
                        .filter_map(|v| v.as_str())
                        .collect::<Vec<_>>()
                        .join(" | "),
                )
            } else {
                (*vals[0]).clone()
            }
        }
        Some(v) => (*v).clone(),
        None => Json::Null,
    }
}

/// Merge per-shard admin bodies into one aggregate: booleans AND,
/// counters sum, ratio/percentile keys (and the per-shard `page_bytes`
/// constant) average, `summary` strings join with `" | "`, other strings
/// take the first shard's value. A single body passes through verbatim —
/// the `shards = 1` byte-identity contract.
pub fn merge_admin(bodies: &[Json]) -> Json {
    if bodies.len() == 1 {
        return bodies[0].clone();
    }
    let mut keys: Vec<String> = Vec::new();
    for b in bodies {
        if let Some(m) = b.as_obj() {
            for k in m.keys() {
                if !keys.contains(k) {
                    keys.push(k.clone());
                }
            }
        }
    }
    let mut out = Json::obj();
    for k in &keys {
        let vals: Vec<&Json> = bodies.iter().filter_map(|b| b.get(k)).collect();
        out = out.set(k, merge_key(k, &vals));
    }
    out
}

/// Render a JSON value as one protocol line (newline-terminated).
pub fn line_of(j: Json) -> String {
    let mut s = j.to_string();
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_body_merges_verbatim() {
        let b = Json::obj().set("ok", true).set("completed", 3i64).set("frag_pct", 2.5);
        assert_eq!(merge_admin(&[b.clone()]).to_string(), b.to_string());
    }

    #[test]
    fn multi_body_sums_counters_and_averages_ratios() {
        let a = Json::obj()
            .set("ok", true)
            .set("completed", 3i64)
            .set("frag_pct", 2.0)
            .set("ttft_p50_s", 0.25)
            .set("page_bytes", 4096usize)
            .set("summary", "a")
            .set("backend", "reference");
        let b = Json::obj()
            .set("ok", true)
            .set("completed", 5i64)
            .set("frag_pct", 4.0)
            .set("ttft_p50_s", 0.75)
            .set("page_bytes", 4096usize)
            .set("summary", "b")
            .set("backend", "reference");
        let m = merge_admin(&[a, b]);
        assert_eq!(m.get("ok").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(m.get("completed").and_then(|x| x.as_i64()), Some(8));
        assert_eq!(m.get("frag_pct").and_then(|x| x.as_f64()), Some(3.0));
        assert_eq!(m.get("ttft_p50_s").and_then(|x| x.as_f64()), Some(0.5));
        assert_eq!(m.get("page_bytes").and_then(|x| x.as_i64()), Some(4096));
        assert_eq!(m.get("summary").and_then(|x| x.as_str()), Some("a | b"));
        assert_eq!(m.get("backend").and_then(|x| x.as_str()), Some("reference"));
    }

    #[test]
    fn timeout_ms_maps_to_the_deadline() {
        let d = Defaults { max_new: 8, temperature: 0.0 };
        let r = parse_request(r#"{"prompt":"x","timeout_ms":250}"#, &d).unwrap();
        match r {
            Request::Generate { deadline_secs, .. } => {
                assert_eq!(deadline_secs, Some(0.25))
            }
            _ => panic!("expected generate"),
        }
        let r = parse_request(r#"{"prompt":"x","timeout_ms":1500,"deadline_s":9.0}"#, &d)
            .unwrap();
        match r {
            Request::Generate { deadline_secs, .. } => {
                assert_eq!(deadline_secs, Some(1.5), "timeout_ms wins over deadline_s")
            }
            _ => panic!("expected generate"),
        }
    }

    #[test]
    fn parse_errors_are_stable() {
        let d = Defaults { max_new: 8, temperature: 0.0 };
        let e = parse_request(r#"{"op":"nope"}"#, &d).unwrap_err();
        assert!(format!("{e:#}").contains("unknown op 'nope'"));
        let e = parse_request(r#"{"op":"generate"}"#, &d).unwrap_err();
        assert!(format!("{e:#}").contains("missing 'prompt'"));
        let e = parse_request(r#"{"op":"admin","cmd":"x"}"#, &d).unwrap_err();
        assert!(format!("{e:#}").contains("metrics|kv|cache|shards"));
        assert!(matches!(
            parse_request(r#"{"op":"admin","cmd":"shards"}"#, &d),
            Ok(Request::Admin { cmd: AdminCmd::Shards, legacy: false })
        ));
    }

    #[test]
    fn generate_retry_parses_and_requires_id() {
        let d = Defaults { max_new: 8, temperature: 0.0 };
        assert!(matches!(
            parse_request(r#"{"op":"generate_retry","id":7}"#, &d),
            Ok(Request::GenerateRetry { id: 7 })
        ));
        let e = parse_request(r#"{"op":"generate_retry"}"#, &d).unwrap_err();
        assert!(format!("{e:#}").contains("generate_retry needs 'id'"));
    }
}
