//! Sharded multi-worker serving (DESIGN.md §14).
//!
//! Three pieces replace the old thread-per-connection server:
//!
//! * [`shard`] — N worker shards, each one `Coordinator` + `Backend`
//!   (+ private KV pool and prefix cache) on its own thread, driven over
//!   a command channel and answering on a shared event channel.
//! * [`router`] — prefix-affinity placement: sessions land on the shard
//!   whose rendezvous hash of their prompt-prefix fingerprint wins, so
//!   repeated prefixes hit the same shard's prefix cache; a configurable
//!   imbalance factor spills sessions off an overloaded home shard.
//! * [`frontend`] — a single nonblocking event loop owning every client
//!   socket: JSON-lines framing, bounded per-connection outboxes with
//!   slow-consumer disconnect, admin fan-out/fan-in across shards.
//!
//! `shards = 1` (the default) is the old single-worker behavior with
//! byte-identical wire output — same response shapes, same id sequence.
//!
//! Shutdown is a drain, not an abort: a `shutdown` op (or Ctrl-C via
//! [`install_ctrlc`]) stops admission, streams a
//! `{"draining":true,"done":false}` marker to in-flight streaming
//! clients, runs every shard's active set dry so each in-flight request
//! still gets its final line, then exits.

pub mod frontend;
pub mod journal;
pub mod router;
pub mod shard;
pub mod supervisor;
pub mod wire;

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, Context, Result};

use crate::backend::{self, Backend};
use crate::config::Config;
use crate::coordinator::Coordinator;
use crate::engine::scripted::ScriptedFactory;
use crate::util::failpoint::FaultSpec;

use frontend::{run_frontend_with, Durable, FrontOpts};
use router::Router;
use shard::{FrontEvent, ShardCmd, ShardHandle, ShardOpts};
use supervisor::{ShardRuntime, SupervisorCfg};
use wire::Defaults;

/// Process-wide drain flag, set by the Ctrl-C handler (or
/// [`request_shutdown`]) and polled by the front-end loop.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Ask the running server to drain and exit, as if a `shutdown` op
/// arrived.
pub fn request_shutdown() {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Whether a drain has been requested process-wide.
pub fn shutdown_requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

#[cfg(unix)]
extern "C" {
    fn signal(signum: i32, handler: usize) -> usize;
    fn _exit(code: i32) -> !;
}

/// First Ctrl-C requests a graceful drain; a second one while the drain
/// is still running exits immediately with the conventional 130.
#[cfg(unix)]
unsafe extern "C" fn on_sigint(_sig: i32) {
    if SHUTDOWN.swap(true, Ordering::SeqCst) {
        _exit(130);
    }
}

/// Install the SIGINT handler (libc `signal` — the ctrlc crate is not in
/// the offline vendor set). No-op off unix.
pub fn install_ctrlc() {
    #[cfg(unix)]
    unsafe {
        signal(2, on_sigint as usize);
    }
}

/// Serve until drained on the configured address. `cfg.shards <= 1`
/// keeps today's single-worker path (one coordinator on the caller's
/// backend); above that, shard 0 runs on the caller's backend and shards
/// 1..N each construct their own from the same config.
pub fn serve(be: &dyn Backend, cfg: Config) -> Result<()> {
    let listener = TcpListener::bind(&cfg.server_addr)
        .with_context(|| format!("binding {}", cfg.server_addr))?;
    if cfg.shards <= 1 {
        println!("specpv server listening on {} ({} backend)", cfg.server_addr, be.name());
        let coord = Coordinator::new(be, cfg);
        serve_on(listener, coord)
    } else {
        println!(
            "specpv server listening on {} ({} backend, {} shards)",
            cfg.server_addr,
            be.name(),
            cfg.shards
        );
        serve_sharded(listener, be, cfg)
    }
}

/// Open the durability layer a config describes (`journal_dir` set):
/// the write-ahead journal (replayed, torn tail truncated) and the
/// crash-consistent checkpoint store, plus the recovery counters
/// `[recovered_sessions, journal_replayed, journal_torn_records]` for
/// the registry. `None` when journaling is off.
pub fn open_durable(cfg: &Config) -> Result<Option<(Durable, [u64; 3])>> {
    let Some(dir) = cfg.journal_path() else { return Ok(None) };
    let (jnl, replay) = journal::Journal::open(&dir, cfg.journal_fsync)?;
    let store = crate::kvstore::CheckpointStore::open(&dir.join(journal::CKPT_SUBDIR))?;
    let counters = [replay.requests.len() as u64, replay.records, replay.torn];
    let durable = Durable {
        journal: jnl,
        store,
        recovered: replay.requests,
        next_gid: replay.next_gid,
    };
    Ok(Some((durable, counters)))
}

/// Serve on an already-bound listener with an existing (single)
/// coordinator. Tests inject a scripted coordinator here; `serve` binds
/// the real one. The shard loop runs on the caller's thread — the
/// backend's handles are not `Send` — with the front end spawned beside
/// it.
pub fn serve_on(listener: TcpListener, coord: Coordinator<'_>) -> Result<()> {
    serve_on_abortable(listener, coord, None)
}

/// [`serve_on`] with the crash-equivalent abort hook: when the flag
/// flips, the front end returns without draining, flushing or marking
/// the journal clean — process-equivalent to a SIGKILL for the
/// durability layer (the shard loop still winds down in-process).
pub fn serve_on_abortable(
    listener: TcpListener,
    mut coord: Coordinator<'_>,
    abort: Option<Arc<AtomicBool>>,
) -> Result<()> {
    let defaults = Defaults {
        max_new: coord.cfg.max_new_tokens,
        temperature: coord.cfg.temperature,
    };
    let router = Router::new(1, coord.cfg.route_imbalance);
    let shard_queue = coord.cfg.shard_queue;
    let (durable, counters) = match open_durable(&coord.cfg)? {
        Some((d, c)) => (Some(d), c),
        None => (None, [0; 3]),
    };
    let opts = ShardOpts {
        checkpoint_every: coord.cfg.checkpoint_every_steps,
        recovered_sessions: counters[0],
        journal_replayed: counters[1],
        journal_torn_records: counters[2],
        ..ShardOpts::default()
    };
    let fopts = FrontOpts { shard_queue, durable, abort };
    let (cmd_tx, cmd_rx) = channel::<ShardCmd>();
    let (ev_tx, ev_rx) = channel::<FrontEvent>();
    let handles = vec![ShardHandle::new(0, cmd_tx)];
    thread::scope(|s| {
        let fe = s.spawn(move || {
            run_frontend_with(listener, handles, ev_rx, router, defaults, fopts)
        });
        shard::run_shard_with(0, &mut coord, cmd_rx, ev_tx, opts);
        fe.join()
            .unwrap_or_else(|_| Err(anyhow!("front end panicked")))
    })?;
    println!("server metrics: {}", coord.registry.summary());
    Ok(())
}

/// Multi-shard serve: every shard is **supervised** (DESIGN.md §15) —
/// its generation runs on a disposable thread that builds its own
/// backend from the config, so a crashed or wedged shard restarts with
/// its in-flight sessions failed over. The caller's backend is used for
/// the banner only; supervised generations must own theirs.
fn serve_sharded(listener: TcpListener, _be: &dyn Backend, cfg: Config) -> Result<()> {
    let runtime = backend_runtime(&cfg);
    serve_supervised(listener, cfg, runtime)
}

/// A [`ShardRuntime`] that builds a backend (and coordinator) from the
/// config inside each generation.
pub fn backend_runtime(cfg: &Config) -> ShardRuntime {
    let cfg = cfg.clone();
    Arc::new(move |shard, cmd_rx, ev_tx, opts| {
        let be = backend::from_config(&cfg)?;
        let mut coord = Coordinator::new(be.as_ref(), cfg.clone());
        shard::run_shard_with(shard, &mut coord, cmd_rx, ev_tx, opts);
        println!("shard {shard} metrics: {}", coord.registry.summary());
        Ok(())
    })
}

/// A [`ShardRuntime`] over a scripted factory (tests, load simulation).
pub fn scripted_runtime(cfg: &Config, factory: ScriptedFactory) -> ShardRuntime {
    let cfg = cfg.clone();
    Arc::new(move |shard, cmd_rx, ev_tx, opts| {
        let mut coord = Coordinator::with_factory(cfg.clone(), Box::new(factory.clone()));
        shard::run_shard_with(shard, &mut coord, cmd_rx, ev_tx, opts);
        Ok(())
    })
}

/// Serve with one supervisor per shard on an already-bound listener.
/// The front end runs on the caller's thread; each supervisor spawns
/// (and respawns) its shard's generation from `runtime`. Returns once
/// drained.
pub fn serve_supervised(
    listener: TcpListener,
    cfg: Config,
    runtime: ShardRuntime,
) -> Result<()> {
    serve_supervised_abortable(listener, cfg, runtime, None)
}

/// [`serve_supervised`] with the crash-equivalent abort hook (see
/// [`serve_on_abortable`]).
pub fn serve_supervised_abortable(
    listener: TcpListener,
    cfg: Config,
    runtime: ShardRuntime,
    abort: Option<Arc<AtomicBool>>,
) -> Result<()> {
    let n = cfg.shards.max(1);
    let defaults = Defaults {
        max_new: cfg.max_new_tokens,
        temperature: cfg.temperature,
    };
    let router = Router::new(n, cfg.route_imbalance);
    let (durable, counters) = match open_durable(&cfg)? {
        Some((d, c)) => (Some(d), c),
        None => (None, [0; 3]),
    };
    let sup = SupervisorCfg {
        heartbeat_ms: cfg.shard_heartbeat_ms,
        max_restarts: cfg.max_restarts,
        checkpoint_every: cfg.checkpoint_every_steps,
        faults: FaultSpec::parse(&cfg.faults).unwrap_or_default(),
        recovered_sessions: 0,
        journal_replayed: 0,
        journal_torn_records: 0,
    };
    let shard_queue = cfg.shard_queue;
    let (ev_tx, ev_rx) = channel::<FrontEvent>();
    let mut handles = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        let (tx, rx) = channel::<ShardCmd>();
        handles.push(ShardHandle::new(i, tx));
        rxs.push(rx);
    }
    thread::scope(|s| {
        for (i, rx) in rxs.into_iter().enumerate() {
            let tx = ev_tx.clone();
            let rt = Arc::clone(&runtime);
            // recovery counters live on shard 0's registry only — the
            // cross-shard admin merge sums counters, so this keeps the
            // aggregate exact
            let supc = if i == 0 {
                SupervisorCfg {
                    recovered_sessions: counters[0],
                    journal_replayed: counters[1],
                    journal_torn_records: counters[2],
                    ..sup.clone()
                }
            } else {
                sup.clone()
            };
            s.spawn(move || supervisor::supervise_shard(i, supc, rx, tx, rt));
        }
        drop(ev_tx);
        run_frontend_with(
            listener,
            handles,
            ev_rx,
            router,
            defaults,
            FrontOpts { shard_queue, durable, abort },
        )
    })
}

/// Serve a multi-shard scripted server for tests: every shard gets its
/// own (supervised) coordinator over a clone of `factory`; the front
/// end runs on the caller's thread. Returns once drained (a `shutdown`
/// op).
pub fn serve_scripted(listener: TcpListener, cfg: Config, factory: ScriptedFactory) -> Result<()> {
    let runtime = scripted_runtime(&cfg, factory);
    serve_supervised(listener, cfg, runtime)
}

/// [`serve_scripted`] with the crash-equivalent abort hook (see
/// [`serve_on_abortable`]).
pub fn serve_scripted_abortable(
    listener: TcpListener,
    cfg: Config,
    factory: ScriptedFactory,
    abort: Option<Arc<AtomicBool>>,
) -> Result<()> {
    let runtime = scripted_runtime(&cfg, factory);
    serve_supervised_abortable(listener, cfg, runtime, abort)
}
