//! Nonblocking event-loop front end: one poll loop multiplexes every
//! client socket (hand-rolled `set_nonblocking` + readiness polling —
//! mio is not in the offline vendor set), frames JSON lines in
//! per-connection buffers, routes parsed ops to worker shards through
//! the [`Router`] and fans shard events back to the owning connections.
//! Replaces the old two-threads-per-connection design and its
//! self-connect accept wakeup: all socket work happens here, and shard
//! events arrive on one mpsc receiver whose 1 ms `recv_timeout` doubles
//! as the idle wait (a shard event wakes the loop immediately; fresh
//! socket bytes wait out at most the timeout).
//!
//! Backpressure: response lines queue in a per-connection outbox; a
//! consumer that stops reading past `MAX_OUTBOX` buffered bytes is
//! disconnected rather than ballooning memory. A closed connection's
//! in-flight requests are cancelled on their shards — and its parked
//! (queued-but-unrouted) requests released here — so the routing table
//! and load accounting converge.
//!
//! Fault tolerance (DESIGN.md §15): the front end retains each admitted
//! request (prompt + options + streaming progress) and the most recent
//! failover checkpoint its shard shipped for it. On `ShardDown` it
//! re-homes the dead shard's sessions onto live shards — resuming from
//! the checkpoint when one exists, deterministically regenerating
//! otherwise — or parks them until a shard comes back. Overload control:
//! with `shard_queue > 0`, a generate whose target shard already carries
//! that many in-flight sessions is shed with a structured
//! `{"error":"overloaded","retry_after_ms":…}` line instead of queueing
//! without bound.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::EngineKind;
use crate::engine::{GenRequest, SessionCheckpoint};
use crate::json::Json;
use crate::kvstore::CheckpointStore;

use super::journal::{self, Journal, ReplayedRequest};
use super::router::Router;
use super::shard::{ConnId, FrontEvent, Gid, ShardHandle, SubmitReq};
use super::wire::{self, AdminCmd, Defaults, Request};

/// Slow-consumer disconnect threshold: a connection whose un-flushed
/// outbox exceeds this many bytes is dropped.
const MAX_OUTBOX: usize = 1 << 20;

/// A journal watermark tied to a position in a connection's outbox: it
/// fires — and is written to the journal — only once the socket accepted
/// every byte before it. Journaling at *flush* time (not emit time) is
/// what keeps the delivered watermark honest: tokens sitting in the
/// outbox at crash time replay on recovery.
#[derive(Debug, Clone, Copy)]
enum Mark {
    /// `tokens` absolute delta tokens delivered for gid
    Progress(Gid, usize),
    /// gid's final line delivered; it no longer needs recovery
    Done(Gid),
}

/// Durable-serving state threaded into the front end when `journal_dir`
/// is configured: the open write-ahead journal, the crash-consistent
/// checkpoint store, and the unfinished requests replayed on boot.
pub struct Durable {
    pub journal: Journal,
    pub store: CheckpointStore,
    /// unfinished requests rebuilt by the boot-time journal scan
    pub recovered: BTreeMap<Gid, ReplayedRequest>,
    /// smallest gid this incarnation may assign (monotone id space)
    pub next_gid: Gid,
}

/// Front-end knobs beyond the routing defaults.
#[derive(Default)]
pub struct FrontOpts {
    /// overload bound: shed when the target shard's in-flight load is
    /// already this deep (0 = unbounded)
    pub shard_queue: usize,
    /// durability layer (`journal_dir` configured)
    pub durable: Option<Durable>,
    /// crash-equivalent teardown flag: when set, the loop returns
    /// immediately — no drain, no outbox flush, no journal mark-clean —
    /// freezing the durable state exactly as a SIGKILL would (used by
    /// the in-process crash-recovery tests and bench)
    pub abort: Option<Arc<AtomicBool>>,
}

/// Buffered output of a recovered session that no client has claimed
/// yet: lines accumulate here (with their journal marks) until a
/// `generate_retry` transfers them onto a real connection. Nothing in a
/// virtual buffer counts as delivered — the journal watermark stays
/// frozen until the bytes reach a real socket.
struct Virtual {
    /// the synthetic connection id shards address lines to
    vconn: ConnId,
    buf: Vec<u8>,
    marks: Vec<(usize, Mark)>,
    /// the journaled delivered watermark (what the client already has)
    delivered: usize,
    /// the session ran to its final line while unclaimed
    done: bool,
}

struct Conn {
    stream: TcpStream,
    /// unparsed inbound bytes (a partial JSON line)
    rbuf: Vec<u8>,
    /// outbox: rendered lines not yet written to the socket
    wbuf: Vec<u8>,
    /// write cursor into `wbuf`
    wpos: usize,
    /// generate gids owned by this connection still in flight
    inflight: Vec<Gid>,
    /// journal watermarks keyed by outbox offset (same coordinate as
    /// `wpos`), kept in non-decreasing offset order
    marks: VecDeque<(usize, Mark)>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            inflight: Vec::new(),
            marks: VecDeque::new(),
        }
    }

    fn push_line(&mut self, j: Json) {
        self.wbuf.extend_from_slice(wire::line_of(j).as_bytes());
    }

    fn outbox_len(&self) -> usize {
        self.wbuf.len() - self.wpos
    }
}

/// One in-flight admin fan-out (correlation id → aggregation state).
struct AdminAgg {
    conn: ConnId,
    cmd: AdminCmd,
    legacy: bool,
    want: usize,
    bodies: Vec<(usize, Json)>,
}

/// Everything needed to resubmit a request after its shard dies: the
/// parsed request plus how much of its answer the client already has.
struct Retained {
    gen: GenRequest,
    engine: Option<EngineKind>,
    /// per-request `engine=auto` (policy layer, DESIGN.md §16)
    auto: bool,
    stream: bool,
    deadline_secs: Option<f64>,
    priority: i32,
    /// absolute tokens already streamed to the client (dedup floor for
    /// a failover resubmission)
    streamed: usize,
    /// the queued ack line already went out
    acked: bool,
    /// this request was displaced off a dead shard at least once
    displaced: bool,
}

/// Routing-table entry for one admitted gid.
struct RouteEntry {
    /// owning shard; `None` while parked (every shard down)
    shard: Option<usize>,
    conn: ConnId,
    retained: Retained,
}

struct Frontend {
    shards: Vec<ShardHandle>,
    router: Router,
    defaults: Defaults,
    /// overload bound: shed when the target shard's in-flight load is
    /// already this deep (0 = unbounded)
    shard_queue: usize,
    conns: HashMap<ConnId, Conn>,
    routes: HashMap<Gid, RouteEntry>,
    /// latest failover checkpoint per gid (front-end-owned; host data)
    ckpts: HashMap<Gid, SessionCheckpoint>,
    /// gids waiting for any shard to come back up
    parked: VecDeque<Gid>,
    /// durability layer (`journal_dir` configured): WAL + checkpoint store
    durable: Option<Durable>,
    /// crash-equivalent teardown flag (see [`FrontOpts::abort`])
    abort: Option<Arc<AtomicBool>>,
    /// unclaimed recovered sessions by gid (DESIGN.md §17)
    virtuals: HashMap<Gid, Virtual>,
    /// synthetic connection id → recovered gid it buffers for
    vconn_gid: HashMap<ConnId, Gid>,
    /// synthetic connection id → the real connection that claimed it via
    /// `generate_retry` (shards keep addressing the vconn)
    conn_alias: HashMap<ConnId, ConnId>,
    admin_pending: HashMap<u64, AdminAgg>,
    next_conn: ConnId,
    next_gid: Gid,
    next_corr: u64,
    draining: bool,
    drained: Vec<bool>,
    dead: Vec<ConnId>,
    // observability counters (surfaced through `admin metrics`)
    shed_requests: u64,
    slow_consumer_disconnects: u64,
    failover_checkpoint: u64,
    failover_regen: u64,
}

/// Run the event-loop front end until drained (a `shutdown` op or the
/// process-wide Ctrl-C flag). Owns the listener and every client socket.
pub fn run_frontend(
    listener: TcpListener,
    shards: Vec<ShardHandle>,
    ev_rx: Receiver<FrontEvent>,
    router: Router,
    defaults: Defaults,
    shard_queue: usize,
) -> Result<()> {
    run_frontend_with(
        listener,
        shards,
        ev_rx,
        router,
        defaults,
        FrontOpts { shard_queue, ..FrontOpts::default() },
    )
}

/// [`run_frontend`] with the durability layer and the crash-equivalent
/// abort hook (DESIGN.md §17). Recovered sessions from the journal scan
/// are resubmitted before the first poll iteration.
pub fn run_frontend_with(
    listener: TcpListener,
    shards: Vec<ShardHandle>,
    ev_rx: Receiver<FrontEvent>,
    router: Router,
    defaults: Defaults,
    opts: FrontOpts,
) -> Result<()> {
    let n = shards.len();
    let mut fe = Frontend {
        shards,
        router,
        defaults,
        shard_queue: opts.shard_queue,
        conns: HashMap::new(),
        routes: HashMap::new(),
        ckpts: HashMap::new(),
        parked: VecDeque::new(),
        durable: opts.durable,
        abort: opts.abort,
        virtuals: HashMap::new(),
        vconn_gid: HashMap::new(),
        conn_alias: HashMap::new(),
        admin_pending: HashMap::new(),
        next_conn: 0,
        next_gid: 0,
        next_corr: 0,
        draining: false,
        drained: vec![false; n],
        dead: Vec::new(),
        shed_requests: 0,
        slow_consumer_disconnects: 0,
        failover_checkpoint: 0,
        failover_regen: 0,
    };
    fe.seed_recovered();
    fe.run(listener, ev_rx)
}

impl Frontend {
    fn run(mut self, listener: TcpListener, ev_rx: Receiver<FrontEvent>) -> Result<()> {
        listener.set_nonblocking(true)?;
        loop {
            if let Some(a) = &self.abort {
                if a.load(Ordering::SeqCst) {
                    // crash-equivalent teardown: no drain, no outbox
                    // flush, no journal mark-clean — durable state
                    // freezes exactly as a SIGKILL would leave it
                    return Ok(());
                }
            }
            if !self.draining && super::shutdown_requested() {
                self.begin_drain();
            }
            self.accept(&listener);
            self.read_conns();
            loop {
                match ev_rx.try_recv() {
                    Ok(ev) => self.handle_event(ev),
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            }
            self.write_conns();
            self.reap();
            if self.draining && self.drained.iter().all(|&d| d) {
                // every shard has delivered its final lines; flush what
                // the sockets will take, then exit
                self.flush_all(Duration::from_millis(500));
                // graceful shutdown: every session reached its terminal
                // line (flush_all fired the remaining delivery marks),
                // so a clean restart replays nothing
                if let Some(d) = &mut self.durable {
                    let _ = d.journal.mark_clean();
                    d.store.clear();
                }
                return Ok(());
            }
            // idle wait: a shard event wakes us immediately; fresh socket
            // bytes wait out at most the 1 ms timeout
            match ev_rx.recv_timeout(Duration::from_millis(1)) {
                Ok(ev) => self.handle_event(ev),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }

    fn begin_drain(&mut self) {
        if self.draining {
            return;
        }
        self.draining = true;
        // commands already queued (submits, admins) are processed before
        // the Drain marker — channel order is the drain barrier
        for h in &self.shards {
            h.drain();
        }
        // parked requests have no shard to deliver their final line;
        // fail them here
        while let Some(gid) = self.parked.pop_front() {
            self.fail_unrouted(gid, "server shutting down");
        }
    }

    /// Cold-restart recovery (DESIGN.md §17): rebuild every unfinished
    /// request the journal replayed, attach each to a virtual connection
    /// that buffers its output until a `generate_retry` claims it, and
    /// resubmit — resuming from the durable checkpoint when one decodes,
    /// deterministically regenerating from the journaled prompt
    /// otherwise. Durable images for gids that need no recovery are
    /// garbage-collected from disk.
    fn seed_recovered(&mut self) {
        let (recovered, journal_next_gid) = match &mut self.durable {
            Some(d) => (std::mem::take(&mut d.recovered), d.next_gid),
            None => return,
        };
        let mut images = match &self.durable {
            Some(d) => d.store.scan(),
            None => BTreeMap::new(),
        };
        if let Some(d) = &self.durable {
            for gid in images.keys() {
                if !recovered.contains_key(gid) {
                    d.store.remove(*gid);
                }
            }
        }
        images.retain(|gid, _| recovered.contains_key(gid));
        self.next_gid = self.next_gid.max(journal_next_gid);
        for (gid, r) in recovered {
            let vcid = self.next_conn;
            self.next_conn += 1;
            self.vconn_gid.insert(vcid, gid);
            self.virtuals.insert(
                gid,
                Virtual {
                    vconn: vcid,
                    buf: Vec::new(),
                    marks: Vec::new(),
                    delivered: r.delivered,
                    done: false,
                },
            );
            let retained = Retained {
                gen: GenRequest {
                    prompt: r.prompt,
                    max_new: r.max_new,
                    temperature: r.temperature,
                    seed: r.seed,
                },
                engine: r.engine,
                auto: r.auto,
                stream: r.stream,
                deadline_secs: r.deadline_secs,
                priority: r.priority,
                streamed: r.delivered,
                acked: true,
                displaced: true,
            };
            if let Some(ck) = images.remove(&gid) {
                self.ckpts.insert(gid, ck);
            }
            if self.router.all_down() {
                self.routes.insert(gid, RouteEntry { shard: None, conn: vcid, retained });
                self.parked.push_back(gid);
            } else {
                let place = self.router.place(&retained.gen.prompt);
                self.routes.insert(
                    gid,
                    RouteEntry { shard: Some(place.shard), conn: vcid, retained },
                );
                let resume = self.ckpts.get(&gid).cloned();
                if resume.is_some() {
                    self.failover_checkpoint += 1;
                } else {
                    self.failover_regen += 1;
                }
                self.submit_to(place.shard, gid, resume);
            }
        }
    }

    /// Terminal error line for a request that never reached (or lost)
    /// its shard; releases all front-end state for the gid.
    fn fail_unrouted(&mut self, gid: Gid, err: &str) {
        self.ckpts.remove(&gid);
        let Some(e) = self.routes.remove(&gid) else { return };
        if let Some(c) = self.conns.get_mut(&e.conn) {
            c.inflight.retain(|&g| g != gid);
            c.push_line(
                Json::obj()
                    .set("ok", false)
                    .set("id", gid as i64)
                    .set("done", true)
                    .set("error", err),
            );
        }
    }

    fn accept(&mut self, listener: &TcpListener) {
        if self.draining {
            return;
        }
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let cid = self.next_conn;
                    self.next_conn += 1;
                    self.conns.insert(cid, Conn::new(stream));
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
    }

    fn read_conns(&mut self) {
        let cids: Vec<ConnId> = self.conns.keys().copied().collect();
        for cid in cids {
            let Some(mut conn) = self.conns.remove(&cid) else { continue };
            let mut closed = false;
            let mut buf = [0u8; 4096];
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        closed = true;
                        break;
                    }
                    Ok(n) => conn.rbuf.extend_from_slice(&buf[..n]),
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        closed = true;
                        break;
                    }
                }
            }
            while let Some(pos) = conn.rbuf.iter().position(|&b| b == b'\n') {
                let raw: Vec<u8> = conn.rbuf.drain(..=pos).collect();
                let text = String::from_utf8_lossy(&raw);
                let line = text.trim();
                if !line.is_empty() {
                    self.handle_line(cid, &mut conn, line);
                }
            }
            self.conns.insert(cid, conn);
            if closed {
                self.dead.push(cid);
            }
        }
    }

    fn handle_line(&mut self, cid: ConnId, conn: &mut Conn, line: &str) {
        let req = match wire::parse_request(line, &self.defaults) {
            Ok(r) => r,
            Err(e) => {
                conn.push_line(Json::obj().set("ok", false).set("error", format!("{e:#}")));
                return;
            }
        };
        match req {
            Request::Ping => conn.push_line(Json::obj().set("ok", true)),
            Request::Shutdown => {
                conn.push_line(Json::obj().set("ok", true));
                self.begin_drain();
            }
            Request::Cancel { id } => match self.routes.get(&id).and_then(|e| e.shard) {
                // the owning shard answers after the final line, keeping
                // the old final-then-ack ordering on the wire
                Some(shard) => self.shards[shard].cancel(id, cid),
                None if self.routes.contains_key(&id) => {
                    // parked: no shard owns it — cancel here, final line
                    // to the owner first, ack to the canceller after.
                    // `conn` is detached from the map while its line is
                    // handled, so route owner == canceller needs it
                    // addressed directly.
                    self.parked.retain(|&g| g != id);
                    self.ckpts.remove(&id);
                    if let Some(e) = self.routes.remove(&id) {
                        let fin = Json::obj()
                            .set("ok", true)
                            .set("id", id as i64)
                            .set("done", true)
                            .set("cancelled", true)
                            .set("text", "");
                        if e.conn == cid {
                            conn.inflight.retain(|&g| g != id);
                            conn.push_line(fin);
                        } else if let Some(c) = self.conns.get_mut(&e.conn) {
                            c.inflight.retain(|&g| g != id);
                            c.push_line(fin);
                        }
                    }
                    conn.push_line(Json::obj().set("ok", true).set("cancelled", true));
                }
                None => conn.push_line(Json::obj().set("ok", true).set("cancelled", false)),
            },
            Request::Admin { cmd, legacy } => {
                if self.draining {
                    conn.push_line(
                        Json::obj().set("ok", false).set("error", "server shutting down"),
                    );
                    return;
                }
                let corr = self.next_corr;
                self.next_corr += 1;
                self.admin_pending.insert(
                    corr,
                    AdminAgg {
                        conn: cid,
                        cmd,
                        legacy,
                        want: self.shards.len(),
                        bodies: Vec::new(),
                    },
                );
                for h in &self.shards {
                    h.admin(corr, cmd);
                }
            }
            Request::Generate { gen, engine, auto, stream, deadline_secs, priority } => {
                if self.draining {
                    conn.push_line(
                        Json::obj().set("ok", false).set("error", "server shutting down"),
                    );
                    return;
                }
                // overload control: shed before admitting (no gid burned)
                if !self.router.all_down() {
                    let place = self.router.peek(&gen.prompt);
                    if self.shard_queue > 0 && self.router.load(place.shard) >= self.shard_queue
                    {
                        let retry = 50 + 10 * self.router.load(place.shard) as u64;
                        conn.push_line(
                            Json::obj()
                                .set("ok", false)
                                .set("error", "overloaded")
                                .set("retry_after_ms", retry as i64),
                        );
                        self.shed_requests += 1;
                        return;
                    }
                }
                let gid = self.next_gid;
                self.next_gid += 1;
                let retained = Retained {
                    gen,
                    engine,
                    auto,
                    stream,
                    deadline_secs,
                    priority,
                    streamed: 0,
                    acked: false,
                    displaced: false,
                };
                // write-ahead: the accept record lands before any line
                // (even the queued ack) can reach the client
                if let Some(d) = &mut self.durable {
                    let _ = d.journal.append(&journal::accept_record(
                        gid,
                        &retained.gen,
                        engine,
                        auto,
                        stream,
                        deadline_secs,
                        priority,
                    ));
                }
                conn.inflight.push(gid);
                if self.router.all_down() {
                    // hold until a shard restarts
                    self.routes.insert(gid, RouteEntry { shard: None, conn: cid, retained });
                    self.parked.push_back(gid);
                    return;
                }
                let place = self.router.place(&retained.gen.prompt);
                self.routes
                    .insert(gid, RouteEntry { shard: Some(place.shard), conn: cid, retained });
                self.submit_to(place.shard, gid, None);
            }
            Request::GenerateRetry { id } => {
                let Some(mut v) = self.virtuals.remove(&id) else {
                    conn.push_line(Json::obj().set("ok", false).set(
                        "error",
                        format!("unknown or already-delivered request id {id}"),
                    ));
                    return;
                };
                // header tells the client where the replayed stream picks
                // up: everything below `delivered` was flushed to it
                // before the crash
                conn.push_line(
                    Json::obj()
                        .set("ok", true)
                        .set("id", id as i64)
                        .set("retry", true)
                        .set("delivered", v.delivered)
                        .set("done", false),
                );
                // transfer the buffered suffix (and its journal marks,
                // rebased to this outbox) onto the claiming connection
                let base = conn.wbuf.len();
                conn.wbuf.extend_from_slice(&v.buf);
                for (off, m) in v.marks.drain(..) {
                    conn.marks.push_back((base + off, m));
                }
                if v.done {
                    // complete answer already buffered; nothing further
                    // will arrive for the virtual connection
                    self.vconn_gid.remove(&v.vconn);
                } else {
                    // still generating: future lines addressed to the
                    // virtual connection land here via the alias
                    self.conn_alias.insert(v.vconn, cid);
                    conn.inflight.push(id);
                }
            }
        }
    }

    /// Build a [`SubmitReq`] from the retained request state and send it.
    fn submit_to(&mut self, shard: usize, gid: Gid, resume: Option<SessionCheckpoint>) {
        let Some(e) = self.routes.get(&gid) else { return };
        self.shards[shard].submit(SubmitReq {
            gid,
            conn: e.conn,
            gen: e.retained.gen.clone(),
            engine: e.retained.engine,
            auto: e.retained.auto,
            stream: e.retained.stream,
            deadline_secs: e.retained.deadline_secs,
            priority: e.retained.priority,
            resume: resume.map(Box::new),
            skip_tokens: e.retained.streamed,
            ack_sent: e.retained.acked,
        });
    }

    /// Re-place one displaced or parked gid on a live shard, resuming
    /// from its retained checkpoint when one exists.
    fn resubmit(&mut self, gid: Gid) {
        let Some(e) = self.routes.get(&gid) else { return };
        let place = self.router.place(&e.retained.gen.prompt);
        if let Some(e) = self.routes.get_mut(&gid) {
            e.shard = Some(place.shard);
        }
        let resume = self.ckpts.get(&gid).cloned();
        let displaced = self.routes.get(&gid).map(|e| e.retained.displaced).unwrap_or(false);
        if resume.is_some() {
            self.failover_checkpoint += 1;
        } else if displaced {
            self.failover_regen += 1;
        }
        self.submit_to(place.shard, gid, resume);
    }

    /// A shard's generation died: exclude it from routing, fail its
    /// sessions over to live shards (or park them), then release the
    /// supervisor's restart barrier.
    fn handle_shard_down(&mut self, dead: usize) {
        self.router.set_down(dead, true);
        let mut gids: Vec<Gid> = self
            .routes
            .iter()
            .filter(|(_, e)| e.shard == Some(dead))
            .map(|(&g, _)| g)
            .collect();
        gids.sort_unstable();
        for gid in gids {
            self.router.finished(dead);
            if self.draining {
                // no live shard will re-run it during a drain; fail it
                self.fail_unrouted(gid, &format!("shard {dead} failed during drain"));
                continue;
            }
            if let Some(e) = self.routes.get_mut(&gid) {
                e.retained.displaced = true;
                if self.router.all_down() {
                    e.shard = None;
                    self.parked.push_back(gid);
                } else {
                    self.resubmit(gid);
                }
            }
        }
        // barrier: the supervisor may restart the generation only after
        // every failed-over session has left the dead shard's queue
        self.shards[dead].failover_done();
        if self.draining {
            // the pre-death Drain marker died with the generation;
            // re-issue it so the restarted (or dead-ended) shard still
            // reports Drained
            self.shards[dead].drain();
        }
    }

    /// Resolve the connection a shard-addressed id actually writes to:
    /// claimed virtual connections forward to their claimant.
    fn effective_conn(&self, conn: ConnId) -> ConnId {
        self.conn_alias.get(&conn).copied().unwrap_or(conn)
    }

    /// Route one rendered line to its connection: a live socket's outbox,
    /// an unclaimed recovered session's virtual buffer, or (connection
    /// gone) the floor.
    fn deliver_line(&mut self, conn: ConnId, line: String) {
        let eff = self.effective_conn(conn);
        if let Some(c) = self.conns.get_mut(&eff) {
            c.wbuf.extend_from_slice(line.as_bytes());
            return;
        }
        if let Some(&gid) = self.vconn_gid.get(&conn) {
            if let Some(v) = self.virtuals.get_mut(&gid) {
                v.buf.extend_from_slice(line.as_bytes());
            }
        }
    }

    fn handle_event(&mut self, ev: FrontEvent) {
        match ev {
            FrontEvent::Line { conn, line } => self.deliver_line(conn, line),
            FrontEvent::Terminal { conn, shard, gid } => {
                self.router.finished(shard);
                self.routes.remove(&gid);
                self.ckpts.remove(&gid);
                let eff = self.effective_conn(conn);
                if let Some(c) = self.conns.get_mut(&eff) {
                    c.inflight.retain(|&g| g != gid);
                    if self.durable.is_some() {
                        // journaled once the final line flushes
                        c.marks.push_back((c.wbuf.len(), Mark::Done(gid)));
                    }
                } else if let Some(v) = self.virtuals.get_mut(&gid) {
                    // finished while unclaimed: the complete answer sits
                    // in the virtual buffer awaiting a generate_retry;
                    // the done record fires only when it is delivered
                    v.done = true;
                    v.marks.push((v.buf.len(), Mark::Done(gid)));
                } else if let Some(d) = &mut self.durable {
                    // owner connection is gone — nothing further can be
                    // delivered, so the session needs no recovery
                    let _ = d.journal.append(&journal::done_record(gid));
                    d.store.remove(gid);
                }
                // a claimed virtual's request finished: retire the alias
                if self.vconn_gid.get(&conn) == Some(&gid) && !self.virtuals.contains_key(&gid)
                {
                    self.vconn_gid.remove(&conn);
                    self.conn_alias.remove(&conn);
                }
            }
            FrontEvent::Checkpoint { gid, ck } => {
                // latest wins; dropped if the request already finished
                if self.routes.contains_key(&gid) {
                    if let Some(d) = &mut self.durable {
                        // atomic replace: a crash mid-save leaves the
                        // previous image, never a torn one
                        let _ = d.store.save(gid, &ck);
                    }
                    self.ckpts.insert(gid, *ck);
                }
            }
            FrontEvent::Progress { gid, tokens } => {
                let owner = match self.routes.get_mut(&gid) {
                    Some(e) => {
                        e.retained.streamed = tokens;
                        Some(e.conn)
                    }
                    None => None,
                };
                if self.durable.is_some() {
                    if let Some(oc) = owner {
                        let eff = self.effective_conn(oc);
                        if let Some(c) = self.conns.get_mut(&eff) {
                            c.marks.push_back((c.wbuf.len(), Mark::Progress(gid, tokens)));
                        } else if let Some(v) = self.virtuals.get_mut(&gid) {
                            v.marks.push((v.buf.len(), Mark::Progress(gid, tokens)));
                        }
                    }
                }
            }
            FrontEvent::Acked { gid } => {
                if let Some(e) = self.routes.get_mut(&gid) {
                    e.retained.acked = true;
                }
            }
            // supervisor-ledger bookkeeping only
            FrontEvent::CancelDone { .. } => {}
            FrontEvent::ShardDown { shard } => self.handle_shard_down(shard),
            FrontEvent::ShardUp { shard } => {
                self.router.set_down(shard, false);
                while let Some(gid) = self.parked.pop_front() {
                    self.resubmit(gid);
                }
            }
            FrontEvent::Admin { corr, shard, body } => {
                let done = match self.admin_pending.get_mut(&corr) {
                    Some(agg) => {
                        agg.bodies.push((shard, body));
                        agg.bodies.len() >= agg.want
                    }
                    None => false,
                };
                if done {
                    if let Some(agg) = self.admin_pending.remove(&corr) {
                        let (conn, body) = self.render_admin(agg);
                        if let Some(c) = self.conns.get_mut(&conn) {
                            c.push_line(body);
                        }
                    }
                }
            }
            FrontEvent::Drained { shard } => {
                if let Some(d) = self.drained.get_mut(shard) {
                    *d = true;
                }
            }
        }
    }

    /// Assemble the final admin response from the per-shard bodies: a
    /// verbatim pass-through at one shard, the documented merge above it,
    /// and the structured per-shard dump for `cmd:"shards"`. Metrics gain
    /// the front-end-owned counters (routing, shedding, failover) that no
    /// shard can see.
    fn render_admin(&self, mut agg: AdminAgg) -> (ConnId, Json) {
        agg.bodies.sort_by_key(|(s, _)| *s);
        let body = if agg.cmd == AdminCmd::Shards {
            let per_shard: Vec<Json> = agg
                .bodies
                .iter()
                .map(|(s, b)| {
                    b.clone()
                        .set("placed", self.router.placed(*s) as i64)
                        .set("load", self.router.load(*s))
                })
                .collect();
            Json::obj()
                .set("ok", true)
                .set("shards", self.shards.len())
                .set("routed_away", self.router.routed_away() as i64)
                .set("per_shard", per_shard)
        } else {
            let bodies: Vec<Json> = agg.bodies.into_iter().map(|(_, b)| b).collect();
            let merged = wire::merge_admin(&bodies);
            if agg.cmd == AdminCmd::Metrics {
                merged
                    .set("routed_away", self.router.routed_away() as i64)
                    .set("shed_requests", self.shed_requests as i64)
                    .set("slow_consumer_disconnects", self.slow_consumer_disconnects as i64)
                    .set("failover_checkpoint", self.failover_checkpoint as i64)
                    .set("failover_regen", self.failover_regen as i64)
                    .set("parked_requests", self.parked.len())
                    .set("retained_checkpoints", self.ckpts.len())
            } else {
                merged
            }
        };
        let body = if agg.legacy {
            body.set("deprecated", true)
        } else {
            body.set("v", 1i64).set("cmd", agg.cmd.name())
        };
        (agg.conn, body)
    }

    fn write_conns(&mut self) {
        let mut fired: Vec<Mark> = Vec::new();
        for (&cid, conn) in self.conns.iter_mut() {
            while conn.wpos < conn.wbuf.len() {
                match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                    Ok(0) => {
                        self.dead.push(cid);
                        break;
                    }
                    Ok(n) => conn.wpos += n,
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        self.dead.push(cid);
                        break;
                    }
                }
            }
            // delivery watermarks: a mark fires once the socket accepted
            // every byte before it
            while conn.marks.front().map(|&(off, _)| off <= conn.wpos).unwrap_or(false) {
                if let Some((_, m)) = conn.marks.pop_front() {
                    fired.push(m);
                }
            }
            if conn.wpos == conn.wbuf.len() {
                conn.wbuf.clear();
                conn.wpos = 0;
            } else if conn.wpos > (64 << 10) {
                // reclaim the flushed prefix of a long-lived outbox
                // (mark offsets shift with it)
                for m in conn.marks.iter_mut() {
                    m.0 -= conn.wpos;
                }
                conn.wbuf.drain(..conn.wpos);
                conn.wpos = 0;
            }
            if conn.outbox_len() > MAX_OUTBOX {
                eprintln!(
                    "server: disconnecting slow consumer (conn {cid}, {} bytes buffered)",
                    conn.outbox_len()
                );
                self.slow_consumer_disconnects += 1;
                self.dead.push(cid);
            }
        }
        if let Some(d) = &mut self.durable {
            for m in fired {
                match m {
                    Mark::Progress(gid, tokens) => {
                        let _ = d.journal.append(&journal::progress_record(gid, tokens));
                    }
                    Mark::Done(gid) => {
                        let _ = d.journal.append(&journal::done_record(gid));
                        d.store.remove(gid);
                    }
                }
            }
        }
    }

    /// Drop closed connections; cancel their routed in-flight requests
    /// on the owning shards (every gid still reaches its Terminal event)
    /// and release their parked — queued-but-unrouted — requests, which
    /// no shard will ever answer for.
    fn reap(&mut self) {
        while let Some(cid) = self.dead.pop() {
            let Some(conn) = self.conns.remove(&cid) else { continue };
            for gid in conn.inflight {
                match self.routes.get(&gid).map(|e| e.shard) {
                    Some(Some(shard)) => self.shards[shard].cancel(gid, cid),
                    Some(None) => {
                        self.routes.remove(&gid);
                        self.ckpts.remove(&gid);
                        self.parked.retain(|&g| g != gid);
                    }
                    None => {}
                }
            }
        }
    }

    /// Best-effort outbox flush before exit, bounded by `budget`.
    fn flush_all(&mut self, budget: Duration) {
        let deadline = Instant::now() + budget;
        loop {
            self.write_conns();
            self.reap();
            let pending = self.conns.values().any(|c| c.outbox_len() > 0);
            if !pending || Instant::now() >= deadline {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}
