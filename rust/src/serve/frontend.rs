//! Nonblocking event-loop front end: one poll loop multiplexes every
//! client socket (hand-rolled `set_nonblocking` + readiness polling —
//! mio is not in the offline vendor set), frames JSON lines in
//! per-connection buffers, routes parsed ops to worker shards through
//! the [`Router`] and fans shard events back to the owning connections.
//! Replaces the old two-threads-per-connection design and its
//! self-connect accept wakeup: all socket work happens here, and shard
//! events arrive on one mpsc receiver whose 1 ms `recv_timeout` doubles
//! as the idle wait (a shard event wakes the loop immediately; fresh
//! socket bytes wait out at most the timeout).
//!
//! Backpressure: response lines queue in a per-connection outbox; a
//! consumer that stops reading past `MAX_OUTBOX` buffered bytes is
//! disconnected rather than ballooning memory. A closed connection's
//! in-flight requests are cancelled on their shards so the routing table
//! and load accounting converge.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::json::Json;

use super::router::Router;
use super::shard::{ConnId, FrontEvent, Gid, ShardHandle, SubmitReq};
use super::wire::{self, AdminCmd, Defaults, Request};

/// Slow-consumer disconnect threshold: a connection whose un-flushed
/// outbox exceeds this many bytes is dropped.
const MAX_OUTBOX: usize = 1 << 20;

struct Conn {
    stream: TcpStream,
    /// unparsed inbound bytes (a partial JSON line)
    rbuf: Vec<u8>,
    /// outbox: rendered lines not yet written to the socket
    wbuf: Vec<u8>,
    /// write cursor into `wbuf`
    wpos: usize,
    /// generate gids owned by this connection still in flight
    inflight: Vec<Gid>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            inflight: Vec::new(),
        }
    }

    fn push_line(&mut self, j: Json) {
        self.wbuf.extend_from_slice(wire::line_of(j).as_bytes());
    }

    fn outbox_len(&self) -> usize {
        self.wbuf.len() - self.wpos
    }
}

/// One in-flight admin fan-out (correlation id → aggregation state).
struct AdminAgg {
    conn: ConnId,
    cmd: AdminCmd,
    legacy: bool,
    want: usize,
    bodies: Vec<(usize, Json)>,
}

struct Frontend {
    shards: Vec<ShardHandle>,
    router: Router,
    defaults: Defaults,
    conns: HashMap<ConnId, Conn>,
    /// gid → (shard, owning connection)
    routes: HashMap<Gid, (usize, ConnId)>,
    admin_pending: HashMap<u64, AdminAgg>,
    next_conn: ConnId,
    next_gid: Gid,
    next_corr: u64,
    draining: bool,
    drained: Vec<bool>,
    dead: Vec<ConnId>,
}

/// Run the event-loop front end until drained (a `shutdown` op or the
/// process-wide Ctrl-C flag). Owns the listener and every client socket.
pub fn run_frontend(
    listener: TcpListener,
    shards: Vec<ShardHandle>,
    ev_rx: Receiver<FrontEvent>,
    router: Router,
    defaults: Defaults,
) -> Result<()> {
    let n = shards.len();
    let fe = Frontend {
        shards,
        router,
        defaults,
        conns: HashMap::new(),
        routes: HashMap::new(),
        admin_pending: HashMap::new(),
        next_conn: 0,
        next_gid: 0,
        next_corr: 0,
        draining: false,
        drained: vec![false; n],
        dead: Vec::new(),
    };
    fe.run(listener, ev_rx)
}

impl Frontend {
    fn run(mut self, listener: TcpListener, ev_rx: Receiver<FrontEvent>) -> Result<()> {
        listener.set_nonblocking(true)?;
        loop {
            if !self.draining && super::shutdown_requested() {
                self.begin_drain();
            }
            self.accept(&listener);
            self.read_conns();
            loop {
                match ev_rx.try_recv() {
                    Ok(ev) => self.handle_event(ev),
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            }
            self.write_conns();
            self.reap();
            if self.draining && self.drained.iter().all(|&d| d) {
                // every shard has delivered its final lines; flush what
                // the sockets will take, then exit
                self.flush_all(Duration::from_millis(500));
                return Ok(());
            }
            // idle wait: a shard event wakes us immediately; fresh socket
            // bytes wait out at most the 1 ms timeout
            match ev_rx.recv_timeout(Duration::from_millis(1)) {
                Ok(ev) => self.handle_event(ev),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
    }

    fn begin_drain(&mut self) {
        if self.draining {
            return;
        }
        self.draining = true;
        // commands already queued (submits, admins) are processed before
        // the Drain marker — channel order is the drain barrier
        for h in &self.shards {
            h.drain();
        }
    }

    fn accept(&mut self, listener: &TcpListener) {
        if self.draining {
            return;
        }
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let cid = self.next_conn;
                    self.next_conn += 1;
                    self.conns.insert(cid, Conn::new(stream));
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
    }

    fn read_conns(&mut self) {
        let cids: Vec<ConnId> = self.conns.keys().copied().collect();
        for cid in cids {
            let Some(mut conn) = self.conns.remove(&cid) else { continue };
            let mut closed = false;
            let mut buf = [0u8; 4096];
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        closed = true;
                        break;
                    }
                    Ok(n) => conn.rbuf.extend_from_slice(&buf[..n]),
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        closed = true;
                        break;
                    }
                }
            }
            while let Some(pos) = conn.rbuf.iter().position(|&b| b == b'\n') {
                let raw: Vec<u8> = conn.rbuf.drain(..=pos).collect();
                let text = String::from_utf8_lossy(&raw);
                let line = text.trim();
                if !line.is_empty() {
                    self.handle_line(cid, &mut conn, line);
                }
            }
            self.conns.insert(cid, conn);
            if closed {
                self.dead.push(cid);
            }
        }
    }

    fn handle_line(&mut self, cid: ConnId, conn: &mut Conn, line: &str) {
        let req = match wire::parse_request(line, &self.defaults) {
            Ok(r) => r,
            Err(e) => {
                conn.push_line(Json::obj().set("ok", false).set("error", format!("{e:#}")));
                return;
            }
        };
        match req {
            Request::Ping => conn.push_line(Json::obj().set("ok", true)),
            Request::Shutdown => {
                conn.push_line(Json::obj().set("ok", true));
                self.begin_drain();
            }
            Request::Cancel { id } => match self.routes.get(&id) {
                // the owning shard answers after the final line, keeping
                // the old final-then-ack ordering on the wire
                Some(&(shard, _)) => self.shards[shard].cancel(id, cid),
                None => conn.push_line(Json::obj().set("ok", true).set("cancelled", false)),
            },
            Request::Admin { cmd, legacy } => {
                if self.draining {
                    conn.push_line(
                        Json::obj().set("ok", false).set("error", "server shutting down"),
                    );
                    return;
                }
                let corr = self.next_corr;
                self.next_corr += 1;
                self.admin_pending.insert(
                    corr,
                    AdminAgg {
                        conn: cid,
                        cmd,
                        legacy,
                        want: self.shards.len(),
                        bodies: Vec::new(),
                    },
                );
                for h in &self.shards {
                    h.admin(corr, cmd);
                }
            }
            Request::Generate { gen, engine, stream, deadline_secs, priority } => {
                if self.draining {
                    conn.push_line(
                        Json::obj().set("ok", false).set("error", "server shutting down"),
                    );
                    return;
                }
                let place = self.router.place(&gen.prompt);
                let gid = self.next_gid;
                self.next_gid += 1;
                self.routes.insert(gid, (place.shard, cid));
                conn.inflight.push(gid);
                self.shards[place.shard].submit(SubmitReq {
                    gid,
                    conn: cid,
                    gen,
                    engine,
                    stream,
                    deadline_secs,
                    priority,
                });
            }
        }
    }

    fn handle_event(&mut self, ev: FrontEvent) {
        match ev {
            FrontEvent::Line { conn, line } => {
                // lines for a connection that already went away are dropped
                if let Some(c) = self.conns.get_mut(&conn) {
                    c.wbuf.extend_from_slice(line.as_bytes());
                }
            }
            FrontEvent::Terminal { conn, shard, gid } => {
                self.router.finished(shard);
                self.routes.remove(&gid);
                if let Some(c) = self.conns.get_mut(&conn) {
                    c.inflight.retain(|&g| g != gid);
                }
            }
            FrontEvent::Admin { corr, shard, body } => {
                let done = match self.admin_pending.get_mut(&corr) {
                    Some(agg) => {
                        agg.bodies.push((shard, body));
                        agg.bodies.len() >= agg.want
                    }
                    None => false,
                };
                if done {
                    if let Some(agg) = self.admin_pending.remove(&corr) {
                        let (conn, body) = self.render_admin(agg);
                        if let Some(c) = self.conns.get_mut(&conn) {
                            c.push_line(body);
                        }
                    }
                }
            }
            FrontEvent::Drained { shard } => {
                if let Some(d) = self.drained.get_mut(shard) {
                    *d = true;
                }
            }
        }
    }

    /// Assemble the final admin response from the per-shard bodies: a
    /// verbatim pass-through at one shard, the documented merge above it,
    /// and the structured per-shard dump for `cmd:"shards"`.
    fn render_admin(&self, mut agg: AdminAgg) -> (ConnId, Json) {
        agg.bodies.sort_by_key(|(s, _)| *s);
        let body = if agg.cmd == AdminCmd::Shards {
            let per_shard: Vec<Json> = agg
                .bodies
                .iter()
                .map(|(s, b)| {
                    b.clone()
                        .set("placed", self.router.placed(*s) as i64)
                        .set("load", self.router.load(*s))
                })
                .collect();
            Json::obj()
                .set("ok", true)
                .set("shards", self.shards.len())
                .set("routed_away", self.router.routed_away() as i64)
                .set("per_shard", per_shard)
        } else {
            let bodies: Vec<Json> = agg.bodies.into_iter().map(|(_, b)| b).collect();
            wire::merge_admin(&bodies)
        };
        let body = if agg.legacy {
            body.set("deprecated", true)
        } else {
            body.set("v", 1i64).set("cmd", agg.cmd.name())
        };
        (agg.conn, body)
    }

    fn write_conns(&mut self) {
        for (&cid, conn) in self.conns.iter_mut() {
            while conn.wpos < conn.wbuf.len() {
                match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                    Ok(0) => {
                        self.dead.push(cid);
                        break;
                    }
                    Ok(n) => conn.wpos += n,
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        self.dead.push(cid);
                        break;
                    }
                }
            }
            if conn.wpos == conn.wbuf.len() {
                conn.wbuf.clear();
                conn.wpos = 0;
            } else if conn.wpos > (64 << 10) {
                // reclaim the flushed prefix of a long-lived outbox
                conn.wbuf.drain(..conn.wpos);
                conn.wpos = 0;
            }
            if conn.outbox_len() > MAX_OUTBOX {
                eprintln!(
                    "server: disconnecting slow consumer (conn {cid}, {} bytes buffered)",
                    conn.outbox_len()
                );
                self.dead.push(cid);
            }
        }
    }

    /// Drop closed connections; cancel their in-flight requests on the
    /// owning shards so every gid still reaches its Terminal event.
    fn reap(&mut self) {
        while let Some(cid) = self.dead.pop() {
            let Some(conn) = self.conns.remove(&cid) else { continue };
            for gid in conn.inflight {
                if let Some(&(shard, _)) = self.routes.get(&gid) {
                    self.shards[shard].cancel(gid, cid);
                }
            }
        }
    }

    /// Best-effort outbox flush before exit, bounded by `budget`.
    fn flush_all(&mut self, budget: Duration) {
        let deadline = Instant::now() + budget;
        loop {
            self.write_conns();
            self.reap();
            let pending = self.conns.values().any(|c| c.outbox_len() > 0);
            if !pending || Instant::now() >= deadline {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}
