//! Worker shard: one `Coordinator` + `Backend` (+ private KV pool /
//! prefix cache) driven over a command/event channel pair. The shard
//! loop runs on the thread that owns the backend — whose handles are not
//! `Send` — and is the only code that touches it; the front end speaks
//! to it exclusively through [`ShardHandle`] and reads rendered response
//! lines plus lifecycle events back on one shared mpsc receiver.
//!
//! Wire ids are global (`Gid`, assigned by the front end in parse
//! order); the shard maps them to its coordinator's local `RequestId`s.
//! Every submitted gid produces exactly one [`FrontEvent::Terminal`] —
//! on success, failure, cancellation or rejected admission — which is
//! what lets the front end keep its routing table and per-shard load
//! accounting exact.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};

use crate::config::EngineKind;
use crate::coordinator::{Coordinator, Event, RequestId, RequestState, SubmitOpts};
use crate::engine::GenRequest;
use crate::json::Json;
use crate::tokenizer;

use super::wire::{self, AdminCmd};

/// Front-end connection id.
pub type ConnId = u64;
/// Wire-visible (global) request id, assigned by the front end in parse
/// order across all shards.
pub type Gid = u64;

/// A parsed `generate` op bound for a shard.
pub struct SubmitReq {
    pub gid: Gid,
    pub conn: ConnId,
    pub gen: GenRequest,
    pub engine: Option<EngineKind>,
    pub stream: bool,
    pub deadline_secs: Option<f64>,
    pub priority: i32,
}

/// Commands a shard consumes (front end → shard).
pub enum ShardCmd {
    Submit(Box<SubmitReq>),
    /// cancel gid; the ack line goes to `conn` (the canceller), which may
    /// differ from the request's owning connection
    Cancel { gid: Gid, conn: ConnId },
    /// admin subcommand; the body fans back in under correlation id `corr`
    Admin { corr: u64, cmd: AdminCmd },
    /// stop admitting, run the in-flight set dry, then exit the loop
    Drain,
}

/// Events a shard emits (shard → front end).
pub enum FrontEvent {
    /// a rendered response line for connection `conn`
    Line { conn: ConnId, line: String },
    /// gid reached a terminal state on `shard` (route/load cleanup)
    Terminal { conn: ConnId, shard: usize, gid: Gid },
    /// one shard's admin body for fan-in under `corr`
    Admin { corr: u64, shard: usize, body: Json },
    /// the shard drained and exited its loop
    Drained { shard: usize },
}

/// Cloneable front-end handle to a shard's command channel. Sends to a
/// shard that already exited are silently dropped — a shard only exits
/// after drain, once every outcome the front end still expects has been
/// delivered.
#[derive(Clone)]
pub struct ShardHandle {
    id: usize,
    cmd_tx: Sender<ShardCmd>,
}

impl ShardHandle {
    pub fn new(id: usize, cmd_tx: Sender<ShardCmd>) -> ShardHandle {
        ShardHandle { id, cmd_tx }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn submit(&self, req: SubmitReq) {
        let _ = self.cmd_tx.send(ShardCmd::Submit(Box::new(req)));
    }

    pub fn cancel(&self, gid: Gid, conn: ConnId) {
        let _ = self.cmd_tx.send(ShardCmd::Cancel { gid, conn });
    }

    pub fn admin(&self, corr: u64, cmd: AdminCmd) {
        let _ = self.cmd_tx.send(ShardCmd::Admin { corr, cmd });
    }

    pub fn drain(&self) {
        let _ = self.cmd_tx.send(ShardCmd::Drain);
    }
}

/// Per-request reply routing held by the shard loop.
struct PendingReq {
    gid: Gid,
    conn: ConnId,
    stream: bool,
}

/// The shard device loop: drain commands, tick the scheduler, emit
/// response lines and lifecycle events. Returns after a `Drain` command
/// (or a disconnected front end) once the in-flight set is dry, sending
/// [`FrontEvent::Drained`] last.
pub fn run_shard(
    shard: usize,
    coord: &mut Coordinator<'_>,
    cmd_rx: Receiver<ShardCmd>,
    ev_tx: Sender<FrontEvent>,
) {
    let mut pending: HashMap<RequestId, PendingReq> = HashMap::new();
    let mut draining = false;
    loop {
        // block when there is nothing to schedule, drain otherwise
        if coord.idle() && !draining {
            match cmd_rx.recv() {
                Ok(cmd) => {
                    handle_cmd(shard, cmd, coord, &mut pending, &ev_tx, &mut draining)
                }
                Err(_) => draining = true,
            }
        }
        loop {
            match cmd_rx.try_recv() {
                Ok(cmd) => {
                    handle_cmd(shard, cmd, coord, &mut pending, &ev_tx, &mut draining)
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    draining = true;
                    break;
                }
            }
        }
        if draining && coord.idle() {
            break;
        }
        for ev in coord.tick() {
            route_event(shard, ev, coord, &mut pending, &ev_tx);
        }
    }
    coord.sync_backend_counters();
    let _ = ev_tx.send(FrontEvent::Drained { shard });
}

fn handle_cmd(
    shard: usize,
    cmd: ShardCmd,
    coord: &mut Coordinator<'_>,
    pending: &mut HashMap<RequestId, PendingReq>,
    ev_tx: &Sender<FrontEvent>,
    draining: &mut bool,
) {
    match cmd {
        ShardCmd::Submit(sr) => {
            let sr = *sr;
            let opts = SubmitOpts {
                engine: sr.engine,
                deadline_secs: sr.deadline_secs,
                priority: sr.priority,
            };
            match coord.submit_opts(sr.gen, opts) {
                Ok(local) => {
                    if sr.stream {
                        // ack with the id so the client can cancel
                        send_line(
                            ev_tx,
                            sr.conn,
                            Json::obj()
                                .set("ok", true)
                                .set("id", sr.gid as i64)
                                .set("stream", true)
                                .set("queued", true),
                        );
                    }
                    pending.insert(
                        local,
                        PendingReq { gid: sr.gid, conn: sr.conn, stream: sr.stream },
                    );
                }
                Err(e) => {
                    send_line(
                        ev_tx,
                        sr.conn,
                        Json::obj().set("ok", false).set("error", format!("{e:#}")),
                    );
                    let _ = ev_tx.send(FrontEvent::Terminal {
                        conn: sr.conn,
                        shard,
                        gid: sr.gid,
                    });
                }
            }
        }
        ShardCmd::Cancel { gid, conn } => {
            let local = pending.iter().find(|(_, p)| p.gid == gid).map(|(&l, _)| l);
            let cancelled = match local {
                Some(l) => coord.cancel(l),
                None => false,
            };
            if cancelled {
                if let Some(l) = local {
                    if let Some(p) = pending.remove(&l) {
                        // final line (with the partial text) first, ack after
                        send_final(shard, l, &p, coord, ev_tx);
                    }
                }
            }
            send_line(ev_tx, conn, Json::obj().set("ok", true).set("cancelled", cancelled));
        }
        ShardCmd::Admin { corr, cmd } => {
            let body = match cmd {
                AdminCmd::Metrics => wire::metrics_body(coord),
                AdminCmd::Kv => wire::kv_body(coord),
                AdminCmd::Cache => wire::cache_body(coord),
                AdminCmd::Shards => wire::shard_body(shard, coord),
            };
            let _ = ev_tx.send(FrontEvent::Admin { corr, shard, body });
        }
        ShardCmd::Drain => {
            *draining = true;
            for ev in coord.begin_drain() {
                if let Event::Draining { id } = ev {
                    if let Some(p) = pending.get(&id) {
                        if p.stream {
                            send_line(
                                ev_tx,
                                p.conn,
                                Json::obj()
                                    .set("ok", true)
                                    .set("id", p.gid as i64)
                                    .set("draining", true)
                                    .set("done", false),
                            );
                        }
                    }
                }
            }
        }
    }
}

fn route_event(
    shard: usize,
    ev: Event,
    coord: &Coordinator<'_>,
    pending: &mut HashMap<RequestId, PendingReq>,
    ev_tx: &Sender<FrontEvent>,
) {
    match ev {
        // swap transitions — including a recovered SwapFault, which only
        // re-queues the request — are scheduler-internal (output is
        // unaffected); operators observe them through the admin ops.
        // Draining is emitted by begin_drain, never by tick.
        Event::Started { .. }
        | Event::SwappedOut { .. }
        | Event::Resumed { .. }
        | Event::SwapFault { .. }
        | Event::Draining { .. } => {}
        Event::Step { id, new_tokens, step, .. } => {
            if let Some(p) = pending.get(&id) {
                if p.stream && !new_tokens.is_empty() {
                    send_line(
                        ev_tx,
                        p.conn,
                        Json::obj()
                            .set("ok", true)
                            .set("id", p.gid as i64)
                            .set("stream", true)
                            .set("step", step)
                            .set("delta", tokenizer::decode(&new_tokens))
                            .set("done", false),
                    );
                }
            }
        }
        Event::Finished { id } | Event::Cancelled { id } | Event::Failed { id, .. } => {
            if let Some(p) = pending.remove(&id) {
                send_final(shard, id, &p, coord, ev_tx);
            }
        }
    }
}

/// The terminal response line for a request (results keyed by id — the
/// loop never assumes "the last submitted request finished"), followed by
/// the [`FrontEvent::Terminal`] the front end uses for cleanup.
fn send_final(
    shard: usize,
    local: RequestId,
    p: &PendingReq,
    coord: &Coordinator<'_>,
    ev_tx: &Sender<FrontEvent>,
) {
    let resp = match coord.get(local) {
        None => Json::obj().set("ok", false).set("error", "request vanished"),
        Some(tr) => match (&tr.state, &tr.result) {
            (RequestState::Done, Some(r)) => Json::obj()
                .set("ok", true)
                .set("id", p.gid as i64)
                .set("done", true)
                .set("text", r.text())
                .set("tokens", r.tokens.len())
                .set("tok_per_s", r.stats.throughput())
                .set("tau", r.stats.accept_len())
                .set(
                    "modes",
                    Json::obj()
                        .set("full", r.stats.full_steps)
                        .set("partial", r.stats.partial_steps)
                        .set("refresh", r.stats.refresh_steps),
                )
                .set("latency_s", tr.service_secs)
                .set("ttft_s", tr.ttft_secs)
                .set("steps", tr.steps),
            (RequestState::Cancelled, r) => Json::obj()
                .set("ok", true)
                .set("id", p.gid as i64)
                .set("done", true)
                .set("cancelled", true)
                .set("text", r.as_ref().map(|r| r.text()).unwrap_or_default()),
            (RequestState::Failed(e), _) => Json::obj()
                .set("ok", false)
                .set("id", p.gid as i64)
                .set("done", true)
                .set("error", e.as_str()),
            _ => Json::obj()
                .set("ok", false)
                .set("id", p.gid as i64)
                .set("error", "not finished"),
        },
    };
    send_line(ev_tx, p.conn, resp);
    let _ = ev_tx.send(FrontEvent::Terminal { conn: p.conn, shard, gid: p.gid });
}

fn send_line(ev_tx: &Sender<FrontEvent>, conn: ConnId, j: Json) {
    let _ = ev_tx.send(FrontEvent::Line { conn, line: wire::line_of(j) });
}
