//! Worker shard: one `Coordinator` + `Backend` (+ private KV pool /
//! prefix cache) driven over a command/event channel pair. The shard
//! loop runs on the thread that owns the backend — whose handles are not
//! `Send` — and is the only code that touches it; the front end speaks
//! to it exclusively through [`ShardHandle`] and reads rendered response
//! lines plus lifecycle events back on one shared mpsc receiver.
//!
//! Wire ids are global (`Gid`, assigned by the front end in parse
//! order); the shard maps them to its coordinator's local `RequestId`s.
//! Every submitted gid produces exactly one [`FrontEvent::Terminal`] —
//! on success, failure, cancellation or rejected admission — which is
//! what lets the front end keep its routing table and per-shard load
//! accounting exact.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;

use crate::config::EngineKind;
use crate::coordinator::{Coordinator, Event, RequestId, RequestState, SubmitOpts};
use crate::engine::{GenRequest, SessionCheckpoint};
use crate::json::Json;
use crate::tokenizer;

use super::wire::{self, AdminCmd};

/// Front-end connection id.
pub type ConnId = u64;
/// Wire-visible (global) request id, assigned by the front end in parse
/// order across all shards.
pub type Gid = u64;

/// A parsed `generate` op bound for a shard.
pub struct SubmitReq {
    pub gid: Gid,
    pub conn: ConnId,
    pub gen: GenRequest,
    pub engine: Option<EngineKind>,
    /// per-request `engine=auto` (policy layer, DESIGN.md §16)
    pub auto: bool,
    pub stream: bool,
    pub deadline_secs: Option<f64>,
    pub priority: i32,
    /// failover resume point: the last checkpoint taken on the dead
    /// shard (None → deterministic regeneration from the prompt)
    pub resume: Option<Box<SessionCheckpoint>>,
    /// tokens the client already received in deltas before failover —
    /// re-emitted tokens below this absolute index are suppressed so the
    /// client's concatenated stream stays byte-identical
    pub skip_tokens: usize,
    /// the queued ack line already went out before the shard died
    pub ack_sent: bool,
}

/// Commands a shard consumes (front end → shard).
pub enum ShardCmd {
    Submit(Box<SubmitReq>),
    /// cancel gid; the ack line goes to `conn` (the canceller), which may
    /// differ from the request's owning connection
    Cancel { gid: Gid, conn: ConnId },
    /// admin subcommand; the body fans back in under correlation id `corr`
    Admin { corr: u64, cmd: AdminCmd },
    /// the front end finished re-homing a dead shard's sessions — the
    /// supervisor may now restart the generation (barrier that prevents
    /// a restarted shard double-executing failed-over requests)
    FailoverDone,
    /// stop admitting, run the in-flight set dry, then exit the loop
    Drain,
}

/// Events a shard emits (shard → front end).
pub enum FrontEvent {
    /// a rendered response line for connection `conn`
    Line { conn: ConnId, line: String },
    /// gid reached a terminal state on `shard` (route/load cleanup)
    Terminal { conn: ConnId, shard: usize, gid: Gid },
    /// one shard's admin body for fan-in under `corr`
    Admin { corr: u64, shard: usize, body: Json },
    /// periodic failover checkpoint for gid (front-end-owned storage)
    Checkpoint { gid: Gid, ck: Box<SessionCheckpoint> },
    /// `tokens` deltas have been emitted to gid's client so far —
    /// the front end's `skip_tokens` for a later failover
    Progress { gid: Gid, tokens: usize },
    /// the queued ack line for gid went out (suppress it after failover)
    Acked { gid: Gid },
    /// a cancel ack for gid went out (supervisor ledger bookkeeping;
    /// the front end ignores it)
    CancelDone { gid: Gid },
    /// the shard's generation died; the front end must re-home its
    /// in-flight sessions and answer with `FailoverDone`
    ShardDown { shard: usize },
    /// a restarted generation is accepting submits again
    ShardUp { shard: usize },
    /// the shard drained and exited its loop
    Drained { shard: usize },
}

/// Cloneable front-end handle to a shard's command channel. Sends to a
/// shard that already exited are silently dropped — a shard only exits
/// after drain, once every outcome the front end still expects has been
/// delivered.
#[derive(Clone)]
pub struct ShardHandle {
    id: usize,
    cmd_tx: Sender<ShardCmd>,
}

impl ShardHandle {
    pub fn new(id: usize, cmd_tx: Sender<ShardCmd>) -> ShardHandle {
        ShardHandle { id, cmd_tx }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn submit(&self, req: SubmitReq) {
        let _ = self.cmd_tx.send(ShardCmd::Submit(Box::new(req)));
    }

    pub fn cancel(&self, gid: Gid, conn: ConnId) {
        let _ = self.cmd_tx.send(ShardCmd::Cancel { gid, conn });
    }

    pub fn admin(&self, corr: u64, cmd: AdminCmd) {
        let _ = self.cmd_tx.send(ShardCmd::Admin { corr, cmd });
    }

    pub fn drain(&self) {
        let _ = self.cmd_tx.send(ShardCmd::Drain);
    }

    pub fn failover_done(&self) {
        let _ = self.cmd_tx.send(ShardCmd::FailoverDone);
    }
}

/// Liveness pulse a supervised shard ticks every loop iteration. The
/// supervisor reads it to distinguish a wedged backend (busy, beats
/// frozen) from an idle shard blocked on its command channel.
#[derive(Default)]
pub struct Pulse {
    pub beats: AtomicU64,
    /// inside `Coordinator::tick` (device work) right now
    pub busy: AtomicBool,
}

/// One-shot failpoint trigger: armed once by the supervisor, fired at
/// most once across all generation incarnations of a shard (a restarted
/// generation must not re-fire the fault that killed its predecessor).
#[derive(Clone)]
pub struct OneShot {
    armed: Arc<AtomicBool>,
    pub value: u64,
}

impl OneShot {
    pub fn new(value: u64) -> OneShot {
        OneShot { armed: Arc::new(AtomicBool::new(true)), value }
    }

    /// Consume the trigger; true exactly once.
    pub fn fire(&self) -> bool {
        self.armed.swap(false, Ordering::SeqCst)
    }

    pub fn is_armed(&self) -> bool {
        self.armed.load(Ordering::SeqCst)
    }
}

/// Supervision/failpoint options for a shard loop; `Default` is the
/// plain unsupervised loop (exactly the pre-supervision behavior).
#[derive(Default, Clone)]
pub struct ShardOpts {
    /// liveness pulse shared with the supervisor
    pub pulse: Option<Arc<Pulse>>,
    /// panic the loop after this many routed `Step` events (one-shot)
    pub panic_after_steps: Option<OneShot>,
    /// stall one tick for this many ms (one-shot; with a heartbeat
    /// configured this reads as a wedged backend)
    pub slow_op_ms: Option<OneShot>,
    /// checkpoint streak: snapshot each session every N of its scheduler
    /// steps for failover (0 = off)
    pub checkpoint_every: usize,
    /// restart count carried into this incarnation's registry
    pub restarts: u64,
    /// cold-restart recovery counters (DESIGN.md §17), seeded into the
    /// registry so `admin metrics` reports them; the front end sets them
    /// on shard 0 only (the cross-shard merge sums counters)
    pub recovered_sessions: u64,
    pub journal_replayed: u64,
    pub journal_torn_records: u64,
}

/// Per-request reply routing held by the shard loop.
struct PendingReq {
    gid: Gid,
    conn: ConnId,
    stream: bool,
    /// absolute index of the next token a `Step` event will carry
    /// (checkpoint-resumed sessions start past the preloaded tokens)
    next_abs: usize,
    /// suppress delta tokens below this absolute index (already
    /// delivered before a failover)
    skip: usize,
    /// the resume checkpoint's emitted-token history: a durable
    /// checkpoint can be *ahead* of the client's delivered watermark
    /// (taken after tokens were generated but before their delivery was
    /// journaled), and `Step` events index past the preloaded tokens —
    /// the gap `[skip, resumed_tokens)` is re-emitted from here
    resume_emitted: Option<Vec<u32>>,
}

/// The shard device loop: drain commands, tick the scheduler, emit
/// response lines and lifecycle events. Returns after a `Drain` command
/// (or a disconnected front end) once the in-flight set is dry, sending
/// [`FrontEvent::Drained`] last.
pub fn run_shard(
    shard: usize,
    coord: &mut Coordinator<'_>,
    cmd_rx: Receiver<ShardCmd>,
    ev_tx: Sender<FrontEvent>,
) {
    run_shard_with(shard, coord, cmd_rx, ev_tx, ShardOpts::default());
}

/// [`run_shard`] with supervision hooks: a liveness pulse, periodic
/// failover checkpoints, and the shard-level failpoints (DESIGN.md §15).
pub fn run_shard_with(
    shard: usize,
    coord: &mut Coordinator<'_>,
    cmd_rx: Receiver<ShardCmd>,
    ev_tx: Sender<FrontEvent>,
    opts: ShardOpts,
) {
    let mut pending: HashMap<RequestId, PendingReq> = HashMap::new();
    let mut draining = false;
    let mut steps_routed: u64 = 0;
    coord.registry.restarts = opts.restarts;
    coord.registry.recovered_sessions = opts.recovered_sessions;
    coord.registry.journal_replayed = opts.journal_replayed;
    coord.registry.journal_torn_records = opts.journal_torn_records;
    loop {
        if let Some(p) = &opts.pulse {
            p.beats.fetch_add(1, Ordering::SeqCst);
        }
        // block when there is nothing to schedule, drain otherwise
        if coord.idle() && !draining {
            match cmd_rx.recv() {
                Ok(cmd) => {
                    handle_cmd(shard, cmd, coord, &mut pending, &ev_tx, &mut draining)
                }
                Err(_) => draining = true,
            }
        }
        loop {
            match cmd_rx.try_recv() {
                Ok(cmd) => {
                    handle_cmd(shard, cmd, coord, &mut pending, &ev_tx, &mut draining)
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    draining = true;
                    break;
                }
            }
        }
        if draining && coord.idle() {
            break;
        }
        if let Some(p) = &opts.pulse {
            p.busy.store(true, Ordering::SeqCst);
        }
        // failpoint: one wedged tick — under a configured heartbeat the
        // supervisor declares this generation dead and fails over
        if let Some(slow) = &opts.slow_op_ms {
            // only stall real work — an idle tick would fire the
            // failpoint before any request is in flight
            if !coord.idle() && slow.fire() {
                std::thread::sleep(std::time::Duration::from_millis(slow.value));
            }
        }
        let evs = coord.tick();
        if let Some(p) = &opts.pulse {
            p.busy.store(false, Ordering::SeqCst);
        }
        let mut panic_due = false;
        for ev in evs {
            // capture before route_event consumes the event
            let ck_due = match &ev {
                Event::Step { id, step, finished: false, .. }
                    if opts.checkpoint_every > 0 && *step % opts.checkpoint_every == 0 =>
                {
                    Some(*id)
                }
                _ => None,
            };
            let is_step = matches!(ev, Event::Step { .. });
            route_event(shard, ev, coord, &mut pending, &ev_tx);
            if let Some(id) = ck_due {
                if let (Some(ck), Some(p)) = (coord.checkpoint(id), pending.get(&id)) {
                    let _ = ev_tx
                        .send(FrontEvent::Checkpoint { gid: p.gid, ck: Box::new(ck) });
                }
            }
            if is_step {
                steps_routed += 1;
                if let Some(panic_at) = &opts.panic_after_steps {
                    if panic_at.is_armed() && steps_routed >= panic_at.value {
                        panic_due = true;
                    }
                }
            }
        }
        if panic_due {
            if let Some(panic_at) = &opts.panic_after_steps {
                if panic_at.fire() {
                    panic!("failpoint: shard_panic after {steps_routed} steps");
                }
            }
        }
    }
    coord.sync_backend_counters();
    let _ = ev_tx.send(FrontEvent::Drained { shard });
}

fn handle_cmd(
    shard: usize,
    cmd: ShardCmd,
    coord: &mut Coordinator<'_>,
    pending: &mut HashMap<RequestId, PendingReq>,
    ev_tx: &Sender<FrontEvent>,
    draining: &mut bool,
) {
    match cmd {
        ShardCmd::Submit(sr) => {
            let sr = *sr;
            let opts = SubmitOpts {
                engine: sr.engine,
                deadline_secs: sr.deadline_secs,
                priority: sr.priority,
                auto: sr.auto,
            };
            let resume_emitted = sr.resume.as_ref().map(|b| b.emitted.clone());
            match coord.submit_failover(sr.gen, opts, sr.resume.map(|b| *b)) {
                Ok(local) => {
                    if sr.stream && !sr.ack_sent {
                        // ack with the id so the client can cancel
                        send_line(
                            ev_tx,
                            sr.conn,
                            Json::obj()
                                .set("ok", true)
                                .set("id", sr.gid as i64)
                                .set("stream", true)
                                .set("queued", true),
                        );
                        let _ = ev_tx.send(FrontEvent::Acked { gid: sr.gid });
                    }
                    pending.insert(
                        local,
                        PendingReq {
                            gid: sr.gid,
                            conn: sr.conn,
                            stream: sr.stream,
                            next_abs: 0,
                            skip: sr.skip_tokens,
                            resume_emitted,
                        },
                    );
                }
                Err(e) => {
                    send_line(
                        ev_tx,
                        sr.conn,
                        Json::obj().set("ok", false).set("error", format!("{e:#}")),
                    );
                    let _ = ev_tx.send(FrontEvent::Terminal {
                        conn: sr.conn,
                        shard,
                        gid: sr.gid,
                    });
                }
            }
        }
        ShardCmd::Cancel { gid, conn } => {
            let local = pending.iter().find(|(_, p)| p.gid == gid).map(|(&l, _)| l);
            let cancelled = match local {
                Some(l) => coord.cancel(l),
                None => false,
            };
            if cancelled {
                if let Some(l) = local {
                    if let Some(p) = pending.remove(&l) {
                        // final line (with the partial text) first, ack after
                        send_final(shard, l, &p, coord, ev_tx, false);
                    }
                }
            }
            send_line(ev_tx, conn, Json::obj().set("ok", true).set("cancelled", cancelled));
            let _ = ev_tx.send(FrontEvent::CancelDone { gid });
        }
        ShardCmd::Admin { corr, cmd } => {
            let body = match cmd {
                AdminCmd::Metrics => wire::metrics_body(coord),
                AdminCmd::Kv => wire::kv_body(coord),
                AdminCmd::Cache => wire::cache_body(coord),
                AdminCmd::Shards => wire::shard_body(shard, coord),
            };
            let _ = ev_tx.send(FrontEvent::Admin { corr, shard, body });
        }
        // the barrier only matters to a supervisor waiting to restart; a
        // live generation has nothing to do with it
        ShardCmd::FailoverDone => {}
        ShardCmd::Drain => {
            *draining = true;
            for ev in coord.begin_drain() {
                if let Event::Draining { id } = ev {
                    if let Some(p) = pending.get(&id) {
                        if p.stream {
                            send_line(
                                ev_tx,
                                p.conn,
                                Json::obj()
                                    .set("ok", true)
                                    .set("id", p.gid as i64)
                                    .set("draining", true)
                                    .set("done", false),
                            );
                        }
                    }
                }
            }
        }
    }
}

fn route_event(
    shard: usize,
    ev: Event,
    coord: &Coordinator<'_>,
    pending: &mut HashMap<RequestId, PendingReq>,
    ev_tx: &Sender<FrontEvent>,
) {
    match ev {
        // swap transitions — including a recovered SwapFault, which only
        // re-queues the request — are scheduler-internal (output is
        // unaffected); operators observe them through the admin ops.
        // Draining is emitted by begin_drain, never by tick.
        Event::SwappedOut { .. }
        | Event::Resumed { .. }
        | Event::SwapFault { .. }
        | Event::Draining { .. } => {}
        Event::Started { id } => {
            // a checkpoint resume preloads tokens the client already
            // has; future Step events index past them (a failed resume
            // regenerated instead, so resumed_tokens reads 0 and the
            // skip filter alone dedups the re-emitted prefix). `Started`
            // also fires when a SwapFault re-queued the session for a
            // fresh run mid-incarnation: raise the skip watermark to
            // everything delivered so far so the deterministic re-run's
            // prefix is suppressed rather than duplicated on the wire.
            if let Some(p) = pending.get_mut(&id) {
                p.skip = p.skip.max(p.next_abs);
                p.next_abs = coord.get(id).map(|tr| tr.resumed_tokens).unwrap_or(0);
                // Cold-restart checkpoint resume: the durable checkpoint
                // may hold tokens past the journaled delivered watermark
                // (generated but not yet confirmed on the wire before the
                // crash). Step events start past the preloaded tokens, so
                // replay the gap from the checkpoint's emitted history.
                if p.stream && p.next_abs > p.skip {
                    if let Some(em) =
                        p.resume_emitted.as_ref().filter(|em| em.len() >= p.next_abs)
                    {
                        send_line(
                            ev_tx,
                            p.conn,
                            Json::obj()
                                .set("ok", true)
                                .set("id", p.gid as i64)
                                .set("stream", true)
                                .set("step", 0usize)
                                .set("delta", tokenizer::decode(&em[p.skip..p.next_abs]))
                                .set("done", false),
                        );
                        let _ = ev_tx
                            .send(FrontEvent::Progress { gid: p.gid, tokens: p.next_abs });
                        p.skip = p.next_abs;
                    }
                }
            }
        }
        Event::Step { id, new_tokens, step, .. } => {
            if let Some(p) = pending.get_mut(&id) {
                let base = p.next_abs;
                p.next_abs += new_tokens.len();
                if p.stream && !new_tokens.is_empty() {
                    // drop tokens the client received before failover
                    let fresh: Vec<u32> = new_tokens
                        .iter()
                        .enumerate()
                        .filter(|(j, _)| base + j >= p.skip)
                        .map(|(_, &t)| t)
                        .collect();
                    if !fresh.is_empty() {
                        send_line(
                            ev_tx,
                            p.conn,
                            Json::obj()
                                .set("ok", true)
                                .set("id", p.gid as i64)
                                .set("stream", true)
                                .set("step", step)
                                .set("delta", tokenizer::decode(&fresh))
                                .set("done", false),
                        );
                        let _ = ev_tx.send(FrontEvent::Progress {
                            gid: p.gid,
                            tokens: p.next_abs.max(p.skip),
                        });
                    }
                }
            }
        }
        Event::Finished { id } | Event::Cancelled { id } | Event::Failed { id, .. } => {
            if let Some(p) = pending.remove(&id) {
                send_final(shard, id, &p, coord, ev_tx, false);
            }
        }
        Event::DeadlineExceeded { id } => {
            if let Some(p) = pending.remove(&id) {
                send_final(shard, id, &p, coord, ev_tx, true);
            }
        }
    }
}

/// The terminal response line for a request (results keyed by id — the
/// loop never assumes "the last submitted request finished"), followed by
/// the [`FrontEvent::Terminal`] the front end uses for cleanup.
fn send_final(
    shard: usize,
    local: RequestId,
    p: &PendingReq,
    coord: &Coordinator<'_>,
    ev_tx: &Sender<FrontEvent>,
    deadline: bool,
) {
    let resp = match coord.get(local) {
        None => Json::obj().set("ok", false).set("error", "request vanished"),
        Some(tr) => match (&tr.state, &tr.result) {
            (RequestState::Done, Some(r)) => Json::obj()
                .set("ok", true)
                .set("id", p.gid as i64)
                .set("done", true)
                .set("text", r.text())
                .set("tokens", r.tokens.len())
                .set("tok_per_s", r.stats.throughput())
                .set("tau", r.stats.accept_len())
                .set(
                    "modes",
                    Json::obj()
                        .set("full", r.stats.full_steps)
                        .set("partial", r.stats.partial_steps)
                        .set("refresh", r.stats.refresh_steps),
                )
                .set("latency_s", tr.service_secs)
                .set("ttft_s", tr.ttft_secs)
                .set("steps", tr.steps),
            (RequestState::Cancelled, r) => Json::obj()
                .set("ok", true)
                .set("id", p.gid as i64)
                .set("done", true)
                .set("cancelled", true)
                .set("text", r.as_ref().map(|r| r.text()).unwrap_or_default()),
            (RequestState::Failed(e), _) => {
                let j = Json::obj()
                    .set("ok", false)
                    .set("id", p.gid as i64)
                    .set("done", true)
                    .set("error", e.as_str());
                if deadline {
                    j.set("deadline_exceeded", true)
                } else {
                    j
                }
            }
            _ => Json::obj()
                .set("ok", false)
                .set("id", p.gid as i64)
                .set("error", "not finished"),
        },
    };
    send_line(ev_tx, p.conn, resp);
    let _ = ev_tx.send(FrontEvent::Terminal { conn: p.conn, shard, gid: p.gid });
}

fn send_line(ev_tx: &Sender<FrontEvent>, conn: ConnId, j: Json) {
    let _ = ev_tx.send(FrontEvent::Line { conn, line: wire::line_of(j) });
}
