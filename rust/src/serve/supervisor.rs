//! Shard supervision (DESIGN.md §15): each shard's device loop runs as a
//! disposable **generation** on a detached thread, watched by a
//! supervisor that owns the shard's command/event channels. The
//! supervisor proxies both directions — commands forwarded to the live
//! generation, events relayed to the shared front-end channel — so when
//! a generation dies (panic, backend start failure, or a wedged backend
//! caught by the heartbeat) the supervisor can:
//!
//! 1. relay everything the dead generation still delivered (per-sender
//!    FIFO keeps shard-local ordering exact),
//! 2. answer its outstanding admin/cancel commands so fan-ins never
//!    hang,
//! 3. announce [`FrontEvent::ShardDown`] and wait for the front end's
//!    [`ShardCmd::FailoverDone`] barrier (the front end re-homes the
//!    shard's in-flight sessions from their last checkpoints — the
//!    barrier is what stops a restarted generation from double-executing
//!    them),
//! 4. restart a fresh generation with exponential backoff, bounded by
//!    `max_restarts`, degrading to an error-answering stub beyond that.
//!
//! A wedged generation cannot be killed (threads are cooperative), so it
//! is **abandoned**: the supervisor drops its event receiver — every
//! late send fails silently, so a zombie can never corrupt the wire —
//! and its command sender, which makes the zombie drain and exit on its
//! own if it ever un-wedges.

use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::json::Json;
use crate::util::failpoint::FaultSpec;

use super::shard::{ConnId, FrontEvent, Gid, OneShot, Pulse, ShardCmd, ShardOpts};
use super::wire;

/// Builds and runs one shard generation: construct the backend and
/// coordinator *inside* the call (backend handles are not `Send`, so
/// each incarnation owns a fresh one) and drive the shard loop to
/// drain. An `Err` means the generation could not start — the
/// supervisor treats it like a crash.
pub type ShardRuntime = Arc<
    dyn Fn(usize, Receiver<ShardCmd>, Sender<FrontEvent>, ShardOpts) -> Result<()>
        + Send
        + Sync,
>;

/// Supervision parameters, lifted from the serving `Config`.
#[derive(Clone)]
pub struct SupervisorCfg {
    /// declare a generation wedged when it sits busy inside a tick with
    /// a frozen pulse for this long (0 = heartbeat off)
    pub heartbeat_ms: u64,
    /// generation restarts before the shard degrades to a dead stub
    pub max_restarts: usize,
    /// checkpoint cadence forwarded to the shard loop (steps, 0 = off)
    pub checkpoint_every: usize,
    /// failpoint spec; the shard-scoped one-shots (`shard_panic@step`,
    /// `slow_op_ms`) are armed here so they fire once per shard, not
    /// once per incarnation
    pub faults: FaultSpec,
    /// cold-restart recovery counters seeded into every generation's
    /// registry (DESIGN.md §17); the front end sets them on shard 0
    /// only so the cross-shard counter-summing merge stays exact
    pub recovered_sessions: u64,
    pub journal_replayed: u64,
    pub journal_torn_records: u64,
}

struct GenShared {
    done: AtomicBool,
    panicked: AtomicBool,
}

/// One live (or dying) generation of a shard.
struct Generation {
    shared: Arc<GenShared>,
    pulse: Arc<Pulse>,
    cmd_tx: Option<Sender<ShardCmd>>,
    ev_rx: Receiver<FrontEvent>,
    join: Option<thread::JoinHandle<()>>,
    last_beats: u64,
    beats_changed: Instant,
}

/// Commands awaiting an answer from the current generation; on death the
/// supervisor answers them itself so nothing upstream hangs.
#[derive(Default)]
struct Ledger {
    /// outstanding admin correlation ids
    admins: HashSet<u64>,
    /// outstanding cancels: gid → canceller's connection
    cancels: HashMap<Gid, ConnId>,
}

fn track_event(ev: &FrontEvent, ledger: &mut Ledger) {
    match ev {
        FrontEvent::Admin { corr, .. } => {
            ledger.admins.remove(corr);
        }
        FrontEvent::CancelDone { gid } => {
            ledger.cancels.remove(gid);
        }
        _ => {}
    }
}

fn spawn_generation(
    shard: usize,
    runtime: &ShardRuntime,
    sup: &SupervisorCfg,
    panic_shot: &Option<OneShot>,
    slow_shot: &Option<OneShot>,
    restarts: u64,
) -> Generation {
    let (cmd_tx, cmd_rx) = channel::<ShardCmd>();
    let (gen_ev_tx, ev_rx) = channel::<FrontEvent>();
    let pulse = Arc::new(Pulse::default());
    let shared = Arc::new(GenShared {
        done: AtomicBool::new(false),
        panicked: AtomicBool::new(false),
    });
    let opts = ShardOpts {
        pulse: Some(Arc::clone(&pulse)),
        panic_after_steps: panic_shot.clone(),
        slow_op_ms: slow_shot.clone(),
        checkpoint_every: sup.checkpoint_every,
        restarts,
        recovered_sessions: sup.recovered_sessions,
        journal_replayed: sup.journal_replayed,
        journal_torn_records: sup.journal_torn_records,
    };
    let rt = Arc::clone(runtime);
    let sh = Arc::clone(&shared);
    let join = thread::spawn(move || {
        let outcome = catch_unwind(AssertUnwindSafe(|| rt(shard, cmd_rx, gen_ev_tx, opts)));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                eprintln!("[supervisor] shard {shard} generation failed to start: {e:#}");
                sh.panicked.store(true, Ordering::SeqCst);
            }
            Err(_) => {
                // the default panic hook already printed the message
                sh.panicked.store(true, Ordering::SeqCst);
            }
        }
        sh.done.store(true, Ordering::SeqCst);
    });
    Generation {
        shared,
        pulse,
        cmd_tx: Some(cmd_tx),
        ev_rx,
        join: Some(join),
        last_beats: 0,
        beats_changed: Instant::now(),
    }
}

/// Is the current generation dead? Covers a finished thread that
/// panicked (or failed to start) and — with a heartbeat configured — a
/// wedge: busy inside a tick with a frozen pulse past the timeout.
fn is_dead(shard: usize, gen: &mut Generation, heartbeat_ms: u64) -> bool {
    if gen.shared.done.load(Ordering::SeqCst) {
        return gen.shared.panicked.load(Ordering::SeqCst);
    }
    if heartbeat_ms > 0 {
        let beats = gen.pulse.beats.load(Ordering::SeqCst);
        if beats != gen.last_beats {
            gen.last_beats = beats;
            gen.beats_changed = Instant::now();
        } else if gen.pulse.busy.load(Ordering::SeqCst)
            && gen.beats_changed.elapsed() >= Duration::from_millis(heartbeat_ms)
        {
            eprintln!(
                "[supervisor] shard {shard}: generation wedged for {heartbeat_ms}ms, \
                 abandoning it"
            );
            return true;
        }
    }
    false
}

enum DeathOutcome {
    /// barrier passed; restart (or degrade) per the restart budget
    Restart,
    /// a drain arrived during failover: report drained and exit
    Drain,
    /// the front end is gone; exit quietly
    FrontendGone,
}

/// Tear down a dead generation: relay its remaining events, answer its
/// outstanding commands, announce `ShardDown`, and hold new commands off
/// until the front end's `FailoverDone` barrier.
fn handle_death(
    shard: usize,
    gen: Generation,
    ledger: &mut Ledger,
    cmd_rx: &Receiver<ShardCmd>,
    ev_tx: &Sender<FrontEvent>,
) -> DeathOutcome {
    // deliver everything the generation produced before dying — FIFO per
    // sender, so the front end sees a clean prefix of the shard's stream
    while let Ok(ev) = gen.ev_rx.try_recv() {
        track_event(&ev, ledger);
        if matches!(ev, FrontEvent::Drained { .. }) {
            continue;
        }
        let _ = ev_tx.send(ev);
    }
    if gen.shared.done.load(Ordering::SeqCst) {
        if let Some(j) = gen.join {
            let _ = j.join();
        }
    }
    // a wedged zombie keeps running, but its event receiver dies here —
    // every late send fails silently — and dropping cmd_tx makes it
    // drain and exit on its own if it ever un-wedges
    // (`gen` partially moved above, remaining fields drop at scope end)

    // answer what the dead generation left hanging
    let corrs: Vec<u64> = ledger.admins.drain().collect();
    for corr in corrs {
        let body = Json::obj()
            .set("ok", false)
            .set("error", format!("shard {shard} restarting"));
        let _ = ev_tx.send(FrontEvent::Admin { corr, shard, body });
    }
    let cancels: Vec<(Gid, ConnId)> = ledger.cancels.drain().collect();
    for (_gid, conn) in cancels {
        let _ = ev_tx.send(FrontEvent::Line {
            conn,
            line: wire::line_of(Json::obj().set("ok", true).set("cancelled", false)),
        });
    }
    let _ = ev_tx.send(FrontEvent::ShardDown { shard });
    // barrier: the front end re-homes this shard's sessions (checkpoint
    // failover or deterministic regeneration) before we restart
    let mut drain_requested = false;
    loop {
        match cmd_rx.recv() {
            Ok(ShardCmd::FailoverDone) => break,
            // raced submits were sent before the front end saw ShardDown;
            // re-homing covers them, so they are dropped here
            Ok(ShardCmd::Submit(_)) => {}
            Ok(ShardCmd::Cancel { gid: _, conn }) => {
                let _ = ev_tx.send(FrontEvent::Line {
                    conn,
                    line: wire::line_of(
                        Json::obj().set("ok", true).set("cancelled", false),
                    ),
                });
            }
            Ok(ShardCmd::Admin { corr, cmd: _ }) => {
                let body = Json::obj()
                    .set("ok", false)
                    .set("error", format!("shard {shard} restarting"));
                let _ = ev_tx.send(FrontEvent::Admin { corr, shard, body });
            }
            Ok(ShardCmd::Drain) => drain_requested = true,
            Err(_) => return DeathOutcome::FrontendGone,
        }
    }
    if drain_requested {
        DeathOutcome::Drain
    } else {
        DeathOutcome::Restart
    }
}

/// Supervise one shard until drained: spawn a generation, proxy
/// commands and events, and run the death → failover → restart state
/// machine described in the module docs.
pub fn supervise_shard(
    shard: usize,
    sup: SupervisorCfg,
    cmd_rx: Receiver<ShardCmd>,
    ev_tx: Sender<FrontEvent>,
    runtime: ShardRuntime,
) {
    let panic_shot = sup.faults.shard_panic_step.map(OneShot::new);
    let slow_shot = (sup.faults.slow_op_ms > 0).then(|| OneShot::new(sup.faults.slow_op_ms));
    let mut restarts: u64 = 0;
    let mut ledger = Ledger::default();
    let mut gen =
        spawn_generation(shard, &runtime, &sup, &panic_shot, &slow_shot, restarts);
    let mut frontend_gone = false;
    loop {
        // 1. relay generation events
        let mut exited_clean = false;
        while let Ok(ev) = gen.ev_rx.try_recv() {
            track_event(&ev, &mut ledger);
            let drained = matches!(ev, FrontEvent::Drained { .. });
            let _ = ev_tx.send(ev);
            if drained {
                exited_clean = true;
                break;
            }
        }
        if exited_clean
            || (gen.shared.done.load(Ordering::SeqCst)
                && !gen.shared.panicked.load(Ordering::SeqCst))
        {
            // every send happened before `done` was set — relay the tail
            // (the Drained marker included) so the front end never hangs
            while let Ok(ev) = gen.ev_rx.try_recv() {
                track_event(&ev, &mut ledger);
                let _ = ev_tx.send(ev);
            }
            if let Some(j) = gen.join.take() {
                let _ = j.join();
            }
            return;
        }
        // 2. death check → failover → restart or degrade
        if is_dead(shard, &mut gen, sup.heartbeat_ms) {
            match handle_death(shard, gen, &mut ledger, &cmd_rx, &ev_tx) {
                DeathOutcome::FrontendGone => return,
                DeathOutcome::Drain => {
                    let _ = ev_tx.send(FrontEvent::Drained { shard });
                    return;
                }
                DeathOutcome::Restart => {
                    restarts += 1;
                    if restarts as usize > sup.max_restarts {
                        eprintln!(
                            "[supervisor] shard {shard}: restart budget exhausted \
                             ({} restarts), degrading to dead stub",
                            sup.max_restarts
                        );
                        run_dead_shard(
                            shard,
                            format!(
                                "restart budget exhausted ({} restarts)",
                                sup.max_restarts
                            ),
                            cmd_rx,
                            ev_tx,
                        );
                        return;
                    }
                    let backoff = 50u64.saturating_mul(1u64 << (restarts - 1).min(5));
                    thread::sleep(Duration::from_millis(backoff.min(2000)));
                    eprintln!(
                        "[supervisor] shard {shard}: restarting generation \
                         (attempt {restarts}/{})",
                        sup.max_restarts
                    );
                    gen = spawn_generation(
                        shard,
                        &runtime,
                        &sup,
                        &panic_shot,
                        &slow_shot,
                        restarts,
                    );
                    let _ = ev_tx.send(FrontEvent::ShardUp { shard });
                    continue;
                }
            }
        }
        // 3. pump commands to the generation
        match cmd_rx.recv_timeout(Duration::from_millis(1)) {
            Ok(cmd) => {
                match &cmd {
                    ShardCmd::Admin { corr, .. } => {
                        ledger.admins.insert(*corr);
                    }
                    ShardCmd::Cancel { gid, conn } => {
                        ledger.cancels.insert(*gid, *conn);
                    }
                    _ => {}
                }
                if let Some(tx) = &gen.cmd_tx {
                    let _ = tx.send(cmd);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                if !frontend_gone {
                    frontend_gone = true;
                    // dropping the generation's sender makes its loop see
                    // a disconnect and drain on its own
                    gen.cmd_tx = None;
                }
                thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

/// Stand-in loop for a shard that can no longer run (backend start
/// failure past the restart budget): answers every command with an error
/// (or a negative ack) so the front end's routing table and admin
/// fan-ins stay live, then reports drained.
pub fn run_dead_shard(
    shard: usize,
    err: String,
    cmd_rx: Receiver<ShardCmd>,
    ev_tx: Sender<FrontEvent>,
) {
    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            ShardCmd::Submit(sr) => {
                let _ = ev_tx.send(FrontEvent::Line {
                    conn: sr.conn,
                    line: wire::line_of(
                        Json::obj()
                            .set("ok", false)
                            .set("error", format!("shard {shard} unavailable: {err}")),
                    ),
                });
                let _ = ev_tx.send(FrontEvent::Terminal {
                    conn: sr.conn,
                    shard,
                    gid: sr.gid,
                });
            }
            ShardCmd::Cancel { gid, conn } => {
                let _ = ev_tx.send(FrontEvent::Line {
                    conn,
                    line: wire::line_of(Json::obj().set("ok", true).set("cancelled", false)),
                });
                let _ = ev_tx.send(FrontEvent::CancelDone { gid });
            }
            ShardCmd::Admin { corr, cmd: _ } => {
                let body = Json::obj()
                    .set("ok", false)
                    .set("error", format!("shard {shard} unavailable: {err}"));
                let _ = ev_tx.send(FrontEvent::Admin { corr, shard, body });
            }
            ShardCmd::FailoverDone => {}
            ShardCmd::Drain => break,
        }
    }
    let _ = ev_tx.send(FrontEvent::Drained { shard });
}
