//! Adaptive speculation policy (DESIGN.md §16): the feedback layer that
//! closes the loop between observed decode behaviour and the speculation
//! hyperparameters the repo previously hard-coded.
//!
//! Per active session the coordinator polls a cumulative
//! [`SpecObservation`] each tick (committed vs proposed draft tokens,
//! full/partial/refresh round counts, context length) and folds the
//! delta into a [`PolicyState`]. A deterministic controller — a pure
//! function of the observed stream, no wall clock and no global RNG —
//! then emits a [`PolicyDirective`]:
//!
//! * **depth**: the draft depth grows while the acceptance EWMA stays at
//!   or above `policy_grow` and shrinks at or below `policy_shrink`,
//!   never leaving `[draft_min, draft_max]` (property-tested);
//! * **refresh**: SpecPV's full-verification refresh fires when the
//!   accumulated acceptance shortfall over partial rounds crosses
//!   `drift_threshold`, instead of waiting for the fixed buffer-cap
//!   cadence (which remains as the fallback ceiling);
//! * **engine**: `engine=auto` picks ar / triforce / spec_pv per request
//!   from the prompt length, vetoed down to `ar` when the candidate's
//!   observed acceptance probe has collapsed.
//!
//! Engines stay in charge of their own contracts: a losslessness-pinned
//! engine ignores depth overrides whenever applying one could perturb
//! its sampling RNG stream (temperature > 0), so `policy=adaptive`
//! output is byte-identical to `policy=off` on those engines.

use std::collections::HashMap;

use crate::config::{EngineKind, PolicyConfig, PolicyMode};

/// Cumulative speculation counters a session exposes to the policy
/// layer (`EngineSession::spec_observe`). All fields are monotone
/// counters except the gauges `context_len`, `depth` and `pv_len`; the
/// controller diffs consecutive snapshots.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecObservation {
    /// draft tokens offered to verification so far
    pub proposed: u64,
    /// draft tokens accepted (committed to the output) so far
    pub committed: u64,
    /// draft→verify→accept rounds completed
    pub verify_steps: u64,
    /// rounds verified against the full KV cache
    pub full_steps: u64,
    /// rounds verified against the partial cache (SpecPV)
    pub partial_steps: u64,
    /// full-verification refreshes taken (SpecPV)
    pub refresh_steps: u64,
    /// gauge: prompt + emitted tokens
    pub context_len: usize,
    /// gauge: the engine's current draft depth (tree depth / chain γ)
    pub depth: usize,
    /// gauge: partially-verified tokens awaiting a refresh (SpecPV)
    pub pv_len: usize,
}

/// What the controller asks an engine to do next
/// (`EngineSession::apply_policy`). The default (no depth override, no
/// forced refresh) is a no-op.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PolicyDirective {
    /// pin the draft depth (tree depth / chain γ) to this value; engines
    /// clamp to their own hard limits and ignore the override entirely
    /// when honouring it could break their output contract
    pub draft_depth: Option<usize>,
    /// SpecPV: take a full-verification refresh at the next opportunity
    /// instead of waiting for the buffer-cap cadence
    pub force_refresh: bool,
}

impl PolicyDirective {
    pub fn is_noop(&self) -> bool {
        self.draft_depth.is_none() && !self.force_refresh
    }
}

/// Per-session controller state. Serialized into `SessionCheckpoint` so
/// a failed-over session resumes with its learned depth and drift
/// instead of resetting to defaults (DESIGN.md §15/§16).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyState {
    /// EWMA of the per-round acceptance ratio committed/proposed
    pub accept_ewma: f64,
    /// accumulated acceptance shortfall over partial rounds since the
    /// last refresh (the partial-vs-full divergence proxy: drafts the
    /// partial cache rejects that a tracking cache would have kept)
    pub drift: f64,
    /// current commanded draft depth (0 until the first observation
    /// adopts the engine's own depth, clamped into bounds)
    pub depth: usize,
    /// verify rounds folded in
    pub rounds: u64,
    /// rounds since the last depth adjustment window closed
    pub since_adjust: u64,
    /// lifetime: depth moves taken by this session
    pub depth_changes: u64,
    /// lifetime: drift-triggered refreshes requested
    pub forced_refreshes: u64,
    /// a forced refresh was issued and has not been observed yet
    pub refresh_pending: bool,
    /// the cumulative snapshot at the previous tick (delta base)
    pub last: SpecObservation,
}

impl PolicyState {
    /// Serialize for the durable checkpoint image (DESIGN.md §17). All
    /// counters fit f64-exact JSON numbers (they count decode rounds,
    /// far below 2^53).
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        let last = Json::obj()
            .set("proposed", self.last.proposed as f64)
            .set("committed", self.last.committed as f64)
            .set("verify_steps", self.last.verify_steps as f64)
            .set("full_steps", self.last.full_steps as f64)
            .set("partial_steps", self.last.partial_steps as f64)
            .set("refresh_steps", self.last.refresh_steps as f64)
            .set("context_len", self.last.context_len as f64)
            .set("depth", self.last.depth as f64)
            .set("pv_len", self.last.pv_len as f64);
        Json::obj()
            .set("accept_ewma", self.accept_ewma)
            .set("drift", self.drift)
            .set("depth", self.depth as f64)
            .set("rounds", self.rounds as f64)
            .set("since_adjust", self.since_adjust as f64)
            .set("depth_changes", self.depth_changes as f64)
            .set("forced_refreshes", self.forced_refreshes as f64)
            .set("refresh_pending", self.refresh_pending)
            .set("last", last)
    }

    /// Inverse of [`PolicyState::to_json`]; missing keys default to 0 so
    /// older images stay loadable.
    pub fn from_json(j: &crate::json::Json) -> PolicyState {
        let f = |o: &crate::json::Json, k: &str| o.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let u = |o: &crate::json::Json, k: &str| f(o, k) as u64;
        let last_j = j.get("last").cloned().unwrap_or(crate::json::Json::Null);
        let last = SpecObservation {
            proposed: u(&last_j, "proposed"),
            committed: u(&last_j, "committed"),
            verify_steps: u(&last_j, "verify_steps"),
            full_steps: u(&last_j, "full_steps"),
            partial_steps: u(&last_j, "partial_steps"),
            refresh_steps: u(&last_j, "refresh_steps"),
            context_len: f(&last_j, "context_len") as usize,
            depth: f(&last_j, "depth") as usize,
            pv_len: f(&last_j, "pv_len") as usize,
        };
        PolicyState {
            accept_ewma: f(j, "accept_ewma"),
            drift: f(j, "drift"),
            depth: f(j, "depth") as usize,
            rounds: u(j, "rounds"),
            since_adjust: u(j, "since_adjust"),
            depth_changes: u(j, "depth_changes"),
            forced_refreshes: u(j, "forced_refreshes"),
            refresh_pending: j.get("refresh_pending").and_then(|v| v.as_bool()).unwrap_or(false),
            last,
        }
    }
}

impl Default for PolicyState {
    fn default() -> Self {
        PolicyState {
            accept_ewma: 0.0,
            drift: 0.0,
            depth: 0,
            rounds: 0,
            since_adjust: 0,
            depth_changes: 0,
            forced_refreshes: 0,
            refresh_pending: false,
            last: SpecObservation::default(),
        }
    }
}

/// The per-tick delta a [`PolicyState::update`] fold produced, plus the
/// directive. The coordinator feeds the deltas into the registry's
/// per-engine counters and the `engine=auto` probe.
#[derive(Debug, Clone, Copy, Default)]
pub struct PolicyUpdate {
    pub directive: PolicyDirective,
    pub rounds: u64,
    pub proposed: u64,
    pub committed: u64,
    pub full_steps: u64,
    pub partial_steps: u64,
    pub refresh_steps: u64,
}

impl PolicyState {
    /// Rebuild controller state from a failover checkpoint: the learned
    /// depth, EWMA and drift carry over, but the delta base resets — the
    /// rebuilt session's counters restart from zero.
    pub fn resumed(mut self) -> PolicyState {
        self.last = SpecObservation::default();
        self.refresh_pending = false;
        self
    }

    /// Fold one cumulative observation snapshot into the state and
    /// return the resulting directive. Deterministic: the same
    /// observation stream always produces the same directive stream.
    pub fn update(&mut self, cfg: &PolicyConfig, obs: SpecObservation) -> PolicyUpdate {
        if self.depth == 0 {
            self.depth = obs.depth.clamp(cfg.draft_min, cfg.draft_max);
        }
        let d_rounds = obs.verify_steps.saturating_sub(self.last.verify_steps);
        let d_prop = obs.proposed.saturating_sub(self.last.proposed);
        let d_comm = obs.committed.saturating_sub(self.last.committed);
        let d_full = obs.full_steps.saturating_sub(self.last.full_steps);
        let d_partial = obs.partial_steps.saturating_sub(self.last.partial_steps);
        let d_refresh = obs.refresh_steps.saturating_sub(self.last.refresh_steps);
        self.last = obs;
        if d_refresh > 0 {
            // the refresh (forced or cadence) re-anchored the partial
            // cache on exact state — accumulated drift is gone
            self.drift = 0.0;
            self.refresh_pending = false;
        }
        if d_rounds > 0 {
            let ratio = if d_prop == 0 {
                1.0
            } else {
                (d_comm as f64 / d_prop as f64).min(1.0)
            };
            for _ in 0..d_rounds {
                if self.rounds == 0 {
                    self.accept_ewma = ratio;
                } else {
                    self.accept_ewma += cfg.alpha * (ratio - self.accept_ewma);
                }
                self.rounds += 1;
            }
            self.drift += d_partial as f64 * (1.0 - ratio);
            self.since_adjust += d_rounds;
            if cfg.mode == PolicyMode::Adaptive
                && self.since_adjust >= cfg.adjust_every as u64
            {
                self.since_adjust = 0;
                let next = if self.accept_ewma >= cfg.grow {
                    (self.depth + 1).min(cfg.draft_max.max(cfg.draft_min))
                } else if self.accept_ewma <= cfg.shrink {
                    self.depth.saturating_sub(1).max(cfg.draft_min)
                } else {
                    self.depth
                };
                if next != self.depth {
                    self.depth = next;
                    self.depth_changes += 1;
                }
            }
            if cfg.mode == PolicyMode::Adaptive
                && !self.refresh_pending
                && obs.pv_len > 0
                && self.drift >= cfg.drift_threshold
            {
                self.refresh_pending = true;
                self.forced_refreshes += 1;
            }
        }
        PolicyUpdate {
            directive: self.directive(cfg),
            rounds: d_rounds,
            proposed: d_prop,
            committed: d_comm,
            full_steps: d_full,
            partial_steps: d_partial,
            refresh_steps: d_refresh,
        }
    }

    /// The directive this state currently commands (no-op outside
    /// adaptive mode or before the first observation).
    pub fn directive(&self, cfg: &PolicyConfig) -> PolicyDirective {
        if cfg.mode != PolicyMode::Adaptive || self.depth == 0 {
            return PolicyDirective::default();
        }
        PolicyDirective {
            draft_depth: Some(self.depth),
            force_refresh: self.refresh_pending,
        }
    }
}

/// Coordinator-level aggregate acceptance per engine: the `engine=auto`
/// "early acceptance probe". Accrues across sessions (including
/// completed ones) so a cold request inherits what the fleet learned.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineProbe {
    pub rounds: u64,
    pub accept_ewma: f64,
}

/// The coordinator-owned policy engine: per-session states plus the
/// per-engine probe aggregates.
#[derive(Debug, Default)]
pub struct PolicyEngine {
    pub cfg: PolicyConfig,
    states: HashMap<u64, PolicyState>,
    probes: HashMap<EngineKind, EngineProbe>,
    /// lifetime counters (registry mirrors)
    pub depth_changes: u64,
    pub forced_refreshes: u64,
}

impl PolicyEngine {
    pub fn new(cfg: PolicyConfig) -> PolicyEngine {
        PolicyEngine { cfg, ..PolicyEngine::default() }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.mode != PolicyMode::Off
    }

    /// Fold a session's latest cumulative observation; returns the
    /// directive plus the tick's deltas for the registry counters.
    pub fn observe(
        &mut self,
        id: u64,
        kind: EngineKind,
        obs: SpecObservation,
    ) -> PolicyUpdate {
        let st = self.states.entry(id).or_default();
        let before = (st.depth_changes, st.forced_refreshes);
        let up = st.update(&self.cfg, obs);
        self.depth_changes += st.depth_changes - before.0;
        self.forced_refreshes += st.forced_refreshes - before.1;
        if up.rounds > 0 && up.proposed > 0 {
            let ratio = (up.committed as f64 / up.proposed as f64).min(1.0);
            let probe = self.probes.entry(kind).or_default();
            for _ in 0..up.rounds {
                if probe.rounds == 0 {
                    probe.accept_ewma = ratio;
                } else {
                    probe.accept_ewma += self.cfg.alpha * (ratio - probe.accept_ewma);
                }
                probe.rounds += 1;
            }
        }
        up
    }

    /// `engine=auto`: pick the engine for a fresh request. Deterministic
    /// in (prompt length, observation history).
    pub fn select(&self, prompt_len: usize) -> EngineKind {
        let cand = if prompt_len >= self.cfg.auto_long {
            EngineKind::SpecPv
        } else if prompt_len >= self.cfg.auto_short {
            EngineKind::TriForce
        } else {
            EngineKind::Autoregressive
        };
        // acceptance probe: speculation whose observed acceptance has
        // collapsed decodes slower than plain AR — stop choosing it
        if cand != EngineKind::Autoregressive {
            if let Some(p) = self.probes.get(&cand) {
                if p.rounds >= self.cfg.probe_rounds as u64
                    && p.accept_ewma <= self.cfg.shrink
                {
                    return EngineKind::Autoregressive;
                }
            }
        }
        cand
    }

    pub fn state(&self, id: u64) -> Option<&PolicyState> {
        self.states.get(&id)
    }

    pub fn probe(&self, kind: EngineKind) -> EngineProbe {
        self.probes.get(&kind).copied().unwrap_or_default()
    }

    /// Adopt a checkpointed state for a failed-over session.
    pub fn restore(&mut self, id: u64, st: PolicyState) {
        self.states.insert(id, st.resumed());
    }

    /// The directive a session's current state commands (used to re-arm
    /// a freshly rebuilt failover session with its learned depth).
    pub fn directive_for(&self, id: u64) -> PolicyDirective {
        self.states
            .get(&id)
            .map(|st| st.directive(&self.cfg))
            .unwrap_or_default()
    }

    /// Drop a terminal session's state (the probe aggregate keeps what
    /// it learned).
    pub fn finish(&mut self, id: u64) {
        self.states.remove(&id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: PolicyMode) -> PolicyConfig {
        PolicyConfig { mode, ..PolicyConfig::default() }
    }

    fn obs_after(rounds: u64, depth: usize, accept_per_round: u64) -> SpecObservation {
        SpecObservation {
            proposed: rounds * depth as u64,
            committed: rounds * accept_per_round,
            verify_steps: rounds,
            full_steps: rounds,
            depth,
            context_len: 64 + rounds as usize,
            ..SpecObservation::default()
        }
    }

    #[test]
    fn depth_grows_on_high_acceptance() {
        let c = cfg(PolicyMode::Adaptive);
        let mut st = PolicyState::default();
        let mut d = 0;
        for r in 1..=16u64 {
            let up = st.update(&c, obs_after(r, 3, 3)); // 100% acceptance
            d = up.directive.draft_depth.unwrap();
        }
        assert!(d > 3, "perfect acceptance must deepen the draft (got {d})");
        assert!(d <= c.draft_max);
        assert!(st.depth_changes > 0);
    }

    #[test]
    fn depth_shrinks_on_low_acceptance() {
        let c = cfg(PolicyMode::Adaptive);
        let mut st = PolicyState::default();
        let mut d = 0;
        for r in 1..=16u64 {
            let up = st.update(&c, obs_after(r, 4, 0)); // nothing accepted
            d = up.directive.draft_depth.unwrap();
        }
        assert!(d < 4, "zero acceptance must shallow the draft (got {d})");
        assert!(d >= c.draft_min);
    }

    #[test]
    fn fixed_mode_observes_but_never_directs() {
        let c = cfg(PolicyMode::Fixed);
        let mut st = PolicyState::default();
        for r in 1..=16u64 {
            let up = st.update(&c, obs_after(r, 3, 3));
            assert!(up.directive.is_noop(), "fixed mode must not override");
        }
        assert!(st.accept_ewma > 0.9, "counters still accrue in fixed mode");
        assert_eq!(st.depth_changes, 0);
    }

    #[test]
    fn drift_triggers_refresh_and_refresh_resets() {
        let c = PolicyConfig {
            mode: PolicyMode::Adaptive,
            drift_threshold: 1.0,
            ..PolicyConfig::default()
        };
        let mut st = PolicyState::default();
        // partial rounds at 50% acceptance: shortfall 0.5/round
        let mut obs = SpecObservation { depth: 4, pv_len: 4, ..Default::default() };
        let mut forced = false;
        for r in 1..=8u64 {
            obs.verify_steps = r;
            obs.partial_steps = r;
            obs.proposed = 4 * r;
            obs.committed = 2 * r;
            obs.pv_len = 2 * r as usize;
            let up = st.update(&c, obs);
            forced = forced || up.directive.force_refresh;
        }
        assert!(forced, "accumulated shortfall must force a refresh");
        assert_eq!(st.forced_refreshes, 1, "idempotent until the refresh lands");
        // the refresh happens: drift and the pending flag clear
        obs.refresh_steps = 1;
        obs.verify_steps += 1;
        obs.full_steps += 1;
        obs.pv_len = 0;
        let up = st.update(&c, obs);
        assert!(!up.directive.force_refresh);
        assert_eq!(st.drift, 0.0);
    }

    #[test]
    fn controller_is_deterministic() {
        let c = cfg(PolicyMode::Adaptive);
        let stream: Vec<SpecObservation> =
            (1..=32u64).map(|r| obs_after(r, 3, (r % 4).min(3))).collect();
        let run = |stream: &[SpecObservation]| {
            let mut st = PolicyState::default();
            let dirs: Vec<PolicyDirective> =
                stream.iter().map(|o| st.update(&c, *o).directive).collect();
            (dirs, st)
        };
        let (d1, s1) = run(&stream);
        let (d2, s2) = run(&stream);
        assert_eq!(d1, d2);
        assert_eq!(s1, s2);
    }

    #[test]
    fn auto_select_by_prompt_length() {
        let pe = PolicyEngine::new(cfg(PolicyMode::Adaptive));
        assert_eq!(pe.select(8), EngineKind::Autoregressive);
        assert_eq!(pe.select(128), EngineKind::TriForce);
        assert_eq!(pe.select(2048), EngineKind::SpecPv);
    }

    #[test]
    fn auto_probe_vetoes_collapsed_speculation() {
        let mut pe = PolicyEngine::new(cfg(PolicyMode::Adaptive));
        // triforce sessions whose drafts never get accepted
        let mut obs = SpecObservation { depth: 4, ..Default::default() };
        for r in 1..=16u64 {
            obs.verify_steps = r;
            obs.full_steps = r;
            obs.proposed = 4 * r;
            obs.committed = 0;
            pe.observe(7, EngineKind::TriForce, obs);
        }
        assert_eq!(
            pe.select(128),
            EngineKind::Autoregressive,
            "collapsed acceptance must fall back to ar"
        );
        // spec_pv is a different probe — unaffected
        assert_eq!(pe.select(2048), EngineKind::SpecPv);
    }

    #[test]
    fn resumed_state_keeps_learning_resets_delta_base() {
        let c = cfg(PolicyMode::Adaptive);
        let mut st = PolicyState::default();
        for r in 1..=16u64 {
            st.update(&c, obs_after(r, 3, 3));
        }
        let learned = st.depth;
        assert!(learned > 3);
        let rs = st.clone().resumed();
        assert_eq!(rs.depth, learned, "learned depth survives failover");
        assert_eq!(rs.last, SpecObservation::default(), "delta base reset");
    }
}
