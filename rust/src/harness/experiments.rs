//! The per-table/figure experiment drivers (see DESIGN.md §5 for the
//! paper↔module map and §3 for the scale substitutions).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::backend::Backend;
use crate::bench::{fmt_speedup, Table};
use crate::config::{Config, EngineKind, Reduction};
use crate::coordinator::aggregate;
use crate::corpus;
use crate::engine::{self, GenRequest};
use crate::json::Json;
use crate::metrics::{bleurt_proxy, exact_match, rouge_l};
use crate::tokenizer;

use super::{engine_cfg, macro_tau, micro_throughput, run_continuation, BUDGETS};

fn ladder(quick: bool) -> Vec<usize> {
    if quick {
        vec![1024, 3072]
    } else {
        super::CTX_LADDER.to_vec()
    }
}

fn gen_len(quick: bool) -> usize {
    if quick {
        48
    } else {
        64
    }
}

fn n_prompts(_quick: bool) -> usize {
    1
}

/// AR throughput per context (the α denominator), computed once.
fn ar_baseline(
    be: &dyn Backend,
    base: &Config,
    ctxs: &[usize],
    gen: usize,
    n: usize,
    offload: bool,
) -> Result<BTreeMap<usize, f64>> {
    let mut cfg = engine_cfg(base, EngineKind::Autoregressive, None);
    cfg.offload.enabled = offload;
    let mut m = BTreeMap::new();
    for &ctx in ctxs {
        let stats = run_continuation(be, &cfg, ctx, gen, n, 0xA11)?;
        m.insert(ctx, micro_throughput(&stats, offload));
    }
    Ok(m)
}

// ---------------------------------------------------------------------------
// Fig. 1 — drafting vs verification time share as context grows
// ---------------------------------------------------------------------------
pub fn fig1(be: &dyn Backend, base: &Config, out: &Path, quick: bool) -> Result<()> {
    let mut t = Table::new(
        "Fig.1 — EAGLE3-full: draft vs verification time share vs context",
        &["ctx", "draft_ms/step", "verify_ms/step", "draft_%", "verify_%"],
    );
    let cfg = engine_cfg(base, EngineKind::SpecFull, None);
    for ctx in ladder(quick) {
        let stats = run_continuation(be, &cfg, ctx, gen_len(quick), n_prompts(quick), 0xF16)?;
        let agg = aggregate(&stats);
        let steps = agg.verify_steps.max(1) as f64;
        let d = agg.draft_secs / steps * 1e3;
        let v = agg.verify_secs / steps * 1e3;
        let tot = (agg.draft_secs + agg.verify_secs).max(1e-12);
        t.row(
            vec![
                ctx.to_string(),
                format!("{d:.1}"),
                format!("{v:.1}"),
                format!("{:.0}%", agg.draft_secs / tot * 100.0),
                format!("{:.0}%", agg.verify_secs / tot * 100.0),
            ],
            Json::obj()
                .set("ctx", ctx)
                .set("draft_ms", d)
                .set("verify_ms", v)
                .set("verify_frac", agg.verify_secs / tot),
        );
    }
    t.emit(out, "fig1")
}

// ---------------------------------------------------------------------------
// Table 1 — α and τ across engines × context (the headline table)
// ---------------------------------------------------------------------------
pub fn table1(be: &dyn Backend, base: &Config, out: &Path, quick: bool) -> Result<()> {
    table1_inner(be, base, out, quick, false, "table1")
}

fn table1_inner(
    be: &dyn Backend,
    base: &Config,
    out: &Path,
    quick: bool,
    offload: bool,
    name: &str,
) -> Result<()> {
    let ctxs = ladder(quick);
    let gen = gen_len(quick);
    let n = n_prompts(quick);
    let ar = ar_baseline(be, base, &ctxs, gen, n, offload)?;

    let mut engines: Vec<(String, Config)> = vec![
        (
            "TriForce".into(),
            engine_cfg(base, EngineKind::TriForce, None),
        ),
        (
            "TokenSwift".into(),
            engine_cfg(base, EngineKind::TokenSwift, None),
        ),
        (
            "EAGLE3-YARN".into(),
            engine_cfg(base, EngineKind::SpecFull, None),
        ),
    ];
    for b in BUDGETS {
        engines.push((
            format!("SpecPV-{b}"),
            engine_cfg(base, EngineKind::SpecPv, Some(b)),
        ));
    }
    if offload {
        // Fig. 4 uses a reduced engine set like the paper's plot
        engines.retain(|(n, _)| n == "EAGLE3-YARN" || n.starts_with("SpecPV"));
    }

    let mut headers = vec!["method".to_string()];
    for &c in &ctxs {
        headers.push(format!("{}K α", c / 1024).replace(".0", ""));
        headers.push("τ".into());
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let title = if offload {
        "Fig.4 — throughput speedup with KV-cache offloading (simulated PCIe)"
    } else {
        "Table 1 — speedup α and accept length τ vs context length"
    };
    let mut t = Table::new(title, &hdr_refs);

    for (label, mut cfg) in engines {
        cfg.offload.enabled = offload;
        let mut cells = vec![label.clone()];
        let mut j = Json::obj().set("method", label.clone());
        for &ctx in &ctxs {
            let stats = run_continuation(be, &cfg, ctx, gen, n, 0x7AB1)?;
            let tp = micro_throughput(&stats, offload);
            let alpha = tp / ar[&ctx].max(1e-9);
            let tau = macro_tau(&stats);
            cells.push(fmt_speedup(alpha));
            cells.push(format!("{tau:.2}"));
            j = j
                .set(&format!("alpha_{ctx}"), alpha)
                .set(&format!("tau_{ctx}"), tau)
                .set(&format!("tok_s_{ctx}"), tp);
            println!(
                "  [{name}] {label} ctx={ctx}: {tp:.1} tok/s (α={alpha:.2}, τ={tau:.2})"
            );
        }
        t.row(cells, j);
    }
    t.emit(out, name)
}

// ---------------------------------------------------------------------------
// Fig. 4 — offloaded-KV throughput (PCIe simulator)
// ---------------------------------------------------------------------------
pub fn fig4(be: &dyn Backend, base: &Config, out: &Path, quick: bool) -> Result<()> {
    table1_inner(be, base, out, quick, true, "fig4")
}

// ---------------------------------------------------------------------------
// Table 2 — similarity between SpecPV and full-verification generation
// ---------------------------------------------------------------------------
pub fn table2(be: &dyn Backend, base: &Config, out: &Path, quick: bool) -> Result<()> {
    let ctx = if quick { 2048 } else { 3072 };
    let gen = if quick { 64 } else { 160 };
    let n_docs = if quick { 1 } else { 2 };
    let budgets: Vec<usize> = if quick { vec![256] } else { vec![512, 256, 64] };

    let mut t = Table::new(
        "Table 2 — similarity of SpecPV vs full-verification summaries",
        &["dataset", "budget", "ROUGE-L", "BLEURT*"],
    );

    for (ds, gen_doc) in [
        ("GovReport*", corpus::report_text as fn(u64, usize) -> String),
        ("QMSum*", corpus::meeting_text as fn(u64, usize) -> String),
    ] {
        // references: full-verification outputs (and AR as the paper's "—"
        // noise-floor row)
        let mut refs: Vec<String> = Vec::new();
        let mut ar_out: Vec<String> = Vec::new();
        for d in 0..n_docs {
            let prompt = corpus::summarize_prompt(&gen_doc(0x2b0 + d as u64, ctx));
            let req = GenRequest::greedy(tokenizer::encode(&prompt), gen);
            let full = engine::generate_with(
                &engine_cfg(base, EngineKind::SpecFull, None),
                be,
                &req,
            )?;
            refs.push(full.text());
            let arr = engine::generate_with(
                &engine_cfg(base, EngineKind::Autoregressive, None),
                be,
                &req,
            )?;
            ar_out.push(arr.text());
        }
        // noise floor: full verification vs naive AR
        let rl: f64 = (0..n_docs)
            .map(|d| rouge_l(&ar_out[d], &refs[d]))
            .sum::<f64>()
            / n_docs as f64;
        let bl: f64 = (0..n_docs)
            .map(|d| bleurt_proxy(&ar_out[d], &refs[d]))
            .sum::<f64>()
            / n_docs as f64;
        t.row(
            vec![ds.into(), "—(AR)".into(), format!("{rl:.1}"), format!("{bl:.1}")],
            Json::obj()
                .set("dataset", ds)
                .set("budget", "ar")
                .set("rouge_l", rl)
                .set("bleurt", bl),
        );

        for &b in &budgets {
            let cfg = engine_cfg(base, EngineKind::SpecPv, Some(b));
            let mut rl = 0.0;
            let mut bl = 0.0;
            for d in 0..n_docs {
                let prompt = corpus::summarize_prompt(&gen_doc(0x2b0 + d as u64, ctx));
                let req = GenRequest::greedy(tokenizer::encode(&prompt), gen);
                let r = engine::generate_with(&cfg, be, &req)?;
                rl += rouge_l(&r.text(), &refs[d]);
                bl += bleurt_proxy(&r.text(), &refs[d]);
            }
            rl /= n_docs as f64;
            bl /= n_docs as f64;
            t.row(
                vec![
                    ds.into(),
                    b.to_string(),
                    format!("{rl:.1}"),
                    format!("{bl:.1}"),
                ],
                Json::obj()
                    .set("dataset", ds)
                    .set("budget", b)
                    .set("rouge_l", rl)
                    .set("bleurt", bl),
            );
            println!("  [table2] {ds} budget={b}: RL={rl:.1} BLT={bl:.1}");
        }
    }
    t.emit(out, "table2")
}

// ---------------------------------------------------------------------------
// Table 3 — model-size sweep (paper: Qwen3 4B/8B/14B → specpv s/m/l)
// ---------------------------------------------------------------------------
pub fn table3(be: &dyn Backend, base: &Config, out: &Path, quick: bool) -> Result<()> {
    // m/l ship buckets up to 4096 → max ctx leaves prefill+refresh headroom
    let ctxs: Vec<usize> = if quick { vec![1024] } else { vec![1024, 2048, 3584] };
    let gen = gen_len(quick);
    let n = 1;
    let sizes: Vec<String> = be
        .sizes()
        .into_iter()
        .filter(|s| s != "tiny")
        .collect();

    let mut headers = vec!["size".to_string(), "method".to_string()];
    for &c in &ctxs {
        headers.push(format!("{}K α", c / 1024));
        headers.push("τ".into());
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 3 — size sweep (s/m/l ≙ Qwen3 4B/8B/14B)", &hdr_refs);

    for size in sizes {
        let mut base_s = base.clone();
        base_s.model_size = size.to_string();
        let ar = ar_baseline(be, &base_s, &ctxs, gen, n, false)?;
        for (label, cfg) in [
            (
                "EAGLE3-YARN".to_string(),
                engine_cfg(&base_s, EngineKind::SpecFull, None),
            ),
            (
                "SpecPV-512".to_string(),
                engine_cfg(&base_s, EngineKind::SpecPv, Some(512)),
            ),
            (
                "SpecPV-256".to_string(),
                engine_cfg(&base_s, EngineKind::SpecPv, Some(256)),
            ),
        ] {
            let mut cells = vec![size.to_string(), label.clone()];
            let mut j = Json::obj().set("size", size.as_str()).set("method", label.clone());
            for &ctx in &ctxs {
                let stats = run_continuation(be, &cfg, ctx, gen, n, 0x3AB)?;
                let alpha = micro_throughput(&stats, false) / ar[&ctx].max(1e-9);
                let tau = macro_tau(&stats);
                cells.push(fmt_speedup(alpha));
                cells.push(format!("{tau:.2}"));
                j = j
                    .set(&format!("alpha_{ctx}"), alpha)
                    .set(&format!("tau_{ctx}"), tau);
                println!("  [table3] {size}/{label} ctx={ctx}: α={alpha:.2} τ={tau:.2}");
            }
            t.row(cells, j);
        }
    }
    t.emit(out, "table3")
}

// ---------------------------------------------------------------------------
// Fig. 5 — needle-QA accuracy under shrinking partial budgets
// ---------------------------------------------------------------------------
pub fn fig5(be: &dyn Backend, base: &Config, out: &Path, quick: bool) -> Result<()> {
    let ctxs: Vec<usize> = if quick { vec![1536] } else { vec![1536, 3072] };
    let n_inst = if quick { 3 } else { 6 };
    let budgets: Vec<Option<usize>> =
        vec![None, Some(512), Some(256), Some(64)]; // None = full verification

    let mut t = Table::new(
        "Fig.5 — QA exact-match vs partial KV budget (needle retrieval)",
        &["ctx", "method", "accuracy"],
    );
    for &ctx in &ctxs {
        for b in &budgets {
            let cfg = match b {
                None => engine_cfg(base, EngineKind::SpecFull, None),
                Some(b) => engine_cfg(base, EngineKind::SpecPv, Some(*b)),
            };
            let mut hit = 0usize;
            for i in 0..n_inst {
                let qa = corpus::needle_qa(0x9A + i as u64 * 7 + ctx as u64, ctx, 8);
                let prompt = format!("{}{}", qa.context, qa.question);
                let req = GenRequest::greedy(tokenizer::encode(&prompt), 12);
                let r = engine::generate_with(&cfg, be, &req)?;
                // the answer is the first code-word-shaped token run
                let out_text = r.text();
                let got = out_text
                    .split_whitespace()
                    .next()
                    .unwrap_or("")
                    .trim_matches(|c: char| !c.is_alphanumeric());
                if exact_match(got, &qa.answer) {
                    hit += 1;
                }
            }
            let acc = hit as f64 / n_inst as f64 * 100.0;
            let label = match b {
                None => "full".to_string(),
                Some(b) => format!("SpecPV-{b}"),
            };
            println!("  [fig5] ctx={ctx} {label}: {acc:.0}%");
            t.row(
                vec![ctx.to_string(), label.clone(), format!("{acc:.0}%")],
                Json::obj()
                    .set("ctx", ctx)
                    .set("method", label)
                    .set("accuracy", acc),
            );
        }
    }
    t.emit(out, "fig5")
}

// ---------------------------------------------------------------------------
// Table 4 — reduction-strategy ablation (mean/max/last)
// ---------------------------------------------------------------------------
pub fn table4(be: &dyn Backend, base: &Config, out: &Path, quick: bool) -> Result<()> {
    let ctx = if quick { 2048 } else { 3072 };
    let gen = if quick { 64 } else { 160 };
    let n_docs = if quick { 1 } else { 2 };

    // full-verification references
    let mut refs = Vec::new();
    for d in 0..n_docs {
        let prompt = corpus::summarize_prompt(&corpus::report_text(0x4AB + d as u64, ctx));
        let req = GenRequest::greedy(tokenizer::encode(&prompt), gen);
        refs.push(
            engine::generate_with(&engine_cfg(base, EngineKind::SpecFull, None), be, &req)?
                .text(),
        );
    }

    let mut t = Table::new(
        "Table 4 — retrieval-score reduction ablation (budget 256)",
        &["reduction", "ROUGE-L", "τ"],
    );
    for red in [Reduction::Mean, Reduction::Max, Reduction::Last] {
        let mut cfg = engine_cfg(base, EngineKind::SpecPv, Some(256));
        cfg.specpv.reduction = red;
        let mut rl = 0.0;
        let mut taus = Vec::new();
        for d in 0..n_docs {
            let prompt =
                corpus::summarize_prompt(&corpus::report_text(0x4AB + d as u64, ctx));
            let req = GenRequest::greedy(tokenizer::encode(&prompt), gen);
            let r = engine::generate_with(&cfg, be, &req)?;
            rl += rouge_l(&r.text(), &refs[d]);
            taus.push(r.stats);
        }
        rl /= n_docs as f64;
        let tau = macro_tau(&taus);
        println!("  [table4] {red}: RL={rl:.1} τ={tau:.2}");
        t.row(
            vec![red.to_string(), format!("{rl:.1}"), format!("{tau:.2}")],
            Json::obj()
                .set("reduction", red.to_string())
                .set("rouge_l", rl)
                .set("tau", tau),
        );
    }
    t.emit(out, "table4")
}

// ---------------------------------------------------------------------------
// Fig. 6 — refresh-interval (buffer size) vs similarity and speedup
// ---------------------------------------------------------------------------
pub fn fig6(be: &dyn Backend, base: &Config, out: &Path, quick: bool) -> Result<()> {
    let ctx = if quick { 2048 } else { 3072 };
    let gen = if quick { 64 } else { 160 };
    let caps: Vec<usize> = if quick {
        vec![24, 48]
    } else {
        vec![20, 36, 48, 120]
    };

    let prompt = corpus::summarize_prompt(&corpus::meeting_text(0x6F6, ctx));
    let req = GenRequest::greedy(tokenizer::encode(&prompt), gen);
    let full = engine::generate_with(&engine_cfg(base, EngineKind::SpecFull, None), be, &req)?;
    let ar = engine::generate_with(
        &engine_cfg(base, EngineKind::Autoregressive, None),
        be,
        &req,
    )?;
    let ar_tp = ar.stats.throughput();

    let mut t = Table::new(
        "Fig.6 — refresh interval (buffer cap) vs ROUGE-L and speedup",
        &["buffer_cap", "refreshes", "ROUGE-L", "speedup"],
    );
    for cap in caps {
        let mut cfg = engine_cfg(base, EngineKind::SpecPv, Some(256));
        cfg.specpv.buffer_cap = cap;
        let r = engine::generate_with(&cfg, be, &req)?;
        let rl = rouge_l(&r.text(), &full.text());
        let sp = r.stats.throughput() / ar_tp.max(1e-9);
        println!(
            "  [fig6] cap={cap}: refreshes={} RL={rl:.1} α={sp:.2}",
            r.stats.refresh_steps
        );
        t.row(
            vec![
                cap.to_string(),
                r.stats.refresh_steps.to_string(),
                format!("{rl:.1}"),
                fmt_speedup(sp),
            ],
            Json::obj()
                .set("cap", cap)
                .set("refreshes", r.stats.refresh_steps)
                .set("rouge_l", rl)
                .set("speedup", sp),
        );
    }
    t.emit(out, "fig6")
}

// ---------------------------------------------------------------------------
// Fig. 7 — case study: side-by-side summaries
// ---------------------------------------------------------------------------
pub fn fig7(be: &dyn Backend, base: &Config, out: &Path, quick: bool) -> Result<()> {
    let ctx = if quick { 2048 } else { 4096 };
    let gen = if quick { 96 } else { 224 };
    let prompt = corpus::summarize_prompt(&corpus::novel_text(0x777, ctx));
    let req = GenRequest::greedy(tokenizer::encode(&prompt), gen);

    let full = engine::generate_with(&engine_cfg(base, EngineKind::SpecFull, None), be, &req)?;
    let pv = engine::generate_with(&engine_cfg(base, EngineKind::SpecPv, Some(256)), be, &req)?;

    let mut t = Table::new(
        "Fig.7 — case study: full verification vs SpecPV-256 continuation",
        &["method", "output", "ROUGE-L vs full"],
    );
    let rl = rouge_l(&pv.text(), &full.text());
    t.row(
        vec!["full".into(), full.text().replace('\n', " ⏎ "), "100.0".into()],
        Json::obj().set("method", "full").set("text", full.text()),
    );
    t.row(
        vec![
            "SpecPV-256".into(),
            pv.text().replace('\n', " ⏎ "),
            format!("{rl:.1}"),
        ],
        Json::obj()
            .set("method", "specpv")
            .set("text", pv.text())
            .set("rouge_l", rl),
    );
    t.emit(out, "fig7")
}

// ---------------------------------------------------------------------------
// Fig. 8 — draft-training loss curves (from the build-time train log)
// ---------------------------------------------------------------------------
pub fn fig8(_be: &dyn Backend, base: &Config, out: &Path) -> Result<()> {
    let path = base.artifacts_dir.join("train_log.json");
    if !path.exists() {
        // the train log only exists after `make artifacts`; the reference
        // backend has no training phase, so `bench all` skips this figure
        println!("  [fig8] {path:?} not found (needs `make artifacts`) — skipped");
        return Ok(());
    }
    let text = std::fs::read_to_string(&path)?;
    let log = Json::parse(&text)?;
    let mut t = Table::new(
        "Fig.8 — training loss curves (target, EAGLE-3 TTT draft, medusa)",
        &["phase", "steps", "first", "ema@25%", "ema@50%", "ema@75%", "final ema"],
    );
    if let Some(obj) = log.as_obj() {
        for (phase, v) in obj {
            let ema: Vec<f64> = v
                .at("ema")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_f64())
                .collect();
            let loss: Vec<f64> = v
                .at("loss")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_f64())
                .collect();
            if ema.is_empty() {
                continue;
            }
            let q = |f: f64| ema[((ema.len() - 1) as f64 * f) as usize];
            t.row(
                vec![
                    phase.clone(),
                    ema.len().to_string(),
                    format!("{:.3}", loss[0]),
                    format!("{:.3}", q(0.25)),
                    format!("{:.3}", q(0.5)),
                    format!("{:.3}", q(0.75)),
                    format!("{:.3}", ema[ema.len() - 1]),
                ],
                Json::obj()
                    .set("phase", phase.as_str())
                    .set("final_ema", ema[ema.len() - 1]),
            );
        }
    }
    t.emit(out, "fig8")
}
