//! Experiment drivers: one function per paper table/figure (DESIGN.md §5).
//! Each regenerates its result from scratch (workload → engines → table)
//! and writes `results/<id>.{md,json}`. The context lengths and budgets
//! are the 10×-scaled substitutes documented in DESIGN.md §3.

pub mod experiments;

use std::path::Path;

use anyhow::Result;

use crate::backend::Backend;
use crate::config::{Config, EngineKind};
use crate::coordinator::aggregate;
use crate::engine::{self, GenRequest};
use crate::metrics::GenStats;
use crate::tokenizer;

/// Default scaled context ladder (paper: 10K…60K; ours: 1K…6K).
pub const CTX_LADDER: [usize; 3] = [1024, 3072, 6144];

/// Scaled SpecPV budgets (paper: 8K/4K/2K).
pub const BUDGETS: [usize; 3] = [1024, 512, 256];

/// Run one engine over `n_prompts` continuation prompts of `ctx` bytes,
/// generating `gen` tokens each; returns per-prompt stats.
pub fn run_continuation(
    be: &dyn Backend,
    cfg: &Config,
    ctx: usize,
    gen: usize,
    n_prompts: usize,
    seed0: u64,
) -> Result<Vec<GenStats>> {
    // warmup: force lazy executable compilation out of the timed region
    // (a fresh (engine, bucket, budget) combination otherwise pays its
    // PJRT compiles inside the first measured decode loop)
    {
        let text = crate::corpus::continuation_prompt(seed0 ^ 0xFFFF, ctx);
        let req = GenRequest::greedy(tokenizer::encode(&text), 4);
        let _ = engine::generate_with(cfg, be, &req)?;
    }
    let mut out = Vec::new();
    for i in 0..n_prompts {
        let text = crate::corpus::continuation_prompt(seed0 + i as u64, ctx);
        let req = GenRequest::greedy(tokenizer::encode(&text), gen);
        let r = engine::generate_with(cfg, be, &req)?;
        out.push(r.stats);
    }
    Ok(out)
}

/// Micro-averaged throughput over a batch (paper Table 1 caption: α is
/// the micro-averaged throughput speedup).
pub fn micro_throughput(stats: &[GenStats], with_offload: bool) -> f64 {
    let agg = aggregate(stats);
    let secs = agg.decode_secs + if with_offload { agg.offload_secs } else { 0.0 };
    if secs <= 0.0 {
        return 0.0;
    }
    agg.new_tokens as f64 / secs
}

/// Macro-averaged accept length τ.
pub fn macro_tau(stats: &[GenStats]) -> f64 {
    if stats.is_empty() {
        return 0.0;
    }
    stats.iter().map(|s| s.accept_len()).sum::<f64>() / stats.len() as f64
}

/// Engine config helper.
pub fn engine_cfg(base: &Config, kind: EngineKind, budget: Option<usize>) -> Config {
    let mut c = base.clone();
    c.engine = kind;
    if let Some(b) = budget {
        c.specpv.retrieval_budget = b;
    }
    c
}

/// Dispatch an experiment by id ("fig1", "table1", … or "all").
pub fn run_experiment(
    be: &dyn Backend,
    base: &Config,
    id: &str,
    out: &Path,
    quick: bool,
) -> Result<()> {
    match id {
        "fig1" => experiments::fig1(be, base, out, quick),
        "table1" => experiments::table1(be, base, out, quick),
        "fig4" => experiments::fig4(be, base, out, quick),
        "table2" => experiments::table2(be, base, out, quick),
        "table3" => experiments::table3(be, base, out, quick),
        "fig5" => experiments::fig5(be, base, out, quick),
        "table4" => experiments::table4(be, base, out, quick),
        "fig6" => experiments::fig6(be, base, out, quick),
        "fig7" => experiments::fig7(be, base, out, quick),
        "fig8" => experiments::fig8(be, base, out),
        "all" => {
            for id in [
                "table1", "fig1", "fig4", "fig8", "table4", "fig6",
                "table2", "fig7", "table3", "fig5",
            ] {
                println!("=== {id} ===");
                run_experiment(be, base, id, out, quick)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    }
}
