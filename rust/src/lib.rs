//! # SpecPV — self-speculative decoding with partial verification
//!
//! Rust/JAX/Pallas reproduction of *"SpecPV: Improving Self-Speculative
//! Decoding for Long-Context Generation via Partial Verification"*
//! (Tan et al., 2025).
//!
//! This crate is the **L3 coordinator**: it owns the serving event loop,
//! the paged KV-cache bookkeeping, draft-tree construction, the
//! Full/Partial/Refresh verification mode machine (paper Alg. 1),
//! speculative sampling, the offload simulator, the TCP server and all
//! evaluation baselines. Engines run on the typed kernel-op API of the
//! [`backend::Backend`] trait: the `backend::pjrt` implementation plays
//! the AOT artifacts (L2 JAX graphs wrapping the L1 Pallas kernels,
//! compiled to HLO text by `python/compile/aot.py`) through the PJRT CPU
//! client, and `backend::reference` executes the same char-LM forward
//! semantics in pure Rust so the whole stack runs artifact-free. Python
//! is never on the request path.
//!
//! See `DESIGN.md` for the system inventory and the experiment index.

pub mod backend;
pub mod bench;
pub mod cache;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod engine;
pub mod harness;
pub mod json;
pub mod kvstore;
pub mod manifest;
pub mod metrics;
pub mod model;
pub mod offload;
pub mod policy;
pub mod retrieval;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod server;
pub mod tokenizer;
pub mod tree;
pub mod util;
pub mod weights;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
