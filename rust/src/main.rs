//! `specpv` — launcher CLI for the SpecPV serving stack.
//!
//! ```text
//! specpv generate --prompt-file f.txt [--engine spec_pv] [--max-new 256]
//! specpv continue --ctx 4096 --seed 1 [--engine ...]   # PG-19-style demo
//! specpv serve    [--addr 127.0.0.1:7799] [--max-active 4]
//!                 [--max-queue 256] [--max-prompt 7168]
//!                 [--kv-budget-bytes N] [--prefix-cache-bytes N]
//!                 [--shards N] [--route-imbalance F]
//!                 [--journal-dir DIR] [--journal-fsync always|interval_ms:N|never]
//!                 # N > 1: sharded serving — N workers, each its own
//!                 # coordinator/backend/KV pool, sessions routed by
//!                 # prompt-prefix affinity; Ctrl-C drains gracefully
//!                 # --journal-dir: write-ahead request journal +
//!                 # durable checkpoint store; a restart recovers every
//!                 # unfinished session and {"op":"generate_retry",
//!                 # "id":N} replays exactly the missing suffix
//! specpv bench    <fig1|table1|fig4|table2|table3|fig5|table4|fig6|fig7|fig8|all>
//!                 [--out results] [--quick]
//! specpv bench backend [--quick] [--check] [--update-baseline]
//!                 # reference-backend op bench: fast vs naive-oracle
//!                 # timings + five-engine e2e; writes BENCH_backend.json
//!                 # at the repo root; --check fails on a >2x regression
//!                 # vs BENCH_baseline.json; --update-baseline rewrites
//!                 # the committed ceilings from this run
//! specpv bench kvstore [--quick]   # KV state manager bench: prefix-hit
//!                 # vs cold-prefill TTFT at the 1024 bucket, snapshot
//!                 # export/import and swap round-trip costs; writes
//!                 # BENCH_kvstore.json at the repo root
//! specpv bench serve [--quick]     # cross-session batched decode:
//!                 # sweeps batch 1/2/4/8 concurrent sessions, reports
//!                 # aggregate tok/s + p95 step latency, writes
//!                 # BENCH_serve.json; fails unless batch=4 beats batch=1,
//!                 # shards=2 beats shards=1, and checkpoint recovery
//!                 # (failover and journaled cold restart) beats full
//!                 # regeneration on >=1024-token prompts
//! specpv bench policy [--quick] [--check]  # adaptive speculation
//!                 # policy sweep (virtual time): adaptive vs fixed depth
//!                 # + fixed refresh period on short/long/drifty scripted
//!                 # workloads; writes BENCH_policy.json; --check fails
//!                 # unless adaptive >= best fixed on every workload and
//!                 # strictly beats the fixed refresh period on drifty
//! specpv inspect  # backend / artifact catalog summary
//! ```
//! Common flags: `--artifacts DIR --size s|m|l --engine E --budget N
//! --backend auto|pjrt|reference --threads N --set key=value`.
//!
//! The backend defaults to `auto`: the PJRT artifact player when
//! `artifacts/manifest.json` exists, the pure-Rust reference backend
//! otherwise — so every command works on a fresh checkout.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use specpv::backend::{self, Backend};
use specpv::cli::Cli;
use specpv::config::Config;
use specpv::engine::{self, GenRequest};
use specpv::harness;
use specpv::{corpus, server, tokenizer};

fn usage() -> ! {
    eprintln!(
        "usage: specpv <generate|continue|serve|bench|inspect> [options]\n\
         see rust/src/main.rs header for the full flag list"
    );
    std::process::exit(2);
}

fn build_config(cli: &Cli) -> Result<Config> {
    let mut cfg = match cli.opt("config") {
        Some(path) => Config::from_file(std::path::Path::new(path))?,
        None => Config::default(),
    };
    // every config key doubles as `--<key-with-dashes>` (plus legacy
    // aliases), generated from the one declarative table in config.rs
    for def in specpv::config::options() {
        let flag = def.flag();
        let value = cli
            .opt(&flag)
            .or_else(|| def.alias.and_then(|a| cli.opt(a)));
        if let Some(v) = value {
            def.apply(&mut cfg, v)?;
        } else if def.switch
            && (cli.has_flag(&flag) || def.alias.is_some_and(|a| cli.has_flag(a)))
        {
            def.apply(&mut cfg, "true")?;
        }
    }
    // generic overrides: --set key=value (repeatable via comma list)
    if let Some(kvs) = cli.opt("set") {
        let mut map = BTreeMap::new();
        for kv in kvs.split(',') {
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("--set '{kv}' is not key=value"))?;
            map.insert(k.to_string(), v.to_string());
        }
        cfg.apply_overrides(&map)?;
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    let cfg = build_config(&cli)?;
    match cli.command() {
        Some("generate") => {
            let be = backend::from_config(&cfg)?;
            let prompt = match (cli.opt("prompt"), cli.opt("prompt-file")) {
                (Some(p), _) => p.to_string(),
                (None, Some(f)) => std::fs::read_to_string(f)?,
                (None, None) => bail!("--prompt or --prompt-file required"),
            };
            let req = GenRequest {
                prompt: tokenizer::encode(&prompt),
                max_new: cfg.max_new_tokens,
                temperature: cfg.temperature,
                seed: cli.opt_parse::<u64>("seed")?.unwrap_or(0),
            };
            let r = engine::generate_with(&cfg, be.as_ref(), &req)?;
            println!("{}", r.text());
            eprintln!(
                "[{} tokens, {:.1} tok/s, τ={:.2}, modes F/P/R = {}/{}/{}]",
                r.tokens.len(),
                r.stats.throughput(),
                r.stats.accept_len(),
                r.stats.full_steps,
                r.stats.partial_steps,
                r.stats.refresh_steps,
            );
        }
        Some("continue") => {
            let be = backend::from_config(&cfg)?;
            let ctx = cli.opt_parse::<usize>("ctx")?.unwrap_or(2048);
            let seed = cli.opt_parse::<u64>("seed")?.unwrap_or(1);
            let prompt = corpus::continuation_prompt(seed, ctx);
            let req = GenRequest {
                prompt: tokenizer::encode(&prompt),
                max_new: cfg.max_new_tokens,
                temperature: cfg.temperature,
                seed,
            };
            let r = engine::generate_with(&cfg, be.as_ref(), &req)?;
            println!("...{}", &prompt[prompt.len().saturating_sub(200)..]);
            println!("--- continuation ({} engine) ---", cfg.engine);
            println!("{}", r.text());
            eprintln!(
                "[{:.1} tok/s, τ={:.2}, modes F/P/R = {}/{}/{}]",
                r.stats.throughput(),
                r.stats.accept_len(),
                r.stats.full_steps,
                r.stats.partial_steps,
                r.stats.refresh_steps,
            );
        }
        Some("serve") => {
            let be = backend::from_config(&cfg)?;
            // first Ctrl-C drains gracefully (in-flight requests finish,
            // streaming clients see a draining marker); second exits hard
            specpv::serve::install_ctrlc();
            server::serve(be.as_ref(), cfg)?;
        }
        Some("bench") => {
            let id = cli.sub().unwrap_or("all").to_string();
            let out = PathBuf::from(cli.opt_or("out", "results"));
            if id == "backend" {
                // reference-backend microbench: times each kernel op fast
                // vs the naive oracle and the five engines end-to-end;
                // writes BENCH_backend.json at the repo root
                return specpv::bench::backend::run(
                    &out,
                    cli.has_flag("quick"),
                    cli.has_flag("check"),
                    cli.has_flag("update-baseline"),
                );
            }
            if id == "kvstore" {
                // KV state manager bench: prefix-hit vs cold TTFT,
                // snapshot export/import, swap round-trip
                return specpv::bench::kvstore::run(&out, cli.has_flag("quick"));
            }
            if id == "serve" {
                // cross-session batched decode: sweeps batch ∈ {1,2,4,8}
                // concurrent sessions, writes BENCH_serve.json, fails
                // unless batch=4 beats batch=1 aggregate tok/s
                return specpv::bench::serve::run(&out, cli.has_flag("quick"), cfg.threads);
            }
            if id == "policy" {
                // adaptive speculation policy sweep in virtual time:
                // adaptive vs fixed depth / fixed refresh period on the
                // short/long/drifty scripted workloads
                return specpv::bench::policy::run(
                    &out,
                    cli.has_flag("quick"),
                    cli.has_flag("check"),
                );
            }
            let be = backend::from_config(&cfg)?;
            harness::run_experiment(be.as_ref(), &cfg, &id, &out, cli.has_flag("quick"))?;
            let c = be.counters();
            eprintln!(
                "[{} backend: {} executions ({:.1}s), {} compiles ({:.1}s)]",
                be.name(), c.executions, c.exec_secs, c.compilations, c.compile_secs
            );
            let mut per: Vec<_> = c.per_exec.iter().collect();
            per.sort_by(|a, b| b.1 .1.partial_cmp(&a.1 .1).unwrap());
            for (name, (n, secs)) in per.iter().take(12) {
                eprintln!(
                    "  {name:32} {n:>6} calls {secs:>8.2}s ({:>7.2} ms/call)",
                    secs / *n as f64 * 1e3
                );
            }
        }
        Some("inspect") => {
            let be = backend::from_config(&cfg)?;
            println!("{}", be.describe());
            println!("models:");
            for size in be.sizes() {
                let info = be.model(&size)?;
                println!(
                    "  {size}: L={} d={} H={} vocab={} ({}) full buckets {:?}",
                    info.n_layer,
                    info.d_model,
                    info.n_head,
                    info.vocab,
                    info.weights_file,
                    be.full_buckets(&size),
                );
            }
        }
        _ => usage(),
    }
    Ok(())
}
