//! Token selection: greedy argmax, temperature sampling, and tree-walk
//! speculative sampling (Leviathan et al. 2023 / SpecInfer-style multi-
//! candidate verification). The efficiency benches run at temperature 0
//! like the paper (§4.2); stochastic verification is exercised by unit
//! tests and available through the server API.

use crate::util::rng::Rng;

/// Softmax over a logits row (numerically stable), optionally tempered.
pub fn softmax(logits: &[f32], temperature: f32) -> Vec<f32> {
    let t = temperature.max(1e-6);
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&x| ((x - m) / t).exp()).collect();
    let z: f32 = exps.iter().sum::<f32>().max(1e-30);
    exps.iter().map(|e| e / z).collect()
}

/// log-softmax (for draft-tree cumulative scores).
pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let z: f32 = logits.iter().map(|&x| (x - m).exp()).sum();
    let lz = z.ln() + m;
    logits.iter().map(|&x| x - lz).collect()
}

pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best
}

/// Indices of the top-k logits, descending.
pub fn top_k(logits: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    let k = k.min(idx.len());
    idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
        logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx.sort_by(|&a, &b| {
        logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx
}

/// Sample an index from a probability vector.
pub fn sample(probs: &[f32], rng: &mut Rng) -> usize {
    let r = rng.f64() as f32;
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if r < acc {
            return i;
        }
    }
    probs.len() - 1
}

/// Pick the committed token at a verified node: greedy argmax at
/// temperature 0, otherwise a categorical sample.
pub fn pick_token(logits: &[f32], temperature: f32, rng: &mut Rng) -> u32 {
    if temperature <= 0.0 {
        argmax(logits) as u32
    } else {
        sample(&softmax(logits, temperature), rng) as u32
    }
}

/// Single-candidate speculative acceptance (Leviathan et al. 2023):
/// accept draft token `x` with prob min(1, p(x)/q(x)); on rejection,
/// resample from normalize(max(p − q, 0)). `p`/`q` are target/draft
/// probability vectors. Returns (accepted, committed_token).
pub fn spec_accept(
    p: &[f32],
    q: &[f32],
    x: usize,
    rng: &mut Rng,
) -> (bool, usize) {
    let px = p[x];
    let qx = q[x].max(1e-30);
    if (rng.f64() as f32) < (px / qx).min(1.0) {
        return (true, x);
    }
    // residual distribution
    let resid: Vec<f32> = p
        .iter()
        .zip(q.iter())
        .map(|(&pi, &qi)| (pi - qi).max(0.0))
        .collect();
    let z: f32 = resid.iter().sum();
    if z <= 0.0 {
        // p ≤ q everywhere except x (can't happen with proper dists, but
        // guard): fall back to sampling from p
        return (false, sample(p, rng));
    }
    let norm: Vec<f32> = resid.iter().map(|r| r / z).collect();
    (false, sample(&norm, rng))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_temperature_sharpens() {
        let hot = softmax(&[1.0, 2.0], 2.0);
        let cold = softmax(&[1.0, 2.0], 0.1);
        assert!(cold[1] > hot[1]);
    }

    #[test]
    fn argmax_topk() {
        let l = [0.1f32, 5.0, -1.0, 3.0];
        assert_eq!(argmax(&l), 1);
        assert_eq!(top_k(&l, 2), vec![1, 3]);
        assert_eq!(top_k(&l, 10).len(), 4);
    }

    #[test]
    fn log_softmax_consistent() {
        let l = [0.5f32, 1.5, -0.5];
        let ls = log_softmax(&l);
        let p = softmax(&l, 1.0);
        for i in 0..3 {
            assert!((ls[i].exp() - p[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn sample_respects_support() {
        let mut rng = Rng::new(1);
        let probs = [0.0f32, 1.0, 0.0];
        for _ in 0..100 {
            assert_eq!(sample(&probs, &mut rng), 1);
        }
    }

    /// The headline correctness property of speculative sampling: the
    /// committed-token distribution equals the target distribution p,
    /// regardless of the draft q (Leviathan et al., Thm 1).
    #[test]
    fn spec_sampling_preserves_distribution() {
        let p = vec![0.5f32, 0.3, 0.2];
        let q = vec![0.2f32, 0.2, 0.6];
        let mut rng = Rng::new(7);
        let mut counts = [0usize; 3];
        let n = 60_000;
        for _ in 0..n {
            let x = sample(&q, &mut rng);
            let (_, committed) = spec_accept(&p, &q, x, &mut rng);
            counts[committed] += 1;
        }
        for i in 0..3 {
            let freq = counts[i] as f32 / n as f32;
            assert!(
                (freq - p[i]).abs() < 0.02,
                "token {i}: freq {freq} vs p {}",
                p[i]
            );
        }
    }

    /// Greedy limit of speculative acceptance: as temperature → 0 the
    /// target distribution is one-hot at its argmax, so `spec_accept`
    /// commits exactly the argmax regardless of the draft — i.e. the
    /// greedy chain walk the engines use is the T=0 special case.
    #[test]
    fn spec_accept_greedy_limit_equals_argmax_chain() {
        Prop::new("one-hot target commits its argmax", 200).run(|g| {
            let n = g.usize_in(2, 32);
            // one-hot target (greedy limit), arbitrary proper-ish draft
            let best = g.usize_in(0, n - 1);
            let mut p = vec![0f32; n];
            p[best] = 1.0;
            let mut q: Vec<f32> = (0..n).map(|_| g.f32_in(0.01, 1.0)).collect();
            let z: f32 = q.iter().sum();
            for x in &mut q {
                *x /= z;
            }
            let mut rng = Rng::new(g.u64());
            let x = g.usize_in(0, n - 1);
            let (accepted, committed) = spec_accept(&p, &q, x, &mut rng);
            assert_eq!(committed, best, "greedy limit must commit argmax(p)");
            if x == best {
                // p(x)/q(x) ≥ 1 → acceptance is certain
                assert!(accepted, "drafting the argmax must always accept");
            }
        });
    }

    /// Chain acceptance preserves the target distribution position-wise:
    /// walking a drafted chain with `spec_accept` (stop at the first
    /// rejection, as the engines do) leaves the first committed token
    /// distributed exactly as p, and the second committed token — on
    /// chains whose first draft was accepted — again as p (the i.i.d.
    /// target of this synthetic setup).
    #[test]
    fn spec_accept_chain_prefix_matches_target_distribution() {
        let p = vec![0.45f32, 0.35, 0.2];
        let q = vec![0.2f32, 0.3, 0.5];
        let mut rng = Rng::new(42);
        let n = 60_000;
        let mut first = [0usize; 3];
        let mut second = [0usize; 3];
        let mut second_n = 0usize;
        for _ in 0..n {
            // draft a 2-chain from q, verify both positions
            let x0 = sample(&q, &mut rng);
            let (acc0, c0) = spec_accept(&p, &q, x0, &mut rng);
            first[c0] += 1;
            if acc0 {
                let x1 = sample(&q, &mut rng);
                let (_, c1) = spec_accept(&p, &q, x1, &mut rng);
                second[c1] += 1;
                second_n += 1;
            }
        }
        for i in 0..3 {
            let f = first[i] as f32 / n as f32;
            assert!(
                (f - p[i]).abs() < 0.02,
                "pos 0 token {i}: freq {f} vs p {}",
                p[i]
            );
        }
        assert!(second_n > n / 4, "acceptance rate implausibly low");
        for i in 0..3 {
            let f = second[i] as f32 / second_n as f32;
            assert!(
                (f - p[i]).abs() < 0.02,
                "pos 1 token {i}: freq {f} vs p {}",
                p[i]
            );
        }
    }

    #[test]
    fn pick_token_greedy_matches_argmax() {
        Prop::new("greedy pick == argmax", 100).run(|g| {
            let n = g.usize_in(1, 50);
            let l = g.vec_f32(n, -5.0, 5.0);
            let mut rng = Rng::new(g.u64());
            assert_eq!(pick_token(&l, 0.0, &mut rng), argmax(&l) as u32);
        });
    }

    #[test]
    fn topk_property_sorted_and_maximal() {
        Prop::new("top_k sorted desc, contains max", 100).run(|g| {
            let n = g.usize_in(1, 64);
            let l = g.vec_f32(n, -10.0, 10.0);
            let k = g.usize_in(1, l.len());
            let t = top_k(&l, k);
            assert_eq!(t.len(), k);
            for w in t.windows(2) {
                assert!(l[w[0]] >= l[w[1]]);
            }
            assert_eq!(t[0], argmax(&l));
        });
    }
}
