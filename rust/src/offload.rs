//! KV-offload simulator (paper §3.3 last ¶ and Fig. 4).
//!
//! The paper's memory-constrained setting offloads the **full** KV cache
//! to host RAM over PCIe, keeping only the partial and draft caches on
//! device; every full-cache verification then pays a transfer of the
//! whole used cache (layer-by-layer, partially hidden by prefetch).
//! We have no discrete GPU, so the PCIe cost is *modelled*: each
//! full-cache touch adds `bytes / bw × (1 − overlap)` seconds to a
//! virtual clock which the harness adds to the measured decode time
//! (partial-verification steps add nothing — exactly the asymmetry that
//! produces Fig. 4). The simulator is deterministic; parameters come from
//! `OffloadConfig` (defaults: 12 GB/s effective PCIe 4.0, 30 % overlap).

use crate::config::OffloadConfig;

#[derive(Debug, Clone)]
pub struct OffloadSim {
    cfg: OffloadConfig,
    /// accumulated simulated transfer seconds
    pub secs: f64,
    /// transfers counted
    pub touches: u64,
    pub bytes: u64,
}

impl OffloadSim {
    pub fn new(cfg: OffloadConfig) -> OffloadSim {
        OffloadSim { cfg, secs: 0.0, touches: 0, bytes: 0 }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Account one full-cache touch (a verify/commit/score/gather over the
    /// offloaded cache) reading `used_tokens × bytes_per_token` bytes.
    pub fn touch_full(&mut self, used_tokens: usize, bytes_per_token: usize) {
        if !self.cfg.enabled {
            return;
        }
        let bytes = (used_tokens * bytes_per_token) as f64;
        let t = bytes / (self.cfg.pcie_gbps * 1e9) * (1.0 - self.cfg.overlap);
        self.secs += t;
        self.touches += 1;
        self.bytes += bytes as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(enabled: bool) -> OffloadConfig {
        OffloadConfig { enabled, pcie_gbps: 10.0, overlap: 0.5 }
    }

    #[test]
    fn disabled_is_free() {
        let mut s = OffloadSim::new(cfg(false));
        s.touch_full(1_000_000, 1024);
        assert_eq!(s.secs, 0.0);
        assert_eq!(s.touches, 0);
    }

    #[test]
    fn cost_scales_with_bytes() {
        let mut s = OffloadSim::new(cfg(true));
        s.touch_full(1000, 1000); // 1 MB over 10 GB/s, 50% hidden
        let expect = 1e6 / 10e9 * 0.5;
        assert!((s.secs - expect).abs() < 1e-12);
        s.touch_full(2000, 1000);
        assert!((s.secs - 3.0 * expect).abs() < 1e-12);
        assert_eq!(s.touches, 2);
    }
}
