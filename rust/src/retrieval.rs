//! Retrieval-block selection for the partial KV cache (paper §3.2).
//!
//! The `score_*` executable returns, per layer, the three reductions
//! (mean/max/last) of the Quest-style block scores; this module picks the
//! top-k retrieval blocks per layer, merges them with the always-kept
//! sink and local blocks, and produces the per-layer gather index list
//! (token order: sink ++ retrieval ++ local) the `gather_*` executable
//! consumes, plus the valid-length bookkeeping of the resulting cache.

use crate::config::{Reduction, SpecPvConfig};

pub const NEG_INF: f32 = -1e30;

/// The gather plan for one refresh: per-layer block ids (each `nsel`
/// long, padded by repeating the final block) and the valid token count
/// of the assembled core.
#[derive(Debug, Clone)]
pub struct GatherPlan {
    /// [L][nsel] block indices in token order
    pub block_idx: Vec<Vec<i32>>,
    /// valid tokens in the partial cache after gathering (== write offset
    /// for the buffer region); identical across layers by construction
    pub core_len: usize,
    /// number of real (unpadded) blocks per layer
    pub core_blocks: usize,
}

/// Scores layout from the executable: `[L, 3, NB]` flattened.
pub fn layer_scores<'a>(
    scores: &'a [f32],
    layer: usize,
    nb: usize,
    red: Reduction,
) -> &'a [f32] {
    let off = layer * 3 * nb + red.row() * nb;
    &scores[off..off + nb]
}

/// Top-k block indices by score, excluding `excluded`, ascending order.
fn top_blocks(
    scores: &[f32],
    k: usize,
    lo_excluded: usize,
    hi_start: usize,
) -> Vec<usize> {
    // candidates: [lo_excluded, hi_start) — sink blocks below, local above
    let mut idx: Vec<usize> = (lo_excluded..hi_start)
        .filter(|&i| scores[i] > NEG_INF / 2.0)
        .collect();
    idx.sort_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// Build the gather plan after a Refresh.
///
/// * `scores`: flat `[L, 3, NB]` download from the score executable.
/// * `committed`: target-cache committed token count (post-commit).
/// * `nsel`: gather width in blocks (partial bucket / block).
pub fn plan_gather(
    scores: &[f32],
    n_layer: usize,
    nb: usize,
    block: usize,
    committed: usize,
    nsel: usize,
    cfg: &SpecPvConfig,
) -> GatherPlan {
    assert!(committed > 0, "cannot build a partial cache before prefill");
    let valid_blocks = committed.div_ceil(block).min(nb);
    let sink = cfg.sink_blocks.min(valid_blocks);
    let local = cfg.local_blocks.min(valid_blocks - sink);
    let local_start = valid_blocks - local;
    let want_ret = (cfg.retrieval_budget / block)
        .min(nsel.saturating_sub(sink + local));

    let mut block_idx = Vec::with_capacity(n_layer);
    let mut core_blocks = 0usize;
    for l in 0..n_layer {
        let s = layer_scores(scores, l, nb, cfg.reduction);
        let ret = top_blocks(s, want_ret, sink, local_start);
        let mut ids: Vec<i32> = Vec::with_capacity(nsel);
        ids.extend((0..sink).map(|b| b as i32));
        ids.extend(ret.iter().map(|&b| b as i32));
        ids.extend((local_start..valid_blocks).map(|b| b as i32));
        core_blocks = ids.len();
        // pad by repeating the final block; padded slots land beyond the
        // valid length and are never visible to attention
        let last = *ids.last().expect("nonempty plan");
        while ids.len() < nsel {
            ids.push(last);
        }
        assert_eq!(ids.len(), nsel);
        block_idx.push(ids);
    }

    // the final core block is the one containing token committed-1; it is
    // partially filled unless committed % block == 0
    let fill = (committed - 1) % block + 1;
    let core_len = (core_blocks - 1) * block + fill;
    GatherPlan { block_idx, core_len, core_blocks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;

    fn cfg(budget: usize) -> SpecPvConfig {
        SpecPvConfig { retrieval_budget: budget, ..Default::default() }
    }

    fn mk_scores(n_layer: usize, nb: usize, f: impl Fn(usize, usize) -> f32) -> Vec<f32> {
        // identical mean/max/last rows for test simplicity
        let mut v = vec![0f32; n_layer * 3 * nb];
        for l in 0..n_layer {
            for r in 0..3 {
                for b in 0..nb {
                    v[l * 3 * nb + r * nb + b] = f(l, b);
                }
            }
        }
        v
    }

    #[test]
    fn picks_highest_scoring_blocks() {
        let nb = 32;
        let scores = mk_scores(2, nb, |_, b| if b == 10 || b == 20 { 5.0 } else { 0.1 });
        // budget 2 blocks => exactly blocks 10, 20 chosen as retrieval
        let plan = plan_gather(&scores, 2, nb, 32, 32 * 30, 2 + 1 + 2, &cfg(64));
        for l in 0..2 {
            let ids = &plan.block_idx[l];
            assert_eq!(ids[0], 0); // sink
            assert_eq!(&ids[1..3], &[10, 20]); // retrieval ascending
            assert_eq!(&ids[3..5], &[28, 29]); // local = last two blocks
        }
    }

    #[test]
    fn partial_last_block_shortens_core_len() {
        let nb = 16;
        let scores = mk_scores(1, nb, |_, b| b as f32);
        let committed = 32 * 7 + 5; // last block holds 5 tokens
        let plan = plan_gather(&scores, 1, nb, 32, committed, 6, &cfg(64));
        assert_eq!(plan.core_blocks, 1 + 2 + 2); // sink + 2 ret + 2 local
        assert_eq!(plan.core_len, (5 - 1) * 32 + 5);
    }

    #[test]
    fn pads_to_nsel() {
        let nb = 8;
        let scores = mk_scores(1, nb, |_, b| b as f32);
        let plan = plan_gather(&scores, 1, nb, 32, 32 * 8, 16, &cfg(1024));
        assert_eq!(plan.block_idx[0].len(), 16);
        // padding repeats the last real block
        let last_real = plan.block_idx[0][plan.core_blocks - 1];
        for &p in &plan.block_idx[0][plan.core_blocks..] {
            assert_eq!(p, last_real);
        }
    }

    #[test]
    fn tie_break_is_deterministic_lowest_block_first() {
        // all-equal scores: the stable sort keeps candidate order, so
        // retrieval picks the lowest-indexed eligible blocks — and two
        // identical calls produce identical plans
        let nb = 24;
        let scores = mk_scores(2, nb, |_, _| 1.0);
        let nsel = 1 + 4 + 2; // sink + 4 retrieval + 2 local
        let a = plan_gather(&scores, 2, nb, 32, 32 * 20, nsel, &cfg(128));
        let b = plan_gather(&scores, 2, nb, 32, 32 * 20, nsel, &cfg(128));
        assert_eq!(a.block_idx, b.block_idx, "tied plan must be deterministic");
        assert_eq!(a.core_len, b.core_len);
        for ids in &a.block_idx {
            // retrieval = first eligible blocks after the sink
            assert_eq!(&ids[1..5], &[1, 2, 3, 4]);
        }
        Prop::new("tied scores break ties deterministically", 100).run(|g| {
            let nb = g.usize_in(8, 40);
            let n_layer = g.usize_in(1, 3);
            let tied = g.f32_in(-1.0, 1.0);
            let scores = mk_scores(n_layer, nb, |_, _| tied);
            let committed = g.usize_in(5 * 32, nb * 32);
            let c = cfg(*g.pick(&[64usize, 128]));
            let nsel = (c.retrieval_budget / 32 + 3).min(nb);
            let x = plan_gather(&scores, n_layer, nb, 32, committed, nsel, &c);
            let y = plan_gather(&scores, n_layer, nb, 32, committed, nsel, &c);
            assert_eq!(x.block_idx, y.block_idx);
            // every layer saw the same (tied) scores → identical rows
            for ids in &x.block_idx[1..] {
                assert_eq!(ids, &x.block_idx[0]);
            }
        });
    }

    #[test]
    fn padding_repeats_final_block_under_random_geometries() {
        Prop::new("gather padding repeats the final block", 150).run(|g| {
            let nb = g.usize_in(4, 64);
            let n_layer = g.usize_in(1, 4);
            let committed = g.usize_in(1, nb * 32);
            let scores: Vec<f32> =
                (0..n_layer * 3 * nb).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let c = cfg(*g.pick(&[32usize, 64, 128, 256]));
            // nsel must cover the always-kept sink+local blocks (callers
            // derive it from the partial bucket, which always does)
            let nsel = g.usize_in(c.sink_blocks + c.local_blocks + 1, nb + 4);
            let plan = plan_gather(&scores, n_layer, nb, 32, committed, nsel, &c);
            for ids in &plan.block_idx {
                assert_eq!(ids.len(), nsel, "every layer padded to nsel");
                let last_real = ids[plan.core_blocks - 1];
                for &p in &ids[plan.core_blocks..] {
                    assert_eq!(p, last_real, "padding must repeat the final block");
                }
            }
        });
    }

    #[test]
    fn core_len_is_consistent_across_layers() {
        Prop::new("per-layer core width identical", 150).run(|g| {
            let nb = g.usize_in(6, 48);
            let n_layer = g.usize_in(2, 5);
            let committed = g.usize_in(32, nb * 32);
            // deliberately different scores per layer: the *selection*
            // differs, the core width must not
            let scores: Vec<f32> =
                (0..n_layer * 3 * nb).map(|_| g.f32_in(-3.0, 3.0)).collect();
            let c = cfg(*g.pick(&[64usize, 128]));
            let nsel = (c.retrieval_budget / 32 + 3).min(nb);
            let plan = plan_gather(&scores, n_layer, nb, 32, committed, nsel, &c);
            assert_eq!(plan.block_idx.len(), n_layer);
            for ids in &plan.block_idx {
                // the first core_blocks entries are the real core in
                // every layer: strictly ascending and in range
                for w in ids[..plan.core_blocks].windows(2) {
                    assert!(w[0] < w[1]);
                }
            }
            // core_len is derived from core_blocks + the committed fill,
            // identically for every layer by construction
            let fill = (committed - 1) % 32 + 1;
            assert_eq!(plan.core_len, (plan.core_blocks - 1) * 32 + fill);
            assert!(plan.core_len <= committed);
        });
    }

    #[test]
    fn excludes_sink_and_local_from_retrieval() {
        Prop::new("retrieval excludes sink/local", 100).run(|g| {
            let nb = g.usize_in(8, 64);
            let n_layer = g.usize_in(1, 4);
            let committed = g.usize_in(5 * 32, nb * 32);
            let scores: Vec<f32> =
                (0..n_layer * 3 * nb).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let c = cfg(*g.pick(&[64usize, 128, 256]));
            let nsel = (c.retrieval_budget / 32 + 3).min(nb);
            let plan = plan_gather(&scores, n_layer, nb, 32, committed, nsel, &c);
            let valid_blocks = committed.div_ceil(32).min(nb);
            for ids in &plan.block_idx {
                // strictly ascending within the real core, within range
                for w in ids[..plan.core_blocks].windows(2) {
                    assert!(w[0] < w[1], "{ids:?}");
                }
                for &b in &ids[..plan.core_blocks] {
                    assert!((b as usize) < valid_blocks);
                }
            }
            // core_len consistent with committed fill
            let fill = (committed - 1) % 32 + 1;
            assert_eq!(plan.core_len, (plan.core_blocks - 1) * 32 + fill);
        });
    }
}
