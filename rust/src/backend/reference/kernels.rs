//! Dense kernels for the reference backend: cache-blocked, thread-pooled
//! matmul over a pre-transposed weight layout, plus the original scalar
//! kernels kept as the **naive oracle** (`specpv bench backend` measures
//! fast-vs-naive, and `rust/tests/backend_parity.rs` asserts the two are
//! byte-identical — which is why the oracle is a runtime mode rather
//! than a `#[cfg(test)]` item).
//!
//! Determinism contract: for every output element `out[i][o]` both paths
//! accumulate `x[i][k] · w[k][o]` over `k` **ascending, with a single
//! accumulator, skipping `x[i][k] == 0` terms** — the exact reduction
//! order of the original scalar kernel. Parallelism only ever partitions
//! *output elements* (rows or column blocks), never the `k` reduction,
//! so results are byte-identical at any thread count.

use crate::util::pool::{split_range, Pool};

/// Below this many multiply-accumulates a kernel runs serially — the
/// pool's wake/latch round-trip (a few µs) dwarfs the work.
pub(crate) const PAR_MIN_WORK: usize = 16 * 1024;

/// Raw `*mut f32` that may cross a pool boundary. Chunks index disjoint
/// ranges, computed deterministically from the chunk id.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub *mut f32);

// SAFETY: every user writes only the chunk-id-derived disjoint range, and
// Pool::run blocks until all chunks finished.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// A dense weight matrix `[din, dout]` stored twice: `rm` row-major
/// (what the seeded init produces and what the naive oracle streams, so
/// the oracle keeps the original kernel's access pattern) and `t`
/// transposed `[dout, din]` (so the fast path computes each output as a
/// contiguous–contiguous dot). ~1 MB of weights at the CI geometry, so
/// the duplication is free.
pub(crate) struct Mat {
    pub rm: Vec<f32>,
    pub t: Vec<f32>,
    pub din: usize,
    pub dout: usize,
}

impl Mat {
    pub fn from_row_major(rm: Vec<f32>, din: usize, dout: usize) -> Mat {
        debug_assert_eq!(rm.len(), din * dout);
        let mut t = vec![0f32; rm.len()];
        for k in 0..din {
            for o in 0..dout {
                t[o * din + k] = rm[k * dout + o];
            }
        }
        Mat { rm, t, din, dout }
    }

    #[inline]
    pub fn trow(&self, o: usize) -> &[f32] {
        &self.t[o * self.din..(o + 1) * self.din]
    }
}

/// Plain dot product, ascending, single accumulator (the attention
/// score/readout reduction — no zero-skip, matching the original).
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Matmul reduction for one output element: ascending `k`, single
/// accumulator, zero-input terms skipped (original kernel order).
#[inline]
pub(crate) fn dot_skip(x: &[f32], w: &[f32]) -> f32 {
    let mut acc = 0f32;
    for (&xv, &wv) in x.iter().zip(w) {
        if xv != 0.0 {
            acc += xv * wv;
        }
    }
    acc
}

#[inline]
fn mm_cols(out: &mut [f32], xr: &[f32], w: &Mat, o0: usize, o1: usize) {
    for (ov, o) in out.iter_mut().zip(o0..o1) {
        *ov = dot_skip(xr, w.trow(o));
    }
}

/// `out[t, dout] = x[t, din] @ w`, parallel over rows (tall inputs) or
/// output-column blocks (wide single-row projections like `lm_head`).
/// Every element of `out` is written (no pre-zeroing needed).
pub(crate) fn matmul_t(pool: &Pool, out: &mut [f32], x: &[f32], w: &Mat, t: usize) {
    let (din, dout) = (w.din, w.dout);
    debug_assert_eq!(out.len(), t * dout);
    debug_assert_eq!(x.len(), t * din);
    if t == 0 {
        return;
    }
    let work = t * din * dout;
    if pool.threads() == 1 || work < PAR_MIN_WORK {
        for i in 0..t {
            mm_cols(&mut out[i * dout..(i + 1) * dout], &x[i * din..(i + 1) * din], w, 0, dout);
        }
        return;
    }
    let optr = SendPtr(out.as_mut_ptr());
    if t >= 2 * pool.threads() {
        // row-parallel: each chunk owns a contiguous row band
        let chunks = pool.threads().min(t);
        pool.run(chunks, &|c| {
            let (r0, r1) = split_range(t, chunks, c);
            for i in r0..r1 {
                // SAFETY: row i belongs to exactly one chunk
                let orow =
                    unsafe { std::slice::from_raw_parts_mut(optr.0.add(i * dout), dout) };
                mm_cols(orow, &x[i * din..(i + 1) * din], w, 0, dout);
            }
        });
    } else {
        // column-parallel: each chunk owns a contiguous column band of
        // every row (the t=1 lm_head projection lands here)
        let chunks = pool.threads().min(dout);
        pool.run(chunks, &|c| {
            let (o0, o1) = split_range(dout, chunks, c);
            if o0 == o1 {
                return;
            }
            for i in 0..t {
                // SAFETY: columns o0..o1 of row i belong to this chunk only
                let oseg = unsafe {
                    std::slice::from_raw_parts_mut(optr.0.add(i * dout + o0), o1 - o0)
                };
                mm_cols(oseg, &x[i * din..(i + 1) * din], w, o0, o1);
            }
        });
    }
}

/// The original scalar matmul (axpy over row-major weights, fresh-output
/// accumulation). Kept verbatim as the parity oracle and the bench
/// baseline. `out` must be zeroed.
pub(crate) fn matmul_naive(out: &mut [f32], x: &[f32], w: &Mat, t: usize) {
    let (din, dout) = (w.din, w.dout);
    for i in 0..t {
        let xr = &x[i * din..(i + 1) * din];
        let or = &mut out[i * dout..(i + 1) * dout];
        for (k, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w.rm[k * dout..(k + 1) * dout];
            for (o, &wv) in wr.iter().enumerate() {
                or[o] += xv * wv;
            }
        }
    }
}

/// Row-wise RMSNorm into a caller-provided buffer (eps 1e-5, original
/// reduction order).
pub(crate) fn rmsnorm_into(out: &mut [f32], x: &[f32], g: &[f32], t: usize, h: usize) {
    for i in 0..t {
        let row = &x[i * h..(i + 1) * h];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / h as f32;
        let r = 1.0 / (ms + 1e-5).sqrt();
        let orow = &mut out[i * h..(i + 1) * h];
        for j in 0..h {
            orow[j] = row[j] * g[j] * r;
        }
    }
}

#[inline]
pub(crate) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mat(rng: &mut Rng, din: usize, dout: usize) -> Mat {
        let rm: Vec<f32> = (0..din * dout).map(|_| rng.normal() as f32).collect();
        Mat::from_row_major(rm, din, dout)
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_row_major(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        // rm[k, o] == t[o, k]
        assert_eq!(m.trow(0), &[1.0, 4.0]);
        assert_eq!(m.trow(2), &[3.0, 6.0]);
    }

    #[test]
    fn fast_matches_naive_bytewise_at_any_thread_count() {
        let mut rng = Rng::new(11);
        // shapes cover the serial path, the row-parallel band split and
        // the column-parallel t=1 lm_head projection
        for (t, din, dout) in
            [(1usize, 32, 320), (1, 64, 512), (16, 48, 48), (64, 32, 96), (5, 7, 9)]
        {
            let w = mat(&mut rng, din, dout);
            let mut x: Vec<f32> = (0..t * din).map(|_| rng.normal() as f32).collect();
            // sprinkle exact zeros to exercise the skip path
            for i in (0..x.len()).step_by(7) {
                x[i] = 0.0;
            }
            let mut want = vec![0f32; t * dout];
            matmul_naive(&mut want, &x, &w, t);
            for threads in [1usize, 2, 4] {
                let pool = Pool::new(threads);
                let mut got = vec![f32::NAN; t * dout];
                matmul_t(&pool, &mut got, &x, &w, t);
                assert!(
                    got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "t={t} din={din} dout={dout} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn rmsnorm_shape_and_scale() {
        let x = vec![3.0f32, 4.0, 0.0, 0.0];
        let g = vec![1.0f32, 1.0];
        let mut out = vec![0f32; 4];
        rmsnorm_into(&mut out, &x, &g, 2, 2);
        // row 0: ms = 12.5, r = 1/sqrt(12.500_01)
        let r = 1.0 / (12.5f32 + 1e-5).sqrt();
        assert_eq!(out[0].to_bits(), (3.0 * r).to_bits());
        assert_eq!(out[2], 0.0);
    }
}
