//! Model definition and forward passes for the reference backend: the
//! seeded-weight char LM (`python/compile/model.py` semantics) with two
//! execution paths over identical math:
//!
//! * **fast** — arena-backed buffers, pooled blocked matmuls, one RoPE
//!   table per forward, and *no* `lm_head` projection (the post-final-norm
//!   hidden rows are returned so logits materialize lazily at read time);
//! * **naive** — the original scalar pipeline kept verbatim as the parity
//!   oracle and bench baseline (fresh `Vec` per op, per-token `sin_cos`,
//!   eager full-vocab logits).
//!
//! Both accumulate every float in the same fixed order, so their outputs
//! are byte-identical (`rust/tests/backend_parity.rs`).

use crate::util::pool::Pool;
use crate::util::rng::Rng;

use super::attention::{
    attention, attention_batch, attention_naive, rope_apply_naive, rope_apply_tab, rope_tab,
    AttItem, KvDims, RopeTab,
};
use super::kernels::{matmul_naive, matmul_t, rmsnorm_into, silu, Mat};
use super::scratch::Arena;

/// Model hyperparameters (mirrors `model.py::ModelCfg` at reduced scale).
#[derive(Debug, Clone)]
pub(crate) struct RefCfg {
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub rope_theta: f64,
    pub train_ctx: usize,
}

impl RefCfg {
    pub fn hd(&self) -> usize {
        self.n_head * self.d_head
    }

    /// EAGLE-3 feature taps (low/mid/top layer inputs); fewer than three
    /// distinct layers (the tiny LM) means no fused feature.
    pub fn feat_layers(&self) -> Vec<usize> {
        let mut v = vec![0, self.n_layer / 2, self.n_layer - 1];
        v.dedup();
        v
    }

    pub fn has_feats(&self) -> bool {
        self.feat_layers().len() == 3
    }
}

pub(crate) struct LayerW {
    pub ln1: Vec<f32>,
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub ln2: Vec<f32>,
    pub wg: Mat,
    pub wu: Mat,
    pub wd: Mat,
}

pub(crate) struct TargetW {
    pub embed: Vec<f32>,
    pub ln_f: Vec<f32>,
    pub head: Mat,
    pub layers: Vec<LayerW>,
}

pub(crate) struct DraftW {
    pub fuse: Mat,
    pub inp: Mat,
    pub ln_f: Vec<f32>,
    pub layer: LayerW,
}

pub(crate) struct MedusaW {
    /// per head: (w1 [h,h], w2 [h,V])
    pub heads: Vec<(Mat, Mat)>,
}

pub(crate) struct RefModel {
    pub cfg: RefCfg,
    pub target: TargetW,
    pub draft: Option<DraftW>,
    pub medusa: Option<MedusaW>,
    pub inv_freq: Vec<f32>,
    pub mscale: f32,
}

// ---------------------------------------------------------------------------
// Deterministic init (seeded xorshift; scales mirror model.py). The RNG
// stream order is unchanged from the scalar backend, so weights — and
// therefore every generated token — are byte-identical across the
// refactor.
// ---------------------------------------------------------------------------

fn normal_mat(rng: &mut Rng, rows: usize, cols: usize, std: f32) -> Vec<f32> {
    (0..rows * cols).map(|_| rng.normal() as f32 * std).collect()
}

fn dense(rng: &mut Rng, fan_in: usize, fan_out: usize) -> Mat {
    let rm = normal_mat(rng, fan_in, fan_out, 1.0 / (fan_in as f32).sqrt());
    Mat::from_row_major(rm, fan_in, fan_out)
}

fn init_layer(rng: &mut Rng, cfg: &RefCfg) -> LayerW {
    let (h, hd, ff) = (cfg.d_model, cfg.hd(), cfg.d_ff);
    LayerW {
        ln1: vec![1.0; h],
        wq: dense(rng, h, hd),
        wk: dense(rng, h, hd),
        wv: dense(rng, h, hd),
        wo: dense(rng, hd, h),
        ln2: vec![1.0; h],
        wg: dense(rng, h, ff),
        wu: dense(rng, h, ff),
        wd: dense(rng, ff, h),
    }
}

pub(crate) fn seed_of(size: &str) -> u64 {
    size.bytes()
        .fold(0x5EED_CAFE_F00Du64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64))
}

pub(crate) fn init_model(size: &str, cfg: RefCfg, with_draft: bool) -> RefModel {
    let mut rng = Rng::new(seed_of(size));
    let h = cfg.d_model;
    let target = TargetW {
        embed: normal_mat(&mut rng, cfg.vocab, h, 0.02),
        ln_f: vec![1.0; h],
        head: dense(&mut rng, h, cfg.vocab),
        layers: (0..cfg.n_layer).map(|_| init_layer(&mut rng, &cfg)).collect(),
    };
    let draft = with_draft.then(|| DraftW {
        fuse: dense(&mut rng, 3 * h, h),
        inp: dense(&mut rng, 2 * h, h),
        ln_f: vec![1.0; h],
        layer: init_layer(&mut rng, &cfg),
    });
    let medusa = with_draft.then(|| MedusaW {
        heads: (0..3)
            .map(|_| (dense(&mut rng, h, h), dense(&mut rng, h, cfg.vocab)))
            .collect(),
    });
    let (inv_freq, mscale) = yarn_inv_freq(&cfg, super::YARN_FACTOR);
    RefModel { cfg, target, draft, medusa, inv_freq, mscale }
}

/// YARN-scaled inverse frequencies + attention temperature
/// (`model.py::yarn_inv_freq`, NTK-by-parts).
pub(crate) fn yarn_inv_freq(cfg: &RefCfg, factor: f64) -> (Vec<f32>, f32) {
    let d = cfg.d_head;
    let inv: Vec<f64> = (0..d / 2)
        .map(|k| 1.0 / cfg.rope_theta.powf(2.0 * k as f64 / d as f64))
        .collect();
    if factor <= 1.0 {
        return (inv.iter().map(|&x| x as f32).collect(), 1.0);
    }
    let l = cfg.train_ctx as f64;
    let (beta_fast, beta_slow) = (32.0f64, 1.0f64);
    let corr_dim = |rot: f64| -> f64 {
        (d as f64 * (l / (rot * 2.0 * std::f64::consts::PI)).ln())
            / (2.0 * cfg.rope_theta.ln())
    };
    let low = corr_dim(beta_fast).floor().max(0.0);
    let high = corr_dim(beta_slow).ceil().min(d as f64 / 2.0 - 1.0);
    let denom = (high - low).max(1.0);
    let inv_yarn: Vec<f32> = inv
        .iter()
        .enumerate()
        .map(|(k, &f)| {
            let ramp = ((k as f64 - low) / denom).clamp(0.0, 1.0);
            (f * (1.0 - ramp) + (f / factor) * ramp) as f32
        })
        .collect();
    let mscale = (0.1 * factor.ln() + 1.0) as f32;
    (inv_yarn, mscale)
}

// ---------------------------------------------------------------------------
// Forward outputs
// ---------------------------------------------------------------------------

pub(crate) struct FwdOut {
    /// `[T, h]` post-final-norm rows (fast path; logits materialize
    /// lazily at read time). Empty on the naive path.
    pub hidden: Vec<f32>,
    /// `[T, V]` eager logits (naive path). Empty on the fast path.
    pub logits: Vec<f32>,
    /// `[T, 3h]` fused EAGLE-3 feature (empty when the model has < 3 taps)
    pub feats: Vec<f32>,
    /// per layer `[H, T, D]` post-RoPE queries (empty unless requested)
    pub queries: Vec<Vec<f32>>,
}

impl FwdOut {
    /// Return the arena-owned buffers for reuse.
    pub fn recycle(self, arena: &mut Arena) {
        arena.give(self.hidden);
        arena.give(self.logits);
        arena.give(self.feats);
    }
}

fn embed_rows(x: &mut [f32], tokens: &[i32], embed: &[f32], h: usize, vocab: usize) {
    for (i, &tok) in tokens.iter().enumerate() {
        let row = (tok.max(0) as usize).min(vocab - 1);
        x[i * h..(i + 1) * h].copy_from_slice(&embed[row * h..(row + 1) * h]);
    }
}

fn queries_transposed(xq: &[f32], t: usize, n_head: usize, d: usize) -> Vec<f32> {
    // [T, H·D] → [H, T, D]
    let hd = n_head * d;
    let mut q = vec![0f32; hd * t];
    for i in 0..t {
        for hh in 0..n_head {
            q[(hh * t + i) * d..(hh * t + i) * d + d]
                .copy_from_slice(&xq[i * hd + hh * d..i * hd + hh * d + d]);
        }
    }
    q
}

// ---------------------------------------------------------------------------
// Fast path
// ---------------------------------------------------------------------------

/// One transformer layer (`model.py::layer_fwd`): writes this step's K/V
/// rows at `write_pos`, runs tree attention, returns the post-RoPE
/// queries (an arena buffer the caller must `give` back).
#[allow(clippy::too_many_arguments)]
fn layer_fwd(
    w: &LayerW,
    cfg: &RefCfg,
    pool: &Pool,
    arena: &mut Arena,
    x: &mut [f32],
    pos: &[i32],
    kv: &mut [f32],
    dims: KvDims,
    layer: usize,
    kv_len: usize,
    write_pos: usize,
    mask: &[f32],
    rope: &RopeTab,
    mscale: f32,
) -> Vec<f32> {
    let t = pos.len();
    let (h, hd, d) = (cfg.d_model, cfg.hd(), cfg.d_head);
    let tk = mask.len() / t;
    let mut hn = arena.take(t * h);
    rmsnorm_into(&mut hn, x, &w.ln1, t, h);
    let mut xq = arena.take(t * hd);
    let mut xk = arena.take(t * hd);
    let mut xv = arena.take(t * hd);
    matmul_t(pool, &mut xq, &hn, &w.wq, t);
    matmul_t(pool, &mut xk, &hn, &w.wk, t);
    matmul_t(pool, &mut xv, &hn, &w.wv, t);
    rope_apply_tab(&mut xq, rope, t, cfg.n_head, d);
    rope_apply_tab(&mut xk, rope, t, cfg.n_head, d);

    // functional dynamic_update_slice (clamped start, full T-row block)
    let start = write_pos.min(dims.b.saturating_sub(t));
    for i in 0..t {
        for hh in 0..cfg.n_head {
            let krow = dims.row(layer, 0, hh, start + i);
            kv[krow..krow + d].copy_from_slice(&xk[i * hd + hh * d..i * hd + hh * d + d]);
            let vrow = dims.row(layer, 1, hh, start + i);
            kv[vrow..vrow + d].copy_from_slice(&xv[i * hd + hh * d..i * hd + hh * d + d]);
        }
    }

    let scale = mscale / (d as f32).sqrt();
    let mut att = arena.take(t * hd);
    attention(pool, &mut att, &xq, kv, dims, layer, t, tk, mask, kv_len, scale);
    let mut proj = arena.take(t * h);
    matmul_t(pool, &mut proj, &att, &w.wo, t);
    for (xx, p) in x.iter_mut().zip(&proj) {
        *xx += p;
    }

    // MLP; hn is re-normed in place, proj doubles as the down buffer
    rmsnorm_into(&mut hn, x, &w.ln2, t, h);
    let mut g = arena.take(t * cfg.d_ff);
    let mut u = arena.take(t * cfg.d_ff);
    matmul_t(pool, &mut g, &hn, &w.wg, t);
    matmul_t(pool, &mut u, &hn, &w.wu, t);
    for (gv, &uv) in g.iter_mut().zip(&u) {
        *gv = silu(*gv) * uv;
    }
    matmul_t(pool, &mut proj, &g, &w.wd, t);
    for (xx, p) in x.iter_mut().zip(&proj) {
        *xx += p;
    }
    arena.give(hn);
    arena.give(xk);
    arena.give(xv);
    arena.give(att);
    arena.give(proj);
    arena.give(g);
    arena.give(u);
    xq
}

/// Target forward (`model.py::target_fwd`): serves prefill, AR decode,
/// full/partial/refresh verification and the tiny LM — only the bucket,
/// token count and mask differ. Fast path: returns post-final-norm
/// hidden rows instead of projecting the vocabulary.
#[allow(clippy::too_many_arguments)]
pub(crate) fn target_fwd(
    model: &RefModel,
    pool: &Pool,
    arena: &mut Arena,
    kv: &mut [f32],
    bucket: usize,
    tokens: &[i32],
    pos: &[i32],
    mask: &[f32],
    kv_len: usize,
    write_pos: usize,
    want_queries: bool,
) -> FwdOut {
    let cfg = &model.cfg;
    let t = tokens.len();
    let h = cfg.d_model;
    let dims = KvDims { l: cfg.n_layer, h: cfg.n_head, b: bucket, d: cfg.d_head };
    let mut x = arena.take(t * h);
    embed_rows(&mut x, tokens, &model.target.embed, h, cfg.vocab);
    let rope = rope_tab(pos, &model.inv_freq);
    let taps = cfg.feat_layers();
    let has_feats = cfg.has_feats();
    let mut feats = if has_feats { arena.take(t * 3 * h) } else { Vec::new() };
    let mut queries: Vec<Vec<f32>> = Vec::new();
    for (l, w) in model.target.layers.iter().enumerate() {
        if has_feats {
            if let Some(slot) = taps.iter().position(|&tl| tl == l) {
                for i in 0..t {
                    feats[i * 3 * h + slot * h..i * 3 * h + (slot + 1) * h]
                        .copy_from_slice(&x[i * h..(i + 1) * h]);
                }
            }
        }
        let xq = layer_fwd(
            w, cfg, pool, arena, &mut x, pos, kv, dims, l, kv_len, write_pos, mask, &rope,
            model.mscale,
        );
        if want_queries {
            queries.push(queries_transposed(&xq, t, cfg.n_head, cfg.d_head));
        }
        arena.give(xq);
    }
    let mut hidden = arena.take(t * h);
    rmsnorm_into(&mut hidden, &x, &model.target.ln_f, t, h);
    arena.give(x);
    FwdOut { hidden, logits: Vec::new(), feats, queries }
}

/// Draft decoder forward (`model.py::draft_fwd`). Expand steps keep
/// eager logits (every draft row is read every step); prefill passes
/// `want_logits = false` — the op contract zeroes the logits region, so
/// projecting the chunk would be the op's single largest matmul thrown
/// away. The returned hidden is the pre-norm residual, moved without a
/// copy.
#[allow(clippy::too_many_arguments)]
pub(crate) fn draft_fwd(
    model: &RefModel,
    pool: &Pool,
    arena: &mut Arena,
    kv: &mut [f32],
    bucket: usize,
    tokens: &[i32],
    feats: &[f32],
    pos: &[i32],
    mask: &[f32],
    kv_len: usize,
    write_pos: usize,
    want_logits: bool,
) -> (Vec<f32>, Vec<f32>) {
    let cfg = &model.cfg;
    let dw = model.draft.as_ref().expect("draft weights");
    let t = tokens.len();
    let h = cfg.d_model;
    let dims = KvDims { l: 1, h: cfg.n_head, b: bucket, d: cfg.d_head };
    let mut f = arena.take(t * h);
    matmul_t(pool, &mut f, feats, &dw.fuse, t);
    let mut cat = arena.take(t * 2 * h);
    for (i, &tok) in tokens.iter().enumerate() {
        let row = (tok.max(0) as usize).min(cfg.vocab - 1);
        cat[i * 2 * h..i * 2 * h + h]
            .copy_from_slice(&model.target.embed[row * h..(row + 1) * h]);
        cat[i * 2 * h + h..(i + 1) * 2 * h].copy_from_slice(&f[i * h..(i + 1) * h]);
    }
    let mut x = arena.take(t * h);
    matmul_t(pool, &mut x, &cat, &dw.inp, t);
    let rope = rope_tab(pos, &model.inv_freq);
    let xq = layer_fwd(
        &dw.layer, cfg, pool, arena, &mut x, pos, kv, dims, 0, kv_len, write_pos, mask,
        &rope, model.mscale,
    );
    arena.give(xq);
    arena.give(cat);
    if !want_logits {
        arena.give(f);
        return (Vec::new(), x);
    }
    let mut xf = f; // reuse the fuse buffer for the final norm
    rmsnorm_into(&mut xf, &x, &dw.ln_f, t, h);
    let mut logits = arena.take(t * cfg.vocab);
    matmul_t(pool, &mut logits, &xf, &model.target.head, t);
    arena.give(xf);
    (logits, x)
}

// ---------------------------------------------------------------------------
// Batched fast path (cross-session fusion, DESIGN.md §12)
//
// One session's per-layer matmuls stream the full weight matrix for a
// handful of rows; stacking B sessions' rows into one matmul amortizes
// that weight traffic (and the pool wake/latch round-trip) B×. Everything
// that is *row-independent* — embedding, RMSNorm, the six per-layer
// matmuls, SwiGLU, residual adds, the final norm — runs over the stacked
// `[ΣT, …]` buffer; everything *sequence-dependent* — RoPE positions, KV
// writes, attention over each session's own KV slab — stays per-session
// (attention units are fused into one pool dispatch, never one softmax).
// Because every per-row reduction runs in the exact single-session order,
// batched outputs are byte-identical to sequential execution at any batch
// size and thread count (`rust/tests/batched_parity.rs`).
// ---------------------------------------------------------------------------

/// One session's slice of a batched target/tiny forward.
pub(crate) struct BatchItem<'a> {
    pub kv: &'a mut [f32],
    pub bucket: usize,
    pub tokens: &'a [i32],
    pub pos: &'a [i32],
    pub mask: &'a [f32],
    /// visible history length (== write offset for verify-shaped ops)
    pub kv_len: usize,
    pub write_pos: usize,
    pub want_queries: bool,
}

/// One session's slice of a batched draft-expand forward.
pub(crate) struct DraftItem<'a> {
    pub kv: &'a mut [f32],
    pub bucket: usize,
    pub tokens: &'a [i32],
    pub feats: &'a [f32],
    pub pos: &'a [i32],
    pub mask: &'a [f32],
    pub kv_len: usize,
    pub write_pos: usize,
}

/// The per-layer view `layer_fwd_batch` needs of either item kind.
struct LayerItem<'a> {
    kv: &'a mut [f32],
    bucket: usize,
    mask: &'a [f32],
    kv_len: usize,
    write_pos: usize,
}

/// One transformer layer over the stacked rows of many sessions: fused
/// matmuls over `[ΣT, …]`, per-session RoPE/KV-write/attention. Returns
/// the stacked post-RoPE queries (an arena buffer the caller `give`s).
#[allow(clippy::too_many_arguments)]
fn layer_fwd_batch(
    w: &LayerW,
    cfg: &RefCfg,
    pool: &Pool,
    arena: &mut Arena,
    x: &mut [f32],
    items: &mut [LayerItem<'_>],
    ts: &[usize],
    offs: &[usize],
    ropes: &[RopeTab],
    kv_layers: usize,
    layer: usize,
    mscale: f32,
) -> Vec<f32> {
    let total: usize = ts.iter().sum();
    let (h, hd, d) = (cfg.d_model, cfg.hd(), cfg.d_head);
    let mut hn = arena.take(total * h);
    rmsnorm_into(&mut hn, x, &w.ln1, total, h);
    let mut xq = arena.take(total * hd);
    let mut xk = arena.take(total * hd);
    let mut xv = arena.take(total * hd);
    matmul_t(pool, &mut xq, &hn, &w.wq, total);
    matmul_t(pool, &mut xk, &hn, &w.wk, total);
    matmul_t(pool, &mut xv, &hn, &w.wv, total);
    for (bi, _it) in items.iter().enumerate() {
        let (t, off) = (ts[bi], offs[bi]);
        rope_apply_tab(&mut xq[off * hd..(off + t) * hd], &ropes[bi], t, cfg.n_head, d);
        rope_apply_tab(&mut xk[off * hd..(off + t) * hd], &ropes[bi], t, cfg.n_head, d);
    }
    for (bi, it) in items.iter_mut().enumerate() {
        let (t, off) = (ts[bi], offs[bi]);
        let dims = KvDims { l: kv_layers, h: cfg.n_head, b: it.bucket, d };
        let start = it.write_pos.min(dims.b.saturating_sub(t));
        for i in 0..t {
            for hh in 0..cfg.n_head {
                let src = (off + i) * hd + hh * d;
                let krow = dims.row(layer, 0, hh, start + i);
                it.kv[krow..krow + d].copy_from_slice(&xk[src..src + d]);
                let vrow = dims.row(layer, 1, hh, start + i);
                it.kv[vrow..vrow + d].copy_from_slice(&xv[src..src + d]);
            }
        }
    }

    let scale = mscale / (d as f32).sqrt();
    let mut att = arena.take(total * hd);
    {
        let atts: Vec<AttItem> = items
            .iter()
            .enumerate()
            .map(|(bi, it)| AttItem {
                q: &xq[offs[bi] * hd..(offs[bi] + ts[bi]) * hd],
                kv: &*it.kv,
                dims: KvDims { l: kv_layers, h: cfg.n_head, b: it.bucket, d },
                layer,
                t: ts[bi],
                tk: it.mask.len() / ts[bi],
                mask: it.mask,
                kv_len: it.kv_len,
                out_off: offs[bi],
            })
            .collect();
        attention_batch(pool, &mut att, &atts, scale);
    }
    let mut proj = arena.take(total * h);
    matmul_t(pool, &mut proj, &att, &w.wo, total);
    for (xx, p) in x.iter_mut().zip(&proj) {
        *xx += p;
    }

    rmsnorm_into(&mut hn, x, &w.ln2, total, h);
    let mut g = arena.take(total * cfg.d_ff);
    let mut u = arena.take(total * cfg.d_ff);
    matmul_t(pool, &mut g, &hn, &w.wg, total);
    matmul_t(pool, &mut u, &hn, &w.wu, total);
    for (gv, &uv) in g.iter_mut().zip(&u) {
        *gv = silu(*gv) * uv;
    }
    matmul_t(pool, &mut proj, &g, &w.wd, total);
    for (xx, p) in x.iter_mut().zip(&proj) {
        *xx += p;
    }
    arena.give(hn);
    arena.give(xk);
    arena.give(xv);
    arena.give(att);
    arena.give(proj);
    arena.give(g);
    arena.give(u);
    xq
}

/// Batched target forward over many sessions (verify/prefill/tiny step
/// shapes). Per-item outputs are split back out at the end; `hidden` and
/// `feats` in each returned [`FwdOut`] are arena buffers the caller must
/// `recycle`.
pub(crate) fn target_fwd_batch(
    model: &RefModel,
    pool: &Pool,
    arena: &mut Arena,
    items: &mut [BatchItem<'_>],
) -> Vec<FwdOut> {
    let cfg = &model.cfg;
    let (h, hd, d) = (cfg.d_model, cfg.hd(), cfg.d_head);
    let ts: Vec<usize> = items.iter().map(|it| it.tokens.len()).collect();
    let mut offs = Vec::with_capacity(ts.len());
    let mut total = 0usize;
    for &t in &ts {
        offs.push(total);
        total += t;
    }
    let mut x = arena.take(total * h);
    for (bi, it) in items.iter().enumerate() {
        embed_rows(
            &mut x[offs[bi] * h..(offs[bi] + ts[bi]) * h],
            it.tokens,
            &model.target.embed,
            h,
            cfg.vocab,
        );
    }
    let ropes: Vec<RopeTab> = items.iter().map(|it| rope_tab(it.pos, &model.inv_freq)).collect();
    let taps = cfg.feat_layers();
    let has_feats = cfg.has_feats();
    let mut feats = if has_feats { arena.take(total * 3 * h) } else { Vec::new() };
    let mut queries: Vec<Vec<Vec<f32>>> = items.iter().map(|_| Vec::new()).collect();
    for (l, w) in model.target.layers.iter().enumerate() {
        if has_feats {
            if let Some(slot) = taps.iter().position(|&tl| tl == l) {
                for i in 0..total {
                    feats[i * 3 * h + slot * h..i * 3 * h + (slot + 1) * h]
                        .copy_from_slice(&x[i * h..(i + 1) * h]);
                }
            }
        }
        let xq = {
            let mut litems: Vec<LayerItem> = items
                .iter_mut()
                .map(|it| LayerItem {
                    kv: &mut *it.kv,
                    bucket: it.bucket,
                    mask: it.mask,
                    kv_len: it.kv_len,
                    write_pos: it.write_pos,
                })
                .collect();
            layer_fwd_batch(
                w, cfg, pool, arena, &mut x, &mut litems, &ts, &offs, &ropes, cfg.n_layer, l,
                model.mscale,
            )
        };
        for (bi, it) in items.iter().enumerate() {
            if it.want_queries {
                let (t, off) = (ts[bi], offs[bi]);
                queries[bi]
                    .push(queries_transposed(&xq[off * hd..(off + t) * hd], t, cfg.n_head, d));
            }
        }
        arena.give(xq);
    }
    let mut hidden = arena.take(total * h);
    rmsnorm_into(&mut hidden, &x, &model.target.ln_f, total, h);
    arena.give(x);
    let mut outs = Vec::with_capacity(items.len());
    for bi in 0..items.len() {
        let (t, off) = (ts[bi], offs[bi]);
        let mut hid = arena.take(t * h);
        hid.copy_from_slice(&hidden[off * h..(off + t) * h]);
        let ft = if has_feats {
            let mut f = arena.take(t * 3 * h);
            f.copy_from_slice(&feats[off * 3 * h..(off + t) * 3 * h]);
            f
        } else {
            Vec::new()
        };
        outs.push(FwdOut {
            hidden: hid,
            logits: Vec::new(),
            feats: ft,
            queries: std::mem::take(&mut queries[bi]),
        });
    }
    arena.give(hidden);
    arena.give(feats);
    outs
}

/// Batched EAGLE draft-expand forward: the fuse/input projections, the
/// single decoder layer and the `lm_head` projection all run over the
/// stacked `[ΣW, …]` rows. Returns per-item `(logits, hidden)` pairs
/// (arena buffers the caller must `give` back).
pub(crate) fn draft_fwd_batch(
    model: &RefModel,
    pool: &Pool,
    arena: &mut Arena,
    items: &mut [DraftItem<'_>],
) -> Vec<(Vec<f32>, Vec<f32>)> {
    let cfg = &model.cfg;
    let dw = model.draft.as_ref().expect("draft weights");
    let h = cfg.d_model;
    let ts: Vec<usize> = items.iter().map(|it| it.tokens.len()).collect();
    let mut offs = Vec::with_capacity(ts.len());
    let mut total = 0usize;
    for &t in &ts {
        offs.push(total);
        total += t;
    }
    let mut fin = arena.take(total * 3 * h);
    for (bi, it) in items.iter().enumerate() {
        fin[offs[bi] * 3 * h..(offs[bi] + ts[bi]) * 3 * h].copy_from_slice(it.feats);
    }
    let mut f = arena.take(total * h);
    matmul_t(pool, &mut f, &fin, &dw.fuse, total);
    arena.give(fin);
    let mut cat = arena.take(total * 2 * h);
    for (bi, it) in items.iter().enumerate() {
        for (i, &tok) in it.tokens.iter().enumerate() {
            let row = (tok.max(0) as usize).min(cfg.vocab - 1);
            let dst = (offs[bi] + i) * 2 * h;
            cat[dst..dst + h].copy_from_slice(&model.target.embed[row * h..(row + 1) * h]);
            cat[dst + h..dst + 2 * h]
                .copy_from_slice(&f[(offs[bi] + i) * h..(offs[bi] + i + 1) * h]);
        }
    }
    arena.give(f);
    let mut x = arena.take(total * h);
    matmul_t(pool, &mut x, &cat, &dw.inp, total);
    arena.give(cat);
    let ropes: Vec<RopeTab> = items.iter().map(|it| rope_tab(it.pos, &model.inv_freq)).collect();
    let xq = {
        let mut litems: Vec<LayerItem> = items
            .iter_mut()
            .map(|it| LayerItem {
                kv: &mut *it.kv,
                bucket: it.bucket,
                mask: it.mask,
                kv_len: it.kv_len,
                write_pos: it.write_pos,
            })
            .collect();
        layer_fwd_batch(
            &dw.layer, cfg, pool, arena, &mut x, &mut litems, &ts, &offs, &ropes, 1, 0,
            model.mscale,
        )
    };
    arena.give(xq);
    let mut xf = arena.take(total * h);
    rmsnorm_into(&mut xf, &x, &dw.ln_f, total, h);
    let mut logits = arena.take(total * cfg.vocab);
    matmul_t(pool, &mut logits, &xf, &model.target.head, total);
    arena.give(xf);
    let mut outs = Vec::with_capacity(items.len());
    for bi in 0..items.len() {
        let (t, off) = (ts[bi], offs[bi]);
        let mut lg = arena.take(t * cfg.vocab);
        lg.copy_from_slice(&logits[off * cfg.vocab..(off + t) * cfg.vocab]);
        let mut hid = arena.take(t * h);
        hid.copy_from_slice(&x[off * h..(off + t) * h]);
        outs.push((lg, hid));
    }
    arena.give(logits);
    arena.give(x);
    outs
}

// ---------------------------------------------------------------------------
// Naive oracle path (the original scalar pipeline, kept verbatim)
// ---------------------------------------------------------------------------

fn matmul_alloc(x: &[f32], w: &Mat, t: usize) -> Vec<f32> {
    let mut out = vec![0f32; t * w.dout];
    matmul_naive(&mut out, x, w, t);
    out
}

fn rmsnorm_alloc(x: &[f32], g: &[f32], t: usize, h: usize) -> Vec<f32> {
    let mut out = vec![0f32; t * h];
    rmsnorm_into(&mut out, x, g, t, h);
    out
}

#[allow(clippy::too_many_arguments)]
fn layer_fwd_naive(
    w: &LayerW,
    cfg: &RefCfg,
    x: &mut Vec<f32>,
    pos: &[i32],
    kv: &mut [f32],
    dims: KvDims,
    layer: usize,
    kv_len: usize,
    write_pos: usize,
    mask: &[f32],
    inv_freq: &[f32],
    mscale: f32,
) -> Vec<f32> {
    let t = pos.len();
    let (h, hd, d) = (cfg.d_model, cfg.hd(), cfg.d_head);
    let tk = mask.len() / t;
    let hn = rmsnorm_alloc(x, &w.ln1, t, h);
    let mut xq = matmul_alloc(&hn, &w.wq, t);
    let mut xk = matmul_alloc(&hn, &w.wk, t);
    let xv = matmul_alloc(&hn, &w.wv, t);
    rope_apply_naive(&mut xq, pos, inv_freq, t, cfg.n_head, d);
    rope_apply_naive(&mut xk, pos, inv_freq, t, cfg.n_head, d);

    let start = write_pos.min(dims.b.saturating_sub(t));
    for i in 0..t {
        for hh in 0..cfg.n_head {
            let krow = dims.row(layer, 0, hh, start + i);
            kv[krow..krow + d].copy_from_slice(&xk[i * hd + hh * d..i * hd + hh * d + d]);
            let vrow = dims.row(layer, 1, hh, start + i);
            kv[vrow..vrow + d].copy_from_slice(&xv[i * hd + hh * d..i * hd + hh * d + d]);
        }
    }

    let scale = mscale / (d as f32).sqrt();
    let mut att = vec![0f32; t * hd];
    attention_naive(&mut att, &xq, kv, dims, layer, t, tk, mask, kv_len, scale);
    let proj = matmul_alloc(&att, &w.wo, t);
    for (xx, p) in x.iter_mut().zip(&proj) {
        *xx += p;
    }

    let h2 = rmsnorm_alloc(x, &w.ln2, t, h);
    let g = matmul_alloc(&h2, &w.wg, t);
    let u = matmul_alloc(&h2, &w.wu, t);
    let act: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| silu(gv) * uv).collect();
    let down = matmul_alloc(&act, &w.wd, t);
    for (xx, p) in x.iter_mut().zip(&down) {
        *xx += p;
    }
    xq
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn target_fwd_naive(
    model: &RefModel,
    kv: &mut [f32],
    bucket: usize,
    tokens: &[i32],
    pos: &[i32],
    mask: &[f32],
    kv_len: usize,
    write_pos: usize,
    want_queries: bool,
) -> FwdOut {
    let cfg = &model.cfg;
    let t = tokens.len();
    let h = cfg.d_model;
    let dims = KvDims { l: cfg.n_layer, h: cfg.n_head, b: bucket, d: cfg.d_head };
    let mut x = vec![0f32; t * h];
    embed_rows(&mut x, tokens, &model.target.embed, h, cfg.vocab);
    let taps = cfg.feat_layers();
    let mut feats: Vec<Vec<f32>> = Vec::new();
    let mut queries: Vec<Vec<f32>> = Vec::new();
    for (l, w) in model.target.layers.iter().enumerate() {
        if cfg.has_feats() && taps.contains(&l) {
            feats.push(x.clone());
        }
        let xq = layer_fwd_naive(
            w, cfg, &mut x, pos, kv, dims, l, kv_len, write_pos, mask, &model.inv_freq,
            model.mscale,
        );
        if want_queries {
            queries.push(queries_transposed(&xq, t, cfg.n_head, cfg.d_head));
        }
    }
    let xf = rmsnorm_alloc(&x, &model.target.ln_f, t, h);
    let logits = matmul_alloc(&xf, &model.target.head, t);
    let fused = if cfg.has_feats() {
        let mut f = vec![0f32; t * 3 * h];
        for i in 0..t {
            for (s, fv) in feats.iter().enumerate() {
                f[i * 3 * h + s * h..i * 3 * h + (s + 1) * h]
                    .copy_from_slice(&fv[i * h..(i + 1) * h]);
            }
        }
        f
    } else {
        Vec::new()
    };
    FwdOut { hidden: Vec::new(), logits, feats: fused, queries }
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn draft_fwd_naive(
    model: &RefModel,
    kv: &mut [f32],
    bucket: usize,
    tokens: &[i32],
    feats: &[f32],
    pos: &[i32],
    mask: &[f32],
    kv_len: usize,
    write_pos: usize,
) -> (Vec<f32>, Vec<f32>) {
    let cfg = &model.cfg;
    let dw = model.draft.as_ref().expect("draft weights");
    let t = tokens.len();
    let h = cfg.d_model;
    let dims = KvDims { l: 1, h: cfg.n_head, b: bucket, d: cfg.d_head };
    let f = matmul_alloc(feats, &dw.fuse, t);
    let mut cat = vec![0f32; t * 2 * h];
    for (i, &tok) in tokens.iter().enumerate() {
        let row = (tok.max(0) as usize).min(cfg.vocab - 1);
        cat[i * 2 * h..i * 2 * h + h]
            .copy_from_slice(&model.target.embed[row * h..(row + 1) * h]);
        cat[i * 2 * h + h..(i + 1) * 2 * h].copy_from_slice(&f[i * h..(i + 1) * h]);
    }
    let mut x = matmul_alloc(&cat, &dw.inp, t);
    layer_fwd_naive(
        &dw.layer, cfg, &mut x, pos, kv, dims, 0, kv_len, write_pos, mask, &model.inv_freq,
        model.mscale,
    );
    let hidden = x.clone();
    let xf = rmsnorm_alloc(&x, &dw.ln_f, t, h);
    let logits = matmul_alloc(&xf, &model.target.head, t);
    (logits, hidden)
}
