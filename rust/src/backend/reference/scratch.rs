//! Scratch arena for the reference backend's hot paths.
//!
//! Every forward used to allocate a dozen fresh `Vec<f32>` temporaries
//! per layer (`matmul`, `rmsnorm`, attention accumulators, feature taps).
//! The arena keeps those buffers alive across backend ops: a kernel
//! `take`s a zeroed buffer, uses it, and `give`s it back when the op
//! finishes, so the per-layer temporaries of the steady-state decode
//! loop allocate nothing. (A few small per-forward buffers remain plain
//! `Vec`s by design: the RoPE table, the per-layer transposed query
//! copies when a verify requests them, and the vectors an op returns to
//! the caller.)
//!
//! Batched execution (DESIGN.md §12) widened the size distribution: a
//! fused op takes `B×`-row temporaries while interleaved single ops take
//! the 1-session sizes. `take` therefore picks the **smallest free
//! buffer whose capacity already fits** (falling back to the largest
//! free buffer when none fits), so the arena converges on one buffer per
//! size class instead of repeatedly regrowing a small vector to batch
//! width — steady-state mixed batched/single traffic allocates nothing.
//!
//! Lifetimes are intentionally simple: buffers live exactly for one
//! backend op (the op's entry point borrows the backend's
//! `RefCell<Arena>` for its whole duration, which is fine because a
//! backend serves one op at a time). Worker threads never touch the
//! arena — parallel kernels receive pre-`take`n buffers and write
//! disjoint chunks of them.

/// Free-list capacity: a batched verify holds ~10 temporaries at once
/// and the drafting loop a handful more; 64 slots cover every op mix
/// without letting a pathological caller hoard memory.
const MAX_FREE: usize = 64;

/// A free-list of reusable `f32` buffers. `take` pops the best-fitting
/// buffer (or allocates) and zero-fills to the requested length; `give`
/// returns a buffer to the list. Capacity grows to the high-water mark
/// of each size class and stays.
#[derive(Default)]
pub(crate) struct Arena {
    free: Vec<Vec<f32>>,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// A zero-filled buffer of exactly `len` elements. Best-fit reuse:
    /// the smallest free buffer with `capacity >= len`, else the largest
    /// free buffer (which then grows once), else a fresh allocation.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None; // smallest capacity >= len
        let mut largest: Option<usize> = None; // fallback: largest capacity
        for (i, v) in self.free.iter().enumerate() {
            let cap = v.capacity();
            if cap >= len && best.map(|b| cap < self.free[b].capacity()).unwrap_or(true) {
                best = Some(i);
            }
            if largest.map(|l| cap > self.free[l].capacity()).unwrap_or(true) {
                largest = Some(i);
            }
        }
        let mut v = match best.or(largest) {
            Some(i) => self.free.swap_remove(i),
            None => Vec::new(),
        };
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Return a buffer for reuse. Zero-capacity vectors (the empty
    /// placeholders various ops pass around) are dropped, not pooled.
    pub fn give(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 && self.free.len() < MAX_FREE {
            self.free.push(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_reuses_capacity() {
        let mut a = Arena::new();
        let mut v = a.take(8);
        assert_eq!(v, vec![0.0; 8]);
        v.iter_mut().for_each(|x| *x = 7.0);
        let cap = v.capacity();
        a.give(v);
        let v2 = a.take(4);
        assert_eq!(v2, vec![0.0; 4], "reused buffer must be re-zeroed");
        assert!(v2.capacity() >= 4 && cap >= 8);
    }

    #[test]
    fn empty_buffers_are_not_pooled() {
        let mut a = Arena::new();
        a.give(Vec::new());
        assert!(a.free.is_empty());
    }

    #[test]
    fn take_prefers_best_fit_over_regrowing_small_buffers() {
        let mut a = Arena::new();
        a.give(Vec::with_capacity(4));
        a.give(Vec::with_capacity(64));
        a.give(Vec::with_capacity(16));
        // len 10 → the 16-cap buffer, not the 4-cap one (which would
        // regrow) and not the 64-cap one (reserved for bigger takes)
        let v = a.take(10);
        assert!(v.capacity() >= 10 && v.capacity() < 64, "cap {}", v.capacity());
        // len 100 → the largest (64) grows once rather than allocating
        let w = a.take(100);
        assert!(w.capacity() >= 100);
        assert_eq!(a.free.len(), 1, "only the 4-cap buffer remains");
    }
}
