//! Scratch arena for the reference backend's hot paths.
//!
//! Every forward used to allocate a dozen fresh `Vec<f32>` temporaries
//! per layer (`matmul`, `rmsnorm`, attention accumulators, feature taps).
//! The arena keeps those buffers alive across backend ops: a kernel
//! `take`s a zeroed buffer, uses it, and `give`s it back when the op
//! finishes, so the per-layer temporaries of the steady-state decode
//! loop allocate nothing. (A few small per-forward buffers remain plain
//! `Vec`s by design: the RoPE table, the per-layer transposed query
//! copies when a verify requests them, and the vectors an op returns to
//! the caller.)
//!
//! Lifetimes are intentionally simple: buffers live exactly for one
//! backend op (the op's entry point borrows the backend's
//! `RefCell<Arena>` for its whole duration, which is fine because a
//! backend serves one op at a time). Worker threads never touch the
//! arena — parallel kernels receive pre-`take`n buffers and write
//! disjoint chunks of them.

/// A free-list of reusable `f32` buffers. `take` pops (or allocates) and
/// zero-fills to the requested length; `give` returns a buffer to the
/// list. Capacity grows to the high-water mark of each slot and stays.
#[derive(Default)]
pub(crate) struct Arena {
    free: Vec<Vec<f32>>,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// A zero-filled buffer of exactly `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Return a buffer for reuse. Zero-capacity vectors (the empty
    /// placeholders various ops pass around) are dropped, not pooled.
    pub fn give(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 && self.free.len() < 32 {
            self.free.push(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_reuses_capacity() {
        let mut a = Arena::new();
        let mut v = a.take(8);
        assert_eq!(v, vec![0.0; 8]);
        v.iter_mut().for_each(|x| *x = 7.0);
        let cap = v.capacity();
        a.give(v);
        let v2 = a.take(4);
        assert_eq!(v2, vec![0.0; 4], "reused buffer must be re-zeroed");
        assert!(v2.capacity() >= 4 && cap >= 8);
    }

    #[test]
    fn empty_buffers_are_not_pooled() {
        let mut a = Arena::new();
        a.give(Vec::new());
        assert!(a.free.is_empty());
    }
}
