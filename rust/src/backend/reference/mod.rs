//! Pure-Rust **reference backend**: executes the same char-LM forward
//! semantics as the AOT artifacts (`python/compile/model.py`) directly on
//! the host — embedding → RMSNorm → RoPE(+YARN) → tree attention over the
//! flat-state KV layout → SwiGLU → logits — with deterministic seeded
//! weights, so every engine runs end-to-end with **no artifacts**.
//!
//! Design goals (in priority order):
//! 1. *semantic parity* with the JAX graphs: same state layouts
//!    (kv | logits | feats | queries), same fused acceptance compaction,
//!    same visibility rule (`history < kv_len` ∪ masked new region), same
//!    Quest block scoring and block gather — so the decode algorithms
//!    (including SpecPV's partial-verify ≡ full-verify-over-the-same-rows
//!    property) are directly testable;
//! 2. *determinism*: weights come from a seeded xorshift init and every
//!    float reduction runs in a fixed order — parallel kernels only ever
//!    partition output elements — so identical requests produce
//!    byte-identical outputs across runs, machines and thread counts;
//! 3. *speed*: the hot paths are cache-blocked matmuls over pre-transposed
//!    weights on a scoped thread pool ([`crate::util::pool`]), a scratch
//!    arena that eliminates per-op allocation, precomputed RoPE tables,
//!    contiguous per-head KV slabs in attention, and **lazy logits** —
//!    `lm_head` runs only for the rows a [`ReadOp`] actually requests
//!    (see the module split: `kernels.rs`, `attention.rs`, `model.rs`,
//!    `scratch.rs`, and DESIGN.md §10).
//!
//! The original scalar pipeline is kept as a runtime-selectable **naive
//! oracle** ([`ReferenceBackend::naive`]); `specpv bench backend` measures
//! fast-vs-naive and `rust/tests/backend_parity.rs` pins byte equality.
//!
//! The weights are random (not trained), which is irrelevant to the
//! properties under test: losslessness (spec_full ≡ ar), the SpecPV mode
//! machine, cache accounting and scheduler behaviour are all functions of
//! the *algorithm*, not of output quality.

mod attention;
mod kernels;
mod model;
mod scratch;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::manifest::{Consts, ModelInfo, StateLayout};
use crate::util::pool::{self, Pool};

use self::attention::{compact_window, KvDims};
use self::kernels::{dot, matmul_naive, matmul_t};
use self::model::{init_model, RefCfg, RefModel};
use self::scratch::Arena;

use super::Backend as _;

use super::{
    CommitOp, Counters, DraftExpandOp, DraftPrefillOp, GatherOp, PrefillOp, ReadOp, ScoreOp,
    StateBuf, StateKind, TinyForwardOp, VerifyOp,
};

// Scaled-down geometry (the aot.py constants at CI scale). CHUNK is both
// the prefill chunk and the logits/feats row capacity, so it must cover
// the widest refresh variant.
const CHUNK: usize = 64;
const TREE_T: usize = 16;
const REFRESH_T: usize = 48;
const BIG_REFRESH_T: usize = 64;
const QROWS: usize = 16;
const DRAFT_W: usize = 8;
const DRAFT_REGION: usize = 32;
const PREV_MAX: usize = 8;
const PREV_WINDOW: usize = 16;
const BLOCK: usize = 16;
pub(crate) const YARN_FACTOR: f64 = 16.0;
const FULL_BUCKETS: [usize; 7] = [128, 288, 512, 1024, 2048, 4096, 8192];
const PARTIAL_BUCKETS: [usize; 6] = [96, 160, 224, 384, 640, 1280];
// must be ≥ 2·CHUNK so the tiny prefill's chunked writes never clamp
// (mirrors aot.py: TINY_BUCKET = 2 × CHUNK)
const TINY_BUCKET: usize = 128;

const NEG_INF: f32 = -1e30;

// ---------------------------------------------------------------------------
// Flat-state layouts (mirrors aot.py, element counts in f32)
// ---------------------------------------------------------------------------

fn full_layout(cfg: &RefCfg, b: usize) -> StateLayout {
    let kv = cfg.n_layer * 2 * cfg.n_head * b * cfg.d_head;
    let logits = CHUNK * cfg.vocab;
    let feats = CHUNK * 3 * cfg.d_model;
    let queries = cfg.n_layer * cfg.n_head * QROWS * cfg.d_head;
    StateLayout { kv, logits, feats, queries, total: kv + logits + feats + queries }
}

fn partial_layout(cfg: &RefCfg, p: usize) -> StateLayout {
    let kv = cfg.n_layer * 2 * cfg.n_head * p * cfg.d_head;
    let logits = TREE_T * cfg.vocab;
    let feats = TREE_T * 3 * cfg.d_model;
    StateLayout { kv, logits, feats, queries: 0, total: kv + logits + feats }
}

fn draft_layout(cfg: &RefCfg, b: usize) -> StateLayout {
    let kv = 2 * cfg.n_head * b * cfg.d_head;
    let logits = DRAFT_W * cfg.vocab;
    let hidden = CHUNK * cfg.d_model;
    StateLayout { kv, logits, feats: hidden, queries: 0, total: kv + logits + hidden }
}

fn tiny_layout(cfg: &RefCfg, b: usize) -> StateLayout {
    let kv = cfg.n_layer * 2 * cfg.n_head * b * cfg.d_head;
    StateLayout { kv, logits: cfg.vocab, feats: 0, queries: 0, total: kv + cfg.vocab }
}

// ---------------------------------------------------------------------------
// Host state
// ---------------------------------------------------------------------------

/// The reference backend's state buffer: the flat layout of DESIGN.md §4
/// plus (fast path) the post-final-norm hidden rows that back the
/// lazy-logits contract. When `hidden` is non-empty the `logits` region
/// of `data` is stale and reads project `hidden · lm_head` for the
/// requested rows only; when empty (naive mode, or a state no
/// verification ever ran on) reads fall back to the `data` region.
struct HostState {
    data: Vec<f32>,
    /// `[rows_cap, d_model]`; rows past the op's `t` are zero, so lazily
    /// projected padding rows read as exact `0.0` — identical to the
    /// eagerly zero-padded logits region.
    hidden: Vec<f32>,
}

impl HostState {
    fn zeroed(total: usize) -> HostState {
        HostState { data: vec![0f32; total], hidden: Vec::new() }
    }
}

/// Which kernel pipeline a backend instance executes. Both produce
/// byte-identical outputs; `Naive` is the original scalar code kept as
/// the parity oracle and bench baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    Fast,
    Naive,
}

// ---------------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------------

pub struct ReferenceBackend {
    consts: Consts,
    models: BTreeMap<String, RefModel>,
    counters: RefCell<Counters>,
    scratch: RefCell<Arena>,
    pool: Arc<Pool>,
    mode: KernelMode,
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ReferenceBackend {
    /// Fast kernels on the process-wide pool (`SPECPV_THREADS` sizes it).
    pub fn new() -> ReferenceBackend {
        Self::with_pool(KernelMode::Fast, Arc::clone(pool::global()))
    }

    /// The original scalar pipeline (parity oracle / bench baseline).
    pub fn naive() -> ReferenceBackend {
        Self::with_pool(KernelMode::Naive, Arc::clone(pool::global()))
    }

    /// Fast kernels on a private pool of exactly `threads` participants
    /// (the thread-count determinism test uses 1 vs N).
    pub fn with_threads(threads: usize) -> ReferenceBackend {
        Self::with_pool(KernelMode::Fast, Arc::new(Pool::new(threads)))
    }

    fn with_pool(mode: KernelMode, pool: Arc<Pool>) -> ReferenceBackend {
        let vocab = crate::tokenizer::VOCAB;
        let mk = |l, h, nh, d, ff| RefCfg {
            n_layer: l,
            d_model: h,
            n_head: nh,
            d_head: d,
            d_ff: ff,
            vocab,
            rope_theta: 10000.0,
            train_ctx: 128,
        };
        let mut models = BTreeMap::new();
        models.insert("s".to_string(), init_model("s", mk(4, 32, 2, 16, 64), true));
        models.insert("m".to_string(), init_model("m", mk(6, 48, 3, 16, 96), true));
        models.insert("l".to_string(), init_model("l", mk(8, 64, 4, 16, 128), true));
        models.insert("tiny".to_string(), init_model("tiny", mk(2, 16, 2, 8, 32), false));
        let consts = Consts {
            chunk: CHUNK,
            tree_t: TREE_T,
            refresh_t: REFRESH_T,
            big_refresh_t: BIG_REFRESH_T,
            qrows: QROWS,
            draft_w: DRAFT_W,
            draft_region: DRAFT_REGION,
            block: BLOCK,
            prev_max_: PREV_MAX,
            prev_window_: PREV_WINDOW,
            vocab,
            full_buckets: FULL_BUCKETS.to_vec(),
            partial_buckets: PARTIAL_BUCKETS.to_vec(),
            tiny_bucket: TINY_BUCKET,
        };
        ReferenceBackend {
            consts,
            models,
            counters: RefCell::new(Counters::default()),
            scratch: RefCell::new(Arena::new()),
            pool,
            mode,
        }
    }

    /// Which kernel pipeline this instance runs.
    pub fn kernel_mode(&self) -> KernelMode {
        self.mode
    }

    fn model_of(&self, size: &str) -> Result<&RefModel> {
        self.models
            .get(size)
            .ok_or_else(|| anyhow!("reference backend has no model size '{size}'"))
    }

    fn count(&self, label: &str, t0: Instant) {
        let dt = t0.elapsed().as_secs_f64();
        let mut c = self.counters.borrow_mut();
        c.executions += 1;
        c.exec_secs += dt;
        let e = c.per_exec.entry(label.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dt;
    }

    /// Project `lm_head` for `n` hidden rows starting at `row0` (the
    /// lazy-logits materialization; same per-element reduction order as
    /// the eager oracle, so the bytes match).
    fn project_rows(&self, m: &RefModel, hidden: &[f32], row0: usize, n: usize) -> Vec<f32> {
        let h = m.cfg.d_model;
        let mut out = vec![0f32; n * m.cfg.vocab];
        matmul_t(&self.pool, &mut out, &hidden[row0 * h..(row0 + n) * h], &m.target.head, n);
        out
    }

    /// Shared body of prefill / verify_full / verify_partial.
    fn verify_like(&self, op: &VerifyOp, mut state: StateBuf, partial: bool) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let lay = if partial {
            partial_layout(cfg, op.bucket)
        } else {
            full_layout(cfg, op.bucket)
        };
        let rows = if partial { TREE_T } else { CHUNK };
        if op.t > rows {
            bail!("verify t={} exceeds the {}-row state region", op.t, rows);
        }
        if op.tokens.len() != op.t || op.pos.len() != op.t || op.mask.len() != op.t * op.t {
            bail!("verify op geometry mismatch (t={})", op.t);
        }
        let hs = state.downcast_mut::<HostState>()?;
        if hs.data.len() != lay.total {
            bail!("state length {} != layout total {}", hs.data.len(), lay.total);
        }
        let dims = KvDims { l: cfg.n_layer, h: cfg.n_head, b: op.bucket, d: cfg.d_head };
        compact_window(
            &mut hs.data[..lay.kv], dims, op.kv_len, op.prev_idx, op.n_prev, PREV_WINDOW,
        );
        let eff = op.kv_len + op.n_prev;
        let (v, h, h3) = (cfg.vocab, cfg.d_model, 3 * cfg.d_model);
        match self.mode {
            KernelMode::Fast => {
                let mut arena = self.scratch.borrow_mut();
                let out = model::target_fwd(
                    model,
                    &self.pool,
                    &mut arena,
                    &mut hs.data[..lay.kv],
                    op.bucket,
                    op.tokens,
                    op.pos,
                    op.mask,
                    eff,
                    eff,
                    !partial,
                );
                pack_feats(&mut hs.data[lay.off_feats()..lay.off_feats() + lay.feats], &out.feats, op.t, h3);
                if !partial {
                    let qr = &mut hs.data[lay.off_queries()..lay.off_queries() + lay.queries];
                    pack_queries(qr, &out.queries, cfg, op.t);
                }
                hs.hidden.clear();
                hs.hidden.resize(rows * h, 0.0);
                hs.hidden[..op.t * h].copy_from_slice(&out.hidden);
                out.recycle(&mut arena);
            }
            KernelMode::Naive => {
                let out = model::target_fwd_naive(
                    model,
                    &mut hs.data[..lay.kv],
                    op.bucket,
                    op.tokens,
                    op.pos,
                    op.mask,
                    eff,
                    eff,
                    !partial,
                );
                let lg = &mut hs.data[lay.off_logits()..lay.off_logits() + lay.logits];
                lg.fill(0.0);
                lg[..op.t * v].copy_from_slice(&out.logits);
                pack_feats(&mut hs.data[lay.off_feats()..lay.off_feats() + lay.feats], &out.feats, op.t, h3);
                if !partial {
                    let qr = &mut hs.data[lay.off_queries()..lay.off_queries() + lay.queries];
                    pack_queries(qr, &out.queries, cfg, op.t);
                }
                hs.hidden.clear();
            }
        }
        let fam = if partial { "pverify" } else { "verify" };
        self.count(&format!("{fam}_{}_b{}_t{}", op.size, op.bucket, op.t), t0);
        Ok(state)
    }

    /// Fused body of `prefill_batch` / `verify_full_batch` /
    /// `verify_partial_batch`: one stacked forward over every session's
    /// rows (DESIGN.md §12). Naive mode and width-1 groups fall back to
    /// the sequential single-op path, which keeps the oracle pipeline
    /// oracle-shaped and makes B=1 trivially byte-identical.
    fn verify_like_batch(
        &self,
        ops: &[VerifyOp],
        states: &mut [&mut StateBuf],
        partial: bool,
    ) -> Result<()> {
        super::check_batch(ops.len(), states.len())?;
        if ops.is_empty() {
            return Ok(());
        }
        if self.mode == KernelMode::Naive || ops.len() == 1 {
            for (op, st) in ops.iter().zip(states.iter_mut()) {
                let owned = std::mem::replace(&mut **st, StateBuf::nil());
                **st = self.verify_like(op, owned, partial)?;
            }
            return Ok(());
        }
        let t0 = Instant::now();
        let size = ops[0].size;
        let model = self.model_of(size)?;
        let cfg = &model.cfg;
        let rows = if partial { TREE_T } else { CHUNK };
        // validate every op + state before mutating anything, so a batch
        // error never leaves a half-executed group behind
        let mut lays = Vec::with_capacity(ops.len());
        for (op, st) in ops.iter().zip(states.iter()) {
            if op.size != size {
                bail!("batched verify ops must share one model size ({} vs {size})", op.size);
            }
            if op.t > rows {
                bail!("verify t={} exceeds the {rows}-row state region", op.t);
            }
            if op.tokens.len() != op.t || op.pos.len() != op.t || op.mask.len() != op.t * op.t {
                bail!("verify op geometry mismatch (t={})", op.t);
            }
            let lay = if partial {
                partial_layout(cfg, op.bucket)
            } else {
                full_layout(cfg, op.bucket)
            };
            let hs = st.downcast_ref::<HostState>()?;
            if hs.data.len() != lay.total {
                bail!("state length {} != layout total {}", hs.data.len(), lay.total);
            }
            lays.push(lay);
        }
        let b = ops.len();
        let (h, h3) = (cfg.d_model, 3 * cfg.d_model);
        let mut items: Vec<model::BatchItem> = Vec::with_capacity(b);
        let mut rests: Vec<&mut [f32]> = Vec::with_capacity(b);
        let mut hiddens: Vec<&mut Vec<f32>> = Vec::with_capacity(b);
        for ((st, op), lay) in states.iter_mut().zip(ops).zip(&lays) {
            let hs = st.downcast_mut::<HostState>().expect("state validated above");
            let HostState { data, hidden } = hs;
            let (kvr, rest) = data.split_at_mut(lay.kv);
            let dims = KvDims { l: cfg.n_layer, h: cfg.n_head, b: op.bucket, d: cfg.d_head };
            compact_window(kvr, dims, op.kv_len, op.prev_idx, op.n_prev, PREV_WINDOW);
            let eff = op.kv_len + op.n_prev;
            items.push(model::BatchItem {
                kv: kvr,
                bucket: op.bucket,
                tokens: op.tokens,
                pos: op.pos,
                mask: op.mask,
                kv_len: eff,
                write_pos: eff,
                want_queries: !partial,
            });
            rests.push(rest);
            hiddens.push(hidden);
        }
        {
            let mut arena = self.scratch.borrow_mut();
            let outs = model::target_fwd_batch(model, &self.pool, &mut arena, &mut items);
            for (i, out) in outs.into_iter().enumerate() {
                let (op, lay) = (&ops[i], &lays[i]);
                let fo = lay.off_feats() - lay.kv;
                pack_feats(&mut rests[i][fo..fo + lay.feats], &out.feats, op.t, h3);
                if !partial {
                    let qo = lay.off_queries() - lay.kv;
                    pack_queries(&mut rests[i][qo..qo + lay.queries], &out.queries, cfg, op.t);
                }
                hiddens[i].clear();
                hiddens[i].resize(rows * h, 0.0);
                hiddens[i][..op.t * h].copy_from_slice(&out.hidden);
                out.recycle(&mut arena);
            }
        }
        let fam = if partial { "pverify" } else { "verify" };
        self.count(&format!("{fam}_{size}_b{}_t{}_x{b}", ops[0].bucket, ops[0].t), t0);
        Ok(())
    }

    /// Fused body of `draft_expand_batch`.
    fn draft_expand_batch_impl(
        &self,
        ops: &[DraftExpandOp],
        states: &mut [&mut StateBuf],
    ) -> Result<()> {
        super::check_batch(ops.len(), states.len())?;
        if ops.is_empty() {
            return Ok(());
        }
        if self.mode == KernelMode::Naive || ops.len() == 1 {
            for (op, st) in ops.iter().zip(states.iter_mut()) {
                let owned = std::mem::replace(&mut **st, StateBuf::nil());
                **st = self.draft_expand(op, owned)?;
            }
            return Ok(());
        }
        let t0 = Instant::now();
        let size = ops[0].size;
        let model = self.model_of(size)?;
        let cfg = &model.cfg;
        let mut lays = Vec::with_capacity(ops.len());
        for (op, st) in ops.iter().zip(states.iter()) {
            if op.size != size {
                bail!("batched draft ops must share one model size ({} vs {size})", op.size);
            }
            if op.tokens.len() != DRAFT_W || op.mask.len() != DRAFT_W * DRAFT_REGION {
                bail!("draft expand wants W={DRAFT_W} tokens and a [W, region] mask");
            }
            let lay = draft_layout(cfg, op.bucket);
            let hs = st.downcast_ref::<HostState>()?;
            if hs.data.len() != lay.total {
                bail!("state length {} != layout total {}", hs.data.len(), lay.total);
            }
            lays.push(lay);
        }
        let b = ops.len();
        let h = cfg.d_model;
        let mut items: Vec<model::DraftItem> = Vec::with_capacity(b);
        let mut rests: Vec<&mut [f32]> = Vec::with_capacity(b);
        for ((st, op), lay) in states.iter_mut().zip(ops).zip(&lays) {
            let hs = st.downcast_mut::<HostState>().expect("state validated above");
            let (kvr, rest) = hs.data.split_at_mut(lay.kv);
            items.push(model::DraftItem {
                kv: kvr,
                bucket: op.bucket,
                tokens: op.tokens,
                feats: op.feats,
                pos: op.pos,
                mask: op.mask,
                kv_len: op.kv_len,
                write_pos: op.write_pos,
            });
            rests.push(rest);
        }
        {
            let mut arena = self.scratch.borrow_mut();
            let outs = model::draft_fwd_batch(model, &self.pool, &mut arena, &mut items);
            for (i, (lg, hid)) in outs.into_iter().enumerate() {
                let lay = &lays[i];
                rests[i][..lay.logits].copy_from_slice(&lg);
                let ho = lay.off_feats() - lay.kv;
                rests[i][ho..ho + lay.feats].fill(0.0);
                rests[i][ho..ho + DRAFT_W * h].copy_from_slice(&hid);
                arena.give(lg);
                arena.give(hid);
            }
        }
        self.count(&format!("draft_step_{size}_b{}_x{b}", ops[0].bucket), t0);
        Ok(())
    }

    /// Fused body of `tiny_forward_batch`: stacked tiny-LM forward plus
    /// one fused `lm_head` projection over every session's kept row.
    fn tiny_forward_batch_impl(
        &self,
        ops: &[TinyForwardOp],
        states: &mut [&mut StateBuf],
    ) -> Result<()> {
        super::check_batch(ops.len(), states.len())?;
        if ops.is_empty() {
            return Ok(());
        }
        if self.mode == KernelMode::Naive || ops.len() == 1 {
            for (op, st) in ops.iter().zip(states.iter_mut()) {
                let owned = std::mem::replace(&mut **st, StateBuf::nil());
                **st = self.tiny_forward(op, owned)?;
            }
            return Ok(());
        }
        let t0 = Instant::now();
        let model = self.model_of("tiny")?;
        let cfg = &model.cfg;
        let lay = tiny_layout(cfg, TINY_BUCKET);
        for (op, st) in ops.iter().zip(states.iter()) {
            if op.tokens.len() != op.t || op.mask.len() != op.t * op.t {
                bail!("tiny op geometry mismatch (t={})", op.t);
            }
            let hs = st.downcast_ref::<HostState>()?;
            if hs.data.len() != lay.total {
                bail!("state length {} != layout total {}", hs.data.len(), lay.total);
            }
        }
        let b = ops.len();
        let (h, v) = (cfg.d_model, cfg.vocab);
        let mut items: Vec<model::BatchItem> = Vec::with_capacity(b);
        let mut rests: Vec<&mut [f32]> = Vec::with_capacity(b);
        for (st, op) in states.iter_mut().zip(ops) {
            let hs = st.downcast_mut::<HostState>().expect("state validated above");
            let (kvr, rest) = hs.data.split_at_mut(lay.kv);
            items.push(model::BatchItem {
                kv: kvr,
                bucket: TINY_BUCKET,
                tokens: op.tokens,
                pos: op.pos,
                mask: op.mask,
                kv_len: op.kv_len,
                write_pos: op.write_pos,
                want_queries: false,
            });
            rests.push(rest);
        }
        {
            let mut arena = self.scratch.borrow_mut();
            let outs = model::target_fwd_batch(model, &self.pool, &mut arena, &mut items);
            // fused lm_head over the kept rows: one [B, h] × head matmul
            // replaces B single-row projections (identical per-row dots)
            let mut rows_buf = arena.take(b * h);
            for (i, out) in outs.iter().enumerate() {
                let row = ops[i].last_idx.min(ops[i].t - 1);
                rows_buf[i * h..(i + 1) * h].copy_from_slice(&out.hidden[row * h..(row + 1) * h]);
            }
            let mut lg = arena.take(b * v);
            matmul_t(&self.pool, &mut lg, &rows_buf, &model.target.head, b);
            for (i, rest) in rests.iter_mut().enumerate() {
                rest[..v].copy_from_slice(&lg[i * v..(i + 1) * v]);
            }
            arena.give(rows_buf);
            arena.give(lg);
            for out in outs {
                out.recycle(&mut arena);
            }
        }
        self.count(&format!("verify_tiny_b{TINY_BUCKET}_t{}_x{b}", ops[0].t), t0);
        Ok(())
    }
}

/// Zero-pad the state's feats region and write the packed `[t, 3h]` rows.
fn pack_feats(region: &mut [f32], feats: &[f32], t: usize, h3: usize) {
    region.fill(0.0);
    if !feats.is_empty() {
        region[..t * h3].copy_from_slice(feats);
    }
}

/// Zero-pad the state's queries region and keep the first `qrows` of each
/// layer/head (`[L, H, QROWS, D]` packing).
fn pack_queries(region: &mut [f32], queries: &[Vec<f32>], cfg: &RefCfg, t: usize) {
    let d = cfg.d_head;
    region.fill(0.0);
    let keep = t.min(QROWS);
    for (l, q) in queries.iter().enumerate() {
        for hh in 0..cfg.n_head {
            for i in 0..keep {
                let dst = ((l * cfg.n_head + hh) * QROWS + i) * d;
                let src = (hh * t + i) * d;
                region[dst..dst + d].copy_from_slice(&q[src..src + d]);
            }
        }
    }
}

impl super::Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn consts(&self) -> &Consts {
        &self.consts
    }

    fn model(&self, size: &str) -> Result<ModelInfo> {
        let m = self.model_of(size)?;
        Ok(ModelInfo {
            n_layer: m.cfg.n_layer,
            d_model: m.cfg.d_model,
            n_head: m.cfg.n_head,
            d_head: m.cfg.d_head,
            d_ff: m.cfg.d_ff,
            vocab: m.cfg.vocab,
            weights_file: format!("builtin://{size}"),
            yarn_factor: YARN_FACTOR,
        })
    }

    fn sizes(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    fn full_buckets(&self, size: &str) -> Vec<usize> {
        if self.models.contains_key(size) {
            FULL_BUCKETS.to_vec()
        } else {
            Vec::new()
        }
    }

    fn partial_buckets(&self, size: &str) -> Vec<usize> {
        if self.models.contains_key(size) {
            PARTIAL_BUCKETS.to_vec()
        } else {
            Vec::new()
        }
    }

    fn refresh_widths(&self, size: &str, _bucket: usize) -> Vec<usize> {
        if self.models.contains_key(size) {
            vec![REFRESH_T, BIG_REFRESH_T]
        } else {
            Vec::new()
        }
    }

    fn state_layout(&self, kind: StateKind, size: &str, bucket: usize) -> Result<StateLayout> {
        let cfg = &self.model_of(size)?.cfg;
        Ok(match kind {
            StateKind::Full => full_layout(cfg, bucket),
            StateKind::Partial => partial_layout(cfg, bucket),
            StateKind::Draft => draft_layout(cfg, bucket),
            StateKind::Tiny => tiny_layout(cfg, bucket),
        })
    }

    fn alloc_state(&self, kind: StateKind, size: &str, bucket: usize) -> Result<StateBuf> {
        let lay = self.state_layout(kind, size, bucket)?;
        Ok(StateBuf::new(HostState::zeroed(lay.total)))
    }

    fn state_image_len(
        &self,
        kind: StateKind,
        size: &str,
        bucket: usize,
        state: &StateBuf,
    ) -> Result<(usize, usize)> {
        let lay = self.state_layout(kind, size, bucket)?;
        let hs = state.downcast_ref::<HostState>()?;
        if hs.data.len() != lay.total {
            bail!(
                "export: state length {} != {:?} {size} b{bucket} layout total {}",
                hs.data.len(),
                kind,
                lay.total
            );
        }
        // the lazy hidden rows travel as the image's extra section, so a
        // restored state materializes the exact same logits bytes on read
        Ok((hs.data.len(), hs.hidden.len()))
    }

    fn export_pages(
        &self,
        kind: StateKind,
        size: &str,
        bucket: usize,
        state: &StateBuf,
        pages: std::ops::Range<usize>,
        page_elems: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let (data_len, extra_len) = self.state_image_len(kind, size, bucket, state)?;
        let hs = state.downcast_ref::<HostState>()?;
        let total = data_len + extra_len;
        let n = super::page_count(total, page_elems);
        if pages.end > n {
            bail!("export_pages: range {pages:?} exceeds {n} pages of {total} elems");
        }
        // host state: pages slice straight out of data/hidden, so a
        // partial export genuinely moves only the requested bytes
        let mut out = Vec::with_capacity(pages.len());
        let mut moved = 0usize;
        for p in pages {
            let mut page = Vec::new();
            let start = p * page_elems;
            super::copy_image_range(
                &hs.data,
                &hs.hidden,
                start,
                (start + page_elems).min(total),
                &mut page,
            );
            moved += page.len();
            out.push(page);
        }
        self.counters.borrow_mut().download_bytes += (moved * 4) as u64;
        Ok(out)
    }

    fn import_pages(
        &self,
        kind: StateKind,
        size: &str,
        bucket: usize,
        data_len: usize,
        extra_len: usize,
        page_elems: usize,
        read_page: &mut dyn FnMut(usize, &mut Vec<f32>) -> Result<()>,
    ) -> Result<StateBuf> {
        let lay = self.state_layout(kind, size, bucket)?;
        if data_len != lay.total {
            bail!(
                "import: image data length {data_len} != {kind:?} {size} b{bucket} \
                 layout total {}",
                lay.total
            );
        }
        let total = data_len + extra_len;
        let mut data = Vec::with_capacity(data_len);
        let mut hidden = Vec::with_capacity(extra_len);
        let mut scratch = Vec::new();
        for p in 0..super::page_count(total, page_elems) {
            read_page(p, &mut scratch)?;
            let want = page_elems.min(total - p * page_elems);
            if scratch.len() != want {
                bail!("import: page {p} holds {} f32, want {want}", scratch.len());
            }
            for (j, &x) in scratch.iter().enumerate() {
                if p * page_elems + j < data_len {
                    data.push(x);
                } else {
                    hidden.push(x);
                }
            }
        }
        self.counters.borrow_mut().upload_bytes += (total * 4) as u64;
        Ok(StateBuf::new(HostState { data, hidden }))
    }

    fn prefill(&self, op: &PrefillOp, state: StateBuf) -> Result<StateBuf> {
        let zero_prev = [0i32; PREV_MAX];
        self.verify_like(
            &VerifyOp {
                size: op.size,
                bucket: op.bucket,
                t: CHUNK,
                tokens: op.tokens,
                pos: op.pos,
                mask: op.mask,
                kv_len: op.kv_len,
                prev_idx: &zero_prev,
                n_prev: 0,
            },
            state,
            false,
        )
    }

    fn verify_full(&self, op: &VerifyOp, state: StateBuf) -> Result<StateBuf> {
        self.verify_like(op, state, false)
    }

    fn verify_partial(&self, op: &VerifyOp, state: StateBuf) -> Result<StateBuf> {
        self.verify_like(op, state, true)
    }

    fn commit(&self, op: &CommitOp, mut state: StateBuf) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let lay = full_layout(cfg, op.bucket);
        let hs = state.downcast_mut::<HostState>()?;
        let dims = KvDims { l: cfg.n_layer, h: cfg.n_head, b: op.bucket, d: cfg.d_head };
        compact_window(&mut hs.data[..lay.kv], dims, op.kv_len, op.idx, op.n, op.window);
        self.count(&format!("commit_{}_b{}_w{}", op.size, op.bucket, op.window), t0);
        Ok(state)
    }

    fn score(&self, op: &ScoreOp, state: &StateBuf) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let lay = full_layout(cfg, op.bucket);
        let buf = &state.downcast_ref::<HostState>()?.data;
        let dims = KvDims { l: cfg.n_layer, h: cfg.n_head, b: op.bucket, d: cfg.d_head };
        let nb = op.bucket / BLOCK;
        let d = cfg.d_head;
        let mut out = vec![0f32; cfg.n_layer * 3 * nb];
        for layer in 0..cfg.n_layer {
            // s[t][blk]: Quest block scores summed over heads
            let mut s = vec![0f32; QROWS * nb];
            let mut any_valid = vec![false; nb];
            for hh in 0..cfg.n_head {
                for (blk, valid) in any_valid.iter_mut().enumerate() {
                    let b0 = blk * BLOCK;
                    let mut kmax = vec![f32::NEG_INFINITY; d];
                    let mut kmin = vec![f32::INFINITY; d];
                    let mut any = false;
                    for r in b0..(b0 + BLOCK).min(op.kv_len.min(op.bucket)) {
                        any = true;
                        let kr = &buf[dims.row(layer, 0, hh, r)..dims.row(layer, 0, hh, r) + d];
                        for dd in 0..d {
                            kmax[dd] = kmax[dd].max(kr[dd]);
                            kmin[dd] = kmin[dd].min(kr[dd]);
                        }
                    }
                    if !any {
                        kmax.fill(0.0);
                        kmin.fill(0.0);
                    } else {
                        *valid = true;
                    }
                    let qbase = lay.off_queries() + (layer * cfg.n_head + hh) * QROWS * d;
                    for t in 0..QROWS {
                        let qr = &buf[qbase + t * d..qbase + (t + 1) * d];
                        s[t * nb + blk] += dot(qr, &kmax).max(dot(qr, &kmin));
                    }
                }
            }
            let n = op.n_queries.clamp(1, QROWS);
            for blk in 0..nb {
                let (mean, max, last) = if any_valid[blk] {
                    let mut sum = 0f32;
                    let mut mx = f32::NEG_INFINITY;
                    for t in 0..n {
                        sum += s[t * nb + blk];
                        mx = mx.max(s[t * nb + blk]);
                    }
                    (sum / n as f32, mx, s[(n - 1) * nb + blk])
                } else {
                    (NEG_INF, NEG_INF, NEG_INF)
                };
                out[layer * 3 * nb + blk] = mean;
                out[layer * 3 * nb + nb + blk] = max;
                out[layer * 3 * nb + 2 * nb + blk] = last;
            }
        }
        self.counters.borrow_mut().download_bytes += (out.len() * 4) as u64;
        self.count(&format!("score_{}_b{}", op.size, op.bucket), t0);
        Ok(out)
    }

    fn refresh_gather(&self, op: &GatherOp, state: &StateBuf) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let play = partial_layout(cfg, op.p_bucket);
        let nsel = op.p_bucket / BLOCK;
        if op.block_idx.len() != cfg.n_layer * nsel {
            bail!(
                "gather wants {} block ids, got {}",
                cfg.n_layer * nsel,
                op.block_idx.len()
            );
        }
        let buf = &state.downcast_ref::<HostState>()?.data;
        let src = KvDims { l: cfg.n_layer, h: cfg.n_head, b: op.bucket, d: cfg.d_head };
        let dst = KvDims { l: cfg.n_layer, h: cfg.n_head, b: op.p_bucket, d: cfg.d_head };
        let nb = op.bucket / BLOCK;
        let d = cfg.d_head;
        let mut out = HostState::zeroed(play.total);
        for layer in 0..cfg.n_layer {
            for (sel, &blk) in op.block_idx[layer * nsel..(layer + 1) * nsel].iter().enumerate() {
                let blk = (blk.max(0) as usize).min(nb - 1);
                for plane in 0..2 {
                    for hh in 0..cfg.n_head {
                        // whole [BLOCK, D] runs are contiguous per head
                        let s = src.row(layer, plane, hh, blk * BLOCK);
                        let t = dst.row(layer, plane, hh, sel * BLOCK);
                        out.data[t..t + BLOCK * d].copy_from_slice(&buf[s..s + BLOCK * d]);
                    }
                }
            }
        }
        self.count(&format!("gather_{}_b{}_p{}", op.size, op.bucket, op.p_bucket), t0);
        Ok(StateBuf::new(out))
    }

    fn draft_prefill(
        &self,
        op: &DraftPrefillOp,
        target_state: &StateBuf,
        mut draft_state: StateBuf,
    ) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let flay = full_layout(cfg, op.bucket);
        let dlay = draft_layout(cfg, op.bucket);
        if op.tokens.len() != CHUNK {
            bail!("draft prefill wants {CHUNK} tokens");
        }
        let tbuf = &target_state.downcast_ref::<HostState>()?.data;
        let feats = &tbuf[flay.off_feats()..flay.off_feats() + CHUNK * 3 * cfg.d_model];
        let hs = draft_state.downcast_mut::<HostState>()?;
        // draft prefill does not emit logits (aot parity): the logits
        // region is zeroed and only the chunk's hidden rows are kept, so
        // the fast path skips the chunk-wide lm_head projection entirely
        let (logits, hidden) = match self.mode {
            KernelMode::Fast => {
                let mut arena = self.scratch.borrow_mut();
                model::draft_fwd(
                    model, &self.pool, &mut arena, &mut hs.data[..dlay.kv], op.bucket,
                    op.tokens, feats, op.pos, op.mask, op.kv_len, op.write_pos, false,
                )
            }
            KernelMode::Naive => model::draft_fwd_naive(
                model, &mut hs.data[..dlay.kv], op.bucket, op.tokens, feats, op.pos, op.mask,
                op.kv_len, op.write_pos,
            ),
        };
        hs.data[dlay.off_logits()..dlay.off_logits() + dlay.logits].fill(0.0);
        let hd = &mut hs.data[dlay.off_feats()..dlay.off_feats() + dlay.feats];
        hd.fill(0.0);
        hd[..CHUNK * cfg.d_model].copy_from_slice(&hidden);
        let mut arena = self.scratch.borrow_mut();
        arena.give(logits);
        arena.give(hidden);
        self.count(&format!("draft_prefill_{}_b{}", op.size, op.bucket), t0);
        Ok(draft_state)
    }

    fn draft_expand(&self, op: &DraftExpandOp, mut draft_state: StateBuf) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let dlay = draft_layout(cfg, op.bucket);
        if op.tokens.len() != DRAFT_W || op.mask.len() != DRAFT_W * DRAFT_REGION {
            bail!("draft expand wants W={DRAFT_W} tokens and a [W, region] mask");
        }
        let hs = draft_state.downcast_mut::<HostState>()?;
        let (logits, hidden) = match self.mode {
            KernelMode::Fast => {
                let mut arena = self.scratch.borrow_mut();
                model::draft_fwd(
                    model, &self.pool, &mut arena, &mut hs.data[..dlay.kv], op.bucket,
                    op.tokens, op.feats, op.pos, op.mask, op.kv_len, op.write_pos, true,
                )
            }
            KernelMode::Naive => model::draft_fwd_naive(
                model, &mut hs.data[..dlay.kv], op.bucket, op.tokens, op.feats, op.pos,
                op.mask, op.kv_len, op.write_pos,
            ),
        };
        hs.data[dlay.off_logits()..dlay.off_logits() + dlay.logits].copy_from_slice(&logits);
        let hd = &mut hs.data[dlay.off_feats()..dlay.off_feats() + dlay.feats];
        hd.fill(0.0);
        hd[..DRAFT_W * cfg.d_model].copy_from_slice(&hidden);
        let mut arena = self.scratch.borrow_mut();
        arena.give(logits);
        arena.give(hidden);
        self.count(&format!("draft_step_{}_b{}", op.size, op.bucket), t0);
        Ok(draft_state)
    }

    fn medusa(&self, size: &str, feat: &[f32]) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let model = self.model_of(size)?;
        let cfg = &model.cfg;
        let mw = model
            .medusa
            .as_ref()
            .ok_or_else(|| anyhow!("model '{size}' has no medusa heads"))?;
        if feat.len() != cfg.d_model {
            bail!("medusa feat wants d_model={}", cfg.d_model);
        }
        let h = cfg.d_model;
        let mut out = Vec::with_capacity(3 * cfg.vocab);
        for (w1, w2) in &mw.heads {
            let mut hid = vec![0f32; h];
            match self.mode {
                KernelMode::Fast => matmul_t(&self.pool, &mut hid, feat, w1, 1),
                KernelMode::Naive => matmul_naive(&mut hid, feat, w1, 1),
            }
            for (x, &f) in hid.iter_mut().zip(feat) {
                *x = kernels::silu(*x) + f;
            }
            let mut lg = vec![0f32; cfg.vocab];
            match self.mode {
                KernelMode::Fast => matmul_t(&self.pool, &mut lg, &hid, w2, 1),
                KernelMode::Naive => matmul_naive(&mut lg, &hid, w2, 1),
            }
            out.extend(lg);
        }
        self.count(&format!("medusa_{size}"), t0);
        Ok(out)
    }

    fn tiny_forward(&self, op: &TinyForwardOp, mut state: StateBuf) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of("tiny")?;
        let cfg = &model.cfg;
        let lay = tiny_layout(cfg, TINY_BUCKET);
        if op.tokens.len() != op.t || op.mask.len() != op.t * op.t {
            bail!("tiny op geometry mismatch (t={})", op.t);
        }
        let hs = state.downcast_mut::<HostState>()?;
        let row = op.last_idx.min(op.t - 1);
        let v = cfg.vocab;
        match self.mode {
            KernelMode::Fast => {
                // lazy even at verify time: only the kept row is projected
                let mut arena = self.scratch.borrow_mut();
                let out = model::target_fwd(
                    model, &self.pool, &mut arena, &mut hs.data[..lay.kv], TINY_BUCKET,
                    op.tokens, op.pos, op.mask, op.kv_len, op.write_pos, false,
                );
                let h = cfg.d_model;
                matmul_t(
                    &self.pool,
                    &mut hs.data[lay.kv..lay.kv + v],
                    &out.hidden[row * h..(row + 1) * h],
                    &model.target.head,
                    1,
                );
                out.recycle(&mut arena);
            }
            KernelMode::Naive => {
                let out = model::target_fwd_naive(
                    model, &mut hs.data[..lay.kv], TINY_BUCKET, op.tokens, op.pos, op.mask,
                    op.kv_len, op.write_pos, false,
                );
                hs.data[lay.kv..lay.kv + v].copy_from_slice(&out.logits[row * v..(row + 1) * v]);
            }
        }
        self.count(&format!("verify_tiny_b{TINY_BUCKET}_t{}", op.t), t0);
        Ok(state)
    }

    // --- batched kernel ops (stacked-row fusion, DESIGN.md §12) ---------

    fn fuses_batches(&self) -> bool {
        // naive mode keeps the oracle pipeline sequential by design
        self.mode == KernelMode::Fast
    }

    fn prefill_batch(&self, ops: &[PrefillOp], states: &mut [&mut StateBuf]) -> Result<()> {
        let zero_prev = [0i32; PREV_MAX];
        let vops: Vec<VerifyOp> = ops
            .iter()
            .map(|op| VerifyOp {
                size: op.size,
                bucket: op.bucket,
                t: CHUNK,
                tokens: op.tokens,
                pos: op.pos,
                mask: op.mask,
                kv_len: op.kv_len,
                prev_idx: &zero_prev,
                n_prev: 0,
            })
            .collect();
        self.verify_like_batch(&vops, states, false)
    }

    fn verify_full_batch(&self, ops: &[VerifyOp], states: &mut [&mut StateBuf]) -> Result<()> {
        self.verify_like_batch(ops, states, false)
    }

    fn verify_partial_batch(&self, ops: &[VerifyOp], states: &mut [&mut StateBuf]) -> Result<()> {
        self.verify_like_batch(ops, states, true)
    }

    fn draft_expand_batch(
        &self,
        ops: &[DraftExpandOp],
        states: &mut [&mut StateBuf],
    ) -> Result<()> {
        self.draft_expand_batch_impl(ops, states)
    }

    fn tiny_forward_batch(
        &self,
        ops: &[TinyForwardOp],
        states: &mut [&mut StateBuf],
    ) -> Result<()> {
        self.tiny_forward_batch_impl(ops, states)
    }

    fn read_logits(&self, op: &ReadOp, state: &StateBuf) -> Result<Vec<f32>> {
        let hs = state.downcast_ref::<HostState>()?;
        let out = match *op {
            ReadOp::FullWindow { size, bucket, start } => {
                let m = self.model_of(size)?;
                let lay = full_layout(&m.cfg, bucket);
                let (v, h3) = (m.cfg.vocab, 3 * m.cfg.d_model);
                let start = start.min(CHUNK - QROWS);
                let mut out = if hs.hidden.is_empty() {
                    hs.data[lay.off_logits() + start * v..lay.off_logits() + (start + QROWS) * v]
                        .to_vec()
                } else {
                    self.project_rows(m, &hs.hidden, start, QROWS)
                };
                out.extend_from_slice(
                    &hs.data[lay.off_feats() + start * h3..lay.off_feats() + (start + QROWS) * h3],
                );
                out
            }
            ReadOp::LastRow { size, bucket, idx } => {
                let m = self.model_of(size)?;
                let lay = full_layout(&m.cfg, bucket);
                let (v, h3) = (m.cfg.vocab, 3 * m.cfg.d_model);
                let idx = idx.min(CHUNK - 1);
                let mut out = if hs.hidden.is_empty() {
                    hs.data[lay.off_logits() + idx * v..lay.off_logits() + (idx + 1) * v].to_vec()
                } else {
                    self.project_rows(m, &hs.hidden, idx, 1)
                };
                out.extend_from_slice(
                    &hs.data[lay.off_feats() + idx * h3..lay.off_feats() + (idx + 1) * h3],
                );
                out
            }
            ReadOp::Partial { size, bucket } => {
                let m = self.model_of(size)?;
                let lay = partial_layout(&m.cfg, bucket);
                if hs.hidden.is_empty() {
                    hs.data[lay.off_logits()..lay.total].to_vec()
                } else {
                    let mut out = self.project_rows(m, &hs.hidden, 0, TREE_T);
                    out.extend_from_slice(&hs.data[lay.off_feats()..lay.total]);
                    out
                }
            }
            ReadOp::Draft { size, bucket } => {
                let cfg = &self.model_of(size)?.cfg;
                let lay = draft_layout(cfg, bucket);
                let mut out = Vec::with_capacity(lay.logits + DRAFT_W * cfg.d_model);
                out.extend_from_slice(&hs.data[lay.off_logits()..lay.off_logits() + lay.logits]);
                out.extend_from_slice(
                    &hs.data[lay.off_feats()..lay.off_feats() + DRAFT_W * cfg.d_model],
                );
                out
            }
            ReadOp::DraftHiddenRow { size, bucket, idx } => {
                let cfg = &self.model_of(size)?.cfg;
                let lay = draft_layout(cfg, bucket);
                let h = cfg.d_model;
                let idx = idx.min(CHUNK - 1);
                hs.data[lay.off_feats() + idx * h..lay.off_feats() + (idx + 1) * h].to_vec()
            }
            ReadOp::Tiny => {
                let cfg = &self.model_of("tiny")?.cfg;
                let lay = tiny_layout(cfg, TINY_BUCKET);
                hs.data[lay.kv..lay.kv + cfg.vocab].to_vec()
            }
        };
        self.counters.borrow_mut().download_bytes += (out.len() * 4) as u64;
        Ok(out)
    }

    fn counters(&self) -> Counters {
        self.counters.borrow().clone()
    }

    fn describe(&self) -> String {
        format!(
            "reference backend (pure rust, deterministic seeded weights, {:?} kernels, \
             {} threads): models {:?}, full buckets {:?}, partial buckets {:?}",
            self.mode,
            self.pool.threads(),
            self.models.keys().collect::<Vec<_>>(),
            FULL_BUCKETS,
            PARTIAL_BUCKETS
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::Backend;
    use super::*;

    fn be() -> ReferenceBackend {
        ReferenceBackend::new()
    }

    #[test]
    fn catalog_is_consistent() {
        let b = be();
        let info = b.model("s").unwrap();
        assert_eq!(info.vocab, crate::tokenizer::VOCAB);
        assert_eq!(b.full_buckets("s"), FULL_BUCKETS.to_vec());
        assert!(b.model("xl").is_err());
        let lay = b.state_layout(StateKind::Full, "s", 288).unwrap();
        assert_eq!(
            lay.total,
            lay.kv + lay.logits + lay.feats + lay.queries
        );
    }

    #[test]
    fn weights_are_deterministic() {
        let a = init_model("s", be().models["s"].cfg.clone(), true);
        let b = init_model("s", be().models["s"].cfg.clone(), true);
        assert_eq!(a.target.embed, b.target.embed);
        assert_eq!(a.target.layers[2].wq.rm, b.target.layers[2].wq.rm);
        assert_eq!(a.target.layers[2].wq.t, b.target.layers[2].wq.t);
        assert_eq!(a.draft.unwrap().fuse.rm, b.draft.unwrap().fuse.rm);
    }

    fn run_verify(b: &ReferenceBackend) -> Vec<f32> {
        let st = b.alloc_state(StateKind::Full, "s", 128).unwrap();
        let t = TREE_T;
        let tokens: Vec<i32> = (0..t as i32).map(|i| 65 + i).collect();
        let pos: Vec<i32> = (0..t as i32).collect();
        let mask = crate::tree::chain_mask(t, t);
        let zero = [0i32; PREV_MAX];
        let op = VerifyOp {
            size: "s",
            bucket: 128,
            t,
            tokens: &tokens,
            pos: &pos,
            mask: &mask,
            kv_len: 0,
            prev_idx: &zero,
            n_prev: 0,
        };
        let st = b.verify_full(&op, st).unwrap();
        b.read_logits(&ReadOp::FullWindow { size: "s", bucket: 128, start: 0 }, &st)
            .unwrap()
    }

    #[test]
    fn verify_is_deterministic_and_shapes_hold() {
        let b = be();
        let x = run_verify(&b);
        let y = run_verify(&b);
        assert_eq!(x, y, "reference forward must be bit-deterministic");
        let info = b.model("s").unwrap();
        assert_eq!(x.len(), QROWS * (info.vocab + 3 * info.d_model));
        assert!(x.iter().all(|v| v.is_finite()));
        // rows 0..T hold real logits, later rows are zero padding
        assert!(x[..info.vocab].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn fast_kernels_match_naive_oracle_bytewise() {
        let fast = run_verify(&be());
        let naive = run_verify(&ReferenceBackend::naive());
        assert_eq!(fast.len(), naive.len());
        assert!(
            fast.iter().zip(&naive).all(|(a, b)| a.to_bits() == b.to_bits()),
            "fast and naive kernel pipelines diverged"
        );
        let one_thread = run_verify(&ReferenceBackend::with_threads(1));
        let four_threads = run_verify(&ReferenceBackend::with_threads(4));
        assert!(
            one_thread.iter().zip(&four_threads).all(|(a, b)| a.to_bits() == b.to_bits()),
            "thread count changed the bytes"
        );
    }

    #[test]
    fn chain_verify_matches_stepwise_decode() {
        // processing [a, b] in one chain call must equal processing a then
        // b in two T=1 calls — the losslessness property spec engines rely
        // on (same rows visible, same write positions).
        let b = be();
        let zero = [0i32; PREV_MAX];
        // one-shot: chain of 2
        let st = b.alloc_state(StateKind::Full, "s", 128).unwrap();
        let mask2 = crate::tree::chain_mask(2, 2);
        let st = b
            .verify_full(
                &VerifyOp {
                    size: "s",
                    bucket: 128,
                    t: 2,
                    tokens: &[72, 105],
                    pos: &[0, 1],
                    mask: &mask2,
                    kv_len: 0,
                    prev_idx: &zero,
                    n_prev: 0,
                },
                st,
            )
            .unwrap();
        let chain =
            b.read_logits(&ReadOp::LastRow { size: "s", bucket: 128, idx: 1 }, &st).unwrap();
        // stepwise: two T=1 calls
        let st = b.alloc_state(StateKind::Full, "s", 128).unwrap();
        let one = |st, tok: i32, pos: i32, kv_len: usize| {
            b.verify_full(
                &VerifyOp {
                    size: "s",
                    bucket: 128,
                    t: 1,
                    tokens: &[tok],
                    pos: &[pos],
                    mask: &[1.0],
                    kv_len,
                    prev_idx: &zero,
                    n_prev: 0,
                },
                st,
            )
            .unwrap()
        };
        let st = one(st, 72, 0, 0);
        let st = one(st, 105, 1, 1);
        let step =
            b.read_logits(&ReadOp::LastRow { size: "s", bucket: 128, idx: 0 }, &st).unwrap();
        let v = b.model("s").unwrap().vocab;
        for (i, (a, bb)) in chain[..v].iter().zip(&step[..v]).enumerate() {
            assert!((a - bb).abs() < 1e-5, "logit {i}: {a} vs {bb}");
        }
    }

    #[test]
    fn export_import_roundtrip_preserves_reads_bytewise() {
        // export → import must preserve the lazy-hidden rows: reads off
        // the imported state (which re-run the lm_head projection) must
        // match reads off the original byte-for-byte
        let b = be();
        let st = b.alloc_state(StateKind::Full, "s", 128).unwrap();
        let t = TREE_T;
        let tokens: Vec<i32> = (0..t as i32).map(|i| 66 + i).collect();
        let pos: Vec<i32> = (0..t as i32).collect();
        let mask = crate::tree::chain_mask(t, t);
        let zero = [0i32; PREV_MAX];
        let op = VerifyOp {
            size: "s",
            bucket: 128,
            t,
            tokens: &tokens,
            pos: &pos,
            mask: &mask,
            kv_len: 0,
            prev_idx: &zero,
            n_prev: 0,
        };
        let st = b.verify_full(&op, st).unwrap();
        let read = |s: &StateBuf| {
            b.read_logits(&ReadOp::FullWindow { size: "s", bucket: 128, start: 0 }, s)
                .unwrap()
        };
        let before = read(&st);
        let snap = b.export_state(StateKind::Full, "s", 128, &st).unwrap();
        assert!(!snap.extra.is_empty(), "fast path must export hidden rows");
        assert_eq!(snap.bytes(), (snap.data.len() + snap.extra.len()) * 4);
        let st2 = b.import_state(&snap).unwrap();
        let after = read(&st2);
        assert!(
            before.iter().zip(&after).all(|(a, c)| a.to_bits() == c.to_bits()),
            "imported state reads diverged"
        );
        // geometry mismatches are rejected
        let mut bad = snap.clone();
        bad.data.pop();
        assert!(b.import_state(&bad).is_err());
        // and state_bytes matches the layout
        let lay = b.state_layout(StateKind::Full, "s", 128).unwrap();
        assert_eq!(b.state_bytes(StateKind::Full, "s", 128).unwrap(), lay.total * 4);
    }

    #[test]
    fn reads_before_any_verify_return_zeros() {
        // a freshly allocated state has no hidden rows; reads must fall
        // back to the zeroed data region (the pre-refactor behaviour)
        let b = be();
        let st = b.alloc_state(StateKind::Full, "s", 128).unwrap();
        let out = b
            .read_logits(&ReadOp::FullWindow { size: "s", bucket: 128, start: 0 }, &st)
            .unwrap();
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn batched_verify_matches_sequential_bytewise() {
        // two sessions with different kv_lens: batch ≡ sequential bytes,
        // pinned via the reads that materialize the lazy logits
        let b = be();
        let t = TREE_T;
        let mask = crate::tree::chain_mask(t, t);
        let zero = [0i32; PREV_MAX];
        let mut specs = Vec::new();
        for kl in [0usize, 16] {
            let tokens: Vec<i32> =
                (0..t as i32).map(|i| 65 + (i + kl as i32) % 26).collect();
            let pos: Vec<i32> = (0..t as i32).map(|i| kl as i32 + i).collect();
            specs.push((tokens, pos, kl));
        }
        let run = |batched: bool| -> Vec<Vec<f32>> {
            let mut states: Vec<StateBuf> = (0..specs.len())
                .map(|_| b.alloc_state(StateKind::Full, "s", 128).unwrap())
                .collect();
            // warm the kv prefix of the second state so kv_len=16 is real
            for (si, (tokens, _pos, kl)) in specs.iter().enumerate() {
                if *kl > 0 {
                    let warm_pos: Vec<i32> = (0..*kl as i32).collect();
                    let warm_mask = crate::tree::chain_mask(*kl, *kl);
                    let op = VerifyOp {
                        size: "s",
                        bucket: 128,
                        t: *kl,
                        tokens: &tokens[..*kl],
                        pos: &warm_pos,
                        mask: &warm_mask,
                        kv_len: 0,
                        prev_idx: &zero,
                        n_prev: 0,
                    };
                    let st = states.remove(si);
                    states.insert(si, b.verify_full(&op, st).unwrap());
                }
            }
            let ops: Vec<VerifyOp> = specs
                .iter()
                .map(|(tokens, pos, kl)| VerifyOp {
                    size: "s",
                    bucket: 128,
                    t,
                    tokens,
                    pos,
                    mask: &mask,
                    kv_len: *kl,
                    prev_idx: &zero,
                    n_prev: 0,
                })
                .collect();
            if batched {
                let mut refs: Vec<&mut StateBuf> = states.iter_mut().collect();
                b.verify_full_batch(&ops, &mut refs).unwrap();
            } else {
                for (idx, op) in ops.iter().enumerate() {
                    let st = std::mem::replace(&mut states[idx], StateBuf::nil());
                    states[idx] = b.verify_full(op, st).unwrap();
                }
            }
            states
                .iter()
                .map(|st| {
                    b.read_logits(
                        &ReadOp::FullWindow { size: "s", bucket: 128, start: 0 },
                        st,
                    )
                    .unwrap()
                })
                .collect()
        };
        let seq = run(false);
        let bat = run(true);
        for (a, c) in seq.iter().zip(&bat) {
            assert!(
                a.iter().zip(c.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "batched verify diverged from sequential"
            );
        }
    }

    #[test]
    fn medusa_and_tiny_shapes() {
        let b = be();
        let info = b.model("s").unwrap();
        let heads = b.medusa("s", &vec![0.1; info.d_model]).unwrap();
        assert_eq!(heads.len(), 3 * info.vocab);
        let st = b.alloc_state(StateKind::Tiny, "tiny", TINY_BUCKET).unwrap();
        let st = b
            .tiny_forward(
                &TinyForwardOp {
                    t: 1,
                    tokens: &[65],
                    pos: &[0],
                    mask: &[1.0],
                    kv_len: 0,
                    write_pos: 0,
                    last_idx: 0,
                },
                st,
            )
            .unwrap();
        let lg = b.read_logits(&ReadOp::Tiny, &st).unwrap();
        assert_eq!(lg.len(), b.model("tiny").unwrap().vocab);
        assert!(b.counters().executions >= 2);
    }
}
