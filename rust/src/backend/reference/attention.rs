//! Tree attention over the flat `[L, 2, H, B, D]` KV layout, RoPE with
//! precomputed cos/sin tables, and the fused acceptance compaction.
//!
//! The fast path walks each head's keys/values as one contiguous
//! `[B, D]` slab (consecutive rows of a head are adjacent in the flat
//! layout), reuses per-task score buffers, and parallelizes over
//! `(head, query-row)` pairs — every pair writes a disjoint `[D]` output
//! slice, so scheduling cannot change results. Softmax order is the
//! original's exactly: committed rows ascending, then masked new-region
//! rows ascending; max, exp and the weighted-V accumulation all run in
//! that one fixed order.

use crate::util::pool::{split_range, Pool};

use super::kernels::{dot, SendPtr, PAR_MIN_WORK};

/// KV-cache addressing over a flat `[L, 2, H, B, D]` region.
#[derive(Clone, Copy)]
pub(crate) struct KvDims {
    pub l: usize,
    pub h: usize,
    pub b: usize,
    pub d: usize,
}

impl KvDims {
    #[inline]
    pub fn row(&self, layer: usize, plane: usize, head: usize, row: usize) -> usize {
        (((layer * 2 + plane) * self.h + head) * self.b + row) * self.d
    }
}

/// Acceptance compaction fused into the next verification step
/// (`model.py::compact_window`): move row `kv_len + prev_idx[j]` →
/// `kv_len + j` for `j < n_prev`. `prev_idx` is strictly increasing with
/// `prev_idx[j] ≥ j`, so an ascending in-place copy matches the
/// gather-then-scatter of the JAX graph.
pub(crate) fn compact_window(
    kv: &mut [f32],
    dims: KvDims,
    kv_len: usize,
    prev_idx: &[i32],
    n_prev: usize,
    window: usize,
) {
    // dynamic_slice clamp semantics
    let start = kv_len.min(dims.b.saturating_sub(window));
    for layer in 0..dims.l {
        for plane in 0..2 {
            for head in 0..dims.h {
                for j in 0..n_prev.min(prev_idx.len()) {
                    let src = (prev_idx[j].max(0) as usize).min(window - 1);
                    if src == j {
                        continue;
                    }
                    // src row is strictly behind dst (prev_idx[j] > j)
                    let s = dims.row(layer, plane, head, start + src);
                    let t = dims.row(layer, plane, head, start + j);
                    let (head_seg, tail_seg) = kv.split_at_mut(s);
                    head_seg[t..t + dims.d].copy_from_slice(&tail_seg[..dims.d]);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// RoPE
// ---------------------------------------------------------------------------

/// Per-op cos/sin table `[T, D/2]`. Positions are shared by every layer
/// and head, so one table replaces `L × 2 × H` rounds of `sin_cos` calls
/// per forward (the angles — and therefore the rotated values — are
/// bit-identical to the per-token computation).
pub(crate) struct RopeTab {
    sin: Vec<f32>,
    cos: Vec<f32>,
    half: usize,
}

pub(crate) fn rope_tab(pos: &[i32], inv_freq: &[f32]) -> RopeTab {
    let half = inv_freq.len();
    let mut sin = vec![0f32; pos.len() * half];
    let mut cos = vec![0f32; pos.len() * half];
    for (i, &p) in pos.iter().enumerate() {
        let pf = p as f32;
        for (k, &f) in inv_freq.iter().enumerate() {
            let (s, c) = (pf * f).sin_cos();
            sin[i * half + k] = s;
            cos[i * half + k] = c;
        }
    }
    RopeTab { sin, cos, half }
}

/// Rotate `[T, H·D]` rows in place using a precomputed table.
pub(crate) fn rope_apply_tab(x: &mut [f32], tab: &RopeTab, t: usize, n_head: usize, d: usize) {
    let hd = n_head * d;
    let half = tab.half;
    for i in 0..t {
        let srow = &tab.sin[i * half..(i + 1) * half];
        let crow = &tab.cos[i * half..(i + 1) * half];
        for hh in 0..n_head {
            let base = i * hd + hh * d;
            for k in 0..half {
                let (sin, cos) = (srow[k], crow[k]);
                let x1 = x[base + 2 * k];
                let x2 = x[base + 2 * k + 1];
                x[base + 2 * k] = x1 * cos - x2 * sin;
                x[base + 2 * k + 1] = x1 * sin + x2 * cos;
            }
        }
    }
}

/// The original per-token RoPE (oracle path).
pub(crate) fn rope_apply_naive(
    x: &mut [f32],
    pos: &[i32],
    inv_freq: &[f32],
    t: usize,
    n_head: usize,
    d: usize,
) {
    let hd = n_head * d;
    for i in 0..t {
        let p = pos[i] as f32;
        for hh in 0..n_head {
            let base = i * hd + hh * d;
            for (k, &f) in inv_freq.iter().enumerate() {
                let ang = p * f;
                let (sin, cos) = ang.sin_cos();
                let x1 = x[base + 2 * k];
                let x2 = x[base + 2 * k + 1];
                x[base + 2 * k] = x1 * cos - x2 * sin;
                x[base + 2 * k + 1] = x1 * sin + x2 * cos;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Attention
// ---------------------------------------------------------------------------

/// One `(head, query-row)` softmax-attention in the original reduction
/// order. `keys`/`vals` are the head's contiguous `[B, D]` slabs; `or`
/// is the query's `[D]` output slice (zeroed by the caller).
#[allow(clippy::too_many_arguments)]
fn att_row(
    or: &mut [f32],
    qr: &[f32],
    keys: &[f32],
    vals: &[f32],
    d: usize,
    b: usize,
    kv_len: usize,
    mask_row: &[f32],
    scale: f32,
    probs: &mut Vec<f32>,
    midx: &mut Vec<usize>,
) {
    let kvn = kv_len.min(b);
    probs.clear();
    midx.clear();
    let mut m = f32::NEG_INFINITY;
    // committed history rows, then the masked new region — the same
    // visibility rule as kernels/ref.py::tree_attention_ref
    for j in 0..kvn {
        let s = dot(qr, &keys[j * d..j * d + d]) * scale;
        if s > m {
            m = s;
        }
        probs.push(s);
    }
    for (r, &mv) in mask_row.iter().enumerate() {
        let j = kv_len + r;
        if j >= b || mv <= 0.5 {
            continue;
        }
        let s = dot(qr, &keys[j * d..j * d + d]) * scale;
        if s > m {
            m = s;
        }
        probs.push(s);
        midx.push(j);
    }
    if probs.is_empty() {
        return; // fully masked row (never happens for real rows)
    }
    let mut z = 0f32;
    for p in probs.iter_mut() {
        *p = (*p - m).exp();
        z += *p;
    }
    let zr = 1.0 / z.max(1e-30);
    for j in 0..kvn {
        let w = probs[j] * zr;
        let vr = &vals[j * d..j * d + d];
        for dd in 0..d {
            or[dd] += w * vr[dd];
        }
    }
    for (q2, &j) in midx.iter().enumerate() {
        let w = probs[kvn + q2] * zr;
        let vr = &vals[j * d..j * d + d];
        for dd in 0..d {
            or[dd] += w * vr[dd];
        }
    }
}

/// Tree attention for one layer: `out[T, H·D]` (zeroed by the caller)
/// from queries `q[T, H·D]` against the layer's KV slabs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention(
    pool: &Pool,
    out: &mut [f32],
    q: &[f32],
    kv: &[f32],
    dims: KvDims,
    layer: usize,
    t: usize,
    tk: usize,
    mask: &[f32],
    kv_len: usize,
    scale: f32,
) {
    let d = dims.d;
    let hd = dims.h * d;
    let kvn = kv_len.min(dims.b);
    let items = dims.h * t;
    let per_item = |hh: usize, i: usize, or: &mut [f32], probs: &mut Vec<f32>, midx: &mut Vec<usize>| {
        let qr = &q[i * hd + hh * d..i * hd + hh * d + d];
        let kbase = dims.row(layer, 0, hh, 0);
        let vbase = dims.row(layer, 1, hh, 0);
        let keys = &kv[kbase..kbase + dims.b * d];
        let vals = &kv[vbase..vbase + dims.b * d];
        att_row(
            or,
            qr,
            keys,
            vals,
            d,
            dims.b,
            kv_len,
            &mask[i * tk..(i + 1) * tk],
            scale,
            probs,
            midx,
        );
    };
    let work = items * (kvn + tk) * d;
    if pool.threads() == 1 || work < PAR_MIN_WORK {
        let mut probs = Vec::with_capacity(kvn + tk);
        let mut midx = Vec::with_capacity(tk);
        for hh in 0..dims.h {
            for i in 0..t {
                per_item(hh, i, &mut out[i * hd + hh * d..i * hd + hh * d + d], &mut probs, &mut midx);
            }
        }
        return;
    }
    let chunks = pool.threads().min(items);
    let optr = SendPtr(out.as_mut_ptr());
    pool.run(chunks, &|c| {
        let (a, b) = split_range(items, chunks, c);
        let mut probs = Vec::with_capacity(kvn + tk);
        let mut midx = Vec::with_capacity(tk);
        for it in a..b {
            let hh = it / t;
            let i = it % t;
            // SAFETY: (head, row) output slices are disjoint and each
            // pair belongs to exactly one chunk
            let or =
                unsafe { std::slice::from_raw_parts_mut(optr.0.add(i * hd + hh * d), d) };
            per_item(hh, i, or, &mut probs, &mut midx);
        }
    });
}

/// One session's slice of a batched attention call (DESIGN.md §12):
/// `q` is this session's `[t, H·D]` query rows, `kv` its own KV slab,
/// `out_off` the row offset of its output inside the stacked buffer.
pub(crate) struct AttItem<'a> {
    pub q: &'a [f32],
    pub kv: &'a [f32],
    pub dims: KvDims,
    pub layer: usize,
    pub t: usize,
    pub tk: usize,
    pub mask: &'a [f32],
    pub kv_len: usize,
    pub out_off: usize,
}

/// Tree attention for one layer across **many sessions** in one pool
/// dispatch. Attention never mixes sessions — each `(session, head,
/// query-row)` unit runs [`att_row`] over that session's own KV slab in
/// the exact single-session reduction order — so the fusion only widens
/// the parallel work list: B sessions' units share one wake/latch
/// round-trip instead of B. Byte-identical to per-session
/// [`attention`] calls at any thread count.
pub(crate) fn attention_batch(pool: &Pool, out: &mut [f32], items: &[AttItem], scale: f32) {
    let counts: Vec<usize> = items.iter().map(|it| it.dims.h * it.t).collect();
    let total_units: usize = counts.iter().sum();
    if total_units == 0 {
        return;
    }
    let run_unit = |it: &AttItem,
                    hh: usize,
                    i: usize,
                    or: &mut [f32],
                    probs: &mut Vec<f32>,
                    midx: &mut Vec<usize>| {
        let d = it.dims.d;
        let hd = it.dims.h * d;
        let qr = &it.q[i * hd + hh * d..i * hd + hh * d + d];
        let kbase = it.dims.row(it.layer, 0, hh, 0);
        let vbase = it.dims.row(it.layer, 1, hh, 0);
        att_row(
            or,
            qr,
            &it.kv[kbase..kbase + it.dims.b * d],
            &it.kv[vbase..vbase + it.dims.b * d],
            d,
            it.dims.b,
            it.kv_len,
            &it.mask[i * it.tk..(i + 1) * it.tk],
            scale,
            probs,
            midx,
        );
    };
    let work: usize = items
        .iter()
        .map(|it| it.dims.h * it.t * (it.kv_len.min(it.dims.b) + it.tk) * it.dims.d)
        .sum();
    if pool.threads() == 1 || work < PAR_MIN_WORK {
        let mut probs = Vec::new();
        let mut midx = Vec::new();
        for it in items {
            let d = it.dims.d;
            let hd = it.dims.h * d;
            for hh in 0..it.dims.h {
                for i in 0..it.t {
                    let o = (it.out_off + i) * hd + hh * d;
                    run_unit(it, hh, i, &mut out[o..o + d], &mut probs, &mut midx);
                }
            }
        }
        return;
    }
    let chunks = pool.threads().min(total_units);
    let optr = SendPtr(out.as_mut_ptr());
    pool.run(chunks, &|c| {
        let (a, b) = split_range(total_units, chunks, c);
        let mut probs = Vec::new();
        let mut midx = Vec::new();
        for u in a..b {
            // locate the owning item (B ≤ a dozen; linear scan is fine)
            let mut idx = u;
            let mut bi = 0usize;
            while idx >= counts[bi] {
                idx -= counts[bi];
                bi += 1;
            }
            let it = &items[bi];
            let hh = idx / it.t;
            let i = idx % it.t;
            let d = it.dims.d;
            let hd = it.dims.h * d;
            // SAFETY: every (item, head, row) output slice is disjoint
            // (items have disjoint out_off row bands) and each unit
            // belongs to exactly one chunk
            let or = unsafe {
                std::slice::from_raw_parts_mut(optr.0.add((it.out_off + i) * hd + hh * d), d)
            };
            run_unit(it, hh, i, or, &mut probs, &mut midx);
        }
    });
}

/// The original tuple-vector attention (oracle path).
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_naive(
    out: &mut [f32],
    q: &[f32],
    kv: &[f32],
    dims: KvDims,
    layer: usize,
    t: usize,
    tk: usize,
    mask: &[f32],
    kv_len: usize,
    scale: f32,
) {
    let d = dims.d;
    let hd = dims.h * d;
    let mut scores: Vec<(usize, f32)> = Vec::with_capacity(kv_len + tk);
    for hh in 0..dims.h {
        for i in 0..t {
            let qr = &q[i * hd + hh * d..i * hd + hh * d + d];
            scores.clear();
            let mut m = f32::NEG_INFINITY;
            for j in 0..kv_len.min(dims.b) {
                let kr = &kv[dims.row(layer, 0, hh, j)..dims.row(layer, 0, hh, j) + d];
                let s = dot(qr, kr) * scale;
                if s > m {
                    m = s;
                }
                scores.push((j, s));
            }
            for r in 0..tk {
                let j = kv_len + r;
                if j >= dims.b || mask[i * tk + r] <= 0.5 {
                    continue;
                }
                let kr = &kv[dims.row(layer, 0, hh, j)..dims.row(layer, 0, hh, j) + d];
                let s = dot(qr, kr) * scale;
                if s > m {
                    m = s;
                }
                scores.push((j, s));
            }
            let or = &mut out[i * hd + hh * d..i * hd + hh * d + d];
            if scores.is_empty() {
                continue;
            }
            let mut z = 0f32;
            for (_, s) in scores.iter_mut() {
                *s = (*s - m).exp();
                z += *s;
            }
            let zr = 1.0 / z.max(1e-30);
            for &(j, p) in scores.iter() {
                let vr = &kv[dims.row(layer, 1, hh, j)..dims.row(layer, 1, hh, j) + d];
                let w = p * zr;
                for dd in 0..d {
                    or[dd] += w * vr[dd];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn compact_window_moves_accepted_rows() {
        let dims = KvDims { l: 1, h: 1, b: 32, d: 2 };
        let mut kv: Vec<f32> =
            (0..dims.l * 2 * dims.h * dims.b * dims.d).map(|i| i as f32).collect();
        let before_row6 =
            kv[dims.row(0, 0, 0, 10 + 6)..dims.row(0, 0, 0, 10 + 6) + 2].to_vec();
        // kv_len 10, accepted window rows [2, 6] → rows 12, 16 move to 10, 11
        compact_window(&mut kv, dims, 10, &[2, 6, 0, 0], 2, 16);
        let r10 = &kv[dims.row(0, 0, 0, 10)..dims.row(0, 0, 0, 10) + 2];
        assert_eq!(r10, &[(12 * 2) as f32, (12 * 2 + 1) as f32][..]);
        let r11 = &kv[dims.row(0, 0, 0, 11)..dims.row(0, 0, 0, 11) + 2];
        assert_eq!(r11, &before_row6[..]);
    }

    #[test]
    fn rope_tab_matches_per_token_rotation() {
        let inv_freq = vec![1.0f32, 0.25, 0.0625];
        let pos = vec![0i32, 3, 17, 100];
        let (t, n_head, d) = (4usize, 2usize, 6usize);
        let mut rng = Rng::new(5);
        let base: Vec<f32> = (0..t * n_head * d).map(|_| rng.normal() as f32).collect();
        let mut a = base.clone();
        let mut b = base;
        rope_apply_naive(&mut a, &pos, &inv_freq, t, n_head, d);
        let tab = rope_tab(&pos, &inv_freq);
        rope_apply_tab(&mut b, &tab, t, n_head, d);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn batched_attention_matches_per_session_calls_bytewise() {
        let mut rng = Rng::new(33);
        // two "sessions" with different buckets, kv_lens and t widths
        let specs = [(KvDims { l: 1, h: 2, b: 32, d: 8 }, 20usize, 3usize),
                     (KvDims { l: 1, h: 2, b: 64, d: 8 }, 45, 5)];
        let mut kvs: Vec<Vec<f32>> = Vec::new();
        let mut qs: Vec<Vec<f32>> = Vec::new();
        let mut masks: Vec<Vec<f32>> = Vec::new();
        for &(dims, _kv_len, t) in &specs {
            kvs.push((0..dims.l * 2 * dims.h * dims.b * dims.d).map(|_| rng.normal() as f32).collect());
            qs.push((0..t * dims.h * dims.d).map(|_| rng.normal() as f32).collect());
            masks.push(crate::tree::chain_mask(t, t));
        }
        let hd = specs[0].0.h * specs[0].0.d;
        let total_rows: usize = specs.iter().map(|&(_, _, t)| t).sum();
        for threads in [1usize, 4] {
            let pool = Pool::new(threads);
            // per-session reference
            let mut want = vec![0f32; total_rows * hd];
            let mut off = 0usize;
            for (si, &(dims, kv_len, t)) in specs.iter().enumerate() {
                attention(
                    &pool,
                    &mut want[off * hd..(off + t) * hd],
                    &qs[si],
                    &kvs[si],
                    dims,
                    0,
                    t,
                    t,
                    &masks[si],
                    kv_len,
                    0.4,
                );
                off += t;
            }
            // one fused dispatch
            let mut items = Vec::new();
            let mut off = 0usize;
            for (si, &(dims, kv_len, t)) in specs.iter().enumerate() {
                items.push(AttItem {
                    q: &qs[si],
                    kv: &kvs[si],
                    dims,
                    layer: 0,
                    t,
                    tk: t,
                    mask: &masks[si],
                    kv_len,
                    out_off: off,
                });
                off += t;
            }
            let mut got = vec![0f32; total_rows * hd];
            attention_batch(&pool, &mut got, &items, 0.4);
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "batched attention diverged at {threads} threads"
            );
        }
    }

    #[test]
    fn fast_attention_matches_naive_bytewise() {
        let dims = KvDims { l: 2, h: 3, b: 64, d: 8 };
        let mut rng = Rng::new(21);
        let mut kv: Vec<f32> =
            (0..dims.l * 2 * dims.h * dims.b * dims.d).map(|_| rng.normal() as f32).collect();
        // zero the "unwritten" tail like a real cache
        let kv_len = 40usize;
        let t = 5usize;
        let tk = t;
        for layer in 0..dims.l {
            for plane in 0..2 {
                for hh in 0..dims.h {
                    for row in kv_len + t..dims.b {
                        let s = dims.row(layer, plane, hh, row);
                        kv[s..s + dims.d].iter_mut().for_each(|x| *x = 0.0);
                    }
                }
            }
        }
        let q: Vec<f32> = (0..t * dims.h * dims.d).map(|_| rng.normal() as f32).collect();
        let mask = crate::tree::chain_mask(t, t);
        for layer in 0..dims.l {
            let mut want = vec![0f32; t * dims.h * dims.d];
            attention_naive(&mut want, &q, &kv, dims, layer, t, tk, &mask, kv_len, 0.35);
            for threads in [1usize, 3] {
                let pool = Pool::new(threads);
                let mut got = vec![0f32; t * dims.h * dims.d];
                attention(&pool, &mut got, &q, &kv, dims, layer, t, tk, &mask, kv_len, 0.35);
                assert!(
                    got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "layer {layer}, {threads} threads"
                );
            }
        }
    }
}
