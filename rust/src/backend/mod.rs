//! Device abstraction: the typed kernel-op API every engine runs on.
//!
//! The engines' hot path used to be hard-wired to the PJRT runtime
//! through a stringly-typed `invoke("verify_s_b1024_t16", &[Arg])` ABI.
//! This module replaces that contract with a [`Backend`] trait whose
//! methods are the *semantic* operations of the SpecPV stack — each a
//! struct carrying bucket/tree geometry instead of a formatted
//! executable name:
//!
//! | op                | semantics                                          |
//! |-------------------|----------------------------------------------------|
//! | `prefill`         | target fwd over one causal prompt chunk            |
//! | `verify_full`     | tree/AR/refresh verification against the full KV   |
//! | `verify_partial`  | tree verification against the partial KV (§3.2)    |
//! | `commit`          | standalone acceptance compaction after a Refresh   |
//! | `score`           | Quest-style retrieval block scores (Eqs. 1–3)      |
//! | `refresh_gather`  | assemble a fresh partial state from a gather plan  |
//! | `draft_prefill`   | EAGLE draft prefill consuming target-state feats   |
//! | `draft_expand`    | EAGLE draft chain/level step over W tree slots     |
//! | `medusa`          | Medusa heads off the top target feature            |
//! | `tiny_forward`    | TriForce independent tiny-LM step (streaming ring) |
//! | `read_logits`     | host-visible extractor reads from a state          |
//!
//! The bandwidth-bound ops additionally ship **batched variants**
//! (`prefill_batch`, `verify_full_batch`, `verify_partial_batch`,
//! `draft_expand_batch`, `tiny_forward_batch`) that execute many
//! independent sessions' ops in one invocation with a strict byte-parity
//! contract — see DESIGN.md §12. Default impls fall back to a sequential
//! loop; the reference backend fuses them into stacked matmuls.
//!
//! Two implementations ship:
//! * [`pjrt::PjrtBackend`] — the AOT-artifact player: maps typed ops to
//!   manifest executable names in one place and executes them on the
//!   PJRT CPU client (`crate::runtime`);
//! * [`reference::ReferenceBackend`] — a pure-Rust host backend with the
//!   same char-LM forward semantics and deterministic seeded weights, so
//!   every engine runs end-to-end with no artifacts (CI, tests, demos).
//!
//! State buffers are opaque [`StateBuf`] handles (device buffers for
//! pjrt, host vectors for the reference backend) threaded call-to-call;
//! ops that mutate a state take it by value and return the successor, so
//! a host backend can update in place while a device backend re-threads
//! buffers. See DESIGN.md §10.

pub mod pjrt;
pub mod reference;

use std::any::Any;
use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::config::{BackendKind, Config};
use crate::manifest::{Consts, ModelInfo, StateLayout};

/// Execution counters every backend tracks (surfaced through
/// `Registry::summary` and the server `metrics` op so operators can see
/// which backend served a request and what it cost).
#[derive(Debug, Default, Clone)]
pub struct Counters {
    pub executions: u64,
    pub exec_secs: f64,
    pub compilations: u64,
    pub compile_secs: f64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
    pub per_exec: HashMap<String, (u64, f64)>,
}

/// An opaque, backend-owned state buffer (the flat f32 state of
/// DESIGN.md §4). Only the backend that produced it can interpret it.
pub struct StateBuf(Box<dyn Any>);

impl StateBuf {
    pub fn new<T: 'static>(inner: T) -> StateBuf {
        StateBuf(Box::new(inner))
    }

    /// Placeholder used when moving a state out of a session field.
    pub fn nil() -> StateBuf {
        StateBuf(Box::new(()))
    }

    pub fn downcast<T: 'static>(self) -> Result<T> {
        self.0
            .downcast::<T>()
            .map(|b| *b)
            .map_err(|_| anyhow!("state buffer belongs to a different backend"))
    }

    pub fn downcast_ref<T: 'static>(&self) -> Result<&T> {
        self.0
            .downcast_ref::<T>()
            .ok_or_else(|| anyhow!("state buffer belongs to a different backend"))
    }

    pub fn downcast_mut<T: 'static>(&mut self) -> Result<&mut T> {
        self.0
            .downcast_mut::<T>()
            .ok_or_else(|| anyhow!("state buffer belongs to a different backend"))
    }
}

impl std::fmt::Debug for StateBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("StateBuf(..)")
    }
}

/// A host-side snapshot of a state buffer (the KV state manager's unit
/// of exchange — see DESIGN.md §11): the flat f32 state of DESIGN.md §4
/// plus any backend-private lazy rows, tagged with the geometry needed to
/// re-import it. Produced by [`Backend::export_state`], consumed by
/// [`Backend::import_state`]; stored by `kvstore` for prefix caching and
/// session swapping.
#[derive(Clone)]
pub struct StateSnapshot {
    pub kind: StateKind,
    pub size: String,
    pub bucket: usize,
    /// the flat state (kv | logits | feats | queries)
    pub data: Vec<f32>,
    /// backend-private extra rows (reference backend: the lazy-logits
    /// hidden rows; always empty on pjrt)
    pub extra: Vec<f32>,
}

impl StateSnapshot {
    /// Host bytes this snapshot occupies.
    pub fn bytes(&self) -> usize {
        (self.data.len() + self.extra.len()) * 4
    }
}

impl std::fmt::Debug for StateSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "StateSnapshot({:?} {} b{}, {} f32 + {} extra)",
            self.kind,
            self.size,
            self.bucket,
            self.data.len(),
            self.extra.len()
        )
    }
}

/// Which flat-state layout a buffer follows (DESIGN.md §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StateKind {
    /// target model over a full bucket: kv | logits | feats | queries
    Full,
    /// SpecPV partial cache: kv | logits | feats
    Partial,
    /// EAGLE draft layer: kv | logits | hidden
    Draft,
    /// TriForce tiny LM: kv | last-row logits
    Tiny,
}

/// Target forward over one causal prompt chunk (tokens padded to the
/// chunk width, `mask` a causal chain over the real rows).
#[derive(Debug)]
pub struct PrefillOp<'a> {
    pub size: &'a str,
    pub bucket: usize,
    pub tokens: &'a [i32],
    pub pos: &'a [i32],
    pub mask: &'a [f32],
    /// committed KV length (write offset for the chunk's rows)
    pub kv_len: usize,
}

/// Verification step with fused acceptance compaction: the accepted rows
/// of the previous step (`prev_idx[..n_prev]`, window-relative) are
/// compacted into the committed region before the `t` new tokens are
/// processed and appended at `kv_len + n_prev`. Used for AR decode
/// (`t == 1`), tree verification (`t == tree_t`) and Refresh steps
/// (`t` = a refresh width); against the full bucket (`verify_full`) or
/// the partial bucket (`verify_partial`).
#[derive(Debug)]
pub struct VerifyOp<'a> {
    pub size: &'a str,
    /// full bucket B (verify_full) or partial bucket P (verify_partial)
    pub bucket: usize,
    /// token-slot width of this step (compiled T variant on pjrt)
    pub t: usize,
    pub tokens: &'a [i32],
    pub pos: &'a [i32],
    /// `[t, t]` ancestor mask
    pub mask: &'a [f32],
    pub kv_len: usize,
    /// accepted rows of the previous step, padded to the fused window
    pub prev_idx: &'a [i32],
    pub n_prev: usize,
}

/// Standalone acceptance compaction (after a Refresh step, where up to a
/// refresh-width of rows must commit before score/gather run).
#[derive(Debug)]
pub struct CommitOp<'a> {
    pub size: &'a str,
    pub bucket: usize,
    /// compaction window width (a refresh width)
    pub window: usize,
    /// kept rows, window-relative, padded to `window`
    pub idx: &'a [i32],
    pub n: usize,
    pub kv_len: usize,
}

/// Retrieval block scores from the queries the last verification wrote.
/// Returns flat `[L, 3, NB]` (mean/max/last reductions stacked).
#[derive(Debug)]
pub struct ScoreOp<'a> {
    pub size: &'a str,
    pub bucket: usize,
    pub kv_len: usize,
    pub n_queries: usize,
}

/// Assemble a fresh partial state by gathering whole KV blocks out of a
/// full state (the Refresh step's cache rebuild).
#[derive(Debug)]
pub struct GatherOp<'a> {
    pub size: &'a str,
    /// source full bucket
    pub bucket: usize,
    /// destination partial bucket
    pub p_bucket: usize,
    /// flat `[L, nsel]` block ids in token order (sink ++ retrieval ++
    /// local), padded by repeating the final block
    pub block_idx: &'a [i32],
}

/// EAGLE draft prefill over one chunk; the fused target features are
/// sliced from the target state backend-side (no host round-trip).
#[derive(Debug)]
pub struct DraftPrefillOp<'a> {
    pub size: &'a str,
    pub bucket: usize,
    pub tokens: &'a [i32],
    pub pos: &'a [i32],
    pub mask: &'a [f32],
    pub kv_len: usize,
    pub write_pos: usize,
}

/// EAGLE draft chain/level step over the W draft slots.
#[derive(Debug)]
pub struct DraftExpandOp<'a> {
    pub size: &'a str,
    pub bucket: usize,
    pub tokens: &'a [i32],
    /// `[W, 3h]` fused features (target feats or recycled hiddens)
    pub feats: &'a [f32],
    pub pos: &'a [i32],
    /// `[W, draft_region]` scratch-region visibility mask
    pub mask: &'a [f32],
    pub kv_len: usize,
    pub write_pos: usize,
}

/// TriForce tiny-LM forward (streaming ring cache: `write_pos` may lie
/// behind `kv_len` once the ring wraps).
#[derive(Debug)]
pub struct TinyForwardOp<'a> {
    pub t: usize,
    pub tokens: &'a [i32],
    pub pos: &'a [i32],
    pub mask: &'a [f32],
    pub kv_len: usize,
    pub write_pos: usize,
    /// which row's logits the state keeps (last real token of the chunk)
    pub last_idx: usize,
}

/// Host-visible extractor reads (the only downloads on the request path).
#[derive(Debug)]
pub enum ReadOp<'a> {
    /// `qrows` rows of `[logits | feats]` starting at row `start`
    FullWindow { size: &'a str, bucket: usize, start: usize },
    /// single row `[logits | feats]` at `idx` (prefill tail)
    LastRow { size: &'a str, bucket: usize, idx: usize },
    /// the partial state's `tree_t` rows of `[logits | feats]`
    Partial { size: &'a str, bucket: usize },
    /// the draft state's `[W·V logits | W·h hiddens]`
    Draft { size: &'a str, bucket: usize },
    /// one draft hidden row (last real prompt token of a padded chunk)
    DraftHiddenRow { size: &'a str, bucket: usize, idx: usize },
    /// the tiny state's kept logits row
    Tiny,
}

/// A device (or host) executor for the SpecPV kernel-op set. Object-safe
/// so engines, the coordinator and the server are generic over
/// `&dyn Backend`.
///
/// The catalog methods (`consts`, `model`, `full_buckets`, …) describe
/// the geometry this backend can execute — manifest-driven for pjrt,
/// built-in for the reference backend — and replace every direct
/// manifest access the engines used to perform.
pub trait Backend {
    /// Short stable identifier ("pjrt", "reference") for telemetry.
    fn name(&self) -> &'static str;

    /// Global geometry constants (chunk, tree_t, refresh widths, …).
    fn consts(&self) -> &Consts;

    /// Model hyperparameters for a size ("s", "m", "l", "tiny").
    fn model(&self, size: &str) -> Result<ModelInfo>;

    /// Model sizes this backend can execute (sorted).
    fn sizes(&self) -> Vec<String>;

    /// Full target buckets available for `size`, ascending.
    fn full_buckets(&self, size: &str) -> Vec<usize>;

    /// Partial buckets available for `size`, ascending.
    fn partial_buckets(&self, size: &str) -> Vec<usize>;

    /// Refresh widths executable against `(size, bucket)`, ascending.
    fn refresh_widths(&self, size: &str, bucket: usize) -> Vec<usize>;

    /// Flat-state layout of a `(kind, size, bucket)` state.
    fn state_layout(&self, kind: StateKind, size: &str, bucket: usize) -> Result<StateLayout>;

    /// Fresh all-zero state of the given kind.
    fn alloc_state(&self, kind: StateKind, size: &str, bucket: usize) -> Result<StateBuf>;

    /// Resident bytes of one `(kind, size, bucket)` state — the unit the
    /// KV pool's admission accounting is denominated in.
    fn state_bytes(&self, kind: StateKind, size: &str, bucket: usize) -> Result<usize> {
        Ok(self.state_layout(kind, size, bucket)?.total * 4)
    }

    // --- page-granular state ABI (DESIGN.md §13) ------------------------
    //
    // A state's host image is the flat f32 sequence `data ++ extra`
    // (`data` = the DESIGN.md §4 flat state, `extra` = backend-private
    // rows such as the reference backend's lazy-logits hiddens). The
    // paged KV tier moves that image page-by-page: `export_pages` /
    // `import_pages` are the required primitives, and the whole-state
    // snapshot ABI below is the provided wrapper expressed as the full
    // page range.

    /// f32 element counts `(data_len, extra_len)` of this state's host
    /// image — the geometry `export_pages`/`import_pages` page over.
    fn state_image_len(
        &self,
        kind: StateKind,
        size: &str,
        bucket: usize,
        state: &StateBuf,
    ) -> Result<(usize, usize)>;

    /// Export the pages `pages` (page ids at `page_elems` f32 per page
    /// over the host image) of a state. Every page is `page_elems` long
    /// except the final one, which carries the image tail. Exported
    /// content is exact: a state rebuilt from these pages continues
    /// generation byte-identically.
    fn export_pages(
        &self,
        kind: StateKind,
        size: &str,
        bucket: usize,
        state: &StateBuf,
        pages: std::ops::Range<usize>,
        page_elems: usize,
    ) -> Result<Vec<Vec<f32>>>;

    /// Rebuild a state buffer by streaming pages: the backend calls
    /// `read_page(page_id, &mut scratch)` for each page of the image in
    /// order, so the caller materializes one page at a time (from the
    /// paged pool, a snapshot, or disk) instead of one whole slab.
    fn import_pages(
        &self,
        kind: StateKind,
        size: &str,
        bucket: usize,
        data_len: usize,
        extra_len: usize,
        page_elems: usize,
        read_page: &mut dyn FnMut(usize, &mut Vec<f32>) -> Result<()>,
    ) -> Result<StateBuf>;

    /// Whole-state host snapshot — the page ABI expressed as the full
    /// range (one page spanning the image).
    fn export_state(
        &self,
        kind: StateKind,
        size: &str,
        bucket: usize,
        state: &StateBuf,
    ) -> Result<StateSnapshot> {
        let (data_len, extra_len) = self.state_image_len(kind, size, bucket, state)?;
        let total = data_len + extra_len;
        let pe = total.max(1);
        let mut pages =
            self.export_pages(kind, size, bucket, state, 0..page_count(total, pe), pe)?;
        let mut data = pages.pop().unwrap_or_default();
        let extra = data.split_off(data_len);
        Ok(StateSnapshot { kind, size: size.to_string(), bucket, data, extra })
    }

    /// Rebuild a state buffer from a whole-state snapshot (the full-range
    /// page import).
    fn import_state(&self, snap: &StateSnapshot) -> Result<StateBuf> {
        let (data_len, extra_len) = (snap.data.len(), snap.extra.len());
        let pe = (data_len + extra_len).max(1);
        self.import_pages(
            snap.kind,
            &snap.size,
            snap.bucket,
            data_len,
            extra_len,
            pe,
            &mut |page, buf| {
                debug_assert_eq!(page, 0, "whole-state import is a single page");
                buf.clear();
                buf.extend_from_slice(&snap.data);
                buf.extend_from_slice(&snap.extra);
                Ok(())
            },
        )
    }

    // --- kernel ops -----------------------------------------------------

    fn prefill(&self, op: &PrefillOp, state: StateBuf) -> Result<StateBuf>;

    fn verify_full(&self, op: &VerifyOp, state: StateBuf) -> Result<StateBuf>;

    fn verify_partial(&self, op: &VerifyOp, state: StateBuf) -> Result<StateBuf>;

    fn commit(&self, op: &CommitOp, state: StateBuf) -> Result<StateBuf>;

    fn score(&self, op: &ScoreOp, state: &StateBuf) -> Result<Vec<f32>>;

    fn refresh_gather(&self, op: &GatherOp, state: &StateBuf) -> Result<StateBuf>;

    fn draft_prefill(
        &self,
        op: &DraftPrefillOp,
        target_state: &StateBuf,
        draft_state: StateBuf,
    ) -> Result<StateBuf>;

    fn draft_expand(&self, op: &DraftExpandOp, draft_state: StateBuf) -> Result<StateBuf>;

    /// Medusa heads: top-layer feature `[d_model]` → flat `[3, V]` logits.
    fn medusa(&self, size: &str, feat: &[f32]) -> Result<Vec<f32>>;

    fn tiny_forward(&self, op: &TinyForwardOp, state: StateBuf) -> Result<StateBuf>;

    fn read_logits(&self, op: &ReadOp, state: &StateBuf) -> Result<Vec<f32>>;

    // --- batched kernel ops (cross-session fusion, DESIGN.md §12) -------
    //
    // Each takes parallel slices of per-session ops and the state buffers
    // they mutate in place. The contract is strict byte parity: executing
    // a batch must leave every state (and every subsequent read off it)
    // bit-identical to executing the ops one at a time in slice order, at
    // any batch size and thread count. The defaults below are exactly
    // that sequential loop, so a backend without a fused path (pjrt plays
    // single-sequence AOT executables) is automatically correct; the
    // reference backend overrides them to stack per-session rows into one
    // matmul per layer per op, amortizing weight traffic B×.
    //
    // Failure semantics: a fused implementation must validate every op
    // before mutating any state (all-or-nothing); the sequential defaults
    // stop at the first error, which may leave earlier members executed
    // and the failing member's state nil. Callers treat any batch error
    // as fatal for the whole group (the coordinator fails every member),
    // so a partially-executed state is never stepped again either way.

    /// True when this backend's `*_batch` ops actually fuse work across
    /// sessions (rather than inheriting the sequential default loop).
    /// The coordinator uses this to report honest occupancy metrics.
    fn fuses_batches(&self) -> bool {
        false
    }

    /// Batched [`Backend::prefill`] over independent sessions' chunks.
    fn prefill_batch(&self, ops: &[PrefillOp], states: &mut [&mut StateBuf]) -> Result<()> {
        check_batch(ops.len(), states.len())?;
        for (op, st) in ops.iter().zip(states.iter_mut()) {
            let owned = std::mem::replace(&mut **st, StateBuf::nil());
            **st = self.prefill(op, owned)?;
        }
        Ok(())
    }

    /// Batched [`Backend::verify_full`] over independent sessions.
    fn verify_full_batch(&self, ops: &[VerifyOp], states: &mut [&mut StateBuf]) -> Result<()> {
        check_batch(ops.len(), states.len())?;
        for (op, st) in ops.iter().zip(states.iter_mut()) {
            let owned = std::mem::replace(&mut **st, StateBuf::nil());
            **st = self.verify_full(op, owned)?;
        }
        Ok(())
    }

    /// Batched [`Backend::verify_partial`] over independent sessions.
    fn verify_partial_batch(&self, ops: &[VerifyOp], states: &mut [&mut StateBuf]) -> Result<()> {
        check_batch(ops.len(), states.len())?;
        for (op, st) in ops.iter().zip(states.iter_mut()) {
            let owned = std::mem::replace(&mut **st, StateBuf::nil());
            **st = self.verify_partial(op, owned)?;
        }
        Ok(())
    }

    /// Batched [`Backend::draft_expand`] over independent draft sessions.
    fn draft_expand_batch(
        &self,
        ops: &[DraftExpandOp],
        states: &mut [&mut StateBuf],
    ) -> Result<()> {
        check_batch(ops.len(), states.len())?;
        for (op, st) in ops.iter().zip(states.iter_mut()) {
            let owned = std::mem::replace(&mut **st, StateBuf::nil());
            **st = self.draft_expand(op, owned)?;
        }
        Ok(())
    }

    /// Batched [`Backend::tiny_forward`] over independent tiny sessions.
    fn tiny_forward_batch(
        &self,
        ops: &[TinyForwardOp],
        states: &mut [&mut StateBuf],
    ) -> Result<()> {
        check_batch(ops.len(), states.len())?;
        for (op, st) in ops.iter().zip(states.iter_mut()) {
            let owned = std::mem::replace(&mut **st, StateBuf::nil());
            **st = self.tiny_forward(op, owned)?;
        }
        Ok(())
    }

    /// Snapshot of the execution counters.
    fn counters(&self) -> Counters;

    /// Human-readable catalog summary (`specpv inspect`).
    fn describe(&self) -> String {
        let c = self.consts();
        format!(
            "{} backend: chunk={} tree_t={} refresh_t={} block={} vocab={}",
            self.name(),
            c.chunk,
            c.tree_t,
            c.refresh_t,
            c.block,
            c.vocab
        )
    }
}

/// Shared arity check for the batched kernel-op entry points (also used
/// by backend implementations' fused paths).
pub(crate) fn check_batch(ops: usize, states: usize) -> Result<()> {
    if ops != states {
        bail!("batched op count {ops} != state count {states}");
    }
    Ok(())
}

/// Pages an image of `total` f32 elements occupies at `page_elems` per
/// page (0 for an empty image).
pub fn page_count(total: usize, page_elems: usize) -> usize {
    if total == 0 {
        0
    } else {
        (total + page_elems - 1) / page_elems
    }
}

/// Copy the image element range `[start, end)` of `data ++ extra` into
/// `out` (cleared first). Shared by backends' `export_pages` and the
/// pool's image pager; handles ranges straddling the data/extra seam.
pub fn copy_image_range(data: &[f32], extra: &[f32], start: usize, end: usize, out: &mut Vec<f32>) {
    out.clear();
    let d = data.len();
    if start < d {
        out.extend_from_slice(&data[start..end.min(d)]);
    }
    if end > d {
        out.extend_from_slice(&extra[start.max(d) - d..end - d]);
    }
}

/// Smallest bucket in `buckets` (ascending or not) holding `need` tokens.
pub fn pick_bucket(buckets: &[usize], need: usize, what: &str, size: &str) -> Result<usize> {
    let mut bs = buckets.to_vec();
    bs.sort_unstable();
    bs.dedup();
    match bs.iter().find(|&&b| b >= need) {
        Some(&b) => Ok(b),
        None => bail!("no {what} bucket ≥ {need} for size {size} (have {bs:?})"),
    }
}

/// Construct the backend selected by the config. `Auto` resolves to pjrt
/// when the artifacts directory holds a manifest and to the reference
/// backend otherwise, so fresh checkouts (and CI) run end-to-end with no
/// artifacts. An explicit `threads` override (config key / `--threads`
/// flag) sizes a private kernel pool for the reference backend; 0 keeps
/// the process-wide pool (`SPECPV_THREADS` env / auto).
pub fn from_config(cfg: &Config) -> Result<Box<dyn Backend>> {
    match resolve_kind(cfg.backend, &cfg.artifacts_dir) {
        BackendKind::Pjrt => Ok(Box::new(pjrt::PjrtBackend::new(&cfg.artifacts_dir)?)),
        _ if cfg.threads >= 1 => Ok(Box::new(reference::ReferenceBackend::with_threads(
            crate::util::pool::resolve_threads(cfg.threads),
        ))),
        _ => Ok(Box::new(reference::ReferenceBackend::new())),
    }
}

/// The concrete kind `Auto` resolves to for an artifacts directory.
pub fn resolve_kind(kind: BackendKind, artifacts_dir: &Path) -> BackendKind {
    match kind {
        BackendKind::Auto => {
            if artifacts_dir.join("manifest.json").exists() {
                BackendKind::Pjrt
            } else {
                BackendKind::Reference
            }
        }
        k => k,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn statebuf_downcast_roundtrip() {
        let b = StateBuf::new(vec![1f32, 2.0]);
        assert_eq!(b.downcast_ref::<Vec<f32>>().unwrap(), &vec![1.0, 2.0]);
        let v: Vec<f32> = b.downcast().unwrap();
        assert_eq!(v, vec![1.0, 2.0]);
        let wrong = StateBuf::new(3usize);
        assert!(wrong.downcast::<Vec<f32>>().is_err());
    }

    #[test]
    fn page_math() {
        assert_eq!(page_count(0, 4), 0);
        assert_eq!(page_count(1, 4), 1);
        assert_eq!(page_count(8, 4), 2);
        assert_eq!(page_count(9, 4), 3);
        let data = [1.0f32, 2.0, 3.0];
        let extra = [4.0f32, 5.0];
        let mut out = Vec::new();
        copy_image_range(&data, &extra, 0, 3, &mut out);
        assert_eq!(out, [1.0, 2.0, 3.0]);
        copy_image_range(&data, &extra, 2, 5, &mut out); // straddles the seam
        assert_eq!(out, [3.0, 4.0, 5.0]);
        copy_image_range(&data, &extra, 3, 5, &mut out);
        assert_eq!(out, [4.0, 5.0]);
    }

    #[test]
    fn pick_bucket_smallest_fit() {
        assert_eq!(pick_bucket(&[512, 128, 288], 200, "full", "s").unwrap(), 288);
        assert!(pick_bucket(&[128], 200, "full", "s").is_err());
    }

    #[test]
    fn auto_resolves_to_reference_without_artifacts() {
        let kind = resolve_kind(BackendKind::Auto, Path::new("/nonexistent"));
        assert_eq!(kind, BackendKind::Reference);
        assert_eq!(resolve_kind(BackendKind::Pjrt, Path::new("/nonexistent")), BackendKind::Pjrt);
    }
}
