//! Pure-Rust **reference backend**: executes the same char-LM forward
//! semantics as the AOT artifacts (`python/compile/model.py`) directly on
//! the host — embedding → RMSNorm → RoPE(+YARN) → tree attention over the
//! flat-state KV layout → SwiGLU → logits — with deterministic seeded
//! weights, so every engine runs end-to-end with **no artifacts**.
//!
//! Design goals (in priority order):
//! 1. *semantic parity* with the JAX graphs: same state layouts
//!    (kv | logits | feats | queries), same fused acceptance compaction,
//!    same visibility rule (`history < kv_len` ∪ masked new region), same
//!    Quest block scoring and block gather — so the decode algorithms
//!    (including SpecPV's partial-verify ≡ full-verify-over-the-same-rows
//!    property) are directly testable;
//! 2. *determinism*: weights come from a seeded xorshift init and all
//!    float loops run in a fixed order, so identical requests produce
//!    byte-identical outputs across runs and machines;
//! 3. *CI speed*: a scaled-down geometry (chunk 64, buckets ≤ 1024,
//!    d_model 16–64) keeps an end-to-end generation in the tens of
//!    milliseconds.
//!
//! The weights are random (not trained), which is irrelevant to the
//! properties under test: losslessness (spec_full ≡ ar), the SpecPV mode
//! machine, cache accounting and scheduler behaviour are all functions of
//! the *algorithm*, not of output quality.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::manifest::{Consts, ModelInfo, StateLayout};
use crate::util::rng::Rng;

use super::{
    CommitOp, Counters, DraftExpandOp, DraftPrefillOp, GatherOp, PrefillOp, ReadOp, ScoreOp,
    StateBuf, StateKind, TinyForwardOp, VerifyOp,
};

// Scaled-down geometry (the aot.py constants at CI scale). CHUNK is both
// the prefill chunk and the logits/feats row capacity, so it must cover
// the widest refresh variant.
const CHUNK: usize = 64;
const TREE_T: usize = 16;
const REFRESH_T: usize = 48;
const BIG_REFRESH_T: usize = 64;
const QROWS: usize = 16;
const DRAFT_W: usize = 8;
const DRAFT_REGION: usize = 32;
const PREV_MAX: usize = 8;
const PREV_WINDOW: usize = 16;
const BLOCK: usize = 16;
const YARN_FACTOR: f64 = 16.0;
const FULL_BUCKETS: [usize; 7] = [128, 288, 512, 1024, 2048, 4096, 8192];
const PARTIAL_BUCKETS: [usize; 6] = [96, 160, 224, 384, 640, 1280];
// must be ≥ 2·CHUNK so the tiny prefill's chunked writes never clamp
// (mirrors aot.py: TINY_BUCKET = 2 × CHUNK)
const TINY_BUCKET: usize = 128;

const NEG_INF: f32 = -1e30;

/// Model hyperparameters (mirrors `model.py::ModelCfg` at reduced scale).
#[derive(Debug, Clone)]
struct RefCfg {
    n_layer: usize,
    d_model: usize,
    n_head: usize,
    d_head: usize,
    d_ff: usize,
    vocab: usize,
    rope_theta: f64,
    train_ctx: usize,
}

impl RefCfg {
    fn hd(&self) -> usize {
        self.n_head * self.d_head
    }

    /// EAGLE-3 feature taps (low/mid/top layer inputs); fewer than three
    /// distinct layers (the tiny LM) means no fused feature.
    fn feat_layers(&self) -> Vec<usize> {
        let mut v = vec![0, self.n_layer / 2, self.n_layer - 1];
        v.dedup();
        v
    }

    fn has_feats(&self) -> bool {
        self.feat_layers().len() == 3
    }
}

struct LayerW {
    ln1: Vec<f32>,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    ln2: Vec<f32>,
    wg: Vec<f32>,
    wu: Vec<f32>,
    wd: Vec<f32>,
}

struct TargetW {
    embed: Vec<f32>,
    ln_f: Vec<f32>,
    head: Vec<f32>,
    layers: Vec<LayerW>,
}

struct DraftW {
    fuse: Vec<f32>,
    inp: Vec<f32>,
    ln_f: Vec<f32>,
    layer: LayerW,
}

struct MedusaW {
    /// per head: (w1 [h,h], w2 [h,V])
    heads: Vec<(Vec<f32>, Vec<f32>)>,
}

struct RefModel {
    cfg: RefCfg,
    target: TargetW,
    draft: Option<DraftW>,
    medusa: Option<MedusaW>,
    inv_freq: Vec<f32>,
    mscale: f32,
}

// ---------------------------------------------------------------------------
// Deterministic init (seeded xorshift; scales mirror model.py)
// ---------------------------------------------------------------------------

fn normal_mat(rng: &mut Rng, rows: usize, cols: usize, std: f32) -> Vec<f32> {
    (0..rows * cols).map(|_| rng.normal() as f32 * std).collect()
}

fn dense(rng: &mut Rng, fan_in: usize, fan_out: usize) -> Vec<f32> {
    normal_mat(rng, fan_in, fan_out, 1.0 / (fan_in as f32).sqrt())
}

fn init_layer(rng: &mut Rng, cfg: &RefCfg) -> LayerW {
    let (h, hd, ff) = (cfg.d_model, cfg.hd(), cfg.d_ff);
    LayerW {
        ln1: vec![1.0; h],
        wq: dense(rng, h, hd),
        wk: dense(rng, h, hd),
        wv: dense(rng, h, hd),
        wo: dense(rng, hd, h),
        ln2: vec![1.0; h],
        wg: dense(rng, h, ff),
        wu: dense(rng, h, ff),
        wd: dense(rng, ff, h),
    }
}

fn seed_of(size: &str) -> u64 {
    size.bytes()
        .fold(0x5EED_CAFE_F00Du64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64))
}

fn init_model(size: &str, cfg: RefCfg, with_draft: bool) -> RefModel {
    let mut rng = Rng::new(seed_of(size));
    let h = cfg.d_model;
    let target = TargetW {
        embed: normal_mat(&mut rng, cfg.vocab, h, 0.02),
        ln_f: vec![1.0; h],
        head: dense(&mut rng, h, cfg.vocab),
        layers: (0..cfg.n_layer).map(|_| init_layer(&mut rng, &cfg)).collect(),
    };
    let draft = with_draft.then(|| DraftW {
        fuse: dense(&mut rng, 3 * h, h),
        inp: dense(&mut rng, 2 * h, h),
        ln_f: vec![1.0; h],
        layer: init_layer(&mut rng, &cfg),
    });
    let medusa = with_draft.then(|| MedusaW {
        heads: (0..3)
            .map(|_| (dense(&mut rng, h, h), dense(&mut rng, h, cfg.vocab)))
            .collect(),
    });
    let (inv_freq, mscale) = yarn_inv_freq(&cfg, YARN_FACTOR);
    RefModel { cfg, target, draft, medusa, inv_freq, mscale }
}

/// YARN-scaled inverse frequencies + attention temperature
/// (`model.py::yarn_inv_freq`, NTK-by-parts).
fn yarn_inv_freq(cfg: &RefCfg, factor: f64) -> (Vec<f32>, f32) {
    let d = cfg.d_head;
    let inv: Vec<f64> = (0..d / 2)
        .map(|k| 1.0 / cfg.rope_theta.powf(2.0 * k as f64 / d as f64))
        .collect();
    if factor <= 1.0 {
        return (inv.iter().map(|&x| x as f32).collect(), 1.0);
    }
    let l = cfg.train_ctx as f64;
    let (beta_fast, beta_slow) = (32.0f64, 1.0f64);
    let corr_dim = |rot: f64| -> f64 {
        (d as f64 * (l / (rot * 2.0 * std::f64::consts::PI)).ln())
            / (2.0 * cfg.rope_theta.ln())
    };
    let low = corr_dim(beta_fast).floor().max(0.0);
    let high = corr_dim(beta_slow).ceil().min(d as f64 / 2.0 - 1.0);
    let denom = (high - low).max(1.0);
    let inv_yarn: Vec<f32> = inv
        .iter()
        .enumerate()
        .map(|(k, &f)| {
            let ramp = ((k as f64 - low) / denom).clamp(0.0, 1.0);
            (f * (1.0 - ramp) + (f / factor) * ramp) as f32
        })
        .collect();
    let mscale = (0.1 * factor.ln() + 1.0) as f32;
    (inv_yarn, mscale)
}

// ---------------------------------------------------------------------------
// Flat-state layouts (mirrors aot.py, element counts in f32)
// ---------------------------------------------------------------------------

fn full_layout(cfg: &RefCfg, b: usize) -> StateLayout {
    let kv = cfg.n_layer * 2 * cfg.n_head * b * cfg.d_head;
    let logits = CHUNK * cfg.vocab;
    let feats = CHUNK * 3 * cfg.d_model;
    let queries = cfg.n_layer * cfg.n_head * QROWS * cfg.d_head;
    StateLayout { kv, logits, feats, queries, total: kv + logits + feats + queries }
}

fn partial_layout(cfg: &RefCfg, p: usize) -> StateLayout {
    let kv = cfg.n_layer * 2 * cfg.n_head * p * cfg.d_head;
    let logits = TREE_T * cfg.vocab;
    let feats = TREE_T * 3 * cfg.d_model;
    StateLayout { kv, logits, feats, queries: 0, total: kv + logits + feats }
}

fn draft_layout(cfg: &RefCfg, b: usize) -> StateLayout {
    let kv = 2 * cfg.n_head * b * cfg.d_head;
    let logits = DRAFT_W * cfg.vocab;
    let hidden = CHUNK * cfg.d_model;
    StateLayout { kv, logits, feats: hidden, queries: 0, total: kv + logits + hidden }
}

fn tiny_layout(cfg: &RefCfg, b: usize) -> StateLayout {
    let kv = cfg.n_layer * 2 * cfg.n_head * b * cfg.d_head;
    StateLayout { kv, logits: cfg.vocab, feats: 0, queries: 0, total: kv + cfg.vocab }
}

// ---------------------------------------------------------------------------
// Dense math helpers (fixed loop order for determinism)
// ---------------------------------------------------------------------------

/// `out[t, dout] += x[t, din] @ w[din, dout]` (out must be zeroed).
fn matmul_into(out: &mut [f32], x: &[f32], w: &[f32], t: usize, din: usize, dout: usize) {
    for i in 0..t {
        let xr = &x[i * din..(i + 1) * din];
        let or = &mut out[i * dout..(i + 1) * dout];
        for (k, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w[k * dout..(k + 1) * dout];
            for (o, &wv) in wr.iter().enumerate() {
                or[o] += xv * wv;
            }
        }
    }
}

fn matmul(x: &[f32], w: &[f32], t: usize, din: usize, dout: usize) -> Vec<f32> {
    let mut out = vec![0f32; t * dout];
    matmul_into(&mut out, x, w, t, din, dout);
    out
}

/// Row-wise RMSNorm (`model.py::rmsnorm`, eps 1e-5).
fn rmsnorm(x: &[f32], g: &[f32], t: usize, h: usize) -> Vec<f32> {
    let mut out = vec![0f32; t * h];
    for i in 0..t {
        let row = &x[i * h..(i + 1) * h];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / h as f32;
        let r = 1.0 / (ms + 1e-5).sqrt();
        for j in 0..h {
            out[i * h + j] = row[j] * g[j] * r;
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Rotate `[T, H·D]` rows in place (per head, interleaved pairs).
fn rope_apply(x: &mut [f32], pos: &[i32], inv_freq: &[f32], t: usize, n_head: usize, d: usize) {
    let hd = n_head * d;
    for i in 0..t {
        let p = pos[i] as f32;
        for hh in 0..n_head {
            let base = i * hd + hh * d;
            for (k, &f) in inv_freq.iter().enumerate() {
                let ang = p * f;
                let (sin, cos) = ang.sin_cos();
                let x1 = x[base + 2 * k];
                let x2 = x[base + 2 * k + 1];
                x[base + 2 * k] = x1 * cos - x2 * sin;
                x[base + 2 * k + 1] = x1 * sin + x2 * cos;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// KV-cache addressing over a flat `[L, 2, H, B, D]` region
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
struct KvDims {
    l: usize,
    h: usize,
    b: usize,
    d: usize,
}

impl KvDims {
    fn row(&self, layer: usize, plane: usize, head: usize, row: usize) -> usize {
        (((layer * 2 + plane) * self.h + head) * self.b + row) * self.d
    }
}

/// Acceptance compaction fused into the next verification step
/// (`model.py::compact_window`): move row `kv_len + prev_idx[j]` →
/// `kv_len + j` for `j < n_prev`. `prev_idx` is strictly increasing with
/// `prev_idx[j] ≥ j`, so an ascending in-place copy matches the
/// gather-then-scatter of the JAX graph.
fn compact_window(
    kv: &mut [f32],
    dims: KvDims,
    kv_len: usize,
    prev_idx: &[i32],
    n_prev: usize,
    window: usize,
) {
    // dynamic_slice clamp semantics
    let start = kv_len.min(dims.b.saturating_sub(window));
    for layer in 0..dims.l {
        for plane in 0..2 {
            for head in 0..dims.h {
                for j in 0..n_prev.min(prev_idx.len()) {
                    let src = (prev_idx[j].max(0) as usize).min(window - 1);
                    if src == j {
                        continue;
                    }
                    // src row is strictly behind dst (prev_idx[j] > j)
                    let s = dims.row(layer, plane, head, start + src);
                    let t = dims.row(layer, plane, head, start + j);
                    let (head_seg, tail_seg) = kv.split_at_mut(s);
                    head_seg[t..t + dims.d].copy_from_slice(&tail_seg[..dims.d]);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Transformer forward
// ---------------------------------------------------------------------------

struct FwdOut {
    /// [T, V]
    logits: Vec<f32>,
    /// [T, 3h] fused EAGLE-3 feature (empty when the model has < 3 taps)
    feats: Vec<f32>,
    /// per layer `[H, T, D]` post-RoPE queries (empty unless requested)
    queries: Vec<Vec<f32>>,
}

#[allow(clippy::too_many_arguments)]
fn attention(
    out: &mut [f32],
    q: &[f32],
    kv: &[f32],
    dims: KvDims,
    layer: usize,
    t: usize,
    tk: usize,
    mask: &[f32],
    kv_len: usize,
    scale: f32,
) {
    let d = dims.d;
    let hd = dims.h * d;
    let mut scores: Vec<(usize, f32)> = Vec::with_capacity(kv_len + tk);
    for hh in 0..dims.h {
        for i in 0..t {
            let qr = &q[i * hd + hh * d..i * hd + hh * d + d];
            scores.clear();
            let mut m = f32::NEG_INFINITY;
            // committed history rows, then the masked new region — the
            // same visibility rule as kernels/ref.py::tree_attention_ref
            for j in 0..kv_len.min(dims.b) {
                let kr = &kv[dims.row(layer, 0, hh, j)..dims.row(layer, 0, hh, j) + d];
                let s = dot(qr, kr) * scale;
                if s > m {
                    m = s;
                }
                scores.push((j, s));
            }
            for r in 0..tk {
                let j = kv_len + r;
                if j >= dims.b || mask[i * tk + r] <= 0.5 {
                    continue;
                }
                let kr = &kv[dims.row(layer, 0, hh, j)..dims.row(layer, 0, hh, j) + d];
                let s = dot(qr, kr) * scale;
                if s > m {
                    m = s;
                }
                scores.push((j, s));
            }
            let or = &mut out[i * hd + hh * d..i * hd + hh * d + d];
            if scores.is_empty() {
                continue; // fully masked row (never happens for real rows)
            }
            let mut z = 0f32;
            for (_, s) in scores.iter_mut() {
                *s = (*s - m).exp();
                z += *s;
            }
            let zr = 1.0 / z.max(1e-30);
            for &(j, p) in scores.iter() {
                let vr = &kv[dims.row(layer, 1, hh, j)..dims.row(layer, 1, hh, j) + d];
                let w = p * zr;
                for dd in 0..d {
                    or[dd] += w * vr[dd];
                }
            }
        }
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// One transformer layer (`model.py::layer_fwd`): writes this step's K/V
/// rows at `write_pos`, runs tree attention, returns the post-RoPE
/// queries for the retrieval scorer.
#[allow(clippy::too_many_arguments)]
fn layer_fwd(
    w: &LayerW,
    cfg: &RefCfg,
    x: &mut Vec<f32>,
    pos: &[i32],
    kv: &mut [f32],
    dims: KvDims,
    layer: usize,
    kv_len: usize,
    write_pos: usize,
    mask: &[f32],
    inv_freq: &[f32],
    mscale: f32,
) -> Vec<f32> {
    let t = pos.len();
    let (h, hd, d) = (cfg.d_model, cfg.hd(), cfg.d_head);
    let tk = mask.len() / t;
    let hn = rmsnorm(x, &w.ln1, t, h);
    let mut xq = matmul(&hn, &w.wq, t, h, hd);
    let mut xk = matmul(&hn, &w.wk, t, h, hd);
    let xv = matmul(&hn, &w.wv, t, h, hd);
    rope_apply(&mut xq, pos, inv_freq, t, cfg.n_head, d);
    rope_apply(&mut xk, pos, inv_freq, t, cfg.n_head, d);

    // functional dynamic_update_slice (clamped start, full T-row block)
    let start = write_pos.min(dims.b.saturating_sub(t));
    for i in 0..t {
        for hh in 0..cfg.n_head {
            let krow = dims.row(layer, 0, hh, start + i);
            kv[krow..krow + d].copy_from_slice(&xk[i * hd + hh * d..i * hd + hh * d + d]);
            let vrow = dims.row(layer, 1, hh, start + i);
            kv[vrow..vrow + d].copy_from_slice(&xv[i * hd + hh * d..i * hd + hh * d + d]);
        }
    }

    let scale = mscale / (d as f32).sqrt();
    let mut att = vec![0f32; t * hd];
    attention(&mut att, &xq, kv, dims, layer, t, tk, mask, kv_len, scale);
    let proj = matmul(&att, &w.wo, t, hd, h);
    for (xx, p) in x.iter_mut().zip(&proj) {
        *xx += p;
    }

    let h2 = rmsnorm(x, &w.ln2, t, h);
    let g = matmul(&h2, &w.wg, t, h, cfg.d_ff);
    let u = matmul(&h2, &w.wu, t, h, cfg.d_ff);
    let act: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| silu(gv) * uv).collect();
    let down = matmul(&act, &w.wd, t, cfg.d_ff, h);
    for (xx, p) in x.iter_mut().zip(&down) {
        *xx += p;
    }
    xq
}

/// Target forward (`model.py::target_fwd`): serves prefill, AR decode,
/// full/partial/refresh verification and the tiny LM — only the bucket,
/// token count and mask differ.
#[allow(clippy::too_many_arguments)]
fn target_fwd(
    model: &RefModel,
    kv: &mut [f32],
    bucket: usize,
    tokens: &[i32],
    pos: &[i32],
    mask: &[f32],
    kv_len: usize,
    write_pos: usize,
    want_queries: bool,
) -> FwdOut {
    let cfg = &model.cfg;
    let t = tokens.len();
    let h = cfg.d_model;
    let dims = KvDims { l: cfg.n_layer, h: cfg.n_head, b: bucket, d: cfg.d_head };
    let mut x = vec![0f32; t * h];
    for (i, &tok) in tokens.iter().enumerate() {
        let row = (tok.max(0) as usize).min(cfg.vocab - 1);
        x[i * h..(i + 1) * h].copy_from_slice(&model.target.embed[row * h..(row + 1) * h]);
    }
    let taps = cfg.feat_layers();
    let mut feats: Vec<Vec<f32>> = Vec::new();
    let mut queries: Vec<Vec<f32>> = Vec::new();
    for (l, w) in model.target.layers.iter().enumerate() {
        if cfg.has_feats() && taps.contains(&l) {
            feats.push(x.clone());
        }
        let xq = layer_fwd(
            w, cfg, &mut x, pos, kv, dims, l, kv_len, write_pos, mask, &model.inv_freq,
            model.mscale,
        );
        if want_queries {
            // [T, H·D] → [H, T, D]
            let (hd, d) = (cfg.hd(), cfg.d_head);
            let mut q = vec![0f32; hd * t];
            for i in 0..t {
                for hh in 0..cfg.n_head {
                    q[(hh * t + i) * d..(hh * t + i) * d + d]
                        .copy_from_slice(&xq[i * hd + hh * d..i * hd + hh * d + d]);
                }
            }
            queries.push(q);
        }
    }
    let xf = rmsnorm(&x, &model.target.ln_f, t, h);
    let logits = matmul(&xf, &model.target.head, t, h, cfg.vocab);
    let fused = if cfg.has_feats() {
        let mut f = vec![0f32; t * 3 * h];
        for i in 0..t {
            for (s, fv) in feats.iter().enumerate() {
                f[i * 3 * h + s * h..i * 3 * h + (s + 1) * h]
                    .copy_from_slice(&fv[i * h..(i + 1) * h]);
            }
        }
        f
    } else {
        Vec::new()
    };
    FwdOut { logits, feats: fused, queries }
}

/// Draft decoder forward (`model.py::draft_fwd`).
#[allow(clippy::too_many_arguments)]
fn draft_fwd(
    model: &RefModel,
    kv: &mut [f32],
    bucket: usize,
    tokens: &[i32],
    feats: &[f32],
    pos: &[i32],
    mask: &[f32],
    kv_len: usize,
    write_pos: usize,
) -> (Vec<f32>, Vec<f32>) {
    let cfg = &model.cfg;
    let dw = model.draft.as_ref().expect("draft weights");
    let t = tokens.len();
    let h = cfg.d_model;
    let dims = KvDims { l: 1, h: cfg.n_head, b: bucket, d: cfg.d_head };
    let f = matmul(feats, &dw.fuse, t, 3 * h, h);
    let mut cat = vec![0f32; t * 2 * h];
    for (i, &tok) in tokens.iter().enumerate() {
        let row = (tok.max(0) as usize).min(cfg.vocab - 1);
        cat[i * 2 * h..i * 2 * h + h]
            .copy_from_slice(&model.target.embed[row * h..(row + 1) * h]);
        cat[i * 2 * h + h..(i + 1) * 2 * h].copy_from_slice(&f[i * h..(i + 1) * h]);
    }
    let mut x = matmul(&cat, &dw.inp, t, 2 * h, h);
    layer_fwd(
        &dw.layer, cfg, &mut x, pos, kv, dims, 0, kv_len, write_pos, mask, &model.inv_freq,
        model.mscale,
    );
    let hidden = x.clone();
    let xf = rmsnorm(&x, &dw.ln_f, t, h);
    let logits = matmul(&xf, &model.target.head, t, h, cfg.vocab);
    (logits, hidden)
}

// ---------------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------------

pub struct ReferenceBackend {
    consts: Consts,
    models: BTreeMap<String, RefModel>,
    counters: RefCell<Counters>,
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ReferenceBackend {
    pub fn new() -> ReferenceBackend {
        let vocab = crate::tokenizer::VOCAB;
        let mk = |l, h, nh, d, ff| RefCfg {
            n_layer: l,
            d_model: h,
            n_head: nh,
            d_head: d,
            d_ff: ff,
            vocab,
            rope_theta: 10000.0,
            train_ctx: 128,
        };
        let mut models = BTreeMap::new();
        models.insert("s".to_string(), init_model("s", mk(4, 32, 2, 16, 64), true));
        models.insert("m".to_string(), init_model("m", mk(6, 48, 3, 16, 96), true));
        models.insert("l".to_string(), init_model("l", mk(8, 64, 4, 16, 128), true));
        models.insert("tiny".to_string(), init_model("tiny", mk(2, 16, 2, 8, 32), false));
        let consts = Consts {
            chunk: CHUNK,
            tree_t: TREE_T,
            refresh_t: REFRESH_T,
            big_refresh_t: BIG_REFRESH_T,
            qrows: QROWS,
            draft_w: DRAFT_W,
            draft_region: DRAFT_REGION,
            block: BLOCK,
            prev_max_: PREV_MAX,
            prev_window_: PREV_WINDOW,
            vocab,
            full_buckets: FULL_BUCKETS.to_vec(),
            partial_buckets: PARTIAL_BUCKETS.to_vec(),
            tiny_bucket: TINY_BUCKET,
        };
        ReferenceBackend { consts, models, counters: RefCell::new(Counters::default()) }
    }

    fn model_of(&self, size: &str) -> Result<&RefModel> {
        self.models
            .get(size)
            .ok_or_else(|| anyhow!("reference backend has no model size '{size}'"))
    }

    fn count(&self, label: &str, t0: Instant) {
        let dt = t0.elapsed().as_secs_f64();
        let mut c = self.counters.borrow_mut();
        c.executions += 1;
        c.exec_secs += dt;
        let e = c.per_exec.entry(label.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dt;
    }

    /// Shared body of prefill / verify_full / verify_partial.
    fn verify_like(&self, op: &VerifyOp, mut state: StateBuf, partial: bool) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let lay = if partial {
            partial_layout(cfg, op.bucket)
        } else {
            full_layout(cfg, op.bucket)
        };
        let rows = if partial { TREE_T } else { CHUNK };
        if op.t > rows {
            bail!("verify t={} exceeds the {}-row state region", op.t, rows);
        }
        if op.tokens.len() != op.t || op.pos.len() != op.t || op.mask.len() != op.t * op.t {
            bail!("verify op geometry mismatch (t={})", op.t);
        }
        let buf = state.downcast_mut::<Vec<f32>>()?;
        if buf.len() != lay.total {
            bail!("state length {} != layout total {}", buf.len(), lay.total);
        }
        let dims =
            KvDims { l: cfg.n_layer, h: cfg.n_head, b: op.bucket, d: cfg.d_head };
        compact_window(&mut buf[..lay.kv], dims, op.kv_len, op.prev_idx, op.n_prev, PREV_WINDOW);
        let eff = op.kv_len + op.n_prev;
        let out = target_fwd(
            model,
            &mut buf[..lay.kv],
            op.bucket,
            op.tokens,
            op.pos,
            op.mask,
            eff,
            eff,
            !partial,
        );
        // pack: zero-padded logits/feats rows (+ queries for full states)
        let (v, h3) = (cfg.vocab, 3 * cfg.d_model);
        let lg = &mut buf[lay.off_logits()..lay.off_logits() + lay.logits];
        lg.fill(0.0);
        lg[..op.t * v].copy_from_slice(&out.logits);
        let fs = &mut buf[lay.off_feats()..lay.off_feats() + lay.feats];
        fs.fill(0.0);
        if !out.feats.is_empty() {
            fs[..op.t * h3].copy_from_slice(&out.feats);
        }
        if !partial {
            let d = cfg.d_head;
            let qr = &mut buf[lay.off_queries()..lay.off_queries() + lay.queries];
            qr.fill(0.0);
            let keep = op.t.min(QROWS);
            for (l, q) in out.queries.iter().enumerate() {
                for hh in 0..cfg.n_head {
                    for i in 0..keep {
                        let dst = ((l * cfg.n_head + hh) * QROWS + i) * d;
                        let src = (hh * op.t + i) * d;
                        qr[dst..dst + d].copy_from_slice(&q[src..src + d]);
                    }
                }
            }
        }
        let fam = if partial { "pverify" } else { "verify" };
        self.count(&format!("{fam}_{}_b{}_t{}", op.size, op.bucket, op.t), t0);
        Ok(state)
    }
}

impl super::Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn consts(&self) -> &Consts {
        &self.consts
    }

    fn model(&self, size: &str) -> Result<ModelInfo> {
        let m = self.model_of(size)?;
        Ok(ModelInfo {
            n_layer: m.cfg.n_layer,
            d_model: m.cfg.d_model,
            n_head: m.cfg.n_head,
            d_head: m.cfg.d_head,
            d_ff: m.cfg.d_ff,
            vocab: m.cfg.vocab,
            weights_file: format!("builtin://{size}"),
            yarn_factor: YARN_FACTOR,
        })
    }

    fn sizes(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    fn full_buckets(&self, size: &str) -> Vec<usize> {
        if self.models.contains_key(size) {
            FULL_BUCKETS.to_vec()
        } else {
            Vec::new()
        }
    }

    fn partial_buckets(&self, size: &str) -> Vec<usize> {
        if self.models.contains_key(size) {
            PARTIAL_BUCKETS.to_vec()
        } else {
            Vec::new()
        }
    }

    fn refresh_widths(&self, size: &str, _bucket: usize) -> Vec<usize> {
        if self.models.contains_key(size) {
            vec![REFRESH_T, BIG_REFRESH_T]
        } else {
            Vec::new()
        }
    }

    fn state_layout(&self, kind: StateKind, size: &str, bucket: usize) -> Result<StateLayout> {
        let cfg = &self.model_of(size)?.cfg;
        Ok(match kind {
            StateKind::Full => full_layout(cfg, bucket),
            StateKind::Partial => partial_layout(cfg, bucket),
            StateKind::Draft => draft_layout(cfg, bucket),
            StateKind::Tiny => tiny_layout(cfg, bucket),
        })
    }

    fn alloc_state(&self, kind: StateKind, size: &str, bucket: usize) -> Result<StateBuf> {
        let lay = self.state_layout(kind, size, bucket)?;
        Ok(StateBuf::new(vec![0f32; lay.total]))
    }

    fn prefill(&self, op: &PrefillOp, state: StateBuf) -> Result<StateBuf> {
        let zero_prev = [0i32; PREV_MAX];
        self.verify_like(
            &VerifyOp {
                size: op.size,
                bucket: op.bucket,
                t: CHUNK,
                tokens: op.tokens,
                pos: op.pos,
                mask: op.mask,
                kv_len: op.kv_len,
                prev_idx: &zero_prev,
                n_prev: 0,
            },
            state,
            false,
        )
    }

    fn verify_full(&self, op: &VerifyOp, state: StateBuf) -> Result<StateBuf> {
        self.verify_like(op, state, false)
    }

    fn verify_partial(&self, op: &VerifyOp, state: StateBuf) -> Result<StateBuf> {
        self.verify_like(op, state, true)
    }

    fn commit(&self, op: &CommitOp, mut state: StateBuf) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let lay = full_layout(cfg, op.bucket);
        let buf = state.downcast_mut::<Vec<f32>>()?;
        let dims = KvDims { l: cfg.n_layer, h: cfg.n_head, b: op.bucket, d: cfg.d_head };
        compact_window(&mut buf[..lay.kv], dims, op.kv_len, op.idx, op.n, op.window);
        self.count(&format!("commit_{}_b{}_w{}", op.size, op.bucket, op.window), t0);
        Ok(state)
    }

    fn score(&self, op: &ScoreOp, state: &StateBuf) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let lay = full_layout(cfg, op.bucket);
        let buf = state.downcast_ref::<Vec<f32>>()?;
        let dims = KvDims { l: cfg.n_layer, h: cfg.n_head, b: op.bucket, d: cfg.d_head };
        let nb = op.bucket / BLOCK;
        let d = cfg.d_head;
        let mut out = vec![0f32; cfg.n_layer * 3 * nb];
        for layer in 0..cfg.n_layer {
            // s[t][blk]: Quest block scores summed over heads
            let mut s = vec![0f32; QROWS * nb];
            let mut any_valid = vec![false; nb];
            for hh in 0..cfg.n_head {
                for (blk, valid) in any_valid.iter_mut().enumerate() {
                    let b0 = blk * BLOCK;
                    let mut kmax = vec![f32::NEG_INFINITY; d];
                    let mut kmin = vec![f32::INFINITY; d];
                    let mut any = false;
                    for r in b0..(b0 + BLOCK).min(op.kv_len.min(op.bucket)) {
                        any = true;
                        let kr = &buf[dims.row(layer, 0, hh, r)..dims.row(layer, 0, hh, r) + d];
                        for dd in 0..d {
                            kmax[dd] = kmax[dd].max(kr[dd]);
                            kmin[dd] = kmin[dd].min(kr[dd]);
                        }
                    }
                    if !any {
                        kmax.fill(0.0);
                        kmin.fill(0.0);
                    } else {
                        *valid = true;
                    }
                    let qbase = lay.off_queries() + (layer * cfg.n_head + hh) * QROWS * d;
                    for t in 0..QROWS {
                        let qr = &buf[qbase + t * d..qbase + (t + 1) * d];
                        s[t * nb + blk] += dot(qr, &kmax).max(dot(qr, &kmin));
                    }
                }
            }
            let n = op.n_queries.clamp(1, QROWS);
            for blk in 0..nb {
                let (mean, max, last) = if any_valid[blk] {
                    let mut sum = 0f32;
                    let mut mx = f32::NEG_INFINITY;
                    for t in 0..n {
                        sum += s[t * nb + blk];
                        mx = mx.max(s[t * nb + blk]);
                    }
                    (sum / n as f32, mx, s[(n - 1) * nb + blk])
                } else {
                    (NEG_INF, NEG_INF, NEG_INF)
                };
                out[layer * 3 * nb + blk] = mean;
                out[layer * 3 * nb + nb + blk] = max;
                out[layer * 3 * nb + 2 * nb + blk] = last;
            }
        }
        self.counters.borrow_mut().download_bytes += (out.len() * 4) as u64;
        self.count(&format!("score_{}_b{}", op.size, op.bucket), t0);
        Ok(out)
    }

    fn refresh_gather(&self, op: &GatherOp, state: &StateBuf) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let play = partial_layout(cfg, op.p_bucket);
        let nsel = op.p_bucket / BLOCK;
        if op.block_idx.len() != cfg.n_layer * nsel {
            bail!(
                "gather wants {} block ids, got {}",
                cfg.n_layer * nsel,
                op.block_idx.len()
            );
        }
        let buf = state.downcast_ref::<Vec<f32>>()?;
        let src = KvDims { l: cfg.n_layer, h: cfg.n_head, b: op.bucket, d: cfg.d_head };
        let dst = KvDims { l: cfg.n_layer, h: cfg.n_head, b: op.p_bucket, d: cfg.d_head };
        let nb = op.bucket / BLOCK;
        let d = cfg.d_head;
        let mut out = vec![0f32; play.total];
        for layer in 0..cfg.n_layer {
            for (sel, &blk) in op.block_idx[layer * nsel..(layer + 1) * nsel].iter().enumerate() {
                let blk = (blk.max(0) as usize).min(nb - 1);
                for plane in 0..2 {
                    for hh in 0..cfg.n_head {
                        for r in 0..BLOCK {
                            let s = src.row(layer, plane, hh, blk * BLOCK + r);
                            let t = dst.row(layer, plane, hh, sel * BLOCK + r);
                            out[t..t + d].copy_from_slice(&buf[s..s + d]);
                        }
                    }
                }
            }
        }
        self.count(&format!("gather_{}_b{}_p{}", op.size, op.bucket, op.p_bucket), t0);
        Ok(StateBuf::new(out))
    }

    fn draft_prefill(
        &self,
        op: &DraftPrefillOp,
        target_state: &StateBuf,
        mut draft_state: StateBuf,
    ) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let flay = full_layout(cfg, op.bucket);
        let dlay = draft_layout(cfg, op.bucket);
        if op.tokens.len() != CHUNK {
            bail!("draft prefill wants {CHUNK} tokens");
        }
        let tbuf = target_state.downcast_ref::<Vec<f32>>()?;
        let feats = &tbuf[flay.off_feats()..flay.off_feats() + CHUNK * 3 * cfg.d_model];
        let dbuf = draft_state.downcast_mut::<Vec<f32>>()?;
        // draft prefill does not emit logits (aot parity): the logits
        // region is zeroed and only the chunk's hidden rows are kept
        let (_logits, hidden) = {
            let kv = &mut dbuf[..dlay.kv];
            draft_fwd(
                model, kv, op.bucket, op.tokens, feats, op.pos, op.mask, op.kv_len,
                op.write_pos,
            )
        };
        dbuf[dlay.off_logits()..dlay.off_logits() + dlay.logits].fill(0.0);
        let hd = &mut dbuf[dlay.off_feats()..dlay.off_feats() + dlay.feats];
        hd.fill(0.0);
        hd[..CHUNK * cfg.d_model].copy_from_slice(&hidden);
        self.count(&format!("draft_prefill_{}_b{}", op.size, op.bucket), t0);
        Ok(draft_state)
    }

    fn draft_expand(&self, op: &DraftExpandOp, mut draft_state: StateBuf) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of(op.size)?;
        let cfg = &model.cfg;
        let dlay = draft_layout(cfg, op.bucket);
        if op.tokens.len() != DRAFT_W || op.mask.len() != DRAFT_W * DRAFT_REGION {
            bail!("draft expand wants W={DRAFT_W} tokens and a [W, region] mask");
        }
        let dbuf = draft_state.downcast_mut::<Vec<f32>>()?;
        let (logits, hidden) = {
            let kv = &mut dbuf[..dlay.kv];
            draft_fwd(
                model, kv, op.bucket, op.tokens, op.feats, op.pos, op.mask, op.kv_len,
                op.write_pos,
            )
        };
        dbuf[dlay.off_logits()..dlay.off_logits() + dlay.logits].copy_from_slice(&logits);
        let hd = &mut dbuf[dlay.off_feats()..dlay.off_feats() + dlay.feats];
        hd.fill(0.0);
        hd[..DRAFT_W * cfg.d_model].copy_from_slice(&hidden);
        self.count(&format!("draft_step_{}_b{}", op.size, op.bucket), t0);
        Ok(draft_state)
    }

    fn medusa(&self, size: &str, feat: &[f32]) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let model = self.model_of(size)?;
        let cfg = &model.cfg;
        let mw = model
            .medusa
            .as_ref()
            .ok_or_else(|| anyhow!("model '{size}' has no medusa heads"))?;
        if feat.len() != cfg.d_model {
            bail!("medusa feat wants d_model={}", cfg.d_model);
        }
        let h = cfg.d_model;
        let mut out = Vec::with_capacity(3 * cfg.vocab);
        for (w1, w2) in &mw.heads {
            let mut hid = matmul(feat, w1, 1, h, h);
            for (x, &f) in hid.iter_mut().zip(feat) {
                *x = silu(*x) + f;
            }
            out.extend(matmul(&hid, w2, 1, h, cfg.vocab));
        }
        self.count(&format!("medusa_{size}"), t0);
        Ok(out)
    }

    fn tiny_forward(&self, op: &TinyForwardOp, mut state: StateBuf) -> Result<StateBuf> {
        let t0 = Instant::now();
        let model = self.model_of("tiny")?;
        let cfg = &model.cfg;
        let lay = tiny_layout(cfg, TINY_BUCKET);
        if op.tokens.len() != op.t || op.mask.len() != op.t * op.t {
            bail!("tiny op geometry mismatch (t={})", op.t);
        }
        let buf = state.downcast_mut::<Vec<f32>>()?;
        let out = {
            let kv = &mut buf[..lay.kv];
            target_fwd(
                model, kv, TINY_BUCKET, op.tokens, op.pos, op.mask, op.kv_len,
                op.write_pos, false,
            )
        };
        let v = cfg.vocab;
        let row = op.last_idx.min(op.t - 1);
        buf[lay.kv..lay.kv + v].copy_from_slice(&out.logits[row * v..(row + 1) * v]);
        self.count(&format!("verify_tiny_b{TINY_BUCKET}_t{}", op.t), t0);
        Ok(state)
    }

    fn read_logits(&self, op: &ReadOp, state: &StateBuf) -> Result<Vec<f32>> {
        let buf = state.downcast_ref::<Vec<f32>>()?;
        let out = match *op {
            ReadOp::FullWindow { size, bucket, start } => {
                let cfg = &self.model_of(size)?.cfg;
                let lay = full_layout(cfg, bucket);
                let (v, h3) = (cfg.vocab, 3 * cfg.d_model);
                let start = start.min(CHUNK - QROWS);
                let mut out = Vec::with_capacity(QROWS * (v + h3));
                out.extend_from_slice(
                    &buf[lay.off_logits() + start * v..lay.off_logits() + (start + QROWS) * v],
                );
                out.extend_from_slice(
                    &buf[lay.off_feats() + start * h3..lay.off_feats() + (start + QROWS) * h3],
                );
                out
            }
            ReadOp::LastRow { size, bucket, idx } => {
                let cfg = &self.model_of(size)?.cfg;
                let lay = full_layout(cfg, bucket);
                let (v, h3) = (cfg.vocab, 3 * cfg.d_model);
                let idx = idx.min(CHUNK - 1);
                let mut out = Vec::with_capacity(v + h3);
                out.extend_from_slice(
                    &buf[lay.off_logits() + idx * v..lay.off_logits() + (idx + 1) * v],
                );
                out.extend_from_slice(
                    &buf[lay.off_feats() + idx * h3..lay.off_feats() + (idx + 1) * h3],
                );
                out
            }
            ReadOp::Partial { size, bucket } => {
                let cfg = &self.model_of(size)?.cfg;
                let lay = partial_layout(cfg, bucket);
                buf[lay.off_logits()..lay.total].to_vec()
            }
            ReadOp::Draft { size, bucket } => {
                let cfg = &self.model_of(size)?.cfg;
                let lay = draft_layout(cfg, bucket);
                let mut out = Vec::with_capacity(lay.logits + DRAFT_W * cfg.d_model);
                out.extend_from_slice(&buf[lay.off_logits()..lay.off_logits() + lay.logits]);
                out.extend_from_slice(
                    &buf[lay.off_feats()..lay.off_feats() + DRAFT_W * cfg.d_model],
                );
                out
            }
            ReadOp::DraftHiddenRow { size, bucket, idx } => {
                let cfg = &self.model_of(size)?.cfg;
                let lay = draft_layout(cfg, bucket);
                let h = cfg.d_model;
                let idx = idx.min(CHUNK - 1);
                buf[lay.off_feats() + idx * h..lay.off_feats() + (idx + 1) * h].to_vec()
            }
            ReadOp::Tiny => {
                let cfg = &self.model_of("tiny")?.cfg;
                let lay = tiny_layout(cfg, TINY_BUCKET);
                buf[lay.kv..lay.kv + cfg.vocab].to_vec()
            }
        };
        self.counters.borrow_mut().download_bytes += (out.len() * 4) as u64;
        Ok(out)
    }

    fn counters(&self) -> Counters {
        self.counters.borrow().clone()
    }

    fn describe(&self) -> String {
        format!(
            "reference backend (pure rust, deterministic seeded weights): \
             models {:?}, full buckets {:?}, partial buckets {:?}",
            self.models.keys().collect::<Vec<_>>(),
            FULL_BUCKETS,
            PARTIAL_BUCKETS
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::Backend;
    use super::*;

    fn be() -> ReferenceBackend {
        ReferenceBackend::new()
    }

    #[test]
    fn catalog_is_consistent() {
        let b = be();
        let info = b.model("s").unwrap();
        assert_eq!(info.vocab, crate::tokenizer::VOCAB);
        assert_eq!(b.full_buckets("s"), FULL_BUCKETS.to_vec());
        assert!(b.model("xl").is_err());
        let lay = b.state_layout(StateKind::Full, "s", 288).unwrap();
        assert_eq!(
            lay.total,
            lay.kv + lay.logits + lay.feats + lay.queries
        );
    }

    #[test]
    fn weights_are_deterministic() {
        let a = init_model("s", be().models["s"].cfg.clone(), true);
        let b = init_model("s", be().models["s"].cfg.clone(), true);
        assert_eq!(a.target.embed, b.target.embed);
        assert_eq!(a.target.layers[2].wq, b.target.layers[2].wq);
        assert_eq!(a.draft.unwrap().fuse, b.draft.unwrap().fuse);
    }

    #[test]
    fn verify_is_deterministic_and_shapes_hold() {
        let b = be();
        let run = || -> Vec<f32> {
            let st = b.alloc_state(StateKind::Full, "s", 128).unwrap();
            let t = TREE_T;
            let tokens: Vec<i32> = (0..t as i32).map(|i| 65 + i).collect();
            let pos: Vec<i32> = (0..t as i32).collect();
            let mask = crate::tree::chain_mask(t, t);
            let zero = [0i32; PREV_MAX];
            let op = VerifyOp {
                size: "s",
                bucket: 128,
                t,
                tokens: &tokens,
                pos: &pos,
                mask: &mask,
                kv_len: 0,
                prev_idx: &zero,
                n_prev: 0,
            };
            let st = b.verify_full(&op, st).unwrap();
            b.read_logits(&ReadOp::FullWindow { size: "s", bucket: 128, start: 0 }, &st)
                .unwrap()
        };
        let x = run();
        let y = run();
        assert_eq!(x, y, "reference forward must be bit-deterministic");
        let info = b.model("s").unwrap();
        assert_eq!(x.len(), QROWS * (info.vocab + 3 * info.d_model));
        assert!(x.iter().all(|v| v.is_finite()));
        // rows 0..T hold real logits, later rows are zero padding
        assert!(x[..info.vocab].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn chain_verify_matches_stepwise_decode() {
        // processing [a, b] in one chain call must equal processing a then
        // b in two T=1 calls — the losslessness property spec engines rely
        // on (same rows visible, same write positions).
        let b = be();
        let zero = [0i32; PREV_MAX];
        // one-shot: chain of 2
        let st = b.alloc_state(StateKind::Full, "s", 128).unwrap();
        let mask2 = crate::tree::chain_mask(2, 2);
        let st = b
            .verify_full(
                &VerifyOp {
                    size: "s",
                    bucket: 128,
                    t: 2,
                    tokens: &[72, 105],
                    pos: &[0, 1],
                    mask: &mask2,
                    kv_len: 0,
                    prev_idx: &zero,
                    n_prev: 0,
                },
                st,
            )
            .unwrap();
        let chain =
            b.read_logits(&ReadOp::LastRow { size: "s", bucket: 128, idx: 1 }, &st).unwrap();
        // stepwise: two T=1 calls
        let st = b.alloc_state(StateKind::Full, "s", 128).unwrap();
        let one = |st, tok: i32, pos: i32, kv_len: usize| {
            b.verify_full(
                &VerifyOp {
                    size: "s",
                    bucket: 128,
                    t: 1,
                    tokens: &[tok],
                    pos: &[pos],
                    mask: &[1.0],
                    kv_len,
                    prev_idx: &zero,
                    n_prev: 0,
                },
                st,
            )
            .unwrap()
        };
        let st = one(st, 72, 0, 0);
        let st = one(st, 105, 1, 1);
        let step =
            b.read_logits(&ReadOp::LastRow { size: "s", bucket: 128, idx: 0 }, &st).unwrap();
        let v = b.model("s").unwrap().vocab;
        for (i, (a, bb)) in chain[..v].iter().zip(&step[..v]).enumerate() {
            assert!((a - bb).abs() < 1e-5, "logit {i}: {a} vs {bb}");
        }
    }

    #[test]
    fn compact_window_moves_accepted_rows() {
        let dims = KvDims { l: 1, h: 1, b: 32, d: 2 };
        let mut kv: Vec<f32> = (0..dims.l * 2 * dims.h * dims.b * dims.d)
            .map(|i| i as f32)
            .collect();
        let before_row6 = kv[dims.row(0, 0, 0, 10 + 6)..dims.row(0, 0, 0, 10 + 6) + 2].to_vec();
        // kv_len 10, accepted window rows [2, 6] → rows 12, 16 move to 10, 11
        compact_window(&mut kv, dims, 10, &[2, 6, 0, 0], 2, PREV_WINDOW);
        let r10 = &kv[dims.row(0, 0, 0, 10)..dims.row(0, 0, 0, 10) + 2];
        assert_eq!(r10, &[(12 * 2) as f32, (12 * 2 + 1) as f32][..]);
        let r11 = &kv[dims.row(0, 0, 0, 11)..dims.row(0, 0, 0, 11) + 2];
        assert_eq!(r11, &before_row6[..]);
    }

    #[test]
    fn medusa_and_tiny_shapes() {
        let b = be();
        let info = b.model("s").unwrap();
        let heads = b.medusa("s", &vec![0.1; info.d_model]).unwrap();
        assert_eq!(heads.len(), 3 * info.vocab);
        let st = b.alloc_state(StateKind::Tiny, "tiny", TINY_BUCKET).unwrap();
        let st = b
            .tiny_forward(
                &TinyForwardOp {
                    t: 1,
                    tokens: &[65],
                    pos: &[0],
                    mask: &[1.0],
                    kv_len: 0,
                    write_pos: 0,
                    last_idx: 0,
                },
                st,
            )
            .unwrap();
        let lg = b.read_logits(&ReadOp::Tiny, &st).unwrap();
        assert_eq!(lg.len(), b.model("tiny").unwrap().vocab);
        assert!(b.counters().executions >= 2);
    }
}
