//! PJRT implementation of the [`Backend`](super::Backend) trait: the AOT
//! artifact player. This is the **only** place that maps the typed
//! kernel-op API to manifest executable names — engines never format an
//! executable name again. The low-level compile/upload/execute machinery
//! stays in [`crate::runtime`].
//!
//! The batched kernel ops (`*_batch`, DESIGN.md §12) use the trait's
//! default sequential loop: every AOT executable is compiled for a
//! single sequence, so a fused cross-session invocation has no artifact
//! to run — the coordinator's grouping still works, it just degrades to
//! per-session execution (and the scheduler's occupancy metrics report
//! the fallback).

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::PjRtBuffer;

use crate::manifest::{Consts, Manifest, ModelInfo, StateLayout};
use crate::model;
use crate::runtime::{Arg, Runtime};

use super::{
    CommitOp, Counters, DraftExpandOp, DraftPrefillOp, GatherOp, PrefillOp, ReadOp, ScoreOp,
    StateBuf, StateKind, TinyForwardOp, VerifyOp,
};

pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    /// Backend over an artifacts directory (`manifest.json`, `*.hlo.txt`,
    /// weights binaries).
    pub fn new(artifacts_dir: &Path) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: Runtime::new(artifacts_dir)? })
    }

    pub fn from_runtime(rt: Runtime) -> PjrtBackend {
        PjrtBackend { rt }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.rt.manifest
    }

    fn buckets_of_family(&self, family: &str, size: &str) -> Vec<usize> {
        let mut buckets: Vec<usize> = self
            .rt
            .manifest
            .executables
            .values()
            .filter(|e| e.family == family && e.size == size)
            .map(|e| e.bucket)
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        buckets
    }

    /// Shared verify-ABI invocation (prefill / verify_full / verify_partial
    /// all compile the same graph; only the name family differs).
    fn verify_like(&self, name: &str, op: &VerifyOp, state: StateBuf) -> Result<StateBuf> {
        let buf: PjRtBuffer = state.downcast()?;
        let out = self.rt.invoke(
            name,
            &[
                Arg::I32(op.tokens),
                Arg::I32(op.pos),
                Arg::F32(op.mask),
                Arg::Buf(&buf),
                Arg::Scalar(op.kv_len as i32),
                Arg::I32(op.prev_idx),
                Arg::Scalar(op.n_prev as i32),
            ],
        )?;
        Ok(StateBuf::new(out))
    }
}

impl super::Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn consts(&self) -> &Consts {
        &self.rt.manifest.consts
    }

    fn model(&self, size: &str) -> Result<ModelInfo> {
        Ok(self.rt.manifest.model(size)?.clone())
    }

    fn sizes(&self) -> Vec<String> {
        self.rt.manifest.models.keys().cloned().collect()
    }

    fn full_buckets(&self, size: &str) -> Vec<usize> {
        self.buckets_of_family("verify", size)
    }

    fn partial_buckets(&self, size: &str) -> Vec<usize> {
        self.buckets_of_family("pverify", size)
    }

    fn refresh_widths(&self, size: &str, bucket: usize) -> Vec<usize> {
        let c = self.consts();
        let mut widths: Vec<usize> = [c.refresh_t, c.big_refresh_t]
            .into_iter()
            .filter(|&w| {
                self.rt
                    .manifest
                    .executables
                    .contains_key(&model::verify_name(size, bucket, w))
            })
            .collect();
        widths.sort_unstable();
        widths.dedup();
        widths
    }

    fn state_layout(&self, kind: StateKind, size: &str, bucket: usize) -> Result<StateLayout> {
        let name = match kind {
            StateKind::Full => model::verify_name(size, bucket, self.consts().tree_t),
            StateKind::Partial => model::pverify_name(size, bucket, self.consts().tree_t),
            StateKind::Draft => model::draft_step_name(size, bucket),
            StateKind::Tiny => format!("verify_tiny_b{bucket}_t1"),
        };
        self.rt
            .manifest
            .exec(&name)?
            .layout
            .with_context(|| format!("{name} missing state layout"))
    }

    fn alloc_state(&self, kind: StateKind, size: &str, bucket: usize) -> Result<StateBuf> {
        let layout = self.state_layout(kind, size, bucket)?;
        Ok(StateBuf::new(self.rt.zero_state(layout.total)?))
    }

    fn state_image_len(
        &self,
        kind: StateKind,
        size: &str,
        bucket: usize,
        state: &StateBuf,
    ) -> Result<(usize, usize)> {
        // pjrt states are one flat device buffer; there is no
        // backend-private extra section
        state.downcast_ref::<PjRtBuffer>()?;
        Ok((self.state_layout(kind, size, bucket)?.total, 0))
    }

    fn export_pages(
        &self,
        kind: StateKind,
        size: &str,
        bucket: usize,
        state: &StateBuf,
        pages: std::ops::Range<usize>,
        page_elems: usize,
    ) -> Result<Vec<Vec<f32>>> {
        // device→host readback over the flat-state ABI: PJRT exposes no
        // sub-buffer reads, so one download serves the whole requested
        // range (callers batch page ranges to amortize this)
        let buf = state.downcast_ref::<PjRtBuffer>()?;
        let data = self.rt.download_f32(buf)?;
        let layout = self.state_layout(kind, size, bucket)?;
        if data.len() != layout.total {
            bail!(
                "export: device buffer holds {} f32, {:?} {size} b{bucket} layout wants {}",
                data.len(),
                kind,
                layout.total
            );
        }
        let n = super::page_count(data.len(), page_elems);
        if pages.end > n {
            bail!("export_pages: range {pages:?} exceeds {n} pages of {} elems", data.len());
        }
        Ok(pages
            .map(|p| {
                let start = p * page_elems;
                data[start..(start + page_elems).min(data.len())].to_vec()
            })
            .collect())
    }

    fn import_pages(
        &self,
        kind: StateKind,
        size: &str,
        bucket: usize,
        data_len: usize,
        extra_len: usize,
        page_elems: usize,
        read_page: &mut dyn FnMut(usize, &mut Vec<f32>) -> Result<()>,
    ) -> Result<StateBuf> {
        if extra_len != 0 {
            bail!("pjrt states carry no extra rows (got {extra_len})");
        }
        let layout = self.state_layout(kind, size, bucket)?;
        if data_len != layout.total {
            bail!(
                "import: image holds {data_len} f32, {kind:?} {size} b{bucket} \
                 layout wants {}",
                layout.total
            );
        }
        // assemble the flat image host-side, then one upload
        let mut data = Vec::with_capacity(data_len);
        let mut scratch = Vec::new();
        for p in 0..super::page_count(data_len, page_elems) {
            read_page(p, &mut scratch)?;
            let want = page_elems.min(data_len - p * page_elems);
            if scratch.len() != want {
                bail!("import: page {p} holds {} f32, want {want}", scratch.len());
            }
            data.extend_from_slice(&scratch);
        }
        Ok(StateBuf::new(self.rt.upload_f32(&data, &[data.len()])?))
    }

    fn prefill(&self, op: &PrefillOp, state: StateBuf) -> Result<StateBuf> {
        let name = model::verify_name(op.size, op.bucket, self.consts().chunk);
        let zero_prev = vec![0i32; self.consts().prev_max()];
        self.verify_like(
            &name,
            &VerifyOp {
                size: op.size,
                bucket: op.bucket,
                t: self.consts().chunk,
                tokens: op.tokens,
                pos: op.pos,
                mask: op.mask,
                kv_len: op.kv_len,
                prev_idx: &zero_prev,
                n_prev: 0,
            },
            state,
        )
    }

    fn verify_full(&self, op: &VerifyOp, state: StateBuf) -> Result<StateBuf> {
        self.verify_like(&model::verify_name(op.size, op.bucket, op.t), op, state)
    }

    fn verify_partial(&self, op: &VerifyOp, state: StateBuf) -> Result<StateBuf> {
        self.verify_like(&model::pverify_name(op.size, op.bucket, op.t), op, state)
    }

    fn commit(&self, op: &CommitOp, state: StateBuf) -> Result<StateBuf> {
        let buf: PjRtBuffer = state.downcast()?;
        let out = self.rt.invoke(
            &model::commit_name(op.size, op.bucket, op.window),
            &[
                Arg::Buf(&buf),
                Arg::I32(op.idx),
                Arg::Scalar(op.n as i32),
                Arg::Scalar(op.kv_len as i32),
            ],
        )?;
        Ok(StateBuf::new(out))
    }

    fn score(&self, op: &ScoreOp, state: &StateBuf) -> Result<Vec<f32>> {
        let buf = state.downcast_ref::<PjRtBuffer>()?;
        self.rt.invoke_download(
            &model::score_name(op.size, op.bucket),
            &[
                Arg::Buf(buf),
                Arg::Scalar(op.kv_len as i32),
                Arg::Scalar(op.n_queries as i32),
            ],
        )
    }

    fn refresh_gather(&self, op: &GatherOp, state: &StateBuf) -> Result<StateBuf> {
        let buf = state.downcast_ref::<PjRtBuffer>()?;
        let out = self.rt.invoke(
            &model::gather_name(op.size, op.bucket, op.p_bucket),
            &[Arg::Buf(buf), Arg::I32(op.block_idx)],
        )?;
        Ok(StateBuf::new(out))
    }

    fn draft_prefill(
        &self,
        op: &DraftPrefillOp,
        target_state: &StateBuf,
        draft_state: StateBuf,
    ) -> Result<StateBuf> {
        let tbuf = target_state.downcast_ref::<PjRtBuffer>()?;
        let dbuf: PjRtBuffer = draft_state.downcast()?;
        let out = self.rt.invoke(
            &model::draft_prefill_name(op.size, op.bucket),
            &[
                Arg::I32(op.tokens),
                Arg::Buf(tbuf),
                Arg::I32(op.pos),
                Arg::F32(op.mask),
                Arg::Buf(&dbuf),
                Arg::Scalar(op.kv_len as i32),
                Arg::Scalar(op.write_pos as i32),
            ],
        )?;
        Ok(StateBuf::new(out))
    }

    fn draft_expand(&self, op: &DraftExpandOp, draft_state: StateBuf) -> Result<StateBuf> {
        let dbuf: PjRtBuffer = draft_state.downcast()?;
        let out = self.rt.invoke(
            &model::draft_step_name(op.size, op.bucket),
            &[
                Arg::I32(op.tokens),
                Arg::F32(op.feats),
                Arg::I32(op.pos),
                Arg::F32(op.mask),
                Arg::Buf(&dbuf),
                Arg::Scalar(op.kv_len as i32),
                Arg::Scalar(op.write_pos as i32),
            ],
        )?;
        Ok(StateBuf::new(out))
    }

    fn medusa(&self, size: &str, feat: &[f32]) -> Result<Vec<f32>> {
        self.rt
            .invoke_download(&model::medusa_name(size), &[Arg::F32(feat)])
    }

    fn tiny_forward(&self, op: &TinyForwardOp, state: StateBuf) -> Result<StateBuf> {
        let buf: PjRtBuffer = state.downcast()?;
        let name = format!("verify_tiny_b{}_t{}", self.consts().tiny_bucket, op.t);
        let out = self.rt.invoke(
            &name,
            &[
                Arg::I32(op.tokens),
                Arg::I32(op.pos),
                Arg::F32(op.mask),
                Arg::Buf(&buf),
                Arg::Scalar(op.kv_len as i32),
                Arg::Scalar(op.write_pos as i32),
                Arg::Scalar(op.last_idx as i32),
            ],
        )?;
        Ok(StateBuf::new(out))
    }

    fn read_logits(&self, op: &ReadOp, state: &StateBuf) -> Result<Vec<f32>> {
        let buf = state.downcast_ref::<PjRtBuffer>()?;
        match *op {
            ReadOp::FullWindow { size, bucket, start } => self.rt.invoke_download(
                &model::read_full_name(size, bucket),
                &[Arg::Buf(buf), Arg::Scalar(start as i32)],
            ),
            ReadOp::LastRow { size, bucket, idx } => self.rt.invoke_download(
                &model::read_last_name(size, bucket),
                &[Arg::Buf(buf), Arg::Scalar(idx as i32)],
            ),
            ReadOp::Partial { size, bucket } => self
                .rt
                .invoke_download(&model::read_partial_name(size, bucket), &[Arg::Buf(buf)]),
            ReadOp::Draft { size, bucket } => self
                .rt
                .invoke_download(&model::read_draft_name(size, bucket), &[Arg::Buf(buf)]),
            ReadOp::DraftHiddenRow { size, bucket, idx } => self.rt.invoke_download(
                &format!("read_draft_row_{size}_b{bucket}"),
                &[Arg::Buf(buf), Arg::Scalar(idx as i32)],
            ),
            ReadOp::Tiny => self.rt.invoke_download(
                &format!("read_tiny_b{}", self.consts().tiny_bucket),
                &[Arg::Buf(buf)],
            ),
        }
    }

    fn counters(&self) -> Counters {
        self.rt.counters.borrow().clone()
    }

    fn describe(&self) -> String {
        let m = &self.rt.manifest;
        format!(
            "pjrt backend over {:?}: {} executables, models {:?}",
            m.dir,
            m.executables.len(),
            m.models.keys().collect::<Vec<_>>()
        )
    }
}
