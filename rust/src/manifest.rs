//! Parser for `artifacts/manifest.json` (written by `python/compile/aot.py`).
//! The rust runtime is entirely manifest-driven: argument order (including
//! the exact weight-tensor order), shapes, state layouts, and the static
//! attributes (bucket, T, family) all come from here.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::json::Json;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Is this argument a weight tensor (vs a per-call input)?
    pub fn is_weight(&self) -> bool {
        self.name.starts_with("t.")
            || self.name.starts_with("d.")
            || self.name.starts_with("md.")
    }
}

/// Flat-state region layout, in f32 element counts (see aot.py docstring).
#[derive(Debug, Clone, Copy, Default)]
pub struct StateLayout {
    pub kv: usize,
    pub logits: usize,
    pub feats: usize,
    pub queries: usize,
    pub total: usize,
}

impl StateLayout {
    pub fn off_logits(&self) -> usize {
        self.kv
    }

    pub fn off_feats(&self) -> usize {
        self.kv + self.logits
    }

    pub fn off_queries(&self) -> usize {
        self.kv + self.logits + self.feats
    }
}

#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub layout: Option<StateLayout>,
    pub family: String,
    pub size: String,
    pub bucket: usize,
    pub t: usize,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub weights_file: String,
    pub yarn_factor: f64,
}

/// Global constants shared between aot.py and the coordinator.
#[derive(Debug, Clone)]
pub struct Consts {
    pub chunk: usize,
    pub tree_t: usize,
    pub refresh_t: usize,
    pub big_refresh_t: usize,
    pub qrows: usize,
    pub draft_w: usize,
    pub draft_region: usize,
    pub block: usize,
    pub prev_max_: usize,
    pub prev_window_: usize,
    pub vocab: usize,
    pub full_buckets: Vec<usize>,
    pub partial_buckets: Vec<usize>,
    pub tiny_bucket: usize,
}

impl Consts {
    /// Max accepted rows the fused verify compaction can absorb.
    pub fn prev_max(&self) -> usize {
        self.prev_max_
    }

    /// Window the fused compaction gathers from (== tree_t).
    pub fn prev_window(&self) -> usize {
        self.prev_window_
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub executables: BTreeMap<String, ExecSpec>,
    pub models: BTreeMap<String, ModelInfo>,
    pub consts: Consts,
}

fn req_usize(j: &Json, k: &str) -> Result<usize> {
    j.at(k)?
        .as_usize()
        .ok_or_else(|| anyhow!("'{k}' is not a number"))
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let cj = j.at("consts")?;
        let usizes = |k: &str| -> Result<Vec<usize>> {
            Ok(cj
                .at(k)?
                .as_arr()
                .ok_or_else(|| anyhow!("'{k}' not an array"))?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect())
        };
        let consts = Consts {
            chunk: req_usize(cj, "chunk")?,
            tree_t: req_usize(cj, "tree_t")?,
            refresh_t: req_usize(cj, "refresh_t")?,
            big_refresh_t: req_usize(cj, "big_refresh_t")?,
            qrows: req_usize(cj, "qrows")?,
            draft_w: req_usize(cj, "draft_w")?,
            draft_region: req_usize(cj, "draft_region")?,
            block: req_usize(cj, "block")?,
            prev_max_: req_usize(cj, "prev_max")?,
            prev_window_: req_usize(cj, "prev_window")?,
            vocab: req_usize(cj, "vocab")?,
            full_buckets: usizes("full_buckets")?,
            partial_buckets: usizes("partial_buckets")?,
            tiny_bucket: req_usize(cj, "tiny_bucket")?,
        };

        let mut models = BTreeMap::new();
        for (name, mj) in j
            .at("models")?
            .as_obj()
            .ok_or_else(|| anyhow!("models not an object"))?
        {
            models.insert(
                name.clone(),
                ModelInfo {
                    n_layer: req_usize(mj, "n_layer")?,
                    d_model: req_usize(mj, "d_model")?,
                    n_head: req_usize(mj, "n_head")?,
                    d_head: req_usize(mj, "d_head")?,
                    d_ff: req_usize(mj, "d_ff")?,
                    vocab: req_usize(mj, "vocab")?,
                    weights_file: mj
                        .at("weights")?
                        .as_str()
                        .ok_or_else(|| anyhow!("weights not a string"))?
                        .to_string(),
                    yarn_factor: mj
                        .at("yarn_factor")?
                        .as_f64()
                        .unwrap_or(1.0),
                },
            );
        }

        let mut executables = BTreeMap::new();
        for (name, ej) in j
            .at("executables")?
            .as_obj()
            .ok_or_else(|| anyhow!("executables not an object"))?
        {
            let mut args = Vec::new();
            for aj in ej
                .at("args")?
                .as_arr()
                .ok_or_else(|| anyhow!("args not an array"))?
            {
                let dtype = match aj.at("dtype")?.as_str() {
                    Some("float32") => DType::F32,
                    Some("int32") => DType::I32,
                    other => bail!("unsupported dtype {other:?}"),
                };
                args.push(ArgSpec {
                    name: aj
                        .at("name")?
                        .as_str()
                        .ok_or_else(|| anyhow!("arg name"))?
                        .to_string(),
                    shape: aj
                        .at("shape")?
                        .as_arr()
                        .ok_or_else(|| anyhow!("arg shape"))?
                        .iter()
                        .filter_map(|x| x.as_usize())
                        .collect(),
                    dtype,
                });
            }
            let layout = match ej.get("layout") {
                Some(Json::Obj(_)) => {
                    let lj = ej.at("layout")?;
                    Some(StateLayout {
                        kv: req_usize(lj, "kv")?,
                        logits: req_usize(lj, "logits")?,
                        feats: req_usize(lj, "feats")?,
                        queries: req_usize(lj, "queries")?,
                        total: req_usize(lj, "total")?,
                    })
                }
                _ => None,
            };
            let attrs = ej.at("attrs")?;
            executables.insert(
                name.clone(),
                ExecSpec {
                    name: name.clone(),
                    file: ej
                        .at("file")?
                        .as_str()
                        .ok_or_else(|| anyhow!("file"))?
                        .to_string(),
                    args,
                    layout,
                    family: attrs
                        .get("family")
                        .and_then(|x| x.as_str())
                        .unwrap_or("")
                        .to_string(),
                    size: attrs
                        .get("size")
                        .and_then(|x| x.as_str())
                        .unwrap_or("")
                        .to_string(),
                    bucket: attrs
                        .get("bucket")
                        .and_then(|x| x.as_usize())
                        .unwrap_or(0),
                    t: attrs.get("t").and_then(|x| x.as_usize()).unwrap_or(0),
                },
            );
        }

        Ok(Manifest { dir: dir.to_path_buf(), executables, models, consts })
    }

    pub fn exec(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("executable '{name}' not in manifest"))
    }

    pub fn model(&self, size: &str) -> Result<&ModelInfo> {
        self.models
            .get(size)
            .ok_or_else(|| anyhow!("model size '{size}' not in manifest"))
    }

    /// Smallest full bucket that can hold `len` tokens for `size`.
    pub fn pick_bucket(&self, size: &str, len: usize) -> Result<usize> {
        let mut buckets: Vec<usize> = self
            .executables
            .values()
            .filter(|e| e.family == "verify" && e.size == size)
            .map(|e| e.bucket)
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        buckets
            .into_iter()
            .find(|&b| b >= len)
            .ok_or_else(|| anyhow!("no bucket for size {size} len {len}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argspec_weight_detection() {
        let w = ArgSpec {
            name: "t.embed".into(),
            shape: vec![320, 128],
            dtype: DType::F32,
        };
        let a = ArgSpec { name: "tokens".into(), shape: vec![16], dtype: DType::I32 };
        assert!(w.is_weight());
        assert!(!a.is_weight());
        assert_eq!(w.elems(), 320 * 128);
    }

    #[test]
    fn layout_offsets() {
        let l = StateLayout { kv: 100, logits: 10, feats: 20, queries: 5, total: 135 };
        assert_eq!(l.off_logits(), 100);
        assert_eq!(l.off_feats(), 110);
        assert_eq!(l.off_queries(), 130);
    }

    #[test]
    fn missing_manifest_errors() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
