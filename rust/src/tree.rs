//! Draft-tree construction and flattening (EAGLE-2-style dynamic trees,
//! paper §2 "organize candidate tokens … token tree").
//!
//! The engine drafts level by level; this module owns the tree data
//! structure, the selection of which nodes enter the verification step,
//! the [T, T] ancestor mask the `tree_attention` kernel consumes, and the
//! greedy accept-path walk.

/// One candidate node. `parent == usize::MAX` marks the root.
#[derive(Debug, Clone)]
pub struct Node {
    pub token: u32,
    pub parent: usize,
    /// cumulative log-probability under the draft (root = 0)
    pub score: f32,
    pub depth: usize,
}

/// A draft tree rooted at the last committed ("bonus") token.
#[derive(Debug, Clone)]
pub struct Tree {
    pub nodes: Vec<Node>,
}

pub const ROOT: usize = usize::MAX;

impl Tree {
    /// New tree whose root is the bonus token from the previous step.
    pub fn new(root_token: u32) -> Tree {
        Tree {
            nodes: vec![Node { token: root_token, parent: ROOT, score: 0.0, depth: 0 }],
        }
    }

    /// Add a candidate under `parent` (index into `nodes`).
    pub fn add(&mut self, parent: usize, token: u32, logprob: f32) -> usize {
        assert!(parent < self.nodes.len(), "bad parent");
        let score = self.nodes[parent].score + logprob;
        let depth = self.nodes[parent].depth + 1;
        self.nodes.push(Node { token, parent, score, depth });
        self.nodes.len() - 1
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Keep the root plus the best `max_nodes - 1` candidates by
    /// cumulative score, closed under ancestors (EAGLE-2 top-N selection).
    /// Returns the pruned tree with nodes in topological (parent-first)
    /// order, plus the mapping old→new index.
    pub fn prune_top(&self, max_nodes: usize) -> Tree {
        assert!(max_nodes >= 1);
        let n = self.nodes.len();
        let mut order: Vec<usize> = (1..n).collect();
        order.sort_by(|&a, &b| {
            self.nodes[b]
                .score
                .partial_cmp(&self.nodes[a].score)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut keep = vec![false; n];
        keep[0] = true;
        let mut kept = 1;
        for &i in &order {
            if kept >= max_nodes {
                break;
            }
            // include i and any not-yet-kept ancestors
            let mut chain = vec![];
            let mut j = i;
            while !keep[j] {
                chain.push(j);
                j = self.nodes[j].parent;
            }
            if kept + chain.len() <= max_nodes {
                for &c in &chain {
                    keep[c] = true;
                }
                kept += chain.len();
            }
        }
        // topological order = original insertion order filtered (parents
        // were always inserted before children)
        let mut remap = vec![usize::MAX; n];
        let mut nodes = Vec::with_capacity(kept);
        for i in 0..n {
            if keep[i] {
                remap[i] = nodes.len();
                let nd = &self.nodes[i];
                nodes.push(Node {
                    token: nd.token,
                    parent: if nd.parent == ROOT { ROOT } else { remap[nd.parent] },
                    score: nd.score,
                    depth: nd.depth,
                });
            }
        }
        Tree { nodes }
    }

    /// Flatten for verification: token ids, per-node depth offsets and the
    /// `[t_pad, t_pad]` ancestor mask (row i attends column j iff j is an
    /// ancestor-or-self of i). Rows/cols past `len()` get a self-edge so
    /// padded softmax rows stay finite.
    pub fn flatten(&self, t_pad: usize) -> FlatTree {
        let n = self.nodes.len();
        assert!(n <= t_pad, "tree {n} exceeds pad {t_pad}");
        let mut tokens = vec![0i32; t_pad];
        let mut depth = vec![0usize; t_pad];
        let mut mask = vec![0f32; t_pad * t_pad];
        for (i, nd) in self.nodes.iter().enumerate() {
            tokens[i] = nd.token as i32;
            depth[i] = nd.depth;
            // walk ancestors
            let mut j = i;
            loop {
                mask[i * t_pad + j] = 1.0;
                let p = self.nodes[j].parent;
                if p == ROOT {
                    break;
                }
                j = p;
            }
        }
        for i in n..t_pad {
            mask[i * t_pad + i] = 1.0;
        }
        FlatTree { tokens, depth, mask, n }
    }

    /// Greedy accept walk: `pick[i]` is the target's argmax token at node
    /// i. Returns the accepted node indices (excluding the root) in path
    /// order, plus the bonus token (target argmax at the deepest accepted
    /// node).
    pub fn greedy_accept(&self, pick: &[u32]) -> (Vec<usize>, u32) {
        let mut cur = 0usize;
        let mut path = Vec::new();
        loop {
            let want = pick[cur];
            let next = (0..self.nodes.len()).find(|&j| {
                self.nodes[j].parent == cur && self.nodes[j].token == want
            });
            match next {
                Some(j) => {
                    path.push(j);
                    cur = j;
                }
                None => break,
            }
        }
        (path, pick[cur])
    }

    /// All children of node `i`.
    pub fn children(&self, i: usize) -> Vec<usize> {
        (0..self.nodes.len())
            .filter(|&j| self.nodes[j].parent == i)
            .collect()
    }
}

/// Flattened tree ready for a verification call.
#[derive(Debug)]
pub struct FlatTree {
    pub tokens: Vec<i32>,
    pub depth: Vec<usize>,
    pub mask: Vec<f32>,
    /// real node count (≤ tokens.len())
    pub n: usize,
}

impl FlatTree {
    /// Absolute positions given the root's absolute position.
    pub fn positions(&self, root_pos: usize) -> Vec<i32> {
        self.depth.iter().map(|&d| (root_pos + d) as i32).collect()
    }
}

/// Build a causal-chain mask [t_pad, t_pad] whose first `n` rows form a
/// chain (row i sees 0..=i), used for prefill chunks, catch-up calls and
/// the pv-chain part of Refresh steps.
pub fn chain_mask(n: usize, t_pad: usize) -> Vec<f32> {
    let mut mask = vec![0f32; t_pad * t_pad];
    for i in 0..t_pad {
        if i < n {
            for j in 0..=i {
                mask[i * t_pad + j] = 1.0;
            }
        } else {
            mask[i * t_pad + i] = 1.0;
        }
    }
    mask
}

/// Mask for a Refresh step: rows 0..n_chain are a causal chain; rows
/// n_chain.. hold a tree whose own mask is `tree_mask` (t_tree wide) and
/// which sees the whole chain.
pub fn refresh_mask(n_chain: usize, tree: &FlatTree, t_pad: usize) -> Vec<f32> {
    let t_tree = tree.tokens.len();
    assert!(n_chain + t_tree <= t_pad);
    let mut mask = chain_mask(n_chain, t_pad);
    // clear the default self-edges in the tree block rows
    for i in n_chain..t_pad {
        for j in 0..t_pad {
            mask[i * t_pad + j] = 0.0;
        }
    }
    for ti in 0..t_tree {
        let row = n_chain + ti;
        for j in 0..n_chain {
            mask[row * t_pad + j] = 1.0; // tree sees the whole chain
        }
        for tj in 0..t_tree {
            mask[row * t_pad + n_chain + tj] = tree.mask[ti * t_tree + tj];
        }
    }
    for i in (n_chain + t_tree)..t_pad {
        mask[i * t_pad + i] = 1.0;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;

    fn demo_tree() -> Tree {
        // root(10) -> a(1), b(2); a -> c(3); b -> d(4)
        let mut t = Tree::new(10);
        let a = t.add(0, 1, -0.1);
        let b = t.add(0, 2, -0.5);
        t.add(a, 3, -0.2);
        t.add(b, 4, -0.1);
        t
    }

    #[test]
    fn flatten_mask_ancestors() {
        let t = demo_tree();
        let f = t.flatten(8);
        assert_eq!(f.n, 5);
        // node 3 (= c) sees root, a, itself; not b
        let row = |i: usize, j: usize| f.mask[i * 8 + j];
        assert_eq!(row(3, 0), 1.0);
        assert_eq!(row(3, 1), 1.0);
        assert_eq!(row(3, 3), 1.0);
        assert_eq!(row(3, 2), 0.0);
        // padded rows: self-edge only
        assert_eq!(row(7, 7), 1.0);
        assert_eq!(row(7, 0), 0.0);
    }

    #[test]
    fn greedy_accept_walks_path() {
        let t = demo_tree();
        // target argmax: at root→1 (a), at a→3 (c), at c→99
        let pick = vec![1, 3, 0, 99, 0];
        let (path, bonus) = t.greedy_accept(&pick);
        assert_eq!(path, vec![1, 3]);
        assert_eq!(bonus, 99);
    }

    #[test]
    fn greedy_reject_all() {
        let t = demo_tree();
        let pick = vec![7, 0, 0, 0, 0]; // root wants 7, no child has it
        let (path, bonus) = t.greedy_accept(&pick);
        assert!(path.is_empty());
        assert_eq!(bonus, 7);
    }

    #[test]
    fn prune_keeps_ancestor_closure() {
        let t = demo_tree();
        let p = t.prune_top(3);
        assert_eq!(p.len(), 3);
        // every node's parent must be in the tree, before it
        for (i, n) in p.nodes.iter().enumerate() {
            if n.parent != ROOT {
                assert!(n.parent < i);
            }
        }
        assert_eq!(p.nodes[0].parent, ROOT);
    }

    #[test]
    fn chain_mask_shape() {
        let m = chain_mask(3, 5);
        assert_eq!(m[0], 1.0);
        assert_eq!(m[1 * 5 + 0], 1.0);
        assert_eq!(m[1 * 5 + 2], 0.0);
        assert_eq!(m[4 * 5 + 4], 1.0);
        assert_eq!(m[4 * 5 + 0], 0.0);
    }

    #[test]
    fn refresh_mask_blocks() {
        let t = demo_tree();
        let f = t.flatten(5);
        let m = refresh_mask(3, &f, 10);
        // chain row 2 sees 0..=2
        assert_eq!(m[2 * 10 + 2], 1.0);
        assert_eq!(m[2 * 10 + 3], 0.0);
        // tree root (row 3) sees whole chain + itself
        assert_eq!(m[3 * 10 + 0], 1.0);
        assert_eq!(m[3 * 10 + 3], 1.0);
        // tree node c (flat idx 3 → row 6) sees chain, root, a, self
        assert_eq!(m[6 * 10 + 1], 1.0);
        assert_eq!(m[6 * 10 + 3], 1.0);
        assert_eq!(m[6 * 10 + 4], 1.0);
        assert_eq!(m[6 * 10 + 5], 0.0);
        assert_eq!(m[6 * 10 + 6], 1.0);
    }

    #[test]
    fn mask_property_ancestors_only() {
        Prop::new("tree mask = ancestor closure", 100).run(|g| {
            let mut t = Tree::new(0);
            let n = g.usize_in(1, 12);
            for _ in 0..n {
                let parent = g.usize_in(0, t.len() - 1);
                t.add(parent, g.u32() % 320, -(g.f32_in(0.0, 3.0)));
            }
            let pad = t.len() + g.usize_in(0, 4);
            let f = t.flatten(pad);
            for i in 0..t.len() {
                for j in 0..t.len() {
                    // ancestor check by walking
                    let mut anc = false;
                    let mut k = i;
                    loop {
                        if k == j {
                            anc = true;
                            break;
                        }
                        if t.nodes[k].parent == ROOT {
                            break;
                        }
                        k = t.nodes[k].parent;
                    }
                    assert_eq!(f.mask[i * pad + j] > 0.5, anc, "i={i} j={j}");
                }
            }
        });
    }

    #[test]
    fn prune_property_topological_and_bounded() {
        Prop::new("prune topological", 100).run(|g| {
            let mut t = Tree::new(0);
            for _ in 0..g.usize_in(0, 20) {
                let parent = g.usize_in(0, t.len() - 1);
                t.add(parent, g.u32() % 320, -(g.f32_in(0.0, 5.0)));
            }
            let max = g.usize_in(1, 16);
            let p = t.prune_top(max);
            assert!(p.len() <= max.max(1));
            for (i, n) in p.nodes.iter().enumerate() {
                if i == 0 {
                    assert_eq!(n.parent, ROOT);
                } else {
                    assert!(n.parent < i);
                }
            }
        });
    }
}
