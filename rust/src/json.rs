//! Minimal JSON parser + writer (the `serde`/`serde_json` facade crates
//! are not in the offline vendor set).
//!
//! Covers the full JSON grammar the project needs: the AOT `manifest.json`
//! read path, `results/*.json` write path, the TCP JSON-lines protocol and
//! the training-log reader. Numbers are kept as f64 (i64-exact integers
//! round-trip via `as_i64`).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns an error naming the path.
    pub fn at(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders ---------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    // -- serialization ------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.i += 4;
                            // surrogate pairs: only BMP needed here
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{FFFD}'),
                            );
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("bad utf8"))?;
                        s.push_str(std::str::from_utf8(bytes)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("{e}: '{s}'"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;

    #[test]
    fn parse_basics() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at("a").unwrap().as_arr().unwrap()[2]
                .at("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode() {
        let j = Json::parse(r#""café → ok""#).unwrap();
        assert_eq!(j.as_str(), Some("café → ok"));
    }

    #[test]
    fn roundtrip_property() {
        // random value trees survive serialize → parse
        Prop::new("json roundtrip", 200).run(|g| {
            fn gen(g: &mut crate::util::proptest::Gen, depth: usize) -> Json {
                match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
                    0 => Json::Null,
                    1 => Json::Bool(g.bool()),
                    2 => Json::Num((g.u32() as f64 / 16.0).floor()),
                    3 => Json::Str(format!("s{}\n\"x\"", g.u32())),
                    4 => Json::Arr(
                        (0..g.usize_in(0, 4)).map(|_| gen(g, depth - 1)).collect(),
                    ),
                    _ => {
                        let mut m = std::collections::BTreeMap::new();
                        for i in 0..g.usize_in(0, 4) {
                            m.insert(format!("k{i}"), gen(g, depth - 1));
                        }
                        Json::Obj(m)
                    }
                }
            }
            let v = gen(g, 3);
            let s = v.to_string();
            let back = Json::parse(&s).unwrap();
            assert_eq!(v, back, "serialized: {s}");
        });
    }

    #[test]
    fn builder() {
        let j = Json::obj().set("x", 1.5).set("s", "hi").set("b", true);
        assert_eq!(j.to_string(), r#"{"b":true,"s":"hi","x":1.5}"#);
    }
}
