//! `specpv bench backend` — per-op microbenchmarks of the reference
//! backend at the CI-scale geometry, fast kernels vs the naive scalar
//! oracle, plus end-to-end decoding across the five engines.
//!
//! Emits the usual `results/backend_ops.{md,json}` pair **and**
//! `BENCH_backend.json` at the current directory (the repo root in CI),
//! so the perf trajectory of the host path is tracked PR over PR. With
//! `--check`, compares the fast-path op means against the committed
//! `BENCH_baseline.json` ceilings and fails on a >2× regression.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::backend::reference::ReferenceBackend;
use crate::backend::{
    Backend, CommitOp, DraftExpandOp, DraftPrefillOp, GatherOp, PrefillOp, ReadOp, ScoreOp,
    StateBuf, StateKind, TinyForwardOp, VerifyOp,
};
use crate::config::{BackendKind, Config, EngineKind, SpecPvConfig};
use crate::engine::{self, GenRequest};
use crate::json::Json;
use crate::util::stats::Samples;
use crate::{corpus, tokenizer, tree};

use super::{fmt_speedup, measure, Table, SCHEMA_VERSION};

/// Regression tolerance for `--check`: an op fails when its fast-path
/// mean exceeds `REGRESSION_FACTOR ×` the committed baseline ceiling.
const REGRESSION_FACTOR: f64 = 2.0;

/// The file `--check` compares against (committed at the repo root).
const BASELINE_FILE: &str = "BENCH_baseline.json";

/// The rolling per-PR output (repo root; uploaded as a CI artifact).
const OUTPUT_FILE: &str = "BENCH_backend.json";

// CI-scale scenario geometry: a long-context session mid-decode.
const SIZE: &str = "s";
const FULL_BUCKET: usize = 1024;
const PARTIAL_BUCKET: usize = 384;
const COMMITTED: usize = 512;
const CORE_LEN: usize = 192;

struct OpTimes {
    name: &'static str,
    samples: Samples,
}

/// Run the op scenario against one backend instance.
fn bench_ops(be: &ReferenceBackend, warmup: usize, iters: usize) -> Result<Vec<OpTimes>> {
    let consts = be.consts().clone();
    let c = consts.chunk;
    let t_tree = consts.tree_t;
    let t_refresh = consts.refresh_t;
    let block = consts.block;
    let info = be.model(SIZE)?;
    let zero_prev = [0i32; 8];

    // -- setup: prefill COMMITTED tokens into a full state ------------------
    let mut full = Some(be.alloc_state(StateKind::Full, SIZE, FULL_BUCKET)?);
    for ci in 0..COMMITTED / c {
        let toks: Vec<i32> = (0..c).map(|i| 65 + ((ci * c + i) % 26) as i32).collect();
        let pos: Vec<i32> = (0..c).map(|i| (ci * c + i) as i32).collect();
        let mask = tree::chain_mask(c, c);
        let op = PrefillOp {
            size: SIZE,
            bucket: FULL_BUCKET,
            tokens: &toks,
            pos: &pos,
            mask: &mask,
            kv_len: ci * c,
        };
        full = Some(be.prefill(&op, full.take().unwrap())?);
    }

    let mut out = Vec::new();
    let chunk_toks: Vec<i32> = (0..c).map(|i| 65 + (i % 26) as i32).collect();
    let chunk_pos: Vec<i32> = (0..c).map(|i| (COMMITTED + i) as i32).collect();
    let chunk_mask = tree::chain_mask(c, c);

    // -- prefill (one chunk appended at COMMITTED, + the tail-row read) -----
    out.push(OpTimes {
        name: "prefill",
        samples: measure(warmup, iters, || {
            let op = PrefillOp {
                size: SIZE,
                bucket: FULL_BUCKET,
                tokens: &chunk_toks,
                pos: &chunk_pos,
                mask: &chunk_mask,
                kv_len: COMMITTED,
            };
            let st = be.prefill(&op, full.take().unwrap())?;
            be.read_logits(&ReadOp::LastRow { size: SIZE, bucket: FULL_BUCKET, idx: c - 1 }, &st)?;
            full = Some(st);
            Ok(())
        })?,
    });

    // -- verify_full (tree step at COMMITTED, + the window read) ------------
    let tree_toks: Vec<i32> = (0..t_tree).map(|i| 65 + (i % 26) as i32).collect();
    let tree_pos: Vec<i32> = (0..t_tree).map(|i| (COMMITTED + i) as i32).collect();
    let tree_mask = tree::chain_mask(t_tree, t_tree);
    out.push(OpTimes {
        name: "verify_full",
        samples: measure(warmup, iters, || {
            let op = VerifyOp {
                size: SIZE,
                bucket: FULL_BUCKET,
                t: t_tree,
                tokens: &tree_toks,
                pos: &tree_pos,
                mask: &tree_mask,
                kv_len: COMMITTED,
                prev_idx: &zero_prev,
                n_prev: 0,
            };
            let st = be.verify_full(&op, full.take().unwrap())?;
            be.read_logits(&ReadOp::FullWindow { size: SIZE, bucket: FULL_BUCKET, start: 0 }, &st)?;
            full = Some(st);
            Ok(())
        })?,
    });

    // -- verify_refresh (the wide refresh variant) --------------------------
    let rf_toks: Vec<i32> = (0..t_refresh).map(|i| 65 + (i % 26) as i32).collect();
    let rf_pos: Vec<i32> = (0..t_refresh).map(|i| (COMMITTED + i) as i32).collect();
    let rf_mask = tree::chain_mask(t_refresh, t_refresh);
    out.push(OpTimes {
        name: "verify_refresh",
        samples: measure(warmup, iters, || {
            let op = VerifyOp {
                size: SIZE,
                bucket: FULL_BUCKET,
                t: t_refresh,
                tokens: &rf_toks,
                pos: &rf_pos,
                mask: &rf_mask,
                kv_len: COMMITTED,
                prev_idx: &zero_prev,
                n_prev: 0,
            };
            let st = be.verify_full(&op, full.take().unwrap())?;
            be.read_logits(&ReadOp::FullWindow { size: SIZE, bucket: FULL_BUCKET, start: 0 }, &st)?;
            full = Some(st);
            Ok(())
        })?,
    });

    // -- score + gather (Refresh support ops) -------------------------------
    out.push(OpTimes {
        name: "score",
        samples: measure(warmup, iters, || {
            let op = ScoreOp {
                size: SIZE,
                bucket: FULL_BUCKET,
                kv_len: COMMITTED,
                n_queries: 8,
            };
            be.score(&op, full.as_ref().unwrap())?;
            Ok(())
        })?,
    });

    // block plan: the first CORE_LEN/block committed blocks, padded by
    // repeating the final selection (the documented GatherOp convention)
    let nsel = PARTIAL_BUCKET / block;
    let ncore = CORE_LEN / block;
    let mut block_idx = Vec::with_capacity(info.n_layer * nsel);
    for _layer in 0..info.n_layer {
        for s in 0..nsel {
            block_idx.push(s.min(ncore - 1) as i32);
        }
    }
    out.push(OpTimes {
        name: "gather",
        samples: measure(warmup, iters, || {
            let op = GatherOp {
                size: SIZE,
                bucket: FULL_BUCKET,
                p_bucket: PARTIAL_BUCKET,
                block_idx: &block_idx,
            };
            be.refresh_gather(&op, full.as_ref().unwrap())?;
            Ok(())
        })?,
    });

    // -- verify_partial (tree step against the gathered core) ---------------
    let gop = GatherOp {
        size: SIZE,
        bucket: FULL_BUCKET,
        p_bucket: PARTIAL_BUCKET,
        block_idx: &block_idx,
    };
    let mut partial = Some(be.refresh_gather(&gop, full.as_ref().unwrap())?);
    let ptree_pos: Vec<i32> = (0..t_tree).map(|i| (COMMITTED + i) as i32).collect();
    out.push(OpTimes {
        name: "verify_partial",
        samples: measure(warmup, iters, || {
            let op = VerifyOp {
                size: SIZE,
                bucket: PARTIAL_BUCKET,
                t: t_tree,
                tokens: &tree_toks,
                pos: &ptree_pos,
                mask: &tree_mask,
                kv_len: CORE_LEN,
                prev_idx: &zero_prev,
                n_prev: 0,
            };
            let st = be.verify_partial(&op, partial.take().unwrap())?;
            be.read_logits(&ReadOp::Partial { size: SIZE, bucket: PARTIAL_BUCKET }, &st)?;
            partial = Some(st);
            Ok(())
        })?,
    });

    // -- commit (standalone post-Refresh compaction) ------------------------
    // keep every other window row so the compaction actually moves data
    let commit_idx: Vec<i32> =
        (0..t_refresh).map(|i| (2 * i).min(t_refresh - 1) as i32).collect();
    out.push(OpTimes {
        name: "commit",
        samples: measure(warmup, iters, || {
            let op = CommitOp {
                size: SIZE,
                bucket: FULL_BUCKET,
                window: t_refresh,
                idx: &commit_idx,
                n: 24,
                kv_len: COMMITTED,
            };
            let st = be.commit(&op, full.take().unwrap())?;
            full = Some(st);
            Ok(())
        })?,
    });

    // -- draft_expand (EAGLE W-slot step) -----------------------------------
    let mut draft = Some(be.alloc_state(StateKind::Draft, SIZE, FULL_BUCKET)?);
    {
        let op = DraftPrefillOp {
            size: SIZE,
            bucket: FULL_BUCKET,
            tokens: &chunk_toks,
            pos: &chunk_pos,
            mask: &chunk_mask,
            kv_len: 0,
            write_pos: 0,
        };
        draft = Some(be.draft_prefill(&op, full.as_ref().unwrap(), draft.take().unwrap())?);
    }
    let w = consts.draft_w;
    let region = consts.draft_region;
    let dr_toks: Vec<i32> = (0..w).map(|i| 66 + i as i32).collect();
    let dr_feats = vec![0.05f32; w * 3 * info.d_model];
    let dr_pos: Vec<i32> = (0..w).map(|i| (c + i) as i32).collect();
    let mut dr_mask = vec![0f32; w * region];
    for i in 0..w {
        for j in 0..=i {
            dr_mask[i * region + j] = 1.0;
        }
    }
    out.push(OpTimes {
        name: "draft_expand",
        samples: measure(warmup, iters, || {
            let op = DraftExpandOp {
                size: SIZE,
                bucket: FULL_BUCKET,
                tokens: &dr_toks,
                feats: &dr_feats,
                pos: &dr_pos,
                mask: &dr_mask,
                kv_len: c,
                write_pos: c,
            };
            let st = be.draft_expand(&op, draft.take().unwrap())?;
            be.read_logits(&ReadOp::Draft { size: SIZE, bucket: FULL_BUCKET }, &st)?;
            draft = Some(st);
            Ok(())
        })?,
    });

    // -- tiny_forward (TriForce draft step) ---------------------------------
    let mut tiny = Some(be.alloc_state(StateKind::Tiny, "tiny", consts.tiny_bucket)?);
    {
        let tiny_pos: Vec<i32> = (0..c).map(|i| i as i32).collect();
        let op = TinyForwardOp {
            t: c,
            tokens: &chunk_toks,
            pos: &tiny_pos,
            mask: &chunk_mask,
            kv_len: 0,
            write_pos: 0,
            last_idx: c - 1,
        };
        tiny = Some(be.tiny_forward(&op, tiny.take().unwrap())?);
    }
    out.push(OpTimes {
        name: "tiny_forward",
        samples: measure(warmup, iters, || {
            let op = TinyForwardOp {
                t: 1,
                tokens: &[70],
                pos: &[c as i32],
                mask: &[1.0],
                kv_len: c,
                write_pos: c,
                last_idx: 0,
            };
            let st = be.tiny_forward(&op, tiny.take().unwrap())?;
            be.read_logits(&ReadOp::Tiny, &st)?;
            tiny = Some(st);
            Ok(())
        })?,
    });

    // -- medusa ------------------------------------------------------------
    let feat = vec![0.1f32; info.d_model];
    out.push(OpTimes {
        name: "medusa",
        samples: measure(warmup, iters, || {
            be.medusa(SIZE, &feat)?;
            Ok(())
        })?,
    });

    // -- batched ops (cross-session fusion at B=4, DESIGN.md §12) -----------
    // Each batched op runs over 4 independent snapshots of the states
    // prepared above. On the fast pipeline these hit the fused stacked-row
    // kernels; in naive mode they fall back to the sequential loop, so
    // the speedup column directly shows the fusion win.
    const B: usize = 4;
    let full_snap = be.export_state(StateKind::Full, SIZE, FULL_BUCKET, full.as_ref().unwrap())?;
    let mut fulls = Vec::with_capacity(B);
    for _ in 0..B {
        fulls.push(be.import_state(&full_snap)?);
    }
    out.push(OpTimes {
        name: "verify_full_batch4",
        samples: measure(warmup, iters, || {
            let ops: Vec<VerifyOp> = (0..B)
                .map(|_| VerifyOp {
                    size: SIZE,
                    bucket: FULL_BUCKET,
                    t: t_tree,
                    tokens: &tree_toks,
                    pos: &tree_pos,
                    mask: &tree_mask,
                    kv_len: COMMITTED,
                    prev_idx: &zero_prev,
                    n_prev: 0,
                })
                .collect();
            let mut refs: Vec<&mut StateBuf> = fulls.iter_mut().collect();
            be.verify_full_batch(&ops, &mut refs)?;
            Ok(())
        })?,
    });
    out.push(OpTimes {
        name: "prefill_batch4",
        samples: measure(warmup, iters, || {
            let ops: Vec<PrefillOp> = (0..B)
                .map(|_| PrefillOp {
                    size: SIZE,
                    bucket: FULL_BUCKET,
                    tokens: &chunk_toks,
                    pos: &chunk_pos,
                    mask: &chunk_mask,
                    kv_len: COMMITTED,
                })
                .collect();
            let mut refs: Vec<&mut StateBuf> = fulls.iter_mut().collect();
            be.prefill_batch(&ops, &mut refs)?;
            Ok(())
        })?,
    });

    let part_snap =
        be.export_state(StateKind::Partial, SIZE, PARTIAL_BUCKET, partial.as_ref().unwrap())?;
    let mut partials = Vec::with_capacity(B);
    for _ in 0..B {
        partials.push(be.import_state(&part_snap)?);
    }
    out.push(OpTimes {
        name: "verify_partial_batch4",
        samples: measure(warmup, iters, || {
            let ops: Vec<VerifyOp> = (0..B)
                .map(|_| VerifyOp {
                    size: SIZE,
                    bucket: PARTIAL_BUCKET,
                    t: t_tree,
                    tokens: &tree_toks,
                    pos: &ptree_pos,
                    mask: &tree_mask,
                    kv_len: CORE_LEN,
                    prev_idx: &zero_prev,
                    n_prev: 0,
                })
                .collect();
            let mut refs: Vec<&mut StateBuf> = partials.iter_mut().collect();
            be.verify_partial_batch(&ops, &mut refs)?;
            Ok(())
        })?,
    });

    let draft_snap =
        be.export_state(StateKind::Draft, SIZE, FULL_BUCKET, draft.as_ref().unwrap())?;
    let mut drafts = Vec::with_capacity(B);
    for _ in 0..B {
        drafts.push(be.import_state(&draft_snap)?);
    }
    out.push(OpTimes {
        name: "draft_expand_batch4",
        samples: measure(warmup, iters, || {
            let ops: Vec<DraftExpandOp> = (0..B)
                .map(|_| DraftExpandOp {
                    size: SIZE,
                    bucket: FULL_BUCKET,
                    tokens: &dr_toks,
                    feats: &dr_feats,
                    pos: &dr_pos,
                    mask: &dr_mask,
                    kv_len: c,
                    write_pos: c,
                })
                .collect();
            let mut refs: Vec<&mut StateBuf> = drafts.iter_mut().collect();
            be.draft_expand_batch(&ops, &mut refs)?;
            Ok(())
        })?,
    });

    let tiny_snap =
        be.export_state(StateKind::Tiny, "tiny", consts.tiny_bucket, tiny.as_ref().unwrap())?;
    let mut tinies = Vec::with_capacity(B);
    for _ in 0..B {
        tinies.push(be.import_state(&tiny_snap)?);
    }
    out.push(OpTimes {
        name: "tiny_forward_batch4",
        samples: measure(warmup, iters, || {
            let ops: Vec<TinyForwardOp> = (0..B)
                .map(|_| TinyForwardOp {
                    t: 1,
                    tokens: &[70],
                    pos: &[c as i32],
                    mask: &[1.0],
                    kv_len: c,
                    write_pos: c,
                    last_idx: 0,
                })
                .collect();
            let mut refs: Vec<&mut StateBuf> = tinies.iter_mut().collect();
            be.tiny_forward_batch(&ops, &mut refs)?;
            Ok(())
        })?,
    });

    Ok(out)
}

/// End-to-end decode timing per engine on the fast backend.
fn bench_engines(be: &dyn Backend, iters: usize) -> Result<Vec<(EngineKind, Samples, usize)>> {
    let base = Config {
        backend: BackendKind::Reference,
        specpv: SpecPvConfig { retrieval_budget: 64, ..SpecPvConfig::default() },
        ..Config::default()
    };
    let prompt = corpus::continuation_prompt(1, 600);
    let req = GenRequest::greedy(tokenizer::encode(&prompt), 32);
    let mut out = Vec::new();
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::SpecFull,
        EngineKind::SpecPv,
        EngineKind::TriForce,
        EngineKind::TokenSwift,
    ] {
        let mut cfg = base.clone();
        cfg.engine = kind;
        let mut toks = 0usize;
        let samples = measure(1, iters, || {
            let r = engine::generate_with(&cfg, be, &req)?;
            toks = r.tokens.len();
            Ok(())
        })?;
        out.push((kind, samples, toks));
    }
    Ok(out)
}

fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Drive the whole backend bench; see the module docs for outputs.
/// `update_baseline` rewrites `BENCH_baseline.json` from this run's
/// fast-path means (the one documented way to regenerate the ceilings:
/// `specpv bench backend --update-baseline`).
pub fn run(out_dir: &Path, quick: bool, check: bool, update_baseline: bool) -> Result<()> {
    let (warm, fast_iters, naive_iters, eng_iters) =
        if quick { (2, 10, 3, 2) } else { (3, 50, 8, 5) };

    let fast_be = ReferenceBackend::new();
    let naive_be = ReferenceBackend::naive();
    eprintln!("[bench backend] {}", fast_be.describe());

    let fast = bench_ops(&fast_be, warm, fast_iters)?;
    let naive = bench_ops(&naive_be, 1, naive_iters)?;

    let mut ops_table = Table::new(
        "Reference-backend op timings (CI geometry, fast vs naive oracle)",
        &["op", "naive ms", "fast ms", "speedup", "fast p50 ms", "fast p95 ms"],
    );
    let mut op_rows = Vec::new();
    let mut core_speedups = Vec::new();
    let mut fast_ms = std::collections::BTreeMap::new();
    for (f, n) in fast.iter().zip(&naive) {
        assert_eq!(f.name, n.name, "op order must match across modes");
        let fm = f.samples.mean() * 1e3;
        let nm = n.samples.mean() * 1e3;
        let speedup = if fm > 0.0 { nm / fm } else { 0.0 };
        if matches!(f.name, "prefill" | "verify_full" | "verify_partial") {
            core_speedups.push(speedup);
        }
        fast_ms.insert(f.name.to_string(), fm);
        let row_json = Json::obj()
            .set("op", f.name)
            .set("naive_ms", nm)
            .set("fast_ms", fm)
            .set("speedup", speedup)
            .set("p50_ms", f.samples.p50() * 1e3)
            .set("p95_ms", f.samples.p95() * 1e3);
        ops_table.row(
            vec![
                f.name.to_string(),
                format!("{nm:.3}"),
                format!("{fm:.3}"),
                fmt_speedup(speedup),
                format!("{:.3}", f.samples.p50() * 1e3),
                format!("{:.3}", f.samples.p95() * 1e3),
            ],
            row_json.clone(),
        );
        op_rows.push(row_json);
    }
    let gm = geomean(&core_speedups);
    eprintln!(
        "[bench backend] geomean speedup over prefill/verify_full/verify_partial: {}",
        fmt_speedup(gm)
    );
    if let (Some(vf), Some(vp)) = (fast_ms.get("verify_full"), fast_ms.get("verify_partial")) {
        eprintln!(
            "[bench backend] verify_partial / verify_full cost ratio: {:.2} ({vp:.3} vs {vf:.3} ms)",
            vp / vf
        );
    }
    ops_table.emit(out_dir, "backend_ops")?;

    let engines = bench_engines(&fast_be, eng_iters)?;
    let mut eng_table = Table::new(
        "Engine end-to-end decode (fast reference backend, 32 new tokens)",
        &["engine", "mean ms/gen", "tok/s"],
    );
    let mut eng_rows = Vec::new();
    for (kind, s, toks) in &engines {
        let tps = s.per_sec(*toks as f64);
        let row_json = Json::obj()
            .set("engine", format!("{kind:?}"))
            .set("mean_ms", s.mean() * 1e3)
            .set("tokens", *toks)
            .set("tok_per_sec", tps);
        eng_table.row(
            vec![
                format!("{kind:?}"),
                format!("{:.2}", s.mean() * 1e3),
                format!("{tps:.1}"),
            ],
            row_json.clone(),
        );
        eng_rows.push(row_json);
    }
    eng_table.emit(out_dir, "backend_engines")?;

    let combined = Json::obj()
        .set("schema_version", SCHEMA_VERSION)
        .set("threads", crate::util::pool::global().threads())
        .set("geomean_speedup", gm)
        .set("ops", Json::Arr(op_rows))
        .set("engines", Json::Arr(eng_rows));
    std::fs::write(OUTPUT_FILE, combined.to_string())?;
    eprintln!("[bench backend] wrote {OUTPUT_FILE}");

    if update_baseline {
        write_baseline(&fast_ms)?;
    }
    if check {
        check_baseline(&fast_ms)?;
    }
    Ok(())
}

/// Regenerate the committed `BENCH_baseline.json` ceilings from this
/// run's fast-path means (the `{op, mean_ms}` shape `--check` reads).
fn write_baseline(fast_ms: &std::collections::BTreeMap<String, f64>) -> Result<()> {
    let ops: Vec<Json> = fast_ms
        .iter()
        .map(|(name, &ms)| Json::obj().set("op", name.as_str()).set("mean_ms", ms))
        .collect();
    let j = Json::obj()
        .set("schema_version", SCHEMA_VERSION)
        .set(
            "note",
            "Per-op fast-path ceilings for `specpv bench backend --check` (mean ms \
             at the CI geometry). CI fails when a measured mean exceeds 2x its \
             ceiling. Regenerate with `specpv bench backend --update-baseline`.",
        )
        .set("ops", Json::Arr(ops));
    std::fs::write(BASELINE_FILE, j.to_string())?;
    eprintln!("[bench backend] rewrote {BASELINE_FILE} from this run");
    Ok(())
}

/// Compare fast-path means against the committed ceilings; fail on >2×.
fn check_baseline(fast_ms: &std::collections::BTreeMap<String, f64>) -> Result<()> {
    let text = std::fs::read_to_string(BASELINE_FILE)
        .with_context(|| format!("--check requires {BASELINE_FILE} in the current directory"))?;
    let base = Json::parse(&text)?;
    let ops = base
        .at("ops")?
        .as_arr()
        .context("baseline 'ops' must be an array")?;
    let mut violations = Vec::new();
    for entry in ops {
        let name = entry.at("op")?.as_str().context("baseline op name")?;
        let ceiling = entry.at("mean_ms")?.as_f64().context("baseline mean_ms")?;
        match fast_ms.get(name) {
            Some(&got) if got > REGRESSION_FACTOR * ceiling => violations.push(format!(
                "{name}: {got:.3} ms > {REGRESSION_FACTOR}x baseline {ceiling:.3} ms"
            )),
            Some(_) => {}
            None => eprintln!("[bench backend] baseline op '{name}' not measured, skipping"),
        }
    }
    if !violations.is_empty() {
        bail!("perf regression vs {BASELINE_FILE}:\n  {}", violations.join("\n  "));
    }
    eprintln!("[bench backend] baseline check passed ({} ops)", ops.len());
    Ok(())
}
