//! `specpv bench kvstore` — measures what the KV state manager buys and
//! what it costs on the reference backend:
//!
//! * **prefix-hit TTFT vs cold-prefill TTFT** at the 1024-token bucket:
//!   the same long prompt started cold (every chunk prefilled) and warm
//!   (cached prefix pages mapped into the session, only the tail chunk
//!   prefilled). The run fails if the hit path is not strictly faster,
//!   or if a hit materializes any new page — a prefix hit must be a
//!   refcount bump, not a copy.
//! * **state movement costs**: flat snapshot export/import of a full
//!   1024-bucket state vs paged park/unpark through the block pool. The
//!   run fails if the paged restore falls behind the flat memcpy import
//!   by more than the noise headroom — i.e. the paged prefix-hit TTFT
//!   must not regress vs the old snapshot-copy path.
//! * **swap round-trip** cost of a live spec_pv session mid-generation
//!   (suspend → resume), plus a byte-identity check against an
//!   undisturbed run.
//! * **session density**: N spec_pv sessions over one shared long
//!   prefix with distinct tails, all suspended into the pool.
//!   Zero-page + content dedup must make the paged footprint strictly
//!   smaller than the flat-slab sum (`Σ state_bytes`), reported as
//!   sessions-per-GiB for flat / paged / int8-demoted tiers. Resuming
//!   every session must reproduce the undisturbed outputs byte-for-byte
//!   (`kv_quant = none` is exact by contract; int8 is reported, not
//!   identity-checked).
//!
//! Emits `results/kvstore_{ttft,costs,density}.{md,json}` and a
//! combined `BENCH_kvstore.json` at the current directory (the repo
//! root in CI).

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::backend::reference::ReferenceBackend;
use crate::backend::Backend;
use crate::config::{BackendKind, Config, EngineKind, KvQuant, SpecPvConfig};
use crate::engine::{self, GenRequest};
use crate::json::Json;
use crate::kvstore::{KvCtx, KvPool, KvStore, PoolStats, DEFAULT_PAGE_BYTES};
use crate::offload::OffloadSim;
use crate::util::stats::Samples;
use crate::{corpus, tokenizer};

use super::{fmt_speedup, measure, Table, SCHEMA_VERSION};

const OUTPUT_FILE: &str = "BENCH_kvstore.json";

/// Prompt length targeting the 1024 full bucket (prompt + max_new +
/// chunk + refresh headroom ≤ 1024 on the reference geometry).
const PROMPT_TOKENS: usize = 850;
const MAX_NEW: usize = 16;

/// Headroom for the paged-restore vs flat-import gate: the paged path
/// re-assembles the image from refcounted pages, which must stay within
/// measurement noise of one flat memcpy.
const PAGED_RESTORE_SLACK: f64 = 1.5;

fn prompt_req(be: &dyn Backend) -> (GenRequest, usize) {
    let text = corpus::continuation_prompt(1, 4 * PROMPT_TOKENS);
    let mut toks = tokenizer::encode(&text);
    toks.truncate(PROMPT_TOKENS);
    let req = GenRequest::greedy(toks, MAX_NEW);
    let need = crate::model::bucket_need(req.prompt.len(), req.max_new, be.consts());
    let bucket = crate::backend::pick_bucket(&be.full_buckets("s"), need, "full", "s")
        .expect("reference backend has a bucket for the bench prompt");
    (req, bucket)
}

/// Cold vs prefix-hit time-to-first-token (engine start = prefill + the
/// first pick, i.e. the TTFT the coordinator reports). Also returns the
/// number of pages materialized across all hit runs — must be zero.
fn bench_ttft(
    be: &ReferenceBackend,
    warmup: usize,
    iters: usize,
) -> Result<(Samples, Samples, usize, KvStore, u64)> {
    let cfg = Config {
        backend: BackendKind::Reference,
        engine: EngineKind::Autoregressive,
        ..Config::default()
    };
    let (req, bucket) = prompt_req(be);

    let off = KvCtx::disabled();
    let cold = measure(warmup, iters, || {
        let session = engine::build(&cfg).start(be, &req, &off)?;
        drop(session);
        Ok(())
    })?;

    let store = KvStore::new(64 << 20);
    let kv = KvCtx::with_prefix(store.clone());
    // prime: one miss inserts the boundary block table
    drop(engine::build(&cfg).start(be, &req, &kv)?);
    let allocs_before = store.pool().stats().page_allocs;
    let warm = measure(warmup, iters, || {
        let session = engine::build(&cfg).start(be, &req, &kv)?;
        drop(session);
        Ok(())
    })?;
    let hit_new_pages = store.pool().stats().page_allocs - allocs_before;
    Ok((cold, warm, bucket, store, hit_new_pages))
}

/// State movement at the bench bucket: flat snapshot export/import vs
/// paged park/unpark through the block pool.
fn bench_snapshot(
    be: &ReferenceBackend,
    warmup: usize,
    iters: usize,
) -> Result<(Samples, Samples, Samples, Samples, usize)> {
    let (req, _bucket) = prompt_req(be);
    let mut target = crate::engine::session::TargetSession::new(
        be,
        "s",
        crate::model::bucket_need(req.prompt.len(), req.max_new, be.consts()),
        OffloadSim::new(Default::default()),
    )?;
    target.prefill(&req.prompt, None, &KvCtx::disabled())?;
    let mut bytes = 0usize;
    let export = measure(warmup, iters, || {
        let snap = target.export()?;
        bytes = snap.bytes();
        Ok(())
    })?;
    let snap = target.export()?;
    let import = measure(warmup, iters, || {
        target.restore(&snap)?;
        Ok(())
    })?;

    let pool = KvPool::new(0);
    let park = measure(warmup, iters, || {
        let ps = target.park(&pool)?;
        pool.free_state(&ps);
        Ok(())
    })?;
    let ps = target.park(&pool)?;
    let unpark = measure(warmup, iters, || {
        target.restore_paged(&pool, &ps)?;
        Ok(())
    })?;
    pool.free_state(&ps);
    Ok((export, import, park, unpark, bytes))
}

/// Swap round-trip (suspend → resume) on a live spec_pv session, with a
/// byte-identity check against an undisturbed run.
fn bench_swap(be: &ReferenceBackend, iters: usize) -> Result<(Samples, Samples, usize)> {
    let cfg = Config {
        backend: BackendKind::Reference,
        engine: EngineKind::SpecPv,
        specpv: SpecPvConfig { retrieval_budget: 64, ..SpecPvConfig::default() },
        ..Config::default()
    };
    let text = corpus::continuation_prompt(2, 2400);
    let mut toks = tokenizer::encode(&text);
    toks.truncate(600);
    let req = GenRequest::greedy(toks, 32);

    let baseline = engine::generate_with(&cfg, be, &req)?;

    let mut session = engine::build(&cfg).start(be, &req, &KvCtx::disabled())?;
    session.step()?;
    let state_bytes = session.state_bytes();
    let mut out_s = Samples::default();
    let mut in_s = Samples::default();
    for _ in 0..iters {
        if session.is_finished() {
            break;
        }
        let t0 = Instant::now();
        let snaps = session.suspend()?;
        out_s.push(t0.elapsed().as_secs_f64());
        let t1 = Instant::now();
        session.resume(snaps)?;
        in_s.push(t1.elapsed().as_secs_f64());
        session.step()?;
    }
    while !session.is_finished() {
        session.step()?;
    }
    let swapped = session.finish();
    if swapped.tokens != baseline.tokens {
        bail!(
            "swap round-trip changed the output ({} vs {} tokens)",
            swapped.tokens.len(),
            baseline.tokens.len()
        );
    }
    Ok((out_s, in_s, state_bytes))
}

/// Session-density measurement: N suspended spec_pv sessions over one
/// shared long prefix with distinct tails.
struct Density {
    n: usize,
    /// flat-slab footprint: Σ state_bytes of the live sessions
    flat_bytes: usize,
    /// pool RAM after suspending all sessions (f32 pages, dedup/CoW)
    paged_bytes: usize,
    /// pool RAM with `kv_quant = int8` cold demotion on top
    int8_bytes: usize,
    /// pool gauges at peak occupancy of the f32 run
    pages: PoolStats,
}

fn bench_density(be: &ReferenceBackend, quick: bool) -> Result<Density> {
    let cfg = Config {
        backend: BackendKind::Reference,
        engine: EngineKind::SpecPv,
        specpv: SpecPvConfig { retrieval_budget: 64, ..SpecPvConfig::default() },
        ..Config::default()
    };
    let n = if quick { 4 } else { 6 };
    let text = corpus::continuation_prompt(3, 2400);
    let mut prefix_toks = tokenizer::encode(&text);
    prefix_toks.truncate(520);
    let reqs: Vec<GenRequest> = (0..n)
        .map(|i| {
            let mut toks = prefix_toks.clone();
            toks.extend(tokenizer::encode(&format!(" tail variant {i} ends here.")));
            GenRequest::greedy(toks, 12)
        })
        .collect();

    // undisturbed outputs for the identity check
    let baselines: Vec<Vec<u32>> = reqs
        .iter()
        .map(|r| engine::generate_with(&cfg, be, r).map(|g| g.tokens))
        .collect::<Result<_>>()?;

    // --- f32 pool: exact tier ------------------------------------------
    let pool = KvPool::new(0);
    let kv = KvCtx::with_pool(pool.clone());
    let mut sessions = Vec::new();
    let mut flat_bytes = 0usize;
    for req in &reqs {
        let mut s = engine::build(&cfg).start(be, req, &kv)?;
        s.step()?;
        flat_bytes += s.state_bytes();
        sessions.push(s);
    }
    let mut tables = Vec::new();
    for s in &mut sessions {
        tables.push(s.suspend()?);
    }
    let pages = pool.stats();
    let paged_bytes = pages.ram_bytes;

    // resume everything and prove the parked tier is lossless
    for (s, t) in sessions.iter_mut().zip(tables) {
        s.resume(t)?;
    }
    for (i, mut s) in sessions.into_iter().enumerate() {
        while !s.is_finished() {
            s.step()?;
        }
        let got = s.finish().tokens;
        if got != baselines[i] {
            bail!(
                "density session {i}: suspend/resume changed the output \
                 ({} vs {} tokens)",
                got.len(),
                baselines[i].len()
            );
        }
    }

    // --- int8 pool: cold demotion on top -------------------------------
    let pool8 = KvPool::with_opts(0, DEFAULT_PAGE_BYTES, None, KvQuant::Int8);
    let kv8 = KvCtx::with_pool(pool8.clone());
    let mut kept = Vec::new();
    for req in &reqs {
        let mut s = engine::build(&cfg).start(be, req, &kv8)?;
        s.step()?;
        let t = s.suspend()?;
        pool8.park_cold(&t)?;
        kept.push(t);
    }
    let int8_bytes = pool8.stats().ram_bytes;
    for t in &kept {
        for ps in t {
            pool8.free_state(ps);
        }
    }

    Ok(Density { n, flat_bytes, paged_bytes, int8_bytes, pages })
}

fn per_gib(n: usize, bytes: usize) -> f64 {
    if bytes == 0 {
        0.0
    } else {
        n as f64 * (1u64 << 30) as f64 / bytes as f64
    }
}

/// Drive the kvstore bench; see the module docs for outputs.
pub fn run(out_dir: &Path, quick: bool) -> Result<()> {
    let (warmup, iters, swap_iters) = if quick { (1, 3, 4) } else { (2, 8, 10) };
    let be = ReferenceBackend::new();
    eprintln!("[bench kvstore] {}", be.describe());

    let (cold, warm, bucket, store, hit_new_pages) = bench_ttft(&be, warmup, iters)?;
    let speedup = if warm.mean() > 0.0 { cold.mean() / warm.mean() } else { 0.0 };
    let ps = store.stats();
    let mut ttft_table = Table::new(
        "KV state manager: prefix-hit vs cold-prefill TTFT",
        &["path", "mean ms", "p50 ms", "p95 ms"],
    );
    let mut ttft_rows = Vec::new();
    for (name, s) in [("cold_prefill", &cold), ("prefix_hit", &warm)] {
        let row = Json::obj()
            .set("path", name)
            .set("mean_ms", s.mean() * 1e3)
            .set("p50_ms", s.p50() * 1e3)
            .set("p95_ms", s.p95() * 1e3)
            .set("prompt_tokens", PROMPT_TOKENS)
            .set("bucket", bucket);
        ttft_table.row(
            vec![
                name.to_string(),
                format!("{:.3}", s.mean() * 1e3),
                format!("{:.3}", s.p50() * 1e3),
                format!("{:.3}", s.p95() * 1e3),
            ],
            row.clone(),
        );
        ttft_rows.push(row);
    }
    ttft_table.emit(out_dir, "kvstore_ttft")?;
    eprintln!(
        "[bench kvstore] prefix-hit TTFT speedup at b{bucket}: {} \
         ({} hits / {} misses, {} entries, {} bytes cached, {} pages \
         materialized on hits)",
        fmt_speedup(speedup),
        ps.hits,
        ps.misses,
        ps.entries,
        ps.bytes,
        hit_new_pages
    );

    let (export, import, park, unpark, snap_bytes) =
        bench_snapshot(&be, warmup, iters)?;
    let (swap_out, swap_in, session_bytes) = bench_swap(&be, swap_iters)?;
    let mut costs = Table::new(
        "KV state manager: snapshot, paging + swap round-trip costs",
        &["op", "mean ms", "bytes"],
    );
    let mut cost_rows = Vec::new();
    for (name, s, bytes) in [
        ("export_state", &export, snap_bytes),
        ("import_state", &import, snap_bytes),
        ("park_pages", &park, snap_bytes),
        ("unpark_pages", &unpark, snap_bytes),
        ("swap_out", &swap_out, session_bytes),
        ("swap_in", &swap_in, session_bytes),
    ] {
        let row = Json::obj()
            .set("op", name)
            .set("mean_ms", s.mean() * 1e3)
            .set("bytes", bytes);
        costs.row(
            vec![name.to_string(), format!("{:.3}", s.mean() * 1e3), format!("{bytes}")],
            row.clone(),
        );
        cost_rows.push(row);
    }
    costs.emit(out_dir, "kvstore_costs")?;

    let d = bench_density(&be, quick)?;
    let density_ratio = if d.paged_bytes > 0 {
        d.flat_bytes as f64 / d.paged_bytes as f64
    } else {
        0.0
    };
    let mut density = Table::new(
        "KV state manager: suspended-session density (shared long prefix)",
        &["tier", "bytes", "sessions/GiB"],
    );
    let mut density_rows = Vec::new();
    for (name, bytes) in [
        ("flat_slab", d.flat_bytes),
        ("paged_f32", d.paged_bytes),
        ("paged_int8", d.int8_bytes),
    ] {
        let row = Json::obj()
            .set("tier", name)
            .set("bytes", bytes)
            .set("sessions_per_gib", per_gib(d.n, bytes))
            .set("sessions", d.n);
        density.row(
            vec![
                name.to_string(),
                format!("{bytes}"),
                format!("{:.1}", per_gib(d.n, bytes)),
            ],
            row.clone(),
        );
        density_rows.push(row);
    }
    density.emit(out_dir, "kvstore_density")?;
    eprintln!(
        "[bench kvstore] density over {} spec_pv sessions: flat {} B → \
         paged {} B ({density_ratio:.2}x) → int8 {} B \
         ({} pages resident, {} shared, {} dedup hits, {} CoW copies)",
        d.n,
        d.flat_bytes,
        d.paged_bytes,
        d.int8_bytes,
        d.pages.pages_resident,
        d.pages.pages_shared,
        d.pages.dedup_hits,
        d.pages.cow_copies
    );

    let combined = Json::obj()
        .set("schema_version", SCHEMA_VERSION)
        .set("prompt_tokens", PROMPT_TOKENS)
        .set("bucket", bucket)
        .set("ttft_speedup", speedup)
        .set("ttft", Json::Arr(ttft_rows))
        .set("costs", Json::Arr(cost_rows))
        .set("density", Json::Arr(density_rows))
        .set("prefix_hits", ps.hits as i64)
        .set("prefix_misses", ps.misses as i64)
        .set("hit_new_pages", hit_new_pages as i64)
        .set("sessions_per_gib_flat", per_gib(d.n, d.flat_bytes))
        .set("sessions_per_gib_paged", per_gib(d.n, d.paged_bytes))
        .set("sessions_per_gib_int8", per_gib(d.n, d.int8_bytes))
        .set("density_ratio", density_ratio)
        .set("pages_resident", d.pages.pages_resident)
        .set("pages_shared", d.pages.pages_shared)
        .set("dedup_hits", d.pages.dedup_hits as i64)
        .set("cow_copies", d.pages.cow_copies as i64)
        .set("park_ms", park.mean() * 1e3)
        .set("unpark_ms", unpark.mean() * 1e3);
    std::fs::write(OUTPUT_FILE, combined.to_string())?;
    eprintln!("[bench kvstore] wrote {OUTPUT_FILE}");

    if warm.mean() >= cold.mean() {
        bail!(
            "prefix-hit TTFT ({:.3} ms) is not below cold-prefill TTFT ({:.3} ms)",
            warm.mean() * 1e3,
            cold.mean() * 1e3
        );
    }
    if hit_new_pages != 0 {
        bail!(
            "prefix-cache hits materialized {hit_new_pages} new pages; \
             a hit must only map shared pages"
        );
    }
    if unpark.mean() > import.mean() * PAGED_RESTORE_SLACK {
        bail!(
            "paged restore ({:.3} ms) regressed past the flat snapshot \
             import ({:.3} ms) by more than {PAGED_RESTORE_SLACK}x",
            unpark.mean() * 1e3,
            import.mean() * 1e3
        );
    }
    if d.paged_bytes >= d.flat_bytes {
        bail!(
            "paged footprint ({} B) is not below the flat-slab footprint \
             ({} B) across {} suspended sessions",
            d.paged_bytes,
            d.flat_bytes,
            d.n
        );
    }
    Ok(())
}
