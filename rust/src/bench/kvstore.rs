//! `specpv bench kvstore` — measures what the KV state manager buys and
//! what it costs on the reference backend:
//!
//! * **prefix-hit TTFT vs cold-prefill TTFT** at the 1024-token bucket:
//!   the same long prompt started cold (every chunk prefilled) and warm
//!   (restored from the prompt-prefix snapshot cache, only the tail
//!   chunk prefilled). The run fails if the hit path is not strictly
//!   faster — that speedup is the subsystem's reason to exist.
//! * **snapshot export/import** cost of a full 1024-bucket state (the
//!   unit of both prefix caching and swapping).
//! * **swap round-trip** cost of a live spec_pv session mid-generation
//!   (suspend → resume), plus a byte-identity check against an
//!   undisturbed run.
//!
//! Emits `results/kvstore_{ttft,costs}.{md,json}` and a combined
//! `BENCH_kvstore.json` at the current directory (the repo root in CI).

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::backend::reference::ReferenceBackend;
use crate::backend::Backend;
use crate::config::{BackendKind, Config, EngineKind, SpecPvConfig};
use crate::engine::{self, GenRequest};
use crate::json::Json;
use crate::kvstore::KvStore;
use crate::offload::OffloadSim;
use crate::util::stats::Samples;
use crate::{corpus, tokenizer};

use super::{fmt_speedup, measure, Table, SCHEMA_VERSION};

const OUTPUT_FILE: &str = "BENCH_kvstore.json";

/// Prompt length targeting the 1024 full bucket (prompt + max_new +
/// chunk + refresh headroom ≤ 1024 on the reference geometry).
const PROMPT_TOKENS: usize = 850;
const MAX_NEW: usize = 16;

fn prompt_req(be: &dyn Backend) -> (GenRequest, usize) {
    let text = corpus::continuation_prompt(1, 4 * PROMPT_TOKENS);
    let mut toks = tokenizer::encode(&text);
    toks.truncate(PROMPT_TOKENS);
    let req = GenRequest::greedy(toks, MAX_NEW);
    let need = crate::model::bucket_need(req.prompt.len(), req.max_new, be.consts());
    let bucket = crate::backend::pick_bucket(&be.full_buckets("s"), need, "full", "s")
        .expect("reference backend has a bucket for the bench prompt");
    (req, bucket)
}

/// Cold vs prefix-hit time-to-first-token (engine start = prefill + the
/// first pick, i.e. the TTFT the coordinator reports).
fn bench_ttft(
    be: &ReferenceBackend,
    warmup: usize,
    iters: usize,
) -> Result<(Samples, Samples, usize, KvStore)> {
    let cfg = Config {
        backend: BackendKind::Reference,
        engine: EngineKind::Autoregressive,
        ..Config::default()
    };
    let (req, bucket) = prompt_req(be);

    let cold = measure(warmup, iters, || {
        let session = engine::build(&cfg).start(be, &req, None)?;
        drop(session);
        Ok(())
    })?;

    let store = KvStore::new(64 << 20);
    // prime: one miss inserts the boundary snapshot
    drop(engine::build(&cfg).start(be, &req, Some(&store))?);
    let warm = measure(warmup, iters, || {
        let session = engine::build(&cfg).start(be, &req, Some(&store))?;
        drop(session);
        Ok(())
    })?;
    Ok((cold, warm, bucket, store))
}

/// Export/import of a full state at the bench bucket.
fn bench_snapshot(
    be: &ReferenceBackend,
    warmup: usize,
    iters: usize,
) -> Result<(Samples, Samples, usize)> {
    let (req, _bucket) = prompt_req(be);
    let mut target = crate::engine::session::TargetSession::new(
        be,
        "s",
        crate::model::bucket_need(req.prompt.len(), req.max_new, be.consts()),
        OffloadSim::new(Default::default()),
    )?;
    target.prefill(&req.prompt, None, None)?;
    let mut bytes = 0usize;
    let export = measure(warmup, iters, || {
        let snap = target.export()?;
        bytes = snap.bytes();
        Ok(())
    })?;
    let snap = target.export()?;
    let import = measure(warmup, iters, || {
        target.restore(&snap)?;
        Ok(())
    })?;
    Ok((export, import, bytes))
}

/// Swap round-trip (suspend → resume) on a live spec_pv session, with a
/// byte-identity check against an undisturbed run.
fn bench_swap(be: &ReferenceBackend, iters: usize) -> Result<(Samples, Samples, usize)> {
    let cfg = Config {
        backend: BackendKind::Reference,
        engine: EngineKind::SpecPv,
        specpv: SpecPvConfig { retrieval_budget: 64, ..SpecPvConfig::default() },
        ..Config::default()
    };
    let text = corpus::continuation_prompt(2, 2400);
    let mut toks = tokenizer::encode(&text);
    toks.truncate(600);
    let req = GenRequest::greedy(toks, 32);

    let baseline = engine::generate_with(&cfg, be, &req)?;

    let mut session = engine::build(&cfg).start(be, &req, None)?;
    session.step()?;
    let state_bytes = session.state_bytes();
    let mut out_s = Samples::default();
    let mut in_s = Samples::default();
    for _ in 0..iters {
        if session.is_finished() {
            break;
        }
        let t0 = Instant::now();
        let snaps = session.suspend()?;
        out_s.push(t0.elapsed().as_secs_f64());
        let t1 = Instant::now();
        session.resume(snaps)?;
        in_s.push(t1.elapsed().as_secs_f64());
        session.step()?;
    }
    while !session.is_finished() {
        session.step()?;
    }
    let swapped = session.finish();
    if swapped.tokens != baseline.tokens {
        bail!(
            "swap round-trip changed the output ({} vs {} tokens)",
            swapped.tokens.len(),
            baseline.tokens.len()
        );
    }
    Ok((out_s, in_s, state_bytes))
}

/// Drive the kvstore bench; see the module docs for outputs.
pub fn run(out_dir: &Path, quick: bool) -> Result<()> {
    let (warmup, iters, swap_iters) = if quick { (1, 3, 4) } else { (2, 8, 10) };
    let be = ReferenceBackend::new();
    eprintln!("[bench kvstore] {}", be.describe());

    let (cold, warm, bucket, store) = bench_ttft(&be, warmup, iters)?;
    let speedup = if warm.mean() > 0.0 { cold.mean() / warm.mean() } else { 0.0 };
    let ps = store.stats();
    let mut ttft_table = Table::new(
        "KV state manager: prefix-hit vs cold-prefill TTFT",
        &["path", "mean ms", "p50 ms", "p95 ms"],
    );
    let mut ttft_rows = Vec::new();
    for (name, s) in [("cold_prefill", &cold), ("prefix_hit", &warm)] {
        let row = Json::obj()
            .set("path", name)
            .set("mean_ms", s.mean() * 1e3)
            .set("p50_ms", s.p50() * 1e3)
            .set("p95_ms", s.p95() * 1e3)
            .set("prompt_tokens", PROMPT_TOKENS)
            .set("bucket", bucket);
        ttft_table.row(
            vec![
                name.to_string(),
                format!("{:.3}", s.mean() * 1e3),
                format!("{:.3}", s.p50() * 1e3),
                format!("{:.3}", s.p95() * 1e3),
            ],
            row.clone(),
        );
        ttft_rows.push(row);
    }
    ttft_table.emit(out_dir, "kvstore_ttft")?;
    eprintln!(
        "[bench kvstore] prefix-hit TTFT speedup at b{bucket}: {} \
         ({} hits / {} misses, {} entries, {} bytes cached)",
        fmt_speedup(speedup),
        ps.hits,
        ps.misses,
        ps.entries,
        ps.bytes
    );

    let (export, import, snap_bytes) = bench_snapshot(&be, warmup, iters)?;
    let (swap_out, swap_in, session_bytes) = bench_swap(&be, swap_iters)?;
    let mut costs = Table::new(
        "KV state manager: snapshot + swap round-trip costs",
        &["op", "mean ms", "bytes"],
    );
    let mut cost_rows = Vec::new();
    for (name, s, bytes) in [
        ("export_state", &export, snap_bytes),
        ("import_state", &import, snap_bytes),
        ("swap_out", &swap_out, session_bytes),
        ("swap_in", &swap_in, session_bytes),
    ] {
        let row = Json::obj()
            .set("op", name)
            .set("mean_ms", s.mean() * 1e3)
            .set("bytes", bytes);
        costs.row(
            vec![name.to_string(), format!("{:.3}", s.mean() * 1e3), format!("{bytes}")],
            row.clone(),
        );
        cost_rows.push(row);
    }
    costs.emit(out_dir, "kvstore_costs")?;

    let combined = Json::obj()
        .set("schema_version", SCHEMA_VERSION)
        .set("prompt_tokens", PROMPT_TOKENS)
        .set("bucket", bucket)
        .set("ttft_speedup", speedup)
        .set("ttft", Json::Arr(ttft_rows))
        .set("costs", Json::Arr(cost_rows))
        .set("prefix_hits", ps.hits as i64)
        .set("prefix_misses", ps.misses as i64);
    std::fs::write(OUTPUT_FILE, combined.to_string())?;
    eprintln!("[bench kvstore] wrote {OUTPUT_FILE}");

    if warm.mean() >= cold.mean() {
        bail!(
            "prefix-hit TTFT ({:.3} ms) is not below cold-prefill TTFT ({:.3} ms)",
            warm.mean() * 1e3,
            cold.mean() * 1e3
        );
    }
    Ok(())
}
