//! `specpv bench serve` — cross-session batched decode throughput
//! (DESIGN.md §12).
//!
//! Sweeps the continuous-batching width over batch ∈ {1, 2, 4, 8}
//! concurrent spec_pv sessions at the CI geometry on the reference
//! backend and reports, per width: aggregate decode tok/s, p95
//! per-session step latency (each session takes exactly one step per
//! coordinator tick, so tick latency *is* the per-session step latency),
//! the fraction of kernel ops executed fused, and the mean fused-group
//! width. Emits `results/serve.{md,json}` plus the schema-versioned
//! `BENCH_serve.json` at the repo root (uploaded by the CI perf-smoke
//! job), and **hard-fails** unless batch=4 aggregate throughput is
//! strictly greater than batch=1 — batching must be a win, not a wash.
//!
//! The clock starts after the first tick (which pays admission +
//! prefill), so the sweep measures the decode path the batched kernels
//! actually fuse; prefill fusion is exercised at the op level by
//! `bench backend` and `rust/tests/batched_parity.rs`.
//!
//! A second sweep exercises **sharded serving** (DESIGN.md §14): the
//! same total session count is driven through shards ∈ {1, 2, 4} real
//! worker-shard loops — each shard its own reference backend (pinned to
//! one compute thread) + coordinator, sessions placed by the
//! prefix-affinity router — reporting aggregate tok/s and p95 TTFT per
//! shard count. A second hard gate requires shards=2 to strictly beat
//! shards=1 aggregate throughput: sharding must buy real parallelism.
//!
//! A third leg measures **failover recovery** (DESIGN.md §15): a
//! supervised single-shard server with a `shard_panic` failpoint armed
//! mid-stream on a ≥ 1024-token prompt, once resuming from the periodic
//! paged-KV checkpoint and once regenerating from the prompt. The
//! client-visible stall (largest inter-delta gap) lands in the report;
//! a third hard gate requires the checkpoint path to be strictly faster
//! than regeneration.
//!
//! A fourth, **cold-restart** leg exercises the durability layer
//! (DESIGN.md §17): with the write-ahead journal on, a streaming
//! generation is cut down mid-flight by the crash-equivalent abort
//! hook, a second server incarnation recovers it from the journal, and
//! a reconnecting `generate_retry` client times the stall to its first
//! resumed token — once resuming from the durable checkpoint store and
//! once regenerating deterministically from the journal alone. The
//! final hard gate requires the checkpoint restart to strictly beat
//! regeneration on a ≥ 1024-token prompt.

use std::net::TcpListener;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::backend::reference::ReferenceBackend;
use crate::backend::Backend;
use crate::config::{BackendKind, Config, EngineKind, JournalFsync, SpecPvConfig};
use crate::coordinator::{Coordinator, Event};
use crate::engine::GenRequest;
use crate::json::Json;
use crate::serve::router::Router;
use crate::serve::shard::{run_shard, FrontEvent, ShardHandle, SubmitReq};
use crate::server::Client;
use crate::util::stats::Samples;
use crate::{corpus, tokenizer};

use super::{fmt_speedup, Table, SCHEMA_VERSION};

/// The rolling per-PR output (repo root; uploaded as a CI artifact).
const OUTPUT_FILE: &str = "BENCH_serve.json";

/// Continuous-batching widths swept.
const BATCHES: [usize; 4] = [1, 2, 4, 8];

/// Shard counts swept by the sharded-serving leg.
const SHARDS: [usize; 3] = [1, 2, 4];

/// Total concurrent sessions driven through the shard sweep (split
/// across shards by the router).
const SHARD_SESSIONS: usize = 8;

/// CI-geometry request shape: enough prompt to be long-context shaped at
/// the reference scale, enough decode for the batched path to dominate.
const PROMPT_BYTES: usize = 200;
const MAX_NEW: usize = 32;

struct RunStats {
    tokens: usize,
    tok_s: f64,
    p95_step_ms: f64,
    batched_frac: f64,
    mean_width: f64,
}

/// One sweep point: `batch` concurrent sessions driven to completion.
fn run_one(be: &ReferenceBackend, batch: usize, threads: usize) -> Result<RunStats> {
    let cfg = Config {
        backend: BackendKind::Reference,
        engine: EngineKind::SpecPv,
        specpv: SpecPvConfig { retrieval_budget: 64, ..SpecPvConfig::default() },
        max_active: batch,
        // distinct prompts per session: keep the prefix cache out of the
        // measurement so every width pays identical prefill work
        prefix_cache_bytes: 0,
        threads,
        ..Config::default()
    };
    let mut coord = Coordinator::new(be, cfg);
    for s in 0..batch {
        let prompt = corpus::continuation_prompt(s as u64 + 1, PROMPT_BYTES);
        coord.submit(GenRequest::greedy(tokenizer::encode(&prompt), MAX_NEW), None)?;
    }
    // the first tick pays admission + prefill (+ one decode round); the
    // clock starts after it so the sweep isolates decode throughput
    for ev in coord.tick() {
        if let Event::Failed { error, .. } = ev {
            bail!("bench session failed during admission: {error}");
        }
    }
    let mut tokens = 0usize;
    let mut steps = Samples::default();
    let t0 = Instant::now();
    while !coord.idle() {
        let ts = Instant::now();
        let evs = coord.tick();
        steps.push(ts.elapsed().as_secs_f64());
        for ev in evs {
            match ev {
                Event::Step { new_tokens, .. } => tokens += new_tokens.len(),
                Event::Failed { error, .. } => bail!("bench session failed: {error}"),
                _ => {}
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    Ok(RunStats {
        tokens,
        tok_s: tokens as f64 / secs.max(1e-9),
        p95_step_ms: steps.p95() * 1e3,
        batched_frac: coord.registry.batched_frac(),
        mean_width: coord.registry.batch_mean_width(),
    })
}

struct ShardRunStats {
    tokens: usize,
    tok_s: f64,
    p95_ttft_ms: f64,
    routed_away: u64,
}

/// One shard-sweep point: `shards` real worker-shard loops, each its own
/// reference backend (pinned to one compute thread so added shards are
/// the only source of parallelism) + coordinator, with all
/// [`SHARD_SESSIONS`] sessions placed by the prefix-affinity router and
/// driven to completion through the shard command/event channels.
fn run_shards(shards: usize) -> Result<ShardRunStats> {
    let (ev_tx, ev_rx) = channel::<FrontEvent>();
    let mut handles = Vec::with_capacity(shards);
    let mut rxs = Vec::with_capacity(shards);
    for i in 0..shards {
        let (tx, rx) = channel();
        handles.push(ShardHandle::new(i, tx));
        rxs.push(rx);
    }
    let mut router = Router::new(shards, 1.25);
    let t0 = Instant::now();
    thread::scope(move |s| -> Result<ShardRunStats> {
        for (i, rx) in rxs.into_iter().enumerate() {
            let tx = ev_tx.clone();
            s.spawn(move || {
                let be = ReferenceBackend::with_threads(1);
                let cfg = Config {
                    backend: BackendKind::Reference,
                    engine: EngineKind::SpecPv,
                    specpv: SpecPvConfig {
                        retrieval_budget: 64,
                        ..SpecPvConfig::default()
                    },
                    max_active: SHARD_SESSIONS,
                    // distinct prompts: keep the prefix cache out of the
                    // measurement
                    prefix_cache_bytes: 0,
                    threads: 1,
                    ..Config::default()
                };
                let mut coord = Coordinator::new(&be, cfg);
                run_shard(i, &mut coord, rx, tx);
            });
        }
        drop(ev_tx);
        for sid in 0..SHARD_SESSIONS {
            let prompt = corpus::continuation_prompt(sid as u64 + 1, PROMPT_BYTES);
            let toks = tokenizer::encode(&prompt);
            let place = router.place(&toks);
            handles[place.shard].submit(SubmitReq {
                gid: sid as u64,
                conn: 0,
                gen: GenRequest::greedy(toks, MAX_NEW),
                engine: None,
                auto: false,
                stream: false,
                deadline_secs: None,
                priority: 0,
                resume: None,
                skip_tokens: 0,
                ack_sent: false,
            });
        }
        let mut done = 0usize;
        let mut tokens = 0usize;
        let mut ttfts = Samples::default();
        while done < SHARD_SESSIONS {
            match ev_rx.recv() {
                Ok(FrontEvent::Line { line, .. }) => {
                    let j = Json::parse(line.trim())?;
                    if j.get("ok").and_then(|x| x.as_bool()) != Some(true) {
                        bail!("shard bench request failed: {}", line.trim());
                    }
                    tokens += j.get("tokens").and_then(|x| x.as_usize()).unwrap_or(0);
                    if let Some(t) = j.get("ttft_s").and_then(|x| x.as_f64()) {
                        ttfts.push(t);
                    }
                }
                Ok(FrontEvent::Terminal { .. }) => done += 1,
                Ok(_) => {}
                Err(_) => bail!("shard event channel closed early"),
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        for h in &handles {
            h.drain();
        }
        Ok(ShardRunStats {
            tokens,
            tok_s: tokens as f64 / secs.max(1e-9),
            p95_ttft_ms: ttfts.p95() * 1e3,
            routed_away: router.routed_away(),
        })
    })
}

/// Recovery-leg request geometry: a long-context-shaped prompt (the
/// byte-level tokenizer makes bytes = tokens, so this is ≥ 1024 prompt
/// tokens — the regime where checkpoint failover must beat regeneration)
/// and enough decode to straddle the injected panic.
const RECOVERY_PROMPT_BYTES: usize = 1280;
const RECOVERY_MAX_NEW: usize = 24;
/// The injected shard panic lands after this many scheduler steps.
const RECOVERY_PANIC_STEP: usize = 12;

/// One recovery measurement: a supervised single-shard server
/// (reference backend, `ar` engine) with a `shard_panic` failpoint armed
/// mid-stream. A streaming client times the largest gap between
/// consecutive delta lines — detection → restart → failover → first
/// post-recovery token — and the final text is checked for completeness
/// (byte-determinism across the failover). Returns
/// `(prompt_tokens, recovery_ms)`.
fn run_recovery(checkpoint_every: usize) -> Result<(usize, f64)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let cfg = Config {
        backend: BackendKind::Reference,
        engine: EngineKind::Autoregressive,
        shards: 1,
        threads: 1,
        prefix_cache_bytes: 0,
        max_new_tokens: RECOVERY_MAX_NEW,
        checkpoint_every_steps: checkpoint_every,
        faults: format!("shard_panic@step={RECOVERY_PANIC_STEP}"),
        ..Config::default()
    };
    let runtime = crate::serve::backend_runtime(&cfg);
    let server =
        thread::spawn(move || crate::serve::serve_supervised(listener, cfg, runtime));
    let prompt = corpus::continuation_prompt(7, RECOVERY_PROMPT_BYTES);
    let ptoks = tokenizer::encode(&prompt).len();
    if ptoks < 1024 {
        bail!("recovery prompt too short: {ptoks} tokens (need >= 1024)");
    }
    let mut c = Client::connect(&addr)?;
    c.send(
        Json::obj()
            .set("op", "generate")
            .set("prompt", prompt.as_str())
            .set("max_new", RECOVERY_MAX_NEW)
            .set("engine", "ar")
            .set("stream", true),
    )?;
    let mut deltas = 0usize;
    let mut text = String::new();
    let mut last = Instant::now();
    let mut max_gap = 0f64;
    let fin = loop {
        let j = c.recv()?;
        if j.get("done").and_then(|x| x.as_bool()) == Some(true)
            || j.get("ok").and_then(|x| x.as_bool()) == Some(false)
        {
            break j;
        }
        if let Some(d) = j.get("delta").and_then(|x| x.as_str()) {
            // the gap before the first delta is prefill, not recovery
            if deltas > 0 {
                max_gap = max_gap.max(last.elapsed().as_secs_f64());
            }
            last = Instant::now();
            deltas += 1;
            text.push_str(d);
        }
    };
    if fin.get("ok").and_then(|x| x.as_bool()) != Some(true) {
        bail!("recovery request failed: {fin:?}");
    }
    let fin_text = fin.get("text").and_then(|x| x.as_str()).unwrap_or("");
    if fin_text != text {
        bail!(
            "failover broke stream determinism: {} delta bytes vs {} final bytes",
            text.len(),
            fin_text.len()
        );
    }
    if fin.get("tokens").and_then(|x| x.as_usize()) != Some(RECOVERY_MAX_NEW) {
        bail!("recovery run truncated: {fin:?}");
    }
    c.shutdown()?;
    server
        .join()
        .map_err(|_| anyhow::anyhow!("recovery server panicked"))??;
    Ok((ptoks, max_gap * 1e3))
}

/// Cold-restart leg shape: long enough that the abort always lands
/// mid-generation (the client aborts after [`RESTART_ABORT_DELTAS`]
/// streamed lines, two orders of magnitude before completion).
const RESTART_MAX_NEW: usize = 192;
const RESTART_ABORT_DELTAS: usize = 6;

/// One cold-restart measurement (DESIGN.md §17): boot a journaled
/// single-shard server, stream a generation over a >= 1024-token
/// prompt, flip the crash-equivalent abort flag mid-stream (no drain,
/// no journal mark-clean), then boot a second incarnation over the same
/// journal dir and reattach with `generate_retry`. Returns
/// `(prompt_tokens, restart_ms)` where `restart_ms` spans second-boot
/// start (journal scan + resubmit + checkpoint resume or full
/// regeneration) to the first resumed delta reaching the client.
fn run_restart(checkpoint_every: usize) -> Result<(usize, f64)> {
    let dir = std::env::temp_dir().join(format!(
        "specpv-bench-restart-{}-{checkpoint_every}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    let cfg = Config {
        backend: BackendKind::Reference,
        engine: EngineKind::Autoregressive,
        shards: 1,
        threads: 1,
        prefix_cache_bytes: 0,
        max_new_tokens: RESTART_MAX_NEW,
        checkpoint_every_steps: checkpoint_every,
        journal_dir: dir.to_string_lossy().into_owned(),
        journal_fsync: JournalFsync::Never,
        ..Config::default()
    };

    // boot 1: stream until a few deltas arrive, then crash-equivalent
    // abort; drain the socket to EOF so the received prefix matches the
    // journaled delivered watermark exactly (partial tail lines drop)
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let abort = Arc::new(AtomicBool::new(false));
    let boot1 = {
        let cfg = cfg.clone();
        let abort = Arc::clone(&abort);
        let runtime = crate::serve::backend_runtime(&cfg);
        thread::spawn(move || {
            crate::serve::serve_supervised_abortable(listener, cfg, runtime, Some(abort))
        })
    };
    let prompt = corpus::continuation_prompt(11, RECOVERY_PROMPT_BYTES);
    let ptoks = tokenizer::encode(&prompt).len();
    if ptoks < 1024 {
        bail!("restart prompt too short: {ptoks} tokens (need >= 1024)");
    }
    let mut c = Client::connect(&addr)?;
    c.send(
        Json::obj()
            .set("op", "generate")
            .set("prompt", prompt.as_str())
            .set("max_new", RESTART_MAX_NEW)
            .set("engine", "ar")
            .set("stream", true),
    )?;
    let mut gid = None;
    let mut recv_text = String::new();
    let mut deltas = 0usize;
    loop {
        let j = match c.recv() {
            Ok(j) => j,
            // connection dropped by the abort; kernel-buffered full
            // lines were all consumed, a torn tail line failed to parse
            Err(_) => break,
        };
        if gid.is_none() {
            gid = j.get("id").and_then(|x| x.as_i64()).map(|v| v as u64);
        }
        if j.get("done").and_then(|x| x.as_bool()) == Some(true) {
            bail!("restart leg raced to completion before the abort; raise RESTART_MAX_NEW");
        }
        if let Some(d) = j.get("delta").and_then(|x| x.as_str()) {
            recv_text.push_str(d);
            deltas += 1;
            if deltas == RESTART_ABORT_DELTAS {
                abort.store(true, Ordering::SeqCst);
            }
        }
    }
    let gid = gid.ok_or_else(|| anyhow::anyhow!("no ack line before the abort"))?;
    boot1
        .join()
        .map_err(|_| anyhow::anyhow!("boot-1 server panicked"))??;

    // boot 2: same journal dir, fresh incarnation; the timer spans
    // recovery end to end as a reconnecting client observes it
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr2 = listener.local_addr()?.to_string();
    let start = Instant::now();
    let boot2 = {
        let cfg = cfg.clone();
        let runtime = crate::serve::backend_runtime(&cfg);
        thread::spawn(move || crate::serve::serve_supervised(listener, cfg, runtime))
    };
    let mut c2 = Client::connect(&addr2)?;
    c2.send(Json::obj().set("op", "generate_retry").set("id", gid as i64))?;
    let header = c2.recv()?;
    if header.get("retry").and_then(|x| x.as_bool()) != Some(true) {
        bail!("generate_retry rejected after restart: {header:?}");
    }
    let mut first_delta_ms = None;
    let mut resumed_text = String::new();
    let fin = loop {
        let j = c2.recv()?;
        if j.get("done").and_then(|x| x.as_bool()) == Some(true)
            || j.get("ok").and_then(|x| x.as_bool()) == Some(false)
        {
            break j;
        }
        if let Some(d) = j.get("delta").and_then(|x| x.as_str()) {
            if first_delta_ms.is_none() {
                first_delta_ms = Some(start.elapsed().as_secs_f64() * 1e3);
            }
            resumed_text.push_str(d);
        }
    };
    if fin.get("ok").and_then(|x| x.as_bool()) != Some(true) {
        bail!("resumed request failed: {fin:?}");
    }
    if fin.get("tokens").and_then(|x| x.as_usize()) != Some(RESTART_MAX_NEW) {
        bail!("resumed run truncated: {fin:?}");
    }
    // zero duplicated, zero lost: what boot 1 flushed plus what boot 2
    // replayed is byte-identical to the full generation
    let fin_text = fin.get("text").and_then(|x| x.as_str()).unwrap_or("");
    let joined = format!("{recv_text}{resumed_text}");
    if fin_text != joined {
        bail!(
            "cold restart broke byte identity: {} received + {} resumed bytes \
             vs {} final bytes",
            recv_text.len(),
            resumed_text.len(),
            fin_text.len()
        );
    }
    c2.shutdown()?;
    boot2
        .join()
        .map_err(|_| anyhow::anyhow!("boot-2 server panicked"))??;
    let _ = std::fs::remove_dir_all(&dir);
    let ms = first_delta_ms
        .ok_or_else(|| anyhow::anyhow!("resumed stream carried no delta lines"))?;
    Ok((ptoks, ms))
}

/// Drive the sweep; see the module docs for outputs and the hard gate.
pub fn run(out_dir: &Path, quick: bool, threads: usize) -> Result<()> {
    let iters = if quick { 1 } else { 3 };
    let be = if threads >= 1 {
        ReferenceBackend::with_threads(crate::util::pool::resolve_threads(threads))
    } else {
        ReferenceBackend::new()
    };
    eprintln!("[bench serve] {}", be.describe());

    let mut table = Table::new(
        "Cross-session batched decode (spec_pv @ CI geometry): throughput by batch width",
        &["batch", "agg tok/s", "p95 step ms", "speedup vs b1", "batched frac", "mean width"],
    );
    let mut rows = Vec::new();
    let mut base_tok_s = 0f64;
    let mut by_batch: Vec<(usize, f64)> = Vec::new();
    for &batch in &BATCHES {
        // best-of-iters: scheduler/OS noise only ever hurts throughput
        let mut best: Option<RunStats> = None;
        for _ in 0..iters {
            let r = run_one(&be, batch, threads)?;
            if best.as_ref().map(|b| r.tok_s > b.tok_s).unwrap_or(true) {
                best = Some(r);
            }
        }
        let r = best.expect("at least one iteration ran");
        if batch == 1 {
            base_tok_s = r.tok_s;
        }
        let speedup = if base_tok_s > 0.0 { r.tok_s / base_tok_s } else { 0.0 };
        let row_json = Json::obj()
            .set("batch", batch)
            .set("tokens", r.tokens)
            .set("agg_tok_s", r.tok_s)
            .set("p95_step_ms", r.p95_step_ms)
            .set("speedup_vs_b1", speedup)
            .set("batched_frac", r.batched_frac)
            .set("mean_width", r.mean_width);
        table.row(
            vec![
                batch.to_string(),
                format!("{:.1}", r.tok_s),
                format!("{:.3}", r.p95_step_ms),
                fmt_speedup(speedup),
                format!("{:.2}", r.batched_frac),
                format!("{:.2}", r.mean_width),
            ],
            row_json.clone(),
        );
        rows.push(row_json);
        by_batch.push((batch, r.tok_s));
    }
    table.emit(out_dir, "serve")?;

    // sharded-serving leg: same total sessions, split across real worker
    // shards by the prefix-affinity router
    let mut shard_table = Table::new(
        "Sharded serving (8 sessions, spec_pv, 1 compute thread per shard): throughput by shard count",
        &["shards", "agg tok/s", "p95 ttft ms", "speedup vs s1", "routed away"],
    );
    let mut shard_rows = Vec::new();
    let mut base_shard_tok_s = 0f64;
    let mut by_shards: Vec<(usize, f64)> = Vec::new();
    for &shards in &SHARDS {
        let mut best: Option<ShardRunStats> = None;
        for _ in 0..iters {
            let r = run_shards(shards)?;
            if best.as_ref().map(|b| r.tok_s > b.tok_s).unwrap_or(true) {
                best = Some(r);
            }
        }
        let r = best.expect("at least one iteration ran");
        if shards == 1 {
            base_shard_tok_s = r.tok_s;
        }
        let speedup =
            if base_shard_tok_s > 0.0 { r.tok_s / base_shard_tok_s } else { 0.0 };
        let row_json = Json::obj()
            .set("shards", shards)
            .set("sessions", SHARD_SESSIONS)
            .set("tokens", r.tokens)
            .set("agg_tok_s", r.tok_s)
            .set("p95_ttft_ms", r.p95_ttft_ms)
            .set("speedup_vs_s1", speedup)
            .set("routed_away", r.routed_away as i64);
        shard_table.row(
            vec![
                shards.to_string(),
                format!("{:.1}", r.tok_s),
                format!("{:.3}", r.p95_ttft_ms),
                fmt_speedup(speedup),
                r.routed_away.to_string(),
            ],
            row_json.clone(),
        );
        shard_rows.push(row_json);
        by_shards.push((shards, r.tok_s));
    }
    shard_table.emit(out_dir, "serve_shards")?;

    // recovery leg: injected mid-stream shard panic; compare failover
    // from the periodic paged-KV checkpoint against full deterministic
    // regeneration on a >= 1024-token prompt
    let mut rec_table = Table::new(
        "Failover recovery (1 shard, ar engine, shard_panic mid-stream, >=1024-token \
         prompt): client-visible stall by recovery path",
        &["path", "prompt toks", "recovery ms"],
    );
    let mut rec_rows = Vec::new();
    let mut rec_ms = [0f64; 2];
    for (slot, &(label, every)) in
        [("checkpoint", 4usize), ("regenerate", 0usize)].iter().enumerate()
    {
        // best-of-iters: noise only ever inflates the stall
        let mut best: Option<(usize, f64)> = None;
        for _ in 0..iters {
            let r = run_recovery(every)?;
            if best.map(|b| r.1 < b.1).unwrap_or(true) {
                best = Some(r);
            }
        }
        let (ptoks, ms) = best.expect("at least one iteration ran");
        rec_ms[slot] = ms;
        let row_json = Json::obj()
            .set("path", label)
            .set("checkpoint_every_steps", every)
            .set("prompt_tokens", ptoks)
            .set("panic_step", RECOVERY_PANIC_STEP)
            .set("recovery_ms", ms);
        rec_table.row(
            vec![label.to_string(), ptoks.to_string(), format!("{ms:.1}")],
            row_json.clone(),
        );
        rec_rows.push(row_json);
    }
    rec_table.emit(out_dir, "serve_recovery")?;

    // cold-restart leg: crash-equivalent abort mid-stream with the
    // write-ahead journal on, second boot recovers the session and a
    // reconnecting client measures time to the first resumed token —
    // durable-checkpoint resume vs full regeneration from the journal
    let mut restart_table = Table::new(
        "Cold restart (journaled 1-shard server, abort mid-stream, >=1024-token \
         prompt): time to first resumed token by recovery path",
        &["path", "prompt toks", "restart ms"],
    );
    let mut restart_rows = Vec::new();
    let mut restart_ms = [0f64; 2];
    for (slot, &(label, every)) in
        [("checkpoint", 4usize), ("regenerate", 0usize)].iter().enumerate()
    {
        // best-of-iters: noise only ever inflates the stall
        let mut best: Option<(usize, f64)> = None;
        for _ in 0..iters {
            let r = run_restart(every)?;
            if best.map(|b| r.1 < b.1).unwrap_or(true) {
                best = Some(r);
            }
        }
        let (ptoks, ms) = best.expect("at least one iteration ran");
        restart_ms[slot] = ms;
        let row_json = Json::obj()
            .set("path", label)
            .set("checkpoint_every_steps", every)
            .set("prompt_tokens", ptoks)
            .set("abort_after_deltas", RESTART_ABORT_DELTAS)
            .set("restart_ms", ms);
        restart_table.row(
            vec![label.to_string(), ptoks.to_string(), format!("{ms:.1}")],
            row_json.clone(),
        );
        restart_rows.push(row_json);
    }
    restart_table.emit(out_dir, "serve_restart")?;

    let combined = Json::obj()
        .set("schema_version", SCHEMA_VERSION)
        .set("threads", crate::util::pool::resolve_threads(threads))
        .set("engine", "spec_pv")
        .set("prompt_bytes", PROMPT_BYTES)
        .set("max_new", MAX_NEW)
        .set("rows", Json::Arr(rows))
        .set("shard_sessions", SHARD_SESSIONS)
        .set("shard_rows", Json::Arr(shard_rows))
        .set("recovery_rows", Json::Arr(rec_rows))
        .set("restart_rows", Json::Arr(restart_rows));
    std::fs::write(OUTPUT_FILE, combined.to_string())?;
    eprintln!("[bench serve] wrote {OUTPUT_FILE}");

    // hard gate: batching must be a strict aggregate-throughput win
    let tok = |b: usize| by_batch.iter().find(|(w, _)| *w == b).map(|(_, t)| *t).unwrap_or(0.0);
    let (b1, b4) = (tok(1), tok(4));
    if b4 <= b1 {
        bail!(
            "batched decode regression: batch=4 aggregate {b4:.1} tok/s is not \
             strictly greater than batch=1 {b1:.1} tok/s"
        );
    }
    eprintln!(
        "[bench serve] batch=4 vs batch=1 aggregate speedup: {}",
        fmt_speedup(b4 / b1)
    );

    // hard gate: sharding must be a strict aggregate-throughput win too
    let stok = |n: usize| {
        by_shards.iter().find(|(w, _)| *w == n).map(|(_, t)| *t).unwrap_or(0.0)
    };
    let (s1, s2) = (stok(1), stok(2));
    if s2 <= s1 {
        bail!(
            "sharded serving regression: shards=2 aggregate {s2:.1} tok/s is not \
             strictly greater than shards=1 {s1:.1} tok/s"
        );
    }
    eprintln!(
        "[bench serve] shards=2 vs shards=1 aggregate speedup: {}",
        fmt_speedup(s2 / s1)
    );

    // hard gate: for long prompts, resuming from the checkpoint must be
    // strictly faster than regenerating from scratch — otherwise the
    // checkpoint machinery is dead weight
    let (ck, regen) = (rec_ms[0], rec_ms[1]);
    if ck >= regen {
        bail!(
            "failover regression: checkpoint recovery {ck:.1} ms is not strictly \
             faster than full regeneration {regen:.1} ms on a >=1024-token prompt"
        );
    }
    eprintln!(
        "[bench serve] failover recovery: checkpoint {ck:.1} ms vs regenerate {regen:.1} ms"
    );

    // hard gate: across a cold restart, resuming from the durable
    // checkpoint must strictly beat regenerating the whole prefix —
    // otherwise persisting checkpoints buys nothing over the journal
    let (rck, rregen) = (restart_ms[0], restart_ms[1]);
    if rck >= rregen {
        bail!(
            "cold-restart regression: checkpoint restart {rck:.1} ms is not strictly \
             faster than full regeneration {rregen:.1} ms on a >=1024-token prompt"
        );
    }
    eprintln!(
        "[bench serve] cold restart: checkpoint {rck:.1} ms vs regenerate {rregen:.1} ms"
    );
    Ok(())
}
