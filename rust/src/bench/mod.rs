//! In-repo benchmark harness (criterion is not in the offline vendor
//! set). Provides warmup/measure loops, Markdown/JSON table emission and
//! the `results/` directory convention used by every paper-table driver,
//! plus the backend micro-bench behind `specpv bench backend`.

pub mod backend;
pub mod kvstore;
pub mod policy;
pub mod serve;

use std::fs;
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::json::Json;
use crate::util::stats::Samples;

/// Version stamp written into every emitted `*.json` result so
/// `BENCH_*.json` files are comparable across PRs; bump when the row
/// shape of any table changes incompatibly.
pub const SCHEMA_VERSION: usize = 1;

/// Measure a closure: `warmup` unrecorded runs, then `iters` recorded.
pub fn measure<F: FnMut() -> Result<()>>(
    warmup: usize,
    iters: usize,
    mut f: F,
) -> Result<Samples> {
    for _ in 0..warmup {
        f()?;
    }
    let mut s = Samples::default();
    for _ in 0..iters {
        let t0 = Instant::now();
        f()?;
        s.push(t0.elapsed().as_secs_f64());
    }
    Ok(s)
}

/// A rendered results table (rows of strings) with machine-readable rows.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub json_rows: Vec<Json>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            json_rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>, json: Json) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
        self.json_rows.push(json);
    }

    /// Render GitHub-flavoured Markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("## {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.headers.len())
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// Print to stdout and persist under `dir` as `<name>.md` + `<name>.json`.
    pub fn emit(&self, dir: &Path, name: &str) -> Result<()> {
        let md = self.to_markdown();
        println!("{md}");
        fs::create_dir_all(dir)?;
        fs::write(dir.join(format!("{name}.md")), &md)?;
        let j = self.to_json();
        fs::write(dir.join(format!("{name}.json")), j.to_string())?;
        Ok(())
    }

    /// Machine-readable form (the same object `emit` persists).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("schema_version", SCHEMA_VERSION)
            .set("title", self.title.as_str())
            .set(
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
            )
            .set("rows", Json::Arr(self.json_rows.clone()))
    }
}

/// Format a speedup multiple like the paper ("2.53×").
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts() {
        let mut n = 0;
        let s = measure(2, 5, || {
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 7);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn table_markdown() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(
            vec!["1".into(), "2".into()],
            Json::obj().set("a", 1usize).set("b", 2usize),
        );
        let md = t.to_markdown();
        assert!(md.contains("## demo"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic]
    fn table_row_arity_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()], Json::Null);
    }

    #[test]
    fn emitted_json_carries_schema_version() {
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["1".into()], Json::obj().set("a", 1usize));
        let j = t.to_json();
        assert_eq!(
            j.get("schema_version").and_then(|x| x.as_usize()),
            Some(SCHEMA_VERSION)
        );
        assert!(j.get("rows").and_then(|x| x.as_arr()).is_some());
    }
}
