//! `specpv bench policy` — sweeps the adaptive speculation policy
//! (DESIGN.md §16) against fixed configurations on three seeded scripted
//! workloads, in **virtual time**:
//!
//! * **short** — short prompts whose acceptance regime flips between a
//!   deep-friendly phase (ceiling 6) and a collapsed phase (ceiling 1):
//!   any fixed draft depth is a compromise across the phases; the
//!   adaptive controller tracks them.
//! * **long** — the same phase structure under long-context costs
//!   (expensive verify, expensive drafts), where a wrong depth is
//!   costlier.
//! * **drifty** — a SpecPV-shaped workload whose acceptance ceiling
//!   decays with rounds since the last full-verification refresh: the
//!   fixed refresh period lets acceptance rot between refreshes; the
//!   drift-triggered refresh re-anchors as soon as the accumulated
//!   acceptance shortfall crosses the threshold.
//!
//! Every run drives real coordinator scheduling (policy tick, per-session
//! controllers, registry counters) over [`ScriptedFactory`] sessions with
//! a [`SpecSim`] acceptance stream; throughput is computed from the sim's
//! virtual per-round costs, so results are byte-deterministic and never
//! flake on loaded CI machines.
//!
//! Gates (`--check` hard-fails):
//! * adaptive aggregate tok/s ≥ the best fixed configuration on **every**
//!   workload;
//! * on **drifty**, drift-triggered refresh **strictly** beats the best
//!   fixed-period configuration.
//!
//! Emits `results/policy.{md,json}` and a schema-versioned
//! `BENCH_policy.json` at the current directory (the repo root in CI).

use std::path::Path;

use anyhow::{bail, Result};

use crate::config::{Config, EngineKind, PolicyConfig, PolicyMode};
use crate::coordinator::Coordinator;
use crate::engine::scripted::{ScriptedFactory, SpecSim};
use crate::engine::GenRequest;
use crate::json::Json;

use super::{Table, SCHEMA_VERSION};

const OUTPUT_FILE: &str = "BENCH_policy.json";

/// Concurrent scripted sessions per run.
const SESSIONS: usize = 4;
/// Fixed draft depths swept against the adaptive controller.
const DEPTHS: [usize; 4] = [1, 2, 4, 6];

struct Workload {
    name: &'static str,
    prompt_len: usize,
    sim: SpecSim,
}

/// Phase-flipping acceptance ceilings: `hi_rounds` rounds at ceiling 6,
/// then `lo_rounds` at ceiling 1, cycled.
fn phased_accepts(hi_rounds: usize, lo_rounds: usize) -> Vec<usize> {
    let mut v = vec![6; hi_rounds];
    v.extend(std::iter::repeat(1).take(lo_rounds));
    v
}

fn workloads() -> Vec<Workload> {
    vec![
        Workload {
            name: "short",
            prompt_len: 16,
            sim: SpecSim {
                accepts: phased_accepts(16, 16),
                decay_every: 0,
                depth: 2,
                refresh_every: 0,
                draft_us: 20.0,
                verify_us: 100.0,
                refresh_us: 400.0,
            },
        },
        Workload {
            name: "long",
            prompt_len: 2000,
            sim: SpecSim {
                accepts: phased_accepts(16, 16),
                decay_every: 0,
                depth: 2,
                refresh_every: 0,
                draft_us: 45.0,
                verify_us: 300.0,
                refresh_us: 900.0,
            },
        },
        Workload {
            name: "drifty",
            prompt_len: 800,
            sim: SpecSim {
                accepts: vec![5],
                decay_every: 2,
                depth: 4,
                refresh_every: 12,
                draft_us: 10.0,
                verify_us: 100.0,
                refresh_us: 500.0,
            },
        },
    ]
}

/// Policy knobs used by the sweep: tight adjustment cadence so the
/// controller tracks the scripted phase flips within a phase.
fn policy_cfg(mode: PolicyMode) -> PolicyConfig {
    PolicyConfig {
        mode,
        draft_min: 1,
        draft_max: 6,
        alpha: 0.5,
        grow: 0.8,
        shrink: 0.35,
        adjust_every: 1,
        drift_threshold: 1.5,
        ..PolicyConfig::default()
    }
}

struct RunResult {
    tok_s: f64,
    tokens: usize,
    depth_moves: u64,
    forced_refreshes: u64,
}

/// Drive `SESSIONS` scripted sessions through a coordinator under the
/// given policy mode; aggregate tok/s is Σ tokens / Σ virtual decode
/// seconds over the completed requests.
fn run_one(
    sim: &SpecSim,
    prompt_len: usize,
    mode: PolicyMode,
    max_new: usize,
) -> Result<RunResult> {
    let cfg = Config {
        engine: EngineKind::SpecPv,
        max_active: SESSIONS,
        policy: policy_cfg(mode),
        ..Config::default()
    };
    let factory =
        ScriptedFactory { spec: Some(sim.clone()), ..ScriptedFactory::default() };
    let mut coord = Coordinator::with_factory(cfg, Box::new(factory));
    let mut ids = Vec::with_capacity(SESSIONS);
    for i in 0..SESSIONS {
        let req = GenRequest::greedy(vec![1 + i as u32; prompt_len.max(1)], max_new);
        ids.push(coord.submit(req, None)?);
    }
    coord.run_all();
    let mut tokens = 0usize;
    let mut secs = 0.0f64;
    for id in ids {
        let tr = coord.get(id).expect("request tracked");
        let Some(r) = tr.result.as_ref() else {
            bail!("bench request {id} finished without a result ({:?})", tr.state);
        };
        tokens += r.tokens.len();
        secs += r.stats.decode_secs;
    }
    Ok(RunResult {
        tok_s: tokens as f64 / secs.max(1e-12),
        tokens,
        depth_moves: coord.registry.policy_depth_changes,
        forced_refreshes: coord.registry.policy_refreshes,
    })
}

pub fn run(out: &Path, quick: bool, check: bool) -> Result<()> {
    let max_new = if quick { 240 } else { 600 };
    let mut table = Table::new(
        "Adaptive speculation policy vs fixed configurations \
         (virtual time, scripted acceptance streams)",
        &["workload", "config", "tok/s (virtual)", "tokens", "depth moves", "forced refreshes"],
    );
    let mut gate_failures: Vec<String> = Vec::new();
    let mut gates = Vec::new();
    for w in workloads() {
        let mut best_fixed = f64::NEG_INFINITY;
        let mut best_depth = 0usize;
        for &d in &DEPTHS {
            let sim = SpecSim { depth: d, ..w.sim.clone() };
            let r = run_one(&sim, w.prompt_len, PolicyMode::Fixed, max_new)?;
            if r.tok_s > best_fixed {
                best_fixed = r.tok_s;
                best_depth = d;
            }
            table.row(
                vec![
                    w.name.into(),
                    format!("fixed d={d}"),
                    format!("{:.0}", r.tok_s),
                    r.tokens.to_string(),
                    "-".into(),
                    "-".into(),
                ],
                Json::obj()
                    .set("workload", w.name)
                    .set("config", &*format!("fixed_d{d}"))
                    .set("tok_s", r.tok_s)
                    .set("tokens", r.tokens),
            );
        }
        let a = run_one(&w.sim, w.prompt_len, PolicyMode::Adaptive, max_new)?;
        table.row(
            vec![
                w.name.into(),
                "adaptive".into(),
                format!("{:.0}", a.tok_s),
                a.tokens.to_string(),
                a.depth_moves.to_string(),
                a.forced_refreshes.to_string(),
            ],
            Json::obj()
                .set("workload", w.name)
                .set("config", "adaptive")
                .set("tok_s", a.tok_s)
                .set("tokens", a.tokens)
                .set("depth_moves", a.depth_moves as i64)
                .set("forced_refreshes", a.forced_refreshes as i64),
        );
        let margin = a.tok_s / best_fixed;
        println!(
            "[policy:{}] adaptive {:.0} tok/s vs best fixed d={} {:.0} tok/s ({:.2}x)",
            w.name, a.tok_s, best_depth, best_fixed, margin
        );
        // gate: adaptive must not lose to any fixed configuration
        // (1e-9 relative slack absorbs summation-order noise only)
        if a.tok_s < best_fixed * (1.0 - 1e-9) {
            gate_failures.push(format!(
                "{}: adaptive {:.1} tok/s < best fixed d={} {:.1} tok/s",
                w.name, a.tok_s, best_depth, best_fixed
            ));
        }
        // gate: on the drifty workload the drift-triggered refresh must
        // STRICTLY beat every fixed refresh period
        if w.name == "drifty" {
            if a.forced_refreshes == 0 {
                gate_failures.push(
                    "drifty: adaptive run never forced a drift refresh".to_string(),
                );
            }
            if a.tok_s <= best_fixed {
                gate_failures.push(format!(
                    "drifty: adaptive {:.1} tok/s does not strictly beat \
                     best fixed {:.1} tok/s",
                    a.tok_s, best_fixed
                ));
            }
        }
        gates.push(
            Json::obj()
                .set("workload", w.name)
                .set("adaptive_tok_s", a.tok_s)
                .set("best_fixed_tok_s", best_fixed)
                .set("best_fixed_depth", best_depth)
                .set("margin", margin),
        );
    }
    table.emit(out, "policy")?;
    let bench = Json::obj()
        .set("schema_version", SCHEMA_VERSION)
        .set("bench", "policy")
        .set("quick", quick)
        .set("sessions", SESSIONS)
        .set("max_new", max_new)
        .set("gates", Json::Arr(gates))
        .set("gates_ok", gate_failures.is_empty())
        .set("table", table.to_json());
    std::fs::write(OUTPUT_FILE, bench.to_string())?;
    println!("wrote {OUTPUT_FILE}");
    if !gate_failures.is_empty() {
        let msg = gate_failures.join("; ");
        if check {
            bail!("bench policy gates failed: {msg}");
        }
        eprintln!("[bench policy] WARNING: gates failed: {msg}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_beats_best_fixed_on_every_workload() {
        // the CI gate, exercised at quick scale so `cargo test` catches a
        // controller regression before the perf-smoke job does
        let max_new = 240;
        for w in workloads() {
            let mut best_fixed = f64::NEG_INFINITY;
            for &d in &DEPTHS {
                let sim = SpecSim { depth: d, ..w.sim.clone() };
                let r = run_one(&sim, w.prompt_len, PolicyMode::Fixed, max_new).unwrap();
                best_fixed = best_fixed.max(r.tok_s);
            }
            let a = run_one(&w.sim, w.prompt_len, PolicyMode::Adaptive, max_new).unwrap();
            assert!(
                a.tok_s >= best_fixed * (1.0 - 1e-9),
                "{}: adaptive {:.1} < best fixed {:.1}",
                w.name,
                a.tok_s,
                best_fixed
            );
            if w.name == "drifty" {
                assert!(a.tok_s > best_fixed, "drifty gate must be strict");
                assert!(a.forced_refreshes > 0, "drift refresh must fire");
            }
        }
    }

    #[test]
    fn virtual_time_runs_are_deterministic() {
        let w = &workloads()[0];
        let a = run_one(&w.sim, w.prompt_len, PolicyMode::Adaptive, 120).unwrap();
        let b = run_one(&w.sim, w.prompt_len, PolicyMode::Adaptive, 120).unwrap();
        assert_eq!(a.tok_s.to_bits(), b.tok_s.to_bits());
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.depth_moves, b.depth_moves);
        assert_eq!(a.forced_refreshes, b.forced_refreshes);
    }
}
