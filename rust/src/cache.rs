//! KV-cache bookkeeping. The tensors themselves live on the PJRT device
//! (flat-state buffers threaded between executables — see runtime); this
//! module owns the *accounting*: committed lengths, pending-acceptance
//! compaction indices, partial-cache segment map (sink/retrieval/local/
//! buffer, paper §3.2) and the paged block arithmetic — all pure logic
//! with invariant checks, unit-testable without artifacts.

use anyhow::{bail, Result};

/// Accounting for a full (bucketed) target KV cache.
///
/// Invariants:
/// * `committed + pending.len() + headroom ≤ bucket`
/// * `pending` holds strictly-increasing row offsets (< window) of the
///   accepted rows of the last verification step's tree region, which the
///   NEXT verify call compacts (fused) before appending.
#[derive(Debug, Clone)]
pub struct FullCache {
    pub bucket: usize,
    pub committed: usize,
    pub pending: Vec<usize>,
}

impl FullCache {
    pub fn new(bucket: usize) -> FullCache {
        FullCache { bucket, committed: 0, pending: Vec::new() }
    }

    /// Length after the pending rows commit.
    pub fn effective_len(&self) -> usize {
        self.committed + self.pending.len()
    }

    /// Record a prefill chunk (rows written contiguously; no compaction).
    pub fn push_prefill(&mut self, n: usize) -> Result<()> {
        if !self.pending.is_empty() {
            bail!("prefill with pending acceptance");
        }
        if self.committed + n > self.bucket {
            bail!(
                "bucket overflow: {} + {n} > {}",
                self.committed,
                self.bucket
            );
        }
        self.committed += n;
        Ok(())
    }

    /// Consume the pending set for a fused-compaction verify call:
    /// returns (kv_len, prev_idx padded to `prev_max`, n_prev) and
    /// advances `committed`.
    pub fn take_pending(
        &mut self,
        prev_max: usize,
    ) -> Result<(usize, Vec<i32>, usize)> {
        let n = self.pending.len();
        if n > prev_max {
            bail!("pending {n} exceeds fused window {prev_max}");
        }
        let kv_len = self.committed;
        let mut idx: Vec<i32> = self.pending.iter().map(|&i| i as i32).collect();
        idx.resize(prev_max, 0);
        self.committed += n;
        self.pending.clear();
        Ok((kv_len, idx, n))
    }

    /// Record this step's accepted tree rows (for the next call).
    pub fn set_pending(&mut self, rows: Vec<usize>, window: usize) -> Result<()> {
        if !self.pending.is_empty() {
            bail!("pending already set");
        }
        let mut prev = None;
        for &r in &rows {
            if r >= window {
                bail!("pending row {r} outside window {window}");
            }
            if let Some(p) = prev {
                if r <= p {
                    bail!("pending rows not strictly increasing");
                }
            }
            prev = Some(r);
        }
        if self.committed + rows.len() > self.bucket {
            bail!("bucket overflow on acceptance");
        }
        self.pending = rows;
        Ok(())
    }

    /// Immediate commit (standalone `commit_*` executable path, used after
    /// Refresh steps): advances committed by `n` and clears pending.
    pub fn commit_now(&mut self, n: usize) -> Result<()> {
        if self.committed + n > self.bucket {
            bail!("bucket overflow on commit");
        }
        self.committed += n;
        self.pending.clear();
        Ok(())
    }

    /// Room left for new rows (tree + compaction slack).
    pub fn headroom(&self) -> usize {
        self.bucket - self.effective_len()
    }
}

/// Accounting for the SpecPV partial cache (one device buffer holding
/// sink ++ retrieval ++ local ++ buffer, contiguous in token order).
#[derive(Debug, Clone)]
pub struct PartialCache {
    /// partial bucket size P (compiled)
    pub bucket: usize,
    /// valid tokens in the gathered core (≤ core capacity)
    pub core_len: usize,
    /// committed tokens in the buffer region
    pub buf_committed: usize,
    /// pending accepted rows of the last partial step (fused compaction)
    pub pending: Vec<usize>,
    /// tokens partially verified since the last refresh (pv chain,
    /// including per-step bonus tokens) — re-verified at the next Refresh
    pub pv_tokens: Vec<u32>,
    /// buffer capacity before a Refresh is forced (paper §3.3/§4.4)
    pub buffer_cap: usize,
}

impl PartialCache {
    pub fn new(bucket: usize, buffer_cap: usize) -> PartialCache {
        PartialCache {
            bucket,
            core_len: 0,
            buf_committed: 0,
            pending: Vec::new(),
            pv_tokens: Vec::new(),
            buffer_cap,
        }
    }

    /// Reset after a refresh+gather with a fresh core of `core_len` tokens.
    pub fn refresh(&mut self, core_len: usize) {
        self.core_len = core_len;
        self.buf_committed = 0;
        self.pending.clear();
        self.pv_tokens.clear();
    }

    /// kv_len for the next partial verify (committed core + buffer).
    pub fn kv_len(&self) -> usize {
        self.core_len + self.buf_committed
    }

    /// Would a tree of `t` tokens still fit the buffer (slots + cap)?
    /// Paper Alg. 1 `SelectMode`: when it does not, Refresh is selected.
    pub fn fits(&self, t: usize, prev_max: usize) -> bool {
        let after_pending = self.kv_len() + self.pending.len();
        let slots_ok = after_pending + t <= self.bucket;
        let cap_ok = self.pv_tokens.len() + t <= self.buffer_cap;
        let fused_ok = self.pending.len() <= prev_max;
        slots_ok && cap_ok && fused_ok
    }

    pub fn take_pending(
        &mut self,
        prev_max: usize,
    ) -> Result<(usize, Vec<i32>, usize)> {
        let n = self.pending.len();
        if n > prev_max {
            bail!("partial pending {n} exceeds fused window {prev_max}");
        }
        let kv_len = self.kv_len();
        let mut idx: Vec<i32> = self.pending.iter().map(|&i| i as i32).collect();
        idx.resize(prev_max, 0);
        self.buf_committed += n;
        self.pending.clear();
        Ok((kv_len, idx, n))
    }

    /// Record this step's accepted tree rows (for the next call). Rows
    /// must be strictly increasing and inside the fused-compaction
    /// `window` — the same validation (and error shapes) as
    /// [`FullCache::set_pending`].
    pub fn set_pending(&mut self, rows: Vec<usize>, window: usize) -> Result<()> {
        if !self.pending.is_empty() {
            bail!("pending already set");
        }
        let mut prev = None;
        for &r in &rows {
            if r >= window {
                bail!("pending row {r} outside window {window}");
            }
            if let Some(p) = prev {
                if r <= p {
                    bail!("pending rows not strictly increasing");
                }
            }
            prev = Some(r);
        }
        if self.kv_len() + rows.len() > self.bucket {
            bail!("bucket overflow on acceptance");
        }
        self.pending = rows;
        Ok(())
    }
}

/// Draft-cache accounting (committed rows + per-round scratch region).
#[derive(Debug, Clone)]
pub struct DraftCache {
    pub bucket: usize,
    /// committed rows (prompt prefill + catch-up chains)
    pub committed: usize,
    /// scratch rows drafted this round (overwritten next round)
    pub scratch: usize,
    /// scratch region capacity (compiled DRAFT_REGION)
    pub region: usize,
}

impl DraftCache {
    pub fn new(bucket: usize, region: usize) -> DraftCache {
        DraftCache { bucket, committed: 0, scratch: 0, region }
    }

    pub fn push_prefill(&mut self, n: usize) -> Result<()> {
        if self.committed + n + self.region > self.bucket {
            bail!("draft bucket overflow in prefill");
        }
        self.committed += n;
        Ok(())
    }

    /// Commit a catch-up chain of `n` rows (written at `committed`).
    pub fn push_chain(&mut self, n: usize) -> Result<()> {
        if self.committed + n + self.region > self.bucket {
            bail!("draft bucket overflow in catch-up");
        }
        self.committed += n;
        self.scratch = 0;
        Ok(())
    }

    /// Reserve `n` scratch rows for a level expansion; returns the write
    /// offset within the scratch region.
    pub fn push_scratch(&mut self, n: usize) -> Result<usize> {
        if self.scratch + n > self.region {
            bail!("draft scratch region overflow ({} + {n})", self.scratch);
        }
        let off = self.scratch;
        self.scratch += n;
        Ok(off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;

    #[test]
    fn full_cache_flow() {
        let mut c = FullCache::new(1024);
        c.push_prefill(500).unwrap();
        c.set_pending(vec![0, 2, 5], 16).unwrap();
        assert_eq!(c.effective_len(), 503);
        let (kv_len, idx, n) = c.take_pending(8).unwrap();
        assert_eq!(kv_len, 500);
        assert_eq!(n, 3);
        assert_eq!(&idx[..3], &[0, 2, 5]);
        assert_eq!(idx.len(), 8);
        assert_eq!(c.committed, 503);
        assert!(c.pending.is_empty());
    }

    #[test]
    fn full_cache_rejects_bad_pending() {
        let mut c = FullCache::new(64);
        c.push_prefill(10).unwrap();
        assert!(c.set_pending(vec![5, 3], 16).is_err()); // not increasing
        assert!(c.set_pending(vec![16], 16).is_err()); // outside window
        c.set_pending(vec![1], 16).unwrap();
        assert!(c.set_pending(vec![2], 16).is_err()); // double set
    }

    #[test]
    fn full_cache_overflow() {
        let mut c = FullCache::new(32);
        assert!(c.push_prefill(33).is_err());
        c.push_prefill(30).unwrap();
        assert!(c.set_pending(vec![0, 1, 2], 16).is_err());
    }

    #[test]
    fn partial_cache_mode_logic() {
        let mut p = PartialCache::new(512, 36);
        p.refresh(420);
        assert!(p.fits(16, 8));
        // fill the pv budget
        for _ in 0..3 {
            p.pv_tokens.extend([0; 7]);
        }
        // 21 pv + 16 > 36 → must refresh
        assert!(!p.fits(16, 8));
        p.refresh(430);
        assert!(p.fits(16, 8));
        assert_eq!(p.kv_len(), 430);
    }

    #[test]
    fn partial_pending_roundtrip() {
        let mut p = PartialCache::new(512, 100);
        p.refresh(400);
        p.set_pending(vec![0, 1], 16).unwrap();
        let (kv_len, idx, n) = p.take_pending(8).unwrap();
        assert_eq!((kv_len, n), (400, 2));
        assert_eq!(idx.len(), 8);
        assert_eq!(p.kv_len(), 402);
    }

    #[test]
    fn partial_cache_rejects_bad_pending() {
        // same validation + error shapes as FullCache::set_pending
        let mut p = PartialCache::new(128, 40);
        p.refresh(64);
        assert!(p.set_pending(vec![5, 3], 16).is_err()); // not increasing
        assert!(p.set_pending(vec![3, 3], 16).is_err()); // not strictly
        assert!(p.set_pending(vec![16], 16).is_err()); // outside window
        p.set_pending(vec![1], 16).unwrap();
        assert!(p.set_pending(vec![2], 16).is_err()); // double set
        // overflow: kv_len + rows > bucket
        let mut p = PartialCache::new(66, 40);
        p.refresh(64);
        assert!(p.set_pending(vec![0, 1, 2], 16).is_err());
    }

    #[test]
    fn partial_cache_invariants_property() {
        Prop::new("partial cache kv_len/buffer caps", 200).run(|g| {
            let bucket = g.usize_in(64, 512);
            let cap = g.usize_in(17, 60);
            let mut p = PartialCache::new(bucket, cap);
            p.refresh(g.usize_in(1, bucket));
            for _ in 0..g.usize_in(0, 40) {
                if !p.fits(16, 8) {
                    // mode machine forces a Refresh before any overflow
                    assert!(p.kv_len() + p.pending.len() <= bucket);
                    p.refresh(g.usize_in(1, bucket));
                    continue;
                }
                let m = g.usize_in(0, 6);
                let rows: Vec<usize> = (0..=m).map(|i| i * 2).collect();
                if p.set_pending(rows, 16).is_ok() {
                    let (kv_len, idx, n) = p.take_pending(8).unwrap();
                    assert_eq!(idx.len(), 8);
                    assert!(kv_len + n <= bucket, "kv overflow");
                    for _ in 0..n {
                        p.pv_tokens.push(0);
                    }
                }
                assert!(p.kv_len() <= bucket, "kv_len exceeded bucket");
                assert!(
                    p.pv_tokens.len() <= cap + 16,
                    "pv buffer blew past cap + one tree"
                );
            }
        });
    }

    #[test]
    fn draft_cache_regions() {
        let mut d = DraftCache::new(256, 32);
        d.push_prefill(100).unwrap();
        let o1 = d.push_scratch(8).unwrap();
        let o2 = d.push_scratch(8).unwrap();
        assert_eq!((o1, o2), (0, 8));
        d.push_chain(5).unwrap();
        assert_eq!(d.committed, 105);
        assert_eq!(d.scratch, 0);
        assert!(d.push_scratch(33).is_err());
    }

    #[test]
    fn cache_invariants_property() {
        Prop::new("full cache never exceeds bucket", 200).run(|g| {
            let bucket = g.usize_in(64, 512);
            let mut c = FullCache::new(bucket);
            let _ = c.push_prefill(g.usize_in(0, bucket));
            for _ in 0..g.usize_in(0, 30) {
                let m = g.usize_in(0, 6);
                let rows: Vec<usize> = (0..m).map(|i| i * 2).collect();
                if c.set_pending(rows, 16).is_ok() {
                    let _ = c.take_pending(8);
                }
                assert!(c.effective_len() <= bucket);
            }
        });
    }
}
