//! Text-quality metrics (paper §4.1 "Metrics") and serving telemetry.
//!
//! * ROUGE-L — longest-common-subsequence F1 over word tokens (Lin 2004),
//!   used for the Table 2 / Fig. 6 similarity-to-full-verification scores.
//! * exact-match — normalized QA accuracy (Fig. 5).
//! * bleurt_proxy — BLEURT is a learned metric and unavailable offline; we
//!   substitute a smooth bag-of-character-ngram cosine similarity mapped to
//!   [0, 100] (see DESIGN.md §3 substitutions).

use std::collections::HashMap;

/// Lowercase word tokens (unicode-whitespace split, punctuation stripped).
fn words(s: &str) -> Vec<String> {
    s.split_whitespace()
        .map(|w| {
            w.chars()
                .filter(|c| c.is_alphanumeric())
                .flat_map(|c| c.to_lowercase())
                .collect::<String>()
        })
        .filter(|w| !w.is_empty())
        .collect()
}

/// LCS length via the classic O(n·m) DP (rolling row).
fn lcs_len(a: &[String], b: &[String]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for ai in a {
        for (j, bj) in b.iter().enumerate() {
            cur[j + 1] = if ai == bj {
                prev[j] + 1
            } else {
                cur[j].max(prev[j + 1])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// ROUGE-L F1 in [0, 100].
pub fn rouge_l(candidate: &str, reference: &str) -> f64 {
    let c = words(candidate);
    let r = words(reference);
    if c.is_empty() || r.is_empty() {
        return if c.is_empty() && r.is_empty() { 100.0 } else { 0.0 };
    }
    let l = lcs_len(&c, &r) as f64;
    let p = l / c.len() as f64;
    let rec = l / r.len() as f64;
    if p + rec == 0.0 {
        return 0.0;
    }
    100.0 * 2.0 * p * rec / (p + rec)
}

/// Exact match after normalization (lowercase, squeeze whitespace, strip
/// punctuation) — the Fig. 5 QA metric.
pub fn exact_match(candidate: &str, gold: &str) -> bool {
    let norm = |s: &str| words(s).join(" ");
    let c = norm(candidate);
    let g = norm(gold);
    // answer containment counts for generative QA ("the code ... is X.")
    c == g || (!g.is_empty() && c.split(' ').any(|w| w == g))
}

/// BLEURT substitute: cosine similarity between character-3gram count
/// vectors, mapped to [0, 100]. Smooth, symmetric, semantic-overlap-ish.
pub fn bleurt_proxy(a: &str, b: &str) -> f64 {
    fn grams(s: &str) -> HashMap<[u8; 3], f64> {
        let bytes: Vec<u8> = s
            .to_lowercase()
            .bytes()
            .filter(|b| b.is_ascii_alphanumeric() || *b == b' ')
            .collect();
        let mut m = HashMap::new();
        for w in bytes.windows(3) {
            *m.entry([w[0], w[1], w[2]]).or_insert(0.0) += 1.0;
        }
        m
    }
    let ga = grams(a);
    let gb = grams(b);
    if ga.is_empty() || gb.is_empty() {
        return if ga.is_empty() && gb.is_empty() { 100.0 } else { 0.0 };
    }
    let dot: f64 = ga
        .iter()
        .filter_map(|(k, v)| gb.get(k).map(|w| v * w))
        .sum();
    let na: f64 = ga.values().map(|v| v * v).sum::<f64>().sqrt();
    let nb: f64 = gb.values().map(|v| v * v).sum::<f64>().sqrt();
    100.0 * dot / (na * nb)
}

/// Per-generation efficiency record (paper §4.1: speedup α is computed by
/// the harness as a throughput ratio; accept length τ is macro-averaged).
#[derive(Debug, Clone, Default)]
pub struct GenStats {
    /// tokens produced (excluding prompt)
    pub new_tokens: usize,
    /// wall-clock seconds of the decode loop (excludes prefill)
    pub decode_secs: f64,
    /// prefill seconds
    pub prefill_secs: f64,
    /// verification forward passes
    pub verify_steps: usize,
    /// accepted draft tokens per verify step, summed
    pub accepted_total: usize,
    /// time split (Fig. 1)
    pub draft_secs: f64,
    pub verify_secs: f64,
    pub other_secs: f64,
    /// SpecPV mode counts (Alg. 1)
    pub full_steps: usize,
    pub partial_steps: usize,
    pub refresh_steps: usize,
    /// simulated PCIe transfer seconds (offload runs; Fig. 4)
    pub offload_secs: f64,
}

impl GenStats {
    pub fn throughput(&self) -> f64 {
        if self.decode_secs <= 0.0 {
            return 0.0;
        }
        self.new_tokens as f64 / self.decode_secs
    }

    /// Average accepted draft tokens per verification step (τ). Counts
    /// only the *drafted* tokens accepted, i.e. excludes the bonus token
    /// the target emits itself, and may be 0 when everything is rejected.
    pub fn accept_len(&self) -> f64 {
        if self.verify_steps == 0 {
            return 0.0;
        }
        self.accepted_total as f64 / self.verify_steps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rouge_identical() {
        assert!((rouge_l("the cat sat", "the cat sat") - 100.0).abs() < 1e-9);
    }

    #[test]
    fn rouge_disjoint() {
        assert_eq!(rouge_l("aaa bbb", "ccc ddd"), 0.0);
    }

    #[test]
    fn rouge_partial_sane() {
        let r = rouge_l("the cat sat on the mat", "the cat lay on a mat");
        assert!(r > 30.0 && r < 90.0, "{r}");
    }

    #[test]
    fn rouge_order_matters() {
        // LCS is order-sensitive: reversal should lose score
        let a = "one two three four five six";
        let b = "six five four three two one";
        assert!(rouge_l(a, a) > rouge_l(a, b));
    }

    #[test]
    fn em_normalization() {
        assert!(exact_match("  BaTaKo ", "batako"));
        assert!(exact_match("the code of agent X is batako.", "batako"));
        assert!(!exact_match("batak", "batako"));
    }

    #[test]
    fn bleurt_proxy_bounds() {
        assert!((bleurt_proxy("same text", "same text") - 100.0).abs() < 1e-9);
        assert_eq!(bleurt_proxy("aaaa", "zzzz"), 0.0);
        let mid = bleurt_proxy(
            "the committee recorded an expenditure",
            "the committee noted an expense",
        );
        assert!(mid > 20.0 && mid < 95.0, "{mid}");
    }

    #[test]
    fn stats_math() {
        let s = GenStats {
            new_tokens: 100,
            decode_secs: 2.0,
            verify_steps: 25,
            accepted_total: 75,
            ..Default::default()
        };
        assert!((s.throughput() - 50.0).abs() < 1e-9);
        assert!((s.accept_len() - 3.0).abs() < 1e-9);
    }
}
