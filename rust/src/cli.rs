//! Tiny CLI argument parser (no `clap` in the offline vendor set).
//!
//! Grammar: `specpv <command> [subcommand] [--flag value]... [--bool-flag]`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Cli {
    /// positional arguments in order
    pub positional: Vec<String>,
    /// `--key value` options
    pub options: BTreeMap<String, String>,
    /// bare `--key` switches
    pub flags: Vec<String>,
}

impl Cli {
    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value or --key value or bare flag
                if let Some((k, v)) = key.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    cli.options.insert(key.to_string(), v);
                } else {
                    cli.flags.push(key.to_string());
                }
            } else if a.starts_with('-') && a.len() > 1 {
                bail!("short options not supported: '{a}'");
            } else {
                cli.positional.push(a);
            }
        }
        Ok(cli)
    }

    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn sub(&self) -> Option<&str> {
        self.positional.get(1).map(|s| s.as_str())
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{key} {v}: {e}")),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let c = parse("bench table1 --ctx 4096 --engine spec_pv --verbose");
        assert_eq!(c.command(), Some("bench"));
        assert_eq!(c.sub(), Some("table1"));
        assert_eq!(c.opt("ctx"), Some("4096"));
        assert_eq!(c.opt("engine"), Some("spec_pv"));
        assert!(c.has_flag("verbose"));
    }

    #[test]
    fn eq_form() {
        let c = parse("run --budget=512");
        assert_eq!(c.opt("budget"), Some("512"));
    }

    #[test]
    fn typed() {
        let c = parse("x --n 42");
        assert_eq!(c.opt_parse::<usize>("n").unwrap(), Some(42));
        assert!(parse("x --n abc").opt_parse::<usize>("n").is_err());
    }

    #[test]
    fn rejects_short() {
        assert!(Cli::parse(vec!["-x".to_string()]).is_err());
    }
}
