//! Serving coordinator: a round-robin **continuous-batching scheduler**
//! over one runtime.
//!
//! The PJRT CPU client is single-device and the engines are synchronous,
//! so concurrency lives at *decode-round* granularity: up to
//! `Admission::max_active` requests hold live [`EngineSession`]s at once
//! and every scheduler [`Coordinator::tick`] runs exactly one `step()`
//! per active session (rotating the starting index for fairness). A
//! request's life cycle:
//!
//! ```text
//! submit → Queued → (admit: prefill via SessionFactory) → Running
//!        → step()* → Done | Failed | Cancelled
//!                  ↘ (KV pressure: suspend → host store) → Swapped
//!                       → re-queued → (resume: restore) → Running
//! ```
//!
//! Admission is **byte-aware** (the KV state manager, DESIGN.md §11,
//! §13): every live session reserves its resident state bytes with the
//! shared [`KvPool`], and a queued request is admitted only when it fits
//! the `kv_budget_bytes` budget — `max_active` remains as a width cap,
//! but the KV footprint governs who runs. Under pressure the
//! lowest-priority active session is preempted: its states park as
//! refcounted page block tables in the pool, the unshared pages demote
//! (int8 / disk spill per `kv_quant`/`kv_swap_dir`), and it re-queues —
//! resuming byte-identically (for `kv_quant = none`) when bytes free up.
//! A corrupt spill file on resume is recoverable: the session is dropped
//! and the request re-queued for a fresh prefill ([`Event::SwapFault`]),
//! never a panic.
//!
//! `tick()` returns [`Event`]s (per-step token deltas, swap transitions,
//! completions, failures) so the server can stream results keyed by
//! request id; the [`Registry`] tracks queue depth, active-set size,
//! resident KV bytes and time-to-first-token percentiles alongside the
//! per-request latency/throughput telemetry. This is the vLLM-router-
//! shaped outer loop the L3 layer owns; the inner draft/verify loop
//! lives in `engine`.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::time::Instant;

use anyhow::Result;

use crate::backend::{Backend, StateBuf};
use crate::config::{Config, EngineKind};
use crate::policy::{PolicyEngine, PolicyUpdate};
use crate::engine::plan::{exec_batch, exec_single, PlanKey};
use crate::engine::{
    BackendFactory, Drive, EngineSession, GenRequest, GenResult, KernelPlan,
    SessionCheckpoint, SessionFactory, StepOutcome,
};
use crate::kvstore::{KvCtx, KvPool, KvStats, KvStore, PagedState};
use crate::metrics::GenStats;
use crate::util::failpoint::FaultSpec;
use crate::util::rng::Rng;
use crate::util::stats::Samples;

/// Request ids are coordinator-scoped.
pub type RequestId = u64;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Running,
    /// preempted under KV-byte pressure: state parked as demoted pool
    /// pages, waiting in the queue for restore-on-resume
    Swapped,
    Done,
    Cancelled,
    Failed(String),
}

impl RequestState {
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            RequestState::Done | RequestState::Cancelled | RequestState::Failed(_)
        )
    }
}

#[derive(Debug)]
pub struct TrackedRequest {
    pub id: RequestId,
    pub req: GenRequest,
    pub engine: EngineKind,
    pub state: RequestState,
    /// final (or partial, if cancelled/failed mid-flight) result
    pub result: Option<GenResult>,
    pub queued_secs: f64,
    pub service_secs: f64,
    /// submit → first token available (prefill bonus)
    pub ttft_secs: f64,
    /// scheduler steps taken
    pub steps: usize,
    /// wall-clock budget from submit; exceeded → Failed("deadline …")
    pub deadline_secs: Option<f64>,
    /// preemption rank: under KV-byte pressure the lowest-priority
    /// active session is swapped out first (default 0)
    pub priority: i32,
    /// tokens preloaded by a checkpoint resume — already emitted on the
    /// failed shard, never re-delivered in `Step` events (0 for fresh
    /// sessions and regenerating failovers)
    pub resumed_tokens: usize,
    submitted: Instant,
    started: Option<Instant>,
}

/// Scheduler events emitted by [`Coordinator::tick`].
#[derive(Debug, Clone)]
pub enum Event {
    /// Prefill finished; the session is live (TTFT clock stops here).
    Started { id: RequestId },
    /// One step produced tokens (includes the prefill token on step 1).
    Step { id: RequestId, new_tokens: Vec<u32>, step: usize, finished: bool },
    /// Preempted under KV-byte pressure; state parked as demoted pages.
    SwappedOut { id: RequestId },
    /// Swapped-out session restored and running again.
    Resumed { id: RequestId },
    /// A parked session's spilled pages could not be read back (corrupt
    /// or missing spill file); the session was dropped and the request
    /// re-queued for a fresh prefill. Not terminal.
    SwapFault { id: RequestId },
    /// Terminal: result available via `Coordinator::get`.
    Finished { id: RequestId },
    Cancelled { id: RequestId },
    Failed { id: RequestId, error: String },
    /// Terminal: the request's wall-clock deadline (`timeout_ms` /
    /// `deadline_s` on the wire) passed before it finished. Its KV pages
    /// are freed; the tracked state is `Failed("deadline …")` so the
    /// result plumbing matches any other failure.
    DeadlineExceeded { id: RequestId },
    /// The coordinator entered drain (server shutdown): this in-flight
    /// request will run to completion but no new work is admitted.
    /// Streaming clients see a clean end instead of a dropped socket.
    Draining { id: RequestId },
}

impl Event {
    pub fn id(&self) -> RequestId {
        match self {
            Event::Started { id }
            | Event::Step { id, .. }
            | Event::SwappedOut { id }
            | Event::Resumed { id }
            | Event::SwapFault { id }
            | Event::Finished { id }
            | Event::Cancelled { id }
            | Event::Failed { id, .. }
            | Event::DeadlineExceeded { id }
            | Event::Draining { id } => *id,
        }
    }
}

/// Per-engine speculation counters (policy layer, DESIGN.md §16):
/// synced each tick from live sessions' cumulative observations, like
/// the KV gauges. `rounds` counts draft→verify→accept rounds.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SpecCounters {
    /// draft tokens offered to verification
    pub proposed: u64,
    /// draft tokens accepted into the output
    pub committed: u64,
    /// verify rounds folded in
    pub rounds: u64,
    /// rounds verified against the full KV cache
    pub full_steps: u64,
    /// rounds verified against the partial cache (SpecPV)
    pub partial_steps: u64,
    /// full-verification refreshes taken (SpecPV)
    pub refresh_steps: u64,
}

impl SpecCounters {
    /// Mean accepted-run length per verify round (the paper's τ, Eq. 4).
    pub fn tau_mean(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.committed as f64 / self.rounds as f64
        }
    }

    /// Fraction of verify rounds served by the partial cache.
    pub fn partial_frac(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.partial_steps as f64 / self.rounds as f64
        }
    }
}

/// Aggregate serving metrics (reported by the `metrics` server op and
/// the e2e example). Counters accumulate over terminal requests; the
/// `queue_depth`/`active_sessions` gauges reflect the last tick.
#[derive(Debug, Default)]
pub struct Registry {
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    pub tokens_out: u64,
    /// which backend serves this coordinator ("pjrt", "reference",
    /// "scripted" for injected test factories)
    pub backend: String,
    /// backend execution counters (synced on demand via
    /// `Coordinator::sync_backend_counters` — not on the per-tick path)
    pub executions: u64,
    pub exec_secs: f64,
    pub compilations: u64,
    /// gauge: requests waiting for a session slot (as of the last tick)
    pub queue_depth: usize,
    /// gauge: live sessions (as of the last tick)
    pub active_sessions: usize,
    /// gauge: device bytes reserved by live sessions (KV pool)
    pub kv_resident_bytes: usize,
    /// admission byte budget (0 = unlimited)
    pub kv_budget_bytes: usize,
    /// gauge: live pool pages (parked sessions + prefix cache). Shared
    /// pages count once — a prefix-cache hit mapped into N sessions is
    /// still one page here (pinned by rust/tests/scheduler.rs).
    pub kv_pages_resident: usize,
    /// gauge: pool pages with refcount ≥ 2 (CoW / prefix sharing)
    pub kv_pages_shared: usize,
    /// gauge: internal fragmentation of live pages, percent
    pub kv_frag_pct: f64,
    /// sessions preempted into the page pool (lifetime counter)
    pub swap_outs: u64,
    /// sessions restored from the page pool (lifetime counter)
    pub swap_ins: u64,
    /// spill-file read failures survived on resume (session dropped,
    /// request re-queued)
    pub swap_faults: u64,
    /// requests failed by their wall-clock deadline (`timeout_ms`)
    pub deadline_hits: u64,
    /// supervised restarts of the shard this coordinator serves (set by
    /// the shard loop from its supervisor's restart count)
    pub restarts: u64,
    /// failed-over sessions rebuilt from a checkpoint instead of a fresh
    /// prefill (DESIGN.md §15)
    pub checkpoint_resumes: u64,
    /// sessions rebuilt across a process restart from the write-ahead
    /// journal + durable checkpoint store (DESIGN.md §17)
    pub recovered_sessions: u64,
    /// journal records replayed during cold-restart recovery
    pub journal_replayed: u64,
    /// torn/corrupt journal records truncated (not fatal) on boot
    pub journal_torn_records: u64,
    /// prompt-prefix cache counters (synced with the backend counters)
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    /// admission knobs, echoed for operators
    pub max_queue: usize,
    pub max_prompt: usize,
    /// kernel thread-pool width serving this coordinator (the `threads`
    /// config key / `--threads` flag, or the `SPECPV_THREADS`/auto
    /// default when unset), echoed for operators
    pub threads: usize,
    /// cross-session batched execution (DESIGN.md §12): fused groups
    /// (width ≥ 2, actually fused by the backend) issued over the
    /// coordinator's lifetime
    pub batch_groups: u64,
    /// kernel ops executed inside fused groups
    pub batch_ops_fused: u64,
    /// protocol kernel ops executed one session at a time (width-1
    /// groups, and width ≥ 2 groups on a backend whose `*_batch` entry
    /// points are the sequential default — e.g. pjrt)
    pub batch_ops_single: u64,
    /// whole `step()` calls taken by sessions outside the plan/apply
    /// protocol (scripted/foreign sessions, or batching disabled);
    /// tracked separately because one step spans many kernel ops
    pub fallback_steps: u64,
    /// widest fused group observed
    pub batch_width_max: usize,
    /// gauge: fused groups issued by the last tick
    pub batch_tick_groups: usize,
    /// speculation policy mode serving this coordinator
    /// ("off"|"fixed"|"adaptive"), echoed for operators
    pub policy_mode: String,
    /// depth moves commanded by the adaptive controller (lifetime)
    pub policy_depth_changes: u64,
    /// drift-triggered refreshes commanded ahead of the fixed cadence
    pub policy_refreshes: u64,
    /// per-engine speculation counters (DESIGN.md §16), keyed by engine
    /// name, synced each tick
    pub spec: BTreeMap<String, SpecCounters>,
    /// `engine=auto` resolutions per selected engine
    pub auto_selected: BTreeMap<String, u64>,
    pub latency: Samples,
    pub queue_wait: Samples,
    /// submit → first token, sampled at session start
    pub ttft: Samples,
    pub throughput_tok_s: Samples,
    pub accept_len: Samples,
}

impl Registry {
    /// Mean width of fused groups (0 before any group fused).
    pub fn batch_mean_width(&self) -> f64 {
        if self.batch_groups == 0 {
            0.0
        } else {
            self.batch_ops_fused as f64 / self.batch_groups as f64
        }
    }

    /// Fraction of *protocol* kernel-op executions that ran fused rather
    /// than one session at a time (0 before any protocol op ran).
    /// Non-protocol sessions' whole-step fallbacks are excluded — see
    /// [`Registry::fallback_steps`] — because one step spans many ops.
    pub fn batched_frac(&self) -> f64 {
        let total = self.batch_ops_fused + self.batch_ops_single;
        if total == 0 {
            0.0
        } else {
            self.batch_ops_fused as f64 / total as f64
        }
    }

    pub fn record(&mut self, tr: &TrackedRequest) {
        match &tr.state {
            RequestState::Done => {
                self.completed += 1;
                if let Some(r) = &tr.result {
                    self.tokens_out += r.tokens.len() as u64;
                    self.latency.push(tr.service_secs);
                    self.queue_wait.push(tr.queued_secs);
                    self.throughput_tok_s.push(r.stats.throughput());
                    if r.stats.verify_steps > 0 {
                        self.accept_len.push(r.stats.accept_len());
                    }
                }
            }
            RequestState::Cancelled => {
                self.cancelled += 1;
                if let Some(r) = &tr.result {
                    self.tokens_out += r.tokens.len() as u64;
                }
            }
            RequestState::Failed(_) => self.failed += 1,
            _ => {}
        }
    }

    /// Fold one tick's policy-layer deltas into the per-engine counters.
    pub fn note_spec(&mut self, kind: EngineKind, up: &PolicyUpdate) {
        if up.rounds == 0 && up.proposed == 0 && up.refresh_steps == 0 {
            return;
        }
        let c = self.spec.entry(kind.to_string()).or_default();
        c.rounds += up.rounds;
        c.proposed += up.proposed;
        c.committed += up.committed;
        c.full_steps += up.full_steps;
        c.partial_steps += up.partial_steps;
        c.refresh_steps += up.refresh_steps;
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "backend={} completed={} failed={} cancelled={} tokens={} \
             queue_depth={} active={} max_queue={} max_prompt={} \
             threads={} fused_groups={} batch_mean_w={:.2} batch_max_w={} \
             batched_frac={:.2} fallback_steps={} kv_resident={} kv_budget={} swaps={}/{} \
             kv_pages={} kv_pages_shared={} kv_frag={:.1}% swap_faults={} \
             deadline_hits={} restarts={} ckpt_resumes={} \
             recovered={} journal_replayed={} journal_torn={} \
             prefix_hits={} prefix_misses={} execs={} exec_secs={:.2}s \
             compiles={} p50_latency={:.2}s p99={:.2}s p50_ttft={:.3}s \
             p99_ttft={:.3}s mean_tok_s={:.1} mean_tau={:.2}",
            if self.backend.is_empty() { "scripted" } else { self.backend.as_str() },
            self.completed,
            self.failed,
            self.cancelled,
            self.tokens_out,
            self.queue_depth,
            self.active_sessions,
            self.max_queue,
            self.max_prompt,
            self.threads,
            self.batch_groups,
            self.batch_mean_width(),
            self.batch_width_max,
            self.batched_frac(),
            self.fallback_steps,
            self.kv_resident_bytes,
            self.kv_budget_bytes,
            self.swap_outs,
            self.swap_ins,
            self.kv_pages_resident,
            self.kv_pages_shared,
            self.kv_frag_pct,
            self.swap_faults,
            self.deadline_hits,
            self.restarts,
            self.checkpoint_resumes,
            self.recovered_sessions,
            self.journal_replayed,
            self.journal_torn_records,
            self.prefix_hits,
            self.prefix_misses,
            self.executions,
            self.exec_secs,
            self.compilations,
            self.latency.p50(),
            self.latency.p99(),
            self.ttft.p50(),
            self.ttft.p99(),
            self.throughput_tok_s.mean(),
            self.accept_len.mean(),
        );
        s.push_str(&format!(
            " policy={} policy_depth_changes={} policy_refreshes={}",
            if self.policy_mode.is_empty() { "off" } else { self.policy_mode.as_str() },
            self.policy_depth_changes,
            self.policy_refreshes,
        ));
        for (k, c) in &self.spec {
            s.push_str(&format!(
                " spec_{k}={}/{} spec_{k}_tau={:.2} spec_{k}_partial_frac={:.2} \
                 spec_{k}_refreshes={}",
                c.committed,
                c.proposed,
                c.tau_mean(),
                c.partial_frac(),
                c.refresh_steps,
            ));
        }
        for (k, n) in &self.auto_selected {
            s.push_str(&format!(" auto_{k}={n}"));
        }
        s
    }
}

/// Admission control limits.
#[derive(Debug, Clone)]
pub struct Admission {
    pub max_prompt: usize,
    pub max_new: usize,
    pub max_queue: usize,
    /// concurrent live sessions (continuous-batching width)
    pub max_active: usize,
    /// resident KV-state byte budget across live sessions (0 = unlimited)
    pub kv_budget_bytes: usize,
}

impl Default for Admission {
    fn default() -> Self {
        Admission {
            max_prompt: 7 * 1024,
            max_new: 1024,
            max_queue: 256,
            max_active: 4,
            kv_budget_bytes: 0,
        }
    }
}

/// Options for [`Coordinator::submit_opts`].
#[derive(Debug, Clone, Default)]
pub struct SubmitOpts {
    /// engine override (None = the config's engine)
    pub engine: Option<EngineKind>,
    /// wall-clock budget from submit, seconds
    pub deadline_secs: Option<f64>,
    /// preemption rank — lower is swapped out first under byte pressure
    pub priority: i32,
    /// per-request `engine=auto`: when set (and no explicit engine
    /// override), the policy layer picks the engine from the prompt
    /// length and the fleet's acceptance probes (DESIGN.md §16)
    pub auto: bool,
}

struct ActiveEntry<'rt> {
    id: RequestId,
    session: Box<dyn EngineSession + 'rt>,
}

/// A pending kernel plan moved out of its session for (possibly fused)
/// execution, together with the state buffer it mutates. Holding the
/// plan and the state as owned values sidesteps simultaneous borrows of
/// many sessions — the session is dormant until `restore_pending`.
struct InFlight {
    /// index into the active set
    idx: usize,
    plan: KernelPlan,
    state: StateBuf,
}

pub struct Coordinator<'rt> {
    pub cfg: Config,
    pub admission: Admission,
    factory: Box<dyn SessionFactory<'rt> + 'rt>,
    /// the backend behind the factory, when there is one (counters)
    backend: Option<&'rt dyn Backend>,
    queue: VecDeque<RequestId>,
    requests: Vec<TrackedRequest>,
    active: Vec<ActiveEntry<'rt>>,
    /// dormant (swapped-out) session objects awaiting re-admission;
    /// their parked block tables live in `parked`
    swapped: HashMap<RequestId, Box<dyn EngineSession + 'rt>>,
    /// parked block tables of swapped-out sessions (pages demoted to
    /// int8/disk by `KvPool::park_cold` where the config allows)
    parked: HashMap<RequestId, Vec<PagedState>>,
    /// swapped requests whose spilled pages already have a disk
    /// prefetch in flight
    prefetched: HashSet<RequestId>,
    /// the shared page pool: byte-denominated admission ledger plus the
    /// page store parked sessions and the prefix cache live in
    pub pool: KvPool,
    /// shared prompt-prefix cache (None = disabled); its entries are
    /// block tables in `pool`
    prefix: Option<KvStore>,
    /// round-robin rotation cursor
    rr: usize,
    /// fuse compatible kernel ops across sessions (DESIGN.md §12);
    /// off = every session steps through the sequential `step()` path
    batching: bool,
    /// drain mode (server shutdown): reject new submits, run the
    /// in-flight set to completion
    draining: bool,
    /// failover checkpoints attached by `submit_failover`, consumed at
    /// admission: the session is rebuilt from the snapshot instead of a
    /// fresh prefill (falling back to prefill if the rebuild fails)
    resume_ckpts: HashMap<RequestId, SessionCheckpoint>,
    /// parsed failpoint spec (`cfg.faults`; off by default)
    faults: FaultSpec,
    /// dedicated stream for probabilistic fault injection — never shared
    /// with generation sampling
    fault_rng: Rng,
    /// adaptive speculation policy layer (DESIGN.md §16): per-session
    /// controllers + per-engine acceptance probes, ticked after every
    /// step wave
    pub policy: PolicyEngine,
    pub registry: Registry,
}

impl<'rt> Coordinator<'rt> {
    /// Production constructor: sessions are started on `be` with the
    /// config's engine geometry. The config's [`KvCtx`] (page pool sized
    /// by `kv_budget_bytes`/`kv_page_bytes` with the configured swap dir
    /// and cold-page quantization, plus a `prefix_cache_bytes` prefix
    /// cache when non-zero) is shared between the factory's sessions and
    /// the coordinator's admission/preemption accounting.
    pub fn new(be: &'rt dyn Backend, cfg: Config) -> Coordinator<'rt> {
        let kv = KvCtx::from_config(&cfg);
        let factory = BackendFactory::new(be, cfg.clone()).with_kv(kv.clone());
        let mut coord = Coordinator::with_factory(cfg, Box::new(factory));
        coord.backend = Some(be);
        coord.pool = kv.pool;
        coord.prefix = kv.prefix;
        coord.registry.backend = be.name().to_string();
        coord.install_swap_faults();
        coord
    }

    /// Test/simulation constructor with an injected session factory.
    pub fn with_factory(
        cfg: Config,
        factory: Box<dyn SessionFactory<'rt> + 'rt>,
    ) -> Coordinator<'rt> {
        // max_active = 0 would admit nothing while never going idle —
        // the device loop would spin forever; clamp to a working width
        let admission = Admission {
            max_active: cfg.max_active.max(1),
            max_prompt: cfg.max_prompt,
            max_queue: cfg.max_queue,
            kv_budget_bytes: cfg.kv_budget_bytes,
            ..Admission::default()
        };
        let pool = KvPool::with_opts(
            admission.kv_budget_bytes,
            cfg.kv_page_bytes,
            cfg.swap_dir().as_deref(),
            cfg.kv_quant,
        );
        let registry = Registry {
            kv_budget_bytes: admission.kv_budget_bytes,
            max_queue: admission.max_queue,
            max_prompt: admission.max_prompt,
            threads: crate::util::pool::resolve_threads(cfg.threads),
            policy_mode: cfg.policy.mode.to_string(),
            ..Registry::default()
        };
        let policy = PolicyEngine::new(cfg.policy.clone());
        // cfg.faults was validated at config parse; a hand-built Config
        // with a bad spec degrades to all-off rather than panicking
        let faults = FaultSpec::parse(&cfg.faults).unwrap_or_default();
        let fault_rng = Rng::new(faults.seed);
        let mut coord = Coordinator {
            cfg,
            admission,
            factory,
            backend: None,
            queue: VecDeque::new(),
            requests: Vec::new(),
            active: Vec::new(),
            swapped: HashMap::new(),
            parked: HashMap::new(),
            prefetched: HashSet::new(),
            pool,
            prefix: None,
            rr: 0,
            batching: true,
            draining: false,
            resume_ckpts: HashMap::new(),
            faults,
            fault_rng,
            policy,
            registry,
        };
        coord.install_swap_faults();
        coord
    }

    /// Arm the pool's spill-corruption failpoint (idempotent; re-applied
    /// by [`Coordinator::new`] after it swaps in the config's pool).
    fn install_swap_faults(&mut self) {
        if self.faults.swap_corrupt_rate > 0.0 {
            self.pool
                .set_corrupt_faults(self.faults.swap_corrupt_rate, self.faults.seed);
        }
    }

    /// Disable (or re-enable) cross-session batched execution. With
    /// batching off every active session steps through the sequential
    /// `step()` path — the parity harness compares the two, and it is an
    /// operator escape hatch.
    pub fn set_batching(&mut self, on: bool) {
        self.batching = on;
    }

    /// Admit a request (engine defaults to the config's engine).
    pub fn submit(
        &mut self,
        req: GenRequest,
        engine: Option<EngineKind>,
    ) -> Result<RequestId> {
        self.submit_with_deadline(req, engine, None)
    }

    /// Admit a request with an optional wall-clock deadline (seconds from
    /// now); the scheduler fails the request once the deadline passes.
    pub fn submit_with_deadline(
        &mut self,
        req: GenRequest,
        engine: Option<EngineKind>,
        deadline_secs: Option<f64>,
    ) -> Result<RequestId> {
        self.submit_opts(req, SubmitOpts { engine, deadline_secs, ..SubmitOpts::default() })
    }

    /// Admit a request with full submit options (engine override,
    /// deadline, preemption priority).
    pub fn submit_opts(&mut self, req: GenRequest, opts: SubmitOpts) -> Result<RequestId> {
        if self.draining {
            anyhow::bail!("server shutting down");
        }
        if req.prompt.len() > self.admission.max_prompt {
            anyhow::bail!(
                "prompt {} exceeds admission limit {}",
                req.prompt.len(),
                self.admission.max_prompt
            );
        }
        if req.max_new > self.admission.max_new {
            anyhow::bail!("max_new {} exceeds limit", req.max_new);
        }
        if self.queue.len() >= self.admission.max_queue {
            anyhow::bail!("queue full ({})", self.queue.len());
        }
        // engine=auto (DESIGN.md §16): with no explicit override, the
        // policy layer picks per request from prompt length + the
        // fleet's acceptance probes. Deterministic in (prompt, history).
        let engine = match opts.engine {
            Some(kind) => kind,
            None if opts.auto || self.cfg.engine_auto => {
                let kind = self.policy.select(req.prompt.len());
                *self.registry.auto_selected.entry(kind.to_string()).or_insert(0) += 1;
                kind
            }
            None => self.cfg.engine,
        };
        let id = self.requests.len() as RequestId;
        self.requests.push(TrackedRequest {
            id,
            req,
            engine,
            state: RequestState::Queued,
            result: None,
            queued_secs: 0.0,
            service_secs: 0.0,
            ttft_secs: 0.0,
            steps: 0,
            deadline_secs: opts.deadline_secs,
            priority: opts.priority,
            resumed_tokens: 0,
            submitted: Instant::now(),
            started: None,
        });
        self.queue.push_back(id);
        self.registry.queue_depth = self.queue.len();
        Ok(id)
    }

    /// Admit a failed-over request with an optional checkpoint taken on
    /// the dead shard. With a checkpoint the session is rebuilt from the
    /// snapshot at admission (no prefill); without one — or if the
    /// rebuild fails — admission falls back to a deterministic
    /// regeneration from the prompt.
    pub fn submit_failover(
        &mut self,
        req: GenRequest,
        opts: SubmitOpts,
        ck: Option<SessionCheckpoint>,
    ) -> Result<RequestId> {
        let id = self.submit_opts(req, opts)?;
        if let Some(ck) = ck {
            self.resume_ckpts.insert(id, ck);
        }
        Ok(id)
    }

    /// Cancel a queued or running request. Running requests keep their
    /// partial output in `result`. Returns false for unknown/terminal ids.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        self.resume_ckpts.remove(&id);
        let state = match self.requests.get(id as usize) {
            Some(tr) => tr.state.clone(),
            None => return false,
        };
        match state {
            RequestState::Queued => {
                self.queue.retain(|&q| q != id);
                let tr = &mut self.requests[id as usize];
                tr.state = RequestState::Cancelled;
                self.registry.record(tr);
                self.registry.queue_depth = self.queue.len();
                true
            }
            RequestState::Running => {
                let Some(idx) = self.active.iter().position(|e| e.id == id) else {
                    return false;
                };
                let entry = self.active.remove(idx);
                self.pool.release(id);
                self.policy.finish(id);
                let result = entry.session.finish();
                let tr = &mut self.requests[id as usize];
                tr.service_secs =
                    tr.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
                tr.result = Some(result);
                tr.state = RequestState::Cancelled;
                self.registry.record(tr);
                self.registry.active_sessions = self.active.len();
                true
            }
            RequestState::Swapped => {
                self.queue.retain(|&q| q != id);
                if let Some(tables) = self.parked.remove(&id) {
                    for ps in &tables {
                        self.pool.free_state(ps);
                    }
                }
                self.prefetched.remove(&id);
                self.policy.finish(id);
                let result = self.swapped.remove(&id).map(|s| s.finish());
                let tr = &mut self.requests[id as usize];
                tr.service_secs =
                    tr.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
                tr.result = result;
                tr.state = RequestState::Cancelled;
                self.registry.record(tr);
                self.registry.queue_depth = self.queue.len();
                true
            }
            _ => false,
        }
    }

    /// Snapshot a running session for failover (DESIGN.md §15). Returns
    /// `None` when the request is not active or the session is at a
    /// point it cannot checkpoint (mid-plan, finished, or an engine
    /// without checkpoint support) — callers simply keep the previous
    /// checkpoint in that case.
    pub fn checkpoint(&self, id: RequestId) -> Option<SessionCheckpoint> {
        let entry = self.active.iter().find(|e| e.id == id)?;
        match entry.session.checkpoint() {
            Ok(Some(mut ck)) => {
                // carry the learned policy state (depth, acceptance EWMA,
                // drift) so a failed-over session does not relearn from
                // defaults (DESIGN.md §16)
                ck.policy = self.policy.state(id).cloned();
                Some(ck)
            }
            Ok(None) => None,
            Err(e) => {
                eprintln!("[coordinator] checkpoint of request {id} failed: {e:#}");
                None
            }
        }
    }

    /// One scheduler tick: expire deadlines, admit up to `max_active`
    /// within the KV-byte budget (preempting lower-priority sessions
    /// under pressure), then run one `step()` per active session
    /// (round-robin order).
    pub fn tick(&mut self) -> Vec<Event> {
        let mut events = Vec::new();
        self.expire_deadlines(&mut events);
        self.admit(&mut events);
        self.step_active(&mut events);
        self.policy_tick();
        self.registry.queue_depth = self.queue.len();
        self.registry.active_sessions = self.active.len();
        self.registry.kv_resident_bytes = self.pool.resident();
        self.sync_page_gauges();
        events
    }

    /// Poll every live session's cumulative speculation counters, fold
    /// them through the per-session controllers, and apply the resulting
    /// directives (DESIGN.md §16). Runs after the step wave, when every
    /// session sits at a round boundary — a directive therefore never
    /// changes a draft round midway, and the batched plan/apply protocol
    /// is untouched. In `policy=fixed` mode the fold only accrues
    /// counters (every directive is a no-op); `policy=off` skips the
    /// poll entirely.
    fn policy_tick(&mut self) {
        if !self.policy.enabled() {
            return;
        }
        for entry in self.active.iter_mut() {
            let Some(obs) = entry.session.spec_observe() else { continue };
            let kind = entry.session.kind();
            let up = self.policy.observe(entry.id, kind, obs);
            self.registry.note_spec(kind, &up);
            if !up.directive.is_noop() {
                entry.session.apply_policy(&up.directive);
            }
        }
        self.registry.policy_depth_changes = self.policy.depth_changes;
        self.registry.policy_refreshes = self.policy.forced_refreshes;
    }

    /// Refresh the page-level pool gauges. A page shared by several
    /// block tables counts **once** in `kv_pages_resident` — the gauges
    /// report physical pages, not the sum of block-table lengths.
    fn sync_page_gauges(&mut self) {
        let ps = self.pool.stats();
        self.registry.kv_pages_resident = ps.pages_resident;
        self.registry.kv_pages_shared = ps.pages_shared;
        self.registry.kv_frag_pct = ps.frag_pct;
    }

    /// Pull the backend's execution counters into the registry. Called on
    /// demand (the `metrics` op, end of a drain) rather than per tick —
    /// the counter snapshot clones a per-executable map and has no place
    /// on the hot device loop.
    pub fn sync_backend_counters(&mut self) {
        if let Some(be) = self.backend {
            let c = be.counters();
            self.registry.executions = c.executions;
            self.registry.exec_secs = c.exec_secs;
            self.registry.compilations = c.compilations;
        }
        if let Some(st) = &self.prefix {
            let ps = st.stats();
            self.registry.prefix_hits = ps.hits;
            self.registry.prefix_misses = ps.misses;
        }
        self.registry.kv_resident_bytes = self.pool.resident();
        self.sync_page_gauges();
    }

    /// Aggregated KV-subsystem stats (the server `cache` op).
    pub fn kv_stats(&self) -> KvStats {
        KvStats {
            prefix: self.prefix.as_ref().map(|s| s.stats()).unwrap_or_default(),
            resident_bytes: self.pool.resident(),
            budget_bytes: self.pool.budget(),
            live_states: self.pool.live(),
            swapped: self.parked.len(),
            swap_bytes: self
                .parked
                .values()
                .flatten()
                .map(|ps| ps.logical_bytes())
                .sum(),
            swap_outs: self.registry.swap_outs,
            swap_ins: self.registry.swap_ins,
            pages: self.pool.stats(),
        }
    }

    fn expire_deadlines(&mut self, events: &mut Vec<Event>) {
        // only queued + active requests can expire — never rescan the
        // full (append-only) request history on the per-round hot path
        let expired: Vec<RequestId> = self
            .queue
            .iter()
            .copied()
            .chain(self.active.iter().map(|e| e.id))
            .filter(|&id| {
                let tr = &self.requests[id as usize];
                tr.deadline_secs
                    .map(|d| tr.submitted.elapsed().as_secs_f64() > d)
                    .unwrap_or(false)
            })
            .collect();
        for id in expired {
            let msg = format!(
                "deadline of {:.2}s exceeded",
                self.requests[id as usize].deadline_secs.unwrap_or(0.0)
            );
            self.queue.retain(|&q| q != id);
            if let Some(idx) = self.active.iter().position(|e| e.id == id) {
                let entry = self.active.remove(idx);
                self.pool.release(id);
                let result = entry.session.finish();
                self.requests[id as usize].result = Some(result);
            }
            if let Some(session) = self.swapped.remove(&id) {
                if let Some(tables) = self.parked.remove(&id) {
                    for ps in &tables {
                        self.pool.free_state(ps);
                    }
                }
                self.prefetched.remove(&id);
                self.requests[id as usize].result = Some(session.finish());
            }
            self.resume_ckpts.remove(&id);
            self.policy.finish(id);
            let tr = &mut self.requests[id as usize];
            tr.service_secs =
                tr.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
            tr.state = RequestState::Failed(msg);
            self.registry.record(tr);
            self.registry.deadline_hits += 1;
            events.push(Event::DeadlineExceeded { id });
        }
    }

    fn admit(&mut self, events: &mut Vec<Event>) {
        while self.active.len() < self.admission.max_active {
            let Some(&id) = self.queue.front() else { break };
            let (kind, prio) = {
                let tr = &self.requests[id as usize];
                (tr.engine, tr.priority)
            };
            // byte gate: the footprint the session will register — exact
            // for a swapped session (it still knows its layouts), the
            // engine-geometry estimate for a fresh one
            let need = match self.swapped.get(&id) {
                Some(session) => session.state_bytes(),
                None => self
                    .factory
                    .estimate_bytes(kind, &self.requests[id as usize].req),
            };
            if !self.pool.admits(need) {
                // make room by preempting a strictly lower-priority
                // session; if none exists, the head waits — kick off a
                // disk prefetch of its spilled pages (once) so the
                // eventual resume faults less
                if !self.preempt_below(prio, events) {
                    if let Some(tables) = self.parked.get(&id) {
                        if self.prefetched.insert(id) {
                            self.pool.prefetch(tables);
                        }
                    }
                    break;
                }
                continue;
            }
            self.queue.pop_front();
            if self.swapped.contains_key(&id) {
                self.resume_swapped(id, events);
            } else {
                // queue wait stops at first admission only — a resumed
                // session's re-queue time is service-side, not queue-side
                let req = {
                    let tr = &mut self.requests[id as usize];
                    tr.queued_secs = tr.submitted.elapsed().as_secs_f64();
                    tr.req.clone()
                };
                self.start_fresh(id, kind, &req, events);
            }
        }
    }

    fn start_fresh(
        &mut self,
        id: RequestId,
        kind: EngineKind,
        req: &GenRequest,
        events: &mut Vec<Event>,
    ) {
        // failover resume: a checkpoint shipped with the request rebuilds
        // the session mid-generation (no prefill). Any rebuild error
        // degrades to the regeneration path below — same bytes, more work.
        let resumed = match self.resume_ckpts.remove(&id) {
            Some(ck) => match self.factory.start_from_checkpoint(kind, req, &ck) {
                Ok(mut session) => {
                    self.registry.checkpoint_resumes += 1;
                    self.requests[id as usize].resumed_tokens = ck.emitted.len();
                    // restore the learned policy state and re-arm the
                    // rebuilt session with its depth (the session itself
                    // restarted at the config default)
                    if let Some(ps) = &ck.policy {
                        if self.policy.enabled() {
                            self.policy.restore(id, ps.clone());
                            let d = self.policy.directive_for(id);
                            if !d.is_noop() {
                                session.apply_policy(&d);
                            }
                        }
                    }
                    Some(session)
                }
                Err(e) => {
                    eprintln!(
                        "[coordinator] checkpoint resume of request {id} failed, \
                         regenerating: {e:#}"
                    );
                    None
                }
            },
            None => None,
        };
        let started = match resumed {
            Some(session) => Ok(session),
            None => self.factory.start_session(kind, req),
        };
        match started {
            Ok(session) => {
                self.pool.reserve(id, session.state_bytes());
                let tr = &mut self.requests[id as usize];
                tr.state = RequestState::Running;
                tr.started = Some(Instant::now());
                // prefill picked the first token → TTFT stops here
                tr.ttft_secs = tr.submitted.elapsed().as_secs_f64();
                self.registry.ttft.push(tr.ttft_secs);
                self.active.push(ActiveEntry { id, session });
                events.push(Event::Started { id });
            }
            Err(e) => {
                let msg = format!("{e:#}");
                let tr = &mut self.requests[id as usize];
                tr.state = RequestState::Failed(msg.clone());
                self.registry.record(tr);
                events.push(Event::Failed { id, error: msg });
            }
        }
    }

    /// Restore-on-resume: promote the session's parked pages back to RAM
    /// (faulting spilled pages in from disk), re-import them, and put the
    /// session back in the active set. A spill file that no longer
    /// decodes is a `SwapFault`: the dormant session is dropped and the
    /// request re-queued from scratch — generation is deterministic per
    /// seed, so the fresh run yields the same tokens.
    fn resume_swapped(&mut self, id: RequestId, events: &mut Vec<Event>) {
        let mut session = self.swapped.remove(&id).expect("swapped session present");
        let tables = self.parked.remove(&id).unwrap_or_default();
        self.prefetched.remove(&id);
        if let Err(e) = self.pool.promote(&tables) {
            for ps in &tables {
                self.pool.free_state(ps);
            }
            drop(session);
            self.registry.swap_faults += 1;
            eprintln!("[coordinator] swap fault on request {id}, re-queueing: {e:#}");
            self.requests[id as usize].state = RequestState::Queued;
            self.queue.push_front(id);
            events.push(Event::SwapFault { id });
            return;
        }
        match session.resume(tables) {
            Ok(()) => {
                self.pool.reserve(id, session.state_bytes());
                self.registry.swap_ins += 1;
                self.requests[id as usize].state = RequestState::Running;
                self.active.push(ActiveEntry { id, session });
                events.push(Event::Resumed { id });
            }
            Err(e) => {
                let msg = format!("resume after swap: {e:#}");
                let result = session.finish();
                let tr = &mut self.requests[id as usize];
                tr.service_secs =
                    tr.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
                tr.result = Some(result);
                tr.state = RequestState::Failed(msg.clone());
                self.registry.record(tr);
                events.push(Event::Failed { id, error: msg });
            }
        }
    }

    /// Swap out the lowest-priority active session, provided it is
    /// strictly below `prio`. Returns whether bytes were freed.
    fn preempt_below(&mut self, prio: i32, events: &mut Vec<Event>) -> bool {
        let victim = self
            .active
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| self.requests[e.id as usize].priority)
            .map(|(i, e)| (i, self.requests[e.id as usize].priority));
        let Some((idx, vprio)) = victim else { return false };
        if vprio >= prio {
            return false;
        }
        let mut entry = self.active.remove(idx);
        let id = entry.id;
        match entry.session.suspend() {
            Ok(tables) => {
                self.pool.release(id);
                // demote unshared pages (int8 and/or disk per config);
                // a demotion error leaves pages resident, which only
                // costs RAM, never correctness
                if let Err(e) = self.pool.park_cold(&tables) {
                    eprintln!(
                        "[coordinator] cold-park of request {id} incomplete: {e:#}"
                    );
                }
                self.parked.insert(id, tables);
                self.swapped.insert(id, entry.session);
                self.requests[id as usize].state = RequestState::Swapped;
                // re-queue behind the preemptor: it resumes as soon as
                // bytes free up again
                self.queue.push_back(id);
                self.registry.swap_outs += 1;
                events.push(Event::SwappedOut { id });
                true
            }
            Err(e) => {
                // a session that cannot suspend is lost — fail it with
                // its partial output, which also frees its bytes
                let msg = format!("suspend for swap: {e:#}");
                self.pool.release(id);
                let result = entry.session.finish();
                let tr = &mut self.requests[id as usize];
                tr.service_secs =
                    tr.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
                tr.result = Some(result);
                tr.state = RequestState::Failed(msg.clone());
                self.registry.record(tr);
                events.push(Event::Failed { id, error: msg });
                true
            }
        }
    }

    /// Run one `step()` per active session. With a backend present (and
    /// batching on), sessions advance in lock-step **waves** under the
    /// plan/apply protocol: every session runs host-side work up to its
    /// next batchable kernel op, the pending ops are grouped by
    /// [`PlanKey`] and issued as fused backend invocations, and the wave
    /// repeats until every session completed its step. Per-session op
    /// sequences are untouched — only cross-session execution fuses — so
    /// outputs, step events and commit order are byte-identical to the
    /// sequential rotation (pinned by `rust/tests/batched_parity.rs`).
    /// Sessions that do not implement the protocol (scripted tests, any
    /// foreign `EngineSession`) fall back to plain `step()` at their
    /// rotation position.
    fn step_active(&mut self, events: &mut Vec<Event>) {
        let n = self.active.len();
        if n == 0 {
            return;
        }
        let start = self.rr % n;
        self.rr = self.rr.wrapping_add(1);
        let order: Vec<usize> = (0..n).map(|k| (start + k) % n).collect();
        let batched = self.batching && self.backend.is_some();
        // honest occupancy: a width ≥ 2 group only counts as fused when
        // the backend's `*_batch` ops actually fuse (pjrt inherits the
        // sequential defaults and must not report phantom fusion)
        let backend_fuses = self.backend.map(|b| b.fuses_batches()).unwrap_or(false);
        let mut results: Vec<Option<Result<StepOutcome>>> = Vec::with_capacity(n);
        results.resize_with(n, || None);
        let mut planned = vec![false; n];
        let mut tick_groups = 0usize;
        loop {
            // advance every undecided session to its next pending op,
            // completion, or sequential-fallback step
            for &i in &order {
                if results[i].is_some() || planned[i] {
                    continue;
                }
                // failpoint: surface a synthetic backend error for this
                // session's step (exercises the Failed path end to end)
                if self.faults.backend_err_rate > 0.0
                    && self.fault_rng.f64() < self.faults.backend_err_rate
                {
                    results[i] =
                        Some(Err(anyhow::anyhow!("injected backend error (failpoint)")));
                    continue;
                }
                if !batched {
                    self.registry.fallback_steps += 1;
                    results[i] = Some(self.active[i].session.step());
                    continue;
                }
                match self.active[i].session.drive() {
                    Ok(Drive::Pending) => planned[i] = true,
                    Ok(Drive::Complete(o)) => results[i] = Some(Ok(o)),
                    Ok(Drive::Unsupported) => {
                        self.registry.fallback_steps += 1;
                        results[i] = Some(self.active[i].session.step());
                    }
                    Err(e) => results[i] = Some(Err(e)),
                }
            }
            if results.iter().all(|r| r.is_some()) {
                break;
            }
            // move the pending plans out (rotation order) …
            let mut flight: Vec<InFlight> = Vec::new();
            for &i in &order {
                if !planned[i] {
                    continue;
                }
                match self.active[i].session.take_pending() {
                    Some((plan, state)) => flight.push(InFlight { idx: i, plan, state }),
                    None => {
                        planned[i] = false;
                        results[i] = Some(Err(anyhow::anyhow!(
                            "session reported a pending op but exposed none"
                        )));
                    }
                }
            }
            // … group by geometry key …
            let mut groups: Vec<(PlanKey, Vec<usize>)> = Vec::new();
            for (fi, f) in flight.iter().enumerate() {
                let key = f.plan.key();
                match groups.iter_mut().find(|(k, _)| *k == key) {
                    Some((_, v)) => v.push(fi),
                    None => groups.push((key, vec![fi])),
                }
            }
            // … execute each group (fused when width ≥ 2) …
            for (_, members) in &groups {
                let be = self.backend.expect("batched path requires a backend");
                let outcome = if members.len() == 1 {
                    let f = &mut flight[members[0]];
                    exec_single(be, &f.plan, &mut f.state)
                } else {
                    let mut plans: Vec<&KernelPlan> = Vec::with_capacity(members.len());
                    let mut states: Vec<&mut StateBuf> = Vec::with_capacity(members.len());
                    for (fi, f) in flight.iter_mut().enumerate() {
                        if members.contains(&fi) {
                            plans.push(&f.plan);
                            states.push(&mut f.state);
                        }
                    }
                    exec_batch(be, &plans, &mut states)
                };
                if members.len() >= 2 && backend_fuses {
                    self.registry.batch_groups += 1;
                    self.registry.batch_ops_fused += members.len() as u64;
                    self.registry.batch_width_max =
                        self.registry.batch_width_max.max(members.len());
                    tick_groups += 1;
                } else {
                    self.registry.batch_ops_single += members.len() as u64;
                }
                if let Err(e) = outcome {
                    // batch errors are invariant violations; fused
                    // backends validate before mutating, and a
                    // sequential-default backend may leave earlier
                    // members executed — either way every member is
                    // failed here, so no half-executed state is ever
                    // stepped again
                    let msg = format!("batched kernel exec: {e:#}");
                    for &fi in members {
                        results[flight[fi].idx] = Some(Err(anyhow::anyhow!(msg.clone())));
                    }
                }
            }
            // … and hand the (mutated) states back for the next wave
            for f in flight {
                self.active[f.idx].session.restore_pending(f.state);
                planned[f.idx] = false;
            }
        }
        self.registry.batch_tick_groups = tick_groups;
        let mut done: Vec<RequestId> = Vec::new();
        for &i in &order {
            let id = self.active[i].id;
            match results[i].take().expect("every active session stepped") {
                Ok(outcome) => {
                    let tr = &mut self.requests[id as usize];
                    tr.steps += 1;
                    if !outcome.new_tokens.is_empty() || outcome.finished {
                        events.push(Event::Step {
                            id,
                            new_tokens: outcome.new_tokens,
                            step: tr.steps,
                            finished: outcome.finished,
                        });
                    }
                    if outcome.finished {
                        done.push(id);
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    self.requests[id as usize].state =
                        RequestState::Failed(msg.clone());
                    events.push(Event::Failed { id, error: msg });
                    done.push(id);
                }
            }
        }
        for id in done {
            let idx = self
                .active
                .iter()
                .position(|e| e.id == id)
                .expect("finished id in active set");
            let entry = self.active.remove(idx);
            self.pool.release(id);
            // fold the final round's speculation counters before the
            // session is consumed, then drop the controller state (the
            // per-engine probe keeps what it learned)
            if self.policy.enabled() {
                if let Some(obs) = entry.session.spec_observe() {
                    let kind = entry.session.kind();
                    let up = self.policy.observe(id, kind, obs);
                    self.registry.note_spec(kind, &up);
                }
                self.policy.finish(id);
            }
            let result = entry.session.finish();
            let tr = &mut self.requests[id as usize];
            tr.service_secs =
                tr.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
            tr.result = Some(result);
            if tr.state == RequestState::Running {
                tr.state = RequestState::Done;
                events.push(Event::Finished { id });
            }
            self.registry.record(tr);
        }
    }

    /// Drive the scheduler until `id` reaches a terminal state; other
    /// admitted requests make progress on the same ticks (continuous
    /// batching, not head-of-line blocking). Returns all events seen.
    pub fn run_until(&mut self, id: RequestId) -> Vec<Event> {
        let mut all = Vec::new();
        loop {
            match self.requests.get(id as usize) {
                Some(tr) if !tr.state.is_terminal() => {}
                _ => return all,
            }
            if self.idle() {
                return all; // id is not in the system anymore
            }
            all.extend(self.tick());
        }
    }

    /// Drain queue and active set completely.
    pub fn run_all(&mut self) {
        while !self.idle() {
            self.tick();
        }
        self.sync_backend_counters();
    }

    /// Enter drain mode (server shutdown): further submits are rejected
    /// with "server shutting down" while queued/active/swapped work runs
    /// to completion through the normal tick path. Returns one
    /// [`Event::Draining`] per non-terminal request so streaming clients
    /// can be told the stream will end cleanly. Idempotent: repeat calls
    /// return an empty vec.
    pub fn begin_drain(&mut self) -> Vec<Event> {
        if self.draining {
            return Vec::new();
        }
        self.draining = true;
        self.requests
            .iter()
            .filter(|tr| !tr.state.is_terminal())
            .map(|tr| Event::Draining { id: tr.id })
            .collect()
    }

    /// True once [`Coordinator::begin_drain`] has been called.
    pub fn draining(&self) -> bool {
        self.draining
    }

    pub fn get(&self, id: RequestId) -> Option<&TrackedRequest> {
        self.requests.get(id as usize)
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// No queued and no active work.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }
}

/// Aggregate stats across a batch of GenStats (used by the harness).
pub fn aggregate(stats: &[GenStats]) -> GenStats {
    let mut agg = GenStats::default();
    for s in stats {
        agg.new_tokens += s.new_tokens;
        agg.decode_secs += s.decode_secs;
        agg.prefill_secs += s.prefill_secs;
        agg.verify_steps += s.verify_steps;
        agg.accepted_total += s.accepted_total;
        agg.draft_secs += s.draft_secs;
        agg.verify_secs += s.verify_secs;
        agg.other_secs += s.other_secs;
        agg.full_steps += s.full_steps;
        agg.partial_steps += s.partial_steps;
        agg.refresh_steps += s.refresh_steps;
        agg.offload_secs += s.offload_secs;
    }
    agg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_limits() {
        // Coordinator::submit validation is runtime-independent; the full
        // scheduler behaviour is covered in rust/tests/scheduler.rs with
        // scripted sessions.
        let a = Admission::default();
        assert!(a.max_prompt > 1024);
        assert!(a.max_active >= 1);
    }

    #[test]
    fn aggregate_sums() {
        let a = GenStats { new_tokens: 10, decode_secs: 1.0, ..Default::default() };
        let b = GenStats { new_tokens: 5, decode_secs: 0.5, ..Default::default() };
        let s = aggregate(&[a, b]);
        assert_eq!(s.new_tokens, 15);
        assert!((s.decode_secs - 1.5).abs() < 1e-12);
        assert!((s.throughput() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn registry_summary_has_gauges() {
        let r = Registry { queue_depth: 3, active_sessions: 2, ..Default::default() };
        let s = r.summary();
        assert!(s.contains("queue_depth=3"));
        assert!(s.contains("active=2"));
        assert!(s.contains("p50_ttft="));
    }
}
