//! Serving coordinator: session/request management over one runtime.
//!
//! The PJRT CPU client is single-device and the engines are synchronous,
//! so the coordinator runs a FIFO + round-robin *decode scheduler*: many
//! requests can be admitted concurrently (from the TCP server or the
//! batch API) and are interleaved at generation granularity, with
//! per-request telemetry and an aggregate metrics registry. This is the
//! vLLM-router-shaped outer loop the L3 layer owns; the inner
//! draft/verify loop lives in `engine`.

use std::collections::VecDeque;

use anyhow::Result;

use crate::config::{Config, EngineKind};
use crate::engine::{self, GenRequest, GenResult};
use crate::metrics::GenStats;
use crate::runtime::Runtime;
use crate::util::stats::Samples;
use crate::util::Stopwatch;

/// Request ids are coordinator-scoped.
pub type RequestId = u64;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Running,
    Done,
    Failed(String),
}

#[derive(Debug)]
pub struct TrackedRequest {
    pub id: RequestId,
    pub req: GenRequest,
    pub engine: EngineKind,
    pub state: RequestState,
    pub result: Option<GenResult>,
    pub queued_secs: f64,
    pub service_secs: f64,
}

/// Aggregate serving metrics (reported by `metrics` server command and
/// the e2e example).
#[derive(Debug, Default)]
pub struct Registry {
    pub completed: u64,
    pub failed: u64,
    pub tokens_out: u64,
    pub latency: Samples,
    pub queue_wait: Samples,
    pub throughput_tok_s: Samples,
    pub accept_len: Samples,
}

impl Registry {
    pub fn record(&mut self, tr: &TrackedRequest) {
        match &tr.state {
            RequestState::Done => {
                self.completed += 1;
                if let Some(r) = &tr.result {
                    self.tokens_out += r.tokens.len() as u64;
                    self.latency.push(tr.service_secs);
                    self.queue_wait.push(tr.queued_secs);
                    self.throughput_tok_s.push(r.stats.throughput());
                    if r.stats.verify_steps > 0 {
                        self.accept_len.push(r.stats.accept_len());
                    }
                }
            }
            RequestState::Failed(_) => self.failed += 1,
            _ => {}
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "completed={} failed={} tokens={} p50_latency={:.2}s p99={:.2}s \
             mean_tok_s={:.1} mean_tau={:.2}",
            self.completed,
            self.failed,
            self.tokens_out,
            self.latency.p50(),
            self.latency.p99(),
            self.throughput_tok_s.mean(),
            self.accept_len.mean(),
        )
    }
}

/// Admission control limits.
#[derive(Debug, Clone)]
pub struct Admission {
    pub max_prompt: usize,
    pub max_new: usize,
    pub max_queue: usize,
}

impl Default for Admission {
    fn default() -> Self {
        Admission { max_prompt: 7 * 1024, max_new: 1024, max_queue: 256 }
    }
}

pub struct Coordinator<'rt> {
    rt: &'rt Runtime,
    pub cfg: Config,
    pub admission: Admission,
    queue: VecDeque<RequestId>,
    requests: Vec<TrackedRequest>,
    pub registry: Registry,
}

impl<'rt> Coordinator<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: Config) -> Coordinator<'rt> {
        Coordinator {
            rt,
            cfg,
            admission: Admission::default(),
            queue: VecDeque::new(),
            requests: Vec::new(),
            registry: Registry::default(),
        }
    }

    /// Admit a request (engine defaults to the config's engine).
    pub fn submit(
        &mut self,
        req: GenRequest,
        engine: Option<EngineKind>,
    ) -> Result<RequestId> {
        if req.prompt.len() > self.admission.max_prompt {
            anyhow::bail!(
                "prompt {} exceeds admission limit {}",
                req.prompt.len(),
                self.admission.max_prompt
            );
        }
        if req.max_new > self.admission.max_new {
            anyhow::bail!("max_new {} exceeds limit", req.max_new);
        }
        if self.queue.len() >= self.admission.max_queue {
            anyhow::bail!("queue full ({})", self.queue.len());
        }
        let id = self.requests.len() as RequestId;
        self.requests.push(TrackedRequest {
            id,
            req,
            engine: engine.unwrap_or(self.cfg.engine),
            state: RequestState::Queued,
            result: None,
            queued_secs: 0.0,
            service_secs: 0.0,
        });
        self.queue.push_back(id);
        Ok(id)
    }

    /// Run the next queued request to completion; returns its id.
    pub fn step(&mut self) -> Option<RequestId> {
        let id = self.queue.pop_front()?;
        let sw = Stopwatch::new();
        let (engine_kind, req) = {
            let tr = &mut self.requests[id as usize];
            tr.state = RequestState::Running;
            (tr.engine, tr.req.clone())
        };
        let mut cfg = self.cfg.clone();
        cfg.engine = engine_kind;
        let result = engine::generate_with(&cfg, self.rt, &req);
        let tr = &mut self.requests[id as usize];
        tr.service_secs = sw.total();
        match result {
            Ok(r) => {
                tr.result = Some(r);
                tr.state = RequestState::Done;
            }
            Err(e) => tr.state = RequestState::Failed(format!("{e:#}")),
        }
        let tr = &self.requests[id as usize];
        self.registry.record(tr);
        Some(id)
    }

    /// Drain the whole queue.
    pub fn run_all(&mut self) {
        while self.step().is_some() {}
    }

    pub fn get(&self, id: RequestId) -> Option<&TrackedRequest> {
        self.requests.get(id as usize)
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

/// Aggregate stats across a batch of GenStats (used by the harness).
pub fn aggregate(stats: &[GenStats]) -> GenStats {
    let mut agg = GenStats::default();
    for s in stats {
        agg.new_tokens += s.new_tokens;
        agg.decode_secs += s.decode_secs;
        agg.prefill_secs += s.prefill_secs;
        agg.verify_steps += s.verify_steps;
        agg.accepted_total += s.accepted_total;
        agg.draft_secs += s.draft_secs;
        agg.verify_secs += s.verify_secs;
        agg.other_secs += s.other_secs;
        agg.full_steps += s.full_steps;
        agg.partial_steps += s.partial_steps;
        agg.refresh_steps += s.refresh_steps;
        agg.offload_secs += s.offload_secs;
    }
    agg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_limits() {
        // Coordinator::submit validation is runtime-independent; build a
        // dangling coordinator via a null-ish runtime is not possible, so
        // validate the Admission type directly here and the full flow in
        // rust/tests/.
        let a = Admission::default();
        assert!(a.max_prompt > 1024);
    }

    #[test]
    fn aggregate_sums() {
        let a = GenStats { new_tokens: 10, decode_secs: 1.0, ..Default::default() };
        let b = GenStats { new_tokens: 5, decode_secs: 0.5, ..Default::default() };
        let s = aggregate(&[a, b]);
        assert_eq!(s.new_tokens, 15);
        assert!((s.decode_secs - 1.5).abs() < 1e-12);
        assert!((s.throughput() - 10.0).abs() < 1e-9);
    }
}
